//! Explore the latency–memory–accuracy trade-off surface that CodeGEMM's
//! unified kernel exposes (paper §2.2 Table 1, Figure 4): sweep
//! (v, m, b, g) at a fixed ~2-bit budget and report Eq.-1 footprint,
//! weight reconstruction error, modelled A100 block latency, and
//! measured tiny-model perplexity.
//!
//! Run: `cargo run --release --example explore_tradeoffs`

use codegemm::bench::tables::EvalContext;
use codegemm::bench::workloads::LLAMA3_8B;
use codegemm::config::QuantConfig;
use codegemm::model::EngineKind;
use codegemm::quant::footprint::bits_per_weight;
use codegemm::quant::Quantizer;
use codegemm::simulator::{Method, Simulator};
use codegemm::util::prng::Prng;
use codegemm::util::stats;
use codegemm::util::table::{fnum, Table};

fn main() {
    let sim = Simulator::a100();
    let ctx = EvalContext::load(std::path::Path::new("artifacts"));
    println!("accuracy substrate: {}\n", ctx.source);

    // Configurations from Table 1 (same ~2-bit budget, very different
    // shapes) plus finer-g variants.
    let sweep: &[(usize, usize, usize, i64)] = &[
        (4, 1, 8, -1),
        (8, 2, 8, -1),
        (16, 4, 8, -1),
        (8, 1, 8, 16),
        (16, 3, 8, 32),
        (4, 1, 8, 128),
        (8, 2, 8, 128),
        (4, 1, 8, 32),
    ];

    let (n, k) = (256, 512);
    let w = Prng::seeded(3).normal_vec(n * k, 0.02);

    let mut t = Table::new(
        "~2-bit configuration space (one kernel, many operating points)",
        &["config", "q̄ (Eq.1)", "recon rel-err", "A100 block µs", "tiny ppl", "tiny top1 %"],
    );
    for &(v, m, b, g) in sweep {
        let Ok(cfg) = QuantConfig::new(v, m, b, g) else { continue };
        let bits = bits_per_weight(&cfg, 4096, 4096).total;
        let q = Quantizer::new(cfg).quantize(&w, n, k);
        let rel = stats::rel_l2(&q.dequantize(), &w);
        let lat = sim.block_latency_us(&Method::codegemm(cfg), &LLAMA3_8B, 1);
        // Accuracy on the tiny model needs g | 128 and g | 352: remap to
        // the nearest valid tiny group size.
        let tiny_g: i64 = match g {
            -1 => -1,
            16 => 16,
            _ => 32,
        };
        let acc = ctx.measure(EngineKind::codegemm(QuantConfig::new(v, m, b, tiny_g).unwrap()));
        t.row(vec![
            cfg.label(),
            fnum(bits, 3),
            fnum(rel, 3),
            fnum(lat, 1),
            fnum(acc.ppl, 2),
            fnum(acc.top1, 1),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Reading the table: row-wise g=-1 keeps footprint lowest but hurts accuracy;\n\
         finer g buys accuracy for small footprint+latency cost until g=v (paper Fig. 4);\n\
         larger m at fixed q̄ trades latency for accuracy (m/v complexity factor)."
    );
}
