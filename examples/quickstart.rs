//! Quickstart: quantize a weight matrix with the additive-codebook
//! pipeline, run CodeGEMM (Psumbook gather) and verify it is *exactly*
//! dequantize-then-GEMM — the paper's central algebraic identity — then
//! peek at footprint, complexity and on-chip usage.
//!
//! Run: `cargo run --release --example quickstart`

use codegemm::config::QuantConfig;
use codegemm::gemm::{CodeGemmEngine, DenseEngine, DequantEngine, GemmEngine};
use codegemm::quant::footprint::bits_per_weight;
use codegemm::quant::Quantizer;
use codegemm::util::prng::Prng;
use codegemm::util::stats;

fn main() {
    // A weight matrix (stand-in for one Llama linear layer).
    let (n, k) = (4096, 1024); // N >> 2^b so Psumbook build amortizes (paper assumes M >> 2^b)
    let w = Prng::seeded(7).normal_vec(n * k, 0.02);

    // The paper's headline 2-bit configuration: 1 codebook, vectors of 4,
    // 8-bit codes, group-128 normalization.
    let cfg = QuantConfig::m1v4g128();
    let q = Quantizer::new(cfg).quantize(&w, n, k);
    let f = bits_per_weight(&cfg, n, k);
    println!("quantized {n}×{k} with {}: q̄ = {:.3} bits/weight", cfg.label(), f.total);
    println!("  reconstruction rel-err: {:.3}", stats::rel_l2(&q.dequantize(), &w));

    // One activation vector.
    let x = Prng::seeded(8).normal_vec(k, 1.0);

    // CodeGEMM: build the Psumbook once per tile, gather by code.
    let mut codegemm = CodeGemmEngine::from_quantized(&q);
    let y = codegemm.gemv(&x);

    // The dequantization-based baseline computes the same thing the slow way.
    let mut dequant = DequantEngine::from_quantized(&q);
    let y_dq = dequant.gemv(&x);

    // And dense GEMM over the dequantized weights is the oracle.
    let mut oracle = DenseEngine::new(q.dequantize(), n, k);
    let y_ref = oracle.gemv(&x);

    println!("  CodeGEMM vs oracle rel-err: {:.2e}", stats::rel_l2(&y, &y_ref));
    println!("  Dequant  vs oracle rel-err: {:.2e}", stats::rel_l2(&y_dq, &y_ref));
    assert!(stats::rel_l2(&y, &y_ref) < 1e-4, "Psumbook gather ≡ dequantize-then-GEMM");

    // The paper's complexity story, measured (§3):
    let c = codegemm.counters();
    let dense_macs = (n * k) as u64;
    println!("\ncomplexity (measured work):");
    println!("  dense GEMV MACs:        {dense_macs}");
    println!("  CodeGEMM build ops:     {} (m·2^b·K)", c.build_ops);
    println!("  CodeGEMM read ops:      {} (m·N·K/v)", c.read_ops);
    println!(
        "  reduction factor:       {:.2}× (paper: ≈ v/m = {:.0}×)",
        dense_macs as f64 / (c.build_ops + c.read_ops) as f64,
        cfg.v as f64 / cfg.m as f64
    );
    println!("\non-chip footprint per tile:");
    println!("  Psumbook: {} bytes (CodeGEMM)", codegemm.psumbook_bytes());
    println!("  codebook: {} bytes (dequant baseline)", dequant.codebook_bytes());
}
