//! End-to-end serving driver (the EXPERIMENTS.md §E2E run): load the AOT
//! artifacts (trained tiny model, quantized, lowered through the L1
//! Pallas kernel to HLO), compile on the PJRT CPU client, and serve a
//! batched workload through the L3 coordinator — router, continuous
//! batcher, metrics. Python is not involved at any point of this binary.
//!
//! Falls back to the pure-Rust native backend when artifacts are missing
//! so the example always runs; the AOT path is the point, though.
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e`

use codegemm::config::{ModelConfig, QuantConfig, ServeConfig};
use codegemm::coordinator::{DecodeBackend, NativeBackend, PjrtBackend, Request, Server};
use codegemm::model::{EngineKind, ModelWeights};
use codegemm::runtime::ModelRuntime;
use codegemm::util::npy::TensorFile;
use codegemm::util::prng::Prng;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");

    // --- backend: AOT/PJRT when available ---------------------------------
    let backend: Box<dyn DecodeBackend> = if artifacts.join("manifest.json").exists() {
        let rt = ModelRuntime::load(artifacts)?;
        println!(
            "AOT backend: engine={}, quant={:?}, compiled batches {:?}",
            rt.manifest.engine,
            rt.manifest.quant.map(|q| q.label()),
            rt.batch_sizes()
        );
        Box::new(PjrtBackend::new(rt))
    } else {
        println!("artifacts missing — native fallback (run `make artifacts` for the AOT path)");
        let w = ModelWeights::random(ModelConfig::tiny(), 7);
        Box::new(NativeBackend::new(&w, EngineKind::codegemm(QuantConfig::new(4, 1, 8, 32)?), 8))
    };
    let max_batch = backend.max_batch();

    // --- workload: prompts drawn from the model's own training corpus -----
    let prompts: Vec<Vec<usize>> = match TensorFile::load(artifacts.join("corpus.bin")) {
        Ok(tf) => {
            let toks = tf.get("tokens")?.data.as_i32()?.to_vec();
            let mut rng = Prng::seeded(11);
            (0..24)
                .map(|_| {
                    let s = rng.index(toks.len() - 20);
                    toks[s..s + 12].iter().map(|&t| t as usize).collect()
                })
                .collect()
        }
        Err(_) => {
            let mut rng = Prng::seeded(11);
            (0..24).map(|_| (0..12).map(|_| rng.index(255) + 1).collect()).collect()
        }
    };

    // --- serve -------------------------------------------------------------
    let cfg = ServeConfig {
        max_batch,
        batch_window_us: 500,
        max_new_tokens: 32,
        temperature: 0.0,
        ..Default::default()
    };
    println!("serving {} requests (max_batch {max_batch}, greedy, 32 new tokens)…", prompts.len());
    let server = Server::start(backend, cfg);
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| server.submit(Request::new(i as u64, p.clone(), 32)))
        .collect();
    let mut generated = 0usize;
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait();
        generated += r.tokens.len();
        if i < 3 {
            println!(
                "  req {i}: {} tokens, ttft {:.1} ms, finish {:?}",
                r.tokens.len(),
                r.ttft_s * 1e3,
                r.finish
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = server.shutdown();
    println!("\n{}", report.render());
    println!(
        "\nE2E: {generated} tokens in {:.2}s = {:.1} tok/s aggregate (batched decode through {} layers of AOT-compiled HLO)",
        wall,
        generated as f64 / wall,
        ModelConfig::tiny().n_layers,
    );
    Ok(())
}
