//! Drive the calibrated A100 analytic model directly: ask "what would
//! this kernel cost on the paper's testbed?" for arbitrary shapes,
//! inspect the fitted coefficients, and check the shared-memory capacity
//! story (the mechanism behind AQLM-1×16's collapse and the headline
//! 8.93× at 70B).
//!
//! Run: `cargo run --release --example simulate_a100 [N K [M]]`

use codegemm::bench::workloads::GemmShape;
use codegemm::config::QuantConfig;
use codegemm::simulator::memory::{blocks_per_sm, fits_smem, overflow_gather_bytes};
use codegemm::simulator::{Method, Simulator, A100_80GB};
use codegemm::util::table::{fnum, Table};

fn main() {
    let args: Vec<usize> =
        std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let (n, k) = if args.len() >= 2 { (args[0], args[1]) } else { (8192, 8192) };
    let m_batch = if args.len() >= 3 { args[2] } else { 1 };

    let sim = Simulator::a100();
    println!("calibration quality (rel-RMSE per fitted family):");
    for (fam, rmse) in &sim.fit_rmse {
        println!("  {fam:12} {:.1}%", 100.0 * rmse);
    }

    let shape = GemmShape::new(m_batch, n, k);
    let methods = [
        Method::CuBlas,
        Method::CuBlasPlusDequant,
        Method::LutGemm { q: 2, g: 128 },
        Method::QuipSharp,
        Method::Qtip,
        Method::aqlm_1x16(),
        Method::aqlm_2x8(),
        Method::codegemm_m2v8g128(),
        Method::codegemm_m1v4g128(),
    ];
    let mut t = Table::new(
        &format!("modelled A100 cost at (M={m_batch}, N={n}, K={k})"),
        &["method", "µs", "q̄ bits", "weight MB", "smem/block", "fits smem", "blocks/SM"],
    );
    for m in &methods {
        t.row(vec![
            m.label(),
            fnum(sim.latency_us(m, shape), 2),
            fnum(m.bits_per_weight(n, k), 3),
            fnum(m.weight_bytes(n, k) / 1e6, 2),
            format!("{} B", m.smem_bytes(m_batch)),
            if fits_smem(m, &A100_80GB, m_batch) { "yes".into() } else { "NO".into() },
            blocks_per_sm(m, &A100_80GB, m_batch).to_string(),
        ]);
    }
    println!("\n{}", t.render());

    // The §2.3 capacity argument, explicitly.
    let a116 = Method::aqlm_1x16();
    println!(
        "AQLM-1×16 codebook = {} KB > {} KB smem ⇒ {} MB of L2 gather traffic at this shape",
        a116.smem_bytes(1) / 1024,
        A100_80GB.smem_per_sm / 1024,
        fnum(overflow_gather_bytes(&a116, &A100_80GB, m_batch, n, k) / 1e6, 1),
    );

    // Sweep batch to show the CUDA-core batch-scaling limitation (§6).
    let mut t = Table::new(
        "batch scaling (paper §A.4: CUDA-core kernels scale with M, tensor-core cuBLAS doesn't)",
        &["M", "cuBLAS", "CG-m1v4", "AQLM-2x8", "AQLM-1x16"],
    );
    for mb in [1usize, 2, 4, 8, 16, 32] {
        let s = GemmShape::new(mb, n, k);
        t.row(vec![
            mb.to_string(),
            fnum(sim.latency_us(&Method::CuBlas, s), 1),
            fnum(sim.latency_us(&Method::codegemm_m1v4g128(), s), 1),
            fnum(sim.latency_us(&Method::aqlm_2x8(), s), 1),
            fnum(sim.latency_us(&Method::aqlm_1x16(), s), 1),
        ]);
    }
    println!("{}", t.render());

    // What-if: the same kernels on H100 (more smem, more bandwidth).
    let h100 = Simulator::fit(codegemm::simulator::H100_SXM, &codegemm::simulator::kernels::calibration_samples());
    let cfg = QuantConfig::m1v4g128();
    println!(
        "what-if H100: CodeGEMM-{} at (1, {n}, {k}) = {} µs (A100 {} µs)",
        cfg.label(),
        fnum(h100.latency_us(&Method::codegemm(cfg), GemmShape::new(1, n, k)), 2),
        fnum(sim.latency_us(&Method::codegemm(cfg), GemmShape::new(1, n, k)), 2),
    );
}
