"""TensorFile (CGTF) container: roundtrip, format details pinned to the
rust implementation, error cases."""

import numpy as np
import pytest

from compile.export import MAGIC, TensorFile


def test_roundtrip_all_dtypes():
    tf = TensorFile()
    tf.push("w", np.arange(6, dtype=np.float32).reshape(2, 3))
    tf.push("codes", np.array([0, 255, 7], np.uint8))
    tf.push("idx", np.array([[-1, 2]], np.int32))
    tf.push("halfbits", np.array([0x3C00, 0xC000], np.uint16))
    back = TensorFile.from_bytes(tf.to_bytes())
    assert back.names() == ["w", "codes", "idx", "halfbits"]
    for n in tf.names():
        np.testing.assert_array_equal(back.get(n), tf.get(n))
        assert back.get(n).dtype == tf.get(n).dtype


def test_format_layout_matches_rust():
    """Byte-level pinning: magic, little-endian header length, compact JSON."""
    tf = TensorFile()
    tf.push("x", np.array([1.0], np.float32))
    raw = tf.to_bytes()
    assert raw[:8] == MAGIC == b"CGTF0001"
    hlen = int.from_bytes(raw[8:16], "little")
    header = raw[16 : 16 + hlen].decode()
    assert header.startswith('{"tensors":[{"name":"x","dtype":"f32","shape":[1],')
    # data section is exactly the f32 payload
    assert raw[16 + hlen :] == np.array([1.0], "<f4").tobytes()


def test_duplicate_name_rejected():
    tf = TensorFile()
    tf.push("a", np.zeros(1, np.float32))
    with pytest.raises(ValueError):
        tf.push("a", np.zeros(1, np.float32))


def test_unsupported_dtype_rejected():
    tf = TensorFile()
    with pytest.raises(ValueError):
        tf.push("bad", np.zeros(1, np.float64))


def test_bad_magic_rejected():
    with pytest.raises(ValueError):
        TensorFile.from_bytes(b"NOTMAGIC" + b"\0" * 16)


def test_file_roundtrip(tmp_path):
    tf = TensorFile()
    tf.push("w", np.random.default_rng(0).normal(size=(4, 4)).astype(np.float32))
    p = tmp_path / "t.bin"
    tf.save(p)
    back = TensorFile.load(p)
    np.testing.assert_array_equal(back.get("w"), tf.get("w"))
