"""L2 model semantics: the decode step must agree with the teacher-forced
forward (KV-cache correctness), respect batch independence, and run under
both engines."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    TINY,
    ModelConfig,
    init_params,
    make_decode_step,
    param_names,
    train_forward,
)
from compile.aot import quantize_model
from compile.quantize import QuantConfig


@pytest.fixture(scope="module")
def params():
    return init_params(TINY, seed=3)


def dense_weight_list(params):
    names = param_names(TINY)
    return names, [jnp.asarray(params[n]) for n in names]


def run_decode(params, tokens_seq, batch=1):
    """Decode a token sequence through the step function, return final logits."""
    cfg = TINY
    names, weights = dense_weight_list(params)
    step = make_decode_step(cfg, "dense", names)
    kv_k = jnp.zeros((cfg.n_layers, batch, cfg.max_seq, cfg.kv_dim), jnp.float32)
    kv_v = jnp.zeros_like(kv_k)
    logits = None
    for pos, t in enumerate(tokens_seq):
        tok = jnp.full((batch,), t, jnp.int32)
        p = jnp.full((batch,), pos, jnp.int32)
        logits, kv_k, kv_v = step(tok, p, kv_k, kv_v, *weights)
    return np.asarray(logits)


def test_decode_matches_teacher_forced(params):
    seq = [5, 99, 42, 7]
    logits_step = run_decode(params, seq)
    full = train_forward({k: jnp.asarray(v) for k, v in params.items()}, TINY, jnp.asarray([seq], jnp.int32))
    logits_full = np.asarray(full)[0, -1]
    np.testing.assert_allclose(logits_step[0], logits_full, atol=1e-3, rtol=1e-3)


def test_batch_slots_independent(params):
    cfg = TINY
    names, weights = dense_weight_list(params)
    step = make_decode_step(cfg, "dense", names)
    B = 3
    kv_k = jnp.zeros((cfg.n_layers, B, cfg.max_seq, cfg.kv_dim), jnp.float32)
    kv_v = jnp.zeros_like(kv_k)
    # different first tokens, same second token
    l1, kv_k, kv_v = step(jnp.asarray([1, 200, 1], jnp.int32), jnp.zeros(B, jnp.int32), kv_k, kv_v, *weights)
    l2, _, _ = step(jnp.asarray([9, 9, 9], jnp.int32), jnp.ones(B, jnp.int32), kv_k, kv_v, *weights)
    l2 = np.asarray(l2)
    # slot 0 and 2 share history -> identical logits; slot 1 differs
    np.testing.assert_allclose(l2[0], l2[2], atol=1e-5)
    assert np.abs(l2[0] - l2[1]).max() > 1e-4


def test_masked_future_positions_do_not_leak(params):
    """Garbage in KV positions beyond `pos` must not affect logits."""
    cfg = TINY
    names, weights = dense_weight_list(params)
    step = make_decode_step(cfg, "dense", names)
    kv_clean = jnp.zeros((cfg.n_layers, 1, cfg.max_seq, cfg.kv_dim), jnp.float32)
    kv_dirty = kv_clean + 1e6 * jnp.asarray(
        (np.arange(cfg.max_seq) >= 5)[None, None, :, None].astype(np.float32)
    )
    tok = jnp.asarray([42], jnp.int32)
    pos = jnp.asarray([0], jnp.int32)
    lc, *_ = step(tok, pos, kv_clean, kv_clean, *weights)
    ld, *_ = step(tok, pos, kv_dirty, kv_dirty, *weights)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(ld), atol=1e-4)


def test_quantized_decode_step_runs_and_tracks_dense(params):
    cfg = TINY
    qcfg = QuantConfig(4, 2, 8, 32)
    qweights, names = quantize_model(params, cfg, qcfg)
    step = make_decode_step(cfg, "codegemm", names, quant_g=qcfg.g)
    weights = [jnp.asarray(qweights[n]) for n in names]
    kv_k = jnp.zeros((cfg.n_layers, 1, cfg.max_seq, cfg.kv_dim), jnp.float32)
    kv_v = jnp.zeros_like(kv_k)
    lq, kv_k, kv_v = step(jnp.asarray([17], jnp.int32), jnp.asarray([0], jnp.int32), kv_k, kv_v, *weights)
    lq = np.asarray(lq)
    assert lq.shape == (1, cfg.vocab)
    assert np.isfinite(lq).all()
    ld = run_decode(params, [17])
    # ~4-bit-class quantization: top-logit neighborhoods overlap
    corr = np.corrcoef(lq[0], ld[0])[0, 1]
    assert corr > 0.9, corr


def test_param_names_match_rust_contract():
    names = param_names(TINY)
    assert names[0] == "embedding"
    assert "layers.0.wq" in names and "layers.1.w_down" in names
    assert names[-1] == "lm_head"
    assert len(names) == 1 + TINY.n_layers * 9 + 2


def test_rope_position_sensitivity():
    """RoPE: position-dependent rotation that preserves vector norms."""
    from compile.model import rope_rotate, rope_tables

    cos, sin = rope_tables(TINY)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, TINY.hidden)).astype(np.float32))
    r0 = np.asarray(rope_rotate(x, cos[0], sin[0]))
    r5 = np.asarray(rope_rotate(x, cos[5], sin[5]))
    assert np.abs(r0 - r5).max() > 1e-3, "rotation must depend on position"
    # pos 0 is the identity rotation
    np.testing.assert_allclose(r0, np.asarray(x), atol=1e-6)
    # norms preserved per head
    hd = TINY.head_dim
    for h in range(TINY.n_heads):
        n_in = np.linalg.norm(np.asarray(x)[0, h * hd : (h + 1) * hd])
        n_out = np.linalg.norm(r5[0, h * hd : (h + 1) * hd])
        np.testing.assert_allclose(n_in, n_out, rtol=1e-5)
