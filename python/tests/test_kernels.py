"""L1 kernel correctness: the Pallas CodeGEMM kernel (and the dequant
baseline) must match the pure-jnp oracle to float tolerance across a
hypothesis-driven sweep of shapes, batch sizes, quantization configs and
tilings — the paper's central algebraic claim (§3)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.codegemm import codebook_bytes, codegemm_matmul, psumbook_bytes
from compile.kernels.dequant import dequant_matmul
from compile.kernels.ref import (
    codegemm_ref,
    codegemm_via_psumbook_ref,
    dequantize,
    psumbook_ref,
)
from compile.quantize import QuantConfig, quantize


def make_case(n, k, batch, cfg: QuantConfig, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.05, (n, k)).astype(np.float32)
    q = quantize(w, cfg, iters=4, seed=seed)
    x = rng.normal(0, 1.0, (batch, k)).astype(np.float32)
    return w, q, x


def args_of(q, x):
    return (
        jnp.asarray(x),
        jnp.asarray(q.codes),
        jnp.asarray(q.codebooks),
        jnp.asarray(q.scales),
    )


CONFIGS = [
    QuantConfig(4, 1, 8, 32),
    QuantConfig(4, 1, 8, 128),
    QuantConfig(8, 2, 8, 32),
    QuantConfig(8, 1, 6, -1),
    QuantConfig(4, 3, 5, 64),
    QuantConfig(16, 2, 4, 32),
]


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.label())
def test_pallas_matches_oracle(cfg):
    n, k, batch = 64, 128, 2
    _, q, x = make_case(n, k, batch, cfg)
    g = cfg.g if cfg.g > 0 else k
    y_ref = codegemm_ref(*args_of(q, x), g)
    y = codegemm_matmul(*args_of(q, x), g=cfg.g, tile_h=32, tile_w=32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("cfg", CONFIGS[:3], ids=lambda c: c.label())
def test_dequant_baseline_matches_oracle(cfg):
    n, k, batch = 64, 128, 3
    _, q, x = make_case(n, k, batch, cfg)
    g = cfg.g if cfg.g > 0 else k
    y_ref = codegemm_ref(*args_of(q, x), g)
    y = dequant_matmul(*args_of(q, x), g=cfg.g, tile_h=32, tile_w=32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4, rtol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(1, 3),
    k_tiles=st.integers(1, 4),
    batch=st.integers(1, 5),
    v=st.sampled_from([4, 8]),
    m=st.integers(1, 3),
    b=st.sampled_from([3, 5, 8]),
    tile_w=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shape_sweep(n_tiles, k_tiles, batch, v, m, b, tile_w, seed):
    """Property: for every valid (shape, config, tiling), pallas == oracle."""
    n = 32 * n_tiles
    k = tile_w * k_tiles
    g = tile_w  # group == tile keeps every combination valid
    cfg = QuantConfig(v=v, m=m, b=b, g=g)
    _, q, x = make_case(n, k, batch, cfg, seed=seed)
    y_ref = codegemm_ref(*args_of(q, x), g)
    y = codegemm_matmul(*args_of(q, x), g=g, tile_h=32, tile_w=tile_w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=3e-4, rtol=3e-4)


@settings(max_examples=15, deadline=None)
@given(
    batch=st.integers(1, 4),
    v=st.sampled_from([4, 8, 16]),
    m=st.integers(1, 2),
    seed=st.integers(0, 2**16),
)
def test_psumbook_is_all_inner_products(batch, v, m, seed):
    """Eq. 2: p[b,c,i,j] == ⟨centroid(c,i), x-subvector j⟩."""
    k = 64
    rng = np.random.default_rng(seed)
    codebooks = rng.normal(0, 1, (m, 16, v)).astype(np.float32)
    x = rng.normal(0, 1, (batch, k)).astype(np.float32)
    p = np.asarray(psumbook_ref(jnp.asarray(x), jnp.asarray(codebooks)))
    assert p.shape == (batch, m, 16, k // v)
    # spot-check a handful of entries exactly
    for b_ in range(batch):
        for c in range(m):
            for i in (0, 7, 15):
                for j in (0, k // v - 1):
                    want = float(codebooks[c, i] @ x[b_, j * v : (j + 1) * v])
                    np.testing.assert_allclose(p[b_, c, i, j], want, atol=1e-5)


def test_psumbook_algorithm_equals_dequant_algebraically():
    """§3: gather-from-Psumbook ≡ dequantize-then-multiply, exactly."""
    cfg = QuantConfig(4, 2, 6, 32)
    _, q, x = make_case(32, 64, 2, cfg)
    g = cfg.g
    y_a = codegemm_via_psumbook_ref(*args_of(q, x), g)
    y_b = codegemm_ref(*args_of(q, x), g)
    np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_b), atol=1e-4)


def test_space_complexity_claim():
    """§3 Space Complexity: Psumbook footprint beats the codebook's when
    t_w/v < v·(fp16/fp32 ratio)… and scales with t_w/v, not v."""
    # paper example: AQLM 1x16 codebook = 1 MB; CodeGEMM m1v4 t_w=32 = 32 KB
    assert codebook_bytes(1, 16, 8) == 1024 * 1024
    assert psumbook_bytes(1, 16, 32, 8) == (1 << 16) * 4 * 4
    # headline configs fit in 164 KB shared memory
    assert psumbook_bytes(2, 8, 32, 8) < 164 * 1024
    assert psumbook_bytes(1, 8, 32, 4) < 164 * 1024


def test_dequantize_respects_group_scales():
    cfg = QuantConfig(4, 1, 8, 32)
    w, q, _ = make_case(32, 64, 1, cfg)
    wq = np.asarray(dequantize(jnp.asarray(q.codes), jnp.asarray(q.codebooks), jnp.asarray(q.scales), cfg.g))
    rel = np.linalg.norm(wq - w) / np.linalg.norm(w)
    assert rel < 0.5, rel
    # doubling the scales doubles the reconstruction
    wq2 = np.asarray(dequantize(jnp.asarray(q.codes), jnp.asarray(q.codebooks), jnp.asarray(2 * q.scales), cfg.g))
    np.testing.assert_allclose(wq2, 2 * wq, rtol=1e-5)


def test_tile_sweep_table7_configs():
    """The §A.2 tile sweep must be numerically inert (same results)."""
    cfg = QuantConfig(4, 1, 8, 32)
    _, q, x = make_case(128, 128, 1, cfg)
    outs = []
    for tile_h, tile_w in [(32, 32), (64, 32), (128, 64), (64, 128)]:
        outs.append(np.asarray(codegemm_matmul(*args_of(q, x), g=32, tile_h=tile_h, tile_w=tile_w)))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=2e-4, rtol=2e-4)
