"""AOT pipeline pieces: HLO text lowering, manifest schema, quant label
parsing, corpus statistics. (The heavyweight full pipeline is exercised by
`make artifacts`; these tests cover its components quickly.)"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import parse_quant_label, to_hlo_text
from compile.train_tiny import corpus_entropy, make_corpus


def test_parse_quant_labels():
    q = parse_quant_label("m1v4g32")
    assert (q.m, q.v, q.b, q.g) == (1, 4, 8, 32)
    q = parse_quant_label("m2v8b6g-1")
    assert (q.m, q.v, q.b, q.g) == (2, 8, 6, -1)
    with pytest.raises(ValueError):
        parse_quant_label("v4m1")


def test_hlo_text_lowering_roundtrippable():
    """The lowered text must be plain HLO (parseable header, no mosaic
    custom-calls — interpret=True requirement)."""
    fn = lambda x: (jnp.tanh(x) @ x.T,)
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec))
    assert text.startswith("HloModule")
    assert "custom-call" not in text or "Mosaic" not in text
    assert "ROOT" in text


def test_pallas_kernel_lowering_has_no_mosaic_calls():
    from compile.kernels.codegemm import codegemm_matmul
    from compile.quantize import QuantConfig, quantize

    w = np.random.default_rng(0).normal(0, 0.05, (32, 64)).astype(np.float32)
    q = quantize(w, QuantConfig(4, 1, 6, 32), iters=2)
    fn = lambda x, c, cb, s: (codegemm_matmul(x, c, cb, s, g=32, tile_h=32, tile_w=32),)
    specs = [
        jax.ShapeDtypeStruct((1, 64), jnp.float32),
        jax.ShapeDtypeStruct(q.codes.shape, jnp.int32),
        jax.ShapeDtypeStruct(q.codebooks.shape, jnp.float32),
        jax.ShapeDtypeStruct(q.scales.shape, jnp.float32),
    ]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    assert "Mosaic" not in text, "pallas must be lowered with interpret=True"


def test_corpus_is_structured_and_deterministic():
    t1, lp1 = make_corpus(length=4000, seed=3)
    t2, lp2 = make_corpus(length=4000, seed=3)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(lp1, lp2)
    h = corpus_entropy(t1, lp1)
    assert 0.1 < h < 0.5 * np.log(256), h
    # transition rows are normalized
    z = np.exp(lp1).sum(1)
    np.testing.assert_allclose(z, 1.0, atol=1e-3)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_manifest_schema():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    m = json.load(open(path))
    assert m["version"] == 1
    assert m["engine"] == "codegemm"
    assert m["model"]["vocab"] == 256
    assert set(m["quant"]) == {"v", "m", "b", "g"}
    names = m["weight_args"]
    assert names[0] == "embedding"
    assert any(n.endswith(".codes") for n in names)
    arts = {a["batch"]: a["hlo"] for a in m["artifacts"]}
    assert 1 in arts
    base = os.path.dirname(path)
    for f in list(arts.values()) + [m["weights_file"]]:
        assert os.path.exists(os.path.join(base, f)), f
