"""Quantizer properties: reconstruction error trends, Eq. 1 accounting,
determinism, f16 storage grid."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.quantize import QuantConfig, bits_per_weight, quantize


def rand_w(n, k, seed=0, std=0.05):
    return np.random.default_rng(seed).normal(0, std, (n, k)).astype(np.float32)


def rel_err(q, w):
    return np.linalg.norm(q.dequantize() - w) / np.linalg.norm(w)


def test_reconstruction_bounded():
    w = rand_w(64, 128)
    for cfg in [QuantConfig(4, 1, 8, 32), QuantConfig(8, 2, 8, -1)]:
        q = quantize(w, cfg, iters=6)
        assert rel_err(q, w) < 0.6, cfg


def test_more_codebooks_reduce_error():
    w = rand_w(64, 128, seed=1)
    e1 = rel_err(quantize(w, QuantConfig(8, 1, 6, -1), iters=6), w)
    e2 = rel_err(quantize(w, QuantConfig(8, 2, 6, -1), iters=6), w)
    assert e2 < e1


def test_more_bits_reduce_error():
    w = rand_w(64, 128, seed=2)
    errs = [rel_err(quantize(w, QuantConfig(8, 1, b, -1), iters=6), w) for b in (2, 4, 8)]
    assert errs[2] < errs[1] < errs[0]


def test_finer_groups_help_banded_scales():
    rng = np.random.default_rng(3)
    n, k = 32, 128
    band = 1.0 + 9.0 * (np.arange(k) // 32) / 3.0
    w = (rng.normal(0, 0.01, (n, k)) * band).astype(np.float32)
    coarse = rel_err(quantize(w, QuantConfig(4, 1, 4, -1), iters=6), w)
    fine = rel_err(quantize(w, QuantConfig(4, 1, 4, 32), iters=6), w)
    assert fine < coarse


def test_deterministic():
    w = rand_w(32, 64, seed=4)
    a = quantize(w, QuantConfig(4, 1, 6, 32), seed=11)
    b = quantize(w, QuantConfig(4, 1, 6, 32), seed=11)
    np.testing.assert_array_equal(a.codes, b.codes)
    np.testing.assert_array_equal(a.codebooks, b.codebooks)


def test_stored_values_on_f16_grid():
    w = rand_w(16, 64, seed=5)
    q = quantize(w, QuantConfig(4, 1, 6, 32), iters=4)
    np.testing.assert_array_equal(q.codebooks, q.codebooks.astype(np.float16).astype(np.float32))
    np.testing.assert_array_equal(q.scales, q.scales.astype(np.float16).astype(np.float32))


@pytest.mark.parametrize(
    "v,m,b,g,expected",
    [
        # Table 1 of the paper (4096-class square layers).
        (4, 1, 8, -1, 2.005),
        (8, 2, 8, -1, 2.008),
        (16, 4, 8, -1, 2.020),
        (8, 1, 8, 16, 2.002),
        (16, 3, 8, 32, 2.012),
    ],
)
def test_table1_bits_per_weight(v, m, b, g, expected):
    q = bits_per_weight(QuantConfig(v, m, b, g), 4096, 4096)
    assert abs(q - expected) < 0.01, (q, expected)


@settings(max_examples=20, deadline=None)
@given(
    v=st.sampled_from([4, 8]),
    m=st.integers(1, 3),
    b=st.sampled_from([3, 6, 8]),
    seed=st.integers(0, 1000),
)
def test_codes_in_range_and_shapes(v, m, b, seed):
    n, k = 16, 64
    cfg = QuantConfig(v, m, b, 32)
    q = quantize(rand_w(n, k, seed=seed), cfg, iters=3, seed=seed)
    assert q.codes.shape == (n, k // v, m)
    assert q.codebooks.shape == (m, 2**b, v)
    assert q.scales.shape == (n, k // 32)
    assert q.codes.min() >= 0 and q.codes.max() < 2**b


def test_invalid_configs_rejected():
    with pytest.raises(ValueError):
        QuantConfig(4, 1, 8, 30).validate(128)  # g not multiple of v… (30 % 4)
    with pytest.raises(ValueError):
        QuantConfig(8, 1, 8, 32).validate(100)  # k not multiple of v
