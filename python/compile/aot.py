"""AOT compile path (run ONCE by ``make artifacts``; never at serve time).

Pipeline:

1. Train the tiny model on the synthetic corpus (cached: skipped when
   ``weights.f32.bin`` already exists).
2. Quantize every linear with the additive-codebook quantizer
   (``--quant m1v4g32`` by default; lm_head included, embeddings/norms
   stay fp32 as in the paper).
3. Lower the single-token batched decode step — linears running through
   the L1 Pallas CodeGEMM kernel (interpret=True) — to **HLO text** for
   each batch bucket, plus a standalone GEMV kernel artifact.
4. Write ``weights.q.bin`` (the HLO's weight arguments), ``corpus.bin``
   and ``manifest.json`` (the rust runtime contract).

HLO *text* (not serialized proto) is the interchange format: jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .export import TensorFile
from .kernels.codegemm import codegemm_matmul
from .model import LINEARS, TINY, ModelConfig, linear_dims, make_decode_step
from .quantize import QuantConfig, bits_per_weight, quantize
from .train_tiny import export_corpus, export_weights, make_corpus, train

DEFAULT_BATCHES = (1, 2, 4, 8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def parse_quant_label(label: str) -> QuantConfig:
    """Parse e.g. ``m1v4g32`` / ``m2v8g-1`` (b defaults to 8)."""
    import re

    m = re.fullmatch(r"m(\d+)v(\d+)(?:b(\d+))?g(-?\d+)", label)
    if not m:
        raise ValueError(f"bad quant label {label!r}")
    mm, v, b, g = m.groups()
    return QuantConfig(v=int(v), m=int(mm), b=int(b or 8), g=int(g))


def quantize_model(params: dict, cfg: ModelConfig, qcfg: QuantConfig, seed: int = 0xC0DE):
    """Quantize all linears; returns (weights dict, weight name order)."""
    dims = linear_dims(cfg)
    weights: dict[str, np.ndarray] = {"embedding": params["embedding"]}
    names: list[str] = ["embedding"]

    def add(name, arr):
        weights[name] = arr
        names.append(name)

    lin_names = [f"layers.{i}.{w}" for i in range(cfg.n_layers) for w in LINEARS] + ["lm_head"]
    for i in range(cfg.n_layers):
        add(f"layers.{i}.attn_norm", params[f"layers.{i}.attn_norm"])
        add(f"layers.{i}.mlp_norm", params[f"layers.{i}.mlp_norm"])
    add("final_norm", params["final_norm"])
    for ln in lin_names:
        w = params[ln]
        q = quantize(w, qcfg, seed=seed)
        rel = np.linalg.norm(q.dequantize() - w) / max(np.linalg.norm(w), 1e-12)
        print(f"  quantized {ln:20s} {w.shape!s:12s} rel-err {rel:.3f}")
        add(f"{ln}.codes", q.codes.astype(np.int32))
        add(f"{ln}.codebooks", q.codebooks.astype(np.float32))
        add(f"{ln}.scales", q.scales.astype(np.float32))
    return weights, names


def lower_decode_steps(cfg: ModelConfig, engine: str, weights: dict, names: list[str],
                       qcfg: QuantConfig, batches, out_dir: str):
    step = make_decode_step(cfg, engine, names, quant_g=qcfg.g)
    arts = []
    for b in batches:
        specs = [
            jax.ShapeDtypeStruct((b,), jnp.int32),  # tokens
            jax.ShapeDtypeStruct((b,), jnp.int32),  # positions
            jax.ShapeDtypeStruct((cfg.n_layers, b, cfg.max_seq, cfg.kv_dim), jnp.float32),
            jax.ShapeDtypeStruct((cfg.n_layers, b, cfg.max_seq, cfg.kv_dim), jnp.float32),
        ] + [jax.ShapeDtypeStruct(weights[n].shape, weights[n].dtype) for n in names]
        t0 = time.time()
        lowered = jax.jit(step).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"decode_b{b}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        print(f"  lowered decode_b{b}: {len(text) / 1e6:.2f} MB HLO text ({time.time() - t0:.1f}s)")
        arts.append({"name": f"decode_b{b}", "batch": b, "hlo": fname})
    return arts


def lower_gemv_kernel(qcfg: QuantConfig, out_dir: str, n: int = 256, k: int = 128, batch: int = 1):
    """Standalone L1 kernel artifact (AOT-path microbenches + smoke)."""
    rng = np.random.default_rng(1)
    w = rng.normal(0, 0.05, (n, k)).astype(np.float32)
    q = quantize(w, qcfg, iters=4)
    fn = lambda x, c, cb, s: (codegemm_matmul(x, c, cb, s, g=qcfg.g, tile_h=min(2048, n), tile_w=32),)
    specs = [
        jax.ShapeDtypeStruct((batch, k), jnp.float32),
        jax.ShapeDtypeStruct(q.codes.shape, jnp.int32),
        jax.ShapeDtypeStruct(q.codebooks.shape, jnp.float32),
        jax.ShapeDtypeStruct(q.scales.shape, jnp.float32),
    ]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    fname = f"gemv_{qcfg.label()}_n{n}k{k}b{batch}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    tf = TensorFile()
    tf.push("x", rng.normal(0, 1, (batch, k)).astype(np.float32))
    tf.push("codes", q.codes.astype(np.int32))
    tf.push("codebooks", q.codebooks)
    tf.push("scales", q.scales)
    import jax.numpy as _j

    from .kernels.ref import codegemm_ref

    y = np.asarray(codegemm_ref(_j.asarray(tf.get("x")), _j.asarray(q.codes), _j.asarray(q.codebooks), _j.asarray(q.scales), qcfg.g))
    tf.push("y_ref", y.astype(np.float32))
    tf.save(os.path.join(out_dir, f"gemv_{qcfg.label()}_n{n}k{k}b{batch}.bin"))
    print(f"  lowered standalone GEMV kernel ({fname})")
    return fname


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quant", default="m1v4g32", help="codebook config label, e.g. m1v4g32")
    ap.add_argument("--train-steps", type=int, default=600)
    ap.add_argument("--batches", default=",".join(str(b) for b in DEFAULT_BATCHES))
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--force-train", action="store_true")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    qcfg = parse_quant_label(args.quant)
    cfg = TINY
    batches = [int(b) for b in args.batches.split(",")]

    weights_f32 = os.path.join(out, "weights.f32.bin")
    corpus_bin = os.path.join(out, "corpus.bin")
    if os.path.exists(weights_f32) and os.path.exists(corpus_bin) and not args.force_train:
        print(f"using cached {weights_f32}")
        tf = TensorFile.load(weights_f32)
        params = {n: tf.get(n) for n in tf.names()}
    else:
        print(f"training tiny model ({args.train_steps} steps)…")
        params, tokens, log_probs, loss = train(cfg, steps=args.train_steps, seed=args.seed)
        print(f"  final train loss {loss:.4f}")
        export_weights(params, weights_f32)
        export_corpus(tokens, log_probs, corpus_bin)

    print(f"quantizing with {qcfg.label()} "
          f"(q̄ = {bits_per_weight(qcfg, 4096, 4096):.3f} bits at Llama scale)…")
    qweights, names = quantize_model(params, cfg, qcfg)
    qtf = TensorFile()
    for n in names:
        qtf.push(n, qweights[n])
    qtf.save(os.path.join(out, "weights.q.bin"))

    print("lowering decode steps (L2 jax + L1 pallas, interpret=True)…")
    arts = lower_decode_steps(cfg, "codegemm", qweights, names, qcfg, batches, out)
    gemv = lower_gemv_kernel(qcfg, out)

    manifest = {
        "version": 1,
        "engine": "codegemm",
        "model": cfg.to_json_dict(),
        "quant": {"v": qcfg.v, "m": qcfg.m, "b": qcfg.b, "g": qcfg.g},
        "weights_file": "weights.q.bin",
        "weight_args": names,
        "artifacts": arts,
        "extras": {"gemv_kernel": gemv, "corpus": "corpus.bin", "weights_f32": "weights.f32.bin"},
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out}/manifest.json — artifacts complete")


if __name__ == "__main__":
    main()
