"""Train the tiny byte-level Llama on the synthetic Markov-Zipf corpus
(DESIGN.md substitution for Llama-3 weights + WikiText-2) and export:

- ``weights.f32.bin`` — dense fp32 weights (rust ``ModelWeights`` names),
- ``corpus.bin``      — the corpus tokens + true transition log-probs, so
  the rust evaluation measures the model on *its own* training
  distribution's held-out half.

Deterministic given the seed. Training is plain JAX: cross-entropy over
teacher-forced windows, hand-rolled Adam (no optax dependency).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .export import TensorFile
from .model import TINY, ModelConfig, init_params, train_forward


# ---------------------------------------------------------------- corpus


def make_corpus(vocab: int = 256, branching: int = 8, zipf_s: float = 1.2, length: int = 32_768, seed: int = 7):
    """Markov chain with Zipf-weighted sparse transitions (the same family
    as ``rust/src/eval/corpus.rs``; the rust side consumes this exact
    corpus through ``corpus.bin``, so the two implementations never need
    to be bit-identical)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, branching + 1, dtype=np.float64)
    weights = 1.0 / ranks**zipf_s
    weights /= weights.sum()
    eps = 1e-4
    log_probs = np.full((vocab, vocab), np.log(eps / vocab), np.float32)
    successors = np.zeros((vocab, branching), np.int64)
    for cur in range(vocab):
        succ = rng.choice(vocab, size=branching, replace=False)
        successors[cur] = succ
        p = (1.0 - eps) * weights + eps / vocab
        log_probs[cur, succ] = np.log(p).astype(np.float32)
    tokens = np.zeros(length, np.int64)
    cur = int(rng.integers(vocab))
    for t in range(length):
        tokens[t] = cur
        if rng.random() < eps:
            cur = int(rng.integers(vocab))
        else:
            cur = int(successors[cur, rng.choice(branching, p=weights)])
    return tokens, log_probs


def corpus_entropy(tokens: np.ndarray, log_probs: np.ndarray) -> float:
    return float(-log_probs[tokens[:-1], tokens[1:]].mean())


# ---------------------------------------------------------------- training


def train(cfg: ModelConfig = TINY, steps: int = 600, batch: int = 32, window: int = 64,
          lr: float = 3e-3, seed: int = 7, corpus=None, log_every: int = 100, verbose: bool = True):
    """Returns (params, corpus_tokens, log_probs, final_train_loss)."""
    tokens, log_probs = corpus if corpus is not None else make_corpus(vocab=cfg.vocab, seed=seed)
    train_half = tokens[: len(tokens) // 2]
    params = init_params(cfg, seed=seed)
    names = sorted(params)
    flat = [jnp.asarray(params[n]) for n in names]

    rng = np.random.default_rng(seed ^ 0xADA)

    def loss_fn(flat_params, batch_tokens):
        p = dict(zip(names, flat_params))
        logits = train_forward(p, cfg, batch_tokens[:, :-1])
        targets = batch_tokens[:, 1:]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        return (logz - gold).mean()

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    # Hand-rolled Adam.
    mom = [jnp.zeros_like(x) for x in flat]
    var = [jnp.zeros_like(x) for x in flat]
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def adam_update(flat, grads, mom, var, step):
        out_f, out_m, out_v = [], [], []
        for x, g, m, v in zip(flat, grads, mom, var):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1**step)
            vhat = v / (1 - b2**step)
            out_f.append(x - lr * mhat / (jnp.sqrt(vhat) + eps))
            out_m.append(m)
            out_v.append(v)
        return out_f, out_m, out_v

    t0 = time.time()
    loss = float("nan")
    losses = []
    for step in range(1, steps + 1):
        starts = rng.integers(0, len(train_half) - window - 1, size=batch)
        batch_tokens = jnp.asarray(
            np.stack([train_half[s : s + window + 1] for s in starts]).astype(np.int32)
        )
        loss, grads = grad_fn(flat, batch_tokens)
        flat, mom, var = adam_update(flat, grads, mom, var, step)
        losses.append(float(loss))
        if verbose and (step % log_every == 0 or step == 1):
            print(f"step {step:4d}  loss {float(loss):.4f}  ({time.time() - t0:.1f}s)")
    params = {n: np.asarray(x) for n, x in zip(names, flat)}
    return params, tokens, log_probs, float(loss)


# ---------------------------------------------------------------- export


def export_weights(params: dict, path) -> None:
    tf = TensorFile()
    # Deterministic, rust-compatible order (ModelWeights::from_tensor_file
    # looks tensors up by name, so any order works; keep it readable).
    for name in sorted(params):
        tf.push(name, params[name].astype(np.float32))
    tf.save(path)


def export_corpus(tokens: np.ndarray, log_probs: np.ndarray, path) -> None:
    tf = TensorFile()
    tf.push("tokens", tokens.astype(np.int32))
    tf.push("log_probs", log_probs.astype(np.float32))
    tf.save(path)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out-weights", default="../artifacts/weights.f32.bin")
    ap.add_argument("--out-corpus", default="../artifacts/corpus.bin")
    args = ap.parse_args()
    params, tokens, log_probs, loss = train(steps=args.steps, seed=args.seed)
    h = corpus_entropy(tokens, log_probs)
    print(f"final loss {loss:.4f}  (source entropy {h:.4f} nats, uniform {np.log(256):.4f})")
    export_weights(params, args.out_weights)
    export_corpus(tokens, log_probs, args.out_corpus)
    print(f"wrote {args.out_weights} and {args.out_corpus}")


if __name__ == "__main__":
    main()
