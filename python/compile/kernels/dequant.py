"""Dequantization-based baseline Pallas kernel (the AQLM-style comparator,
Figure 1(a)).

Same quantized format, same tiling, but each grid step *reconstructs the
weight tile* through per-code centroid fetches and then multiplies —
keeping the full codebook resident on-chip and performing the redundant
per-element work CodeGEMM eliminates. Exists so benches can contrast the
two algorithms under one substrate and so correctness tests can cross-check
both against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, codes_ref, codebooks_ref, scales_ref, o_ref, *, v, g, tile_w):
    kj = pl.program_id(1)
    x = x_ref[...]  # [B, t_w]
    codes = codes_ref[...]  # [t_h, jn, m]
    cb = codebooks_ref[...]  # [m, 2^b, v] — the FULL codebook, on-chip
    th, jn, m = codes.shape

    # Dequantize the weight tile: per-code centroid fetch + additive sum.
    w = jnp.zeros((th, jn, v), dtype=jnp.float32)
    for c in range(m):
        w = w + cb[c][codes[:, :, c]]
    # Apply group scales.
    gsel = (kj * tile_w + jnp.arange(jn) * v) // g - (kj * tile_w) // g
    sv = scales_ref[...][:, gsel]  # [t_h, jn]
    w = (w * sv[:, :, None]).reshape(th, tile_w)

    # Dense multiply with the reconstructed tile (full M·N·K work).
    partial = jnp.dot(x, w.T, preferred_element_type=jnp.float32)

    @pl.when(kj == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(kj > 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("g", "tile_h", "tile_w"))
def dequant_matmul(x, codes, codebooks, scales, *, g: int, tile_h: int = 2048, tile_w: int = 32):
    """Baseline: dequantize-then-GEMM. Same signature as
    ``codegemm.codegemm_matmul``."""
    batch, k = x.shape
    n, jn_total, m = codes.shape
    _, nc, v = codebooks.shape
    g_eff = g if g > 0 else k
    tile_h = min(tile_h, n)
    tile_w = min(tile_w, k)
    assert n % tile_h == 0 and k % tile_w == 0
    assert tile_w % v == 0
    assert g_eff % tile_w == 0 or tile_w % g_eff == 0
    jn = tile_w // v
    groups_per_tile = max(1, tile_w // g_eff)
    grid = (n // tile_h, k // tile_w)
    return pl.pallas_call(
        functools.partial(_kernel, v=v, g=g_eff, tile_w=tile_w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, tile_w), lambda i, j: (0, j)),
            pl.BlockSpec((tile_h, jn, m), lambda i, j: (i, j, 0)),
            pl.BlockSpec((m, nc, v), lambda i, j: (0, 0, 0)),
            pl.BlockSpec(
                (tile_h, groups_per_tile),
                # block index of the K-tile's first group (works both for
                # tile_w >= g, where each K-tile owns t_w/g groups, and for
                # tile_w < g, where g % t_w == 0 keeps tiles group-aligned).
                lambda i, j: (i, (j * tile_w) // g_eff // groups_per_tile),
            ),
        ],
        out_specs=pl.BlockSpec((batch, tile_h), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, n), jnp.float32),
        interpret=True,
    )(x, codes, codebooks, scales)
