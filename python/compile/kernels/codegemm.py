"""CodeGEMM Pallas kernel (Layer 1).

The paper's kernel re-thought for the TPU memory hierarchy (DESIGN.md
§Hardware-Adaptation): the CUDA thread-block's shared-memory *Psumbook*
becomes a VMEM scratch buffer; ``BlockSpec`` expresses the HBM↔VMEM tile
schedule the CUDA version expressed with thread blocks; the code-indexed
gather is a vectorized ``take_along_axis`` instead of warp shuffles.

Grid: ``(n / t_h, k / t_w)`` — a split-K layout mirroring the paper's
``(t_h × t_w)`` weight tiles (§3, Figure 3). Each grid step:

1. reshapes its activation tile ``[B, t_w]`` into ``t_w/v`` length-``v``
   sub-vectors (Figure 3 step ①),
2. builds the Psumbook ``p[B, m, 2^b, t_w/v]`` in VMEM scratch — all
   centroid·activation inner products for this K-tile (step ②, Eq. 2),
3. gathers partial sums through the code tile, applies the group scales,
   and accumulates into the output block (step ③).

The K dimension is the *minor* grid axis, so the output block for a row
tile stays resident while K sweeps — the Psumbook is rebuilt per K-tile
and reused across all ``t_h`` rows, exactly the reuse structure that gives
the paper its ``m/v`` complexity reduction.

MUST run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls (real-TPU lowering). Interpret mode lowers to plain
HLO, which `make artifacts` then ships to the rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_H = 2048  # paper §3 / §A.2
DEFAULT_TILE_W = 32


def _kernel(x_ref, codes_ref, codebooks_ref, scales_ref, o_ref, *, v, g, tile_w):
    """One (row-tile, K-tile) grid step."""
    kj = pl.program_id(1)

    # --- step ①: reshape the activation tile into length-v sub-vectors.
    x = x_ref[...]  # [B, t_w]
    batch = x.shape[0]
    jn = tile_w // v
    xv = x.reshape(batch, jn, v)

    # --- step ②: build the Psumbook (Eq. 2). This value is the kernel's
    # entire on-chip working set — m · 2^b · (t_w/v) floats per batch
    # column, VMEM-resident for the whole grid step (the paper's
    # shared-memory Psumbook; a dequant kernel would instead need the full
    # m · 2^b · v fp16 codebook *plus* reconstructed weights).
    cb = codebooks_ref[...]  # [m, 2^b, v]
    p = jnp.einsum("civ,bjv->bcij", cb, xv, preferred_element_type=jnp.float32)

    # --- step ③: gather partial sums through the code tile + scale.
    codes = codes_ref[...]  # [t_h, jn, m]
    m = codes.shape[-1]
    acc = jnp.zeros((batch, codes.shape[0], jn), dtype=jnp.float32)
    jidx = jnp.arange(jn)
    for c in range(m):
        # p[b, c, codes[r, j, c], j] — vectorized code-indexed gather.
        acc = acc + p[:, c, codes[:, :, c], jidx]
    # Group scale of the j-th sub-vector in this K-tile: global column is
    # kj*t_w + j*v; scales_ref block covers this tile's groups.
    gsel = (kj * tile_w + jnp.arange(jn) * v) // g - (kj * tile_w) // g
    sv = scales_ref[...][:, gsel]  # [t_h, jn]
    partial = jnp.einsum("brj,rj->br", acc, sv, preferred_element_type=jnp.float32)

    # Accumulate across the K grid (K is the minor axis).
    @pl.when(kj == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(kj > 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("g", "tile_h", "tile_w"))
def codegemm_matmul(
    x,
    codes,
    codebooks,
    scales,
    *,
    g: int,
    tile_h: int = DEFAULT_TILE_H,
    tile_w: int = DEFAULT_TILE_W,
):
    """``y[b, n] = Σ_k x[b, k] · W[n, k]`` over codebook-quantized ``W``.

    Shapes: ``x [B, k]``, ``codes i32 [n, k/v, m]``, ``codebooks
    [m, 2^b, v]``, ``scales [n, k/g]`` → ``[B, n]``.
    """
    batch, k = x.shape
    n, jn_total, m = codes.shape
    _, nc, v = codebooks.shape
    assert jn_total * v == k, (jn_total, v, k)
    g_eff = g if g > 0 else k
    tile_h = min(tile_h, n)
    tile_w = min(tile_w, k)
    assert n % tile_h == 0 and k % tile_w == 0, (n, k, tile_h, tile_w)
    assert tile_w % v == 0
    # A tile must not straddle group boundaries mid-group (either even
    # division works) — mirrors KernelConfig::validate_for.
    assert g_eff % tile_w == 0 or tile_w % g_eff == 0, (g_eff, tile_w)
    jn = tile_w // v
    groups_per_tile = max(1, tile_w // g_eff)

    grid = (n // tile_h, k // tile_w)
    return pl.pallas_call(
        functools.partial(_kernel, v=v, g=g_eff, tile_w=tile_w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, tile_w), lambda i, j: (0, j)),  # x: K-tile
            pl.BlockSpec((tile_h, jn, m), lambda i, j: (i, j, 0)),  # codes
            pl.BlockSpec((m, nc, v), lambda i, j: (0, 0, 0)),  # codebooks
            pl.BlockSpec(
                (tile_h, groups_per_tile),
                # block index of the K-tile's first group (works both for
                # tile_w >= g, where each K-tile owns t_w/g groups, and for
                # tile_w < g, where g % t_w == 0 keeps tiles group-aligned).
                lambda i, j: (i, (j * tile_w) // g_eff // groups_per_tile),
            ),
        ],
        out_specs=pl.BlockSpec((batch, tile_h), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, n), jnp.float32),
        interpret=True,
    )(x, codes, codebooks, scales)


def psumbook_bytes(m: int, b: int, tile_w: int, v: int, batch: int = 1) -> int:
    """On-chip footprint of the Psumbook (§3 Space Complexity)."""
    return m * (2**b) * (tile_w // v) * 4 * batch


def codebook_bytes(m: int, b: int, v: int) -> int:
    """On-chip footprint a dequantization kernel would need (fp16)."""
    return m * (2**b) * v * 2
