"""Pure-`jnp` oracle for the CodeGEMM computation.

Shared array layout (matches ``quantize.py`` and the Pallas kernels):

- ``codes``      i32  ``[n, jn, m]``      with ``jn = k / v``
- ``codebooks``  f32  ``[m, 2**b, v]``
- ``scales``     f32  ``[n, gn]``         with ``gn = k / g`` (g | k)
- ``x``          f32  ``[batch, k]``      activations
- output         f32  ``[batch, n]``      (``y = x · Wᵀ``)

The oracle computes dequantize-then-matmul; every kernel must match it to
float tolerance — the paper's central claim is that the Psumbook gather is
*algebraically identical* to dequantization (§3).
"""

from __future__ import annotations

import jax.numpy as jnp


def dequantize(codes, codebooks, scales, g: int):
    """Reconstruct the dense weight matrix ``W [n, k]``."""
    n, jn, m = codes.shape
    _, _, v = codebooks.shape
    k = jn * v
    # Sum the m codebook contributions: w_norm[n, jn, v]
    w = jnp.zeros((n, jn, v), dtype=codebooks.dtype)
    for c in range(m):
        w = w + codebooks[c][codes[:, :, c]]
    w = w.reshape(n, k)
    # Expand group scales along k.
    s = jnp.repeat(scales, g, axis=1)[:, :k]
    return w * s


def codegemm_ref(x, codes, codebooks, scales, g: int):
    """Oracle matmul: ``y[b, n] = Σ_k x[b, k] · W[n, k]``."""
    w = dequantize(codes, codebooks, scales, g)
    return x @ w.T


def psumbook_ref(x, codebooks):
    """All centroid·activation inner products (Eq. 2).

    Returns ``p[batch, m, 2**b, jn]`` with
    ``p[b, c, i, j] = Σ_t codebooks[c, i, t] · x[b, j·v + t]``.
    """
    batch, k = x.shape
    m, nc, v = codebooks.shape
    xv = x.reshape(batch, k // v, v)
    return jnp.einsum("civ,bjv->bcij", codebooks, xv)


def codegemm_via_psumbook_ref(x, codes, codebooks, scales, g: int):
    """Reference of the *kernel's* algorithm (build Psumbook → gather →
    scale → accumulate) in plain jnp — used to pin down the exact
    complexity-reduced computation the Pallas kernel implements."""
    n, jn, m = codes.shape
    _, _, v = codebooks.shape
    p = psumbook_ref(x, codebooks)  # [B, m, 2^b, jn]
    # gathered[b, n, j] = Σ_c p[b, c, codes[n, j, c], j]
    batch = x.shape[0]
    acc = jnp.zeros((batch, n, jn), dtype=x.dtype)
    jidx = jnp.arange(jn)
    for c in range(m):
        acc = acc + p[:, c, codes[:, :, c], jidx]
    # group scales: j-th vector belongs to group (j*v)//g
    gsel = (jnp.arange(jn) * v) // g
    sv = scales[:, gsel]  # [n, jn]
    return jnp.einsum("bnj,nj->bn", acc, sv)
