"""Additive multi-codebook quantizer (build-time python mirror of
``rust/src/quant``): group-normalize → split into length-``v`` vectors →
residual k-means over ``m`` codebooks → per-vector codes.

Deterministic given the seed; used by ``aot.py`` to produce the quantized
weight arrays the AOT decode-step HLO consumes.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class QuantConfig:
    v: int = 4
    m: int = 1
    b: int = 8
    g: int = 32  # -1 ⇒ row-wise

    def label(self) -> str:
        return f"m{self.m}v{self.v}g{self.g}"

    def validate(self, k: int) -> None:
        if k % self.v:
            raise ValueError(f"k={k} not a multiple of v={self.v}")
        g = self.g if self.g > 0 else k
        if g % self.v or (k % g):
            raise ValueError(f"invalid group size g={self.g} for k={k}, v={self.v}")


@dataclass
class QuantizedLinear:
    cfg: QuantConfig
    n: int
    k: int
    codes: np.ndarray  # i32 [n, k//v, m]
    codebooks: np.ndarray  # f32 [m, 2^b, v]
    scales: np.ndarray  # f32 [n, k//g]

    def dequantize(self) -> np.ndarray:
        g = self.cfg.g if self.cfg.g > 0 else self.k
        w = np.zeros((self.n, self.k // self.cfg.v, self.cfg.v), dtype=np.float32)
        for c in range(self.cfg.m):
            w += self.codebooks[c][self.codes[:, :, c]]
        w = w.reshape(self.n, self.k)
        s = np.repeat(self.scales, g, axis=1)[:, : self.k]
        return w * s


def _f16(x: np.ndarray) -> np.ndarray:
    """Round through the f16 grid (stored precision in the paper, Eq. 1)."""
    return x.astype(np.float16).astype(np.float32)


def _kmeans(points: np.ndarray, n_clusters: int, iters: int, rng: np.random.Generator):
    """Plain k-means with sampled init; returns (centroids, assignment)."""
    npts = points.shape[0]
    if npts <= n_clusters:
        centroids = np.zeros((n_clusters, points.shape[1]), dtype=np.float32)
        centroids[:npts] = points
        return centroids, np.arange(npts) % n_clusters
    idx = rng.choice(npts, size=n_clusters, replace=False)
    centroids = points[idx].copy()
    assign = np.zeros(npts, dtype=np.int64)
    for _ in range(iters):
        # assignment by squared distance (chunked to bound memory)
        d2 = (
            (points**2).sum(1, keepdims=True)
            - 2.0 * points @ centroids.T
            + (centroids**2).sum(1)[None, :]
        )
        assign = d2.argmin(1)
        for c in range(n_clusters):
            mask = assign == c
            if mask.any():
                centroids[c] = points[mask].mean(0)
            else:  # re-seed empty cluster at the worst-fit point
                centroids[c] = points[d2.min(1).argmax()]
    return centroids.astype(np.float32), assign


def quantize(w: np.ndarray, cfg: QuantConfig, iters: int = 12, seed: int = 0xC0DE) -> QuantizedLinear:
    """Quantize a dense ``[n, k]`` matrix."""
    n, k = w.shape
    cfg.validate(k)
    g = cfg.g if cfg.g > 0 else k
    rng = np.random.default_rng(seed)

    # Step 1 — group normalization (absmax per (row, group)).
    wg = w.reshape(n, k // g, g)
    scales = np.abs(wg).max(axis=2)
    scales = np.where(scales == 0.0, 1.0, scales).astype(np.float32)
    scales = _f16(scales)
    w_norm = (wg / scales[:, :, None]).reshape(n, k).astype(np.float32)

    # Steps 2–3 — residual k-means over m additive codebooks.
    jn = k // cfg.v
    vectors = w_norm.reshape(n * jn, cfg.v)
    residual = vectors.copy()
    codebooks = np.zeros((cfg.m, 2**cfg.b, cfg.v), dtype=np.float32)
    codes = np.zeros((n * jn, cfg.m), dtype=np.int32)
    for c in range(cfg.m):
        cents, assign = _kmeans(residual, 2**cfg.b, iters, rng)
        cents = _f16(cents)
        codebooks[c] = cents
        codes[:, c] = assign.astype(np.int32)
        residual = residual - cents[assign]
    return QuantizedLinear(
        cfg=cfg,
        n=n,
        k=k,
        codes=codes.reshape(n, jn, cfg.m),
        codebooks=codebooks,
        scales=scales,
    )


def bits_per_weight(cfg: QuantConfig, n: int, k: int) -> float:
    """Eq. 1 of the paper."""
    g = cfg.g if cfg.g > 0 else k
    s_codebook = 16 * cfg.m * (2**cfg.b) * cfg.v
    s_code = cfg.b * cfg.m * n * (k // cfg.v)
    s_norm = 16 * n * (k // g)
    return (s_codebook + s_code + s_norm) / (n * k)
