"""TensorFile (CGTF) container — the python half of the interchange format.

Byte-for-byte compatible with ``rust/src/util/npy.rs``: magic ``CGTF0001``,
a little-endian u64 header length, a compact JSON header listing
``{name, dtype, shape, offset, nbytes}`` per tensor, then the raw
little-endian data section.
"""

from __future__ import annotations

import json
import struct
from dataclasses import dataclass, field

import numpy as np

MAGIC = b"CGTF0001"

_DTYPES = {
    "f32": np.float32,
    "i32": np.int32,
    "u8": np.uint8,
    "u16": np.uint16,
}
_NAMES = {np.dtype(v): k for k, v in _DTYPES.items()}


def _dtype_name(arr: np.ndarray) -> str:
    try:
        return _NAMES[arr.dtype]
    except KeyError:
        raise ValueError(f"unsupported dtype {arr.dtype} (want f32/i32/u8/u16)") from None


@dataclass
class TensorFile:
    """Ordered named-tensor container."""

    tensors: dict[str, np.ndarray] = field(default_factory=dict)

    def push(self, name: str, arr: np.ndarray) -> None:
        if name in self.tensors:
            raise ValueError(f"duplicate tensor name {name!r}")
        arr = np.ascontiguousarray(arr)
        _dtype_name(arr)  # validate
        self.tensors[name] = arr

    def get(self, name: str) -> np.ndarray:
        return self.tensors[name]

    def names(self) -> list[str]:
        return list(self.tensors)

    def to_bytes(self) -> bytes:
        entries = []
        blobs = []
        offset = 0
        for name, arr in self.tensors.items():
            raw = arr.astype(arr.dtype.newbyteorder("<"), copy=False).tobytes()
            entries.append(
                {
                    "name": name,
                    "dtype": _dtype_name(arr),
                    "shape": list(arr.shape),
                    "offset": offset,
                    "nbytes": len(raw),
                }
            )
            blobs.append(raw)
            offset += len(raw)
        header = json.dumps({"tensors": entries}, separators=(",", ":")).encode()
        return MAGIC + struct.pack("<Q", len(header)) + header + b"".join(blobs)

    @staticmethod
    def from_bytes(data: bytes) -> "TensorFile":
        if data[:8] != MAGIC:
            raise ValueError("not a CGTF file (bad magic)")
        (hlen,) = struct.unpack("<Q", data[8:16])
        header = json.loads(data[16 : 16 + hlen])
        payload = data[16 + hlen :]
        tf = TensorFile()
        for e in header["tensors"]:
            dt = np.dtype(_DTYPES[e["dtype"]]).newbyteorder("<")
            raw = payload[e["offset"] : e["offset"] + e["nbytes"]]
            arr = np.frombuffer(raw, dtype=dt).reshape(e["shape"]).astype(_DTYPES[e["dtype"]])
            tf.push(e["name"], arr)
        return tf

    def save(self, path) -> None:
        with open(path, "wb") as f:
            f.write(self.to_bytes())

    @staticmethod
    def load(path) -> "TensorFile":
        with open(path, "rb") as f:
            return TensorFile.from_bytes(f.read())
