"""Layer 2: the tiny Llama-style decoder in pure JAX.

Architecture and numerics mirror ``rust/src/model/llama.rs`` exactly
(RMSNorm eps 1e-5, rotate-half RoPE, GQA, SwiGLU) so the rust
NativeBackend and the AOT/PJRT backend produce interchangeable results.

Two entry points:

- :func:`train_forward` — teacher-forced full-sequence forward for
  ``train_tiny.py`` (dense fp32 only).
- :func:`make_decode_step` — the single-token batched decode step that
  ``aot.py`` lowers to HLO. Its linear layers run through a pluggable
  engine: ``"dense"`` (fp32 matmul) or ``"codegemm"`` (the L1 Pallas
  kernel over quantized weights).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.codegemm import codegemm_matmul


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256
    hidden: int = 128
    n_layers: int = 2
    n_heads: int = 4
    n_kv_heads: int = 2
    ffn: int = 352
    max_seq: int = 128
    rope_theta: float = 10_000.0
    name: str = "tiny-llama"

    @property
    def head_dim(self) -> int:
        return self.hidden // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def to_json_dict(self) -> dict:
        return {
            "name": self.name,
            "vocab": self.vocab,
            "hidden": self.hidden,
            "n_layers": self.n_layers,
            "n_heads": self.n_heads,
            "n_kv_heads": self.n_kv_heads,
            "ffn": self.ffn,
            "max_seq": self.max_seq,
            "rope_theta": self.rope_theta,
        }


TINY = ModelConfig()

# The seven quantized linears per layer, in rust LINEAR_NAMES order.
LINEARS = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"]


def linear_dims(cfg: ModelConfig) -> dict[str, tuple[int, int]]:
    d, kv, f = cfg.hidden, cfg.kv_dim, cfg.ffn
    return {
        "wq": (d, d),
        "wk": (kv, d),
        "wv": (kv, d),
        "wo": (d, d),
        "w_gate": (f, d),
        "w_up": (f, d),
        "w_down": (d, f),
    }


def param_names(cfg: ModelConfig) -> list[str]:
    """Dense tensor names, identical to rust ``ModelWeights``."""
    names = ["embedding"]
    for i in range(cfg.n_layers):
        names += [f"layers.{i}.{w}" for w in LINEARS]
        names += [f"layers.{i}.attn_norm", f"layers.{i}.mlp_norm"]
    names += ["final_norm", "lm_head"]
    return names


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    d = cfg.hidden
    std = 1.0 / np.sqrt(d)
    dims = linear_dims(cfg)

    def mat(n, k):
        return rng.normal(0.0, std, (n, k)).astype(np.float32)

    params: dict[str, np.ndarray] = {"embedding": mat(cfg.vocab, d)}
    for i in range(cfg.n_layers):
        for w in LINEARS:
            params[f"layers.{i}.{w}"] = mat(*dims[w])
        params[f"layers.{i}.attn_norm"] = np.ones(d, np.float32)
        params[f"layers.{i}.mlp_norm"] = np.ones(d, np.float32)
    params["final_norm"] = np.ones(d, np.float32)
    params["lm_head"] = mat(cfg.vocab, d)
    return params


def rmsnorm(x, w, eps: float = 1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope_tables(cfg: ModelConfig):
    hd = cfg.head_dim
    half = hd // 2
    inv_freq = 1.0 / (cfg.rope_theta ** (2.0 * np.arange(half) / hd))
    t = np.arange(cfg.max_seq)
    ang = np.outer(t, inv_freq).astype(np.float32)  # [S, half]
    return jnp.cos(ang), jnp.sin(ang)


def rope_rotate(x, cos, sin):
    """Rotate-half RoPE over heads. ``x [..., n_heads*hd]``, cos/sin
    ``[..., half]`` broadcastable per position."""
    shape = x.shape
    hd = 2 * cos.shape[-1]
    xh = x.reshape(*shape[:-1], shape[-1] // hd, hd)
    a, b = xh[..., : hd // 2], xh[..., hd // 2 :]
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([a * c - b * s, b * c + a * s], axis=-1)
    return out.reshape(shape)


def _swiglu(gate, up):
    return jax.nn.silu(gate) * up


def train_forward(params: dict, cfg: ModelConfig, tokens):
    """Teacher-forced forward: ``tokens [B, T]`` → logits ``[B, T, V]``."""
    B, T = tokens.shape
    d = cfg.hidden
    groups = cfg.n_heads // cfg.n_kv_heads
    cos_full, sin_full = rope_tables(cfg)
    cos, sin = cos_full[:T], sin_full[:T]
    h = params["embedding"][tokens]  # [B, T, d]
    mask = jnp.tril(jnp.ones((T, T), bool))
    for i in range(cfg.n_layers):
        p = lambda s: params[f"layers.{i}.{s}"]
        x = rmsnorm(h, p("attn_norm"))
        q = x @ p("wq").T
        k = x @ p("wk").T
        v = x @ p("wv").T
        q = rope_rotate(q, cos, sin)
        k = rope_rotate(k, cos, sin)
        hd = cfg.head_dim
        qh = q.reshape(B, T, cfg.n_heads, hd)
        kh = k.reshape(B, T, cfg.n_kv_heads, hd)
        vh = v.reshape(B, T, cfg.n_kv_heads, hd)
        kh = jnp.repeat(kh, groups, axis=2)
        vh = jnp.repeat(vh, groups, axis=2)
        scores = jnp.einsum("bthd,bshd->bhts", qh, kh) / np.sqrt(hd)
        scores = jnp.where(mask[None, None], scores, -1e9)
        attn = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhts,bshd->bthd", attn, vh).reshape(B, T, d)
        h = h + out @ p("wo").T
        x = rmsnorm(h, p("mlp_norm"))
        h = h + _swiglu(x @ p("w_gate").T, x @ p("w_up").T) @ p("w_down").T
    h = rmsnorm(h, params["final_norm"])
    return h @ params["lm_head"].T


def make_decode_step(cfg: ModelConfig, engine: str, weight_names: list[str], *, quant_g: int = 32):
    """Build ``step(tokens, positions, kv_k, kv_v, *weights)`` →
    ``(logits, kv_k', kv_v')`` with the weight list in ``weight_names``
    order (the manifest's ``weight_args`` contract).

    ``engine``: ``"dense"`` (weights are fp32 matrices) or ``"codegemm"``
    (each linear contributes ``<name>.codes/.codebooks/.scales`` and runs
    through the L1 Pallas kernel).
    """
    cos_full, sin_full = rope_tables(cfg)
    groups = cfg.n_heads // cfg.n_kv_heads
    hd = cfg.head_dim

    def linear(w: dict, name: str, x):
        if engine == "dense":
            return x @ w[name].T
        q = w  # flat dict with .codes etc.
        n = None  # tile_h chosen per linear below
        codes = q[f"{name}.codes"]
        n = codes.shape[0]
        return codegemm_matmul(
            x,
            codes,
            q[f"{name}.codebooks"],
            q[f"{name}.scales"],
            g=quant_g,
            tile_h=min(2048, n),
            tile_w=32,
        )

    def step(tokens, positions, kv_k, kv_v, *weights):
        w = dict(zip(weight_names, weights, strict=True))
        B = tokens.shape[0]
        d = cfg.hidden
        s_idx = jnp.arange(cfg.max_seq)
        cos = cos_full[positions]  # [B, half]
        sin = sin_full[positions]
        h = w["embedding"][tokens]  # [B, d]
        # attend mask per slot: positions s ≤ current position
        mask = s_idx[None, :] <= positions[:, None]  # [B, S]
        for i in range(cfg.n_layers):
            name = lambda s: f"layers.{i}.{s}"
            x = rmsnorm(h, w[name("attn_norm")])
            q = linear(w, name("wq"), x)
            k = linear(w, name("wk"), x)
            v = linear(w, name("wv"), x)
            q = rope_rotate(q, cos, sin)
            k = rope_rotate(k, cos, sin)
            bidx = jnp.arange(B)
            kv_k = kv_k.at[i, bidx, positions].set(k)
            kv_v = kv_v.at[i, bidx, positions].set(v)
            keys = kv_k[i]  # [B, S, kv_dim]
            vals = kv_v[i]
            qh = q.reshape(B, cfg.n_heads, hd)
            kh = keys.reshape(B, cfg.max_seq, cfg.n_kv_heads, hd)
            vh = vals.reshape(B, cfg.max_seq, cfg.n_kv_heads, hd)
            kh = jnp.repeat(kh, groups, axis=2)
            vh = jnp.repeat(vh, groups, axis=2)
            scores = jnp.einsum("bhd,bshd->bhs", qh, kh) / np.sqrt(hd)
            scores = jnp.where(mask[:, None, :], scores, -1e9)
            attn = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bhs,bshd->bhd", attn, vh).reshape(B, d)
            h = h + linear(w, name("wo"), out)
            x = rmsnorm(h, w[name("mlp_norm")])
            h = h + linear(w, name("w_down"), _swiglu(linear(w, name("w_gate"), x), linear(w, name("w_up"), x)))
        h = rmsnorm(h, w["final_norm"])
        logits = h @ w["lm_head"].T if engine == "dense" else linear(w, "lm_head", h)
        return logits, kv_k, kv_v

    return step
