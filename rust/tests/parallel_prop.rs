//! Property tests for the `parallel::` subsystem (via `util::proptest`):
//! over random shapes, quant configs and shard counts,
//!
//! - `ShardedEngine` output is **bit-identical** (`==`, not approximate)
//!   to the wrapped serial engine, and
//! - merged shard `Counters` equal the serial engine's counters for the
//!   conserved quantities (MACs for dense/dequant/uniform, lookups and
//!   read ops for the table-lookup kernels).

use codegemm::gemm::{
    CodeGemmEngine, DenseEngine, DequantEngine, GemmEngine, LutGemmEngine, UniformGemmEngine,
};
use codegemm::parallel::{shard, ShardPlan, ShardedEngine, TpLinear};
use codegemm::quant::bcq::BcqLinear;
use codegemm::quant::uniform::UniformLinear;
use codegemm::util::proptest as pt;
use codegemm::util::prng::Prng;
use codegemm::util::stats;
use codegemm::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Shared case generator (batch kept small: these suites stress shard
/// geometry, not prefill width).
fn gen_case() -> pt::GemmCaseGen {
    pt::GemmCaseGen { mbs: &[1, 2, 3], ..Default::default() }
}

#[test]
fn prop_sharded_codegemm_bit_exact_and_lookups_conserved() {
    let pool = Arc::new(ThreadPool::new(4));
    let cfg = pt::PropConfig { cases: 20, ..Default::default() };
    pt::assert_prop("sharded codegemm == serial", cfg, &gen_case(), |c: &pt::GemmCase| {
        let Some(q) = c.quantized(0.02) else {
            return Ok(()); // invalid combination — vacuous
        };
        let x = c.activations(1);
        let mut serial = CodeGemmEngine::from_quantized(&q);
        let ys = serial.gemm(&x, c.mb);
        // Both Psumbook schedules: shared (one book per k-tile, the
        // default) and private (per-shard books) must each stay
        // bit-identical to serial and conserve the per-row gather work.
        for shared in [true, false] {
            let plan = ShardPlan::new(c.n, c.shards, 1, 1);
            let mut sharded = ShardedEngine::from_factory(plan, Arc::clone(&pool), |(r0, r1)| {
                CodeGemmEngine::from_quantized(&shard::slice_rows(&q, r0, r1))
            })
            .with_shared_book(shared);
            let yp = sharded.gemm(&x, c.mb);
            pt::ensure(ys == yp, format!("output not bit-identical (shared={shared}, {c:?})"))?;
            pt::ensure(
                sharded.counters().lookups == serial.counters().lookups,
                format!(
                    "lookups diverged (shared={shared}): sharded {} vs serial {}",
                    sharded.counters().lookups,
                    serial.counters().lookups
                ),
            )?;
            pt::ensure(
                sharded.counters().read_ops == serial.counters().read_ops,
                format!("read_ops diverged (shared={shared})"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_dense_bit_exact_and_macs_conserved() {
    let pool = Arc::new(ThreadPool::new(4));
    let cfg = pt::PropConfig { cases: 24, ..Default::default() };
    pt::assert_prop("sharded dense == serial", cfg, &gen_case(), |c: &pt::GemmCase| {
        let (n, k) = (c.n, c.k);
        let w = c.weights(1.0);
        let x = c.activations(2);
        let mut serial = DenseEngine::new(w.clone(), n, k);
        let plan = ShardPlan::new(n, c.shards, 1, 1);
        let mut sharded = ShardedEngine::from_factory(plan, Arc::clone(&pool), |(r0, r1)| {
            DenseEngine::new(shard::dense_rows(&w, k, r0, r1), r1 - r0, k)
        });
        let (ys, yp) = (serial.gemm(&x, c.mb), sharded.gemm(&x, c.mb));
        pt::ensure(ys == yp, format!("dense output not bit-identical ({c:?})"))?;
        pt::ensure(
            sharded.counters().mac_flops == serial.counters().mac_flops,
            "dense MACs diverged",
        )?;
        pt::ensure(sharded.counters().calls == serial.counters().calls, "calls diverged")
    });
}

#[test]
fn prop_sharded_dequant_bit_exact_and_work_conserved() {
    let pool = Arc::new(ThreadPool::new(4));
    let cfg = pt::PropConfig { cases: 16, ..Default::default() };
    pt::assert_prop("sharded dequant == serial", cfg, &gen_case(), |c: &pt::GemmCase| {
        let Some(q) = c.quantized(0.02) else {
            return Ok(());
        };
        let x = c.activations(3);
        let mut serial = DequantEngine::from_quantized(&q);
        let plan = ShardPlan::new(c.n, c.shards, 1, 1);
        let mut sharded = ShardedEngine::from_factory(plan, Arc::clone(&pool), |(r0, r1)| {
            DequantEngine::from_quantized(&shard::slice_rows(&q, r0, r1))
        });
        let (ys, yp) = (serial.gemm(&x, c.mb), sharded.gemm(&x, c.mb));
        pt::ensure(ys == yp, "dequant output not bit-identical")?;
        // Dequant decodes and multiplies per row: MACs and lookups are
        // both conserved under row sharding.
        pt::ensure(
            sharded.counters().mac_flops == serial.counters().mac_flops,
            "dequant MACs diverged",
        )?;
        pt::ensure(
            sharded.counters().lookups == serial.counters().lookups,
            "dequant lookups diverged",
        )
    });
}

#[test]
fn prop_sharded_uniform_and_lut_bit_exact() {
    let pool = Arc::new(ThreadPool::new(4));
    let cfg = pt::PropConfig { cases: 12, ..Default::default() };
    pt::assert_prop("sharded uniform/lut == serial", cfg, &gen_case(), |c: &pt::GemmCase| {
        let (n, k, mb, shards) = (c.n, c.k, c.mb, c.shards);
        let w = c.weights(0.05);
        let x = c.activations(4);
        let plan = ShardPlan::new(n, shards, 1, 1);

        let uq = UniformLinear::quantize(&w, n, k, 4, 32).expect("uniform");
        let mut serial_u = UniformGemmEngine::new(uq);
        let mut sharded_u = ShardedEngine::from_factory(plan.clone(), Arc::clone(&pool), |(r0, r1)| {
            let ws = shard::dense_rows(&w, k, r0, r1);
            UniformGemmEngine::new(UniformLinear::quantize(&ws, r1 - r0, k, 4, 32).unwrap())
        });
        pt::ensure(serial_u.gemm(&x, mb) == sharded_u.gemm(&x, mb), "uniform not bit-identical")?;
        pt::ensure(
            sharded_u.counters().mac_flops == serial_u.counters().mac_flops,
            "uniform MACs diverged",
        )?;

        let bq = BcqLinear::quantize(&w, n, k, 2, 32).expect("bcq");
        let mut serial_l = LutGemmEngine::new(bq);
        let mut sharded_l = ShardedEngine::from_factory(plan, Arc::clone(&pool), |(r0, r1)| {
            let ws = shard::dense_rows(&w, k, r0, r1);
            LutGemmEngine::new(BcqLinear::quantize(&ws, r1 - r0, k, 2, 32).unwrap())
        });
        pt::ensure(serial_l.gemm(&x, mb) == sharded_l.gemm(&x, mb), "lut not bit-identical")?;
        pt::ensure(
            sharded_l.counters().lookups == serial_l.counters().lookups,
            "lut lookups diverged",
        )
    });
}

#[test]
fn prop_shard_plans_cover_and_align() {
    let gen = pt::gen_fn(|rng: &mut Prng| {
        let align = [1usize, 4, 8, 32][rng.index(4)];
        let len = align * (1 + rng.index(64)) + rng.index(align); // maybe ragged
        let shards = 1 + rng.index(8);
        let min = 1 + rng.index(48);
        (len, shards, min, align)
    });
    pt::assert_prop(
        "plans are disjoint aligned covers",
        pt::PropConfig { cases: 200, ..Default::default() },
        &gen,
        |&(len, shards, min, align)| {
            let p = ShardPlan::new(len, shards, min, align);
            pt::ensure(p.num_shards() <= shards.max(1), "too many shards")?;
            let mut pos = 0usize;
            for (i, &(a, b)) in p.shards.iter().enumerate() {
                pt::ensure(a == pos && b > a, format!("shard {i} not contiguous"))?;
                pt::ensure(
                    a % align == 0,
                    format!("shard {i} start {a} not aligned to {align}"),
                )?;
                pos = b;
            }
            pt::ensure(pos == len, format!("cover ends at {pos}, want {len}"))
        },
    );
}

#[test]
fn prop_row_parallel_deterministic_and_close() {
    let pool = Arc::new(ThreadPool::new(4));
    let gen = pt::gen_fn(|rng: &mut Prng| {
        let n = 8 * (1 + rng.index(6));
        let k = 16 * (1 + rng.index(8));
        let shards = 1 + rng.index(4);
        (n, k, shards, rng.next_u64())
    });
    pt::assert_prop(
        "row-parallel == serial up to reassociation, deterministic",
        pt::PropConfig { cases: 24, ..Default::default() },
        &gen,
        |&(n, k, shards, seed)| {
            let w = Prng::seeded(seed).normal_vec(n * k, 1.0);
            let x = Prng::seeded(seed ^ 5).normal_vec(k, 1.0);
            let mut serial = DenseEngine::new(w.clone(), n, k);
            let mk = || {
                let plan = ShardPlan::new(k, shards, 1, 1);
                let engines: Vec<Box<dyn GemmEngine + Send + Sync>> = plan
                    .shards
                    .iter()
                    .map(|&(c0, c1)| {
                        Box::new(DenseEngine::new(shard::dense_cols(&w, k, c0, c1), n, c1 - c0))
                            as Box<dyn GemmEngine + Send + Sync>
                    })
                    .collect();
                TpLinear::row(plan, engines, Arc::clone(&pool))
            };
            let y1 = mk().gemv(&x);
            let y2 = mk().gemv(&x);
            pt::ensure(y1 == y2, "row-parallel must be deterministic")?;
            let rel = stats::rel_l2(&y1, &serial.gemv(&x));
            pt::ensure(rel < 1e-5, format!("row-parallel diverged: rel {rel}"))
        },
    );
}
