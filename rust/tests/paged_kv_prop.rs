//! Property tests pinning the paged KV cache to the contiguous one
//! (via `util::proptest`):
//!
//! - a full model forward (batched prefill + decode) over `kvcache::PagedKv`
//!   is **bit-identical** (`==`, not approximate) to the same forward over
//!   the contiguous `model::KvCache`, across page sizes × head geometries
//!   × prompt lengths (including lengths straddling page boundaries) —
//!   the acceptance bar for the chunked attention kernel: paging is a
//!   memory layout decision, never a numerics one;
//! - cache metadata (fill length, `bytes_used`) agrees between the two
//!   representations;
//! - decoding with paging enabled stays allocation-free after warmup
//!   (page-table capacity and pool storage never grow).

use codegemm::config::{ModelConfig, QuantConfig};
use codegemm::kvcache::{BlockPool, KvLayout, KvStore, PagedKv, SeqKv};
use codegemm::model::{argmax, EngineKind, LlamaModel, ModelWeights};
use codegemm::util::proptest as pt;

/// One random paged-vs-contiguous scenario.
#[derive(Clone, Copy, Debug)]
struct KvCase {
    page_size: usize,
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
    prompt_len: usize,
    decode_steps: usize,
    seed: u64,
}

const PAGE_SIZES: [usize; 8] = [1, 2, 3, 4, 5, 8, 16, 64];
const HEADS: [(usize, usize); 4] = [(2, 1), (4, 2), (4, 4), (4, 1)];
const MAX_SEQ: usize = 48;

fn gen_case() -> impl pt::Gen<KvCase> {
    pt::gen_fn(|rng| {
        let (n_heads, n_kv_heads) = HEADS[rng.index(HEADS.len())];
        KvCase {
            page_size: PAGE_SIZES[rng.index(PAGE_SIZES.len())],
            n_heads,
            n_kv_heads,
            head_dim: if rng.index(2) == 0 { 4 } else { 8 },
            // Straddles page boundaries for every page size above.
            prompt_len: 1 + rng.index(40),
            decode_steps: rng.index(4),
            seed: rng.next_u64(),
        }
    })
}

fn model_config(c: &KvCase) -> ModelConfig {
    ModelConfig {
        name: "paged-prop".into(),
        vocab: 48,
        hidden: c.n_heads * c.head_dim,
        n_layers: 2,
        n_heads: c.n_heads,
        n_kv_heads: c.n_kv_heads,
        ffn: 3 * c.n_heads * c.head_dim,
        max_seq: MAX_SEQ,
        rope_theta_milli: 10_000_000,
    }
}

fn prompt_for(c: &KvCase, vocab: usize) -> Vec<usize> {
    (0..c.prompt_len).map(|i| (i * 13 + c.seed as usize) % vocab).collect()
}

/// Run prefill + a few decode steps under both cache representations and
/// demand bitwise-equal logits at every step.
fn check_case(c: &KvCase, kind: EngineKind) -> Result<(), String> {
    let cfg = model_config(c);
    let w = ModelWeights::random(cfg.clone(), c.seed);
    let mut model = LlamaModel::load(&w, kind, None);
    let prompt = prompt_for(c, cfg.vocab);

    // Contiguous reference.
    let mut flat = model.new_cache();
    let lf = model.forward_batch(&prompt, 0, &mut flat);

    // Paged run through the pool.
    let layout = KvLayout {
        n_layers: cfg.n_layers,
        kv_dim: cfg.kv_dim(),
        page_size: c.page_size,
        max_seq: MAX_SEQ,
    };
    let mut pool = BlockPool::new(layout, layout.max_pages_per_seq());
    let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
    let mut paged = PagedKv::bind(&mut pool, &mut seq);
    let lp = model.forward_batch(&prompt, 0, &mut paged);

    pt::ensure(lf == lp, format!("prefill logits not bit-identical ({c:?})"))?;
    pt::ensure(
        flat.len == paged.len() && paged.len() == prompt.len(),
        format!("cache fill diverged: flat {} vs paged {} ({c:?})", flat.len, paged.len()),
    )?;
    pt::ensure(
        KvStore::bytes_used(&flat) == paged.bytes_used(),
        format!("bytes_used diverged ({c:?})"),
    )?;
    // Held bytes: the paged side holds whole pages; both bound the fill.
    pt::ensure(paged.bytes() >= paged.bytes_used(), format!("held < filled ({c:?})"))?;

    // Greedy decode must stay bitwise locked step by step.
    let (mut lf, mut lp) = (lf, lp);
    for step in 0..c.decode_steps {
        let pos = prompt.len() + step;
        if pos >= MAX_SEQ {
            break;
        }
        let (tf, tp) = (argmax(&lf), argmax(&lp));
        pt::ensure(tf == tp, format!("greedy token diverged at step {step} ({c:?})"))?;
        lf = model.forward(tf, pos, &mut flat);
        lp = model.forward(tp, pos, &mut paged);
        pt::ensure(lf == lp, format!("decode logits not bit-identical at step {step} ({c:?})"))?;
    }
    Ok(())
}

#[test]
fn prop_paged_prefill_and_decode_bit_exact_dense() {
    let cfg = pt::PropConfig { cases: 28, ..Default::default() };
    pt::assert_prop("paged == contiguous (dense)", cfg, &gen_case(), |c: &KvCase| {
        check_case(c, EngineKind::Dense)
    });
}

#[test]
fn prop_paged_bit_exact_quantized_engine() {
    // The cache representation must also be invisible to table-kernel
    // engines: attention is the only consumer of the cache, so even a
    // quantized model's logits are bitwise identical across paging.
    let cfg = pt::PropConfig { cases: 6, seed: 0xFEED_BEEF, ..Default::default() };
    // Row-wise normalization (g = -1): valid for every sampled layer
    // width (all are multiples of v = 4).
    let quant = QuantConfig::new(4, 1, 6, -1).unwrap();
    pt::assert_prop("paged == contiguous (codegemm)", cfg, &gen_case(), |c: &KvCase| {
        // Quantization requires hidden % v == 0 — all sampled dims are
        // multiples of 8, so every case is valid.
        check_case(c, EngineKind::codegemm(quant))
    });
}

#[test]
fn paged_decode_is_allocation_free_after_warmup() {
    let c = KvCase {
        page_size: 4,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 8,
        prompt_len: 6,
        decode_steps: 0,
        seed: 99,
    };
    let cfg = model_config(&c);
    let w = ModelWeights::random(cfg.clone(), c.seed);
    let mut model = LlamaModel::load(&w, EngineKind::Dense, None);
    let layout = KvLayout {
        n_layers: cfg.n_layers,
        kv_dim: cfg.kv_dim(),
        page_size: c.page_size,
        max_seq: MAX_SEQ,
    };
    let mut pool = BlockPool::new(layout, layout.max_pages_per_seq());
    let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
    let mut logits = vec![0f32; cfg.vocab];
    {
        let mut paged = PagedKv::bind(&mut pool, &mut seq);
        model.forward_into(1, 0, &mut paged, &mut logits);
    }
    let warm_cap = seq.page_capacity();
    // Decode across several page boundaries: pages are claimed from the
    // free list (pool churn) but no buffer grows.
    for pos in 1..30 {
        let mut paged = PagedKv::bind(&mut pool, &mut seq);
        let tok = argmax(&logits);
        model.forward_into(tok, pos, &mut paged, &mut logits);
    }
    assert_eq!(seq.page_capacity(), warm_cap, "page table reallocated during decode");
    assert_eq!(seq.n_pages(), 30usize.div_ceil(c.page_size));
    assert_eq!(pool.stats().allocated as usize, seq.n_pages(), "one pop per page span");
}
