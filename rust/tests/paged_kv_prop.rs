//! Property tests pinning the paged KV cache to the contiguous one
//! (via `util::proptest`):
//!
//! - a full model forward (batched prefill + decode) over `kvcache::PagedKv`
//!   is **bit-identical** (`==`, not approximate) to the same forward over
//!   the contiguous `model::KvCache`, across page sizes × head geometries
//!   × prompt lengths (including lengths straddling page boundaries) —
//!   the acceptance bar for the chunked attention kernel: paging is a
//!   memory layout decision, never a numerics one;
//! - cache metadata (fill length, `bytes_used`) agrees between the two
//!   representations;
//! - decoding with paging enabled stays allocation-free after warmup
//!   (page-table capacity and pool storage never grow);
//! - the coded page dtypes hold their contract: f16/int8 runs are
//!   bitwise deterministic (encode→decode is a pure function of the
//!   written rows), f16 greedy decode tracks f32, and int8 logits stay
//!   within a documented epsilon of f32 with greedy tokens matching
//!   whenever the f32 top-2 margin makes the comparison decidable.

use codegemm::config::{ModelConfig, QuantConfig};
use codegemm::kvcache::{BlockPool, KvDtype, KvLayout, KvStore, PagedKv, SeqKv};
use codegemm::model::{argmax, EngineKind, LlamaModel, ModelWeights};
use codegemm::util::proptest as pt;

/// One random paged-vs-contiguous scenario.
#[derive(Clone, Copy, Debug)]
struct KvCase {
    page_size: usize,
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
    prompt_len: usize,
    decode_steps: usize,
    seed: u64,
}

const PAGE_SIZES: [usize; 8] = [1, 2, 3, 4, 5, 8, 16, 64];
const HEADS: [(usize, usize); 4] = [(2, 1), (4, 2), (4, 4), (4, 1)];
const MAX_SEQ: usize = 48;

fn gen_case() -> impl pt::Gen<KvCase> {
    pt::gen_fn(|rng| {
        let (n_heads, n_kv_heads) = HEADS[rng.index(HEADS.len())];
        KvCase {
            page_size: PAGE_SIZES[rng.index(PAGE_SIZES.len())],
            n_heads,
            n_kv_heads,
            head_dim: if rng.index(2) == 0 { 4 } else { 8 },
            // Straddles page boundaries for every page size above.
            prompt_len: 1 + rng.index(40),
            decode_steps: rng.index(4),
            seed: rng.next_u64(),
        }
    })
}

fn model_config(c: &KvCase) -> ModelConfig {
    ModelConfig {
        name: "paged-prop".into(),
        vocab: 48,
        hidden: c.n_heads * c.head_dim,
        n_layers: 2,
        n_heads: c.n_heads,
        n_kv_heads: c.n_kv_heads,
        ffn: 3 * c.n_heads * c.head_dim,
        max_seq: MAX_SEQ,
        rope_theta_milli: 10_000_000,
    }
}

fn prompt_for(c: &KvCase, vocab: usize) -> Vec<usize> {
    (0..c.prompt_len).map(|i| (i * 13 + c.seed as usize) % vocab).collect()
}

/// Run prefill + a few decode steps under both cache representations and
/// demand bitwise-equal logits at every step.
fn check_case(c: &KvCase, kind: EngineKind) -> Result<(), String> {
    let cfg = model_config(c);
    let w = ModelWeights::random(cfg.clone(), c.seed);
    let mut model = LlamaModel::load(&w, kind, None);
    let prompt = prompt_for(c, cfg.vocab);

    // Contiguous reference.
    let mut flat = model.new_cache();
    let lf = model.forward_batch(&prompt, 0, &mut flat);

    // Paged run through the pool.
    let layout = KvLayout {
        n_layers: cfg.n_layers,
        kv_dim: cfg.kv_dim(),
        page_size: c.page_size,
        max_seq: MAX_SEQ,
        dtype: KvDtype::F32,
    };
    let mut pool = BlockPool::new(layout, layout.max_pages_per_seq());
    let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
    let mut paged = PagedKv::bind(&mut pool, &mut seq);
    let lp = model.forward_batch(&prompt, 0, &mut paged);

    pt::ensure(lf == lp, format!("prefill logits not bit-identical ({c:?})"))?;
    pt::ensure(
        flat.len == paged.len() && paged.len() == prompt.len(),
        format!("cache fill diverged: flat {} vs paged {} ({c:?})", flat.len, paged.len()),
    )?;
    pt::ensure(
        KvStore::bytes_used(&flat) == paged.bytes_used(),
        format!("bytes_used diverged ({c:?})"),
    )?;
    // Held bytes: the paged side holds whole pages; both bound the fill.
    pt::ensure(paged.bytes() >= paged.bytes_used(), format!("held < filled ({c:?})"))?;

    // Greedy decode must stay bitwise locked step by step.
    let (mut lf, mut lp) = (lf, lp);
    for step in 0..c.decode_steps {
        let pos = prompt.len() + step;
        if pos >= MAX_SEQ {
            break;
        }
        let (tf, tp) = (argmax(&lf), argmax(&lp));
        pt::ensure(tf == tp, format!("greedy token diverged at step {step} ({c:?})"))?;
        lf = model.forward(tf, pos, &mut flat);
        lp = model.forward(tp, pos, &mut paged);
        pt::ensure(lf == lp, format!("decode logits not bit-identical at step {step} ({c:?})"))?;
    }
    Ok(())
}

#[test]
fn prop_paged_prefill_and_decode_bit_exact_dense() {
    let cfg = pt::PropConfig { cases: 28, ..Default::default() };
    pt::assert_prop("paged == contiguous (dense)", cfg, &gen_case(), |c: &KvCase| {
        check_case(c, EngineKind::Dense)
    });
}

#[test]
fn prop_paged_bit_exact_quantized_engine() {
    // The cache representation must also be invisible to table-kernel
    // engines: attention is the only consumer of the cache, so even a
    // quantized model's logits are bitwise identical across paging.
    let cfg = pt::PropConfig { cases: 6, seed: 0xFEED_BEEF, ..Default::default() };
    // Row-wise normalization (g = -1): valid for every sampled layer
    // width (all are multiples of v = 4).
    let quant = QuantConfig::new(4, 1, 6, -1).unwrap();
    pt::assert_prop("paged == contiguous (codegemm)", cfg, &gen_case(), |c: &KvCase| {
        // Quantization requires hidden % v == 0 — all sampled dims are
        // multiples of 8, so every case is valid.
        check_case(c, EngineKind::codegemm(quant))
    });
}

#[test]
fn paged_decode_is_allocation_free_after_warmup() {
    let c = KvCase {
        page_size: 4,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 8,
        prompt_len: 6,
        decode_steps: 0,
        seed: 99,
    };
    let cfg = model_config(&c);
    let w = ModelWeights::random(cfg.clone(), c.seed);
    let mut model = LlamaModel::load(&w, EngineKind::Dense, None);
    let layout = KvLayout {
        n_layers: cfg.n_layers,
        kv_dim: cfg.kv_dim(),
        page_size: c.page_size,
        max_seq: MAX_SEQ,
        dtype: KvDtype::F32,
    };
    let mut pool = BlockPool::new(layout, layout.max_pages_per_seq());
    let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
    let mut logits = vec![0f32; cfg.vocab];
    {
        let mut paged = PagedKv::bind(&mut pool, &mut seq);
        model.forward_into(1, 0, &mut paged, &mut logits);
    }
    let warm_cap = seq.page_capacity();
    // Decode across several page boundaries: pages are claimed from the
    // free list (pool churn) but no buffer grows.
    for pos in 1..30 {
        let mut paged = PagedKv::bind(&mut pool, &mut seq);
        let tok = argmax(&logits);
        model.forward_into(tok, pos, &mut paged, &mut logits);
    }
    assert_eq!(seq.page_capacity(), warm_cap, "page table reallocated during decode");
    assert_eq!(seq.n_pages(), 30usize.div_ceil(c.page_size));
    assert_eq!(pool.stats().allocated as usize, seq.n_pages(), "one pop per page span");
}

// ---------------------------------------------------------------------------
// Coded page dtypes: determinism, and accuracy vs the f32 pool
// ---------------------------------------------------------------------------

/// Prefill + self-greedy decode over a paged cache of `dtype`; returns
/// the logits of every step and the greedy tokens fed back in.
fn paged_greedy_run(
    model: &mut LlamaModel,
    cfg: &ModelConfig,
    c: &KvCase,
    dtype: KvDtype,
) -> (Vec<Vec<f32>>, Vec<usize>) {
    let prompt = prompt_for(c, cfg.vocab);
    let layout = KvLayout {
        n_layers: cfg.n_layers,
        kv_dim: cfg.kv_dim(),
        page_size: c.page_size,
        max_seq: MAX_SEQ,
        dtype,
    };
    let mut pool = BlockPool::new(layout, layout.max_pages_per_seq());
    let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
    let mut paged = PagedKv::bind(&mut pool, &mut seq);
    let mut logits = model.forward_batch(&prompt, 0, &mut paged);
    let mut steps = vec![logits.clone()];
    let mut toks = Vec::new();
    for step in 0..c.decode_steps {
        let pos = prompt.len() + step;
        if pos >= MAX_SEQ {
            break;
        }
        let tok = argmax(&logits);
        toks.push(tok);
        logits = model.forward(tok, pos, &mut paged);
        steps.push(logits.clone());
    }
    (steps, toks)
}

#[test]
fn prop_coded_dtype_runs_are_bitwise_deterministic() {
    // Round-trip determinism: the coded page stores are pure functions of
    // the rows written into them (per-row scales, no history), so the
    // same forward over a fresh pool reproduces every logit bit for bit.
    // This is the property that makes spill/restore and prefix sharing of
    // *quantized* pages safe — replaying a prefix must land on identical
    // coded bytes.
    let cfg = pt::PropConfig { cases: 10, ..Default::default() };
    pt::assert_prop("coded dtype determinism", cfg, &gen_case(), |c: &KvCase| {
        let mcfg = model_config(c);
        let w = ModelWeights::random(mcfg.clone(), c.seed);
        let mut model = LlamaModel::load(&w, EngineKind::Dense, None);
        for dtype in [KvDtype::F16, KvDtype::Int8] {
            let (la, ta) = paged_greedy_run(&mut model, &mcfg, c, dtype);
            let (lb, tb) = paged_greedy_run(&mut model, &mcfg, c, dtype);
            pt::ensure(la == lb, format!("{dtype:?} rerun logits not bit-identical ({c:?})"))?;
            pt::ensure(ta == tb, format!("{dtype:?} rerun tokens diverged ({c:?})"))?;
        }
        Ok(())
    });
}

/// Largest |a - b| over two logit vectors.
fn linf(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).fold(0f32, |m, (&x, &y)| m.max((x - y).abs()))
}

/// Gap between the two largest entries — how decidable the argmax is.
fn top2_margin(l: &[f32]) -> f32 {
    let (mut top, mut next) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
    for &x in l {
        if x > top {
            next = top;
            top = x;
        } else if x > next {
            next = x;
        }
    }
    top - next
}

#[test]
fn coded_dtypes_track_f32_within_epsilon_and_match_greedy_tokens() {
    // The smoke model: fixed geometry, f32 pool vs a coded pool fed the
    // same (teacher-forced) tokens so the comparison never forks.
    //
    // The epsilon contract per dtype, both relative to the f32 logit
    // magnitude `s`:
    // - f16: each cached element rounds with relative error ≤ 2^-11;
    //   through two layers of attention that stays far below one part in
    //   a hundred of the logit scale. Bound: 0.005 + 0.01·s.
    // - int8: per-row scales bound each element's error by amax/254
    //   (~0.4% of the row's largest entry); softmax mixing and the output
    //   projections amplify that by a small constant. Bound: 0.1 + 0.1·s.
    //
    // Greedy tokens are asserted equal whenever the f32 top-2 margin
    // exceeds twice the *observed* L∞ error — under that condition a
    // mismatch is arithmetically impossible if the epsilon bound held,
    // so the token check pins exactly the decidable comparisons.
    let c = KvCase {
        page_size: 4,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 8,
        prompt_len: 19,
        decode_steps: 6,
        seed: 0xC0DE,
    };
    let cfg = model_config(&c);
    let w = ModelWeights::random(cfg.clone(), c.seed);
    let mut model = LlamaModel::load(&w, EngineKind::Dense, None);
    let prompt = prompt_for(&c, cfg.vocab);
    let layout_for = |dtype| KvLayout {
        n_layers: cfg.n_layers,
        kv_dim: cfg.kv_dim(),
        page_size: c.page_size,
        max_seq: MAX_SEQ,
        dtype,
    };
    for (dtype, abs_tol, rel_tol) in
        [(KvDtype::F16, 0.005f32, 0.01f32), (KvDtype::Int8, 0.1, 0.1)]
    {
        let ref_layout = layout_for(KvDtype::F32);
        let mut ref_pool = BlockPool::new(ref_layout, ref_layout.max_pages_per_seq());
        let mut ref_seq = SeqKv::with_capacity(ref_layout.max_pages_per_seq());
        let mut ref_kv = PagedKv::bind(&mut ref_pool, &mut ref_seq);
        let coded_layout = layout_for(dtype);
        let mut coded_pool = BlockPool::new(coded_layout, coded_layout.max_pages_per_seq());
        let mut coded_seq = SeqKv::with_capacity(coded_layout.max_pages_per_seq());
        let mut coded_kv = PagedKv::bind(&mut coded_pool, &mut coded_seq);

        let mut lf = model.forward_batch(&prompt, 0, &mut ref_kv);
        let mut lq = model.forward_batch(&prompt, 0, &mut coded_kv);
        for step in 0..=c.decode_steps {
            let scale = lf.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let err = linf(&lf, &lq);
            let tol = abs_tol + rel_tol * scale;
            assert!(
                err <= tol,
                "{dtype:?} step {step}: logits drifted {err} from f32 (tol {tol})"
            );
            let tok = argmax(&lf);
            if top2_margin(&lf) > 2.0 * err {
                assert_eq!(argmax(&lq), tok, "{dtype:?} step {step}: greedy token diverged");
            }
            let pos = prompt.len() + step;
            if step == c.decode_steps || pos >= MAX_SEQ {
                break;
            }
            lf = model.forward(tok, pos, &mut ref_kv);
            lq = model.forward(tok, pos, &mut coded_kv);
        }
    }
}
