//! End-to-end integration over the real AOT artifacts: rust PJRT runtime
//! loads the python-lowered decode-step HLO, runs greedy decode, and the
//! results must agree with the pure-Rust NativeBackend on the same
//! quantized model. Skips (with a message) when `make artifacts` has not
//! been run.

use codegemm::config::ModelConfig;
use codegemm::coordinator::{DecodeBackend, PjrtBackend, SlotStep};
use codegemm::runtime::ModelRuntime;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let d = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_decode_step_runs_and_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(&dir).unwrap();
    assert_eq!(rt.manifest.model, ModelConfig::tiny());
    let mut be = PjrtBackend::with_batch(rt, 1);
    let l1 = be.step(&[SlotStep { slot: 0, token: 104, pos: 0 }]).unwrap();
    let l2 = be.step(&[SlotStep { slot: 0, token: 105, pos: 1 }]).unwrap();
    assert_eq!(l1[0].len(), 256);
    assert!(l1[0].iter().all(|x| x.is_finite()));
    // replay from scratch must reproduce exactly
    be.reset_slot(0);
    let r1 = be.step(&[SlotStep { slot: 0, token: 104, pos: 0 }]).unwrap();
    let r2 = be.step(&[SlotStep { slot: 0, token: 105, pos: 1 }]).unwrap();
    assert_eq!(l1[0], r1[0]);
    assert_eq!(l2[0], r2[0]);
}

#[test]
fn batched_pjrt_matches_single_stream() {
    let Some(dir) = artifacts_dir() else { return };
    let rt1 = ModelRuntime::load(&dir).unwrap();
    let rt4 = ModelRuntime::load(&dir).unwrap();
    let mut b1 = PjrtBackend::with_batch(rt1, 1);
    let mut b4 = PjrtBackend::with_batch(rt4, 4);
    let seq = [10usize, 20, 30];
    let mut last1 = Vec::new();
    for (pos, &t) in seq.iter().enumerate() {
        last1 = b1.step(&[SlotStep { slot: 0, token: t, pos }]).unwrap().remove(0);
    }
    let mut last4 = Vec::new();
    for (pos, &t) in seq.iter().enumerate() {
        // run the same sequence in slot 2 of the batch-4 executable, with
        // other slots doing unrelated work
        let outs = b4
            .step(&[
                SlotStep { slot: 0, token: 7, pos },
                SlotStep { slot: 2, token: t, pos },
            ])
            .unwrap();
        last4 = outs[1].clone();
    }
    let rel = codegemm::util::stats::rel_l2(&last4, &last1);
    assert!(rel < 1e-4, "batched vs single-stream rel {rel}");
}
