//! Cross-module integration tests: quantization → engines → model →
//! evaluation → coordinator, plus simulator-vs-CPU-engine consistency.

use codegemm::bench::tables::{self, EvalContext};
use codegemm::config::{KernelConfig, ModelConfig, QuantConfig, ServeConfig};
use codegemm::coordinator::{Batcher, Metrics, NativeBackend, Request};
use codegemm::eval::corpus::{Corpus, CorpusSpec};
use codegemm::gemm::{CodeGemmEngine, DenseEngine, DequantEngine, GemmEngine};
use codegemm::model::{EngineKind, LlamaModel, ModelWeights};
use codegemm::quant::Quantizer;
use codegemm::simulator::{Method, Simulator};
use codegemm::util::proptest as pt;
use codegemm::util::prng::Prng;
use codegemm::util::stats;
use std::sync::Arc;

// ------------------------------------------------------------ invariants

/// Property: CodeGEMM == dequantize-then-GEMM over random configs/shapes.
#[test]
fn prop_codegemm_identity_over_random_configs() {
    let gen = pt::gen_fn(|rng: &mut Prng| {
        let v = [4usize, 8][rng.index(2)];
        let m = 1 + rng.index(3);
        let b = 3 + rng.index(6);
        let tiles_n = 1 + rng.index(3);
        let tiles_k = 1 + rng.index(3);
        let g = [32i64, 64, -1][rng.index(3)];
        (v, m, b, 16 * tiles_n, 32 * tiles_k, g, rng.next_u64())
    });
    pt::assert_prop("codegemm == dequant-dense", pt::PropConfig { cases: 24, ..Default::default() }, &gen, |&(v, m, b, n, k, g, seed)| {
        let Ok(cfg) = QuantConfig::new(v, m, b, g) else {
            return Ok(()); // invalid combination — vacuous
        };
        let w = Prng::seeded(seed).normal_vec(n * k, 0.02);
        let q = Quantizer::new(cfg).quantize(&w, n, k);
        let x = Prng::seeded(seed ^ 1).normal_vec(k, 1.0);
        let y = CodeGemmEngine::from_quantized(&q).gemv(&x);
        let y_ref = DenseEngine::new(q.dequantize(), n, k).gemv(&x);
        pt::ensure(stats::rel_l2(&y, &y_ref) < 1e-4, format!("mismatch at {cfg:?} {n}x{k}"))
    });
}

/// Property: batching never changes greedy decode results.
#[test]
fn prop_batching_invariance() {
    let w = ModelWeights::random(ModelConfig::tiny(), 21);
    let gen = pt::gen_fn(|rng: &mut Prng| {
        let n_req = 2 + rng.index(4);
        let prompts: Vec<Vec<usize>> = (0..n_req)
            .map(|_| (0..1 + rng.index(6)).map(|_| 1 + rng.index(250)).collect())
            .collect();
        prompts
    });
    let mk = |w: &ModelWeights, batch: usize| {
        Batcher::new(
            Box::new(NativeBackend::new(w, EngineKind::Dense, batch)),
            ServeConfig { max_batch: batch, max_new_tokens: 3, temperature: 0.0, queue_capacity: 64, ..Default::default() },
            Arc::new(Metrics::new()),
        )
    };
    let cfg = pt::PropConfig { cases: 8, ..Default::default() };
    let res = pt::check(cfg, &gen, |prompts: &Vec<Vec<usize>>| {
        let mut seq = Vec::new();
        for p in prompts {
            let mut b = mk(&w, 1);
            b.submit(Request::new(0u64, p.clone(), 3));
            seq.push(b.run_to_completion().remove(0).tokens);
        }
        let mut b = mk(&w, 3);
        for (i, p) in prompts.iter().enumerate() {
            b.submit(Request::new(i as u64, p.clone(), 3));
        }
        let mut out = b.run_to_completion();
        out.sort_by_key(|r| r.id);
        for (i, r) in out.iter().enumerate() {
            pt::ensure(r.tokens == seq[i], format!("request {i} diverged under batching"))?;
        }
        Ok(())
    });
    match res {
        pt::PropResult::Pass { .. } => {}
        other => panic!("{other:?}"),
    }
}

// --------------------------------------------------- cross-module checks

#[test]
fn quantized_model_end_to_end_accuracy_chain() {
    // corpus → bigram weights → quantize under two budgets → ppl ordering.
    let corpus = Corpus::synthesize(CorpusSpec { vocab: 64, len: 1600, ..Default::default() });
    let w = ModelWeights::bigram(ModelConfig::tiny(), &corpus.log_probs, 5);
    let (_, held) = corpus.split();
    let measure = |kind: EngineKind| {
        codegemm::eval::sweep::measure(&w, kind, None, held, 120).ppl
    };
    let fp = measure(EngineKind::Dense);
    let hi = measure(EngineKind::codegemm(QuantConfig::new(4, 4, 8, 32).unwrap()));
    let lo = measure(EngineKind::codegemm(QuantConfig::new(8, 1, 8, -1).unwrap()));
    assert!(fp <= hi * 1.05, "fp {fp} vs high-bit {hi}");
    assert!(hi < lo * 0.9, "high-bit {hi} must beat ~1-bit row-wise {lo}");
}

#[test]
fn simulator_and_cpu_engine_agree_on_build_read_structure() {
    // The simulator's CodeGEMM features and the CPU engine's counters
    // must tell the same story: build share rises with 2^b·K relative to
    // N·K·m/v.
    let (n, k) = (512, 1024);
    let w = Prng::seeded(5).normal_vec(n * k, 0.02);
    let share = |cfg: QuantConfig| {
        let q = Quantizer::new(cfg).quantize(&w, n, k);
        let mut e = CodeGemmEngine::with_kernel(&q, KernelConfig::new(32, 512).unwrap());
        let x = Prng::seeded(6).normal_vec(k, 1.0);
        let _ = e.gemv(&x);
        e.counters().build_share_ops()
    };
    // m2v8 builds 2 codebooks but reads m/v = 1/4 per element; m1v4 builds
    // 1 codebook and reads 1/4 per element ⇒ m2v8 has the higher build
    // share (paper Table 6: 30.5% vs 20.3%).
    let s_m2v8 = share(QuantConfig::m2v8g128());
    let s_m1v4 = share(QuantConfig::m1v4g128());
    assert!(s_m2v8 > s_m1v4, "m2v8 build share {s_m2v8} should exceed m1v4 {s_m1v4}");
}

#[test]
fn dequant_engine_is_slower_in_ops_not_in_results() {
    // N must dominate 2^b for the m/v complexity win (paper §3 assumes
    // M >> 2^b); at small N the Psumbook build is not amortized.
    let (n, k) = (4096, 256);
    let cfg = QuantConfig::m1v4g128();
    let w = Prng::seeded(9).normal_vec(n * k, 0.02);
    let q = Quantizer::new(cfg).quantize(&w, n, k);
    let x = Prng::seeded(10).normal_vec(k, 1.0);
    let mut cg = CodeGemmEngine::from_quantized(&q);
    let mut dq = DequantEngine::from_quantized(&q);
    let (ycg, ydq) = (cg.gemv(&x), dq.gemv(&x));
    assert!(stats::rel_l2(&ycg, &ydq) < 1e-4);
    // Same results, ~v/m fewer MAC-class ops on the CodeGEMM side.
    let cg_ops = cg.counters().build_ops + cg.counters().read_ops + cg.counters().mac_flops;
    let dq_ops = dq.counters().mac_flops + dq.counters().lookups;
    assert!(
        (cg_ops as f64) < 0.8 * dq_ops as f64,
        "codegemm ops {cg_ops} should undercut dequant {dq_ops}"
    );
}

#[test]
fn model_under_every_engine_produces_sane_ppl() {
    let corpus = Corpus::synthesize(CorpusSpec { vocab: 64, len: 1200, ..Default::default() });
    let w = ModelWeights::bigram(ModelConfig::tiny(), &corpus.log_probs, 3);
    let (_, held) = corpus.split();
    for kind in [
        EngineKind::Dense,
        EngineKind::codegemm(QuantConfig::new(4, 2, 8, 32).unwrap()),
        EngineKind::Dequant { cfg: QuantConfig::new(4, 2, 8, 32).unwrap(), tune: codegemm::quant::calib::TuneLevel::None },
        EngineKind::Uniform { bits: 4, group: 32 },
        EngineKind::Lut { bits: 3, group: 32 },
    ] {
        let mut m = LlamaModel::load(&w, kind, None);
        let ppl = codegemm::eval::perplexity::perplexity(&mut m, held, 80);
        assert!(ppl.is_finite() && ppl < 400.0, "{}: ppl {ppl}", m.kind_label);
    }
}

// ------------------------------------------------------- table pipeline

#[test]
fn all_tables_render_without_artifacts() {
    let ctx = EvalContext::bigram_fallback();
    for id in tables::all_ids() {
        // accuracy-bearing tables are slow — keep to the quick ones here;
        // table 4/5/fig4b/fig5 are covered by the benches and the e2e run.
        if matches!(*id, "4" | "5" | "fig4b" | "fig5") {
            continue;
        }
        let out = tables::render(id, &ctx).unwrap();
        assert!(out.contains('|'), "{id} rendered nothing:\n{out}");
    }
}

#[test]
fn headline_claims_hold_in_regenerated_tables() {
    let s = Simulator::a100();
    let g8 = codegemm::bench::workloads::LLAMA3_8B;
    let g70 = codegemm::bench::workloads::LLAMA3_70B;
    // 2-bit CodeGEMM beats fp16 cuBLAS at block level (Table 2).
    assert!(
        s.block_latency_us(&Method::codegemm_m1v4g128(), &g8, 1)
            < s.block_latency_us(&Method::CuBlas, &g8, 1)
    );
    // The 70B AQLM-1x16 collapse (tok/s ratio ≳ 5).
    let ratio = s.tokens_per_s(&Method::codegemm_m1v4g128(), &g70, 1)
        / s.tokens_per_s(&Method::aqlm_1x16(), &g70, 1);
    assert!(ratio > 5.0, "70B speedup {ratio}");
}
