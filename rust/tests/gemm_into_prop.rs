//! Property tests for the zero-allocation execution model
//! (via `util::proptest`): over random shapes, quant configs, batch
//! sizes and shard counts,
//!
//! - `gemm_into` through a caller-owned (and deliberately *dirty*,
//!   cross-engine-reused) scratch is **bit-identical** to the legacy
//!   allocating `gemm` wrapper for every engine family;
//! - sharded `gemm_into` writing the caller's output buffer is
//!   bit-identical to the serial engine;
//! - `LlamaModel::forward_batch` prefill matches token-by-token
//!   `forward` on the same prompt (exact for dense, ≤1e-5 rel-L2 for the
//!   quantized table kernels, which reassociate the batched gather).

use codegemm::config::{ModelConfig, QuantConfig};
use codegemm::gemm::{
    CodeGemmEngine, DenseEngine, DequantEngine, EngineScratch, GemmEngine, LutGemmEngine,
    UniformGemmEngine,
};
use codegemm::model::{EngineKind, LlamaModel, ModelWeights};
use codegemm::parallel::{shard, ShardPlan, ShardedEngine};
use codegemm::quant::bcq::BcqLinear;
use codegemm::quant::uniform::UniformLinear;
use codegemm::util::proptest as pt;
use codegemm::util::stats;
use codegemm::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Check one engine family: `gemm_into` through the shared dirty scratch
/// must be bit-identical to the legacy allocating wrapper.
fn check_engine(
    e_into: &dyn GemmEngine,
    legacy: &mut dyn GemmEngine,
    x: &[f32],
    mb: usize,
    shared: &mut EngineScratch,
) -> Result<(), String> {
    let n = e_into.dims().0;
    let mut y = vec![f32::NAN; n * mb];
    e_into.gemm_into(x, mb, &mut y, shared);
    pt::ensure(y == legacy.gemm(x, mb), format!("{} gemm_into != gemm", legacy.name()))
}

/// One shared dirty scratch across all engines and cases: the reuse path
/// (reshape-in-place, grow-only buffers) must never leak state between
/// calls.
#[test]
fn prop_gemm_into_bit_identical_to_wrapper_across_engines() {
    let cfg = pt::PropConfig { cases: 20, ..Default::default() };
    let shared = std::cell::RefCell::new(EngineScratch::new());
    pt::assert_prop(
        "gemm_into == gemm for every engine",
        cfg,
        &pt::GemmCaseGen::default(),
        |c: &pt::GemmCase| {
            let mut guard = shared.borrow_mut();
            let shared = &mut *guard;
            let (n, k, mb) = (c.n, c.k, c.mb);
            let w = c.weights(0.05);
            let x = c.activations(1);

            if let Some(q) = c.quantized(0.05) {
                check_engine(
                    &CodeGemmEngine::from_quantized(&q),
                    &mut CodeGemmEngine::from_quantized(&q),
                    &x,
                    mb,
                    shared,
                )?;
                check_engine(
                    &DequantEngine::from_quantized(&q),
                    &mut DequantEngine::from_quantized(&q),
                    &x,
                    mb,
                    shared,
                )?;
            }
            let uq = UniformLinear::quantize(&w, n, k, 4, 32).expect("uniform");
            check_engine(
                &UniformGemmEngine::new(uq.clone()),
                &mut UniformGemmEngine::new(uq),
                &x,
                mb,
                shared,
            )?;
            let bq = BcqLinear::quantize(&w, n, k, 2, 32).expect("bcq");
            check_engine(&LutGemmEngine::new(bq.clone()), &mut LutGemmEngine::new(bq), &x, mb, shared)?;
            check_engine(
                &DenseEngine::new(w.clone(), n, k),
                &mut DenseEngine::new(w.clone(), n, k),
                &x,
                mb,
                shared,
            )
        },
    );
}

#[test]
fn prop_sharded_gemm_into_bit_identical_to_serial() {
    let pool = Arc::new(ThreadPool::new(4));
    let cfg = pt::PropConfig { cases: 16, ..Default::default() };
    let cell = std::cell::RefCell::new(EngineScratch::new());
    pt::assert_prop(
        "sharded gemm_into == serial gemm",
        cfg,
        &pt::GemmCaseGen::default(),
        |c: &pt::GemmCase| {
            let mut guard = cell.borrow_mut();
            let scratch_ref = &mut *guard;
            let Some(q) = c.quantized(0.02) else {
                return Ok(()); // invalid combination — vacuous
            };
            let (n, mb) = (c.n, c.mb);
            let x = c.activations(2);
            let mut serial = CodeGemmEngine::from_quantized(&q);
            let plan = ShardPlan::new(n, c.shards, 1, 1);
            let sharded = ShardedEngine::from_factory(plan, Arc::clone(&pool), |(r0, r1)| {
                CodeGemmEngine::from_quantized(&shard::slice_rows(&q, r0, r1))
            });
            let mut y = vec![f32::NAN; n * mb];
            sharded.gemm_into(&x, mb, &mut y, scratch_ref);
            pt::ensure(
                y == serial.gemm(&x, mb),
                format!("sharded gemm_into diverged ({c:?})"),
            )?;
            // Conserved work, accumulated into the caller's scratch.
            pt::ensure(
                scratch_ref.counters.lookups >= serial.counters().lookups,
                "caller scratch must absorb shard counters",
            )
        },
    );
}

#[test]
fn forward_batch_matches_sequential_forward_all_kinds() {
    let w = ModelWeights::random(ModelConfig::tiny(), 77);
    let prompt = [9usize, 120, 4, 33, 7];
    for (kind, tol) in [
        (EngineKind::Dense, 1e-6f64),
        (EngineKind::codegemm(QuantConfig::new(4, 1, 6, 32).unwrap()), 1e-5),
        (EngineKind::Uniform { bits: 4, group: 32 }, 1e-5),
    ] {
        let mut mb = LlamaModel::load(&w, kind, None);
        let mut cb = mb.new_cache();
        let lb = mb.forward_batch(&prompt, 0, &mut cb);
        let mut ms = LlamaModel::load(&w, kind, None);
        let mut cs = ms.new_cache();
        let mut ls = Vec::new();
        for (pos, &t) in prompt.iter().enumerate() {
            ls = ms.forward(t, pos, &mut cs);
        }
        let rel = stats::rel_l2(&lb, &ls);
        assert!(rel < tol, "{}: batched prefill rel {rel} >= {tol}", mb.kind_label);
    }
}

#[test]
fn forward_batch_matches_sequential_under_tensor_parallelism() {
    use codegemm::config::ParallelConfig;
    let w = ModelWeights::random(ModelConfig::tiny(), 78);
    let prompt = [5usize, 6, 7, 8];
    let par = ParallelConfig { num_threads: 3, shard_min_rows: 16, ..Default::default() };
    let pool = Arc::new(ThreadPool::new(3));
    let mut mb = LlamaModel::load_parallel(&w, EngineKind::Dense, None, &par, Arc::clone(&pool));
    let mut cb = mb.new_cache();
    let lb = mb.forward_batch(&prompt, 0, &mut cb);
    let mut ms = LlamaModel::load_parallel(&w, EngineKind::Dense, None, &par, pool);
    let mut cs = ms.new_cache();
    let mut ls = Vec::new();
    for (pos, &t) in prompt.iter().enumerate() {
        ls = ms.forward(t, pos, &mut cs);
    }
    let rel = stats::rel_l2(&lb, &ls);
    assert!(rel < 1e-5, "TP batched prefill diverged: rel {rel}");
}
