//! Property suite for fused projection groups (`gemm::GemmGroup`), via
//! the reusable `util::proptest` generators:
//!
//! - a fused Q/K/V-shaped group (members sliced from one joint
//!   quantization, mimicking `EngineKind::build_projection_set`) is
//!   **bit-exact** (`==`) vs. running its members independently, across
//!   v ∈ {4, 8} × m_batch ∈ {1, 4, 64} × serial/sharded execution,
//!   through a deliberately dirty, reused shared scratch, with warm
//!   scratch never growing;
//! - Psumbook build MACs are counted once per *group* call — the
//!   independent schedule pays exactly `members ×` (the regression-pinned
//!   group factor: 3× for Q/K/V, 2× for gate/up), gather work is
//!   conserved, and `Counters::group_fanout` records the members each
//!   build served;
//! - members with mismatched configs refuse to fuse and fall back to
//!   correct independent execution.

use codegemm::config::QuantConfig;
use codegemm::gemm::{CodeGemmEngine, Counters, EngineScratch, GemmEngine, GemmGroup, GroupMember};
use codegemm::parallel::{shard, ShardPlan};
use codegemm::quant::{QuantizedLinear, Quantizer};
use codegemm::util::proptest as pt;
use codegemm::util::prng::Prng;
use codegemm::util::threadpool::ThreadPool;
use std::sync::Arc;

/// The sweep the issue pins: both paper vector widths, decode (M=1),
/// small-batch and full-chunk (M=64) prefill, serial and sharded.
fn gen_case() -> pt::GemmCaseGen {
    pt::GemmCaseGen {
        vs: &[4, 8],
        bs: &[2, 3, 4],
        mbs: &[1, 4, 64],
        max_shards: 4,
        ..Default::default()
    }
}

/// Q/K/V-shaped member heights for a case: one full-width member and two
/// narrower ones (d, kv, kv).
fn member_heights(c: &pt::GemmCase) -> [usize; 3] {
    [c.n, (c.n / 2).max(4), (c.n / 2).max(4)]
}

/// Joint quantization over the stacked member rows (the factory's group
/// construction), sliced back into per-member layers.
fn stacked_members(c: &pt::GemmCase, ns: &[usize]) -> Option<Vec<QuantizedLinear>> {
    let cfg = c.quant_config()?;
    let n_total: usize = ns.iter().sum();
    let w = Prng::seeded(c.seed).normal_vec(n_total * c.k, 0.02);
    let q = Quantizer::new(cfg).quantize(&w, n_total, c.k);
    let codes = q.codes.unpack();
    let mut parts = Vec::with_capacity(ns.len());
    let mut r = 0usize;
    for &n in ns {
        parts.push(shard::slice_rows_unpacked(&q, &codes, r, r + n));
        r += n;
    }
    Some(parts)
}

fn serial_group(parts: &[QuantizedLinear]) -> GemmGroup {
    GemmGroup::new(
        parts.iter().map(|p| GroupMember::serial(CodeGemmEngine::from_quantized(p))).collect(),
        None,
    )
}

fn sharded_group(parts: &[QuantizedLinear], shards: usize, pool: &Arc<ThreadPool>) -> GemmGroup {
    GemmGroup::new(
        parts
            .iter()
            .map(|p| {
                let plan = ShardPlan::new(p.n, shards, 1, 1);
                if plan.is_serial() {
                    return GroupMember::serial(CodeGemmEngine::from_quantized(p));
                }
                let codes = p.codes.unpack();
                let engines = plan
                    .shards
                    .iter()
                    .map(|&(r0, r1)| {
                        CodeGemmEngine::from_quantized(&shard::slice_rows_unpacked(
                            p, &codes, r0, r1,
                        ))
                    })
                    .collect();
                GroupMember::sharded(plan, engines)
            })
            .collect(),
        Some(Arc::clone(pool)),
    )
}

fn run_group(
    group: &GemmGroup,
    ns: &[usize],
    x: &[f32],
    mb: usize,
    scratch: &mut EngineScratch,
) -> Vec<Vec<f32>> {
    let mut outs: Vec<Vec<f32>> = ns.iter().map(|&n| vec![f32::NAN; n * mb]).collect();
    {
        let mut views: Vec<&mut [f32]> = outs.iter_mut().map(|y| y.as_mut_slice()).collect();
        group.gemm_group_into(x, mb, &mut views, scratch);
    }
    outs
}

fn total_footprint(s: &EngineScratch) -> usize {
    s.footprint_bytes() + s.children.iter().map(|c| c.footprint_bytes()).sum::<usize>()
}

#[test]
fn prop_fused_group_bit_exact_vs_independent_with_dirty_scratch() {
    let pool = Arc::new(ThreadPool::new(4));
    let cfg = pt::PropConfig { cases: 12, ..Default::default() };
    // One scratch across every case and every schedule: book reshape,
    // grow-only staging and counter children must never leak state
    // between geometries or schedules.
    let cell = std::cell::RefCell::new(EngineScratch::new());
    pt::assert_prop("fused group == independent members", cfg, &gen_case(), |c: &pt::GemmCase| {
        let mut guard = cell.borrow_mut();
        let scratch = &mut *guard;
        let ns = member_heights(c);
        let Some(parts) = stacked_members(c, &ns) else {
            return Ok(()); // invalid combination — vacuous
        };
        let x = c.activations(1);
        // Independent reference: each member's own serial engine.
        let reference: Vec<Vec<f32>> = parts
            .iter()
            .map(|p| {
                let mut e = CodeGemmEngine::from_quantized(p);
                e.gemm(&x, c.mb)
            })
            .collect();

        let fused = serial_group(&parts);
        pt::ensure(fused.uses_fused(), "jointly quantized members must fuse")?;
        let y_fused = run_group(&fused, &ns, &x, c.mb, scratch);
        pt::ensure(y_fused == reference, format!("serial fused diverged ({c:?})"))?;

        // Sharded members: the shared book now serves shard × member.
        let sharded = sharded_group(&parts, c.shards, &pool);
        let y_sharded = run_group(&sharded, &ns, &x, c.mb, scratch);
        pt::ensure(y_sharded == reference, format!("sharded fused diverged ({c:?})"))?;

        // The explicit unfused schedule matches bitwise too.
        let unfused = serial_group(&parts).with_fused(false);
        let y_unfused = run_group(&unfused, &ns, &x, c.mb, scratch);
        pt::ensure(y_unfused == reference, format!("unfused fallback diverged ({c:?})"))?;

        // Warm repeat of the largest variant must not grow any buffer.
        let fp = total_footprint(scratch);
        let y_again = run_group(&sharded, &ns, &x, c.mb, scratch);
        pt::ensure(y_again == reference, "warm sharded call diverged")?;
        pt::ensure(
            total_footprint(scratch) == fp,
            format!("warm scratch grew: {} -> {}", fp, total_footprint(scratch)),
        )
    });
}

#[test]
fn prop_group_build_counted_once_and_fanout_recorded() {
    let cfg = pt::PropConfig { cases: 10, seed: 0xF0_5ED, ..Default::default() };
    pt::assert_prop("group build ops == independent / members", cfg, &gen_case(), |c| {
        let ns = member_heights(c);
        let Some(parts) = stacked_members(c, &ns) else {
            return Ok(());
        };
        let x = c.activations(2);
        let run = |fused: bool| -> Counters {
            let group = serial_group(&parts).with_fused(fused);
            let mut scratch = EngineScratch::new();
            run_group(&group, &ns, &x, c.mb, &mut scratch);
            scratch.counters
        };
        let on = run(true);
        let off = run(false);
        // Every member's rows fit one row block (tile_h default 2048), so
        // the independent schedule builds each k-tile exactly once per
        // member: the pinned group factor.
        pt::ensure(
            off.build_ops == 3 * on.build_ops,
            format!("build {} != 3 x {} ({c:?})", off.build_ops, on.build_ops),
        )?;
        pt::ensure(off.read_ops == on.read_ops, "gather work not conserved")?;
        pt::ensure(off.lookups == on.lookups, "lookups not conserved")?;
        pt::ensure(on.calls == 1 && on.group_fanout == 3, "fused call accounting")?;
        pt::ensure(off.calls == 3 && off.group_fanout == 0, "independent call accounting")?;
        pt::ensure(
            on.build_share_ops() < off.build_share_ops() || on.build_ops == 0,
            "fusion must shrink the build share",
        )
    });
}

#[test]
fn mismatched_member_configs_fall_back_but_stay_correct() {
    let k = 64usize;
    let quantize = |n: usize, label: &str, seed: u64| {
        let w = Prng::seeded(seed).normal_vec(n * k, 0.02);
        Quantizer::new(QuantConfig::parse_label(label).unwrap()).quantize(&w, n, k)
    };
    // Different codebooks (separate quantizations) and even different
    // formats: the group must refuse to fuse and still be correct.
    let qa = quantize(24, "m1v4g32", 1);
    let qb = quantize(16, "m2v8g32", 2);
    let group = GemmGroup::new(
        vec![
            GroupMember::serial(CodeGemmEngine::from_quantized(&qa)),
            GroupMember::serial(CodeGemmEngine::from_quantized(&qb)),
        ],
        None,
    );
    assert!(!group.is_fusable());
    assert!(!group.uses_fused());
    for mb in [1usize, 4] {
        let x = Prng::seeded(3 + mb as u64).normal_vec(k * mb, 1.0);
        let mut scratch = EngineScratch::new();
        let mut ya = vec![f32::NAN; 24 * mb];
        let mut yb = vec![f32::NAN; 16 * mb];
        group.gemm_group_into(&x, mb, &mut [&mut ya[..], &mut yb[..]], &mut scratch);
        assert_eq!(ya, CodeGemmEngine::from_quantized(&qa).gemm(&x, mb), "member a (mb={mb})");
        assert_eq!(yb, CodeGemmEngine::from_quantized(&qb).gemm(&x, mb), "member b (mb={mb})");
        assert_eq!(scratch.counters.group_fanout, 0, "no fanout on the fallback");
    }
    // Two separately-quantized members of the *same* config still must
    // not fuse: their codebooks differ.
    let qc = quantize(16, "m1v4g32", 9);
    let same_cfg = GemmGroup::new(
        vec![
            GroupMember::serial(CodeGemmEngine::from_quantized(&qa)),
            GroupMember::serial(CodeGemmEngine::from_quantized(&qc)),
        ],
        None,
    );
    assert!(!same_cfg.is_fusable(), "distinct codebooks must not share a book");
}
