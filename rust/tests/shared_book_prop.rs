//! Property suite for the build-once/gather-many shared-Psumbook path
//! (via the reusable `util::proptest` engine generators):
//!
//! - shared-book sharded CodeGEMM is **bit-exact** (`==`) vs. the serial
//!   engine across shard counts × v ∈ {4, 8} × b ∈ {1, 2, 4} ×
//!   m_batch ∈ {1, 4, 64}, through a deliberately dirty, reused shared
//!   scratch, and warm scratch never grows;
//! - Psumbook build MACs and `read_ops` are counted exactly once per
//!   logical call independent of the shard count, and the private-book
//!   schedule's build cost is pinned at `shards ×` the shared one (the
//!   K=1 vs K=4 regression ratio), so `build_share_ops` shrinks;
//! - shards with mismatched k-tile geometry refuse the shared book and
//!   fall back to correct private-table execution, while uniform shard
//!   construction (the `EngineKind`/factory path) lines its k-tiles up.

use codegemm::config::{KernelConfig, QuantConfig};
use codegemm::gemm::{CodeGemmEngine, DenseEngine, EngineScratch, GemmEngine};
use codegemm::parallel::{shard, ShardPlan, ShardedEngine};
use codegemm::quant::{QuantizedLinear, Quantizer};
use codegemm::util::proptest as pt;
use codegemm::util::prng::Prng;
use codegemm::util::stats;
use codegemm::util::threadpool::ThreadPool;
use std::sync::Arc;

/// The shared-book sweep the issue pins: small codebooks stress the
/// gather indexing, M=64 stresses the batched staging/scatter path.
fn gen_case() -> pt::GemmCaseGen {
    pt::GemmCaseGen {
        vs: &[4, 8],
        bs: &[1, 2, 4],
        mbs: &[1, 4, 64],
        max_shards: 6,
        ..Default::default()
    }
}

fn quantize(n: usize, k: usize, label: &str, seed: u64) -> QuantizedLinear {
    let w = Prng::seeded(seed).normal_vec(n * k, 0.02);
    Quantizer::new(QuantConfig::parse_label(label).unwrap()).quantize(&w, n, k)
}

/// Row-sharded CodeGEMM over `q`, one shard per plan range.
fn sharded(
    q: &QuantizedLinear,
    plan: ShardPlan,
    pool: Arc<ThreadPool>,
    kernel: KernelConfig,
    shared: bool,
) -> ShardedEngine<CodeGemmEngine> {
    let codes = q.codes.unpack();
    ShardedEngine::from_factory(plan, pool, |(r0, r1)| {
        CodeGemmEngine::with_kernel(&shard::slice_rows_unpacked(q, &codes, r0, r1), kernel)
    })
    .with_shared_book(shared)
}

fn total_footprint(s: &EngineScratch) -> usize {
    s.footprint_bytes() + s.children.iter().map(|c| c.footprint_bytes()).sum::<usize>()
}

#[test]
fn prop_shared_book_bit_exact_vs_serial_with_dirty_scratch() {
    let pool = Arc::new(ThreadPool::new(4));
    let cfg = pt::PropConfig { cases: 12, ..Default::default() };
    // One scratch across every case and both calls per case: the reuse
    // path (book reshape-in-place, grow-only staging, counter children)
    // must never leak state between geometries.
    let cell = std::cell::RefCell::new(EngineScratch::new());
    pt::assert_prop(
        "shared-book sharded codegemm == serial",
        cfg,
        &gen_case(),
        |c: &pt::GemmCase| {
            let mut guard = cell.borrow_mut();
            let scratch = &mut *guard;
            let Some(q) = c.quantized(0.02) else {
                return Ok(()); // invalid combination — vacuous
            };
            let x = c.activations(1);
            let mut serial = CodeGemmEngine::from_quantized(&q);
            let plan = ShardPlan::new(c.n, c.shards, 1, 1);
            let eng = sharded(&q, plan, Arc::clone(&pool), KernelConfig::default(), true);
            pt::ensure(
                eng.uses_shared_book() == (c.shards > 1),
                "uniform CodeGEMM shards must take the shared-book path",
            )?;
            let y_ref = serial.gemm(&x, c.mb);
            let mut y = vec![f32::NAN; c.n * c.mb];
            eng.gemm_into(&x, c.mb, &mut y, scratch);
            pt::ensure(y == y_ref, format!("shared-book output diverged ({c:?})"))?;
            // Warm scratch: a second identical call must not grow any
            // buffer (zero-allocation steady state), and must still be
            // bit-exact against the serial result.
            let fp = total_footprint(scratch);
            y.fill(f32::NAN);
            eng.gemm_into(&x, c.mb, &mut y, scratch);
            pt::ensure(y == y_ref, "warm shared-book call diverged")?;
            pt::ensure(
                total_footprint(scratch) == fp,
                format!("warm scratch grew: {} -> {}", fp, total_footprint(scratch)),
            )
        },
    );
}

#[test]
fn build_macs_and_read_ops_counted_once_per_call_for_any_shard_count() {
    let pool = Arc::new(ThreadPool::new(4));
    let q = quantize(64, 128, "m2v8g32", 1);
    for mb in [1usize, 2] {
        let x = Prng::seeded(2).normal_vec(128 * mb, 1.0);
        // Default tile_h covers all 64 rows, so the serial engine also
        // builds exactly once per k-tile — the shared schedule must match
        // it at every shard count.
        let mut serial = CodeGemmEngine::from_quantized(&q);
        let _ = serial.gemm(&x, mb);
        let want = serial.counters().clone();
        for shards in [1usize, 2, 4, 8] {
            let eng = sharded(
                &q,
                ShardPlan::new(64, shards, 1, 1),
                Arc::clone(&pool),
                KernelConfig::default(),
                true,
            );
            let mut scratch = EngineScratch::new();
            let mut y = vec![0f32; 64 * mb];
            eng.gemm_into(&x, mb, &mut y, &mut scratch);
            let got = &scratch.counters;
            assert_eq!(got.build_ops, want.build_ops, "build MACs (K={shards}, mb={mb})");
            assert_eq!(got.read_ops, want.read_ops, "read ops (K={shards}, mb={mb})");
            assert_eq!(got.lookups, want.lookups, "lookups (K={shards}, mb={mb})");
            assert_eq!(got.calls, 1, "one logical call (K={shards})");
        }
    }
}

/// Regression pin for the amortization ratio: with K row shards, private
/// per-shard books cost exactly K× the shared book's build MACs (each
/// shard's row extent fits one row-block here), so `build_share_ops`
/// shrinks under the shared schedule while gather work is conserved.
#[test]
fn private_vs_shared_build_ratio_pinned_at_shard_count() {
    let pool = Arc::new(ThreadPool::new(4));
    let q = quantize(64, 128, "m1v4g32", 3);
    let x = Prng::seeded(4).normal_vec(128, 1.0);
    let mut serial = CodeGemmEngine::from_quantized(&q);
    let _ = serial.gemv(&x);
    let run = |shards: usize, shared: bool| {
        let eng = sharded(
            &q,
            ShardPlan::new(64, shards, 1, 1),
            Arc::clone(&pool),
            KernelConfig::default(),
            shared,
        );
        let mut scratch = EngineScratch::new();
        let mut y = vec![0f32; 64];
        eng.gemm_into(&x, 1, &mut y, &mut scratch);
        scratch.counters
    };
    let shared_k4 = run(4, true);
    let private_k4 = run(4, false);
    let shared_k1 = run(1, true);
    // K=1 vs K=4: the shared schedule's build cost is shard-invariant...
    assert_eq!(shared_k4.build_ops, shared_k1.build_ops);
    assert_eq!(shared_k4.build_ops, serial.counters().build_ops);
    // ...while private books pay once per shard (the pinned K× ratio).
    assert_eq!(private_k4.build_ops, 4 * shared_k4.build_ops);
    // Gather work is per-row and conserved either way.
    assert_eq!(private_k4.read_ops, shared_k4.read_ops);
    assert_eq!(shared_k4.read_ops, serial.counters().read_ops);
    // Net effect: the build share the traffic model reports shrinks.
    assert!(
        shared_k4.build_share_ops() < private_k4.build_share_ops(),
        "shared {} !< private {}",
        shared_k4.build_share_ops(),
        private_k4.build_share_ops()
    );
}

/// Dirty cross-schedule scratch reuse: the same caller scratch must
/// serve private-book, shared-book and plain dense sharded calls in
/// sequence without state leaking between them.
#[test]
fn shared_and_private_schedules_share_one_dirty_scratch() {
    let pool = Arc::new(ThreadPool::new(3));
    let q = quantize(48, 64, "m2v4g32", 5);
    let x = Prng::seeded(6).normal_vec(64 * 3, 1.0);
    let mut serial = CodeGemmEngine::from_quantized(&q);
    let y_ref = serial.gemm(&x, 3);
    let plan = ShardPlan::new(48, 3, 1, 1);
    let mut scratch = EngineScratch::new();

    let private = sharded(&q, plan.clone(), Arc::clone(&pool), KernelConfig::default(), false);
    let mut y = vec![f32::NAN; 48 * 3];
    private.gemm_into(&x, 3, &mut y, &mut scratch);
    assert_eq!(y, y_ref);

    let shared = sharded(&q, plan.clone(), Arc::clone(&pool), KernelConfig::default(), true);
    y.fill(f32::NAN);
    shared.gemm_into(&x, 3, &mut y, &mut scratch);
    assert_eq!(y, y_ref);

    // A different engine family through the same scratch still works.
    let w = Prng::seeded(7).normal_vec(48 * 64, 1.0);
    let dense = ShardedEngine::from_factory(plan, Arc::clone(&pool), |(r0, r1)| {
        DenseEngine::new(shard::dense_rows(&w, 64, r0, r1), r1 - r0, 64)
    });
    let mut yd = vec![f32::NAN; 48 * 3];
    dense.gemm_into(&x, 3, &mut yd, &mut scratch);
    assert_eq!(yd, DenseEngine::new(w.clone(), 48, 64).gemm(&x, 3));
    assert_eq!(scratch.counters.calls, 3);
}

/// The previously-misaligned case: shards whose aligned tile widths
/// disagree cannot line their k-tiles up with one shared book. The
/// engine must detect this at construction and fall back to the private
/// schedule — still correct, just unamortized — while the uniform
/// factory-style construction (same kernel for every shard, aligned via
/// `KernelConfig::align_tile_w`) takes the shared path.
#[test]
fn mismatched_tile_geometry_refuses_shared_book_but_stays_correct() {
    let pool = Arc::new(ThreadPool::new(2));
    let q = quantize(32, 128, "m1v8g32", 7);
    let codes = q.codes.unpack();
    let plan = ShardPlan::new(32, 2, 1, 1);
    let shards: Vec<CodeGemmEngine> = plan
        .shards
        .iter()
        .enumerate()
        .map(|(i, &(r0, r1))| {
            let kernel = KernelConfig { tile_w: if i == 0 { 32 } else { 16 }, tile_h: 8, ..Default::default() };
            CodeGemmEngine::with_kernel(&shard::slice_rows_unpacked(&q, &codes, r0, r1), kernel)
        })
        .collect();
    let eng = ShardedEngine::new(plan.clone(), shards, Arc::clone(&pool));
    assert!(!eng.uses_shared_book(), "mismatched tile_w must refuse the shared book");
    let x = Prng::seeded(8).normal_vec(128, 1.0);
    let mut y = vec![f32::NAN; 32];
    let mut scratch = EngineScratch::new();
    eng.gemm_into(&x, 1, &mut y, &mut scratch);
    // Different per-shard tile widths reassociate each row's k-sum, so
    // compare against the exact dequantized reference, not bit-equality.
    let y_ref = DenseEngine::new(q.dequantize(), 32, 128).gemv(&x);
    let rel = stats::rel_l2(&y, &y_ref);
    assert!(rel < 2e-5, "private fallback diverged: rel {rel}");

    // Same layer, same *requested* (misaligned) tile_w=20 for every
    // shard: align_tile_w rounds each to 16, the k-tiles line up, and
    // the shared path engages.
    let kernel = KernelConfig { tile_w: 20, tile_h: 8, ..Default::default() };
    let uniform = sharded(&q, plan, Arc::clone(&pool), kernel, true);
    assert!(uniform.shards().iter().all(|e| e.kernel_config().tile_w == 16));
    assert!(uniform.uses_shared_book(), "aligned uniform shards must share");
    let mut serial = CodeGemmEngine::with_kernel(&q, kernel);
    let mut y2 = vec![f32::NAN; 32];
    uniform.gemm_into(&x, 1, &mut y2, &mut scratch);
    assert_eq!(y2, serial.gemv(&x));
}
