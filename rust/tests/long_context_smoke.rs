//! Long-context serving smoke test: a prompt that dwarfs the pool page
//! size runs end-to-end through the coordinator on a tiny model —
//! budgeted multi-step prefill, paged attention over many pages, decode,
//! completion, and full pool reclamation.
//!
//! This is the CI guard for the paged-KV serving path at context lengths
//! the unit tests don't reach (prompt ≫ page_size, many pages per
//! sequence, prefill spanning several scheduler steps).
//!
//! The contention tests extend it to the multi-tenant pool: requests
//! sharing a long prompt prefix through the refcounted prefix cache
//! (including a copy-on-write divergence) while a high-priority arrival
//! preempts a decoding low-priority request — everything must stay
//! bitwise identical to uncontended one-at-a-time serving, in both
//! preemption modes, with full pool reclamation at drain.

use codegemm::config::{KvConfig, ModelConfig, PreemptMode, ServeConfig};
use codegemm::coordinator::{Batcher, Metrics, NativeBackend, Request};
use codegemm::model::{EngineKind, ModelWeights};
use std::sync::Arc;

/// A tiny model with a long context window (the stock tiny config stops
/// at 128 positions).
fn long_ctx_config() -> ModelConfig {
    ModelConfig {
        name: "tiny-long".into(),
        vocab: 256,
        hidden: 64,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        ffn: 128,
        max_seq: 384,
        rope_theta_milli: 10_000_000,
    }
}

#[test]
fn long_prompt_serves_and_reclaims_through_paged_pool() {
    let cfg_model = long_ctx_config();
    let w = ModelWeights::random(cfg_model.clone(), 17);
    // 16-token pages, auto pool (2 slots × ceil(384/16) = 48 pages).
    let kv = KvConfig { page_size: 16, pool_pages: 0, ..KvConfig::default() };
    let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
    let cfg = ServeConfig {
        max_batch: 2,
        max_new_tokens: 4,
        temperature: 0.0,
        // Prompt ≫ budget: prefill must span several scheduler steps.
        prefill_budget: 96,
        kv,
        ..Default::default()
    };
    let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));

    let prompt: Vec<usize> = (0..300).map(|i| (i * 7) % 255 + 1).collect();
    assert!(b.submit(Request::new(1, prompt.clone(), 4)));
    let out = b.run_to_completion();

    assert_eq!(out.len(), 1);
    assert_eq!(out[0].tokens.len(), 4, "finish: {:?}", out[0].finish);
    let report = b.metrics.report();
    assert_eq!(report.prefill_tokens, 300);
    assert_eq!(report.decode_tokens as usize, 3, "first token comes from prefill logits");
    // Budgeted prefill: 300 tokens at ≤96/step needs ≥ 4 prefill steps.
    assert!(report.steps >= 4 + 3, "steps: {}", report.steps);

    // Admission claims the whole lifetime up front: ceil(304/16) = 19
    // pages; the pool high-water mark must see it and completion must
    // return every page.
    let kv_stats = report.kv.expect("pool-backed backend");
    assert!(kv_stats.pool.used_hwm >= 19, "hwm: {}", kv_stats.pool.used_hwm);
    assert_eq!(kv_stats.pool.free_pages, kv_stats.pool.total_pages, "full reclamation");
    assert_eq!(kv_stats.pool.used_pages, 0);
}

#[test]
fn long_prompt_greedy_output_matches_direct_model_run() {
    // The scheduler's chunking (budget 96 → steps of 96/96/96/12, each
    // internally chunked at MAX_PREFILL_CHUNK) must not change greedy
    // outputs vs a single whole-prompt prefill on the bare model.
    let cfg_model = long_ctx_config();
    let w = ModelWeights::random(cfg_model.clone(), 17);
    let prompt: Vec<usize> = (0..300).map(|i| (i * 7) % 255 + 1).collect();

    // Direct model run (contiguous cache).
    let mut model = codegemm::model::LlamaModel::load(&w, EngineKind::Dense, None);
    let mut cache = model.new_cache();
    let mut logits = model.forward_batch(&prompt, 0, &mut cache);
    let mut want = Vec::new();
    for step in 0..4 {
        let tok = codegemm::model::argmax(&logits);
        want.push(tok);
        if step < 3 {
            logits = model.forward(tok, prompt.len() + step, &mut cache);
        }
    }

    // Served run (paged pool, budgeted prefill).
    let kv = KvConfig { page_size: 16, pool_pages: 0, ..KvConfig::default() };
    let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
    let cfg = ServeConfig {
        max_batch: 2,
        max_new_tokens: 4,
        temperature: 0.0,
        prefill_budget: 96,
        kv,
        ..Default::default()
    };
    let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
    b.submit(Request::new(1, prompt, 4));
    let out = b.run_to_completion();
    assert_eq!(out[0].tokens, want, "scheduled serving diverged from the direct model run");
}

/// Contention scenario exercised in both preemption modes:
///
/// * three requests share a 64-token (4-page) prompt prefix; the first
///   publishes it, the second is *exactly* the prefix, so the matched
///   cap (`matched = len - 1`) forces a copy-on-write divergence inside
///   the last shared page;
/// * the pool (9 pages) fits the long low-priority request alone, so
///   the high-priority arrival must preempt it mid-decode;
/// * every output must be bitwise identical to an uncontended solo run
///   with the prefix cache off, and the pool must fully drain.
fn contended_serving_is_bit_exact(mode: PreemptMode) {
    let cfg_model = long_ctx_config();
    let w = ModelWeights::random(cfg_model.clone(), 17);

    let prefix: Vec<usize> = (0..64).map(|i| (i * 5) % 251 + 1).collect();
    let p_low: Vec<usize> = prefix.iter().copied().chain((0..16).map(|i| 100 + i)).collect();
    let p_high = prefix.clone(); // exactly the published prefix → CoW
    let p_mid: Vec<usize> = prefix.iter().copied().chain((0..8).map(|i| 200 + i)).collect();

    // Uncontended references: ample pool, sharing and preemption off.
    let ref_kv = KvConfig {
        page_size: 16,
        pool_pages: 0,
        prefix_cache: false,
        preempt: PreemptMode::Off,
        ..KvConfig::default()
    };
    let reference = |prompt: Vec<usize>, max_new: usize| {
        let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &ref_kv));
        let cfg = ServeConfig {
            max_batch: 2,
            max_new_tokens: max_new,
            temperature: 0.0,
            prefill_budget: 128,
            kv: ref_kv.clone(),
            ..Default::default()
        };
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        b.submit(Request::new(0, prompt, max_new));
        b.run_to_completion().remove(0).tokens
    };
    let want_low = reference(p_low.clone(), 48);
    let want_high = reference(p_high.clone(), 8);
    let want_mid = reference(p_mid.clone(), 8);

    // Contended pool: the low request's lifetime is ceil(128/16) = 8
    // pages, so 9 pages admit it alone but not a cold second request.
    let kv = KvConfig { page_size: 16, pool_pages: 9, preempt: mode, ..KvConfig::default() };
    let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
    let cfg = ServeConfig {
        max_batch: 2,
        max_new_tokens: 48,
        temperature: 0.0,
        prefill_budget: 128,
        kv: kv.clone(),
        ..Default::default()
    };
    let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
    b.submit(Request::new(1, p_low, 48)); // priority 0
    b.step(); // prefill (one chunk) + first sample: publishes the prefix
    b.step(); // decoding — a valid preemption victim now
    b.submit(Request::new(2, p_high, 8).with_priority(1));
    b.submit(Request::new(3, p_mid, 8)); // priority 0, queued behind
    let mut out = b.run_to_completion();

    out.sort_by_key(|r| r.id);
    assert_eq!(out.len(), 3);
    assert_eq!(out[0].tokens, want_low, "preempted request diverged from its solo run");
    assert_eq!(out[1].tokens, want_high, "prefix-sharing request diverged from its solo run");
    assert_eq!(out[2].tokens, want_mid, "queued request diverged from its solo run");

    let report = b.metrics.report();
    assert!(report.preemptions >= 1, "tight pool + priorities must force a preemption");
    assert_eq!(report.resumes, report.preemptions, "every victim resumes and completes");
    match mode {
        PreemptMode::Spill => assert_eq!(report.preempt_spills, report.preemptions),
        PreemptMode::Recompute => assert_eq!(report.preempt_recomputes, report.preemptions),
        PreemptMode::Off => unreachable!("contention test runs with preemption on"),
    }

    let kv_stats = report.kv.expect("pool-backed backend reports kv stats");
    assert!(kv_stats.pool.prefix_hits >= 1, "the shared prefix must be served from cache");
    assert!(
        kv_stats.pool.prefix_hit_tokens >= 63,
        "hit tokens: {}",
        kv_stats.pool.prefix_hit_tokens
    );
    assert!(report.prefix_hit_rate() > 0.0);
    assert!(
        kv_stats.pool.cow_copies >= 1,
        "the exact-prefix prompt must diverge through copy-on-write"
    );
    // Full reclamation: no pages held, no dangling refcounts; cached
    // (refcount-zero, revivable) pages still count as free capacity.
    assert_eq!(kv_stats.pool.used_pages, 0);
    assert_eq!(kv_stats.pool.live_refs, 0);
    assert_eq!(kv_stats.pool.free_pages, kv_stats.pool.total_pages, "full reclamation");
}

#[test]
fn contended_serving_bit_exact_spill_mode() {
    contended_serving_is_bit_exact(PreemptMode::Spill);
}

#[test]
fn contended_serving_bit_exact_recompute_mode() {
    contended_serving_is_bit_exact(PreemptMode::Recompute);
}
