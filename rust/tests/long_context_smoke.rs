//! Long-context serving smoke test: a prompt that dwarfs the pool page
//! size runs end-to-end through the coordinator on a tiny model —
//! budgeted multi-step prefill, paged attention over many pages, decode,
//! completion, and full pool reclamation.
//!
//! This is the CI guard for the paged-KV serving path at context lengths
//! the unit tests don't reach (prompt ≫ page_size, many pages per
//! sequence, prefill spanning several scheduler steps).

use codegemm::config::{KvConfig, ModelConfig, ServeConfig};
use codegemm::coordinator::{Batcher, Metrics, NativeBackend, Request};
use codegemm::model::{EngineKind, ModelWeights};
use std::sync::Arc;

/// A tiny model with a long context window (the stock tiny config stops
/// at 128 positions).
fn long_ctx_config() -> ModelConfig {
    ModelConfig {
        name: "tiny-long".into(),
        vocab: 256,
        hidden: 64,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        ffn: 128,
        max_seq: 384,
        rope_theta_milli: 10_000_000,
    }
}

#[test]
fn long_prompt_serves_and_reclaims_through_paged_pool() {
    let cfg_model = long_ctx_config();
    let w = ModelWeights::random(cfg_model.clone(), 17);
    // 16-token pages, auto pool (2 slots × ceil(384/16) = 48 pages).
    let kv = KvConfig { page_size: 16, pool_pages: 0 };
    let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
    let cfg = ServeConfig {
        max_batch: 2,
        max_new_tokens: 4,
        temperature: 0.0,
        // Prompt ≫ budget: prefill must span several scheduler steps.
        prefill_budget: 96,
        kv,
        ..Default::default()
    };
    let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));

    let prompt: Vec<usize> = (0..300).map(|i| (i * 7) % 255 + 1).collect();
    assert!(b.submit(Request::new(1, prompt.clone(), 4)));
    let out = b.run_to_completion();

    assert_eq!(out.len(), 1);
    assert_eq!(out[0].tokens.len(), 4, "finish: {:?}", out[0].finish);
    let report = b.metrics.report();
    assert_eq!(report.prefill_tokens, 300);
    assert_eq!(report.decode_tokens as usize, 3, "first token comes from prefill logits");
    // Budgeted prefill: 300 tokens at ≤96/step needs ≥ 4 prefill steps.
    assert!(report.steps >= 4 + 3, "steps: {}", report.steps);

    // Admission claims the whole lifetime up front: ceil(304/16) = 19
    // pages; the pool high-water mark must see it and completion must
    // return every page.
    let kv_stats = report.kv.expect("pool-backed backend");
    assert!(kv_stats.pool.used_hwm >= 19, "hwm: {}", kv_stats.pool.used_hwm);
    assert_eq!(kv_stats.pool.free_pages, kv_stats.pool.total_pages, "full reclamation");
    assert_eq!(kv_stats.pool.used_pages, 0);
}

#[test]
fn long_prompt_greedy_output_matches_direct_model_run() {
    // The scheduler's chunking (budget 96 → steps of 96/96/96/12, each
    // internally chunked at MAX_PREFILL_CHUNK) must not change greedy
    // outputs vs a single whole-prompt prefill on the bare model.
    let cfg_model = long_ctx_config();
    let w = ModelWeights::random(cfg_model.clone(), 17);
    let prompt: Vec<usize> = (0..300).map(|i| (i * 7) % 255 + 1).collect();

    // Direct model run (contiguous cache).
    let mut model = codegemm::model::LlamaModel::load(&w, EngineKind::Dense, None);
    let mut cache = model.new_cache();
    let mut logits = model.forward_batch(&prompt, 0, &mut cache);
    let mut want = Vec::new();
    for step in 0..4 {
        let tok = codegemm::model::argmax(&logits);
        want.push(tok);
        if step < 3 {
            logits = model.forward(tok, prompt.len() + step, &mut cache);
        }
    }

    // Served run (paged pool, budgeted prefill).
    let kv = KvConfig { page_size: 16, pool_pages: 0 };
    let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
    let cfg = ServeConfig {
        max_batch: 2,
        max_new_tokens: 4,
        temperature: 0.0,
        prefill_budget: 96,
        kv,
        ..Default::default()
    };
    let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
    b.submit(Request::new(1, prompt, 4));
    let out = b.run_to_completion();
    assert_eq!(out[0].tokens, want, "scheduled serving diverged from the direct model run");
}
