//! Property suite for the SIMD kernel layer and the software-pipelined
//! shared-book schedule (via the reusable `util::proptest` generators):
//!
//! - every kernel variant (scalar, unrolled 8/16-lane, AVX2 when the
//!   host has it, auto) is **bit-exact** (`==`) against the scalar
//!   reference across v ∈ {4, 8} × b ∈ {1, 2, 4} × m_batch ∈ {1, 4, 64}
//!   — lanes are independent accumulators, so no float reassociation;
//! - the pipelined shared-book schedule (`pipeline_tiles`) produces
//!   bit-identical outputs to the unpipelined one through a deliberately
//!   dirty, reused scratch, and the warm double-buffered scratch
//!   (including the spare `book2`) never grows;
//! - build MACs / read ops / lookups are schedule-independent: the
//!   pipeline counts each tile's build exactly once at staging time.
//!
//! The compared engines always share one pinned `tile_w` (a multiple of
//! every lane width), so `align_tile_w` resolves identically for every
//! variant and the k-tiling — hence the per-accumulator op order — is
//! the same everywhere. `CODEGEMM_KERNEL` may override the impl choice
//! process-wide (the CI matrix legs do this); the equalities here hold
//! under any override since they pin geometry, not implementation.

use codegemm::config::{KernelConfig, KernelImpl, QuantConfig};
use codegemm::gemm::{CodeGemmEngine, EngineScratch, GemmEngine};
use codegemm::parallel::{shard, ShardPlan, ShardedEngine};
use codegemm::quant::{QuantizedLinear, Quantizer};
use codegemm::util::proptest as pt;
use codegemm::util::prng::Prng;
use codegemm::util::threadpool::ThreadPool;
use std::sync::Arc;

/// The SIMD sweep the issue pins: small codebooks stress the gather
/// indexing, M=64 stresses the batched lane path. `k_unit = 32` keeps
/// every drawn `k` a multiple of the widest lane group, so a pinned
/// `tile_w` aligns identically for every lane count.
fn gen_case() -> pt::GemmCaseGen {
    pt::GemmCaseGen {
        vs: &[4, 8],
        bs: &[1, 2, 4],
        mbs: &[1, 4, 64],
        max_shards: 6,
        ..Default::default()
    }
}

/// Kernel variants under test, as (requested impl, requested lanes).
/// `resolve` may downgrade (Avx2 on a non-AVX2 host runs unrolled;
/// `CODEGEMM_KERNEL` overrides all of them) — bit-exactness must hold
/// regardless of what each request resolves to.
const VARIANTS: &[(KernelImpl, usize)] = &[
    (KernelImpl::Unrolled, 8),
    (KernelImpl::Unrolled, 16),
    (KernelImpl::Avx2, 8),
    (KernelImpl::Auto, 0),
];

fn kernel(imp: KernelImpl, lanes: usize, pipeline: bool) -> KernelConfig {
    KernelConfig {
        tile_w: 64,
        tile_h: 8,
        kernel_impl: imp,
        simd_lanes: lanes,
        pipeline_tiles: pipeline,
    }
}

fn quantize(n: usize, k: usize, label: &str, seed: u64) -> QuantizedLinear {
    let w = Prng::seeded(seed).normal_vec(n * k, 0.02);
    Quantizer::new(QuantConfig::parse_label(label).unwrap()).quantize(&w, n, k)
}

/// Row-sharded CodeGEMM over `q` on the shared-book schedule.
fn sharded(
    q: &QuantizedLinear,
    plan: ShardPlan,
    pool: Arc<ThreadPool>,
    kc: KernelConfig,
) -> ShardedEngine<CodeGemmEngine> {
    let codes = q.codes.unpack();
    ShardedEngine::from_factory(plan, pool, |(r0, r1)| {
        CodeGemmEngine::with_kernel(&shard::slice_rows_unpacked(q, &codes, r0, r1), kc)
    })
    .with_shared_book(true)
}

fn total_footprint(s: &EngineScratch) -> usize {
    s.footprint_bytes() + s.children.iter().map(|c| c.footprint_bytes()).sum::<usize>()
}

#[test]
fn prop_every_kernel_variant_is_bit_exact_vs_scalar() {
    let cfg = pt::PropConfig { cases: 12, ..Default::default() };
    pt::assert_prop("simd kernels == scalar, bitwise", cfg, &gen_case(), |c: &pt::GemmCase| {
        let Some(q) = c.quantized(0.02) else {
            return Ok(()); // invalid combination — vacuous
        };
        let x = c.activations(1);
        let scalar_kc = kernel(KernelImpl::Scalar, 1, true);
        let mut scalar = CodeGemmEngine::with_kernel(&q, scalar_kc);
        let y_ref = scalar.gemm(&x, c.mb);
        for &(imp, lanes) in VARIANTS {
            let kc = kernel(imp, lanes, true);
            let mut e = CodeGemmEngine::with_kernel(&q, kc);
            // Identical k-tiling is the precondition for bit-exactness:
            // the pinned tile_w must survive lane alignment unchanged.
            pt::ensure(
                e.kernel_config().tile_w == scalar.kernel_config().tile_w,
                format!("tile_w diverged under lanes={lanes} ({c:?})"),
            )?;
            let y = e.gemm(&x, c.mb);
            pt::ensure(
                y == y_ref,
                format!("{:?}/{} diverged from scalar ({c:?})", imp, lanes),
            )?;
            // Same work counted whatever the lane width: the kernels
            // vectorize the op stream, they don't change it.
            pt::ensure(
                e.counters().read_ops == scalar.counters().read_ops
                    && e.counters().build_ops == scalar.counters().build_ops,
                format!("counters diverged under {:?}/{}", imp, lanes),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_pipelined_matches_unpipelined_with_dirty_scratch() {
    let pool = Arc::new(ThreadPool::new(4));
    let cfg = pt::PropConfig { cases: 10, ..Default::default() };
    // One scratch per schedule across every case: book/book2 reshape in
    // place, staging is grow-only, children persist — no state may leak
    // between geometries or schedules.
    let cell = std::cell::RefCell::new((EngineScratch::new(), EngineScratch::new()));
    pt::assert_prop(
        "pipelined shared-book == unpipelined, bitwise",
        cfg,
        &gen_case(),
        |c: &pt::GemmCase| {
            let mut guard = cell.borrow_mut();
            let (s_on, s_off) = &mut *guard;
            let Some(q) = c.quantized(0.02) else {
                return Ok(());
            };
            let x = c.activations(1);
            // tile_w 32 with k up to 128 gives up to four k-tiles, so the
            // steady-state overlap actually runs (one tile => prologue only).
            let kc_on = KernelConfig { tile_w: 32, ..kernel(KernelImpl::Auto, 0, true) };
            let kc_off = KernelConfig { pipeline_tiles: false, ..kc_on };
            let plan = ShardPlan::new(c.n, c.shards, 1, 1);
            let on = sharded(&q, plan.clone(), Arc::clone(&pool), kc_on);
            let off = sharded(&q, plan, Arc::clone(&pool), kc_off);
            let mut y_on = vec![f32::NAN; c.n * c.mb];
            let mut y_off = vec![f32::NAN; c.n * c.mb];
            on.gemm_into(&x, c.mb, &mut y_on, s_on);
            off.gemm_into(&x, c.mb, &mut y_off, s_off);
            pt::ensure(y_on == y_off, format!("pipeline diverged ({c:?})"))?;
            // And both match the serial engine on the same k-tiling.
            let mut serial = CodeGemmEngine::with_kernel(&q, kc_on);
            pt::ensure(y_on == serial.gemm(&x, c.mb), format!("shared-book diverged ({c:?})"))?;
            // Warm no-growth, double buffer included: a second identical
            // call must leave the footprint (book + book2 + staging +
            // children) untouched and stay bit-exact.
            let fp = total_footprint(s_on);
            y_on.fill(f32::NAN);
            on.gemm_into(&x, c.mb, &mut y_on, s_on);
            pt::ensure(y_on == y_off, "warm pipelined call diverged")?;
            pt::ensure(
                total_footprint(s_on) == fp,
                format!("warm pipelined scratch grew: {} -> {}", fp, total_footprint(s_on)),
            )
        },
    );
}

/// The pipeline shifts *when* builds run, never *how much* is counted:
/// tile `t+1`'s build MACs are attributed at staging time, exactly once,
/// so every conserved counter is schedule-independent. Only the timing
/// split moves (`build_seconds` holds just the prologue under the
/// pipeline; overlapped build time lands in `read_seconds`).
#[test]
fn pipeline_counts_build_once_and_conserves_counters() {
    let pool = Arc::new(ThreadPool::new(4));
    let q = quantize(64, 128, "m2v8g32", 11);
    for mb in [1usize, 3] {
        let x = Prng::seeded(12).normal_vec(128 * mb, 1.0);
        let run = |pipeline: bool| {
            let kc = KernelConfig { tile_w: 32, ..kernel(KernelImpl::Auto, 0, pipeline) };
            let eng = sharded(&q, ShardPlan::new(64, 4, 1, 1), Arc::clone(&pool), kc);
            assert!(eng.uses_shared_book());
            let mut scratch = EngineScratch::new();
            let mut y = vec![f32::NAN; 64 * mb];
            eng.gemm_into(&x, mb, &mut y, &mut scratch);
            (y, scratch)
        };
        let (y_on, s_on) = run(true);
        let (y_off, s_off) = run(false);
        assert_eq!(y_on, y_off, "mb={mb}");
        let (on, off) = (&s_on.counters, &s_off.counters);
        assert_eq!(on.build_ops, off.build_ops, "build MACs counted once per tile (mb={mb})");
        assert_eq!(on.read_ops, off.read_ops, "gather work conserved (mb={mb})");
        assert_eq!(on.lookups, off.lookups, "lookups conserved (mb={mb})");
        assert_eq!(on.mac_flops, off.mac_flops, "total MACs conserved (mb={mb})");
        assert_eq!(on.calls, 1);
        assert_eq!(off.calls, 1);
        // The pipeline's signature: the spare book materializes only on
        // the pipelined schedule (128/32 = 4 tiles => steady state ran).
        assert!(!s_on.book2.is_empty(), "pipelined run must use the spare book");
        assert!(s_off.book2.is_empty(), "unpipelined run must leave book2 untouched");
    }
}
