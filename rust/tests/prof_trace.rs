//! Integration suite for the kernel profiler (`obs::prof`) on the real
//! pipelined shared-book schedule:
//!
//! - the Chrome trace-event export is valid, loadable JSON (`ph:"X"`
//!   spans with non-negative `ts`/`dur`, `ph:"M"` thread metadata);
//! - the pipelined schedule demonstrably co-issues tile `t+1`'s Psumbook
//!   build with tile `t`'s gather: both land inside the same barrier
//!   window, which is the deterministic overlap evidence the trace shows;
//! - same-seed traced runs are structurally deterministic (same
//!   `(label, tag)` multiset regardless of worker/clock placement);
//! - with the profiler off (the default), outputs and the exact engine
//!   counters are bit-identical to a traced run, and nothing is recorded.
//!
//! This suite lives in its own test binary so flipping the process-global
//! profiler cannot race the library's unit tests; the tests here still
//! serialize on a lock because cargo runs `#[test]`s on parallel threads.

use codegemm::config::QuantConfig;
use codegemm::gemm::{CodeGemmEngine, Counters, EngineScratch, GemmEngine};
use codegemm::obs::prof::{self, Label, ProfSummary, Timeline};
use codegemm::parallel::{shard, ShardPlan, ShardedEngine};
use codegemm::quant::{QuantizedLinear, Quantizer};
use codegemm::util::json::Json;
use codegemm::util::prng::Prng;
use codegemm::util::threadpool::ThreadPool;
use std::sync::{Arc, Mutex};

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Enough k-tiles (k / tile_w = 8 with the default tile_w 32) for a
/// steady pipeline state, three row shards so builds and gathers spread
/// across workers.
const N: usize = 96;
const K: usize = 256;
const THREADS: usize = 3;

fn quantized() -> QuantizedLinear {
    let w = Prng::seeded(5).normal_vec(N * K, 0.02);
    Quantizer::new(QuantConfig::parse_label("m1v4g128").unwrap()).quantize(&w, N, K)
}

fn pipelined(q: &QuantizedLinear) -> ShardedEngine<CodeGemmEngine> {
    let pool = Arc::new(ThreadPool::new(THREADS));
    let plan = ShardPlan::new(N, THREADS, 1, 1);
    let codes = q.codes.unpack();
    ShardedEngine::from_factory(plan, pool, |(r0, r1)| {
        CodeGemmEngine::from_quantized(&shard::slice_rows_unpacked(q, &codes, r0, r1))
    })
    .with_shared_book(true)
}

/// One `gemm_into` call over the pipelined schedule, profiler on or off.
fn run(q: &QuantizedLinear, traced: bool) -> (Vec<f32>, Counters, Timeline) {
    let eng = pipelined(q);
    let x = Prng::seeded(9).normal_vec(K * 4, 1.0);
    let mut y = vec![0f32; N * 4];
    let mut scratch = EngineScratch::new();
    let _ = prof::drain(); // discard whatever an earlier test left behind
    if traced {
        prof::enable();
    }
    eng.gemm_into(&x, 4, &mut y, &mut scratch);
    if traced {
        prof::disable();
    }
    let tl = prof::drain();
    (y, scratch.counters.clone(), tl)
}

/// The deterministic (timing-free) face of the counters — everything
/// except the wall-clock `*_seconds` fields.
#[allow(clippy::type_complexity)]
fn exact_counts(c: &Counters) -> (u64, u64, u64, u64, u64, u64, u64, u64, u64, u64, u64) {
    (
        c.mac_flops,
        c.lookups,
        c.weight_bytes,
        c.activation_bytes,
        c.scratch_bytes,
        c.build_bytes,
        c.read_bytes,
        c.build_ops,
        c.read_ops,
        c.calls,
        c.group_fanout,
    )
}

#[test]
fn chrome_trace_is_valid_loadable_json() {
    let _g = lock();
    let q = quantized();
    let (_, _, tl) = run(&q, true);
    assert!(!tl.events.is_empty(), "traced pipelined run must record spans");

    let text = tl.to_chrome_trace().to_string_pretty();
    let parsed = Json::parse(&text).expect("chrome trace must be valid JSON");
    let Some(Json::Arr(rows)) = parsed.get("traceEvents") else {
        panic!("chrome trace must carry a traceEvents array");
    };
    assert_eq!(
        rows.len(),
        tl.events.len() + tl.threads.len(),
        "one X row per span plus one M row per thread"
    );
    let mut spans = 0usize;
    for row in rows {
        let ph = row.get("ph").and_then(|v| v.as_str()).expect("every row has a ph");
        match ph {
            "M" => {
                assert_eq!(row.get("name").and_then(|v| v.as_str()), Some("thread_name"));
                assert!(row.get("args").and_then(|a| a.get("name")).is_some());
            }
            "X" => {
                spans += 1;
                let ts = row.get("ts").and_then(|v| v.as_f64()).expect("X rows carry ts");
                let dur = row.get("dur").and_then(|v| v.as_f64()).expect("X rows carry dur");
                assert!(ts >= 0.0 && dur >= 0.0, "no negative timestamps or durations");
                let name = row.get("name").and_then(|v| v.as_str()).unwrap_or("");
                assert!(
                    matches!(name, "job" | "build" | "gather" | "stage" | "barrier"),
                    "unexpected span name {name:?}"
                );
                assert!(row.get("tid").and_then(|v| v.as_f64()).is_some());
            }
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    assert_eq!(spans, tl.events.len());
    // Per-thread event streams come out sorted and well-formed.
    for pair in tl.events.windows(2) {
        if pair[0].tid == pair[1].tid {
            assert!(pair[0].start_ns <= pair[1].start_ns, "per-thread starts must be monotonic");
        }
    }
    for e in &tl.events {
        assert!(e.end_ns >= e.start_ns, "spans must close after they open");
        assert!(tl.threads.iter().any(|(tid, _)| *tid == e.tid), "span tid must be registered");
    }
}

#[test]
fn pipelined_schedule_coissues_next_build_with_gather() {
    let _g = lock();
    let q = quantized();
    let (_, _, tl) = run(&q, true);
    let has = |l: Label| tl.events.iter().any(|e| e.label == l);
    assert!(has(Label::Build) && has(Label::Gather) && has(Label::Barrier));

    // The pipeline's defining property: some barrier window holds both
    // tile t's gather and tile t+1's build — the build runs under the
    // gather instead of serializing after it. This is structural (the
    // spans are recorded inside the barrier's scope_run), so it holds on
    // any host, single-core included.
    let coissued = tl.events.iter().filter(|b| b.label == Label::Barrier).any(|b| {
        let inside = |e: &&prof::Event| e.start_ns >= b.start_ns && e.end_ns <= b.end_ns;
        let gathered = tl
            .events
            .iter()
            .filter(|e| e.label == Label::Gather && e.tag == b.tag)
            .any(|e| inside(&e));
        let built = tl
            .events
            .iter()
            .filter(|e| e.label == Label::Build && e.tag == b.tag + 1)
            .any(|e| inside(&e));
        gathered && built
    });
    assert!(coissued, "no barrier window co-scheduled gather(t) with build(t+1)");

    // The derived gauges stay in range and see the build time.
    let s = ProfSummary::from_timeline(&tl);
    assert_eq!(s.events, tl.events.len() as u64);
    assert!((0.0..=1.0).contains(&s.overlap_efficiency));
    assert!(s.hidden_build_s + s.exposed_build_s > 0.0, "builds must take nonzero time");
    assert!((0.0..=1.0).contains(&s.occupancy) && s.occupancy > 0.0);
}

#[test]
fn same_seed_traces_are_structurally_deterministic() {
    let _g = lock();
    let q = quantized();
    let (y1, c1, t1) = run(&q, true);
    let (y2, c2, t2) = run(&q, true);
    assert_eq!(t1.structural(), t2.structural(), "same seed ⇒ same (label, tag) multiset");
    assert_eq!(y1, y2, "same seed ⇒ bit-identical outputs");
    assert_eq!(exact_counts(&c1), exact_counts(&c2));
    assert_eq!(t1.dropped, 0, "this workload must fit the default ring");
}

#[test]
fn profiler_off_is_bit_identical_and_silent() {
    let _g = lock();
    let q = quantized();
    let (y_off, c_off, tl_off) = run(&q, false);
    assert!(tl_off.events.is_empty(), "disabled profiler must record nothing");
    assert_eq!(tl_off.dropped, 0);

    let (y_on, c_on, tl_on) = run(&q, true);
    assert!(!tl_on.events.is_empty());
    assert_eq!(y_off, y_on, "tracing must not change kernel outputs");
    assert_eq!(
        exact_counts(&c_off),
        exact_counts(&c_on),
        "tracing must not change the exact counters"
    );
    // And the byte split introduced for the roofline stays conserved.
    assert_eq!(c_off.build_bytes + c_off.read_bytes, c_off.total_bytes());
}
