//! Property tests for the refcounted prefix-sharing page pool (via
//! `util::proptest`):
//!
//! - **refcount conservation**: over random interleavings of
//!   alloc/free/pin/publish, every page's pool refcount equals a
//!   brute-force census of the references the test itself holds, and
//!   all derived gauges (`used_pages`, `live_refs`, `cached_pages`,
//!   `free_pages`, `prefix_pages`) agree with the census;
//! - **hash-collision safety**: identities forced into one index bucket
//!   (`insert_hashed`/`lookup_hashed`) never alias — a colliding hash
//!   with different content is a miss, never another prompt's page;
//! - **eviction safety**: allocating to exhaustion only ever recycles
//!   refcount-zero cached pages — pages with holders are untouched and
//!   keep their registrations;
//! - **sharing is invisible to the model**: prefill over pinned prefix
//!   pages another sequence published (including the forced
//!   copy-on-write divergence when the hit is capped inside a page) is
//!   **bit-identical** to a cold run of the same page dtype, through a
//!   mid-stream preemption (spill → restore of the coded bytes) and
//!   greedy decode — under all three page encodings (f32/f16/int8),
//!   with the pool fully reclaimable afterwards;
//! - **hits share the quantized bytes**: a prefix hit pins the
//!   publisher's own coded pages (no copy, no decode→re-encode), and
//!   the shared footprint counted in coded bytes shrinks ≥ 3× under
//!   int8 at model-scale row widths.

use codegemm::config::ModelConfig;
use codegemm::kvcache::{BlockPool, KvDtype, KvLayout, PagedKv, PrefixIndex, SeqKv, ROOT_HASH};
use codegemm::model::{argmax, EngineKind, LlamaModel, ModelWeights};
use codegemm::util::prng::Prng;
use codegemm::util::proptest as pt;

// ---------------------------------------------------------------------------
// Refcount conservation under random op interleavings
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct OpsCase {
    pages: usize,
    n_ops: usize,
    seed: u64,
}

fn small_layout(page_size: usize) -> KvLayout {
    KvLayout { n_layers: 1, kv_dim: 2, page_size, max_seq: 256, dtype: KvDtype::F32 }
}

/// Compare every pool gauge against a brute-force census of the
/// references `holders` records (one entry per reference the test owns).
fn census(pool: &BlockPool, holders: &[usize]) -> Result<(), String> {
    let total = pool.total_pages();
    let mut want = vec![0u32; total];
    for &p in holders {
        want[p] += 1;
    }
    for p in 0..total {
        pt::ensure(
            pool.refs(p) == want[p],
            format!("page {p}: pool refcount {} != census {}", pool.refs(p), want[p]),
        )?;
    }
    let used = (0..total).filter(|&p| want[p] > 0).count();
    let cached = (0..total).filter(|&p| want[p] == 0 && pool.is_registered(p)).count();
    let registered = (0..total).filter(|&p| pool.is_registered(p)).count();
    let s = pool.stats();
    pt::ensure(s.used_pages == used, format!("used_pages {} != census {used}", s.used_pages))?;
    pt::ensure(
        s.live_refs == holders.len(),
        format!("live_refs {} != held references {}", s.live_refs, holders.len()),
    )?;
    pt::ensure(
        s.cached_pages == cached,
        format!("cached_pages {} != census {cached}", s.cached_pages),
    )?;
    // free list + cached-evictable together are the allocatable set.
    pt::ensure(
        s.free_pages == total - used,
        format!("free_pages {} != {total} - used {used}", s.free_pages),
    )?;
    pt::ensure(
        s.prefix_pages == registered,
        format!("prefix_pages {} != census {registered}", s.prefix_pages),
    )?;
    Ok(())
}

#[test]
fn prop_refcounts_match_brute_force_census() {
    let gen = pt::gen_fn(|rng: &mut Prng| OpsCase {
        pages: 2 + rng.index(6),
        n_ops: 1 + rng.index(60),
        seed: rng.next_u64(),
    });
    let cfg = pt::PropConfig { cases: 48, ..Default::default() };
    pt::assert_prop("refcount conservation", cfg, &gen, |c: &OpsCase| {
        let ps = 4;
        let mut pool = BlockPool::new(small_layout(ps), c.pages);
        let mut rng = Prng::seeded(c.seed);
        // One entry per reference this test owns (pages may repeat:
        // shared pages hold one entry per holder).
        let mut holders: Vec<usize> = Vec::new();
        let mut published = 0usize;
        for op in 0..c.n_ops {
            match rng.index(4) {
                // Allocate (may evict a cached page — census observes the
                // dropped registration through `is_registered`).
                0 => {
                    if let Some(p) = pool.try_alloc() {
                        holders.push(p);
                    }
                }
                // Drop one of our references.
                1 => {
                    if !holders.is_empty() {
                        let i = rng.index(holders.len());
                        let p = holders.swap_remove(i);
                        pool.free(p);
                    }
                }
                // Add a holder: share a used page or revive a cached one.
                2 => {
                    let mut cands: Vec<usize> = holders.clone();
                    cands.extend(
                        (0..pool.total_pages())
                            .filter(|&p| pool.refs(p) == 0 && pool.is_registered(p)),
                    );
                    if !cands.is_empty() {
                        let p = cands[rng.index(cands.len())];
                        pool.pin(p);
                        holders.push(p);
                    }
                }
                // Register a held, not-yet-registered page under a fresh
                // (never colliding) single-page identity.
                _ => {
                    let cands: Vec<usize> = holders
                        .iter()
                        .copied()
                        .filter(|&p| !pool.is_registered(p))
                        .collect();
                    if !cands.is_empty() {
                        let p = cands[rng.index(cands.len())];
                        let toks: Vec<usize> =
                            (0..ps).map(|j| 10_000 + published * ps + j).collect();
                        pool.publish_prefix(&toks, &[p]);
                        published += 1;
                    }
                }
            }
            census(&pool, &holders).map_err(|e| format!("after op {op}: {e}"))?;
        }
        // Drain: dropping every reference must return the pool to fully
        // allocatable, with only registered pages surviving as cached.
        for p in holders.drain(..) {
            pool.free(p);
        }
        census(&pool, &[])?;
        let s = pool.stats();
        pt::ensure(
            s.free_pages == s.total_pages,
            format!("drained pool not fully allocatable: {} of {}", s.free_pages, s.total_pages),
        )
    });
}

// ---------------------------------------------------------------------------
// Hash collisions degrade to misses, never to aliasing
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct CollisionCase {
    entries: usize,
    seed: u64,
}

#[test]
fn prop_colliding_hashes_never_alias_content() {
    let gen = pt::gen_fn(|rng: &mut Prng| CollisionCase {
        entries: 1 + rng.index(6),
        seed: rng.next_u64(),
    });
    let cfg = pt::PropConfig { cases: 64, ..Default::default() };
    pt::assert_prop("collision safety", cfg, &gen, |c: &CollisionCase| {
        let mut rng = Prng::seeded(c.seed);
        let mut ix = PrefixIndex::new();
        // Distinct identities forced into ONE bucket: same hash, same
        // parent, different token content.
        let idents: Vec<Vec<usize>> =
            (0..c.entries).map(|i| vec![i, rng.index(1000), rng.index(1000)]).collect();
        const HASH: u64 = 0xDEAD_BEEF;
        for (page, toks) in idents.iter().enumerate() {
            pt::ensure(
                ix.insert_hashed(HASH, ROOT_HASH, toks, page),
                format!("fresh identity {toks:?} rejected"),
            )?;
        }
        for (page, toks) in idents.iter().enumerate() {
            pt::ensure(
                ix.lookup_hashed(HASH, ROOT_HASH, toks) == Some(page),
                format!("identity {toks:?} did not resolve to its own page {page}"),
            )?;
        }
        // Same hash, content the index has never seen: a miss, never a
        // wrong page.
        let unknown = vec![c.entries + 1, 2000, 2000];
        pt::ensure(
            ix.lookup_hashed(HASH, ROOT_HASH, &unknown).is_none(),
            "colliding unknown content resolved to a page",
        )?;
        // A different parent chain with identical tokens is a different
        // identity — also a miss.
        pt::ensure(
            ix.lookup_hashed(HASH, 12_345, &idents[0]).is_none(),
            "same tokens under a different parent resolved to a page",
        )?;
        // Partial removal leaves the other bucket entries resolvable.
        pt::ensure(ix.remove_page(0), "page 0 was registered")?;
        pt::ensure(ix.lookup_hashed(HASH, ROOT_HASH, &idents[0]).is_none(), "removed entry hit")?;
        for (page, toks) in idents.iter().enumerate().skip(1) {
            pt::ensure(
                ix.lookup_hashed(HASH, ROOT_HASH, toks) == Some(page),
                format!("bucket survivor {toks:?} lost after removal"),
            )?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Eviction never reclaims referenced pages
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct EvictCase {
    pages: usize,
    held: usize,
    seed: u64,
}

#[test]
fn prop_eviction_only_recycles_refcount_zero_pages() {
    let gen = pt::gen_fn(|rng: &mut Prng| {
        let pages = 3 + rng.index(6);
        EvictCase { pages, held: 1 + rng.index(pages), seed: rng.next_u64() }
    });
    let cfg = pt::PropConfig { cases: 64, ..Default::default() };
    pt::assert_prop("eviction safety", cfg, &gen, |c: &EvictCase| {
        let ps = 4;
        let mut pool = BlockPool::new(small_layout(ps), c.pages);
        let mut rng = Prng::seeded(c.seed);
        let mut holders: Vec<usize> = (0..c.held).map(|_| pool.try_alloc().unwrap()).collect();
        // Register a random subset of the held pages…
        for (i, &p) in holders.iter().enumerate() {
            if rng.index(2) == 0 {
                let toks: Vec<usize> = (0..ps).map(|j| 10_000 + i * ps + j).collect();
                pool.publish_prefix(&toks, &[p]);
            }
        }
        // …then drop a random subset of references (registered drops
        // park as cached-evictable, unregistered drops go to the free
        // list).
        let mut kept: Vec<usize> = Vec::new();
        for p in holders.drain(..) {
            if rng.index(2) == 0 {
                pool.free(p);
            } else {
                kept.push(p);
            }
        }
        let kept_regs: Vec<bool> = kept.iter().map(|&p| pool.is_registered(p)).collect();
        // Allocate to exhaustion: everything allocatable must surface…
        let mut fresh: Vec<usize> = Vec::new();
        while let Some(p) = pool.try_alloc() {
            fresh.push(p);
            pt::ensure(fresh.len() <= c.pages, "allocator yielded more pages than exist")?;
        }
        pt::ensure(
            kept.len() + fresh.len() == c.pages,
            format!("{} held + {} fresh != {} total", kept.len(), fresh.len(), c.pages),
        )?;
        // …but never a page we still hold, and never with a stale
        // registration (eviction unregisters before recycling).
        for &p in &fresh {
            pt::ensure(!kept.contains(&p), format!("allocator recycled held page {p}"))?;
            pt::ensure(
                !pool.is_registered(p),
                format!("recycled page {p} kept its prefix registration"),
            )?;
        }
        for (i, &p) in kept.iter().enumerate() {
            pt::ensure(pool.refs(p) == 1, format!("held page {p} lost its reference"))?;
            pt::ensure(
                pool.is_registered(p) == kept_regs[i],
                format!("held page {p} registration disturbed by allocation pressure"),
            )?;
        }
        pt::ensure(pool.try_alloc().is_none(), "exhausted pool still allocated")?;
        pt::ensure(pool.used_pages() == c.pages, "exhaustion census")
    });
}

// ---------------------------------------------------------------------------
// Prefix sharing + CoW is bitwise invisible to the model
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct ShareCase {
    page_size: usize,
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
    /// Full pages of the published prompt the hitter reuses.
    shared_pages: usize,
    /// Tokens the hitter appends past the shared prefix (0 = the
    /// exact-prefix prompt, whose matched cap forces copy-on-write).
    suffix_len: usize,
    decode_steps: usize,
    /// Page encoding the whole interleaving runs under.
    dtype: KvDtype,
    seed: u64,
}

const MAX_SEQ: usize = 64;

fn share_model_config(c: &ShareCase) -> ModelConfig {
    ModelConfig {
        name: "prefix-prop".into(),
        vocab: 48,
        hidden: c.n_heads * c.head_dim,
        n_layers: 2,
        n_heads: c.n_heads,
        n_kv_heads: c.n_kv_heads,
        ffn: 3 * c.n_heads * c.head_dim,
        max_seq: MAX_SEQ,
        rope_theta_milli: 10_000_000,
    }
}

#[test]
fn prop_shared_prefix_prefill_bit_exact_vs_contiguous() {
    let heads: [(usize, usize); 3] = [(2, 1), (4, 2), (4, 4)];
    let gen = pt::gen_fn(move |rng: &mut Prng| {
        let (n_heads, n_kv_heads) = heads[rng.index(heads.len())];
        ShareCase {
            page_size: [2, 4, 8][rng.index(3)],
            n_heads,
            n_kv_heads,
            head_dim: if rng.index(2) == 0 { 4 } else { 8 },
            shared_pages: 1 + rng.index(3),
            suffix_len: rng.index(6),
            // At least one step so the post-preemption decode always
            // observes the restored pages.
            decode_steps: 1 + rng.index(3),
            dtype: [KvDtype::F32, KvDtype::F16, KvDtype::Int8][rng.index(3)],
            seed: rng.next_u64(),
        }
    });
    let cfg = pt::PropConfig { cases: 24, ..Default::default() };
    pt::assert_prop("shared prefill == contiguous", cfg, &gen, |c: &ShareCase| {
        let ps = c.page_size;
        let cfg_model = share_model_config(c);
        let w = ModelWeights::random(cfg_model.clone(), c.seed);
        let mut model = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut rng = Prng::seeded(c.seed ^ 0x5A5A);

        // Publisher prompt: at least `shared_pages` full pages plus a
        // partial tail (published pages cover only full pages).
        let a_len = c.shared_pages * ps + rng.index(ps);
        let prompt_a: Vec<usize> = (0..a_len.max(1)).map(|_| rng.index(cfg_model.vocab)).collect();
        // Hitter prompt: a full-page prefix of A plus a fresh suffix.
        let fp = 1 + rng.index(c.shared_pages);
        let mut prompt_b: Vec<usize> = prompt_a[..fp * ps].to_vec();
        prompt_b.extend((0..c.suffix_len).map(|_| rng.index(cfg_model.vocab)));

        let layout = KvLayout {
            n_layers: cfg_model.n_layers,
            kv_dim: cfg_model.kv_dim(),
            page_size: ps,
            max_seq: MAX_SEQ,
            dtype: c.dtype,
        };
        let mut pool = BlockPool::new(layout, 2 * layout.max_pages_per_seq());

        // Publisher prefills cold and registers its full prompt pages.
        let mut a = SeqKv::with_capacity(layout.max_pages_per_seq());
        {
            let mut kv = PagedKv::bind(&mut pool, &mut a);
            model.forward_batch(&prompt_a, 0, &mut kv);
        }
        pool.publish_prefix(&prompt_a, a.pages());

        // Hitter admission, mirroring the serving backend's plan: pin the
        // matched pages, cap the hit below the prompt length so the final
        // position is always recomputed (first-sample logits), pre-claim
        // the CoW spare when the cap lands inside a pinned page.
        let avail = pool.prefix_peek(&prompt_b);
        pt::ensure(avail >= fp, format!("published prefix not hittable: {avail} < {fp}"))?;
        let matched = (avail * ps).min(prompt_b.len() - 1);
        let pin = layout.pages_for(matched);
        let pinned = pool.prefix_acquire(&prompt_b, pin);
        pt::ensure(pinned.len() == pin, format!("pinned {} of {pin}", pinned.len()))?;
        let mut b = SeqKv::with_capacity(layout.max_pages_per_seq());
        b.set_prefix(&pinned, matched);
        let expect_cow = matched % ps != 0;
        if expect_cow {
            pt::ensure(b.claim_cow_spare(&mut pool), "pool exhausted claiming CoW spare")?;
        }
        let lp = {
            let mut kv = PagedKv::bind(&mut pool, &mut b);
            model.forward_batch(&prompt_b[matched..], matched, &mut kv)
        };

        // Cold reference over the identical prompt: a fresh pool of the
        // SAME dtype. Sharing, CoW and preemption must be invisible
        // *within* an encoding — encode→decode is deterministic, so the
        // comparison is bitwise even for f16/int8.
        let mut ref_pool = BlockPool::new(layout, layout.max_pages_per_seq());
        let mut r = SeqKv::with_capacity(layout.max_pages_per_seq());
        let lf = {
            let mut kv = PagedKv::bind(&mut ref_pool, &mut r);
            model.forward_batch(&prompt_b, 0, &mut kv)
        };
        pt::ensure(lf == lp, format!("shared prefill logits not bit-identical ({c:?})"))?;
        if c.dtype == KvDtype::F32 {
            // f32 passthrough additionally matches the contiguous cache.
            let mut flat = model.new_cache();
            let lflat = model.forward_batch(&prompt_b, 0, &mut flat);
            pt::ensure(lflat == lf, format!("f32 paged != contiguous ({c:?})"))?;
        }
        if expect_cow {
            pt::ensure(pool.stats().cow_copies >= 1, "capped hit did not copy-on-write")?;
        }

        // Preempt the hitter mid-stream: spill its coded bytes verbatim,
        // release every page (shared pins drop back to the publisher),
        // restore into freshly claimed private pages. Decode after this
        // must still be bitwise locked — the round-trip never decodes
        // and re-encodes.
        {
            let n = layout.pages_for(b.len());
            let len = b.len();
            let snap = pool.export_pages(&b.pages()[..n]);
            b.release(&mut pool);
            pt::ensure(
                b.claim(&mut pool, layout.max_pages_per_seq()),
                "pool exhausted re-admitting the preempted hitter",
            )?;
            for i in 0..n {
                pool.import_page(b.pages()[i], &snap, i);
            }
            b.set_len(len);
        }

        // Greedy decode stays bitwise locked across the restore.
        let (mut lf, mut lp) = (lf, lp);
        for step in 0..c.decode_steps {
            let pos = prompt_b.len() + step;
            if pos >= MAX_SEQ {
                break;
            }
            let (tf, tp) = (argmax(&lf), argmax(&lp));
            pt::ensure(tf == tp, format!("greedy token diverged at step {step} ({c:?})"))?;
            lf = {
                let mut kv = PagedKv::bind(&mut ref_pool, &mut r);
                model.forward(tf, pos, &mut kv)
            };
            lp = {
                let mut kv = PagedKv::bind(&mut pool, &mut b);
                model.forward(tp, pos, &mut kv)
            };
            pt::ensure(lf == lp, format!("decode logits diverged at step {step} ({c:?})"))?;
        }

        // The publisher's pages must be untouched by the hitter: its own
        // replay of the final prompt position still reads shared content.
        b.release(&mut pool);
        a.release(&mut pool);
        let s = pool.stats();
        pt::ensure(s.used_pages == 0 && s.live_refs == 0, "references leaked")?;
        pt::ensure(
            s.free_pages == s.total_pages,
            format!("drained pool not fully allocatable: {} of {}", s.free_pages, s.total_pages),
        )
    });
}

// ---------------------------------------------------------------------------
// Prefix hits share the *quantized* pages, counted in coded bytes
// ---------------------------------------------------------------------------

#[test]
fn prefix_hits_pin_shared_coded_pages_and_int8_footprint_shrinks() {
    // Contention on one published prompt: the hit must pin the
    // publisher's own pages — the pool holds exactly one copy of the
    // coded (possibly quantized) bytes, never a decoded duplicate — and
    // the shared footprint is priced in coded bytes, so an int8 prefix
    // costs ≤ 0.3× its f32 twin at model-scale row widths (kv_dim 64:
    // 1/4 element bytes + one f32 scale per row).
    let mk = |dtype| KvLayout { n_layers: 2, kv_dim: 64, page_size: 8, max_seq: 64, dtype };
    let mut shared_bytes = Vec::new();
    for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
        let l = mk(dtype);
        let mut pool = BlockPool::new(l, 8);
        let toks: Vec<usize> = (0..2 * l.page_size).collect();
        let p0 = pool.try_alloc().unwrap();
        let p1 = pool.try_alloc().unwrap();
        let row: Vec<f32> = (0..l.kv_dim).map(|i| i as f32 * 0.25 - 3.0).collect();
        for &page in &[p0, p1] {
            for layer in 0..l.n_layers {
                for idx in 0..l.page_size {
                    pool.write(page, layer, idx, &row, &row);
                }
            }
        }
        pool.publish_prefix(&toks, &[p0, p1]);
        // Two contending hitters pin the same physical pages.
        let hit_a = pool.prefix_acquire(&toks, usize::MAX);
        let hit_b = pool.prefix_acquire(&toks, usize::MAX);
        assert_eq!(hit_a, vec![p0, p1], "hit must pin the publisher's own coded pages");
        assert_eq!(hit_b, hit_a, "contending hits share one physical copy");
        assert_eq!(pool.refs(p0), 3, "publisher + two hitters on one page");
        assert_eq!(pool.stats().cow_copies, 0, "a read-only hit never copies");
        // Used pages did not grow with the hitters: the shared coded
        // bytes exist once in the pool.
        assert_eq!(pool.used_pages(), 2);
        shared_bytes.push(hit_a.len() * l.page_bytes());
        for p in hit_a.into_iter().chain(hit_b) {
            pool.free(p);
        }
        pool.free(p0);
        pool.free(p1);
        assert_eq!(pool.free_pages(), pool.total_pages(), "full reclamation");
    }
    let (f32_b, f16_b, i8_b) = (shared_bytes[0], shared_bytes[1], shared_bytes[2]);
    assert_eq!(f16_b * 2, f32_b, "f16 prefix costs exactly half");
    assert!(i8_b * 10 <= f32_b * 3, "int8 shared prefix {i8_b}B vs f32 {f32_b}B: want ≤ 0.3×");
}
