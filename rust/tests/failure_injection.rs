//! Failure-injection tests: corrupted artifacts, malformed containers,
//! invalid configurations — every failure must surface as a clear error,
//! never a panic or silent wrong answer.

use codegemm::config::{KernelConfig, ModelConfig, QuantConfig};
use codegemm::model::ModelWeights;
use codegemm::quant::pack::PackedCodes;
use codegemm::quant::Quantizer;
use codegemm::runtime::{Manifest, ModelRuntime};
use codegemm::util::npy::{Tensor, TensorFile};
use codegemm::util::prng::Prng;
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("codegemm-fi-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn truncated_tensorfile_rejected() {
    let w = ModelWeights::random(ModelConfig::tiny(), 1);
    let bytes = w.to_tensor_file().to_bytes().unwrap();
    for cut in [4usize, 15, 64, bytes.len() - 8] {
        let res = TensorFile::from_bytes(&bytes[..cut]);
        assert!(res.is_err(), "truncation at {cut} must error");
    }
}

#[test]
fn tensorfile_with_garbage_header_rejected() {
    let mut bytes = ModelWeights::random(ModelConfig::tiny(), 1).to_tensor_file().to_bytes().unwrap();
    bytes[20] = b'!'; // corrupt the JSON header
    assert!(TensorFile::from_bytes(&bytes).is_err());
}

#[test]
fn manifest_missing_fields_rejected() {
    use codegemm::util::json::Json;
    let min = r#"{"version":1,"engine":"codegemm"}"#;
    let j = Json::parse(min).unwrap();
    assert!(Manifest::from_json(PathBuf::from("/tmp"), &j).is_err());
}

#[test]
fn runtime_with_corrupt_hlo_fails_cleanly() {
    let dir = tmpdir("hlo");
    // Minimal manifest pointing at a garbage HLO file.
    std::fs::write(
        dir.join("manifest.json"),
        r#"{
          "version": 1, "engine": "codegemm",
          "model": {"name":"tiny-llama","vocab":256,"hidden":128,"n_layers":2,
                    "n_heads":4,"n_kv_heads":2,"ffn":352,"max_seq":128,"rope_theta":10000.0},
          "quant": {"v":4,"m":1,"b":8,"g":32},
          "weights_file": "weights.q.bin",
          "weight_args": ["embedding"],
          "artifacts": [{"name":"decode_b1","batch":1,"hlo":"decode_b1.hlo.txt"}]
        }"#,
    )
    .unwrap();
    std::fs::write(dir.join("decode_b1.hlo.txt"), "this is not HLO text").unwrap();
    let mut tf = TensorFile::new();
    tf.push(Tensor::f32("embedding", vec![256, 128], vec![0.0; 256 * 128]));
    tf.save(dir.join("weights.q.bin")).unwrap();
    let err = match ModelRuntime::load(&dir) {
        Ok(_) => panic!("garbage HLO must not load"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("decode_b1"), "error should name the artifact: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn runtime_with_missing_weight_tensor_fails_cleanly() {
    // Real artifacts (if present) but a weights file missing a tensor.
    let real = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !real.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let dir = tmpdir("weights");
    for f in std::fs::read_dir(&real).unwrap() {
        let f = f.unwrap();
        if f.file_name() != "weights.q.bin" {
            std::fs::copy(f.path(), dir.join(f.file_name())).unwrap();
        }
    }
    // Weights file present but lacking every tensor the manifest lists.
    let mut tf = TensorFile::new();
    tf.push(Tensor::f32("bogus", vec![1], vec![0.0]));
    tf.save(dir.join("weights.q.bin")).unwrap();
    let err = match ModelRuntime::load(&dir) {
        Ok(_) => panic!("missing tensors must not load"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("missing tensor"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn packed_codes_reject_out_of_range() {
    assert!(PackedCodes::pack(&[0, 3, 4], 2).is_err(), "code 4 does not fit 2 bits");
    assert!(PackedCodes::pack(&[0, 1], 0).is_err(), "0-bit codes are invalid");
}

#[test]
fn kernel_config_rejects_group_straddling_tiles()
{
    // t_w=32, g=48: tiles straddle group boundaries mid-group.
    let kc = KernelConfig::new(32, 2048).unwrap();
    let q = QuantConfig::new(4, 1, 8, 48).unwrap();
    assert!(kc.validate_for(&q, 4800).is_err());
}

#[test]
fn quantizer_asserts_on_misaligned_k() {
    let cfg = QuantConfig::new(8, 1, 4, -1).unwrap();
    let w = Prng::seeded(1).normal_vec(4 * 20, 0.02); // k=20 not divisible by v=8
    let res = std::panic::catch_unwind(|| Quantizer::new(cfg).quantize(&w, 4, 20));
    assert!(res.is_err(), "misaligned k must be rejected loudly");
}

#[test]
fn model_weights_reject_wrong_shapes() {
    let cfg = ModelConfig::tiny();
    let w = ModelWeights::random(cfg.clone(), 1);
    let mut tf = w.to_tensor_file();
    // Swap in a wrong-sized lm_head.
    tf.tensors.retain(|t| t.name != "lm_head");
    tf.push(Tensor::f32("lm_head", vec![cfg.vocab, cfg.hidden - 1], vec![0.0; cfg.vocab * (cfg.hidden - 1)]));
    assert!(ModelWeights::from_tensor_file(cfg, &tf).is_err());
}
