//! Failure-injection tests: corrupted artifacts, malformed containers,
//! invalid configurations — every failure must surface as a clear error,
//! never a panic or silent wrong answer.
//!
//! The scheduler section injects failures into the preemption machinery:
//! a backend that panics mid-spill (the batcher must fall back to
//! recompute-from-prompt, still bit-exact), an infeasible request
//! arriving while a preempted victim waits to resume (rejected cleanly,
//! the victim still completes), and pool exhaustion with no
//! lower-priority victim (degrades to deferral, drains within a bounded
//! step count).

use codegemm::config::{KernelConfig, KvConfig, ModelConfig, PreemptMode, QuantConfig, ServeConfig};
use codegemm::coordinator::{
    Batcher, DecodeBackend, FinishReason, Metrics, NativeBackend, Request, SlotStep,
};
use codegemm::kvcache::{KvStats, SpilledKv};
use codegemm::model::{EngineKind, ModelWeights};
use std::sync::Arc;
use codegemm::quant::pack::PackedCodes;
use codegemm::quant::Quantizer;
use codegemm::runtime::{Manifest, ModelRuntime};
use codegemm::util::npy::{Tensor, TensorFile};
use codegemm::util::prng::Prng;
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("codegemm-fi-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn truncated_tensorfile_rejected() {
    let w = ModelWeights::random(ModelConfig::tiny(), 1);
    let bytes = w.to_tensor_file().to_bytes().unwrap();
    for cut in [4usize, 15, 64, bytes.len() - 8] {
        let res = TensorFile::from_bytes(&bytes[..cut]);
        assert!(res.is_err(), "truncation at {cut} must error");
    }
}

#[test]
fn tensorfile_with_garbage_header_rejected() {
    let mut bytes = ModelWeights::random(ModelConfig::tiny(), 1).to_tensor_file().to_bytes().unwrap();
    bytes[20] = b'!'; // corrupt the JSON header
    assert!(TensorFile::from_bytes(&bytes).is_err());
}

#[test]
fn manifest_missing_fields_rejected() {
    use codegemm::util::json::Json;
    let min = r#"{"version":1,"engine":"codegemm"}"#;
    let j = Json::parse(min).unwrap();
    assert!(Manifest::from_json(PathBuf::from("/tmp"), &j).is_err());
}

#[test]
fn runtime_with_corrupt_hlo_fails_cleanly() {
    let dir = tmpdir("hlo");
    // Minimal manifest pointing at a garbage HLO file.
    std::fs::write(
        dir.join("manifest.json"),
        r#"{
          "version": 1, "engine": "codegemm",
          "model": {"name":"tiny-llama","vocab":256,"hidden":128,"n_layers":2,
                    "n_heads":4,"n_kv_heads":2,"ffn":352,"max_seq":128,"rope_theta":10000.0},
          "quant": {"v":4,"m":1,"b":8,"g":32},
          "weights_file": "weights.q.bin",
          "weight_args": ["embedding"],
          "artifacts": [{"name":"decode_b1","batch":1,"hlo":"decode_b1.hlo.txt"}]
        }"#,
    )
    .unwrap();
    std::fs::write(dir.join("decode_b1.hlo.txt"), "this is not HLO text").unwrap();
    let mut tf = TensorFile::new();
    tf.push(Tensor::f32("embedding", vec![256, 128], vec![0.0; 256 * 128]));
    tf.save(dir.join("weights.q.bin")).unwrap();
    let err = match ModelRuntime::load(&dir) {
        Ok(_) => panic!("garbage HLO must not load"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("decode_b1"), "error should name the artifact: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn runtime_with_missing_weight_tensor_fails_cleanly() {
    // Real artifacts (if present) but a weights file missing a tensor.
    let real = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !real.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let dir = tmpdir("weights");
    for f in std::fs::read_dir(&real).unwrap() {
        let f = f.unwrap();
        if f.file_name() != "weights.q.bin" {
            std::fs::copy(f.path(), dir.join(f.file_name())).unwrap();
        }
    }
    // Weights file present but lacking every tensor the manifest lists.
    let mut tf = TensorFile::new();
    tf.push(Tensor::f32("bogus", vec![1], vec![0.0]));
    tf.save(dir.join("weights.q.bin")).unwrap();
    let err = match ModelRuntime::load(&dir) {
        Ok(_) => panic!("missing tensors must not load"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("missing tensor"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn packed_codes_reject_out_of_range() {
    assert!(PackedCodes::pack(&[0, 3, 4], 2).is_err(), "code 4 does not fit 2 bits");
    assert!(PackedCodes::pack(&[0, 1], 0).is_err(), "0-bit codes are invalid");
}

#[test]
fn kernel_config_rejects_group_straddling_tiles()
{
    // t_w=32, g=48: tiles straddle group boundaries mid-group.
    let kc = KernelConfig::new(32, 2048).unwrap();
    let q = QuantConfig::new(4, 1, 8, 48).unwrap();
    assert!(kc.validate_for(&q, 4800).is_err());
}

#[test]
fn quantizer_asserts_on_misaligned_k() {
    let cfg = QuantConfig::new(8, 1, 4, -1).unwrap();
    let w = Prng::seeded(1).normal_vec(4 * 20, 0.02); // k=20 not divisible by v=8
    let res = std::panic::catch_unwind(|| Quantizer::new(cfg).quantize(&w, 4, 20));
    assert!(res.is_err(), "misaligned k must be rejected loudly");
}

#[test]
fn model_weights_reject_wrong_shapes() {
    let cfg = ModelConfig::tiny();
    let w = ModelWeights::random(cfg.clone(), 1);
    let mut tf = w.to_tensor_file();
    // Swap in a wrong-sized lm_head.
    tf.tensors.retain(|t| t.name != "lm_head");
    tf.push(Tensor::f32("lm_head", vec![cfg.vocab, cfg.hidden - 1], vec![0.0; cfg.vocab * (cfg.hidden - 1)]));
    assert!(ModelWeights::from_tensor_file(cfg, &tf).is_err());
}

// ---------------------------------------------------------------------------
// Scheduler failure injection: the preemption machinery under faults
// ---------------------------------------------------------------------------

/// A pool-backed backend whose spill path panics mid-preemption — the
/// batcher must contain the panic and fall back to
/// recompute-from-prompt (the victim's pages are still held at the
/// panic, so an ordinary `reset_slot` reclaims them).
struct PanickingSpillBackend {
    inner: NativeBackend,
}

impl DecodeBackend for PanickingSpillBackend {
    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }
    fn max_seq(&self) -> usize {
        self.inner.max_seq()
    }
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }
    fn step(&mut self, steps: &[SlotStep]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.inner.step(steps)
    }
    fn prefill(
        &mut self,
        slot: usize,
        tokens: &[usize],
        pos: usize,
        want_logits: bool,
    ) -> anyhow::Result<Option<Vec<f32>>> {
        self.inner.prefill(slot, tokens, pos, want_logits)
    }
    fn reset_slot(&mut self, slot: usize) {
        self.inner.reset_slot(slot)
    }
    fn can_admit(&self, max_tokens: usize) -> bool {
        self.inner.can_admit(max_tokens)
    }
    fn can_ever_admit(&self, max_tokens: usize) -> bool {
        self.inner.can_ever_admit(max_tokens)
    }
    fn reserve(&mut self, slot: usize, max_tokens: usize) {
        self.inner.reserve(slot, max_tokens)
    }
    fn can_admit_prompt(&self, prompt: &[usize], max_tokens: usize) -> bool {
        self.inner.can_admit_prompt(prompt, max_tokens)
    }
    fn reserve_with_prefix(&mut self, slot: usize, prompt: &[usize], max_tokens: usize) -> usize {
        self.inner.reserve_with_prefix(slot, prompt, max_tokens)
    }
    fn publish_prefix(&mut self, slot: usize, tokens: &[usize]) {
        self.inner.publish_prefix(slot, tokens)
    }
    fn spill(&mut self, _slot: usize) -> Option<SpilledKv> {
        panic!("injected spill failure");
    }
    fn restore(&mut self, slot: usize, spill: &SpilledKv, max_tokens: usize) -> bool {
        self.inner.restore(slot, spill, max_tokens)
    }
    fn kv_stats(&self) -> Option<KvStats> {
        self.inner.kv_stats()
    }
    fn label(&self) -> String {
        format!("panicking-spill/{}", self.inner.label())
    }
}

/// A 4-page pool where one request's lifetime (3 prompt + 6 generated →
/// 3 pages) leaves too little for a second — the contention geometry the
/// batcher unit tests use, reused by every scheduler-fault test below.
fn contended_serve_config(mode: PreemptMode) -> (KvConfig, ServeConfig) {
    let kv = KvConfig { page_size: 4, pool_pages: 4, preempt: mode, ..KvConfig::default() };
    let cfg = ServeConfig {
        max_batch: 2,
        max_new_tokens: 6,
        temperature: 0.0,
        queue_capacity: 8,
        kv: kv.clone(),
        ..Default::default()
    };
    (kv, cfg)
}

/// Greedy tokens of `prompt` served alone (the uncontended reference).
fn solo_tokens(w: &ModelWeights, kv: &KvConfig, cfg: &ServeConfig, prompt: Vec<usize>) -> Vec<usize> {
    let backend = Box::new(NativeBackend::with_kv(w, EngineKind::Dense, 2, kv));
    let mut b = Batcher::new(backend, cfg.clone(), Arc::new(Metrics::new()));
    b.submit(Request::new(0, prompt, cfg.max_new_tokens));
    b.run_to_completion().remove(0).tokens
}

#[test]
fn spill_panic_falls_back_to_recompute_and_stays_bit_exact() {
    let w = ModelWeights::random(ModelConfig::tiny(), 3);
    let (kv, cfg) = contended_serve_config(PreemptMode::Spill);
    let want_low = solo_tokens(&w, &kv, &cfg, vec![1, 2, 3]);
    let want_high = solo_tokens(&w, &kv, &cfg, vec![4, 5, 6]);

    let backend = Box::new(PanickingSpillBackend {
        inner: NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv),
    });
    let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
    b.submit(Request::new(1, vec![1, 2, 3], 6)); // priority 0
    b.step(); // prefill low
    b.step(); // low decodes — a valid preemption victim now
    b.submit(Request::new(2, vec![4, 5, 6], 6).with_priority(1));
    let mut out = b.run_to_completion();
    out.sort_by_key(|r| r.id);

    assert_eq!(out.len(), 2);
    assert_eq!(out[0].tokens, want_low, "recompute fallback diverged");
    assert_eq!(out[1].tokens, want_high, "preempting request diverged");
    assert!(out.iter().all(|r| r.finish == FinishReason::Length));
    let report = b.metrics.report();
    assert!(report.preemptions >= 1, "the high-priority arrival must preempt");
    assert_eq!(report.preempt_spills, 0, "the injected panic must abort every spill");
    assert_eq!(
        report.preempt_recomputes, report.preemptions,
        "every panicked spill must fall back to recompute"
    );
    assert_eq!(report.resumes, report.preemptions, "every victim resumes");
    // The aborted spill must not leak the victim's pages.
    let kv_stats = report.kv.expect("pool-backed backend");
    assert_eq!(kv_stats.pool.used_pages, 0);
    assert_eq!(kv_stats.pool.live_refs, 0);
    assert_eq!(kv_stats.pool.free_pages, kv_stats.pool.total_pages, "full reclamation");
}

#[test]
fn infeasible_request_rejected_while_preempted_victim_waits() {
    let w = ModelWeights::random(ModelConfig::tiny(), 3);
    let (kv, cfg) = contended_serve_config(PreemptMode::Spill);
    let want_low = solo_tokens(&w, &kv, &cfg, vec![1, 2, 3]);
    let want_high = solo_tokens(&w, &kv, &cfg, vec![4, 5, 6]);

    let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
    let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
    b.submit(Request::new(1, vec![1, 2, 3], 6)); // priority 0
    b.step();
    b.step();
    b.submit(Request::new(2, vec![4, 5, 6], 6).with_priority(1));
    b.step(); // preempts the low request; its victim now waits to resume
    assert!(b.metrics.report().preemptions >= 1, "setup: preemption must have happened");
    // 30 prompt + 6 new = 36 positions → 9 pages: can never fit the
    // 4-page pool, even empty. Must be rejected immediately — not
    // deferred forever ahead of (or behind) the waiting victim.
    let huge: Vec<usize> = (1..=30).collect();
    b.submit(Request::new(3, huge, 6).with_priority(2));
    let mut out = b.run_to_completion();
    out.sort_by_key(|r| r.id);

    assert_eq!(out.len(), 3);
    assert_eq!(out[0].tokens, want_low, "victim diverged after resume");
    assert_eq!(out[0].finish, FinishReason::Length);
    assert_eq!(out[1].tokens, want_high);
    assert_eq!(out[1].finish, FinishReason::Length);
    assert_eq!(out[2].finish, FinishReason::Rejected, "infeasible request must reject");
    assert!(out[2].tokens.is_empty());
    let report = b.metrics.report();
    assert_eq!(report.resumes, report.preemptions, "the rejection must not strand the victim");
    let kv_stats = report.kv.expect("pool-backed backend");
    assert_eq!(kv_stats.pool.used_pages, 0);
    assert_eq!(kv_stats.pool.free_pages, kv_stats.pool.total_pages);
}

#[test]
fn exhaustion_without_victim_degrades_to_bounded_deferral() {
    let w = ModelWeights::random(ModelConfig::tiny(), 3);
    // Preemption is ON — but the contender has equal priority, so there
    // is never a strictly-lower victim and the only legal behavior is
    // deferral until completion reclaims pages.
    let (kv, cfg) = contended_serve_config(PreemptMode::Spill);
    let want_first = solo_tokens(&w, &kv, &cfg, vec![1, 2, 3]);
    let want_second = solo_tokens(&w, &kv, &cfg, vec![4, 5, 6]);

    let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
    let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
    b.submit(Request::new(1, vec![1, 2, 3], 6));
    b.step();
    b.step();
    b.submit(Request::new(2, vec![4, 5, 6], 6)); // equal priority: no victim
    let mut out = Vec::new();
    let mut steps = 0;
    while !b.is_idle() {
        assert!(steps < 64, "equal-priority contention must drain within a bounded step count");
        b.step();
        out.extend(b.take_finished());
        steps += 1;
    }
    out.sort_by_key(|r| r.id);

    assert_eq!(out.len(), 2);
    assert_eq!(out[0].tokens, want_first, "running request must be undisturbed");
    assert_eq!(out[1].tokens, want_second, "deferred request diverged once admitted");
    let report = b.metrics.report();
    assert_eq!(report.preemptions, 0, "equal priority must never preempt");
    assert!(report.deferred >= 1, "exhaustion without a victim must count deferrals");
    let kv_stats = report.kv.expect("pool-backed backend");
    assert_eq!(kv_stats.pool.used_pages, 0);
    assert_eq!(kv_stats.pool.free_pages, kv_stats.pool.total_pages);
}
