//! Property and integration tests for the `obs::` subsystem: histogram
//! merge/quantile laws and fixed-memory bounds, end-to-end span / phase
//! attribution through a real serving run, scenario determinism, and the
//! BENCH artifact comparator.

use codegemm::config::{ModelConfig, QuantConfig, ServeConfig};
use codegemm::coordinator::{DecodeBackend, MetricsReport, NativeBackend, Server};
use codegemm::model::{EngineKind, ModelWeights};
use codegemm::obs::{compare, drive, generate, BenchArtifact, Histogram, WorkloadMix};
use codegemm::util::prng::Prng;
use codegemm::util::stats;

// ---------------------------------------------------------------- hist laws

#[test]
fn merge_is_associative_and_commutative_across_random_splits() {
    for seed in 0..5u64 {
        let mut rng = Prng::seeded(seed);
        // Random samples over ~7 octaves, split randomly into 3 shards.
        let mut parts = [Histogram::new(), Histogram::new(), Histogram::new()];
        let mut all = Histogram::new();
        for _ in 0..3_000 {
            let x = rng.range_f64(1e-6, 10.0);
            parts[rng.index(3)].record(x);
            all.record(x);
        }
        let [a, b, c] = parts;
        // (a ⊕ b) ⊕ c
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        // a ⊕ (b ⊕ c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        // c ⊕ b ⊕ a (commuted order)
        let mut cba = c.clone();
        cba.merge(&b);
        cba.merge(&a);
        for h in [&ab_c, &a_bc, &cba] {
            assert_eq!(h.count(), all.count());
            assert_eq!(h.sum(), all.sum(), "sum is exact under merge");
            assert_eq!(h.min(), all.min());
            assert_eq!(h.max(), all.max());
            for i in 0..=20 {
                let q = i as f64 / 20.0;
                assert_eq!(
                    h.quantile(q),
                    all.quantile(q),
                    "seed {seed} q {q}: merged shards must equal combined recording"
                );
            }
        }
    }
}

#[test]
fn quantile_error_bounded_vs_exact_on_random_samples() {
    let mut rng = Prng::seeded(11);
    let mut xs: Vec<f64> = (0..10_000).map(|_| rng.range_f64(1e-4, 5.0)).collect();
    let mut h = Histogram::new();
    for &x in &xs {
        h.record(x);
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let tol = Histogram::relative_error_bound() + 0.01; // + rank granularity
    for p in [10.0, 50.0, 90.0, 95.0, 99.0] {
        let exact = stats::percentile(&xs, p);
        let got = h.percentile(p);
        let rel = (got - exact).abs() / exact;
        assert!(rel <= tol, "p{p}: got {got}, exact {exact}, rel err {rel} > {tol}");
    }
    // Moments stay exact regardless of bucketing.
    let mean_exact = stats::mean(&xs);
    assert!((h.mean() - mean_exact).abs() / mean_exact < 1e-12);
}

#[test]
fn histogram_memory_fixed_under_over_a_million_samples() {
    let mut h = Histogram::new();
    let fp0 = h.footprint_bytes();
    let mut rng = Prng::seeded(3);
    for _ in 0..1_200_000 {
        h.record(rng.range_f64(1e-8, 1e3));
    }
    assert_eq!(h.count(), 1_200_000);
    assert_eq!(h.footprint_bytes(), fp0, "1M+ samples must not allocate");
    assert!(fp0 < 16 * 1024, "histogram stays under 16 KiB ({fp0} bytes)");
}

// ------------------------------------------------------- serving integration

fn run_scenario(weights_seed: u64, workload_seed: u64, n: usize) -> (BenchArtifact, MetricsReport) {
    let cfg_model = ModelConfig::tiny();
    let w = ModelWeights::random(cfg_model.clone(), weights_seed);
    let kind = EngineKind::codegemm(QuantConfig::new(4, 1, 8, 32).unwrap());
    let cfg = ServeConfig { max_batch: 4, temperature: 0.0, ..Default::default() };
    let backend = NativeBackend::with_kv_fused(&w, kind, cfg.max_batch, &cfg.kv, true);
    let label = backend.label();
    let server = Server::start(Box::new(backend), cfg);
    let mix = WorkloadMix::by_name("chat").unwrap();
    let trace = generate(&mix, workload_seed, n, cfg_model.vocab);
    let responses = drive(&server, &trace);
    assert_eq!(responses.len(), n);
    let report = server.shutdown();
    let artifact =
        BenchArtifact::from_report("BENCH_T", "chat", workload_seed, n, &label, &report, vec![]);
    (artifact, report)
}

#[test]
fn serving_run_populates_spans_phases_and_reconciles_engine_share() {
    let (artifact, report) = run_scenario(3, 7, 6);
    assert_eq!(report.completed, 6);

    // Spans: one per completed request, with a coherent lifecycle.
    assert_eq!(report.spans.len(), 6);
    assert_eq!(report.spans_total, 6);
    for s in &report.spans {
        assert!(s.prompt_tokens >= 4 && s.prompt_tokens <= 16, "chat-class prompt");
        assert!(s.generated_tokens >= 1);
        assert!(s.ttft_s > 0.0);
        assert!(s.latency_s >= s.ttft_s, "latency contains ttft");
        assert!(s.prefill_chunks >= 1);
        if s.generated_tokens > 1 {
            assert!(s.tpot_s > 0.0, "tpot recorded for multi-token generations");
        }
    }

    // Phase attribution: scheduler, model and engine namespaces all
    // populated by the run, each namespace's shares summing to 1.
    for phase in ["sched/prefill", "sched/decode", "model/gemm", "model/attention", "model/lm_head"]
    {
        assert!(report.phase_seconds(phase) > 0.0, "phase {phase} must be attributed");
    }
    let sched_sum: f64 = ["sched/prefill", "sched/decode", "sched/sample"]
        .iter()
        .map(|p| report.phase_share(p))
        .sum();
    assert!((sched_sum - 1.0).abs() < 1e-9, "sched shares sum to 1, got {sched_sum}");

    // Engine reconciliation: the report's build share is exactly the
    // counters' ops-based share, and the engine/* phase seconds are
    // exactly the counters' build/read seconds split.
    let eng = report.engine.clone().expect("codegemm backend reports engine counters");
    assert!(eng.build_ops > 0 && eng.read_ops > 0);
    assert_eq!(report.build_share_ops(), Some(eng.build_share_ops()));
    let share = report.build_share_ops().unwrap();
    assert!(share > 0.0 && share < 1.0, "build share {share} must be a proper fraction");
    assert!((report.phase_seconds("engine/build") - eng.build_seconds).abs() < 1e-12);
    assert!((report.phase_seconds("engine/gather") - eng.read_seconds).abs() < 1e-12);

    // The rendered report surfaces all of it.
    let rendered = report.render();
    for needle in ["phases:", "spans:", "engine:", "kv pool:", "tpot:"] {
        assert!(rendered.contains(needle), "render missing '{needle}':\n{rendered}");
    }

    // And the artifact carries the same headline data.
    assert_eq!(artifact.completed, 6);
    assert_eq!(artifact.spans.len(), 6);
    assert!(artifact.build_share_ops > 0.0);
    assert!(!artifact.phase_shares.is_empty());
}

#[test]
fn same_seed_scenarios_produce_identical_structural_traces() {
    let (a, ra) = run_scenario(3, 7, 6);
    let (b, rb) = run_scenario(3, 7, 6);
    assert_eq!(
        a.structural_trace(),
        b.structural_trace(),
        "same seed must reproduce the request trace (timing aside)"
    );
    let keys = |r: &MetricsReport| {
        let mut k: Vec<_> = r.spans.iter().map(|s| s.structural_key()).collect();
        k.sort();
        k
    };
    assert_eq!(keys(&ra), keys(&rb));
    // Different workload seed ⇒ different structural trace.
    let (c, _) = run_scenario(3, 8, 6);
    assert_ne!(a.structural_trace(), c.structural_trace());
}

#[test]
fn comparator_flags_injected_decode_regression() {
    let (base, _) = run_scenario(3, 7, 4);
    assert!(base.decode_tok_s > 0.0, "scenario must measure decode throughput");
    let mut cur = base.clone();
    cur.decode_tok_s = base.decode_tok_s * 0.75; // 25% drop > 20% threshold
    let findings = compare(&base, &cur, 0.2);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].contains("decode throughput"));
    // Within threshold ⇒ clean.
    cur.decode_tok_s = base.decode_tok_s * 0.9;
    assert!(compare(&base, &cur, 0.2).is_empty());
    // Artifact JSON roundtrip keeps comparator behavior identical.
    let rt = BenchArtifact::from_json(&base.to_json()).unwrap();
    assert!(compare(&rt, &base, 0.2).is_empty());
}
