//! Offline stand-in for the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! The build environment has no network access, so the real crate cannot
//! be fetched; this vendored shim implements the subset the codebase
//! uses with the same semantics:
//!
//! - [`Error`]: an opaque error value carrying a context chain.
//! - [`Result<T>`]: alias with `Error` as the default error type.
//! - `?` conversion from any `std::error::Error + Send + Sync + 'static`.
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, prepending a message to the chain.
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! - `{e}` displays the outermost message; `{e:#}` displays the whole
//!   chain joined by `": "` (matching anyhow's alternate formatting).

use std::fmt::{self, Debug, Display};

/// An error value: a chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message to the chain.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirror anyhow's Debug: message, then the cause chain.
        match self.chain.split_first() {
            Some((head, rest)) if !rest.is_empty() => {
                write!(f, "{head}\n\nCaused by:")?;
                for (i, c) in rest.iter().enumerate() {
                    write!(f, "\n    {i}: {c}")?;
                }
                Ok(())
            }
            Some((head, _)) => write!(f, "{head}"),
            None => write!(f, "(empty error)"),
        }
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

// Private extension trait so `Context` can be implemented for both
// `Result<T, E: std::error::Error>` and `Result<T, Error>` without
// overlapping impls (the same device the real anyhow uses: `Error` does
// not implement `std::error::Error`, so the blanket impl skips it).
mod ext {
    use super::*;

    pub trait StdError {
        fn ext_context<C: Display>(self, context: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Attach context to errors (on `Result`) or turn `None` into an error
/// (on `Option`).
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError + Send + Sync + 'static,
{
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "file missing");
    }

    #[test]
    fn context_chains_and_alternate_formats() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: file missing");
        let e2 = Err::<(), Error>(e).with_context(|| "loading artifacts").unwrap_err();
        assert_eq!(format!("{e2:#}"), "loading artifacts: reading manifest: file missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(0).unwrap_err().to_string(), "zero is not allowed");
        assert_eq!(f(-3).unwrap_err().to_string(), "negative input -3");
        let e = anyhow!("custom {}", 7);
        assert_eq!(e.to_string(), "custom 7");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
