//! Offline stub of the `xla`/PJRT bindings.
//!
//! The production build links the real XLA extension; this offline image
//! cannot, so the subset of the API the codebase touches is provided
//! here with two behaviours:
//!
//! - **Builder-graph programs work.** `XlaBuilder` records a tiny
//!   expression graph (parameters, `add_`, `sqrt`) and `compile` +
//!   `execute` evaluate it on host arrays — enough for the PJRT
//!   self-test (`codegemm doctor`) to pass end-to-end.
//! - **HLO-text artifacts do not.** `HloModuleProto::from_text_file`
//!   returns a clear "offline stub" error, so the AOT/serve paths fail
//!   loudly (and their tests skip when artifacts are absent).
//!
//! Handles hold `Rc`s like the real bindings, so none of these types are
//! `Send`/`Sync` — the `unsafe impl Send` justifications in
//! `codegemm::runtime` keep the same obligations.

use std::fmt::{self, Display};
use std::rc::Rc;

/// Error type mirroring `xla::Error` (implements `std::error::Error`, so
/// `?` converts into `anyhow::Error`).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// --------------------------------------------------------------- literals

/// Element types a [`Literal`] can hold (exposed only through the
/// `NativeType` conversion trait).
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl Payload {
    fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(v) => v.len(),
        }
    }
}

/// Host-side tensor value (array or tuple), with dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Native element types supported by the stub.
pub trait NativeType: Copy + 'static {
    fn to_payload(data: &[Self]) -> Payload;
    fn from_payload(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn to_payload(data: &[Self]) -> Payload {
        Payload::F32(data.to_vec())
    }
    fn from_payload(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn to_payload(data: &[Self]) -> Payload {
        Payload::I32(data.to_vec())
    }
    fn from_payload(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { payload: T::to_payload(data), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.payload.len() {
            return Err(Error::msg(format!(
                "reshape: {} elements cannot view as {:?}",
                self.payload.len(),
                dims
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// Copy the raw elements into a host slice.
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        let src = T::from_payload(&self.payload)
            .ok_or_else(|| Error::msg("copy_raw_to: element type mismatch"))?;
        if src.len() != dst.len() {
            return Err(Error::msg(format!(
                "copy_raw_to: literal has {} elements, destination {}",
                src.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(&src);
        Ok(())
    }

    /// Clone the elements out as a `Vec`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_payload(&self.payload).ok_or_else(|| Error::msg("to_vec: element type mismatch"))
    }

    /// Destructure a 3-tuple literal.
    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        match self.payload {
            Payload::Tuple(mut v) if v.len() == 3 => {
                let c = v.pop().unwrap();
                let b = v.pop().unwrap();
                let a = v.pop().unwrap();
                Ok((a, b, c))
            }
            _ => Err(Error::msg("to_tuple3: literal is not a 3-tuple")),
        }
    }

    /// Build a tuple literal (used by tests).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        let n = elems.len() as i64;
        Literal { payload: Payload::Tuple(elems), dims: vec![n] }
    }

    pub fn element_count(&self) -> usize {
        self.payload.len()
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ---------------------------------------------------------------- builder

/// Array shape (element type is tracked only at construction).
#[derive(Clone, Debug)]
pub struct Shape {
    dims: Vec<i64>,
}

impl Shape {
    pub fn array<T: NativeType>(dims: Vec<i64>) -> Shape {
        Shape { dims }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[derive(Debug)]
enum Node {
    Parameter(usize),
    Add(Rc<Node>, Rc<Node>),
    Sqrt(Rc<Node>),
}

fn eval(node: &Node, args: &[&Literal]) -> Result<Vec<f32>> {
    match node {
        Node::Parameter(i) => args
            .get(*i)
            .ok_or_else(|| Error::msg(format!("missing argument {i}")))?
            .to_vec::<f32>(),
        Node::Add(a, b) => {
            let (va, vb) = (eval(a, args)?, eval(b, args)?);
            if va.len() != vb.len() {
                return Err(Error::msg("add: shape mismatch"));
            }
            Ok(va.iter().zip(&vb).map(|(x, y)| x + y).collect())
        }
        Node::Sqrt(a) => Ok(eval(a, args)?.into_iter().map(f32::sqrt).collect()),
    }
}

/// Records a small expression graph.
pub struct XlaBuilder {
    _name: String,
}

impl XlaBuilder {
    pub fn new(name: &str) -> XlaBuilder {
        XlaBuilder { _name: name.to_string() }
    }

    pub fn parameter_s(&self, index: i64, _shape: &Shape, _name: &str) -> Result<XlaOp> {
        Ok(XlaOp { node: Rc::new(Node::Parameter(index as usize)) })
    }
}

/// A node in the builder graph.
#[derive(Clone)]
pub struct XlaOp {
    node: Rc<Node>,
}

impl XlaOp {
    pub fn add_(&self, rhs: &XlaOp) -> Result<XlaOp> {
        Ok(XlaOp { node: Rc::new(Node::Add(self.node.clone(), rhs.node.clone())) })
    }

    pub fn sqrt(&self) -> Result<XlaOp> {
        Ok(XlaOp { node: Rc::new(Node::Sqrt(self.node.clone())) })
    }

    pub fn build(&self) -> Result<XlaComputation> {
        Ok(XlaComputation { root: Some(self.node.clone()) })
    }
}

/// A computation: either a builder graph (executable by the stub) or an
/// HLO proto (never constructible offline).
pub struct XlaComputation {
    root: Option<Rc<Node>>,
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { root: None }
    }
}

/// Parsed HLO module. The offline stub cannot parse HLO text, so the only
/// constructor always errors (callers attach the artifact path as
/// context, producing an actionable message).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::msg(
            "offline xla stub cannot parse HLO text (the real XLA extension is not linked)",
        ))
    }
}

// ------------------------------------------------------------------- PJRT

/// Stand-in PJRT client. Holds an `Rc` so the type is intentionally not
/// `Send`/`Sync`, matching the real bindings.
pub struct PjRtClient {
    _marker: Rc<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _marker: Rc::new(()) })
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match &comp.root {
            Some(root) => {
                Ok(PjRtLoadedExecutable { root: root.clone(), _marker: self._marker.clone() })
            }
            None => Err(Error::msg(
                "offline xla stub cannot compile HLO protos (the real XLA extension is not linked)",
            )),
        }
    }
}

/// Borrow-a-literal bound for `execute`'s generic argument (the real API
/// accepts both `Literal` and `&Literal` argument slices).
pub trait BorrowLiteral {
    fn borrow_literal(&self) -> &Literal;
}

impl BorrowLiteral for Literal {
    fn borrow_literal(&self) -> &Literal {
        self
    }
}

impl<'a> BorrowLiteral for &'a Literal {
    fn borrow_literal(&self) -> &Literal {
        *self
    }
}

/// A compiled executable (builder graphs only, in the stub).
pub struct PjRtLoadedExecutable {
    root: Rc<Node>,
    _marker: Rc<()>,
}

impl PjRtLoadedExecutable {
    /// Execute with one argument list; returns per-device, per-output
    /// buffers like the real API (`[0][0]` is the first output).
    pub fn execute<T: BorrowLiteral>(&self, args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let refs: Vec<&Literal> = args.iter().map(|a| a.borrow_literal()).collect();
        let out = eval(&self.root, &refs)?;
        let n = out.len() as i64;
        let lit = Literal { payload: Payload::F32(out), dims: vec![n] };
        Ok(vec![vec![PjRtBuffer { lit }]])
    }
}

/// Device buffer holding a result.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_graph_executes() {
        let client = PjRtClient::cpu().unwrap();
        let b = XlaBuilder::new("t");
        let x = b.parameter_s(0, &Shape::array::<f32>(vec![4]), "x").unwrap();
        let y = x.add_(&x).unwrap().sqrt().unwrap();
        let exe = client.compile(&y.build().unwrap()).unwrap();
        let input = Literal::vec1(&[2f32, 8.0, 18.0, 32.0]);
        let out = exe.execute::<Literal>(&[input]).unwrap()[0][0].to_literal_sync().unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![2f32, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn execute_accepts_literal_refs() {
        let client = PjRtClient::cpu().unwrap();
        let b = XlaBuilder::new("t");
        let x = b.parameter_s(0, &Shape::array::<f32>(vec![2]), "x").unwrap();
        let exe = client.compile(&x.build().unwrap()).unwrap();
        let input = Literal::vec1(&[1f32, 2.0]);
        let args: Vec<&Literal> = vec![&input];
        let out = exe.execute::<&Literal>(&args).unwrap()[0][0].to_literal_sync().unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![1f32, 2.0]);
    }

    #[test]
    fn hlo_text_errors_clearly() {
        let e = HloModuleProto::from_text_file("/tmp/x.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("offline xla stub"));
    }

    #[test]
    fn literal_roundtrips() {
        let l = Literal::vec1(&[1i32, 2, 3, 4]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        let mut dst = [0i32; 4];
        l.copy_raw_to(&mut dst).unwrap();
        assert_eq!(dst, [1, 2, 3, 4]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn tuple3_destructures() {
        let t = Literal::tuple(vec![
            Literal::vec1(&[1f32]),
            Literal::vec1(&[2f32]),
            Literal::vec1(&[3f32]),
        ]);
        let (a, b, c) = t.to_tuple3().unwrap();
        assert_eq!(a.to_vec::<f32>().unwrap(), vec![1.0]);
        assert_eq!(b.to_vec::<f32>().unwrap(), vec![2.0]);
        assert_eq!(c.to_vec::<f32>().unwrap(), vec![3.0]);
        assert!(Literal::vec1(&[1f32]).to_tuple3().is_err());
    }
}
