//! Bench: paper Table 6 — Psumbook build vs read split. Reports both the
//! op-count split (the quantity the paper profiles per SM) and the
//! measured CPU wall-clock split from the engine's phase timers.
use codegemm::bench::tables;
use codegemm::config::{KernelConfig, QuantConfig};
use codegemm::gemm::{CodeGemmEngine, GemmEngine};
use codegemm::quant::Quantizer;
use codegemm::util::prng::Prng;

fn main() {
    println!("{}", tables::table6());
    // Wall-clock split on one representative shape.
    let (n, k) = (1024, 1024);
    for label in ["m2v8g128", "m1v4g128"] {
        let cfg = QuantConfig::parse_label(label).unwrap();
        let w = Prng::seeded(1).normal_vec(n * k, 0.02);
        let q = Quantizer::new(cfg).quantize(&w, n, k);
        let mut e = CodeGemmEngine::with_kernel(&q, KernelConfig::new(32, 1024).unwrap());
        let x = Prng::seeded(2).normal_vec(k, 1.0);
        for _ in 0..20 {
            let _ = e.gemv(&x);
        }
        let c = e.counters();
        println!(
            "{label} {n}x{k}: build/read = {:.1}%/{:.1}% by ops, {:.1}%/{:.1}% by CPU time",
            100.0 * c.build_share_ops(),
            100.0 * (1.0 - c.build_share_ops()),
            100.0 * c.build_share_time(),
            100.0 * (1.0 - c.build_share_time()),
        );
    }
}
