//! Bench: thread-scaling of the sharded engines (`codegemm::parallel`)
//! on the paper's Llama-3 8B and 70B decoder-block layer shapes, plus the
//! batch-scaling (`M`) sweep that makes the paper's build-amortization
//! curve (Eq. 3) directly measurable.
//!
//! Every matrix runs through one shared driver ([`Matrix`] +
//! [`bench_gemm_into`]): a titled block, a column header, aligned rows
//! (each through the zero-allocation `gemm_into` path where a GEMM is
//! being measured), and one trailing acceptance line — PASS/FAIL when
//! rows carry exact checks, advisory prose otherwise.
//!
//! Matrix 1 (threads): {1, 2, 4, 8} × engines {codegemm, dequant,
//! lutgemm, dense} × {q_proj, gate_proj, down_proj} of each geometry,
//! M = 1 (the decode hot case). Matrix 2 (batch): `M ∈ {1, 4, 16, 64}` —
//! per-token latency should fall as M grows because the per-tile
//! Psumbook build is shared by the whole batch. Shapes are scaled down
//! by `CODEGEMM_SCALING_SCALE` (default 4; aspect ratios preserved) so
//! the quantization setup stays CPU-tractable; the sharding overhead
//! being measured is per-call and does not depend on the scale.
//!
//! Matrix 3 (shared vs private Psumbook): threads × `M ∈ {1, 4, 16,
//! 64}` × 8B/70B q_proj, CodeGEMM sharded with per-shard *private*
//! books vs the build-once/gather-many *shared* book. Reported per row:
//! mean latency and the exact `build_share_ops` fraction — the shared
//! schedule's build share must be ≤ the private one at every measured
//! point (build MACs are attributed once per logical call instead of
//! once per shard).
//!
//! Matrix 4 (paged attention) and matrix 5 (fused projection groups)
//! are documented at their sections below. Matrix 6 (scalar vs SIMD):
//! the serial engine with the kernel dispatch pinned to the scalar
//! reference vs the resolved SIMD path (`KernelImpl::Auto`) on the 8B
//! q_proj shape — the SIMD row must beat the scalar row at M = 1.
//! Matrix 7 (tile_h sweep): serial row-block heights {256 .. 8192} on
//! the 8B q_proj shape with a pipelined 4-thread reference row — the
//! default tile_h must stay within 1.25x of the best swept point.
//! Matrix 8 (KV page dtype): attention latency and held pool bytes over
//! dtype {f32, f16, int8} × ctx {512, 2048} × {decode, batched prefill},
//! gated on the batched chunk resolving each K/V tile exactly twice
//! (counter-pinned) and int8 pool bytes ≤ 0.3× the f32 row.

use codegemm::bench::harness::{black_box, run_bench, BenchOptions, BenchResult};
use codegemm::bench::workloads::{scaled_block_shapes, GemmShape, LLAMA3_70B, LLAMA3_8B};
use codegemm::config::{KernelConfig, KernelImpl, QuantConfig};
use codegemm::gemm::{
    CodeGemmEngine, DenseEngine, DequantEngine, EngineScratch, GemmEngine, GemmGroup, GroupMember,
    LutGemmEngine,
};
use codegemm::kvcache::{BlockPool, KvDtype, KvLayout, KvStore, PagedKv, SeqKv};
use codegemm::model::{attend, attend_batch, AttnScratch, AttnShape, KvCache};
use codegemm::parallel::{shard, ShardPlan, ShardedEngine};
use codegemm::quant::bcq::BcqLinear;
use codegemm::quant::{QuantizedLinear, Quantizer};
use codegemm::util::prng::Prng;
use codegemm::util::threadpool::ThreadPool;
use std::sync::Arc;

const THREADS: [usize; 4] = [1, 2, 4, 8];
const ENGINES: [&str; 4] = ["codegemm", "dequant", "lutgemm", "dense"];
/// Batch sizes for the prefill-amortization sweep (engine cap is 64).
const M_SWEEP: [usize; 4] = [1, 4, 16, 64];

fn scale_from_env() -> usize {
    std::env::var("CODEGEMM_SCALING_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if std::env::var("CODEGEMM_BENCH_QUICK").is_ok() { 16 } else { 4 })
}

/// Shared frame for the report matrices: prints the titled block and the
/// column header on `begin`, accumulates row-level checks, and prints
/// the one acceptance line every matrix ends with.
struct Matrix {
    ok: bool,
}

impl Matrix {
    fn begin(title: &str, columns: String) -> Matrix {
        println!("\n# {title}");
        println!("{columns}");
        Matrix { ok: true }
    }

    /// Record one row-level check; returns the row's check cell.
    fn check(&mut self, pass: bool) -> &'static str {
        if pass {
            "ok"
        } else {
            self.ok = false;
            "FAIL"
        }
    }

    /// Gated acceptance line from the accumulated row checks.
    fn finish(self, pass: &str, fail: &str) {
        println!(
            "# acceptance: {}",
            if self.ok { format!("PASS — {pass}") } else { format!("FAIL — {fail}") }
        );
    }

    /// Advisory acceptance line (matrix carries no exact row checks).
    fn finish_advisory(self, note: &str) {
        println!("# acceptance: {note}");
    }
}

/// Bench one zero-allocation `gemm_into` point: the one GEMM measurement
/// every matrix shares (warm caller scratch, caller-owned output).
fn bench_gemm_into(
    name: &str,
    opts: BenchOptions,
    eng: &(dyn GemmEngine + Send + Sync),
    x: &[f32],
    mb: usize,
    y: &mut [f32],
    scratch: &mut EngineScratch,
) -> BenchResult {
    run_bench(name, opts, || {
        eng.gemm_into(x, mb, y, scratch);
        black_box(&*y);
    })
}

/// Pre-quantized state shared across thread counts for one shape.
struct Prepared {
    w: Vec<f32>,
    q: QuantizedLinear,
    shape: GemmShape,
}

impl Prepared {
    fn new(shape: GemmShape, cfg: QuantConfig) -> Prepared {
        let (n, k) = (shape.n, shape.k);
        let w = Prng::seeded(11).normal_vec(n * k, 0.02);
        let q = Quantizer::new(cfg).with_refinement(0).quantize(&w, n, k);
        Prepared { w, q, shape }
    }

    /// Row-sharded engine of the named kind across `t` workers.
    fn engine(&self, kind: &str, t: usize, pool: Arc<ThreadPool>) -> Box<dyn GemmEngine + Send + Sync> {
        let (n, k) = (self.shape.n, self.shape.k);
        let plan = ShardPlan::new(n, t, 1, 1);
        match kind {
            "codegemm" => Box::new(ShardedEngine::from_factory(plan, pool, |(r0, r1)| {
                CodeGemmEngine::from_quantized(&shard::slice_rows(&self.q, r0, r1))
            })),
            "dequant" => Box::new(ShardedEngine::from_factory(plan, pool, |(r0, r1)| {
                DequantEngine::from_quantized(&shard::slice_rows(&self.q, r0, r1))
            })),
            // BCQ quantization is per-row: quantizing each row slice is
            // identical to slicing a full quantization.
            "lutgemm" => Box::new(ShardedEngine::from_factory(plan, pool, |(r0, r1)| {
                let ws = shard::dense_rows(&self.w, k, r0, r1);
                let bcq = BcqLinear::quantize(&ws, r1 - r0, k, 3, 128).expect("bcq");
                LutGemmEngine::new(bcq)
            })),
            "dense" => Box::new(ShardedEngine::from_factory(plan, pool, |(r0, r1)| {
                DenseEngine::new(shard::dense_rows(&self.w, k, r0, r1), r1 - r0, k)
            })),
            other => panic!("unknown engine kind {other}"),
        }
    }
}

fn main() {
    let opts = BenchOptions::from_env();
    let scale = scale_from_env();
    let cfg = QuantConfig::m1v4g128();

    // ---- matrix 1: thread scaling, decode (M = 1) ----
    let mx = Matrix::begin(
        &format!(
            "sharded decode (M=1) scaling (shapes /{scale}, quant {}, host cores {})",
            cfg.label(),
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        ),
        format!("{:<34} {:>9} {:>12} {:>9}", "engine / shape", "threads", "mean us", "speedup"),
    );
    for geom in [&LLAMA3_8B, &LLAMA3_70B] {
        let shapes: Vec<_> = scaled_block_shapes(geom, 1, scale)
            .into_iter()
            .filter(|(l, _)| matches!(*l, "q_proj" | "gate_proj" | "down_proj"))
            .collect();
        for (label, s) in shapes {
            let prep = Prepared::new(s, cfg);
            for kind in ENGINES {
                let mut base_us = 0.0f64;
                for t in THREADS {
                    let pool = Arc::new(ThreadPool::new(t));
                    let eng = prep.engine(kind, t, pool);
                    let x = Prng::seeded(12).normal_vec(s.k, 1.0);
                    let mut y = vec![0f32; s.n];
                    let mut scratch = EngineScratch::new();
                    let name = format!("{}-{kind} {label} {}x{}", geom.name, s.n, s.k);
                    let r = bench_gemm_into(&name, opts, &*eng, &x, 1, &mut y, &mut scratch);
                    let mean = r.mean_us();
                    if t == 1 {
                        base_us = mean;
                    }
                    let speedup = if mean > 0.0 { base_us / mean } else { 0.0 };
                    println!("{:<34} {:>9} {:>12.1} {:>8.2}x", name, t, mean, speedup);
                }
            }
        }
    }
    mx.finish_advisory(
        "codegemm q_proj/gate_proj decode at 4 threads should be >= 2x the 1-thread row",
    );

    // ---- matrix 2: batch (M) sweep — build amortization across prefill ----
    let mx = Matrix::begin(
        "batched prefill amortization (zero-allocation gemm_into, single thread): \
         per-token latency should fall with M as the Psumbook build is shared",
        format!(
            "{:<34} {:>9} {:>12} {:>14} {:>9}",
            "engine / shape", "M", "mean us", "us per token", "vs M=1"
        ),
    );
    for geom in [&LLAMA3_8B] {
        let shapes: Vec<_> = scaled_block_shapes(geom, 1, scale)
            .into_iter()
            .filter(|(l, _)| matches!(*l, "q_proj" | "down_proj"))
            .collect();
        for (label, s) in shapes {
            let prep = Prepared::new(s, cfg);
            for kind in ["codegemm", "dequant"] {
                let eng: Box<dyn GemmEngine + Send + Sync> = match kind {
                    "codegemm" => Box::new(CodeGemmEngine::from_quantized(&prep.q)),
                    _ => Box::new(DequantEngine::from_quantized(&prep.q)),
                };
                let mut scratch = EngineScratch::new();
                let mut base_per_tok = 0.0f64;
                for mb in M_SWEEP {
                    let x = Prng::seeded(13).normal_vec(s.k * mb, 1.0);
                    let mut y = vec![0f32; s.n * mb];
                    let name = format!("{}-{kind} {label} {}x{} M{mb}", geom.name, s.n, s.k);
                    let r = bench_gemm_into(&name, opts, &*eng, &x, mb, &mut y, &mut scratch);
                    let per_tok = r.mean_us() / mb as f64;
                    if mb == 1 {
                        base_per_tok = per_tok;
                    }
                    let speedup = if per_tok > 0.0 { base_per_tok / per_tok } else { 0.0 };
                    println!(
                        "{:<34} {:>9} {:>12.1} {:>14.2} {:>8.2}x",
                        name,
                        mb,
                        r.mean_us(),
                        per_tok,
                        speedup
                    );
                }
            }
        }
    }
    mx.finish_advisory(
        "codegemm per-token latency at M=16/64 should undercut its M=1 row \
         (Eq. 3 build amortization)",
    );

    // ---- matrix 3: shared vs private Psumbook — build-share sweep ----
    let mut mx = Matrix::begin(
        "shared vs private Psumbook (build once / gather many): one book per k-tile \
         gathered by all row shards vs per-shard private books",
        format!(
            "{:<44} {:>7} {:>4} {:>8} {:>12} {:>14} {:>12} {:>6}",
            "shape", "threads", "M", "variant", "mean us", "b-MACs/call", "build share", "check"
        ),
    );
    for geom in [&LLAMA3_8B, &LLAMA3_70B] {
        let shapes: Vec<_> = scaled_block_shapes(geom, 1, scale)
            .into_iter()
            .filter(|(l, _)| matches!(*l, "q_proj"))
            .collect();
        for (label, s) in shapes {
            let prep = Prepared::new(s, cfg);
            let codes = prep.q.codes.unpack(); // once, not per shard/variant
            for t in THREADS {
                for mb in M_SWEEP {
                    let x = Prng::seeded(15).normal_vec(s.k * mb, 1.0);
                    let mut share = [0f64; 2];
                    for (vi, shared) in [false, true].into_iter().enumerate() {
                        let pool = Arc::new(ThreadPool::new(t));
                        let plan = ShardPlan::new(s.n, t, 1, 1);
                        let eng = ShardedEngine::from_factory(plan, pool, |(r0, r1)| {
                            CodeGemmEngine::from_quantized(&shard::slice_rows_unpacked(
                                &prep.q, &codes, r0, r1,
                            ))
                        })
                        .with_shared_book(shared);
                        let mut scratch = EngineScratch::new();
                        let mut y = vec![0f32; s.n * mb];
                        let variant = if shared { "shared" } else { "private" };
                        let name = format!(
                            "{}-codegemm {label} {}x{} t{t} M{mb} {variant}",
                            geom.name, s.n, s.k
                        );
                        let r = bench_gemm_into(&name, opts, &eng, &x, mb, &mut y, &mut scratch);
                        // Counts are exact and identical every call, so the
                        // share is invariant to the bench iteration count.
                        share[vi] = scratch.counters.build_share_ops();
                        let check =
                            if vi == 0 { "" } else { mx.check(share[1] <= share[0] + 1e-12) };
                        println!(
                            "{:<44} {:>7} {:>4} {:>8} {:>12.1} {:>14.0} {:>12.4} {:>6}",
                            format!("{}-{label} {}x{}", geom.name, s.n, s.k),
                            t,
                            mb,
                            variant,
                            r.mean_us(),
                            scratch.counters.build_ops_per_call(),
                            share[vi],
                            check
                        );
                    }
                }
            }
        }
    }
    mx.finish(
        "shared-book build share <= private-book build share at every (threads, M) point",
        "shared-book build share exceeded the private-book share somewhere above",
    );

    // ---- matrix 4: chunked attention over the paged KV pool ----
    // Context length × page size × {decode, prefill-tail} on an
    // 8B-class GQA head group (8 query heads over 2 KV heads, head_dim
    // 32). "flat" rows run the same kernel over a contiguous cache (one
    // whole-cache tile) as the layout-free baseline; "pool KiB" is the
    // sequence's held page bytes — the capacity the pool actually binds,
    // vs the flat cache's fixed max_seq allocation.
    let mx = Matrix::begin(
        "paged attention: latency & pool bytes over context x page size \
         (decode = 1 query over full context; prefill = 16-token causal tail)",
        format!(
            "{:<40} {:>6} {:>6} {:>9} {:>12} {:>10}",
            "kernel / shape", "ctx", "page", "phase", "mean us", "pool KiB"
        ),
    );
    let shape = AttnShape { n_heads: 8, n_kv_heads: 2, head_dim: 32 };
    let kv_dim = shape.kv_dim();
    let attn_scale = 1.0 / (shape.head_dim as f32).sqrt();
    const PREFILL_TAIL: usize = 16;
    for ctx in [128usize, 512, 2048] {
        // page 0 encodes the contiguous ("flat") baseline.
        for page in [0usize, 16, 64, 256] {
            let mut flat = KvCache::new(1, ctx, kv_dim);
            let layout = KvLayout {
                n_layers: 1,
                kv_dim,
                page_size: page.max(1),
                max_seq: ctx,
                dtype: KvDtype::F32,
            };
            // The flat baseline never touches the pool — keep its arena
            // at a single page instead of ctx pages of dead weight.
            let pool_pages = if page == 0 { 1 } else { layout.max_pages_per_seq() };
            let mut pool = BlockPool::new(layout, pool_pages);
            let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
            let mut paged = PagedKv::bind(&mut pool, &mut seq);
            let mut rng = Prng::seeded(21);
            for pos in 0..ctx {
                let k = rng.normal_vec(kv_dim, 1.0);
                let v = rng.normal_vec(kv_dim, 1.0);
                if page == 0 {
                    flat.write(0, pos, &k, &v);
                } else {
                    paged.write(0, pos, &k, &v);
                }
            }
            let q = rng.normal_vec(shape.n_heads * shape.head_dim, 1.0);
            let mut scratch = AttnScratch::new();
            let mut scores = vec![0f32; shape.scores_len(ctx)];
            let mut out = vec![0f32; q.len()];
            let variant = if page == 0 { "flat".to_string() } else { format!("{page}") };
            let held_kib = if page == 0 { flat.bytes() } else { paged.bytes() } / 1024;
            for phase in ["decode", "prefill"] {
                let name = format!("attn h{}kv{} ctx{ctx} page {variant}", shape.n_heads, shape.n_kv_heads);
                let r = run_bench(&format!("{name} {phase}"), opts, || {
                    if phase == "decode" {
                        if page == 0 {
                            attend(&flat, 0, &shape, &q, ctx, attn_scale, &mut scratch, &mut scores, &mut out);
                        } else {
                            attend(&paged, 0, &shape, &q, ctx, attn_scale, &mut scratch, &mut scores, &mut out);
                        }
                    } else {
                        // Causal tail: the last PREFILL_TAIL positions of a
                        // prompt of length ctx, each attending to its prefix.
                        for b in 0..PREFILL_TAIL {
                            let upto = ctx - PREFILL_TAIL + 1 + b;
                            if page == 0 {
                                attend(&flat, 0, &shape, &q, upto, attn_scale, &mut scratch, &mut scores, &mut out);
                            } else {
                                attend(&paged, 0, &shape, &q, upto, attn_scale, &mut scratch, &mut scores, &mut out);
                            }
                        }
                    }
                    black_box(&out);
                });
                println!(
                    "{:<40} {:>6} {:>6} {:>9} {:>12.1} {:>10}",
                    name, ctx, variant, phase, r.mean_us(), held_kib
                );
            }
        }
    }
    mx.finish_advisory(
        "per-page latency should track the flat baseline closely at every \
         context (tiling overhead is bookkeeping only), while pool KiB for short contexts \
         stays proportional to ctx rather than max_seq",
    );

    // ---- matrix 5: fused projection groups (build once, gather Q/K/V) ----
    // Fused vs unfused over threads × M × the 8B/70B attention (Q/K/V)
    // and MLP (gate/up) sets, all members sliced from one joint
    // quantization (exactly what `EngineKind::build_projection_set`
    // loads). "b/r" is the iteration-invariant build-to-read op ratio;
    // "factor" (on the fused row) is unfused-b/r over fused-b/r — the
    // per-layer build-MAC drop, which must reach the member count at
    // every point (3× for Q/K/V, 2× for gate/up; more at t=1 where the
    // unfused serial engines also re-build per row block).
    let mut mx = Matrix::begin(
        "fused projection groups: one Psumbook build per k-tile shared by Q/K/V \
         (resp. gate/up) vs one build per projection",
        format!(
            "{:<46} {:>7} {:>4} {:>9} {:>12} {:>10} {:>12} {:>7} {:>6}",
            "group / shape", "threads", "M", "variant", "mean us", "b/r", "build share", "factor", "check"
        ),
    );
    for geom in [&LLAMA3_8B, &LLAMA3_70B] {
        let shapes = scaled_block_shapes(geom, 1, scale);
        let pick = |label: &str| shapes.iter().find(|(l, _)| *l == label).expect("shape").1;
        for (set_label, member_shapes) in [
            ("qkv", vec![pick("q_proj"), pick("k_proj"), pick("v_proj")]),
            ("gate_up", vec![pick("gate_proj"), pick("up_proj")]),
        ] {
            let n_members = member_shapes.len();
            let k = member_shapes[0].k;
            let n_total: usize = member_shapes.iter().map(|s| s.n).sum();
            let w = Prng::seeded(17).normal_vec(n_total * k, 0.02);
            let q = Quantizer::new(cfg).with_refinement(0).quantize(&w, n_total, k);
            let codes = q.codes.unpack(); // once per set
            let mut ranges = Vec::with_capacity(n_members);
            let mut r0 = 0usize;
            for s in &member_shapes {
                ranges.push((r0, r0 + s.n));
                r0 += s.n;
            }
            for t in THREADS {
                for mb in M_SWEEP {
                    let x = Prng::seeded(18).normal_vec(k * mb, 1.0);
                    let mut build_read = [0f64; 2];
                    let mut share = [0f64; 2];
                    for (vi, fused) in [false, true].into_iter().enumerate() {
                        let pool = if t > 1 { Some(Arc::new(ThreadPool::new(t))) } else { None };
                        let members: Vec<GroupMember> = ranges
                            .iter()
                            .map(|&(a, b)| {
                                let mq = shard::slice_rows_unpacked(&q, &codes, a, b);
                                if t > 1 {
                                    let plan = ShardPlan::new(b - a, t, 1, 1);
                                    let mcodes = mq.codes.unpack();
                                    let shards = plan
                                        .shards
                                        .iter()
                                        .map(|&(s0, s1)| {
                                            CodeGemmEngine::from_quantized(
                                                &shard::slice_rows_unpacked(&mq, &mcodes, s0, s1),
                                            )
                                        })
                                        .collect();
                                    GroupMember::sharded(plan, shards)
                                } else {
                                    GroupMember::serial(CodeGemmEngine::from_quantized(&mq))
                                }
                            })
                            .collect();
                        let group = GemmGroup::new(members, pool).with_fused(fused);
                        let mut outs: Vec<Vec<f32>> =
                            member_shapes.iter().map(|s| vec![0f32; s.n * mb]).collect();
                        let mut scratch = EngineScratch::new();
                        let variant = if fused { "fused" } else { "unfused" };
                        let name = format!(
                            "{}-{set_label} {}x{k} t{t} M{mb} {variant}",
                            geom.name, n_total
                        );
                        let r = run_bench(&name, opts, || {
                            {
                                let mut views: Vec<&mut [f32]> =
                                    outs.iter_mut().map(|y| y.as_mut_slice()).collect();
                                group.gemm_group_into(&x, mb, &mut views, &mut scratch);
                            }
                            black_box(&outs);
                        });
                        // Exact counts scale uniformly with iterations, so
                        // these ratios are iteration-invariant.
                        build_read[vi] = scratch.counters.build_ops as f64
                            / scratch.counters.read_ops.max(1) as f64;
                        share[vi] = scratch.counters.build_share_ops();
                        let (factor_s, check) = if vi == 0 {
                            (String::new(), "")
                        } else {
                            let factor = build_read[0] / build_read[1];
                            let ok = share[1] <= share[0] + 1e-12
                                && factor >= n_members as f64 * 0.999;
                            (format!("{factor:.2}x"), mx.check(ok))
                        };
                        println!(
                            "{:<46} {:>7} {:>4} {:>9} {:>12.1} {:>10.4} {:>12.4} {:>7} {:>6}",
                            format!("{}-{set_label} {}x{}", geom.name, n_total, k),
                            t,
                            mb,
                            variant,
                            r.mean_us(),
                            build_read[vi],
                            share[vi],
                            factor_s,
                            check
                        );
                    }
                }
            }
        }
    }
    mx.finish(
        "fused build share <= unfused at every point, and the M-invariant \
         build-MAC factor reaches the member count (3x qkv / 2x gate-up)",
        "a fused point fell short of the group amortization factor above",
    );

    // ---- matrix 6: scalar vs SIMD gather/build kernels ----
    // Serial engine, same tiling, only the kernel dispatch differs: the
    // pinned scalar reference vs whatever `KernelImpl::Auto` resolves to
    // on this host (AVX2 when available, else the unrolled lane
    // kernels). Outputs are bit-identical (the SIMD property suite pins
    // this); here only the latency is at stake. The check gates on the
    // decode row (M = 1), where the gather is the whole call. When
    // `CODEGEMM_KERNEL` pins both variants to one impl the comparison is
    // vacuous and the row is marked "-".
    let mut mx = Matrix::begin(
        "scalar vs SIMD gather/build kernels (serial engine, 8B q_proj): \
         the resolved SIMD path must beat the scalar reference at M=1",
        format!(
            "{:<40} {:>4} {:>12} {:>12} {:>10} {:>6}",
            "kernel / shape", "M", "resolved", "mean us", "vs scalar", "check"
        ),
    );
    {
        let shapes: Vec<_> = scaled_block_shapes(&LLAMA3_8B, 1, scale)
            .into_iter()
            .filter(|(l, _)| matches!(*l, "q_proj"))
            .collect();
        let scalar_kc = KernelConfig {
            kernel_impl: KernelImpl::Scalar,
            simd_lanes: 1,
            ..KernelConfig::default()
        };
        let simd_kc = KernelConfig::default(); // Auto: AVX2 if detected, else unrolled
        for (label, s) in shapes {
            let prep = Prepared::new(s, cfg);
            for mb in [1usize, 4, 16] {
                let x = Prng::seeded(19).normal_vec(s.k * mb, 1.0);
                let mut scalar_us = 0.0f64;
                let mut scalar_sel = None;
                for (vi, kc) in [scalar_kc, simd_kc].into_iter().enumerate() {
                    let eng = CodeGemmEngine::with_kernel(&prep.q, kc);
                    let sel = eng.kernel_sel();
                    let mut y = vec![0f32; s.n * mb];
                    let mut scratch = EngineScratch::new();
                    let name = format!(
                        "{}-{label} {}x{} M{mb} {}",
                        LLAMA3_8B.name,
                        s.n,
                        s.k,
                        if vi == 0 { "scalar" } else { "simd" }
                    );
                    let r = bench_gemm_into(&name, opts, &eng, &x, mb, &mut y, &mut scratch);
                    let mean = r.mean_us();
                    let (speed_s, check) = if vi == 0 {
                        scalar_us = mean;
                        scalar_sel = Some(sel);
                        (String::new(), "")
                    } else if scalar_sel == Some(sel) {
                        // Env override pinned both variants to one impl.
                        (String::from("1.00x"), "-")
                    } else {
                        let speed = if mean > 0.0 { scalar_us / mean } else { 0.0 };
                        let cell = if mb == 1 { mx.check(mean <= scalar_us) } else { "" };
                        (format!("{speed:.2}x"), cell)
                    };
                    println!(
                        "{:<40} {:>4} {:>12} {:>12.1} {:>10} {:>6}",
                        name,
                        mb,
                        format!("{}/{}", sel.label(), sel.lanes),
                        mean,
                        speed_s,
                        check
                    );
                }
            }
        }
    }
    mx.finish(
        "SIMD decode (M=1) beat the scalar reference on the 8B q_proj shape",
        "SIMD decode (M=1) did not beat the scalar reference above",
    );

    // ---- matrix 7: tile_h sweep under the pipelined schedule ----
    // tile_h is the serial engine's row-block height: each block re-walks
    // every k-tile (build + gather), so too-small blocks rebuild the
    // Psumbook too often while too-large ones outgrow the gather's cache
    // reuse window. The sweep pins where the default sits on this host; a
    // 4-thread pipelined shared-book row rides along as the reference
    // point the profiler's overlap gauges describe (tile_h does not bind
    // there — row shards partition n instead). The check gates on the
    // decode row: the default tile_h must stay within 1.25x of the best
    // swept serial point.
    let mut mx = Matrix::begin(
        "tile_h sweep (serial row blocks, 8B q_proj, M=1): default must stay \
         within 1.25x of the best swept point; pipelined 4-thread reference row",
        format!(
            "{:<40} {:>8} {:>12} {:>10} {:>6}",
            "variant / shape", "tile_h", "mean us", "vs best", "check"
        ),
    );
    {
        let shapes: Vec<_> = scaled_block_shapes(&LLAMA3_8B, 1, scale)
            .into_iter()
            .filter(|(l, _)| matches!(*l, "q_proj"))
            .collect();
        const TILE_H: [usize; 5] = [256, 1024, 2048, 4096, 8192];
        let default_tile_h = KernelConfig::default().tile_h;
        for (label, s) in shapes {
            let prep = Prepared::new(s, cfg);
            let x = Prng::seeded(23).normal_vec(s.k, 1.0);
            let mut means = Vec::with_capacity(TILE_H.len());
            for th in TILE_H {
                let kc = KernelConfig { tile_h: th, ..KernelConfig::default() };
                let eng = CodeGemmEngine::with_kernel(&prep.q, kc);
                let mut y = vec![0f32; s.n];
                let mut scratch = EngineScratch::new();
                let name = format!("{}-serial {label} {}x{} h{th}", LLAMA3_8B.name, s.n, s.k);
                let r = bench_gemm_into(&name, opts, &eng, &x, 1, &mut y, &mut scratch);
                means.push((th, r.mean_us()));
            }
            let best = means.iter().map(|&(_, us)| us).fold(f64::INFINITY, f64::min);
            let default_us = means
                .iter()
                .find(|&&(th, _)| th == default_tile_h)
                .map(|&(_, us)| us)
                .unwrap_or(f64::INFINITY);
            for &(th, us) in &means {
                let cell =
                    if th == default_tile_h { mx.check(default_us <= best * 1.25) } else { "" };
                println!(
                    "{:<40} {:>8} {:>12.1} {:>9.2}x {:>6}",
                    format!("{}-serial {label} {}x{}", LLAMA3_8B.name, s.n, s.k),
                    th,
                    us,
                    us / best,
                    cell
                );
            }
            // Reference row: the pipelined shared-book schedule at 4
            // threads over the same shape and input.
            let pool = Arc::new(ThreadPool::new(4));
            let plan = ShardPlan::new(s.n, 4, 1, 1);
            let eng = ShardedEngine::from_factory(plan, pool, |(r0, r1)| {
                CodeGemmEngine::from_quantized(&shard::slice_rows(&prep.q, r0, r1))
            })
            .with_shared_book(true);
            let mut y = vec![0f32; s.n];
            let mut scratch = EngineScratch::new();
            let name = format!("{}-pipelined {label} {}x{} t4", LLAMA3_8B.name, s.n, s.k);
            let r = bench_gemm_into(&name, opts, &eng, &x, 1, &mut y, &mut scratch);
            println!(
                "{:<40} {:>8} {:>12.1} {:>9.2}x {:>6}",
                name,
                "-",
                r.mean_us(),
                r.mean_us() / best,
                ""
            );
        }
    }
    mx.finish(
        "default tile_h within 1.25x of the best swept serial point at M=1",
        "default tile_h fell more than 1.25x behind the best swept point above",
    );

    // ---- matrix 8: KV page dtype sweep — latency, pool bytes, tile economics ----
    // The same 8B-class GQA head group as matrix 4 over coded pools:
    // decode is 1 query over the full context, prefill is one batched
    // 16-token causal chunk through `attend_batch`. Two exact gates ride
    // on the rows: the batched chunk must resolve each K/V tile exactly
    // twice (tile loop outside the query loop — the economics that make
    // coded pools affordable), and the int8 pool must hold the same
    // tokens in ≤ 0.3× the f32 bytes (1/4 element width + the per-row
    // scale sidecar at kv_dim 64).
    let mut mx = Matrix::begin(
        "kv page dtype sweep (paged attention h8/kv2/hd32, page 64): decode = 1 query \
         over full context; prefill = one batched 16-token causal chunk",
        format!(
            "{:<40} {:>6} {:>6} {:>9} {:>12} {:>10} {:>9} {:>6}",
            "shape", "ctx", "dtype", "phase", "mean us", "pool KiB", "tile res", "check"
        ),
    );
    {
        let shape = AttnShape { n_heads: 8, n_kv_heads: 2, head_dim: 32 };
        let kv_dim = shape.kv_dim();
        let attn_scale = 1.0 / (shape.head_dim as f32).sqrt();
        const CHUNK: usize = 16;
        let page = 64usize;
        for ctx in [512usize, 2048] {
            let mut held = [0usize; 3];
            for (di, dtype) in [KvDtype::F32, KvDtype::F16, KvDtype::Int8].into_iter().enumerate()
            {
                let layout =
                    KvLayout { n_layers: 1, kv_dim, page_size: page, max_seq: ctx, dtype };
                let mut pool = BlockPool::new(layout, layout.max_pages_per_seq());
                let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
                let mut paged = PagedKv::bind(&mut pool, &mut seq);
                let mut rng = Prng::seeded(29);
                for pos in 0..ctx {
                    let k = rng.normal_vec(kv_dim, 1.0);
                    let v = rng.normal_vec(kv_dim, 1.0);
                    paged.write(0, pos, &k, &v);
                }
                let q1 = rng.normal_vec(shape.n_heads * shape.head_dim, 1.0);
                let qm = rng.normal_vec(CHUNK * shape.n_heads * shape.head_dim, 1.0);
                let mut scratch = AttnScratch::new();
                let mut scores = vec![0f32; shape.scores_len_batch(CHUNK, ctx)];
                let mut out1 = vec![0f32; q1.len()];
                let mut outm = vec![0f32; qm.len()];
                held[di] = paged.bytes();
                let held_kib = held[di] / 1024;
                let pos0 = ctx - CHUNK;
                for phase in ["decode", "prefill"] {
                    let name = format!(
                        "attn h{}kv{} page {page} {}",
                        shape.n_heads,
                        shape.n_kv_heads,
                        dtype.as_str()
                    );
                    let r = run_bench(&format!("{name} ctx{ctx} {phase}"), opts, || {
                        if phase == "decode" {
                            attend(
                                &paged, 0, &shape, &q1, ctx, attn_scale, &mut scratch,
                                &mut scores, &mut out1,
                            );
                        } else {
                            attend_batch(
                                &paged, 0, &shape, &qm, pos0, CHUNK, attn_scale, &mut scratch,
                                &mut scores, &mut outm,
                            );
                        }
                        black_box(&outm);
                        black_box(&out1);
                    });
                    // Counter pin (prefill rows): one batched chunk
                    // resolves each of the context's tiles exactly twice
                    // — K once, V once — independent of the chunk length.
                    let (res_s, check) = if phase == "prefill" {
                        scratch.reset_tile_resolutions();
                        attend_batch(
                            &paged, 0, &shape, &qm, pos0, CHUNK, attn_scale, &mut scratch,
                            &mut scores, &mut outm,
                        );
                        let n_tiles = KvStore::n_tiles(&paged, ctx) as u64;
                        let res = scratch.tile_resolutions;
                        (format!("{res}"), mx.check(res == 2 * n_tiles))
                    } else {
                        (String::new(), "")
                    };
                    println!(
                        "{:<40} {:>6} {:>6} {:>9} {:>12.1} {:>10} {:>9} {:>6}",
                        name,
                        ctx,
                        dtype.as_str(),
                        phase,
                        r.mean_us(),
                        held_kib,
                        res_s,
                        check
                    );
                }
            }
            // Byte gate: same tokens, ≤ 0.3× the f32 footprint under int8.
            let cell = mx.check(held[2] * 10 <= held[0] * 3);
            println!(
                "{:<40} {:>6} {:>6} {:>9} {:>12} {:>10} {:>9} {:>6}",
                "int8/f32 pool bytes",
                ctx,
                "-",
                "-",
                format!("{:.3}x", held[2] as f64 / held[0] as f64),
                "",
                "",
                cell
            );
        }
    }
    mx.finish(
        "batched prefill resolved each tile exactly twice per chunk, and int8 pool \
         bytes <= 0.3x f32 at both contexts",
        "a dtype row missed the tile-resolution or pool-byte gate above",
    );
}
