//! Bench: paper Figure 5 + Tables 4/5 — throughput vs accuracy for every
//! method (throughput from the A100 model; accuracy measured on the tiny
//! model under each quantized engine).
use codegemm::bench::tables::{self, EvalContext};

fn main() {
    let ctx = EvalContext::load(std::path::Path::new("artifacts"));
    println!("{}", tables::table4(&ctx));
    println!("{}", tables::table5(&ctx));
    println!("{}", tables::fig5(&ctx));
}
