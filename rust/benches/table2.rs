//! Bench: regenerate paper Table 2 (decoder-block latency, 8B + 70B) from
//! the calibrated A100 model, and time the CPU CodeGEMM engine on a
//! scaled-down block as a correctness-bearing wall-clock reference.
use codegemm::bench::harness::{black_box, run_bench, BenchOptions};
use codegemm::bench::tables;
use codegemm::bench::workloads::{scaled_block_shapes, LLAMA3_8B};
use codegemm::config::QuantConfig;
use codegemm::gemm::{CodeGemmEngine, GemmEngine};
use codegemm::quant::Quantizer;
use codegemm::util::prng::Prng;

fn main() {
    println!("{}", tables::table2());
    // CPU wall-clock on a 16×-scaled 8B block (absolute µs are CPU
    // numbers; the A100 µs come from the model above).
    let opts = BenchOptions::from_env();
    for label in ["m1v4g128", "m2v8g128"] {
        let cfg = QuantConfig::parse_label(label).unwrap();
        let mut engines: Vec<CodeGemmEngine> = scaled_block_shapes(&LLAMA3_8B, 1, 16)
            .into_iter()
            .map(|(_, s)| {
                let w = Prng::seeded(7).normal_vec(s.n * s.k, 0.02);
                CodeGemmEngine::from_quantized(&Quantizer::new(cfg).quantize(&w, s.n, s.k))
            })
            .collect();
        let xs: Vec<Vec<f32>> =
            engines.iter().map(|e| Prng::seeded(8).normal_vec(e.dims().1, 1.0)).collect();
        let r = run_bench(&format!("cpu-block16x-{label}"), opts, || {
            for (e, x) in engines.iter_mut().zip(&xs) {
                black_box(e.gemv(x));
            }
        });
        println!("{}", r.line());
    }
}
