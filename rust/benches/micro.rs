//! Micro-benchmarks of the L3 hot paths: CPU GEMM engines across shapes
//! and batch sizes, Psumbook construction, the quantizer, and (when
//! artifacts exist) the AOT/PJRT decode step — the numbers behind
//! EXPERIMENTS.md §Perf.
use codegemm::bench::harness::{black_box, run_bench, BenchOptions};
use codegemm::config::QuantConfig;
use codegemm::coordinator::{DecodeBackend, PjrtBackend, SlotStep};
use codegemm::gemm::{CodeGemmEngine, DenseEngine, DequantEngine, GemmEngine, LutGemmEngine, Psumbook};
use codegemm::quant::bcq::BcqLinear;
use codegemm::quant::Quantizer;
use codegemm::runtime::ModelRuntime;
use codegemm::util::prng::Prng;

fn main() {
    let opts = BenchOptions::from_env();
    let shapes = [(1usize, 1024usize, 1024usize), (1, 4096, 1024), (4, 1024, 1024), (8, 1024, 1024)];
    for (mb, n, k) in shapes {
        let w = Prng::seeded(1).normal_vec(n * k, 0.02);
        let x = Prng::seeded(2).normal_vec(k * mb, 1.0);
        let flops = 2.0 * (mb * n * k) as f64;
        let mut dense = DenseEngine::new(w.clone(), n, k);
        let r = run_bench(&format!("dense      M{mb} {n}x{k}"), opts, || {
            black_box(dense.gemm(&x, mb));
        });
        println!("{}   {:.2} GFLOP/s", r.line(), flops / r.mean_us() / 1e3);
        for label in ["m1v4g128", "m2v8g128"] {
            let cfg = QuantConfig::parse_label(label).unwrap();
            let q = Quantizer::new(cfg).quantize(&w, n, k);
            let mut cg = CodeGemmEngine::from_quantized(&q);
            let mut dq = DequantEngine::from_quantized(&q);
            let r = run_bench(&format!("codegemm-{label} M{mb} {n}x{k}"), opts, || {
                black_box(cg.gemm(&x, mb));
            });
            println!("{}   {:.2} eff-GFLOP/s", r.line(), flops / r.mean_us() / 1e3);
            let r = run_bench(&format!("dequant-{label}  M{mb} {n}x{k}"), opts, || {
                black_box(dq.gemm(&x, mb));
            });
            println!("{}   {:.2} eff-GFLOP/s", r.line(), flops / r.mean_us() / 1e3);
        }
        if mb == 1 {
            let bcq = BcqLinear::quantize(&w, n, k, 2, 128).unwrap();
            let mut lut = LutGemmEngine::new(bcq);
            let r = run_bench(&format!("lutgemm-q2g128 {n}x{k}"), opts, || {
                black_box(lut.gemv(&x));
            });
            println!("{}", r.line());
        }
    }
    // Psumbook build in isolation.
    {
        let cfg = QuantConfig::m2v8g128();
        let q = Quantizer::new(cfg).quantize(&Prng::seeded(1).normal_vec(256 * 1024, 0.02), 256, 1024);
        let x = Prng::seeded(2).normal_vec(1024, 1.0);
        let mut p = Psumbook::empty(1024 / cfg.v, cfg.m, cfg.n_centroids(), 1);
        let r = run_bench("psumbook-build K=1024 m2v8", opts, || {
            black_box(p.build(&q.codebooks, cfg.v, &x));
        });
        println!("{}", r.line());
    }
    // Quantizer throughput.
    {
        let w = Prng::seeded(3).normal_vec(512 * 512, 0.02);
        let r = run_bench("quantize 512x512 m1v4g128", BenchOptions { trials: 5, warmup: 1, ..opts }, || {
            black_box(Quantizer::new(QuantConfig::m1v4g128()).quantize(&w, 512, 512));
        });
        println!("{}", r.line());
    }
    // AOT/PJRT decode step (the serve hot path).
    if std::path::Path::new("artifacts/manifest.json").exists() {
        for batch in [1usize, 4] {
            let rt = ModelRuntime::load("artifacts").unwrap();
            if !rt.batch_sizes().contains(&batch) {
                continue;
            }
            let mut be = PjrtBackend::with_batch(rt, batch);
            let steps: Vec<SlotStep> =
                (0..batch).map(|s| SlotStep { slot: s, token: 65 + s, pos: 0 }).collect();
            let mut pos = 0usize;
            let r = run_bench(&format!("pjrt-decode-step b{batch}"), opts, || {
                let st: Vec<SlotStep> =
                    steps.iter().map(|s| SlotStep { pos: pos % 127, ..*s }).collect();
                black_box(be.step(&st).unwrap());
                pos += 1;
            });
            println!("{}   ({:.0} tok/s at this batch)", r.line(), batch as f64 * 1e6 / r.mean_us());
        }
    } else {
        println!("pjrt-decode-step: skipped (run `make artifacts`)");
    }
}
