//! Bench: paper Figure 4 — (a) footprint vs latency sweep on the A100
//! model; (b) footprint vs perplexity sweep on the tiny-model substrate.
use codegemm::bench::tables::{self, EvalContext};

fn main() {
    println!("{}", tables::fig4a());
    let ctx = EvalContext::load(std::path::Path::new("artifacts"));
    println!("{}", tables::fig4b(&ctx));
}
