//! Bench: regenerate paper Table 8 from the calibrated A100 model.
use codegemm::bench::tables;

fn main() {
    println!("{}", tables::table8());
}
