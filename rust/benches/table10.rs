//! Bench: regenerate paper Table 10 from the calibrated A100 model.
use codegemm::bench::tables;

fn main() {
    println!("{}", tables::table10());
}
