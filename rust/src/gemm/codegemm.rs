//! The CodeGEMM engine (paper §3): Psumbook build + code-indexed gather.
//!
//! Walks the weight matrix in `(t_h × t_w)` tiles exactly like the GPU
//! kernel: for each row-block and k-tile the Psumbook is (re)built from
//! the activations — mirroring the per-thread-block build on the GPU, so
//! the build/read phase split (Table 6) and tile sensitivity (Table 7)
//! are measurable — and each row then gathers `m · t_w/v` partial sums
//! per batch column, scaled by the group-normalization factors.
//!
//! The engine is immutable during execution: the activation staging tile
//! and the Psumbook live in the caller's [`EngineScratch`] and are reused
//! call-to-call (reshaped in place between tile geometries), so the
//! decode hot loop never allocates.
//!
//! Complexity per call (paper Eq. 3):
//! build `O(m·2^b·K·N_blocks·M)` + read `O(m·N·K/v·M)` ≈ `O(MNK·m/v)`.

use crate::config::{KernelConfig, QuantConfig};
use crate::gemm::psumbook::Psumbook;
use crate::gemm::scratch::{grow_slice, EngineScratch};
use crate::gemm::tiling::Tiles;
use crate::gemm::GemmEngine;
use crate::quant::QuantizedLinear;
use crate::util::timer::Timer;

/// Unpacked code storage: u8 fast path for `b ≤ 8` (the paper's
/// recommended setting), u16 otherwise.
#[derive(Clone, Debug)]
enum Codes {
    U8(Vec<u8>),
    U16(Vec<u16>),
}

impl Codes {
    #[inline]
    fn bytes_per_code(&self) -> usize {
        match self {
            Codes::U8(_) => 1,
            Codes::U16(_) => 2,
        }
    }
}

/// CPU implementation of the CodeGEMM kernel.
#[derive(Clone, Debug)]
pub struct CodeGemmEngine {
    cfg: QuantConfig,
    kernel: KernelConfig,
    n: usize,
    k: usize,
    /// Vectors per row (K / v).
    jn: usize,
    codebooks: Vec<f32>,
    codes: Codes,
    scales: Vec<f32>,
    groups_per_row: usize,
    scratch: EngineScratch,
}

impl CodeGemmEngine {
    pub fn from_quantized(q: &QuantizedLinear) -> CodeGemmEngine {
        Self::with_kernel(q, KernelConfig::default())
    }

    pub fn with_kernel(q: &QuantizedLinear, mut kernel: KernelConfig) -> CodeGemmEngine {
        q.validate().expect("valid quantized layer");
        // Clamp tile_w to K, rounded down to a v multiple, instead of
        // panicking on non-default shapes.
        kernel.align_tile_w(q.k, q.cfg.v);
        let codes = if q.cfg.b <= 8 {
            Codes::U8(q.codes.unpack_u8().expect("b<=8"))
        } else {
            Codes::U16(q.codes.unpack().into_iter().map(|c| c as u16).collect())
        };
        CodeGemmEngine {
            cfg: q.cfg,
            kernel,
            n: q.n,
            k: q.k,
            jn: q.k / q.cfg.v,
            codebooks: q.codebooks.clone(),
            codes,
            scales: q.scales.clone(),
            groups_per_row: q.groups_per_row(),
            scratch: EngineScratch::new(),
        }
    }

    pub fn kernel_config(&self) -> KernelConfig {
        self.kernel
    }

    pub fn quant_config(&self) -> QuantConfig {
        self.cfg
    }

    /// Psumbook on-chip bytes for the configured tile (per batch column) —
    /// the space-complexity object compared against the codebook size in
    /// the paper's §3.
    pub fn psumbook_bytes(&self) -> usize {
        (self.kernel.tile_w / self.cfg.v) * self.cfg.m * self.cfg.n_centroids() * 4
    }

    /// Single-column gather fast path: flat unchecked indexing into the
    /// (L1-resident) Psumbook; the per-group scale is applied once per
    /// run of vectors sharing it.
    fn gather_rows_b1<C: Copy + Into<usize>>(
        &self,
        codes: &[C],
        book: &Psumbook,
        rows: (usize, usize),
        j0: usize,
        jn_tile: usize,
        y: &mut [f32],
    ) {
        let m = self.cfg.m;
        let v = self.cfg.v;
        let g = self.cfg.group_size(self.k);
        let vectors_per_group = g / v;
        let gpr = self.groups_per_row;
        let nc = self.cfg.n_centroids();
        let data = book.data.as_slice();
        debug_assert_eq!(data.len(), jn_tile * m * nc);
        for r in rows.0..rows.1 {
            let base = (r * self.jn + j0) * m;
            let row_codes = &codes[base..base + jn_tile * m];
            let row_scales = &self.scales[r * gpr..(r + 1) * gpr];
            let mut acc_row = 0f32;
            let mut j = 0usize;
            while j < jn_tile {
                let abs_j = j0 + j;
                let group = (abs_j * v) / g;
                let run_end_abs = ((group + 1) * vectors_per_group).min(j0 + jn_tile);
                let run = run_end_abs - abs_j;
                // SAFETY: `idx < jn_tile*m` by construction and every code
                // is `< nc` (enforced by `QuantizedLinear::validate`), so
                // `slot = idx*nc + code < jn_tile*m*nc = data.len()`.
                // Two accumulators break the serial add dependency chain.
                let (lo, hi) = (j * m, (j + run) * m);
                let (mut acc0, mut acc1) = (0f32, 0f32);
                let mut idx = lo;
                while idx + 1 < hi {
                    unsafe {
                        let c0: usize = (*row_codes.get_unchecked(idx)).into();
                        let c1: usize = (*row_codes.get_unchecked(idx + 1)).into();
                        debug_assert!(c0 < nc && c1 < nc);
                        acc0 += *data.get_unchecked(idx * nc + c0);
                        acc1 += *data.get_unchecked((idx + 1) * nc + c1);
                    }
                    idx += 2;
                }
                if idx < hi {
                    let code: usize = unsafe { (*row_codes.get_unchecked(idx)).into() };
                    debug_assert!(code < nc);
                    acc0 += unsafe { *data.get_unchecked(idx * nc + code) };
                }
                acc_row += row_scales[group] * (acc0 + acc1);
                j += run;
            }
            y[r] += acc_row;
        }
    }

    /// Gather-accumulate one row-block against a built Psumbook.
    #[allow(clippy::too_many_arguments)]
    fn gather_rows<C: Copy + Into<usize>>(
        &self,
        codes: &[C],
        book: &Psumbook,
        rows: (usize, usize),
        j0: usize,
        jn_tile: usize,
        mb: usize,
        y: &mut [f32],
    ) {
        let m = self.cfg.m;
        let v = self.cfg.v;
        let g = self.cfg.group_size(self.k);
        let vectors_per_group = g / v;
        let gpr = self.groups_per_row;
        let n = self.n;
        let nc = self.cfg.n_centroids();
        // Scratch per-batch group accumulator (mb is small: 1..64).
        let mut gacc = [0f32; 64];
        debug_assert!(mb <= 64);
        for r in rows.0..rows.1 {
            // Row's code slice for this tile is contiguous: [(r*jn)+j0 .. +jn_tile] × m.
            let base = (r * self.jn + j0) * m;
            let row_codes = &codes[base..base + jn_tile * m];
            let row_scales = &self.scales[r * gpr..(r + 1) * gpr];
            let mut j = 0usize;
            while j < jn_tile {
                // Run of vectors sharing one group scale.
                let abs_j = j0 + j;
                let group = (abs_j * v) / g;
                let run_end_abs = ((group + 1) * vectors_per_group).min(j0 + jn_tile);
                let run = run_end_abs - abs_j;
                gacc[..mb].fill(0.0);
                let data = book.data.as_slice();
                // SAFETY: idx < jn_tile·m and code < nc (validated), so
                // (idx·nc + code)·mb + b < data.len().
                for idx in j * m..(j + run) * m {
                    let code: usize = unsafe { (*row_codes.get_unchecked(idx)).into() };
                    debug_assert!(code < nc);
                    let off = (idx * nc + code) * mb;
                    for (b, g) in gacc[..mb].iter_mut().enumerate() {
                        *g += unsafe { *data.get_unchecked(off + b) };
                    }
                }
                let s = row_scales[group];
                for b in 0..mb {
                    y[b * n + r] += s * gacc[b];
                }
                j += run;
            }
        }
    }
}

impl GemmEngine for CodeGemmEngine {
    fn name(&self) -> &'static str {
        "codegemm"
    }

    fn dims(&self) -> (usize, usize) {
        (self.n, self.k)
    }

    fn gemm_into(&self, x: &[f32], m_batch: usize, y: &mut [f32], scratch: &mut EngineScratch) {
        assert_eq!(x.len(), self.k * m_batch);
        assert_eq!(y.len(), self.n * m_batch);
        assert!(m_batch <= 64, "engine supports m_batch <= 64");
        y.fill(0.0);
        let (n, k) = (self.n, self.k);
        let v = self.cfg.v;
        let m = self.cfg.m;
        let nc = self.cfg.n_centroids();
        let tw = self.kernel.tile_w;
        let th = self.kernel.tile_h;
        let EngineScratch { counters, buf, book, .. } = scratch;
        for (r0, r1) in Tiles::new(n, th) {
            for (c0, c1) in Tiles::new(k, tw) {
                let width = c1 - c0;
                let jn_tile = width / v;
                // Build phase: stage activations, compute the Psumbook
                // (both in caller scratch, reshaped in place per tile).
                let t = Timer::start();
                let x_tile = grow_slice(buf, width * m_batch);
                for b in 0..m_batch {
                    x_tile[b * width..(b + 1) * width].copy_from_slice(&x[b * k + c0..b * k + c1]);
                }
                if book.jn != jn_tile || book.m != m || book.nc != nc || book.mb != m_batch {
                    book.reshape(jn_tile, m, nc, m_batch);
                }
                let build_macs = book.build(&self.codebooks, v, x_tile);
                counters.build_seconds += t.elapsed_s();
                counters.build_ops += build_macs;
                counters.mac_flops += build_macs;
                counters.scratch_bytes += book.footprint_bytes() as u64;
                counters.activation_bytes += (width * m_batch * 2) as u64;
                // Codebook is streamed on-chip once per (row-block, tile).
                counters.weight_bytes += (m * nc * v * 2) as u64;

                // Read phase: gather partial sums through the codes.
                let t = Timer::start();
                let j0 = c0 / v;
                match (&self.codes, m_batch) {
                    (Codes::U8(codes), 1) => {
                        self.gather_rows_b1(codes, book, (r0, r1), j0, jn_tile, y)
                    }
                    (Codes::U16(codes), 1) => {
                        self.gather_rows_b1(codes, book, (r0, r1), j0, jn_tile, y)
                    }
                    (Codes::U8(codes), _) => {
                        self.gather_rows(codes, book, (r0, r1), j0, jn_tile, m_batch, y)
                    }
                    (Codes::U16(codes), _) => {
                        self.gather_rows(codes, book, (r0, r1), j0, jn_tile, m_batch, y)
                    }
                }
                counters.read_seconds += t.elapsed_s();
                let rows = (r1 - r0) as u64;
                let gathers = rows * (jn_tile * m) as u64 * m_batch as u64;
                counters.read_ops += gathers;
                counters.lookups += gathers;
                counters.scratch_bytes += gathers * 4;
                counters.weight_bytes += rows * (jn_tile * m * self.codes.bytes_per_code()) as u64;
            }
        }
        // Scales stream: one per (row, group) per call.
        counters.weight_bytes += (n * self.groups_per_row * 2) as u64;
        counters.calls += 1;
    }

    fn scratch(&self) -> &EngineScratch {
        &self.scratch
    }

    fn scratch_mut(&mut self) -> &mut EngineScratch {
        &mut self.scratch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::DenseEngine;
    use crate::quant::Quantizer;
    use crate::util::prng::Prng;
    use crate::util::stats;

    fn quantize(n: usize, k: usize, label: &str, seed: u64) -> QuantizedLinear {
        let w = Prng::seeded(seed).normal_vec(n * k, 0.02);
        let cfg = QuantConfig::parse_label(label).unwrap();
        Quantizer::new(cfg).quantize(&w, n, k)
    }

    fn check_against_dense(q: &QuantizedLinear, kernel: KernelConfig, mb: usize, seed: u64) {
        let x = Prng::seeded(seed).normal_vec(q.k * mb, 1.0);
        let y_ref = DenseEngine::new(q.dequantize(), q.n, q.k).gemm(&x, mb);
        let mut cg = CodeGemmEngine::with_kernel(q, kernel);
        let y = cg.gemm(&x, mb);
        let rel = stats::rel_l2(&y, &y_ref);
        assert!(rel < 2e-5, "tile {:?} mb={mb}: rel={rel}", (kernel.tile_w, kernel.tile_h));
    }

    #[test]
    fn matches_dense_across_tile_configs() {
        let q = quantize(64, 128, "m2v8g32", 1);
        for (tw, th) in [(32, 2048), (32, 16), (64, 32), (128, 64), (8, 7)] {
            check_against_dense(&q, KernelConfig { tile_w: tw, tile_h: th }, 1, 2);
        }
    }

    #[test]
    fn matches_dense_batched() {
        let q = quantize(48, 64, "m1v4g16", 3);
        for mb in [1usize, 2, 4, 8] {
            check_against_dense(&q, KernelConfig::default(), mb, 4);
        }
    }

    #[test]
    fn matches_dense_rowwise_norm() {
        let q = quantize(32, 96, "m2v4", 5);
        check_against_dense(&q, KernelConfig { tile_w: 24, tile_h: 10 }, 3, 6);
    }

    #[test]
    fn ragged_edge_tiles() {
        // K=80 with tile_w=32 leaves a 16-wide edge tile.
        let q = quantize(20, 80, "m1v8g16", 7);
        check_against_dense(&q, KernelConfig { tile_w: 32, tile_h: 6 }, 2, 8);
    }

    #[test]
    fn misaligned_tile_w_is_rounded_down_not_panicking() {
        // v=8: tile_w=20 rounds down to 16; tile_w=3 clamps up to v.
        let q = quantize(16, 64, "m1v8g16", 19);
        for tw in [20usize, 12, 3, 1000] {
            let e = CodeGemmEngine::with_kernel(&q, KernelConfig { tile_w: tw, tile_h: 8 });
            assert_eq!(e.kernel_config().tile_w % 8, 0, "tile_w {tw} not v-aligned");
            assert!(e.kernel_config().tile_w >= 8 && e.kernel_config().tile_w <= 64);
            check_against_dense(&q, KernelConfig { tile_w: tw, tile_h: 8 }, 2, 20);
        }
    }

    #[test]
    fn build_read_split_behaves_like_table6() {
        // Larger t_h amortizes the build phase: build share must drop as
        // t_h grows (paper §A.1/§A.2 mechanism).
        let q = quantize(256, 128, "m2v8g128", 9);
        let x = Prng::seeded(10).normal_vec(128, 1.0);
        let share = |th: usize| {
            let mut e = CodeGemmEngine::with_kernel(&q, KernelConfig { tile_w: 32, tile_h: th });
            let _ = e.gemv(&x);
            e.counters().build_share_ops()
        };
        let s_small = share(16);
        let s_large = share(256);
        assert!(s_large < s_small, "th=256 share {s_large} !< th=16 share {s_small}");
    }

    #[test]
    fn build_share_stable_across_batch() {
        // Paper §A.1: the build/read split is stable w.r.t. M at fixed t_w
        // (build amortizes across the batch because it scales with M too).
        let q = quantize(128, 128, "m2v8g128", 11);
        let share = |mb: usize| {
            let x = Prng::seeded(12).normal_vec(128 * mb, 1.0);
            let mut e = CodeGemmEngine::with_kernel(&q, KernelConfig { tile_w: 32, tile_h: 128 });
            let _ = e.gemm(&x, mb);
            e.counters().build_share_ops()
        };
        let s1 = share(1);
        let s8 = share(8);
        assert!((s1 - s8).abs() < 0.02, "share m1={s1} m8={s8}");
    }

    #[test]
    fn complexity_reduction_factor_m_over_v() {
        // Eq. 3: read ops ≈ dense MACs × m/v (build amortized away for
        // large N). m1v4 ⇒ 1/4 of dense MACs in lookups.
        let (n, k) = (512, 128);
        let q = quantize(n, k, "m1v4g128", 13);
        let x = Prng::seeded(14).normal_vec(k, 1.0);
        let mut e = CodeGemmEngine::from_quantized(&q);
        let _ = e.gemv(&x);
        let dense_macs = (n * k) as f64;
        let read = e.counters().read_ops as f64;
        assert!((read / dense_macs - 0.25).abs() < 0.01, "read/dense = {}", read / dense_macs);
    }

    #[test]
    fn psumbook_smaller_than_codebook_iff_v_gt_twv() {
        // Space complexity: psumbook = m·2^b·(t_w/v)·4 bytes vs codebook
        // m·2^b·v·2 bytes. For v=8, t_w=32 ⇒ book has 4 entries/centroid
        // of 4B = 16B vs 16B... compare against the paper's fp16 codebook
        // at v=8: 8×2=16B per centroid — equal here; at v=16: book 2×4=8B
        // per centroid vs 32B codebook.
        let q16 = quantize(32, 128, "m1v16g128", 15);
        let e16 = CodeGemmEngine::with_kernel(&q16, KernelConfig { tile_w: 32, tile_h: 2048 });
        let codebook_bytes = 1 * 256 * 16 * 2;
        assert!(e16.psumbook_bytes() < codebook_bytes);
    }

    #[test]
    fn u16_code_path_for_wide_b() {
        let (n, k) = (16, 32);
        let w = Prng::seeded(16).normal_vec(n * k, 0.02);
        let cfg = QuantConfig::new(4, 1, 10, -1).unwrap(); // 1024 centroids
        let q = Quantizer::new(cfg).quantize(&w, n, k);
        check_against_dense(&q, KernelConfig { tile_w: 16, tile_h: 8 }, 1, 17);
    }
}
