//! The CodeGEMM engine (paper §3): Psumbook build + code-indexed gather.
//!
//! Walks the weight matrix in `(t_h × t_w)` tiles exactly like the GPU
//! kernel: for each row-block and k-tile the Psumbook is (re)built from
//! the activations — mirroring the per-thread-block build on the GPU, so
//! the build/read phase split (Table 6) and tile sensitivity (Table 7)
//! are measurable — and each row then gathers `m · t_w/v` partial sums
//! per batch column, scaled by the group-normalization factors.
//!
//! ## Explicit build and gather phases
//!
//! The two phases are public API so schedulers can recombine them:
//! [`CodeGemmEngine::build_book`] stages a k-tile of activations and
//! (re)builds a caller-owned [`Psumbook`]; [`CodeGemmEngine::gather_into`]
//! accumulates **all of this engine's rows** against an externally built
//! book, counting only gather work. `gemm_into` is the serial composition
//! (build per row-block, like a GPU thread block); the shared-book
//! schedule in `crate::parallel::fanout` instead builds one book per
//! (k-tile, batch) and has every row shard `gather_into` it read-only —
//! build once, gather many (Eq. 3 amortization across shards).
//!
//! The engine is immutable during execution: the activation staging tile
//! and the Psumbook live in the caller's [`EngineScratch`] and are reused
//! call-to-call (reshaped in place between tile geometries), so the
//! decode hot loop never allocates.
//!
//! ## Kernel dispatch
//!
//! The build and gather inner loops live in [`crate::gemm::simd`]: a
//! [`simd::KernelSel`] is resolved once at construction from the
//! `KernelConfig` knobs (`kernel_impl`, `simd_lanes`), the
//! `CODEGEMM_KERNEL` environment override, and runtime CPU detection.
//! [`CodeGemmEngine::build_book`] and the gather entry points route
//! through it; all implementations are bit-identical (lane-order-stable
//! accumulation — see the `simd` module docs), so the selection is
//! purely a speed knob.
//!
//! Complexity per call (paper Eq. 3):
//! build `O(m·2^b·K·N_blocks·M)` + read `O(m·N·K/v·M)` ≈ `O(MNK·m/v)`.

use crate::config::{KernelConfig, QuantConfig};
use crate::gemm::psumbook::Psumbook;
use crate::gemm::scratch::{grow_slice, EngineScratch};
use crate::gemm::simd::{self, GatherCtx, KernelSel};
use crate::gemm::tiling::Tiles;
use crate::gemm::traffic::Counters;
use crate::gemm::GemmEngine;
use crate::quant::QuantizedLinear;
use crate::util::timer::Timer;

/// Unpacked code storage: u8 fast path for `b ≤ 8` (the paper's
/// recommended setting), u16 otherwise.
#[derive(Clone, Debug)]
enum Codes {
    U8(Vec<u8>),
    U16(Vec<u16>),
}

impl Codes {
    #[inline]
    fn bytes_per_code(&self) -> usize {
        match self {
            Codes::U8(_) => 1,
            Codes::U16(_) => 2,
        }
    }
}

/// CPU implementation of the CodeGEMM kernel.
#[derive(Clone, Debug)]
pub struct CodeGemmEngine {
    cfg: QuantConfig,
    kernel: KernelConfig,
    /// Kernel implementation resolved once at construction (config knobs
    /// × `CODEGEMM_KERNEL` env override × CPU detection).
    sel: KernelSel,
    n: usize,
    k: usize,
    /// Vectors per row (K / v).
    jn: usize,
    codebooks: Vec<f32>,
    codes: Codes,
    scales: Vec<f32>,
    groups_per_row: usize,
    scratch: EngineScratch,
}

impl CodeGemmEngine {
    pub fn from_quantized(q: &QuantizedLinear) -> CodeGemmEngine {
        Self::with_kernel(q, KernelConfig::default())
    }

    pub fn with_kernel(q: &QuantizedLinear, mut kernel: KernelConfig) -> CodeGemmEngine {
        q.validate().expect("valid quantized layer");
        // Clamp tile_w to K, rounded down to a v (and SIMD lane)
        // multiple, instead of panicking on non-default shapes.
        kernel.align_tile_w(q.k, q.cfg.v);
        let sel = simd::resolve(&kernel);
        let codes = if q.cfg.b <= 8 {
            Codes::U8(q.codes.unpack_u8().expect("b<=8"))
        } else {
            Codes::U16(q.codes.unpack().into_iter().map(|c| c as u16).collect())
        };
        CodeGemmEngine {
            cfg: q.cfg,
            kernel,
            sel,
            n: q.n,
            k: q.k,
            jn: q.k / q.cfg.v,
            codebooks: q.codebooks.clone(),
            codes,
            scales: q.scales.clone(),
            groups_per_row: q.groups_per_row(),
            scratch: EngineScratch::new(),
        }
    }

    pub fn kernel_config(&self) -> KernelConfig {
        self.kernel
    }

    /// The resolved kernel implementation + lane width this engine runs.
    pub fn kernel_sel(&self) -> KernelSel {
        self.sel
    }

    pub fn quant_config(&self) -> QuantConfig {
        self.cfg
    }

    /// Psumbook on-chip bytes for the configured tile (per batch column) —
    /// the space-complexity object compared against the codebook size in
    /// the paper's §3.
    pub fn psumbook_bytes(&self) -> usize {
        (self.kernel.tile_w / self.cfg.v) * self.cfg.m * self.cfg.n_centroids() * 4
    }

    /// The flat `m × 2^b × v` codebook array (shared read-only by the
    /// parallel shared-book build).
    pub fn codebooks(&self) -> &[f32] {
        &self.codebooks
    }

    /// Weight-stream bytes for the per-(row, group) scales, counted once
    /// per logical call (row partitioning conserves this stream exactly).
    pub(crate) fn scales_stream_bytes(&self) -> u64 {
        (self.n * self.groups_per_row * 2) as u64
    }

    /// Stage the activation k-tile `[c0, c1)` batch-major into `buf`
    /// (`x_tile[b*width..]` is column `b`'s slice), reusing the buffer's
    /// allocation.
    pub fn stage_tile<'b>(
        &self,
        x: &[f32],
        m_batch: usize,
        c0: usize,
        c1: usize,
        buf: &'b mut Vec<f32>,
    ) -> &'b mut [f32] {
        let k = self.k;
        let width = c1 - c0;
        debug_assert!(c0 < c1 && c1 <= k);
        let x_tile = grow_slice(buf, width * m_batch);
        for b in 0..m_batch {
            x_tile[b * width..(b + 1) * width].copy_from_slice(&x[b * k + c0..b * k + c1]);
        }
        x_tile
    }

    /// Stage the k-tile `[c0, c1)` and reshape `book` for its geometry —
    /// the common preamble of the serial build and the parallel
    /// shared-book build (which then splits the build itself by
    /// j-ranges).
    pub(crate) fn prepare_tile<'b>(
        &self,
        x: &[f32],
        m_batch: usize,
        c0: usize,
        c1: usize,
        book: &mut Psumbook,
        buf: &'b mut Vec<f32>,
    ) -> &'b mut [f32] {
        let (v, m, nc) = (self.cfg.v, self.cfg.m, self.cfg.n_centroids());
        let width = c1 - c0;
        debug_assert_eq!(width % v, 0, "tile width must be a v multiple");
        let jn_tile = width / v;
        if book.jn != jn_tile || book.m != m || book.nc != nc || book.mb != m_batch {
            book.reshape(jn_tile, m, nc, m_batch);
        }
        self.stage_tile(x, m_batch, c0, c1, buf)
    }

    /// Attribute one k-tile's build work (MACs and traffic, from the
    /// book's geometry) to `counters` — the single source of truth for
    /// build accounting, shared by the serial engine and the shared-book
    /// schedule so the two cannot drift apart. Returns the MACs counted.
    pub(crate) fn count_build(&self, book: &Psumbook, counters: &mut Counters) -> u64 {
        let v = self.cfg.v;
        let build_macs = (book.jn * book.m * book.nc * v * book.mb) as u64;
        counters.build_ops += build_macs;
        counters.mac_flops += build_macs;
        counters.scratch_bytes += book.footprint_bytes() as u64;
        counters.activation_bytes += (book.jn * v * book.mb * 2) as u64;
        // Codebook is streamed on-chip once per build.
        counters.weight_bytes += (book.m * book.nc * v * 2) as u64;
        // Phase-split byte attribution: everything a build moves (book
        // writes + staged activations + codebook) lands on the build side
        // of the roofline.
        counters.build_bytes += book.footprint_bytes() as u64
            + (book.jn * v * book.mb * 2) as u64
            + (book.m * book.nc * v * 2) as u64;
        build_macs
    }

    /// Build phase for one k-tile: stage the activations `[c0, c1)` into
    /// `buf` and (re)build `book` in place for them, attributing build
    /// MACs, bytes and wall-time to `counters`. The book depends only on
    /// the k-tile — not on any row range — so one build can serve every
    /// row (and row shard) that later [`CodeGemmEngine::gather_into`]s it.
    #[allow(clippy::too_many_arguments)]
    pub fn build_book(
        &self,
        x: &[f32],
        m_batch: usize,
        c0: usize,
        c1: usize,
        book: &mut Psumbook,
        buf: &mut Vec<f32>,
        counters: &mut Counters,
    ) {
        let t = Timer::start();
        let x_tile = self.prepare_tile(x, m_batch, c0, c1, book, buf);
        let (jn, m, nc, mb) = (book.jn, book.m, book.nc, book.mb);
        let built = simd::build_range(
            self.sel,
            &self.codebooks,
            self.cfg.v,
            x_tile,
            jn,
            m,
            nc,
            mb,
            0,
            jn,
            &mut book.data,
        );
        counters.build_seconds += t.elapsed_s();
        let counted = self.count_build(book, counters);
        debug_assert_eq!(built, counted, "attributed MACs must match the build");
    }

    /// Gather phase against an externally built book: accumulate **all**
    /// of this engine's rows for the k-tile starting at column `c0`
    /// (width `book.jn * v`) into the batch-major `y` (`n × m_batch`,
    /// which must hold zeros or the partial sums of other k-tiles).
    ///
    /// Only gather work (read ops, lookups, code/scratch bytes) is
    /// attributed to `counters` — build MACs belong to whoever built the
    /// book, which is exactly what lets the shared-book schedule count
    /// the build once per logical call regardless of how many row shards
    /// gather from it. Wall-time is likewise the scheduler's to measure.
    pub fn gather_into(
        &self,
        book: &Psumbook,
        c0: usize,
        m_batch: usize,
        y: &mut [f32],
        counters: &mut Counters,
    ) {
        assert_eq!(y.len(), self.n * m_batch);
        assert!(m_batch <= 64, "engine supports m_batch <= 64");
        assert_eq!(book.mb, m_batch, "book batch width mismatch");
        assert_eq!(book.m, self.cfg.m, "book codebook count mismatch");
        assert_eq!(book.nc, self.cfg.n_centroids(), "book centroid count mismatch");
        // The gather indexes book.data unchecked, so the storage must
        // actually match the geometry fields (Psumbook fields are pub) —
        // this is the bound the release-mode SAFETY argument rests on.
        assert_eq!(
            book.data.len(),
            book.jn * book.m * book.nc * book.mb,
            "book storage does not match its geometry"
        );
        assert_eq!(c0 % self.cfg.v, 0, "tile start must be a v multiple");
        assert!(c0 / self.cfg.v + book.jn <= self.jn, "k-tile out of range");
        self.gather_block(book, c0, (0, self.n), m_batch, y, counters);
    }

    /// Gather-accumulate one row range against a built book, counting the
    /// gather work.
    fn gather_block(
        &self,
        book: &Psumbook,
        c0: usize,
        rows: (usize, usize),
        m_batch: usize,
        y: &mut [f32],
        counters: &mut Counters,
    ) {
        let jn_tile = book.jn;
        let j0 = c0 / self.cfg.v;
        let ctx = GatherCtx {
            m: self.cfg.m,
            v: self.cfg.v,
            g: self.cfg.group_size(self.k),
            gpr: self.groups_per_row,
            jn: self.jn,
            n: self.n,
            nc: self.cfg.n_centroids(),
            scales: &self.scales,
        };
        let sel = self.sel;
        match (&self.codes, m_batch) {
            (Codes::U8(codes), 1) => simd::gather_b1(sel, &ctx, codes, book, rows, j0, jn_tile, y),
            (Codes::U16(codes), 1) => simd::gather_b1(sel, &ctx, codes, book, rows, j0, jn_tile, y),
            (Codes::U8(codes), _) => {
                simd::gather_mb(sel, &ctx, codes, book, rows, j0, jn_tile, m_batch, y)
            }
            (Codes::U16(codes), _) => {
                simd::gather_mb(sel, &ctx, codes, book, rows, j0, jn_tile, m_batch, y)
            }
        }
        let nrows = (rows.1 - rows.0) as u64;
        let gathers = nrows * (jn_tile * self.cfg.m) as u64 * m_batch as u64;
        counters.read_ops += gathers;
        counters.lookups += gathers;
        counters.scratch_bytes += gathers * 4;
        counters.weight_bytes += nrows * (jn_tile * self.cfg.m * self.codes.bytes_per_code()) as u64;
        // Phase-split byte attribution: code stream + Psumbook reads land
        // on the gather side of the roofline.
        counters.read_bytes +=
            gathers * 4 + nrows * (jn_tile * self.cfg.m * self.codes.bytes_per_code()) as u64;
    }

}

impl GemmEngine for CodeGemmEngine {
    fn name(&self) -> &'static str {
        "codegemm"
    }

    fn dims(&self) -> (usize, usize) {
        (self.n, self.k)
    }

    fn gemm_into(&self, x: &[f32], m_batch: usize, y: &mut [f32], scratch: &mut EngineScratch) {
        assert_eq!(x.len(), self.k * m_batch);
        assert_eq!(y.len(), self.n * m_batch);
        assert!(m_batch <= 64, "engine supports m_batch <= 64");
        y.fill(0.0);
        let (n, k) = (self.n, self.k);
        let tw = self.kernel.tile_w;
        let th = self.kernel.tile_h;
        let EngineScratch { counters, buf, book, .. } = scratch;
        // Serial composition of the two phases: rebuild per row-block
        // (mirroring the GPU's per-thread-block tables), gather the block.
        for (r0, r1) in Tiles::new(n, th) {
            for (c0, c1) in Tiles::new(k, tw) {
                self.build_book(x, m_batch, c0, c1, book, buf, counters);
                let t = Timer::start();
                self.gather_block(book, c0, (r0, r1), m_batch, y, counters);
                counters.read_seconds += t.elapsed_s();
            }
        }
        // Scales stream: one per (row, group) per call — read during the
        // gather's scale application, so it lands on the read side too.
        counters.weight_bytes += self.scales_stream_bytes();
        counters.read_bytes += self.scales_stream_bytes();
        counters.calls += 1;
    }

    fn scratch(&self) -> &EngineScratch {
        &self.scratch
    }

    fn scratch_mut(&mut self) -> &mut EngineScratch {
        &mut self.scratch
    }

    fn as_codegemm(&self) -> Option<&CodeGemmEngine> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::DenseEngine;
    use crate::quant::Quantizer;
    use crate::util::prng::Prng;
    use crate::util::stats;

    fn quantize(n: usize, k: usize, label: &str, seed: u64) -> QuantizedLinear {
        let w = Prng::seeded(seed).normal_vec(n * k, 0.02);
        let cfg = QuantConfig::parse_label(label).unwrap();
        Quantizer::new(cfg).quantize(&w, n, k)
    }

    fn check_against_dense(q: &QuantizedLinear, kernel: KernelConfig, mb: usize, seed: u64) {
        let x = Prng::seeded(seed).normal_vec(q.k * mb, 1.0);
        let y_ref = DenseEngine::new(q.dequantize(), q.n, q.k).gemm(&x, mb);
        let mut cg = CodeGemmEngine::with_kernel(q, kernel);
        let y = cg.gemm(&x, mb);
        let rel = stats::rel_l2(&y, &y_ref);
        assert!(rel < 2e-5, "tile {:?} mb={mb}: rel={rel}", (kernel.tile_w, kernel.tile_h));
    }

    #[test]
    fn matches_dense_across_tile_configs() {
        let q = quantize(64, 128, "m2v8g32", 1);
        for (tw, th) in [(32, 2048), (32, 16), (64, 32), (128, 64), (8, 7)] {
            check_against_dense(&q, KernelConfig { tile_w: tw, tile_h: th, ..Default::default() }, 1, 2);
        }
    }

    #[test]
    fn matches_dense_batched() {
        let q = quantize(48, 64, "m1v4g16", 3);
        for mb in [1usize, 2, 4, 8] {
            check_against_dense(&q, KernelConfig::default(), mb, 4);
        }
    }

    #[test]
    fn matches_dense_rowwise_norm() {
        let q = quantize(32, 96, "m2v4", 5);
        check_against_dense(&q, KernelConfig { tile_w: 24, tile_h: 10, ..Default::default() }, 3, 6);
    }

    #[test]
    fn ragged_edge_tiles() {
        // K=80 with tile_w=32 leaves a 16-wide edge tile.
        let q = quantize(20, 80, "m1v8g16", 7);
        check_against_dense(&q, KernelConfig { tile_w: 32, tile_h: 6, ..Default::default() }, 2, 8);
    }

    #[test]
    fn misaligned_tile_w_is_rounded_down_not_panicking() {
        // v=8: tile_w=20 rounds down to 16; tile_w=3 clamps up to v.
        let q = quantize(16, 64, "m1v8g16", 19);
        for tw in [20usize, 12, 3, 1000] {
            let e = CodeGemmEngine::with_kernel(&q, KernelConfig { tile_w: tw, tile_h: 8, ..Default::default() });
            assert_eq!(e.kernel_config().tile_w % 8, 0, "tile_w {tw} not v-aligned");
            assert!(e.kernel_config().tile_w >= 8 && e.kernel_config().tile_w <= 64);
            check_against_dense(&q, KernelConfig { tile_w: tw, tile_h: 8, ..Default::default() }, 2, 20);
        }
    }

    #[test]
    fn build_read_split_behaves_like_table6() {
        // Larger t_h amortizes the build phase: build share must drop as
        // t_h grows (paper §A.1/§A.2 mechanism).
        let q = quantize(256, 128, "m2v8g128", 9);
        let x = Prng::seeded(10).normal_vec(128, 1.0);
        let share = |th: usize| {
            let mut e = CodeGemmEngine::with_kernel(&q, KernelConfig { tile_w: 32, tile_h: th, ..Default::default() });
            let _ = e.gemv(&x);
            e.counters().build_share_ops()
        };
        let s_small = share(16);
        let s_large = share(256);
        assert!(s_large < s_small, "th=256 share {s_large} !< th=16 share {s_small}");
    }

    #[test]
    fn build_share_stable_across_batch() {
        // Paper §A.1: the build/read split is stable w.r.t. M at fixed t_w
        // (build amortizes across the batch because it scales with M too).
        let q = quantize(128, 128, "m2v8g128", 11);
        let share = |mb: usize| {
            let x = Prng::seeded(12).normal_vec(128 * mb, 1.0);
            let mut e = CodeGemmEngine::with_kernel(&q, KernelConfig { tile_w: 32, tile_h: 128, ..Default::default() });
            let _ = e.gemm(&x, mb);
            e.counters().build_share_ops()
        };
        let s1 = share(1);
        let s8 = share(8);
        assert!((s1 - s8).abs() < 0.02, "share m1={s1} m8={s8}");
    }

    #[test]
    fn complexity_reduction_factor_m_over_v() {
        // Eq. 3: read ops ≈ dense MACs × m/v (build amortized away for
        // large N). m1v4 ⇒ 1/4 of dense MACs in lookups.
        let (n, k) = (512, 128);
        let q = quantize(n, k, "m1v4g128", 13);
        let x = Prng::seeded(14).normal_vec(k, 1.0);
        let mut e = CodeGemmEngine::from_quantized(&q);
        let _ = e.gemv(&x);
        let dense_macs = (n * k) as f64;
        let read = e.counters().read_ops as f64;
        assert!((read / dense_macs - 0.25).abs() < 0.01, "read/dense = {}", read / dense_macs);
    }

    #[test]
    fn psumbook_smaller_than_codebook_iff_v_gt_twv() {
        // Space complexity: psumbook = m·2^b·(t_w/v)·4 bytes vs codebook
        // m·2^b·v·2 bytes. For v=8, t_w=32 ⇒ book has 4 entries/centroid
        // of 4B = 16B vs 16B... compare against the paper's fp16 codebook
        // at v=8: 8×2=16B per centroid — equal here; at v=16: book 2×4=8B
        // per centroid vs 32B codebook.
        let q16 = quantize(32, 128, "m1v16g128", 15);
        let e16 = CodeGemmEngine::with_kernel(&q16, KernelConfig { tile_w: 32, tile_h: 2048, ..Default::default() });
        let codebook_bytes = 1 * 256 * 16 * 2;
        assert!(e16.psumbook_bytes() < codebook_bytes);
    }

    /// Driving the public build/gather phases by hand (one build per
    /// k-tile, all rows gathered from it) must be bit-identical to the
    /// engine's own `gemm_into` when the row blocking matches (tile_h >=
    /// n ⇒ the serial engine also builds once per k-tile).
    #[test]
    fn manual_build_gather_composition_matches_gemm_into() {
        use crate::gemm::tiling::Tiles;
        let q = quantize(24, 96, "m2v4g32", 21);
        for mb in [1usize, 3] {
            let x = Prng::seeded(22).normal_vec(q.k * mb, 1.0);
            let e = CodeGemmEngine::with_kernel(&q, KernelConfig { tile_w: 32, tile_h: 4096, ..Default::default() });
            let mut y_ref = vec![f32::NAN; q.n * mb];
            let mut scratch = EngineScratch::new();
            e.gemm_into(&x, mb, &mut y_ref, &mut scratch);

            let mut y = vec![0f32; q.n * mb];
            let mut book = Psumbook::default();
            let mut buf = Vec::new();
            let mut counters = Counters::new();
            for (c0, c1) in Tiles::new(q.k, e.kernel_config().tile_w) {
                e.build_book(&x, mb, c0, c1, &mut book, &mut buf, &mut counters);
                e.gather_into(&book, c0, mb, &mut y, &mut counters);
            }
            assert_eq!(y, y_ref, "mb={mb}");
            // Work counts match the fused path exactly (minus the
            // per-call scales stream and call count, which the scheduler
            // owns).
            assert_eq!(counters.build_ops, scratch.counters.build_ops);
            assert_eq!(counters.read_ops, scratch.counters.read_ops);
            assert_eq!(counters.lookups, scratch.counters.lookups);
        }
    }

    #[test]
    fn u16_code_path_for_wide_b() {
        let (n, k) = (16, 32);
        let w = Prng::seeded(16).normal_vec(n * k, 0.02);
        let cfg = QuantConfig::new(4, 1, 10, -1).unwrap(); // 1024 centroids
        let q = Quantizer::new(cfg).quantize(&w, n, k);
        check_against_dense(&q, KernelConfig { tile_w: 16, tile_h: 8, ..Default::default() }, 1, 17);
    }
}
