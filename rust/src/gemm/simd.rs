//! SIMD kernel layer for the two CodeGEMM phases: the Psumbook **build**
//! (centroid × activation-subvector inner products) and the code-indexed
//! **gather**.
//!
//! ## Dispatch model
//!
//! A [`KernelSel`] is resolved once per engine from the [`KernelConfig`]
//! knobs (`kernel_impl`, `simd_lanes`) plus runtime CPU detection:
//!
//! * [`KernelImpl::Scalar`] — the reference implementation, one row at a
//!   time (the exact kernels the engine shipped with pre-SIMD).
//! * [`KernelImpl::Unrolled`] — portable lane-parallel path: 8 or 16 rows
//!   (single-column) / 8 batch columns (batched) advance in lock-step
//!   through manually unrolled accumulator arrays the autovectorizer can
//!   chew on. No `std::arch`, works on every target.
//! * [`KernelImpl::Avx2`] — explicit `std::arch::x86_64` path: 8 rows per
//!   `__m256` with `vgatherdps` Psumbook lookups, and an 8-centroid-wide
//!   FMA-shaped build. Selected only when `is_x86_feature_detected!`
//!   confirms AVX2; silently downgrades to `Unrolled` otherwise.
//! * [`KernelImpl::Auto`] (default) — `Avx2` when available, else
//!   `Unrolled`.
//!
//! The `CODEGEMM_KERNEL` environment variable (`scalar` | `unrolled` |
//! `avx2` | `auto`) overrides the config knob — that is what lets CI run
//! the whole suite once per kernel path with no per-test plumbing.
//!
//! ## Bit-exactness by construction
//!
//! Every SIMD path maps **independent accumulators** onto lanes: output
//! rows for the single-column gather, batch columns for the batched
//! gather, centroids for the build. Each lane replays *exactly* the
//! scalar per-accumulator operation sequence — same adds, same order,
//! same mul-then-add scale application (no FMA contraction) — so scalar
//! and SIMD results are bit-identical, not epsilon-close. Floating-point
//! reassociation never happens because no scalar reduction is ever split
//! *across* lanes. `tests/simd_prop.rs` pins this with `assert_eq` (and
//! the tiling layer keeps `tile_w` lane-aligned via
//! [`KernelConfig::align_tile_w`], so every impl sees identical k-tile
//! boundaries and therefore identical group-scale run structure).

use crate::config::{KernelConfig, KernelImpl};
use crate::gemm::psumbook::{self, Psumbook};

/// A resolved kernel selection: which implementation runs and how many
/// lanes it advances per step. Produced by [`resolve`]; immutable for
/// the life of an engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelSel {
    pub imp: KernelImpl,
    pub lanes: usize,
}

impl KernelSel {
    /// Stable label for metrics / bench artifacts.
    pub fn label(&self) -> &'static str {
        match self.imp {
            KernelImpl::Scalar => "scalar",
            KernelImpl::Unrolled => "unrolled",
            KernelImpl::Avx2 => "avx2",
            // `resolve` never returns Auto; keep a label anyway.
            KernelImpl::Auto => "auto",
        }
    }
}

/// Runtime AVX2 detection (false on non-x86_64 targets).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Resolve the configured kernel against the host CPU and the
/// `CODEGEMM_KERNEL` environment override (which wins over the config so
/// CI can force every engine in the process onto one path).
pub fn resolve(cfg: &KernelConfig) -> KernelSel {
    let env = std::env::var("CODEGEMM_KERNEL").ok().and_then(|s| KernelImpl::parse(&s));
    resolve_with(cfg, env)
}

/// [`resolve`] with the environment override made explicit (testable
/// regardless of the process environment).
pub fn resolve_with(cfg: &KernelConfig, env_override: Option<KernelImpl>) -> KernelSel {
    let mut imp = env_override.unwrap_or(cfg.kernel_impl);
    // Lane count comes from the config alone — never from the impl or
    // the environment — so engines configured for different impls tile
    // identically and stay bit-comparable.
    let mut lanes = cfg.effective_lanes();
    if imp == KernelImpl::Auto {
        imp = if avx2_available() { KernelImpl::Avx2 } else { KernelImpl::Unrolled };
    }
    if imp == KernelImpl::Avx2 && !avx2_available() {
        imp = KernelImpl::Unrolled;
    }
    if imp == KernelImpl::Avx2 {
        // __m256 is 8 f32 lanes; the gather kernel is written for exactly 8.
        lanes = 8;
    }
    if lanes == 1 && imp != KernelImpl::Scalar {
        imp = KernelImpl::Scalar;
    }
    if imp == KernelImpl::Scalar {
        lanes = 1;
    }
    KernelSel { imp, lanes }
}

/// Read-only engine geometry the gather kernels need, bundled so they
/// can be free functions (shared by the engine and the remainder
/// handling of every SIMD path).
pub(crate) struct GatherCtx<'a> {
    /// Codebooks per vector.
    pub m: usize,
    /// Sub-vector width.
    pub v: usize,
    /// Effective group size (scale granularity) in weights.
    pub g: usize,
    /// Groups per row.
    pub gpr: usize,
    /// Vectors per full row (`K / v`).
    pub jn: usize,
    /// Output rows of the whole engine (row stride of batched `y`).
    pub n: usize,
    /// Centroids per codebook (`2^b`).
    pub nc: usize,
    /// Per-(row, group) scales, `n × gpr`.
    pub scales: &'a [f32],
}

// ---------------------------------------------------------------------------
// Single-column (m_batch == 1) gather: lanes = output rows.
// ---------------------------------------------------------------------------

/// Dispatch the single-column gather for rows `[rows.0, rows.1)` of the
/// k-tile starting at vector `j0` (width `jn_tile` vectors) against a
/// built book, accumulating into `y[r] +=`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gather_b1<C: Copy + Into<usize>>(
    sel: KernelSel,
    ctx: &GatherCtx,
    codes: &[C],
    book: &Psumbook,
    rows: (usize, usize),
    j0: usize,
    jn_tile: usize,
    y: &mut [f32],
) {
    let data = book.data.as_slice();
    debug_assert_eq!(data.len(), jn_tile * ctx.m * ctx.nc);
    #[cfg(target_arch = "x86_64")]
    {
        if sel.imp == KernelImpl::Avx2 {
            let blocks_end = rows.0 + (rows.1 - rows.0) / 8 * 8;
            if rows.0 < blocks_end {
                // SAFETY: `resolve` only selects Avx2 when the host
                // reports the feature; row blocks are full (8 rows).
                unsafe { gather_b1_avx2(ctx, codes, data, rows.0, blocks_end, j0, jn_tile, y) };
            }
            gather_b1_scalar(ctx, codes, data, blocks_end, rows.1, j0, jn_tile, y);
            return;
        }
    }
    match sel.imp {
        KernelImpl::Unrolled | KernelImpl::Avx2 => {
            if sel.lanes >= 16 {
                gather_b1_lanes::<C, 16>(ctx, codes, data, rows, j0, jn_tile, y)
            } else {
                gather_b1_lanes::<C, 8>(ctx, codes, data, rows, j0, jn_tile, y)
            }
        }
        _ => gather_b1_scalar(ctx, codes, data, rows.0, rows.1, j0, jn_tile, y),
    }
}

/// Reference single-column gather (one row at a time): flat unchecked
/// indexing into the (L1-resident) Psumbook; the per-group scale is
/// applied once per run of vectors sharing it. Every SIMD path must
/// reproduce this per-row operation sequence exactly — it also serves
/// as their remainder handler for row counts not divisible by the lane
/// width.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gather_b1_scalar<C: Copy + Into<usize>>(
    ctx: &GatherCtx,
    codes: &[C],
    data: &[f32],
    r_lo: usize,
    r_hi: usize,
    j0: usize,
    jn_tile: usize,
    y: &mut [f32],
) {
    let (m, v, g) = (ctx.m, ctx.v, ctx.g);
    let vectors_per_group = g / v;
    let (gpr, nc) = (ctx.gpr, ctx.nc);
    for r in r_lo..r_hi {
        let base = (r * ctx.jn + j0) * m;
        let row_codes = &codes[base..base + jn_tile * m];
        let row_scales = &ctx.scales[r * gpr..(r + 1) * gpr];
        let mut acc_row = 0f32;
        let mut j = 0usize;
        while j < jn_tile {
            let abs_j = j0 + j;
            let group = (abs_j * v) / g;
            let run_end_abs = ((group + 1) * vectors_per_group).min(j0 + jn_tile);
            let run = run_end_abs - abs_j;
            // SAFETY: `idx < jn_tile*m` by construction and every code
            // is `< nc` (enforced by `QuantizedLinear::validate`), so
            // `slot = idx*nc + code < jn_tile*m*nc = data.len()`.
            // Two accumulators break the serial add dependency chain.
            let (lo, hi) = (j * m, (j + run) * m);
            let (mut acc0, mut acc1) = (0f32, 0f32);
            let mut idx = lo;
            while idx + 1 < hi {
                unsafe {
                    let c0: usize = (*row_codes.get_unchecked(idx)).into();
                    let c1: usize = (*row_codes.get_unchecked(idx + 1)).into();
                    debug_assert!(c0 < nc && c1 < nc);
                    acc0 += *data.get_unchecked(idx * nc + c0);
                    acc1 += *data.get_unchecked((idx + 1) * nc + c1);
                }
                idx += 2;
            }
            if idx < hi {
                let code: usize = unsafe { (*row_codes.get_unchecked(idx)).into() };
                debug_assert!(code < nc);
                acc0 += unsafe { *data.get_unchecked(idx * nc + code) };
            }
            acc_row += row_scales[group] * (acc0 + acc1);
            j += run;
        }
        y[r] += acc_row;
    }
}

/// Portable lane-parallel single-column gather: `L` rows advance in
/// lock-step, each lane owning the same accumulator pair the scalar path
/// keeps for that row (bit-exact per row; remainder rows fall back to
/// [`gather_b1_scalar`]).
#[allow(clippy::too_many_arguments)]
fn gather_b1_lanes<C: Copy + Into<usize>, const L: usize>(
    ctx: &GatherCtx,
    codes: &[C],
    data: &[f32],
    rows: (usize, usize),
    j0: usize,
    jn_tile: usize,
    y: &mut [f32],
) {
    let (m, v, g) = (ctx.m, ctx.v, ctx.g);
    let vectors_per_group = g / v;
    let (gpr, nc) = (ctx.gpr, ctx.nc);
    let blocks_end = rows.0 + (rows.1 - rows.0) / L * L;
    let mut r0 = rows.0;
    while r0 < blocks_end {
        let mut base = [0usize; L];
        for (l, b) in base.iter_mut().enumerate() {
            *b = ((r0 + l) * ctx.jn + j0) * m;
        }
        let mut acc_row = [0f32; L];
        let mut j = 0usize;
        while j < jn_tile {
            let abs_j = j0 + j;
            let group = (abs_j * v) / g;
            let run_end_abs = ((group + 1) * vectors_per_group).min(j0 + jn_tile);
            let run = run_end_abs - abs_j;
            let (lo, hi) = (j * m, (j + run) * m);
            let mut acc0 = [0f32; L];
            let mut acc1 = [0f32; L];
            let mut idx = lo;
            while idx + 1 < hi {
                // SAFETY: same bound as the scalar path, per lane:
                // `base[l] + idx < (r0+l+1)*jn*m <= codes.len()` and
                // `idx*nc + code < data.len()`.
                for l in 0..L {
                    unsafe {
                        let c0: usize = (*codes.get_unchecked(base[l] + idx)).into();
                        let c1: usize = (*codes.get_unchecked(base[l] + idx + 1)).into();
                        debug_assert!(c0 < nc && c1 < nc);
                        acc0[l] += *data.get_unchecked(idx * nc + c0);
                        acc1[l] += *data.get_unchecked((idx + 1) * nc + c1);
                    }
                }
                idx += 2;
            }
            if idx < hi {
                for l in 0..L {
                    let code: usize = unsafe { (*codes.get_unchecked(base[l] + idx)).into() };
                    debug_assert!(code < nc);
                    acc0[l] += unsafe { *data.get_unchecked(idx * nc + code) };
                }
            }
            for l in 0..L {
                let s = ctx.scales[(r0 + l) * gpr + group];
                acc_row[l] += s * (acc0[l] + acc1[l]);
            }
            j += run;
        }
        for l in 0..L {
            y[r0 + l] += acc_row[l];
        }
        r0 += L;
    }
    gather_b1_scalar(ctx, codes, data, blocks_end, rows.1, j0, jn_tile, y);
}

/// AVX2 single-column gather: 8 rows per `__m256`, Psumbook lookups via
/// `vgatherdps`. Lane `l` of every vector op is row `r0 + l`'s scalar
/// accumulator, so results are bit-identical to [`gather_b1_scalar`].
///
/// Caller guarantees `(r_hi - r_lo) % 8 == 0` and AVX2 support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn gather_b1_avx2<C: Copy + Into<usize>>(
    ctx: &GatherCtx,
    codes: &[C],
    data: &[f32],
    r_lo: usize,
    r_hi: usize,
    j0: usize,
    jn_tile: usize,
    y: &mut [f32],
) {
    use std::arch::x86_64::*;

    #[inline(always)]
    unsafe fn slots<C: Copy + Into<usize>>(
        codes: &[C],
        base: &[usize; 8],
        idx: usize,
        nc: usize,
    ) -> __m256i {
        let row = idx * nc;
        // SAFETY (caller): base[l] + idx < codes.len(); codes < nc.
        let c = |l: usize| -> i32 {
            let code: usize = (*codes.get_unchecked(base[l] + idx)).into();
            (row + code) as i32
        };
        _mm256_setr_epi32(c(0), c(1), c(2), c(3), c(4), c(5), c(6), c(7))
    }

    let (m, v, g) = (ctx.m, ctx.v, ctx.g);
    let vectors_per_group = g / v;
    let (gpr, nc) = (ctx.gpr, ctx.nc);
    debug_assert!(data.len() <= i32::MAX as usize);
    debug_assert_eq!((r_hi - r_lo) % 8, 0);
    let dp = data.as_ptr();
    let mut r0 = r_lo;
    while r0 < r_hi {
        let mut base = [0usize; 8];
        for (l, b) in base.iter_mut().enumerate() {
            *b = ((r0 + l) * ctx.jn + j0) * m;
        }
        let mut acc_row = _mm256_setzero_ps();
        let mut j = 0usize;
        while j < jn_tile {
            let abs_j = j0 + j;
            let group = (abs_j * v) / g;
            let run_end_abs = ((group + 1) * vectors_per_group).min(j0 + jn_tile);
            let run = run_end_abs - abs_j;
            let (lo, hi) = (j * m, (j + run) * m);
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            let mut idx = lo;
            while idx + 1 < hi {
                let s0 = slots(codes, &base, idx, nc);
                let s1 = slots(codes, &base, idx + 1, nc);
                acc0 = _mm256_add_ps(acc0, _mm256_i32gather_ps::<4>(dp, s0));
                acc1 = _mm256_add_ps(acc1, _mm256_i32gather_ps::<4>(dp, s1));
                idx += 2;
            }
            if idx < hi {
                let s0 = slots(codes, &base, idx, nc);
                acc0 = _mm256_add_ps(acc0, _mm256_i32gather_ps::<4>(dp, s0));
            }
            let s = |l: usize| ctx.scales[(r0 + l) * gpr + group];
            let sv = _mm256_setr_ps(s(0), s(1), s(2), s(3), s(4), s(5), s(6), s(7));
            // mul then add (matches the scalar `+= s * (acc0 + acc1)`,
            // no FMA contraction).
            acc_row = _mm256_add_ps(acc_row, _mm256_mul_ps(sv, _mm256_add_ps(acc0, acc1)));
            j += run;
        }
        let yp = y.as_mut_ptr().add(r0);
        _mm256_storeu_ps(yp, _mm256_add_ps(_mm256_loadu_ps(yp), acc_row));
        r0 += 8;
    }
}

// ---------------------------------------------------------------------------
// Batched (m_batch > 1) gather: lanes = batch columns.
// ---------------------------------------------------------------------------

/// Dispatch the batched gather (the batch axis is innermost in the book,
/// so lanes ride contiguous loads instead of `vgatherdps`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gather_mb<C: Copy + Into<usize>>(
    sel: KernelSel,
    ctx: &GatherCtx,
    codes: &[C],
    book: &Psumbook,
    rows: (usize, usize),
    j0: usize,
    jn_tile: usize,
    mb: usize,
    y: &mut [f32],
) {
    let data = book.data.as_slice();
    debug_assert_eq!(data.len(), jn_tile * ctx.m * ctx.nc * mb);
    #[cfg(target_arch = "x86_64")]
    {
        if sel.imp == KernelImpl::Avx2 {
            // SAFETY: `resolve` only selects Avx2 when detected.
            unsafe { gather_mb_avx2(ctx, codes, data, rows, j0, jn_tile, mb, y) };
            return;
        }
    }
    match sel.imp {
        KernelImpl::Unrolled | KernelImpl::Avx2 => {
            gather_mb_chunked(ctx, codes, data, rows, j0, jn_tile, mb, y)
        }
        _ => gather_mb_scalar(ctx, codes, data, rows, j0, jn_tile, mb, y),
    }
}

/// Reference batched gather (one batch column at a time inside the
/// per-vector loop). The SIMD paths regroup the `b` loop into 8-wide
/// chunks, which leaves every per-`b` accumulation sequence untouched —
/// hence bit-exact.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gather_mb_scalar<C: Copy + Into<usize>>(
    ctx: &GatherCtx,
    codes: &[C],
    data: &[f32],
    rows: (usize, usize),
    j0: usize,
    jn_tile: usize,
    mb: usize,
    y: &mut [f32],
) {
    let (m, v, g) = (ctx.m, ctx.v, ctx.g);
    let vectors_per_group = g / v;
    let (gpr, n, nc) = (ctx.gpr, ctx.n, ctx.nc);
    // Scratch per-batch group accumulator (mb is small: 1..64).
    let mut gacc = [0f32; 64];
    debug_assert!(mb <= 64);
    for r in rows.0..rows.1 {
        // Row's code slice for this tile is contiguous: [(r*jn)+j0 .. +jn_tile] × m.
        let base = (r * ctx.jn + j0) * m;
        let row_codes = &codes[base..base + jn_tile * m];
        let row_scales = &ctx.scales[r * gpr..(r + 1) * gpr];
        let mut j = 0usize;
        while j < jn_tile {
            // Run of vectors sharing one group scale.
            let abs_j = j0 + j;
            let group = (abs_j * v) / g;
            let run_end_abs = ((group + 1) * vectors_per_group).min(j0 + jn_tile);
            let run = run_end_abs - abs_j;
            gacc[..mb].fill(0.0);
            // SAFETY: idx < jn_tile·m and code < nc (validated), so
            // (idx·nc + code)·mb + b < data.len().
            for idx in j * m..(j + run) * m {
                let code: usize = unsafe { (*row_codes.get_unchecked(idx)).into() };
                debug_assert!(code < nc);
                let off = (idx * nc + code) * mb;
                for (b, acc) in gacc[..mb].iter_mut().enumerate() {
                    *acc += unsafe { *data.get_unchecked(off + b) };
                }
            }
            let s = row_scales[group];
            for b in 0..mb {
                y[b * n + r] += s * gacc[b];
            }
            j += run;
        }
    }
}

/// Portable batched gather: identical to [`gather_mb_scalar`] except the
/// per-vector batch loop runs in manually unrolled 8-wide chunks.
#[allow(clippy::too_many_arguments)]
fn gather_mb_chunked<C: Copy + Into<usize>>(
    ctx: &GatherCtx,
    codes: &[C],
    data: &[f32],
    rows: (usize, usize),
    j0: usize,
    jn_tile: usize,
    mb: usize,
    y: &mut [f32],
) {
    let (m, v, g) = (ctx.m, ctx.v, ctx.g);
    let vectors_per_group = g / v;
    let (gpr, n, nc) = (ctx.gpr, ctx.n, ctx.nc);
    let mut gacc = [0f32; 64];
    debug_assert!(mb <= 64);
    for r in rows.0..rows.1 {
        let base = (r * ctx.jn + j0) * m;
        let row_codes = &codes[base..base + jn_tile * m];
        let row_scales = &ctx.scales[r * gpr..(r + 1) * gpr];
        let mut j = 0usize;
        while j < jn_tile {
            let abs_j = j0 + j;
            let group = (abs_j * v) / g;
            let run_end_abs = ((group + 1) * vectors_per_group).min(j0 + jn_tile);
            let run = run_end_abs - abs_j;
            gacc[..mb].fill(0.0);
            for idx in j * m..(j + run) * m {
                let code: usize = unsafe { (*row_codes.get_unchecked(idx)).into() };
                debug_assert!(code < nc);
                let off = (idx * nc + code) * mb;
                // SAFETY: off + mb <= data.len() (same bound as the
                // reference path); b + t < mb <= 64 for gacc.
                let mut b = 0usize;
                while b + 8 <= mb {
                    for t in 0..8 {
                        unsafe {
                            *gacc.get_unchecked_mut(b + t) += *data.get_unchecked(off + b + t);
                        }
                    }
                    b += 8;
                }
                while b < mb {
                    unsafe {
                        *gacc.get_unchecked_mut(b) += *data.get_unchecked(off + b);
                    }
                    b += 1;
                }
            }
            let s = row_scales[group];
            for b in 0..mb {
                y[b * n + r] += s * gacc[b];
            }
            j += run;
        }
    }
}

/// AVX2 batched gather: the 8-wide batch chunks become `vaddps` on
/// contiguous loads (the batch axis is innermost in the book layout).
/// Lane `l` is batch column `b + l`'s scalar accumulator — bit-exact.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn gather_mb_avx2<C: Copy + Into<usize>>(
    ctx: &GatherCtx,
    codes: &[C],
    data: &[f32],
    rows: (usize, usize),
    j0: usize,
    jn_tile: usize,
    mb: usize,
    y: &mut [f32],
) {
    use std::arch::x86_64::*;
    let (m, v, g) = (ctx.m, ctx.v, ctx.g);
    let vectors_per_group = g / v;
    let (gpr, n, nc) = (ctx.gpr, ctx.n, ctx.nc);
    let mut gacc = [0f32; 64];
    debug_assert!(mb <= 64);
    let dp = data.as_ptr();
    for r in rows.0..rows.1 {
        let base = (r * ctx.jn + j0) * m;
        let row_codes = &codes[base..base + jn_tile * m];
        let row_scales = &ctx.scales[r * gpr..(r + 1) * gpr];
        let mut j = 0usize;
        while j < jn_tile {
            let abs_j = j0 + j;
            let group = (abs_j * v) / g;
            let run_end_abs = ((group + 1) * vectors_per_group).min(j0 + jn_tile);
            let run = run_end_abs - abs_j;
            gacc[..mb].fill(0.0);
            for idx in j * m..(j + run) * m {
                let code: usize = (*row_codes.get_unchecked(idx)).into();
                debug_assert!(code < nc);
                let off = (idx * nc + code) * mb;
                // SAFETY: off + mb <= data.len(); gacc holds >= mb floats.
                let mut b = 0usize;
                while b + 8 <= mb {
                    let gv = _mm256_loadu_ps(gacc.as_ptr().add(b));
                    let dv = _mm256_loadu_ps(dp.add(off + b));
                    _mm256_storeu_ps(gacc.as_mut_ptr().add(b), _mm256_add_ps(gv, dv));
                    b += 8;
                }
                while b < mb {
                    *gacc.get_unchecked_mut(b) += *data.get_unchecked(off + b);
                    b += 1;
                }
            }
            let s = row_scales[group];
            for b in 0..mb {
                y[b * n + r] += s * gacc[b];
            }
            j += run;
        }
    }
}

// ---------------------------------------------------------------------------
// Psumbook build: lanes = centroids.
// ---------------------------------------------------------------------------

/// Build the book entries for vector range `[j_lo, j_hi)`, dispatching
/// to the AVX2 build when selected and applicable (single column,
/// `v ∈ {4, 8}`, at least one full 8-centroid chunk) and to the scalar
/// reference [`psumbook::build_range`] otherwise. The AVX2 build
/// reproduces the scalar per-entry dot-product association exactly, so
/// mixing paths (e.g. a batched tile after single-column tiles) is
/// always bit-exact. Returns the MACs spent.
#[allow(clippy::too_many_arguments)]
pub fn build_range(
    sel: KernelSel,
    codebooks: &[f32],
    v: usize,
    x: &[f32],
    jn: usize,
    m: usize,
    nc: usize,
    mb: usize,
    j_lo: usize,
    j_hi: usize,
    out: &mut [f32],
) -> u64 {
    // nc is a power of two, so nc % 8 == 0 ⇔ nc >= 8.
    let use_avx2 = sel.imp == KernelImpl::Avx2 && mb == 1 && (v == 4 || v == 8) && nc % 8 == 0;
    #[cfg(target_arch = "x86_64")]
    {
        if use_avx2 {
            // SAFETY: Avx2 is only selected when detected.
            return unsafe {
                if v == 4 {
                    build_range_avx2_v4(codebooks, x, jn, m, nc, j_lo, j_hi, out)
                } else {
                    build_range_avx2_v8(codebooks, x, jn, m, nc, j_lo, j_hi, out)
                }
            };
        }
    }
    let _ = use_avx2;
    psumbook::build_range(codebooks, v, x, jn, m, nc, mb, j_lo, j_hi, out)
}

/// AVX2 single-column build, `v = 4`: 8 centroids per `__m256`, strided
/// `vgatherdps` codebook loads, combined exactly like the scalar
/// `c0·x0 + c1·x1 + c2·x2 + c3·x3` (left-associated adds, no FMA).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn build_range_avx2_v4(
    codebooks: &[f32],
    x: &[f32],
    jn: usize,
    m: usize,
    nc: usize,
    j_lo: usize,
    j_hi: usize,
    out: &mut [f32],
) -> u64 {
    use std::arch::x86_64::*;
    const V: usize = 4;
    debug_assert!(j_lo <= j_hi && j_hi <= jn);
    debug_assert_eq!(x.len(), jn * V);
    debug_assert_eq!(codebooks.len(), m * nc * V);
    debug_assert_eq!(out.len(), (j_hi - j_lo) * m * nc);
    debug_assert_eq!(nc % 8, 0);
    let vidx = _mm256_setr_epi32(
        0,
        V as i32,
        2 * V as i32,
        3 * V as i32,
        4 * V as i32,
        5 * V as i32,
        6 * V as i32,
        7 * V as i32,
    );
    for j in j_lo..j_hi {
        let xj = &x[j * V..(j + 1) * V];
        let (x0, x1, x2, x3) = (
            _mm256_set1_ps(xj[0]),
            _mm256_set1_ps(xj[1]),
            _mm256_set1_ps(xj[2]),
            _mm256_set1_ps(xj[3]),
        );
        let jo = j - j_lo;
        for c in 0..m {
            let cbp = codebooks.as_ptr().add(c * nc * V);
            let op = out.as_mut_ptr().add((jo * m + c) * nc);
            let mut i = 0usize;
            while i < nc {
                // g_t[l] = cb[(i+l)*V + t] — component t of 8 centroids.
                let base = cbp.add(i * V);
                let g0 = _mm256_i32gather_ps::<4>(base, vidx);
                let g1 = _mm256_i32gather_ps::<4>(base.add(1), vidx);
                let g2 = _mm256_i32gather_ps::<4>(base.add(2), vidx);
                let g3 = _mm256_i32gather_ps::<4>(base.add(3), vidx);
                let mut t = _mm256_add_ps(_mm256_mul_ps(g0, x0), _mm256_mul_ps(g1, x1));
                t = _mm256_add_ps(t, _mm256_mul_ps(g2, x2));
                t = _mm256_add_ps(t, _mm256_mul_ps(g3, x3));
                _mm256_storeu_ps(op.add(i), t);
                i += 8;
            }
        }
    }
    ((j_hi - j_lo) * m * nc * V) as u64
}

/// AVX2 single-column build, `v = 8`: as `v = 4` but with the scalar
/// path's two 4-term halves summed at the end (`(a) + (b)`), preserving
/// its association exactly.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn build_range_avx2_v8(
    codebooks: &[f32],
    x: &[f32],
    jn: usize,
    m: usize,
    nc: usize,
    j_lo: usize,
    j_hi: usize,
    out: &mut [f32],
) -> u64 {
    use std::arch::x86_64::*;
    const V: usize = 8;
    debug_assert!(j_lo <= j_hi && j_hi <= jn);
    debug_assert_eq!(x.len(), jn * V);
    debug_assert_eq!(codebooks.len(), m * nc * V);
    debug_assert_eq!(out.len(), (j_hi - j_lo) * m * nc);
    debug_assert_eq!(nc % 8, 0);
    let vidx = _mm256_setr_epi32(
        0,
        V as i32,
        2 * V as i32,
        3 * V as i32,
        4 * V as i32,
        5 * V as i32,
        6 * V as i32,
        7 * V as i32,
    );
    for j in j_lo..j_hi {
        let xj = &x[j * V..(j + 1) * V];
        let xb: [_; 8] = [
            _mm256_set1_ps(xj[0]),
            _mm256_set1_ps(xj[1]),
            _mm256_set1_ps(xj[2]),
            _mm256_set1_ps(xj[3]),
            _mm256_set1_ps(xj[4]),
            _mm256_set1_ps(xj[5]),
            _mm256_set1_ps(xj[6]),
            _mm256_set1_ps(xj[7]),
        ];
        let jo = j - j_lo;
        for c in 0..m {
            let cbp = codebooks.as_ptr().add(c * nc * V);
            let op = out.as_mut_ptr().add((jo * m + c) * nc);
            let mut i = 0usize;
            while i < nc {
                let base = cbp.add(i * V);
                let g = |t: usize| _mm256_i32gather_ps::<4>(base.add(t), vidx);
                let mut a = _mm256_add_ps(_mm256_mul_ps(g(0), xb[0]), _mm256_mul_ps(g(1), xb[1]));
                a = _mm256_add_ps(a, _mm256_mul_ps(g(2), xb[2]));
                a = _mm256_add_ps(a, _mm256_mul_ps(g(3), xb[3]));
                let mut b = _mm256_add_ps(_mm256_mul_ps(g(4), xb[4]), _mm256_mul_ps(g(5), xb[5]));
                b = _mm256_add_ps(b, _mm256_mul_ps(g(6), xb[6]));
                b = _mm256_add_ps(b, _mm256_mul_ps(g(7), xb[7]));
                _mm256_storeu_ps(op.add(i), _mm256_add_ps(a, b));
                i += 8;
            }
        }
    }
    ((j_hi - j_lo) * m * nc * V) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn resolve_scalar_and_lane_interactions() {
        let cfg = |imp: KernelImpl, lanes: usize| KernelConfig {
            kernel_impl: imp,
            simd_lanes: lanes,
            ..KernelConfig::default()
        };
        // Scalar always collapses to 1 lane.
        let s = resolve_with(&cfg(KernelImpl::Scalar, 16), None);
        assert_eq!(s, KernelSel { imp: KernelImpl::Scalar, lanes: 1 });
        // One lane forces scalar regardless of impl.
        let s = resolve_with(&cfg(KernelImpl::Unrolled, 1), None);
        assert_eq!(s, KernelSel { imp: KernelImpl::Scalar, lanes: 1 });
        // Unrolled keeps the configured lane width.
        let s = resolve_with(&cfg(KernelImpl::Unrolled, 16), None);
        assert_eq!(s, KernelSel { imp: KernelImpl::Unrolled, lanes: 16 });
        // Env override wins over config.
        let s = resolve_with(&cfg(KernelImpl::Unrolled, 8), Some(KernelImpl::Scalar));
        assert_eq!(s, KernelSel { imp: KernelImpl::Scalar, lanes: 1 });
        // Auto / Avx2 resolve to a concrete impl matching the host.
        for imp in [KernelImpl::Auto, KernelImpl::Avx2] {
            let s = resolve_with(&cfg(imp, 0), None);
            if avx2_available() {
                assert_eq!(s, KernelSel { imp: KernelImpl::Avx2, lanes: 8 });
            } else {
                assert_eq!(s, KernelSel { imp: KernelImpl::Unrolled, lanes: 8 });
            }
        }
    }

    /// Synthetic gather case: random codes/scales/book entries (the
    /// gather only reads the book, so its contents need not be a real
    /// build).
    struct Case {
        ctx_m: usize,
        v: usize,
        g: usize,
        gpr: usize,
        jn: usize,
        n: usize,
        nc: usize,
        scales: Vec<f32>,
        codes: Vec<u8>,
        data: Vec<f32>,
    }

    fn mk_case(n: usize, jn: usize, jn_tile: usize, m: usize, nc: usize, v: usize, mb: usize) -> Case {
        let k = jn * v;
        let g = k / 2; // two scale groups per row
        let gpr = k / g;
        let mut rng = Prng::seeded(42);
        let scales: Vec<f32> = rng.normal_vec(n * gpr, 1.0);
        let codes: Vec<u8> =
            (0..n * jn * m).map(|i| (rng.normal_vec(1, 1.0)[0].abs() * i as f32) as u8 % nc as u8).collect();
        let data = rng.normal_vec(jn_tile * m * nc * mb, 1.0);
        Case { ctx_m: m, v, g, gpr, jn, n, nc, scales, codes, data }
    }

    fn ctx(c: &Case) -> GatherCtx<'_> {
        GatherCtx {
            m: c.ctx_m,
            v: c.v,
            g: c.g,
            gpr: c.gpr,
            jn: c.jn,
            n: c.n,
            nc: c.nc,
            scales: &c.scales,
        }
    }

    #[test]
    fn lane_gathers_match_scalar_bitwise() {
        // n=13 exercises the remainder path of every lane width.
        let (n, jn, jn_tile, j0) = (13usize, 8usize, 4usize, 2usize);
        let case = mk_case(n, jn, jn_tile, 2, 16, 4, 1);
        let ctx = ctx(&case);
        let mut y_ref = vec![0.1f32; n];
        gather_b1_scalar(&ctx, &case.codes, &case.data, 0, n, j0, jn_tile, &mut y_ref);
        let mut y8 = vec![0.1f32; n];
        gather_b1_lanes::<u8, 8>(&ctx, &case.codes, &case.data, (0, n), j0, jn_tile, &mut y8);
        assert_eq!(y8, y_ref);
        let mut y16 = vec![0.1f32; n];
        gather_b1_lanes::<u8, 16>(&ctx, &case.codes, &case.data, (0, n), j0, jn_tile, &mut y16);
        assert_eq!(y16, y_ref);
        #[cfg(target_arch = "x86_64")]
        if avx2_available() {
            let mut ya = vec![0.1f32; n];
            let sel = KernelSel { imp: KernelImpl::Avx2, lanes: 8 };
            let book = Psumbook { jn: jn_tile, m: 2, nc: 16, mb: 1, data: case.data.clone() };
            gather_b1(sel, &ctx, &case.codes, &book, (0, n), j0, jn_tile, &mut ya);
            assert_eq!(ya, y_ref);
        }
    }

    #[test]
    fn batched_gathers_match_scalar_bitwise() {
        // mb=19 exercises both the 8-wide chunks and the remainder.
        let (n, jn, jn_tile, j0, mb) = (5usize, 6usize, 6usize, 0usize, 19usize);
        let case = mk_case(n, jn, jn_tile, 1, 8, 8, mb);
        let ctx = ctx(&case);
        let mut y_ref = vec![0.5f32; n * mb];
        gather_mb_scalar(&ctx, &case.codes, &case.data, (0, n), j0, jn_tile, mb, &mut y_ref);
        let mut y_ch = vec![0.5f32; n * mb];
        gather_mb_chunked(&ctx, &case.codes, &case.data, (0, n), j0, jn_tile, mb, &mut y_ch);
        assert_eq!(y_ch, y_ref);
        #[cfg(target_arch = "x86_64")]
        if avx2_available() {
            let mut ya = vec![0.5f32; n * mb];
            unsafe {
                gather_mb_avx2(&ctx, &case.codes, &case.data, (0, n), j0, jn_tile, mb, &mut ya)
            };
            assert_eq!(ya, y_ref);
        }
    }

    #[test]
    fn avx2_build_matches_scalar_bitwise() {
        if !avx2_available() {
            return;
        }
        let sel = KernelSel { imp: KernelImpl::Avx2, lanes: 8 };
        for (v, m, nc, jn) in [(4usize, 2usize, 8usize, 5usize), (8, 1, 16, 3), (4, 1, 256, 2)] {
            let mut rng = Prng::seeded(9);
            let codebooks = rng.normal_vec(m * nc * v, 1.0);
            let x = rng.normal_vec(jn * v, 1.0);
            let mut scalar = vec![f32::NAN; jn * m * nc];
            let macs_s =
                psumbook::build_range(&codebooks, v, &x, jn, m, nc, 1, 0, jn, &mut scalar);
            let mut simd = vec![f32::NAN; jn * m * nc];
            let macs_v = build_range(sel, &codebooks, v, &x, jn, m, nc, 1, 0, jn, &mut simd);
            assert_eq!(macs_v, macs_s);
            assert_eq!(simd, scalar, "v={v} m={m} nc={nc}");
            // Split ranges write identical slices.
            let stride = m * nc;
            let mut split = vec![f32::NAN; jn * m * nc];
            let (lo, hi) = split.split_at_mut(stride);
            build_range(sel, &codebooks, v, &x, jn, m, nc, 1, 0, 1, lo);
            build_range(sel, &codebooks, v, &x, jn, m, nc, 1, 1, jn, hi);
            assert_eq!(split, scalar);
        }
    }

    #[test]
    fn small_nc_build_falls_back_to_scalar() {
        // nc=4 (< one AVX2 chunk) must route to the scalar build even
        // when Avx2 is selected.
        let sel = KernelSel { imp: KernelImpl::Avx2, lanes: 8 };
        let (v, m, nc, jn) = (4usize, 1usize, 4usize, 3usize);
        let mut rng = Prng::seeded(10);
        let codebooks = rng.normal_vec(m * nc * v, 1.0);
        let x = rng.normal_vec(jn * v, 1.0);
        let mut a = vec![0f32; jn * m * nc];
        let mut b = vec![0f32; jn * m * nc];
        build_range(sel, &codebooks, v, &x, jn, m, nc, 1, 0, jn, &mut a);
        psumbook::build_range(&codebooks, v, &x, jn, m, nc, 1, 0, jn, &mut b);
        assert_eq!(a, b);
    }
}
