//! CPU reference engines for every matrix-multiplication kernel in the
//! paper's evaluation (§3, §4).
//!
//! Each engine computes `Y (n × M) = W (n × k) · X (k × M)` for its weight
//! format and maintains exact work/traffic counters (MACs, table lookups,
//! bytes touched per memory class, per-phase time) so the benches can
//! report both *measured CPU wall-clock* and the *derived counts* that
//! feed the A100 analytic model.
//!
//! Activation/batch layout: `X` is batch-major (`x[b*k .. (b+1)*k]` is
//! column `b`), outputs likewise (`y[b*n .. (b+1)*n]`).
//!
//! ## Parallel execution
//!
//! Every engine here is single-threaded by design — one engine models one
//! GPU thread block's work. Multi-core execution is layered on top by
//! `crate::parallel`: a `ShardPlan` splits the row dim, each shard gets a
//! complete engine over its row slice (with its own Psumbook/LUT/decode
//! scratch, like a thread-block-local table), and `ShardedEngine` fans
//! `gemm`/`gemv` out over the worker pool, concatenating outputs in shard
//! order. Because a row's accumulation never crosses shards, sharded
//! outputs are bit-exact vs. serial; reduction-dim sharding (`TpLinear`)
//! instead uses a deterministic ordered reduction and is exact up to
//! float reassociation. Counters merge additively across shards
//! (`lookups`/`read_ops`/`mac_flops` are conserved; per-row-block build
//! work scales with the shard count, exactly as it does with GPU grid
//! size).

pub mod codegemm;
pub mod dense;
pub mod dequant;
pub mod lutgemm;
pub mod psumbook;
pub mod tiling;
pub mod traffic;
pub mod uniform_gemm;

pub use codegemm::CodeGemmEngine;
pub use dense::DenseEngine;
pub use dequant::DequantEngine;
pub use lutgemm::LutGemmEngine;
pub use psumbook::Psumbook;
pub use traffic::Counters;
pub use uniform_gemm::UniformGemmEngine;

/// Common interface over all kernel implementations.
pub trait GemmEngine {
    /// Kernel name as used in the paper's tables.
    fn name(&self) -> &'static str;

    /// `(n, k)` weight dimensions.
    fn dims(&self) -> (usize, usize);

    /// Single-vector product `y = W x` (`x.len() == k`).
    fn gemv(&mut self, x: &[f32]) -> Vec<f32> {
        self.gemm(x, 1)
    }

    /// Batched product. `x.len() == k * m_batch`, returns `n * m_batch`.
    fn gemm(&mut self, x: &[f32], m_batch: usize) -> Vec<f32>;

    /// Work/traffic counters accumulated since the last reset.
    fn counters(&self) -> &Counters;

    fn reset_counters(&mut self);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantConfig;
    use crate::quant::{QuantizedLinear, Quantizer};
    use crate::util::prng::Prng;
    use crate::util::stats;

    fn setup(n: usize, k: usize, cfg: QuantConfig) -> (Vec<f32>, QuantizedLinear) {
        let w = Prng::seeded(99).normal_vec(n * k, 0.02);
        let q = Quantizer::new(cfg).quantize(&w, n, k);
        (w, q)
    }

    /// THE central correctness property of the paper: CodeGEMM computes
    /// exactly the same result as dequantize-then-GEMM, because the
    /// Psumbook gather is algebraically identical to reconstructing the
    /// weights (§3 Methodology).
    #[test]
    fn codegemm_matches_dequantized_dense_exactly() {
        for label in ["m1v4g-1", "m2v8g32", "m1v8g16", "m3v4g64"] {
            let cfg = QuantConfig::parse_label(label).unwrap();
            let (_, q) = setup(64, 128, cfg);
            let wq = q.dequantize();
            let mut rng = Prng::seeded(5);
            let x = rng.normal_vec(128, 1.0);
            let mut dense = DenseEngine::new(wq, 64, 128);
            let mut cg = CodeGemmEngine::from_quantized(&q);
            let y_ref = dense.gemv(&x);
            let y = cg.gemv(&x);
            let rel = stats::rel_l2(&y, &y_ref);
            assert!(rel < 2e-5, "{label}: rel={rel}");
        }
    }

    #[test]
    fn all_quantized_engines_agree_with_their_dequantized_weights() {
        let cfg = QuantConfig::new(4, 2, 6, 32).unwrap();
        let (_, q) = setup(48, 64, cfg);
        let x = Prng::seeded(6).normal_vec(64 * 3, 1.0);
        let wq = q.dequantize();
        let y_ref = DenseEngine::new(wq, 48, 64).gemm(&x, 3);
        let mut cg = CodeGemmEngine::from_quantized(&q);
        let mut dq = DequantEngine::from_quantized(&q);
        assert!(stats::rel_l2(&cg.gemm(&x, 3), &y_ref) < 2e-5);
        assert!(stats::rel_l2(&dq.gemm(&x, 3), &y_ref) < 2e-5);
    }

    #[test]
    fn engines_report_dims_and_counters() {
        let cfg = QuantConfig::m1v4g128();
        let (_, q) = setup(32, 128, cfg);
        let mut cg = CodeGemmEngine::from_quantized(&q);
        assert_eq!(cg.dims(), (32, 128));
        let x = vec![1.0f32; 128];
        let _ = cg.gemv(&x);
        assert!(cg.counters().mac_flops > 0);
        cg.reset_counters();
        assert_eq!(cg.counters().mac_flops, 0);
    }
}
