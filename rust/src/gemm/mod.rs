//! CPU reference engines for every matrix-multiplication kernel in the
//! paper's evaluation (§3, §4).
//!
//! Each engine computes `Y (n × M) = W (n × k) · X (k × M)` for its weight
//! format and maintains exact work/traffic counters (MACs, table lookups,
//! bytes touched per memory class, per-phase time) so the benches can
//! report both *measured CPU wall-clock* and the *derived counts* that
//! feed the A100 analytic model.
//!
//! Activation/batch layout: `X` is batch-major (`x[b*k .. (b+1)*k]` is
//! column `b`), outputs likewise (`y[b*n .. (b+1)*n]`).
//!
//! ## Execution model: `&self` engines, caller-owned outputs and scratch
//!
//! The core entry point is [`GemmEngine::gemm_into`]: the caller owns the
//! output slice *and* an [`EngineScratch`] holding every internal buffer
//! (Psumbook / LUT / decode staging) plus the work counters. Engines are
//! therefore immutable (`&self`) during execution and `Sync`-shareable —
//! one engine can serve many threads, each bringing its own scratch and a
//! disjoint output region — and the decode hot loop performs **zero heap
//! allocations after warmup**, because scratch buffers grow to their
//! high-water mark once and are then reused verbatim. This mirrors what
//! LUT-GEMM and VQ-LLM report for GPU table kernels: the inner loop must
//! write into preallocated, tile-resident buffers or the allocator (and
//! not the build/read split the paper measures) dominates.
//!
//! `gemm`/`gemv` remain as thin allocating compatibility wrappers driving
//! `gemm_into` through the engine's built-in scratch.
//!
//! ## Parallel execution
//!
//! Every engine here is single-threaded by design — one engine models one
//! GPU thread block's work. Multi-core execution is layered on top by
//! `crate::parallel`: a `ShardPlan` splits the row dim, each shard gets a
//! complete engine over its row slice, and `ShardedEngine` fans `gemm_into`
//! out over the worker pool — each worker writing a disjoint sub-slice of
//! the caller's output buffer with its own per-worker scratch. Because a
//! row's accumulation never crosses shards, sharded outputs are bit-exact
//! vs. serial; reduction-dim sharding (`TpLinear`) instead uses a
//! deterministic ordered reduction and is exact up to float
//! reassociation. Counters merge additively across shards
//! ([`Counters::merge`]; `lookups`/`read_ops`/`mac_flops` are conserved).
//! For CodeGEMM shards the default schedule is **build once / gather
//! many**: one shared Psumbook per k-tile in the caller's scratch
//! (assembled in parallel via [`psumbook::build_range`], gathered by
//! every shard through [`CodeGemmEngine::gather_into`]), so build MACs
//! are counted once per logical call regardless of shard count. Private
//! per-shard tables (build work scaling with grid size, as on the GPU)
//! remain available via `ShardedEngine::with_shared_book(false)`.
//!
//! ## Fused projection groups
//!
//! Projections that consume the *same* activation vector (a layer's
//! Q/K/V, an MLP's gate/up) and share codebooks (quantized jointly over
//! their stacked rows) fuse into one [`GemmGroup`] call: per k-tile, ONE
//! Psumbook is built and then gathered by every row of every member —
//! the Eq. 3 amortization extended across *both* the row shards and the
//! member projections (the shard × member gather matrix of
//! `crate::parallel::fanout`). Build work is counted once per group call
//! ([`Counters::group_fanout`] records the members amortizing it), so
//! decode-time build MACs per layer drop ~3× for attention and ~2× for
//! the MLP. Mismatched member formats — or the `fused_projections`
//! toggle turned off — fall back to independent per-member calls with
//! identical (bit-exact) outputs.
//!
//! ## Kernel dispatch and the software pipeline
//!
//! Both CodeGEMM phases run through runtime-dispatched SIMD kernels in
//! [`simd`]: the Psumbook build vectorizes over centroids and the gather
//! lane-parallelizes over output rows (decode) or batch columns
//! (prefill), with an AVX2 path selected via CPU detection and a
//! portable unrolled-lane fallback. The implementation and lane width
//! are [`crate::config::KernelConfig`] knobs (`kernel_impl`,
//! `simd_lanes`), resolved once per engine by [`simd::resolve`] and
//! overridable via the `CODEGEMM_KERNEL` env var; every variant is
//! **bit-exact** against the scalar reference because lanes are always
//! independent accumulators — no reduction is ever split across lanes.
//! On top, the shared-book schedule software-pipelines its k-tiles
//! (`KernelConfig::pipeline_tiles`): tile `t+1`'s book build runs inside
//! the same pool scope as tile `t`'s gather, double-buffered through
//! [`EngineScratch::book`]/`book2` — see `crate::parallel::fanout`.

pub mod codegemm;
pub mod dense;
pub mod dequant;
pub mod group;
pub mod lutgemm;
pub mod psumbook;
pub mod scratch;
pub mod simd;
pub mod tiling;
pub mod traffic;
pub mod uniform_gemm;

pub use codegemm::CodeGemmEngine;
pub use dense::DenseEngine;
pub use dequant::DequantEngine;
pub use group::{GemmGroup, GroupMember};
pub use lutgemm::LutGemmEngine;
pub use psumbook::Psumbook;
pub use scratch::EngineScratch;
pub use simd::KernelSel;
pub use traffic::Counters;
pub use uniform_gemm::UniformGemmEngine;

/// Common interface over all kernel implementations.
pub trait GemmEngine {
    /// Kernel name as used in the paper's tables.
    fn name(&self) -> &'static str;

    /// `(n, k)` weight dimensions.
    fn dims(&self) -> (usize, usize);

    /// Zero-allocation batched product: write `W · X` into the
    /// caller-owned `y` (`n * m_batch`, batch-major, fully overwritten),
    /// drawing every internal buffer from — and accumulating counters
    /// into — the caller-owned `scratch`. `x.len() == k * m_batch`.
    fn gemm_into(&self, x: &[f32], m_batch: usize, y: &mut [f32], scratch: &mut EngineScratch);

    /// The engine's built-in scratch, used by the allocating
    /// compatibility wrappers and the [`GemmEngine::counters`] view.
    fn scratch(&self) -> &EngineScratch;
    fn scratch_mut(&mut self) -> &mut EngineScratch;

    /// Single-vector `gemm_into` (`y.len() == n`).
    fn gemv_into(&self, x: &[f32], y: &mut [f32], scratch: &mut EngineScratch) {
        self.gemm_into(x, 1, y, scratch);
    }

    /// Single-vector product `y = W x` (allocating compatibility wrapper).
    fn gemv(&mut self, x: &[f32]) -> Vec<f32> {
        self.gemm(x, 1)
    }

    /// Batched product (allocating compatibility wrapper over
    /// [`GemmEngine::gemm_into`] and the built-in scratch).
    ///
    /// The built-in scratch is taken out for the duration of the call
    /// (so `gemm_into` can borrow `self` immutably) and restored **on
    /// the unwind path too**: a panicking `gemm_into` (e.g. a shape
    /// assert) must not discard the scratch buffers and the counters
    /// accumulated by earlier successful calls.
    fn gemm(&mut self, x: &[f32], m_batch: usize) -> Vec<f32> {
        let n = self.dims().0;
        let mut y = vec![0f32; n * m_batch];
        let mut scratch = std::mem::take(self.scratch_mut());
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.gemm_into(x, m_batch, &mut y, &mut scratch)
        }));
        *self.scratch_mut() = scratch;
        if let Err(payload) = result {
            std::panic::resume_unwind(payload);
        }
        y
    }

    /// Work/traffic counters accumulated by calls made through the
    /// built-in scratch (i.e. the wrapper methods) since the last reset.
    fn counters(&self) -> &Counters {
        &self.scratch().counters
    }

    fn reset_counters(&mut self) {
        self.scratch_mut().counters.reset();
    }

    /// Downcast hook for wrappers that specialize on the CodeGEMM engine:
    /// `crate::parallel::ShardedEngine` uses it to detect that every row
    /// shard is a [`CodeGemmEngine`] and switch to the shared-Psumbook
    /// build-once/gather-many schedule. Other engines keep the `None`
    /// default.
    fn as_codegemm(&self) -> Option<&CodeGemmEngine> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantConfig;
    use crate::quant::{QuantizedLinear, Quantizer};
    use crate::util::prng::Prng;
    use crate::util::stats;

    fn setup(n: usize, k: usize, cfg: QuantConfig) -> (Vec<f32>, QuantizedLinear) {
        let w = Prng::seeded(99).normal_vec(n * k, 0.02);
        let q = Quantizer::new(cfg).quantize(&w, n, k);
        (w, q)
    }

    /// THE central correctness property of the paper: CodeGEMM computes
    /// exactly the same result as dequantize-then-GEMM, because the
    /// Psumbook gather is algebraically identical to reconstructing the
    /// weights (§3 Methodology).
    #[test]
    fn codegemm_matches_dequantized_dense_exactly() {
        for label in ["m1v4g-1", "m2v8g32", "m1v8g16", "m3v4g64"] {
            let cfg = QuantConfig::parse_label(label).unwrap();
            let (_, q) = setup(64, 128, cfg);
            let wq = q.dequantize();
            let mut rng = Prng::seeded(5);
            let x = rng.normal_vec(128, 1.0);
            let mut dense = DenseEngine::new(wq, 64, 128);
            let mut cg = CodeGemmEngine::from_quantized(&q);
            let y_ref = dense.gemv(&x);
            let y = cg.gemv(&x);
            let rel = stats::rel_l2(&y, &y_ref);
            assert!(rel < 2e-5, "{label}: rel={rel}");
        }
    }

    #[test]
    fn all_quantized_engines_agree_with_their_dequantized_weights() {
        let cfg = QuantConfig::new(4, 2, 6, 32).unwrap();
        let (_, q) = setup(48, 64, cfg);
        let x = Prng::seeded(6).normal_vec(64 * 3, 1.0);
        let wq = q.dequantize();
        let y_ref = DenseEngine::new(wq, 48, 64).gemm(&x, 3);
        let mut cg = CodeGemmEngine::from_quantized(&q);
        let mut dq = DequantEngine::from_quantized(&q);
        assert!(stats::rel_l2(&cg.gemm(&x, 3), &y_ref) < 2e-5);
        assert!(stats::rel_l2(&dq.gemm(&x, 3), &y_ref) < 2e-5);
    }

    #[test]
    fn engines_report_dims_and_counters() {
        let cfg = QuantConfig::m1v4g128();
        let (_, q) = setup(32, 128, cfg);
        let mut cg = CodeGemmEngine::from_quantized(&q);
        assert_eq!(cg.dims(), (32, 128));
        let x = vec![1.0f32; 128];
        let _ = cg.gemv(&x);
        assert!(cg.counters().mac_flops > 0);
        cg.reset_counters();
        assert_eq!(cg.counters().mac_flops, 0);
    }

    /// `gemm_into` must match the wrapper bit-for-bit, overwrite whatever
    /// garbage the output buffer held, and tolerate a scratch that was
    /// last used by a *different* engine and shape.
    #[test]
    fn gemm_into_matches_wrapper_with_dirty_shared_scratch() {
        let cfg = QuantConfig::new(4, 2, 6, 32).unwrap();
        let (w, q) = setup(48, 64, cfg);
        let x = Prng::seeded(7).normal_vec(64 * 2, 1.0);
        let mut shared = EngineScratch::new();

        let cg = CodeGemmEngine::from_quantized(&q);
        let dq = DequantEngine::from_quantized(&q);
        let dense = DenseEngine::new(w.clone(), 48, 64);

        let mut y = vec![f32::NAN; 48 * 2];
        cg.gemm_into(&x, 2, &mut y, &mut shared);
        assert_eq!(y, CodeGemmEngine::from_quantized(&q).gemm(&x, 2));

        // Same scratch, different engine family + batch size.
        let mut y1 = vec![f32::NAN; 48];
        dq.gemm_into(&x[..64], 1, &mut y1, &mut shared);
        assert_eq!(y1, DequantEngine::from_quantized(&q).gemv(&x[..64]));

        let mut yd = vec![f32::NAN; 48 * 2];
        dense.gemm_into(&x, 2, &mut yd, &mut shared);
        assert_eq!(yd, DenseEngine::new(w, 48, 64).gemm(&x, 2));

        // The shared scratch accumulated counters from all three calls.
        assert_eq!(shared.counters.calls, 3);
    }

    /// A panic inside `gemm_into` (here: a shape assert) must not lose
    /// the engine's built-in scratch: the wrapper restores it on the
    /// unwind path, so counters from earlier calls survive and the
    /// engine keeps working afterwards.
    #[test]
    fn wrapper_restores_scratch_when_gemm_into_panics() {
        let cfg = QuantConfig::new(4, 1, 6, 32).unwrap();
        let (_, q) = setup(32, 64, cfg);
        let mut e = CodeGemmEngine::from_quantized(&q);
        let x = Prng::seeded(23).normal_vec(64, 1.0);
        let y_ok = e.gemv(&x);
        let counters_before = e.counters().clone();
        assert_eq!(counters_before.calls, 1);
        let footprint_before = e.scratch().footprint_bytes();
        assert!(footprint_before > 0, "warm scratch must hold buffers");

        // Wrong activation length trips the engine's shape assert.
        let bad = vec![0f32; 7];
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| e.gemm(&bad, 1)));
        assert!(panicked.is_err(), "shape mismatch must panic");

        // Scratch (buffers + accumulated counters) survived the unwind …
        assert_eq!(*e.counters(), counters_before, "counters lost on panic");
        assert_eq!(e.scratch().footprint_bytes(), footprint_before, "buffers lost on panic");
        // … and the engine still computes correctly.
        assert_eq!(e.gemv(&x), y_ok);
        assert_eq!(e.counters().calls, 2);
    }

    /// After the first call, repeated same-shape calls must not grow any
    /// scratch buffer (the zero-allocation steady state).
    #[test]
    fn scratch_reaches_steady_state_after_warmup() {
        let cfg = QuantConfig::new(4, 2, 6, 32).unwrap();
        let (_, q) = setup(48, 64, cfg);
        let x = Prng::seeded(8).normal_vec(64 * 4, 1.0);
        let e = CodeGemmEngine::from_quantized(&q);
        let mut scratch = EngineScratch::new();
        let mut y = vec![0f32; 48 * 4];
        e.gemm_into(&x, 4, &mut y, &mut scratch);
        let footprint = scratch.footprint_bytes();
        for _ in 0..3 {
            e.gemm_into(&x, 4, &mut y, &mut scratch);
        }
        assert_eq!(scratch.footprint_bytes(), footprint, "steady state must not grow");
    }
}
