//! Fused projection groups: N CodeGEMM engines that share one input
//! activation (Q/K/V of an attention block, gate/up of a SwiGLU MLP)
//! executed as **one** build-once/gather-many call.
//!
//! The Psumbook for a k-tile depends only on the staged activations and
//! the codebooks — never on which output rows read it (`psumbook`). A
//! layer's Q/K/V projections consume the *same* normed hidden vector, so
//! when they also share codebooks (the factory quantizes the stacked
//! `[wq; wk; wv]` rows jointly, exactly like row shards are sliced from
//! one quantized layer), one book per k-tile can serve every row of
//! every projection. [`GemmGroup`] is that scheduler:
//!
//! - **serial** (no worker pool): per k-tile, build the book once in the
//!   caller's [`EngineScratch`], then every member gathers all of its
//!   rows from it into its own caller-owned output slice;
//! - **sharded** (worker pool): `parallel::fanout::shared_book_fan_out_multi`
//!   builds the book by j-ranges over the pool (phase 1) and fans the
//!   gather out over the full **shard × member matrix** (phase 2) — the
//!   book is shared across *both* axes.
//!
//! Outputs are bit-exact vs. running the members independently: each
//! row still accumulates its k-tiles in ascending order against
//! bit-identical book entries. Build MACs/bytes/time are attributed
//! **once per group call** regardless of member or shard count
//! ([`Counters::group_fanout`] records how many member GEMMs shared each
//! build), so at decode (`M = 1`) a fused Q/K/V drops per-layer build
//! work 3× and gate/up 2× — the Eq. 3 amortization extended across
//! projections, the regime LUT-GEMM and VQ-LLM report as decisive for
//! table-kernel throughput.
//!
//! Members whose formats do not match (different `QuantConfig`, tile
//! width or codebooks), or a group constructed with fusion disabled
//! ([`GemmGroup::with_fused`]), fall back to correct **independent**
//! execution: each member runs exactly as an ungrouped (possibly
//! row-sharded) engine would, one logical call per member.

use crate::gemm::scratch::EngineScratch;
use crate::gemm::tiling::Tiles;
use crate::gemm::{CodeGemmEngine, GemmEngine};
use crate::parallel::fanout::{self, GroupMemberRef, ShardRef};
use crate::parallel::ShardPlan;
use crate::util::threadpool::ThreadPool;
use crate::util::timer::Timer;
use std::sync::Arc;

/// One projection of a fused group: its row shards plus the plan that
/// places them (a serial member is one shard covering all rows).
pub struct GroupMember {
    plan: ShardPlan,
    shards: Vec<CodeGemmEngine>,
}

impl GroupMember {
    /// An unsharded member: one engine owning every output row.
    pub fn serial(engine: CodeGemmEngine) -> GroupMember {
        let n = engine.dims().0;
        GroupMember { plan: ShardPlan::serial(n), shards: vec![engine] }
    }

    /// A row-sharded member: `shards[i]` computes the rows of
    /// `plan.range(i)`.
    pub fn sharded(plan: ShardPlan, shards: Vec<CodeGemmEngine>) -> GroupMember {
        assert_eq!(plan.num_shards(), shards.len(), "one engine per shard");
        assert!(!shards.is_empty(), "member needs at least one shard");
        for (i, e) in shards.iter().enumerate() {
            let (r0, r1) = plan.range(i);
            assert_eq!(e.dims().0, r1 - r0, "shard {i} row count mismatch");
        }
        GroupMember { plan, shards }
    }

    /// Output rows of this member.
    pub fn n(&self) -> usize {
        self.plan.len
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn shards(&self) -> &[CodeGemmEngine] {
        &self.shards
    }
}

/// A set of CodeGEMM engines over the same activations fused around one
/// shared Psumbook build per k-tile. See the module docs for the
/// schedule; see `model::ProjectionSet` for the layer-level wiring.
pub struct GemmGroup {
    members: Vec<GroupMember>,
    /// Reduction dim shared by every member.
    k: usize,
    /// Aligned k-tile width shared by every member shard (valid when
    /// `fusable`).
    tile_w: usize,
    /// Fused schedule requested (the `fused_projections` toggle).
    fused: bool,
    /// All member shards share config/codebooks/tile geometry (computed
    /// once at construction) — the precondition for one shared book.
    fusable: bool,
    /// Per member: its *own* shards are book-compatible with each other
    /// (the independent fallback then still shares one book per member,
    /// as an ungrouped `ShardedEngine` would).
    member_compat: Vec<bool>,
    /// Use per-member shared books on the independent fallback
    /// (`ParallelConfig::shared_psumbook`).
    shared_psumbook: bool,
    /// Worker pool for sharded members / the parallel fused schedule.
    pool: Option<Arc<ThreadPool>>,
}

impl GemmGroup {
    /// Wrap pre-built members. All shards of all members must share the
    /// reduction dim `k`; sharded members require a worker pool. Whether
    /// the group can actually fuse (identical `QuantConfig`, codebooks
    /// and aligned tile width across every shard of every member) is
    /// detected here once — incompatible members are *not* an error,
    /// they simply execute on the independent fallback.
    pub fn new(members: Vec<GroupMember>, pool: Option<Arc<ThreadPool>>) -> GemmGroup {
        assert!(!members.is_empty(), "group needs at least one member");
        let k = members[0].shards[0].dims().1;
        for (i, m) in members.iter().enumerate() {
            for e in &m.shards {
                assert_eq!(e.dims().1, k, "member {i} reduction dim mismatch");
            }
            assert!(pool.is_some() || m.plan.is_serial(), "sharded member {i} needs a worker pool");
        }
        let all: Vec<&CodeGemmEngine> = members.iter().flat_map(|m| m.shards.iter()).collect();
        let fusable = fanout::shared_book_compatible(&all);
        let member_compat: Vec<bool> = members
            .iter()
            .map(|m| fanout::shared_book_compatible(&m.shards.iter().collect::<Vec<_>>()))
            .collect();
        let tile_w = members[0].shards[0].kernel_config().tile_w;
        GemmGroup {
            members,
            k,
            tile_w,
            fused: true,
            fusable,
            member_compat,
            shared_psumbook: true,
            pool,
        }
    }

    /// Enable/disable the fused schedule (on by default). Off, members
    /// execute independently — same outputs, one build per member — so
    /// the group amortization stays directly measurable.
    pub fn with_fused(mut self, on: bool) -> GemmGroup {
        self.fused = on;
        self
    }

    /// Honor `ParallelConfig::shared_psumbook` (on by default). Off
    /// means *private per-tile tables everywhere* — the measurement
    /// baseline — so it vetoes the fused schedule too (fusion IS
    /// build-sharing) and the independent fallback uses private
    /// per-shard books instead of one book per member.
    pub fn with_shared_psumbook(mut self, on: bool) -> GemmGroup {
        self.shared_psumbook = on;
        self
    }

    /// True when calls take the one-shared-build fused path. Requires
    /// the shared-Psumbook toggle: `shared_psumbook = false` requests
    /// private tables, which a fused group cannot provide.
    pub fn uses_fused(&self) -> bool {
        self.fused && self.fusable && self.shared_psumbook
    }

    /// True when every member shard shares format and tile geometry.
    pub fn is_fusable(&self) -> bool {
        self.fusable
    }

    pub fn num_members(&self) -> usize {
        self.members.len()
    }

    pub fn members(&self) -> &[GroupMember] {
        &self.members
    }

    /// `(n, k)` of member `i`.
    pub fn member_dims(&self, i: usize) -> (usize, usize) {
        (self.members[i].n(), self.k)
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Run the whole group against one activation batch: member `i`'s
    /// `n_i × m_batch` product is written into `outs[i]` (batch-major,
    /// fully overwritten), with every internal buffer drawn from — and
    /// all work counters accumulated into — the caller-owned `scratch`.
    ///
    /// Fused: one logical call (`calls += 1`), build work counted once,
    /// `group_fanout += members`. Independent fallback: one logical call
    /// per member, exactly as ungrouped engines would count.
    pub fn gemm_group_into(
        &self,
        x: &[f32],
        m_batch: usize,
        outs: &mut [&mut [f32]],
        scratch: &mut EngineScratch,
    ) {
        assert_eq!(outs.len(), self.members.len(), "one output slice per member");
        assert_eq!(x.len(), self.k * m_batch, "activation length mismatch");
        assert!(m_batch >= 1 && m_batch <= 64, "engine supports m_batch <= 64");
        for (member, y) in self.members.iter().zip(outs.iter()) {
            assert_eq!(y.len(), member.n() * m_batch, "member output length mismatch");
        }
        if !self.uses_fused() {
            return self.independent(x, m_batch, outs, scratch);
        }
        match &self.pool {
            Some(pool) => {
                let refs: Vec<GroupMemberRef<'_, CodeGemmEngine>> = self
                    .members
                    .iter()
                    .map(|m| GroupMemberRef { engines: &m.shards, plan: &m.plan })
                    .collect();
                fanout::shared_book_fan_out_multi(pool, &refs, x, m_batch, outs, scratch);
            }
            None => self.fused_serial(x, m_batch, outs, scratch),
        }
        scratch.counters.group_fanout += self.members.len() as u64;
    }

    /// Serial fused schedule: per k-tile, build the one book on the
    /// caller's thread, then each member gathers all of its rows from it
    /// (members are unsharded here — construction requires a pool
    /// otherwise).
    fn fused_serial(
        &self,
        x: &[f32],
        m_batch: usize,
        outs: &mut [&mut [f32]],
        scratch: &mut EngineScratch,
    ) {
        debug_assert!(self.members.iter().all(|m| m.plan.is_serial()));
        let e0 = &self.members[0].shards[0];
        let EngineScratch { counters, buf, book, .. } = scratch;
        // Gathers accumulate across k-tiles: zero once up front.
        for y in outs.iter_mut() {
            y.fill(0.0);
        }
        for (c0, c1) in Tiles::new(self.k, self.tile_w) {
            // One build serves every member (attributed once, the same
            // accounting as the serial engine's own build phase).
            e0.build_book(x, m_batch, c0, c1, book, buf, counters);
            let t = Timer::start();
            for (member, y) in self.members.iter().zip(outs.iter_mut()) {
                member.shards[0].gather_into(book, c0, m_batch, y, counters);
            }
            counters.read_seconds += t.elapsed_s();
        }
        // Each member streams its own per-(row, group) scales once per
        // logical call.
        counters.weight_bytes +=
            self.members.iter().map(|m| m.shards[0].scales_stream_bytes()).sum::<u64>();
        counters.calls += 1;
    }

    /// Independent fallback: each member executes exactly as an
    /// ungrouped engine of the same shape would — serial `gemm_into`, or
    /// the per-member shared-book / private-table fan-out when sharded.
    fn independent(
        &self,
        x: &[f32],
        m_batch: usize,
        outs: &mut [&mut [f32]],
        scratch: &mut EngineScratch,
    ) {
        for ((member, compat), y) in
            self.members.iter().zip(&self.member_compat).zip(outs.iter_mut())
        {
            if member.plan.is_serial() {
                member.shards[0].gemm_into(x, m_batch, y, scratch);
                continue;
            }
            let pool = self.pool.as_ref().expect("sharded member needs a pool");
            if self.shared_psumbook && *compat {
                fanout::shared_book_fan_out(pool, &member.shards, &member.plan, x, m_batch, y, scratch);
            } else {
                let ns = member.plan.num_shards();
                let EngineScratch { counters, buf2, children, .. } = scratch;
                if children.len() < ns {
                    children.resize_with(ns, EngineScratch::new);
                }
                let engines: Vec<ShardRef> = member.shards.iter().map(|e| e as ShardRef).collect();
                fanout::column_fan_out(
                    pool,
                    &engines,
                    &member.plan,
                    x,
                    m_batch,
                    y,
                    buf2,
                    &mut children[..ns],
                );
                fanout::merge_children_into(counters, &mut children[..ns]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantConfig;
    use crate::gemm::Counters;
    use crate::parallel::shard;
    use crate::quant::{QuantizedLinear, Quantizer};
    use crate::util::prng::Prng;

    /// Quantize the stacked member rows jointly (shared codebooks — the
    /// factory's group construction) and slice members back out.
    fn stacked(ns: &[usize], k: usize, label: &str, seed: u64) -> (QuantizedLinear, Vec<QuantizedLinear>) {
        let n_total: usize = ns.iter().sum();
        let w = Prng::seeded(seed).normal_vec(n_total * k, 0.02);
        let q = Quantizer::new(QuantConfig::parse_label(label).unwrap()).quantize(&w, n_total, k);
        let codes = q.codes.unpack();
        let mut parts = Vec::new();
        let mut r = 0usize;
        for &n in ns {
            parts.push(shard::slice_rows_unpacked(&q, &codes, r, r + n));
            r += n;
        }
        (q, parts)
    }

    fn serial_group(parts: &[QuantizedLinear]) -> GemmGroup {
        GemmGroup::new(
            parts.iter().map(|p| GroupMember::serial(CodeGemmEngine::from_quantized(p))).collect(),
            None,
        )
    }

    /// Independent reference: each member's serial engine on its own.
    fn reference(parts: &[QuantizedLinear], x: &[f32], mb: usize) -> (Vec<Vec<f32>>, Counters) {
        let mut counters = Counters::new();
        let ys = parts
            .iter()
            .map(|p| {
                let mut e = CodeGemmEngine::from_quantized(p);
                let y = e.gemm(x, mb);
                counters.merge(e.counters());
                y
            })
            .collect();
        (ys, counters)
    }

    #[test]
    fn fused_group_is_bit_exact_vs_independent_members() {
        let (ns, k) = ([24usize, 8, 8], 96);
        let (_, parts) = stacked(&ns, k, "m2v4g32", 1);
        let group = serial_group(&parts);
        assert!(group.is_fusable() && group.uses_fused());
        for mb in [1usize, 3] {
            let x = Prng::seeded(2 + mb as u64).normal_vec(k * mb, 1.0);
            let (y_ref, _) = reference(&parts, &x, mb);
            let mut outs: Vec<Vec<f32>> = ns.iter().map(|&n| vec![f32::NAN; n * mb]).collect();
            let mut scratch = EngineScratch::new();
            {
                let mut views: Vec<&mut [f32]> = outs.iter_mut().map(|y| y.as_mut_slice()).collect();
                group.gemm_group_into(&x, mb, &mut views, &mut scratch);
            }
            for (i, (y, want)) in outs.iter().zip(&y_ref).enumerate() {
                assert_eq!(y, want, "member {i} diverged (mb={mb})");
            }
        }
    }

    #[test]
    fn fused_group_counts_build_once_and_records_fanout() {
        // tile_h (default 2048) covers every member's rows, so each
        // independent member builds exactly once per k-tile: the fused
        // group's build MACs must be the independent total divided by
        // the member count — the pinned group factor.
        let (ns, k) = ([16usize, 16, 16], 128);
        let (_, parts) = stacked(&ns, k, "m1v4g32", 3);
        let group = serial_group(&parts);
        let x = Prng::seeded(4).normal_vec(k, 1.0);
        let (_, independent) = reference(&parts, &x, 1);
        let mut outs: Vec<Vec<f32>> = ns.iter().map(|&n| vec![0f32; n]).collect();
        let mut scratch = EngineScratch::new();
        {
            let mut views: Vec<&mut [f32]> = outs.iter_mut().map(|y| y.as_mut_slice()).collect();
            group.gemm_group_into(&x, 1, &mut views, &mut scratch);
        }
        let fused = &scratch.counters;
        assert_eq!(independent.build_ops, 3 * fused.build_ops, "3-member group builds once");
        assert_eq!(independent.read_ops, fused.read_ops, "gather work conserved");
        assert_eq!(independent.lookups, fused.lookups);
        assert_eq!(fused.calls, 1, "one logical call for the whole group");
        assert_eq!(fused.group_fanout, 3, "three members shared each build");
        assert_eq!(independent.calls, 3);
        assert_eq!(independent.group_fanout, 0, "plain calls record no fanout");
        assert!(fused.build_share_ops() < independent.build_share_ops());
    }

    #[test]
    fn unfused_group_matches_fused_bit_exactly() {
        let (ns, k) = ([16usize, 8], 64);
        let (_, parts) = stacked(&ns, k, "m1v8g32", 5);
        let x = Prng::seeded(6).normal_vec(k * 2, 1.0);
        let run = |fused: bool| {
            let group = serial_group(&parts).with_fused(fused);
            let mut outs: Vec<Vec<f32>> = ns.iter().map(|&n| vec![f32::NAN; n * 2]).collect();
            let mut scratch = EngineScratch::new();
            {
                let mut views: Vec<&mut [f32]> = outs.iter_mut().map(|y| y.as_mut_slice()).collect();
                group.gemm_group_into(&x, 2, &mut views, &mut scratch);
            }
            (outs, scratch.counters)
        };
        let (y_on, c_on) = run(true);
        let (y_off, c_off) = run(false);
        assert_eq!(y_on, y_off, "fused and unfused schedules must agree bitwise");
        assert_eq!(c_off.build_ops, 2 * c_on.build_ops);
        assert_eq!(c_off.group_fanout, 0);
        assert_eq!(c_on.group_fanout, 2);
        // The private-table baseline (`shared_psumbook = false`) vetoes
        // fusion — a fused group inherently shares its build.
        let private = serial_group(&parts).with_shared_psumbook(false);
        assert!(private.is_fusable() && !private.uses_fused());
    }

    #[test]
    fn mismatched_member_configs_fall_back_to_independent_calls() {
        // Members quantized separately (different codebooks) cannot
        // share a book; the group must detect this and still compute
        // each member correctly.
        let k = 64;
        let qa = {
            let w = Prng::seeded(7).normal_vec(16 * k, 0.02);
            Quantizer::new(QuantConfig::parse_label("m1v4g32").unwrap()).quantize(&w, 16, k)
        };
        let qb = {
            let w = Prng::seeded(8).normal_vec(8 * k, 0.02);
            Quantizer::new(QuantConfig::parse_label("m2v8g32").unwrap()).quantize(&w, 8, k)
        };
        let group = GemmGroup::new(
            vec![
                GroupMember::serial(CodeGemmEngine::from_quantized(&qa)),
                GroupMember::serial(CodeGemmEngine::from_quantized(&qb)),
            ],
            None,
        );
        assert!(!group.is_fusable(), "mismatched formats must not fuse");
        let x = Prng::seeded(9).normal_vec(k, 1.0);
        let mut ya = vec![f32::NAN; 16];
        let mut yb = vec![f32::NAN; 8];
        let mut scratch = EngineScratch::new();
        group.gemm_group_into(&x, 1, &mut [&mut ya[..], &mut yb[..]], &mut scratch);
        assert_eq!(ya, CodeGemmEngine::from_quantized(&qa).gemv(&x));
        assert_eq!(yb, CodeGemmEngine::from_quantized(&qb).gemv(&x));
        assert_eq!(scratch.counters.calls, 2, "independent fallback: one call per member");
        assert_eq!(scratch.counters.group_fanout, 0);
    }

    #[test]
    fn sharded_fused_group_matches_serial_fused_group() {
        let (ns, k) = ([24usize, 12, 12], 128);
        let (_, parts) = stacked(&ns, k, "m2v8g32", 11);
        let pool = Arc::new(ThreadPool::new(3));
        let sharded_group = GemmGroup::new(
            parts
                .iter()
                .map(|p| {
                    let plan = ShardPlan::new(p.n, 3, 1, 1);
                    let codes = p.codes.unpack();
                    let shards = plan
                        .shards
                        .iter()
                        .map(|&(r0, r1)| {
                            CodeGemmEngine::from_quantized(&shard::slice_rows_unpacked(
                                p, &codes, r0, r1,
                            ))
                        })
                        .collect();
                    GroupMember::sharded(plan, shards)
                })
                .collect(),
            Some(pool),
        );
        assert!(sharded_group.uses_fused());
        let serial = serial_group(&parts);
        for mb in [1usize, 4] {
            let x = Prng::seeded(12 + mb as u64).normal_vec(k * mb, 1.0);
            let run = |g: &GemmGroup| {
                let mut outs: Vec<Vec<f32>> = ns.iter().map(|&n| vec![f32::NAN; n * mb]).collect();
                let mut scratch = EngineScratch::new();
                {
                    let mut views: Vec<&mut [f32]> =
                        outs.iter_mut().map(|y| y.as_mut_slice()).collect();
                    g.gemm_group_into(&x, mb, &mut views, &mut scratch);
                }
                (outs, scratch.counters)
            };
            let (y_serial, c_serial) = run(&serial);
            let (y_sharded, c_sharded) = run(&sharded_group);
            assert_eq!(y_serial, y_sharded, "shard × member gather diverged (mb={mb})");
            // Build counted once per call on both schedules; gather work
            // conserved across the shard × member split.
            assert_eq!(c_serial.build_ops, c_sharded.build_ops);
            assert_eq!(c_serial.read_ops, c_sharded.read_ops);
            assert_eq!(c_sharded.calls, 1);
            assert_eq!(c_sharded.group_fanout, 3);
        }
    }
}
