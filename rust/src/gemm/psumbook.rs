//! The Psumbook (paper §3, Figure 3, Step 2): all inner products between
//! codebook centroids and the activation sub-vectors of one weight tile,
//! precomputed once per (row-block × k-tile) and then *gathered* through
//! the code matrix instead of dequantizing weights.
//!
//! Layout: `data[((j·m + c)·2^b + i)·mb + b]` — the centroid axis `i` is
//! innermost-but-one so each `(j, c)` table is a contiguous `2^b × mb`
//! block (stays L1-resident during the gather), and the batch axis is
//! innermost so batched gathers are contiguous loads.

/// A built Psumbook for one tile.
#[derive(Clone, Debug, Default)]
pub struct Psumbook {
    /// Vectors in the tile (`t_w / v`).
    pub jn: usize,
    /// Number of codebooks.
    pub m: usize,
    /// Centroids per codebook (`2^b`).
    pub nc: usize,
    /// Batch columns.
    pub mb: usize,
    pub data: Vec<f32>,
}

impl Psumbook {
    /// Allocate an uninitialized book (zeroed).
    pub fn empty(jn: usize, m: usize, nc: usize, mb: usize) -> Psumbook {
        Psumbook { jn, m, nc, mb, data: vec![0f32; jn * m * nc * mb] }
    }

    /// Reshape in place for a new tile geometry, reusing the allocation
    /// (grow-only capacity; `build` overwrites every entry in use). This
    /// is what keeps a scratch-resident book allocation-free once it has
    /// seen the largest tile of a workload.
    pub fn reshape(&mut self, jn: usize, m: usize, nc: usize, mb: usize) {
        self.jn = jn;
        self.m = m;
        self.nc = nc;
        self.mb = mb;
        self.data.clear();
        self.data.resize(jn * m * nc * mb, 0.0);
    }

    /// Number of f32 entries.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// On-chip footprint in bytes (the paper's space-complexity object:
    /// `O(m · 2^b · t_w/v)` per batch column).
    pub fn footprint_bytes(&self) -> usize {
        self.len() * 4
    }

    /// Build the book for activations `x` laid out batch-major
    /// (`x[b*k_tile..]` is one column's tile slice, `k_tile = jn*v`).
    ///
    /// `codebooks` is the flat `m × nc × v` array from
    /// [`crate::quant::QuantizedLinear`]. Returns MAC count.
    pub fn build(&mut self, codebooks: &[f32], v: usize, x: &[f32]) -> u64 {
        let (jn, m, nc, mb) = (self.jn, self.m, self.nc, self.mb);
        let k_tile = jn * v;
        debug_assert_eq!(x.len(), k_tile * mb);
        debug_assert_eq!(codebooks.len(), m * nc * v);
        if mb == 1 {
            // Single-column fast path (the GEMV hot case): the activation
            // sub-vector is hoisted out of the centroid loop and the v≤8
            // dot product unrolls; table entries are written sequentially.
            for j in 0..jn {
                let xj = &x[j * v..(j + 1) * v];
                for c in 0..m {
                    let cb = &codebooks[c * nc * v..(c + 1) * nc * v];
                    let out = &mut self.data[(j * m + c) * nc..(j * m + c + 1) * nc];
                    match v {
                        4 => {
                            let (x0, x1, x2, x3) = (xj[0], xj[1], xj[2], xj[3]);
                            for (i, o) in out.iter_mut().enumerate() {
                                let cent = &cb[i * 4..i * 4 + 4];
                                *o = cent[0] * x0 + cent[1] * x1 + cent[2] * x2 + cent[3] * x3;
                            }
                        }
                        8 => {
                            for (i, o) in out.iter_mut().enumerate() {
                                let cent = &cb[i * 8..i * 8 + 8];
                                let a = cent[0] * xj[0] + cent[1] * xj[1] + cent[2] * xj[2] + cent[3] * xj[3];
                                let b = cent[4] * xj[4] + cent[5] * xj[5] + cent[6] * xj[6] + cent[7] * xj[7];
                                *o = a + b;
                            }
                        }
                        _ => {
                            for (i, o) in out.iter_mut().enumerate() {
                                let cent = &cb[i * v..(i + 1) * v];
                                *o = cent.iter().zip(xj).map(|(a, b)| a * b).sum();
                            }
                        }
                    }
                }
            }
            return (jn * m * nc * v) as u64;
        }
        for j in 0..jn {
            for c in 0..m {
                let cb = &codebooks[c * nc * v..(c + 1) * nc * v];
                let base = (j * m + c) * nc * mb;
                for i in 0..nc {
                    let cent = &cb[i * v..(i + 1) * v];
                    for b in 0..mb {
                        let xj = &x[b * k_tile + j * v..b * k_tile + (j + 1) * v];
                        let mut acc = 0f32;
                        for t in 0..v {
                            acc += cent[t] * xj[t];
                        }
                        self.data[base + i * mb + b] = acc;
                    }
                }
            }
        }
        (jn * m * nc * v * mb) as u64
    }

    /// The contiguous `nc × mb` table for `(j, c)`.
    #[inline]
    pub fn table(&self, j: usize, c: usize) -> &[f32] {
        let base = (j * self.m + c) * self.nc * self.mb;
        &self.data[base..base + self.nc * self.mb]
    }

    /// Single-batch lookup.
    #[inline]
    pub fn get(&self, j: usize, c: usize, code: usize, b: usize) -> f32 {
        self.data[((j * self.m + c) * self.nc + code) * self.mb + b]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    /// Psumbook entries must equal the direct inner products (Eq. 2).
    #[test]
    fn entries_match_eq2() {
        let (v, m, nc, jn, mb) = (4usize, 2usize, 8usize, 3usize, 2usize);
        let mut rng = Prng::seeded(1);
        let codebooks = rng.normal_vec(m * nc * v, 1.0);
        let x = rng.normal_vec(jn * v * mb, 1.0);
        let mut book = Psumbook::empty(jn, m, nc, mb);
        let macs = book.build(&codebooks, v, &x);
        assert_eq!(macs, (jn * m * nc * v * mb) as u64);
        for j in 0..jn {
            for c in 0..m {
                for i in 0..nc {
                    for b in 0..mb {
                        let mut expect = 0f32;
                        for t in 0..v {
                            expect += codebooks[(c * nc + i) * v + t] * x[b * jn * v + j * v + t];
                        }
                        let got = book.get(j, c, i, b);
                        assert!((got - expect).abs() < 1e-5, "j{j} c{c} i{i} b{b}: {got} vs {expect}");
                    }
                }
            }
        }
    }

    #[test]
    fn footprint_matches_paper_space_complexity() {
        // m=2, b=8 (nc=256), t_w=32, v=8 ⇒ jn=4 ⇒ 2·256·4 f32 = 8 KiB.
        let book = Psumbook::empty(4, 2, 256, 1);
        assert_eq!(book.footprint_bytes(), 2 * 256 * 4 * 4);
    }

    #[test]
    fn table_slices_are_disjoint_cover() {
        let book = Psumbook::empty(2, 2, 4, 1);
        let total: usize = (0..2).flat_map(|j| (0..2).map(move |c| (j, c))).map(|(j, c)| book.table(j, c).len()).sum();
        assert_eq!(total, book.len());
    }

    #[test]
    fn reshape_reuses_capacity_and_builds_correctly() {
        let (v, m, nc) = (4usize, 1usize, 8usize);
        let codebooks = Prng::seeded(3).normal_vec(m * nc * v, 1.0);
        let mut book = Psumbook::empty(4, m, nc, 2);
        let cap = book.data.capacity();
        // Shrink to a smaller geometry: no reallocation, correct entries.
        book.reshape(2, m, nc, 1);
        assert_eq!(book.data.capacity(), cap);
        let x = Prng::seeded(4).normal_vec(2 * v, 1.0);
        book.build(&codebooks, v, &x);
        for j in 0..2 {
            for i in 0..nc {
                let mut expect = 0f32;
                for t in 0..v {
                    expect += codebooks[i * v + t] * x[j * v + t];
                }
                assert!((book.get(j, 0, i, 0) - expect).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn zero_activations_zero_book() {
        let (v, m, nc, jn) = (4, 1, 4, 2);
        let codebooks = Prng::seeded(2).normal_vec(m * nc * v, 1.0);
        let x = vec![0f32; jn * v];
        let mut book = Psumbook::empty(jn, m, nc, 1);
        book.build(&codebooks, v, &x);
        assert!(book.data.iter().all(|&p| p == 0.0));
    }
}
