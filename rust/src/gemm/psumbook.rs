//! The Psumbook (paper §3, Figure 3, Step 2): all inner products between
//! codebook centroids and the activation sub-vectors of one weight tile,
//! precomputed once and then *gathered* through the code matrix instead
//! of dequantizing weights.
//!
//! ## Build once, gather many
//!
//! The book's entries depend only on the **k-tile of activations** and
//! the codebooks — never on which output rows will read them. That is
//! the paper's amortization lever (Eq. 3): one build serves every row
//! (and, under row-sharded execution, every *shard*) that gathers from
//! it. The serial [`crate::gemm::CodeGemmEngine`] rebuilds per row-block
//! to mirror the GPU's per-thread-block tables; the shared-book schedule
//! in `crate::parallel::fanout` instead builds one scratch-resident book
//! per (k-tile, batch) and lets all row shards gather from it read-only.
//!
//! To make the build itself parallelizable, [`Psumbook::build_slice`]
//! (and the free [`build_range`] it wraps) computes any vector sub-range
//! `[j_lo, j_hi)` of the tile independently: the `j` axis is outermost
//! in the layout, so workers can write disjoint `data` slices with no
//! coordination and the result is bit-identical to a serial build.
//!
//! Layout: `data[((j·m + c)·2^b + i)·mb + b]` — the centroid axis `i` is
//! innermost-but-one so each `(j, c)` table is a contiguous `2^b × mb`
//! block (stays L1-resident during the gather), and the batch axis is
//! innermost so batched gathers are contiguous loads.

/// A built Psumbook for one tile.
#[derive(Clone, Debug, Default)]
pub struct Psumbook {
    /// Vectors in the tile (`t_w / v`).
    pub jn: usize,
    /// Number of codebooks.
    pub m: usize,
    /// Centroids per codebook (`2^b`).
    pub nc: usize,
    /// Batch columns.
    pub mb: usize,
    pub data: Vec<f32>,
}

/// Build the book entries for the vector range `[j_lo, j_hi)` of a tile
/// whose full extent is `jn` vectors, writing into `out` — the sub-slice
/// of a book's `data` covering exactly that range
/// (`(j_hi - j_lo) · m · nc · mb` floats). `x` is the **full** staged
/// activation tile (`jn·v·mb`, batch-major), indexed by absolute `j`.
///
/// Exposed as a free function so the shared-book parallel build can fan
/// j-ranges out over workers, each holding a disjoint `&mut` slice of
/// one book's storage. Entries are computed identically regardless of
/// how the range is partitioned, so any split is bit-identical to a
/// serial [`Psumbook::build`]. Returns the MACs spent.
#[allow(clippy::too_many_arguments)]
pub fn build_range(
    codebooks: &[f32],
    v: usize,
    x: &[f32],
    jn: usize,
    m: usize,
    nc: usize,
    mb: usize,
    j_lo: usize,
    j_hi: usize,
    out: &mut [f32],
) -> u64 {
    let k_tile = jn * v;
    debug_assert!(j_lo <= j_hi && j_hi <= jn);
    debug_assert_eq!(x.len(), k_tile * mb);
    debug_assert_eq!(codebooks.len(), m * nc * v);
    debug_assert_eq!(out.len(), (j_hi - j_lo) * m * nc * mb);
    if mb == 1 {
        // Single-column fast path (the GEMV hot case): the activation
        // sub-vector is hoisted out of the centroid loop and the v≤8
        // dot product unrolls; table entries are written sequentially.
        for j in j_lo..j_hi {
            let xj = &x[j * v..(j + 1) * v];
            let jo = j - j_lo;
            for c in 0..m {
                let cb = &codebooks[c * nc * v..(c + 1) * nc * v];
                let o = &mut out[(jo * m + c) * nc..(jo * m + c + 1) * nc];
                match v {
                    4 => {
                        let (x0, x1, x2, x3) = (xj[0], xj[1], xj[2], xj[3]);
                        for (i, o) in o.iter_mut().enumerate() {
                            let cent = &cb[i * 4..i * 4 + 4];
                            *o = cent[0] * x0 + cent[1] * x1 + cent[2] * x2 + cent[3] * x3;
                        }
                    }
                    8 => {
                        for (i, o) in o.iter_mut().enumerate() {
                            let cent = &cb[i * 8..i * 8 + 8];
                            let a = cent[0] * xj[0] + cent[1] * xj[1] + cent[2] * xj[2] + cent[3] * xj[3];
                            let b = cent[4] * xj[4] + cent[5] * xj[5] + cent[6] * xj[6] + cent[7] * xj[7];
                            *o = a + b;
                        }
                    }
                    _ => {
                        for (i, o) in o.iter_mut().enumerate() {
                            let cent = &cb[i * v..(i + 1) * v];
                            *o = cent.iter().zip(xj).map(|(a, b)| a * b).sum();
                        }
                    }
                }
            }
        }
        return ((j_hi - j_lo) * m * nc * v) as u64;
    }
    for j in j_lo..j_hi {
        let jo = j - j_lo;
        for c in 0..m {
            let cb = &codebooks[c * nc * v..(c + 1) * nc * v];
            let base = (jo * m + c) * nc * mb;
            for i in 0..nc {
                let cent = &cb[i * v..(i + 1) * v];
                for b in 0..mb {
                    let xj = &x[b * k_tile + j * v..b * k_tile + (j + 1) * v];
                    let mut acc = 0f32;
                    for t in 0..v {
                        acc += cent[t] * xj[t];
                    }
                    out[base + i * mb + b] = acc;
                }
            }
        }
    }
    ((j_hi - j_lo) * m * nc * v * mb) as u64
}

impl Psumbook {
    /// Allocate an uninitialized book (zeroed).
    pub fn empty(jn: usize, m: usize, nc: usize, mb: usize) -> Psumbook {
        Psumbook { jn, m, nc, mb, data: vec![0f32; jn * m * nc * mb] }
    }

    /// Reshape in place for a new tile geometry, reusing the allocation
    /// (grow-only capacity; `build` overwrites every entry in use). This
    /// is what keeps a scratch-resident book allocation-free once it has
    /// seen the largest tile of a workload.
    pub fn reshape(&mut self, jn: usize, m: usize, nc: usize, mb: usize) {
        self.jn = jn;
        self.m = m;
        self.nc = nc;
        self.mb = mb;
        self.data.clear();
        self.data.resize(jn * m * nc * mb, 0.0);
    }

    /// Number of f32 entries.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// On-chip footprint in bytes (the paper's space-complexity object:
    /// `O(m · 2^b · t_w/v)` per batch column).
    pub fn footprint_bytes(&self) -> usize {
        self.len() * 4
    }

    /// Build the whole book for activations `x` laid out batch-major
    /// (`x[b*k_tile..]` is one column's tile slice, `k_tile = jn*v`).
    ///
    /// `codebooks` is the flat `m × nc × v` array from
    /// [`crate::quant::QuantizedLinear`]. Returns MAC count.
    pub fn build(&mut self, codebooks: &[f32], v: usize, x: &[f32]) -> u64 {
        let jn = self.jn;
        self.build_slice(codebooks, v, x, 0, jn)
    }

    /// Build only the vector range `[j_lo, j_hi)` of the book (the rest
    /// of `data` is untouched). `x` is still the full staged tile. The
    /// parallel shared-book build splits `[0, jn)` into worker ranges,
    /// each writing its disjoint slice via [`build_range`]; covering the
    /// whole range in any order reproduces [`Psumbook::build`] exactly.
    pub fn build_slice(
        &mut self,
        codebooks: &[f32],
        v: usize,
        x: &[f32],
        j_lo: usize,
        j_hi: usize,
    ) -> u64 {
        let (jn, m, nc, mb) = (self.jn, self.m, self.nc, self.mb);
        let stride = m * nc * mb;
        build_range(
            codebooks,
            v,
            x,
            jn,
            m,
            nc,
            mb,
            j_lo,
            j_hi,
            &mut self.data[j_lo * stride..j_hi * stride],
        )
    }

    /// The contiguous `nc × mb` table for `(j, c)`.
    #[inline]
    pub fn table(&self, j: usize, c: usize) -> &[f32] {
        let base = (j * self.m + c) * self.nc * self.mb;
        &self.data[base..base + self.nc * self.mb]
    }

    /// Single-batch lookup.
    #[inline]
    pub fn get(&self, j: usize, c: usize, code: usize, b: usize) -> f32 {
        self.data[((j * self.m + c) * self.nc + code) * self.mb + b]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    /// Psumbook entries must equal the direct inner products (Eq. 2).
    #[test]
    fn entries_match_eq2() {
        let (v, m, nc, jn, mb) = (4usize, 2usize, 8usize, 3usize, 2usize);
        let mut rng = Prng::seeded(1);
        let codebooks = rng.normal_vec(m * nc * v, 1.0);
        let x = rng.normal_vec(jn * v * mb, 1.0);
        let mut book = Psumbook::empty(jn, m, nc, mb);
        let macs = book.build(&codebooks, v, &x);
        assert_eq!(macs, (jn * m * nc * v * mb) as u64);
        for j in 0..jn {
            for c in 0..m {
                for i in 0..nc {
                    for b in 0..mb {
                        let mut expect = 0f32;
                        for t in 0..v {
                            expect += codebooks[(c * nc + i) * v + t] * x[b * jn * v + j * v + t];
                        }
                        let got = book.get(j, c, i, b);
                        assert!((got - expect).abs() < 1e-5, "j{j} c{c} i{i} b{b}: {got} vs {expect}");
                    }
                }
            }
        }
    }

    /// Any partition of `[0, jn)` into `build_slice` calls must
    /// reproduce the serial `build` bit-for-bit — the invariant the
    /// parallel shared-book build rests on.
    #[test]
    fn sliced_builds_are_bit_identical_to_serial() {
        for (v, m, nc, jn, mb) in [(4usize, 2usize, 8usize, 5usize, 1usize), (8, 1, 4, 6, 3)] {
            let mut rng = Prng::seeded(7);
            let codebooks = rng.normal_vec(m * nc * v, 1.0);
            let x = rng.normal_vec(jn * v * mb, 1.0);
            let mut serial = Psumbook::empty(jn, m, nc, mb);
            let serial_macs = serial.build(&codebooks, v, &x);
            for splits in [vec![0, jn], vec![0, 1, jn], vec![0, 2, 3, jn]] {
                let mut sliced = Psumbook::empty(jn, m, nc, mb);
                // Poison so untouched entries would be caught.
                sliced.data.fill(f32::NAN);
                let mut macs = 0u64;
                for w in splits.windows(2) {
                    macs += sliced.build_slice(&codebooks, v, &x, w[0], w[1]);
                }
                assert_eq!(macs, serial_macs, "MACs conserved across splits");
                assert_eq!(sliced.data, serial.data, "split {splits:?} diverged");
            }
        }
    }

    /// `build_range` into externally split storage (the parallel-build
    /// code path) matches the serial build.
    #[test]
    fn build_range_over_split_storage_matches_serial() {
        let (v, m, nc, jn, mb) = (4usize, 1usize, 8usize, 6usize, 2usize);
        let mut rng = Prng::seeded(8);
        let codebooks = rng.normal_vec(m * nc * v, 1.0);
        let x = rng.normal_vec(jn * v * mb, 1.0);
        let mut serial = Psumbook::empty(jn, m, nc, mb);
        serial.build(&codebooks, v, &x);
        let mut data = vec![f32::NAN; jn * m * nc * mb];
        let stride = m * nc * mb;
        let (lo, hi) = data.split_at_mut(2 * stride);
        build_range(&codebooks, v, &x, jn, m, nc, mb, 0, 2, lo);
        build_range(&codebooks, v, &x, jn, m, nc, mb, 2, jn, hi);
        assert_eq!(data, serial.data);
    }

    #[test]
    fn footprint_matches_paper_space_complexity() {
        // m=2, b=8 (nc=256), t_w=32, v=8 ⇒ jn=4 ⇒ 2·256·4 f32 = 8 KiB.
        let book = Psumbook::empty(4, 2, 256, 1);
        assert_eq!(book.footprint_bytes(), 2 * 256 * 4 * 4);
    }

    #[test]
    fn table_slices_are_disjoint_cover() {
        let book = Psumbook::empty(2, 2, 4, 1);
        let total: usize = (0..2).flat_map(|j| (0..2).map(move |c| (j, c))).map(|(j, c)| book.table(j, c).len()).sum();
        assert_eq!(total, book.len());
    }

    #[test]
    fn reshape_reuses_capacity_and_builds_correctly() {
        let (v, m, nc) = (4usize, 1usize, 8usize);
        let codebooks = Prng::seeded(3).normal_vec(m * nc * v, 1.0);
        let mut book = Psumbook::empty(4, m, nc, 2);
        let cap = book.data.capacity();
        // Shrink to a smaller geometry: no reallocation, correct entries.
        book.reshape(2, m, nc, 1);
        assert_eq!(book.data.capacity(), cap);
        let x = Prng::seeded(4).normal_vec(2 * v, 1.0);
        book.build(&codebooks, v, &x);
        for j in 0..2 {
            for i in 0..nc {
                let mut expect = 0f32;
                for t in 0..v {
                    expect += codebooks[i * v + t] * x[j * v + t];
                }
                assert!((book.get(j, 0, i, 0) - expect).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn zero_activations_zero_book() {
        let (v, m, nc, jn) = (4, 1, 4, 2);
        let codebooks = Prng::seeded(2).normal_vec(m * nc * v, 1.0);
        let x = vec![0f32; jn * v];
        let mut book = Psumbook::empty(jn, m, nc, 1);
        book.build(&codebooks, v, &x);
        assert!(book.data.iter().all(|&p| p == 0.0));
    }
}
