//! Work and traffic accounting shared by all engines.
//!
//! Counters are *exact* counts derived from the algorithm (not sampled):
//! they feed the A100 analytic model (`simulator/`) and the paper's
//! Table 6 build/read split.

/// Accumulated work counters for one engine.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Counters {
    /// Multiply-accumulate operations (1 MAC = 2 FLOPs).
    pub mac_flops: u64,
    /// Table lookups (Psumbook gathers / LUT reads).
    pub lookups: u64,
    /// Bytes of weight-side data read (dense weights, codes, codebooks,
    /// scales, bitplanes) — models DRAM traffic on the weight stream.
    pub weight_bytes: u64,
    /// Bytes of activation data read.
    pub activation_bytes: u64,
    /// Bytes written to / read from the on-chip scratch (Psumbook / LUT /
    /// decode buffers) — models shared-memory traffic.
    pub scratch_bytes: u64,
    /// Bytes moved by the **build** phase (activation staging + codebook
    /// stream + Psumbook writes) — the phase split of the byte classes
    /// above, so the profiler's roofline can place build and gather
    /// separately. `build_bytes + read_bytes == total_bytes()` for the
    /// CodeGEMM engine.
    pub build_bytes: u64,
    /// Bytes moved by the **gather/read** phase (code stream + Psumbook
    /// reads + scales stream) — pairs with `read_seconds` to give the
    /// gather phase's achieved GB/s against the calibrated peak.
    pub read_bytes: u64,
    /// Work spent building per-tile structures (Psumbook/LUT), in MACs.
    pub build_ops: u64,
    /// Work spent in the main accumulate loop, in lookup+add units.
    pub read_ops: u64,
    /// Wall time attributed to the build phase (seconds).
    pub build_seconds: f64,
    /// Wall time attributed to the read/accumulate phase (seconds).
    pub read_seconds: f64,
    /// Number of GEMV/GEMM calls.
    pub calls: u64,
    /// Member GEMMs served by fused projection-group calls: every
    /// build-once/gather-many *group* call adds its member count (Q/K/V
    /// ⇒ 3, gate/up ⇒ 2), so `group_fanout / calls` is the average
    /// number of projections amortizing each shared Psumbook build.
    /// Plain (ungrouped) calls leave it untouched.
    pub group_fanout: u64,
}

impl Counters {
    pub fn new() -> Counters {
        Counters::default()
    }

    pub fn reset(&mut self) {
        *self = Counters::default();
    }

    /// Total FLOPs (2 per MAC).
    pub fn flops(&self) -> u64 {
        2 * self.mac_flops
    }

    /// Fraction of phase work spent building (by op counts) — the
    /// quantity the paper's Table 6 reports as "Psumbook Phase (%)".
    pub fn build_share_ops(&self) -> f64 {
        let total = (self.build_ops + self.read_ops) as f64;
        if total == 0.0 {
            0.0
        } else {
            self.build_ops as f64 / total
        }
    }

    /// Fraction of phase wall-time spent building.
    pub fn build_share_time(&self) -> f64 {
        let total = self.build_seconds + self.read_seconds;
        if total == 0.0 {
            0.0
        } else {
            self.build_seconds / total
        }
    }

    /// Build MACs per logical GEMM call — under the shared-Psumbook
    /// schedule this is invariant to the row-shard count (one build per
    /// k-tile per call), whereas private per-shard tables scale it by K.
    pub fn build_ops_per_call(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.build_ops as f64 / self.calls as f64
        }
    }

    /// Member GEMMs per logical call across fused projection groups —
    /// the group analogue of [`Counters::build_ops_per_call`]. `calls`
    /// counts *every* logical GEMM (ungrouped O/down/lm_head included),
    /// so a fully fused decode layer — 4 calls (qkv, wo, gate_up, down)
    /// carrying `group_fanout` 5 — reports 1.25; an unfused forward
    /// reports 0 (no call shared its build). Feeds the coordinator's
    /// engine gauge.
    pub fn fanout_per_call(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.group_fanout as f64 / self.calls as f64
        }
    }

    /// Total bytes moved (all classes).
    pub fn total_bytes(&self) -> u64 {
        self.weight_bytes + self.activation_bytes + self.scratch_bytes
    }

    /// Merge another counter set into this one.
    pub fn merge(&mut self, other: &Counters) {
        self.mac_flops += other.mac_flops;
        self.lookups += other.lookups;
        self.weight_bytes += other.weight_bytes;
        self.activation_bytes += other.activation_bytes;
        self.scratch_bytes += other.scratch_bytes;
        self.build_bytes += other.build_bytes;
        self.read_bytes += other.read_bytes;
        self.build_ops += other.build_ops;
        self.read_ops += other.read_ops;
        self.build_seconds += other.build_seconds;
        self.read_seconds += other.read_seconds;
        self.calls += other.calls;
        self.group_fanout += other.group_fanout;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_share_by_ops() {
        let mut c = Counters::new();
        c.build_ops = 30;
        c.read_ops = 70;
        assert!((c.build_share_ops() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn empty_shares_are_zero() {
        let c = Counters::new();
        assert_eq!(c.build_share_ops(), 0.0);
        assert_eq!(c.build_share_time(), 0.0);
        assert_eq!(c.build_ops_per_call(), 0.0);
    }

    #[test]
    fn build_ops_per_call_averages_over_calls() {
        let c = Counters { build_ops: 120, calls: 3, ..Default::default() };
        assert!((c.build_ops_per_call() - 40.0).abs() < 1e-12);
    }

    #[test]
    fn merge_adds() {
        let mut a = Counters { mac_flops: 1, lookups: 2, calls: 1, build_bytes: 5, ..Default::default() };
        let b = Counters {
            mac_flops: 10,
            lookups: 20,
            calls: 1,
            group_fanout: 3,
            build_bytes: 7,
            read_bytes: 9,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.mac_flops, 11);
        assert_eq!(a.lookups, 22);
        assert_eq!(a.calls, 2);
        assert_eq!(a.group_fanout, 3);
        assert_eq!(a.build_bytes, 12);
        assert_eq!(a.read_bytes, 9);
    }

    #[test]
    fn fanout_per_call_averages_group_members_over_calls() {
        // One fused Q/K/V call (3 members), one fused gate/up call (2),
        // two plain calls: 5 fused members over 4 logical calls.
        let c = Counters { group_fanout: 5, calls: 4, ..Default::default() };
        assert!((c.fanout_per_call() - 1.25).abs() < 1e-12);
        assert_eq!(Counters::new().fanout_per_call(), 0.0);
    }

    #[test]
    fn flops_is_twice_macs() {
        let c = Counters { mac_flops: 21, ..Default::default() };
        assert_eq!(c.flops(), 42);
    }

    #[test]
    fn reset_clears() {
        let mut c = Counters { mac_flops: 5, build_seconds: 1.0, ..Default::default() };
        c.reset();
        assert_eq!(c, Counters::default());
    }
}
