//! LUT-GEMM baseline (paper §2.3, ref [20]): lookup-table GEMM over the
//! BCQ format. For every length-μ (=8) chunk of the activations, the
//! kernel precomputes all `2^μ` signed sums; each weight bitplane then
//! indexes the table with 8 sign bits at a time, replacing 8 MACs by one
//! lookup + add per plane.

use crate::gemm::scratch::{grow_slice, EngineScratch};
use crate::gemm::GemmEngine;
use crate::quant::bcq::BcqLinear;
use crate::util::timer::Timer;

/// Sub-vector width of the lookup table (LUT-GEMM's μ).
pub const MU: usize = 8;

/// CPU implementation of the LUT-GEMM kernel over BCQ weights. The chunk
/// tables live in the caller's [`EngineScratch`] and are rebuilt in place
/// per batch column — no per-call allocation.
#[derive(Clone, Debug)]
pub struct LutGemmEngine {
    bcq: BcqLinear,
    scratch: EngineScratch,
}

impl LutGemmEngine {
    pub fn new(bcq: BcqLinear) -> LutGemmEngine {
        assert_eq!(bcq.k % MU, 0, "K must be a multiple of MU={MU}");
        assert_eq!(bcq.group % MU, 0, "group must be a multiple of MU");
        LutGemmEngine { bcq, scratch: EngineScratch::new() }
    }

    /// LUT on-chip bytes per batch column: `2^μ · K/μ` f32 entries.
    pub fn lut_bytes(&self) -> usize {
        (1 << MU) * (self.bcq.k / MU) * 4
    }

    /// Build the `2^8` signed-sum table for one 8-chunk of activations
    /// using the doubling recurrence: O(2^μ) instead of O(μ·2^μ).
    fn build_chunk_table(x: &[f32; MU], table: &mut [f32]) {
        // table[t]: bit j of t set ⇒ +x[j], else −x[j].
        table[0] = -x.iter().sum::<f32>();
        let mut size = 1usize;
        for (j, &xj) in x.iter().enumerate() {
            let add = 2.0 * xj;
            let bit = 1usize << j;
            for t in 0..size {
                table[t | bit] = table[t] + add;
            }
            size <<= 1;
        }
    }
}

impl GemmEngine for LutGemmEngine {
    fn name(&self) -> &'static str {
        "lutgemm"
    }

    fn dims(&self) -> (usize, usize) {
        (self.bcq.n, self.bcq.k)
    }

    fn gemm_into(&self, x: &[f32], m_batch: usize, y: &mut [f32], scratch: &mut EngineScratch) {
        let (n, k) = self.dims();
        assert_eq!(x.len(), k * m_batch);
        assert_eq!(y.len(), n * m_batch);
        let q = self.bcq.q_bits;
        let chunks = k / MU;
        let EngineScratch { counters, buf, .. } = scratch;
        let table = grow_slice(buf, chunks << MU);
        for b in 0..m_batch {
            let xb = &x[b * k..(b + 1) * k];
            // Build phase: all chunk tables for this activation column.
            let t = Timer::start();
            for ch in 0..chunks {
                let mut xc = [0f32; MU];
                xc.copy_from_slice(&xb[ch * MU..(ch + 1) * MU]);
                Self::build_chunk_table(&xc, &mut table[ch << MU..(ch + 1) << MU]);
            }
            counters.build_seconds += t.elapsed_s();
            counters.build_ops += (chunks << MU) as u64;
            counters.scratch_bytes += ((chunks << MU) * 4) as u64;

            // Read phase: per row/plane, index the tables by sign bits.
            let t = Timer::start();
            for r in 0..n {
                let mut acc = 0f32;
                for plane in 0..q {
                    let words = self.bcq.row_plane_words(plane, r);
                    for ch in 0..chunks {
                        let c0 = ch * MU;
                        let bits = ((words[c0 / 64] >> (c0 % 64)) & 0xFF) as usize;
                        let alpha = self.bcq.alpha(r, c0, plane);
                        acc += alpha * table[(ch << MU) | bits];
                    }
                }
                y[b * n + r] = acc;
            }
            counters.read_seconds += t.elapsed_s();
            let lookups = (n * q * chunks) as u64;
            counters.read_ops += lookups;
            counters.lookups += lookups;
            counters.mac_flops += lookups; // one MAC (alpha × table) per lookup
            counters.scratch_bytes += lookups * 4;
        }
        // Weight stream: bitplanes + alphas.
        counters.weight_bytes += ((n * k * q) / 8 + n * (k / self.bcq.group) * q * 2) as u64;
        counters.activation_bytes += (k * m_batch * 2) as u64;
        counters.calls += 1;
    }

    fn scratch(&self) -> &EngineScratch {
        &self.scratch
    }

    fn scratch_mut(&mut self) -> &mut EngineScratch {
        &mut self.scratch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::DenseEngine;
    use crate::util::prng::Prng;
    use crate::util::stats;

    #[test]
    fn chunk_table_enumerates_all_sign_patterns() {
        let x = [1.0f32, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
        let mut table = vec![0f32; 256];
        LutGemmEngine::build_chunk_table(&x, &mut table);
        for t in 0..256usize {
            let mut expect = 0f32;
            for (j, &xj) in x.iter().enumerate() {
                expect += if (t >> j) & 1 == 1 { xj } else { -xj };
            }
            assert!((table[t] - expect).abs() < 1e-4, "t={t}: {} vs {expect}", table[t]);
        }
    }

    #[test]
    fn matches_dense_on_dequantized_bcq() {
        let (n, k) = (32, 64);
        let w = Prng::seeded(1).normal_vec(n * k, 0.02);
        let bcq = BcqLinear::quantize(&w, n, k, 3, 32).unwrap();
        let x = Prng::seeded(2).normal_vec(k * 2, 1.0);
        let y_ref = DenseEngine::new(bcq.dequantize(), n, k).gemm(&x, 2);
        let mut e = LutGemmEngine::new(bcq);
        let y = e.gemm(&x, 2);
        assert!(stats::rel_l2(&y, &y_ref) < 1e-4);
    }

    #[test]
    fn lookup_count_is_macs_over_mu() {
        // LUT-GEMM's win: n·k·q MACs become n·(k/8)·q lookups.
        let (n, k, q) = (16, 64, 2);
        let w = Prng::seeded(3).normal_vec(n * k, 1.0);
        let bcq = BcqLinear::quantize(&w, n, k, q, 64).unwrap();
        let mut e = LutGemmEngine::new(bcq);
        let _ = e.gemv(&vec![1.0f32; k]);
        assert_eq!(e.counters().lookups, (n * (k / MU) * q) as u64);
    }

    #[test]
    fn lut_bytes_formula() {
        let w = vec![0.1f32; 8 * 64];
        let bcq = BcqLinear::quantize(&w, 8, 64, 2, 64).unwrap();
        let e = LutGemmEngine::new(bcq);
        assert_eq!(e.lut_bytes(), 256 * 8 * 4);
    }

    #[test]
    #[should_panic(expected = "multiple of MU")]
    fn rejects_unaligned_k() {
        let w = vec![0.1f32; 4 * 12];
        let bcq = BcqLinear::quantize(&w, 4, 12, 2, 12).unwrap();
        let _ = LutGemmEngine::new(bcq);
    }
}
