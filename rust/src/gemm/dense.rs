//! Dense FP32 reference engine — the correctness oracle for every
//! quantized kernel, and the stand-in for the cuBLAS FP16 baseline in
//! CPU-measured comparisons.

use crate::gemm::scratch::EngineScratch;
use crate::gemm::GemmEngine;

/// Row-major dense weight engine.
#[derive(Clone, Debug)]
pub struct DenseEngine {
    w: Vec<f32>,
    n: usize,
    k: usize,
    scratch: EngineScratch,
}

impl DenseEngine {
    pub fn new(w: Vec<f32>, n: usize, k: usize) -> DenseEngine {
        assert_eq!(w.len(), n * k, "weight shape mismatch");
        DenseEngine { w, n, k, scratch: EngineScratch::new() }
    }

    /// Borrow the weights (used by tests and the model runner).
    pub fn weights(&self) -> &[f32] {
        &self.w
    }
}

impl GemmEngine for DenseEngine {
    fn name(&self) -> &'static str {
        "dense-f32"
    }

    fn dims(&self) -> (usize, usize) {
        (self.n, self.k)
    }

    fn gemm_into(&self, x: &[f32], m_batch: usize, y: &mut [f32], scratch: &mut EngineScratch) {
        assert_eq!(x.len(), self.k * m_batch);
        let (n, k) = (self.n, self.k);
        assert_eq!(y.len(), n * m_batch);
        for b in 0..m_batch {
            let xb = &x[b * k..(b + 1) * k];
            let yb = &mut y[b * n..(b + 1) * n];
            for r in 0..n {
                let row = &self.w[r * k..(r + 1) * k];
                // 4-way unrolled dot; autovectorizes well.
                let mut acc0 = 0f32;
                let mut acc1 = 0f32;
                let mut acc2 = 0f32;
                let mut acc3 = 0f32;
                let chunks = k / 4;
                for c in 0..chunks {
                    let i = c * 4;
                    acc0 += row[i] * xb[i];
                    acc1 += row[i + 1] * xb[i + 1];
                    acc2 += row[i + 2] * xb[i + 2];
                    acc3 += row[i + 3] * xb[i + 3];
                }
                let mut acc = acc0 + acc1 + acc2 + acc3;
                for i in chunks * 4..k {
                    acc += row[i] * xb[i];
                }
                yb[r] = acc;
            }
        }
        let macs = (n * k * m_batch) as u64;
        let counters = &mut scratch.counters;
        counters.mac_flops += macs;
        counters.read_ops += macs;
        counters.weight_bytes += (n * k * m_batch) as u64 * 2; // fp16 stream on device
        counters.activation_bytes += (k * m_batch) as u64 * 2;
        counters.calls += 1;
    }

    fn scratch(&self) -> &EngineScratch {
        &self.scratch
    }

    fn scratch_mut(&mut self) -> &mut EngineScratch {
        &mut self.scratch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn identity_gemv() {
        let n = 4;
        let mut w = vec![0f32; n * n];
        for i in 0..n {
            w[i * n + i] = 1.0;
        }
        let mut e = DenseEngine::new(w, n, n);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(e.gemv(&x), x);
    }

    #[test]
    fn known_small_product() {
        // W = [[1,2],[3,4]], x = [5,6] => y = [17, 39]
        let mut e = DenseEngine::new(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        assert_eq!(e.gemv(&[5.0, 6.0]), vec![17.0, 39.0]);
    }

    #[test]
    fn batch_equals_repeated_gemv() {
        let (n, k) = (16, 33); // odd k exercises the remainder loop
        let mut rng = Prng::seeded(1);
        let w = rng.normal_vec(n * k, 1.0);
        let x = rng.normal_vec(k * 3, 1.0);
        let mut e = DenseEngine::new(w, n, k);
        let batched = e.gemm(&x, 3);
        for b in 0..3 {
            let single = e.gemv(&x[b * k..(b + 1) * k]);
            assert_eq!(&batched[b * n..(b + 1) * n], single.as_slice());
        }
    }

    #[test]
    fn counters_track_macs() {
        let (n, k) = (8, 16);
        let mut e = DenseEngine::new(vec![0.0; n * k], n, k);
        let _ = e.gemv(&vec![0.0; k]);
        assert_eq!(e.counters().mac_flops, (n * k) as u64);
        assert_eq!(e.counters().calls, 1);
    }
}
