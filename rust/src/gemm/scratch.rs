//! Caller-owned engine scratch: every buffer a [`crate::gemm::GemmEngine`]
//! needs between the start and end of one `gemm_into` call, plus the work
//! counters that call accumulates into.
//!
//! Moving this state out of the engines is what makes them `&self` (and
//! therefore `Sync`-shareable across worker threads) and what makes the
//! decode hot loop allocation-free: buffers grow to the high-water mark of
//! the shapes they have seen and are then reused verbatim, so after one
//! warmup pass no `gemm_into` call touches the allocator.
//!
//! One scratch can serve many engines of different shapes/configs in
//! sequence (the model forward pass drives every linear of every layer
//! through a single scratch); sharded and tensor-parallel wrappers hand
//! each worker its own entry of [`EngineScratch::children`].

use crate::gemm::psumbook::Psumbook;
use crate::gemm::traffic::Counters;

/// Reusable scratch + counters for `gemm_into` calls.
#[derive(Clone, Debug, Default)]
pub struct EngineScratch {
    /// Work/traffic counters accumulated by every call made with this
    /// scratch (engines add; callers read/reset).
    pub counters: Counters,
    /// Primary f32 staging: CodeGEMM's activation tile, the dequant
    /// kernel's decode row, LUT-GEMM's chunk tables, and the
    /// tensor-parallel input staging.
    pub buf: Vec<f32>,
    /// Secondary f32 staging: batched shard outputs and row-parallel
    /// partial products in the sharded/TP wrappers.
    pub buf2: Vec<f32>,
    /// CodeGEMM's Psumbook (left empty by the other engines). Under the
    /// shared-book sharded schedule this is the **one** book per k-tile
    /// that every row shard gathers from — it lives in the caller's
    /// scratch, not the per-worker children, so a single build serves
    /// the whole fan-out.
    pub book: Psumbook,
    /// The software pipeline's spare Psumbook: under the pipelined
    /// shared-book schedule (`KernelConfig::pipeline_tiles`) tile `t+1`
    /// builds here while tile `t`'s gather reads `book`, then the two
    /// swap roles. Left empty by every other path.
    pub book2: Psumbook,
    /// Per-worker child scratches used by sharded / tensor-parallel
    /// wrappers (one per shard; leaf engines ignore this). On the
    /// shared-book path children carry only the per-shard gather
    /// counters — their buffers stay empty.
    pub children: Vec<EngineScratch>,
}

impl EngineScratch {
    pub fn new() -> EngineScratch {
        EngineScratch::default()
    }

    /// High-water f32 footprint of this scratch (excluding children).
    pub fn footprint_bytes(&self) -> usize {
        (self.buf.capacity()
            + self.buf2.capacity()
            + self.book.data.capacity()
            + self.book2.data.capacity())
            * 4
    }

    /// High-water footprint split by buffer, in bytes:
    /// `(buf, buf2, book, book2)`. Sums to [`footprint_bytes`] — the
    /// working-set breakdown `obs::roofline::FootprintAudit` places
    /// against the cache hierarchy (the books are the on-chip-resident
    /// part; the staging buffers are streamed).
    ///
    /// [`footprint_bytes`]: EngineScratch::footprint_bytes
    pub fn footprint_parts(&self) -> (usize, usize, usize, usize) {
        (
            self.buf.capacity() * 4,
            self.buf2.capacity() * 4,
            self.book.data.capacity() * 4,
            self.book2.data.capacity() * 4,
        )
    }
}

/// Grow-only borrow: ensure `buf` holds at least `len` elements and hand
/// back `&mut buf[..len]`. Contents are unspecified — callers overwrite.
/// Growth only happens while a buffer is still below its high-water mark,
/// which is what keeps steady-state calls allocation-free.
pub fn grow_slice(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
    &mut buf[..len]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_slice_is_grow_only() {
        let mut b = Vec::new();
        assert_eq!(grow_slice(&mut b, 4).len(), 4);
        let cap = b.capacity();
        assert_eq!(grow_slice(&mut b, 2).len(), 2);
        assert_eq!(b.capacity(), cap, "shrinking must not reallocate");
        assert_eq!(grow_slice(&mut b, 4).len(), 4);
        assert_eq!(b.capacity(), cap, "regrowth within capacity is free");
    }

    #[test]
    fn footprint_parts_sum_to_footprint_bytes() {
        let mut s = EngineScratch::new();
        s.buf.resize(7, 0.0);
        s.buf2.resize(3, 0.0);
        s.book.reshape(2, 1, 4, 1);
        let (a, b, c, d) = s.footprint_parts();
        assert_eq!(a + b + c + d, s.footprint_bytes());
        assert!(c > 0, "book capacity must be attributed");
    }

    #[test]
    fn default_scratch_is_empty() {
        let s = EngineScratch::new();
        assert_eq!(s.counters, Counters::default());
        assert!(s.buf.is_empty() && s.buf2.is_empty() && s.children.is_empty());
        assert!(s.book.is_empty() && s.book2.is_empty());
    }
}
