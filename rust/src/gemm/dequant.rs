//! Dequantization-based baseline kernel (the AQLM-style pipeline the
//! paper contrasts against, §2.3 / Figure 1a).
//!
//! For each weight tile the codes fetch centroids from the *full
//! codebook*, reconstruct the FP weights into a scratch buffer (drawn
//! from the caller's [`EngineScratch`], reused call-to-call), and a
//! plain dot product follows. Computational complexity stays at
//! `O(MNK)` (the paper's point) and the on-chip requirement is the whole
//! codebook (`m · 2^b · v` halfwords) — which is why AQLM-1×16 falls off
//! a cliff when `2^16` centroids no longer fit in shared memory.

use crate::config::{KernelConfig, QuantConfig};
use crate::gemm::scratch::{grow_slice, EngineScratch};
use crate::gemm::tiling::Tiles;
use crate::gemm::GemmEngine;
use crate::quant::QuantizedLinear;
use crate::util::timer::Timer;

/// CPU implementation of the dequantize-then-multiply kernel.
#[derive(Clone, Debug)]
pub struct DequantEngine {
    cfg: QuantConfig,
    kernel: KernelConfig,
    n: usize,
    k: usize,
    jn: usize,
    codebooks: Vec<f32>,
    codes: Vec<u16>,
    scales: Vec<f32>,
    groups_per_row: usize,
    scratch: EngineScratch,
}

impl DequantEngine {
    pub fn from_quantized(q: &QuantizedLinear) -> DequantEngine {
        Self::with_kernel(q, KernelConfig::default())
    }

    pub fn with_kernel(q: &QuantizedLinear, mut kernel: KernelConfig) -> DequantEngine {
        q.validate().expect("valid quantized layer");
        // Same clamp as CodeGEMM: tile_w rounds down to a v multiple
        // instead of asserting.
        kernel.align_tile_w(q.k, q.cfg.v);
        DequantEngine {
            cfg: q.cfg,
            kernel,
            n: q.n,
            k: q.k,
            jn: q.k / q.cfg.v,
            codebooks: q.codebooks.clone(),
            codes: q.codes.unpack().into_iter().map(|c| c as u16).collect(),
            scales: q.scales.clone(),
            groups_per_row: q.groups_per_row(),
            scratch: EngineScratch::new(),
        }
    }

    /// On-chip bytes the kernel needs resident: the full codebook (FP16).
    pub fn codebook_bytes(&self) -> usize {
        self.cfg.m * self.cfg.n_centroids() * self.cfg.v * 2
    }
}

impl GemmEngine for DequantEngine {
    fn name(&self) -> &'static str {
        "dequant"
    }

    fn dims(&self) -> (usize, usize) {
        (self.n, self.k)
    }

    fn gemm_into(&self, x: &[f32], m_batch: usize, y: &mut [f32], scratch: &mut EngineScratch) {
        assert_eq!(x.len(), self.k * m_batch);
        assert_eq!(y.len(), self.n * m_batch);
        y.fill(0.0);
        let (n, k) = (self.n, self.k);
        let v = self.cfg.v;
        let m = self.cfg.m;
        let nc = self.cfg.n_centroids();
        let g = self.cfg.group_size(k);
        let tw = self.kernel.tile_w;
        let th = self.kernel.tile_h;
        let gpr = self.groups_per_row;
        let EngineScratch { counters, buf, .. } = scratch;
        let wrow = grow_slice(buf, tw); // decode scratch (one row-tile)
        for (r0, r1) in Tiles::new(n, th) {
            for (c0, c1) in Tiles::new(k, tw) {
                let width = c1 - c0;
                let jn_tile = width / v;
                let j0 = c0 / v;
                for r in r0..r1 {
                    // Dequantize phase: reconstruct the row-tile weights.
                    let t = Timer::start();
                    wrow[..width].fill(0.0);
                    let base = (r * self.jn + j0) * m;
                    for j in 0..jn_tile {
                        for c in 0..m {
                            let code = self.codes[base + j * m + c] as usize;
                            let cent = &self.codebooks[(c * nc + code) * v..(c * nc + code + 1) * v];
                            for t in 0..v {
                                wrow[j * v + t] += cent[t];
                            }
                        }
                    }
                    // Apply group scales.
                    for t_idx in 0..width {
                        let col = c0 + t_idx;
                        wrow[t_idx] *= self.scales[r * gpr + col / g];
                    }
                    counters.build_seconds += t.elapsed_s();
                    let decode_ops = (jn_tile * m * v + width) as u64;
                    counters.build_ops += decode_ops;
                    counters.lookups += (jn_tile * m) as u64;

                    // Multiply phase: full dot per batch column — the
                    // unreduced O(MNK) compute the paper calls out.
                    let t = Timer::start();
                    for b in 0..m_batch {
                        let xb = &x[b * k + c0..b * k + c1];
                        let mut acc = 0f32;
                        for (wv, xv) in wrow[..width].iter().zip(xb) {
                            acc += wv * xv;
                        }
                        y[b * n + r] += acc;
                    }
                    counters.read_seconds += t.elapsed_s();
                    let macs = (width * m_batch) as u64;
                    counters.mac_flops += macs;
                    counters.read_ops += macs;
                    counters.scratch_bytes += (width * 4 * 2) as u64; // write + read decode buf
                    counters.weight_bytes += (jn_tile * m * 2) as u64; // codes (u16 stream)
                }
                // Codebook residency charged once per (row-block, tile),
                // as on the GPU where each thread block re-stages it.
                counters.weight_bytes += self.codebook_bytes() as u64;
            }
        }
        counters.weight_bytes += (n * gpr * 2) as u64;
        counters.activation_bytes += (k * m_batch * 2) as u64;
        counters.calls += 1;
    }

    fn scratch(&self) -> &EngineScratch {
        &self.scratch
    }

    fn scratch_mut(&mut self) -> &mut EngineScratch {
        &mut self.scratch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{CodeGemmEngine, DenseEngine};
    use crate::quant::Quantizer;
    use crate::util::prng::Prng;
    use crate::util::stats;

    fn quantize(n: usize, k: usize, label: &str, seed: u64) -> QuantizedLinear {
        let w = Prng::seeded(seed).normal_vec(n * k, 0.02);
        Quantizer::new(QuantConfig::parse_label(label).unwrap()).quantize(&w, n, k)
    }

    #[test]
    fn matches_dense_on_dequantized_weights() {
        let q = quantize(40, 96, "m2v8g32", 1);
        let x = Prng::seeded(2).normal_vec(96 * 2, 1.0);
        let y_ref = DenseEngine::new(q.dequantize(), 40, 96).gemm(&x, 2);
        let mut e = DequantEngine::from_quantized(&q);
        let y = e.gemm(&x, 2);
        assert!(stats::rel_l2(&y, &y_ref) < 2e-5);
    }

    #[test]
    fn agrees_with_codegemm_bitwise_semantics() {
        // Both kernels compute the same mathematical result; allow only
        // float reassociation noise.
        let q = quantize(64, 64, "m1v4g16", 3);
        let x = Prng::seeded(4).normal_vec(64, 1.0);
        let y_dq = DequantEngine::from_quantized(&q).gemv(&x);
        let y_cg = CodeGemmEngine::from_quantized(&q).gemv(&x);
        assert!(stats::rel_l2(&y_cg, &y_dq) < 2e-5);
    }

    #[test]
    fn compute_is_not_reduced_vs_dense() {
        // The paper's complexity argument: dequant MACs == dense MACs.
        let (n, k) = (32, 64);
        let q = quantize(n, k, "m1v4g-1", 5);
        let x = Prng::seeded(6).normal_vec(k, 1.0);
        let mut e = DequantEngine::from_quantized(&q);
        let _ = e.gemv(&x);
        assert_eq!(e.counters().mac_flops, (n * k) as u64);
    }

    #[test]
    fn codebook_bytes_formula() {
        let q = quantize(16, 32, "m2v8g32", 7);
        let e = DequantEngine::from_quantized(&q);
        assert_eq!(e.codebook_bytes(), 2 * 256 * 8 * 2);
    }

    #[test]
    fn misaligned_tile_w_clamps_instead_of_panicking() {
        let q = quantize(12, 64, "m1v8g32", 9);
        let e = DequantEngine::with_kernel(&q, KernelConfig { tile_w: 21, tile_h: 4, ..Default::default() });
        assert_eq!(e.kernel.tile_w, 16);
        let x = Prng::seeded(10).normal_vec(64, 1.0);
        let y_ref = DenseEngine::new(q.dequantize(), 12, 64).gemv(&x);
        let mut e = DequantEngine::with_kernel(&q, KernelConfig { tile_w: 21, tile_h: 4, ..Default::default() });
        assert!(stats::rel_l2(&e.gemv(&x), &y_ref) < 2e-5);
    }

    #[test]
    fn counters_show_more_weight_traffic_than_codegemm() {
        // The dequant kernel re-stages the whole codebook per tile, so its
        // weight-side traffic must exceed CodeGEMM's on the same layer.
        let q = quantize(128, 128, "m2v8g128", 8);
        let x = Prng::seeded(9).normal_vec(128, 1.0);
        let mut dq = DequantEngine::with_kernel(&q, KernelConfig { tile_w: 32, tile_h: 64, ..Default::default() });
        let mut cg = CodeGemmEngine::with_kernel(&q, KernelConfig { tile_w: 32, tile_h: 64, ..Default::default() });
        let _ = dq.gemv(&x);
        let _ = cg.gemv(&x);
        assert!(
            dq.counters().weight_bytes > cg.counters().weight_bytes,
            "dequant {} !> codegemm {}",
            dq.counters().weight_bytes,
            cg.counters().weight_bytes
        );
    }
}
