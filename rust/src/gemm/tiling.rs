//! Tiling helpers: the kernels walk the weight matrix in `(t_h × t_w)`
//! tiles exactly as the GPU kernels do (paper §3, Figure 3), which is
//! what makes the build/read phase accounting (Table 6) and the tile
//! sensitivity study (Table 7) meaningful on the CPU engines.

/// Half-open ranges covering `[0, len)` in steps of `tile`.
#[derive(Clone, Copy, Debug)]
pub struct Tiles {
    len: usize,
    tile: usize,
    pos: usize,
}

impl Tiles {
    pub fn new(len: usize, tile: usize) -> Tiles {
        assert!(tile > 0, "tile must be positive");
        Tiles { len, tile, pos: 0 }
    }

    /// Number of tiles.
    pub fn count(len: usize, tile: usize) -> usize {
        len.div_ceil(tile)
    }
}

impl Iterator for Tiles {
    type Item = (usize, usize);

    fn next(&mut self) -> Option<(usize, usize)> {
        if self.pos >= self.len {
            return None;
        }
        let start = self.pos;
        let end = (start + self.tile).min(self.len);
        self.pos = end;
        Some((start, end))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_exactly() {
        let tiles: Vec<_> = Tiles::new(10, 4).collect();
        assert_eq!(tiles, vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(Tiles::count(10, 4), 3);
    }

    #[test]
    fn exact_division() {
        let tiles: Vec<_> = Tiles::new(8, 4).collect();
        assert_eq!(tiles, vec![(0, 4), (4, 8)]);
    }

    #[test]
    fn empty_len() {
        assert_eq!(Tiles::new(0, 4).count(), 0);
    }

    #[test]
    fn tile_larger_than_len() {
        let tiles: Vec<_> = Tiles::new(3, 100).collect();
        assert_eq!(tiles, vec![(0, 3)]);
    }

    #[test]
    fn union_is_disjoint_cover() {
        let mut covered = vec![false; 37];
        for (a, b) in Tiles::new(37, 5) {
            for i in a..b {
                assert!(!covered[i], "overlap at {i}");
                covered[i] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }
}
