//! Uniform-quantization GEMM baseline (GPTQ/AWQ-class INTx-FP kernel,
//! paper §2.3). Dequantizes int weights with their group scale on the fly
//! and multiplies — data movement improves with the bit-width, compute
//! does not.

use crate::gemm::scratch::EngineScratch;
use crate::gemm::GemmEngine;
use crate::quant::uniform::UniformLinear;
use crate::util::timer::Timer;

/// CPU implementation of the INTx-FP uniform kernel.
#[derive(Clone, Debug)]
pub struct UniformGemmEngine {
    q: UniformLinear,
    scratch: EngineScratch,
}

impl UniformGemmEngine {
    pub fn new(q: UniformLinear) -> UniformGemmEngine {
        UniformGemmEngine { q, scratch: EngineScratch::new() }
    }
}

impl GemmEngine for UniformGemmEngine {
    fn name(&self) -> &'static str {
        "uniform-int"
    }

    fn dims(&self) -> (usize, usize) {
        (self.q.n, self.q.k)
    }

    fn gemm_into(&self, x: &[f32], m_batch: usize, y: &mut [f32], scratch: &mut EngineScratch) {
        let (n, k) = self.dims();
        assert_eq!(x.len(), k * m_batch);
        assert_eq!(y.len(), n * m_batch);
        let group = self.q.group;
        let n_groups = self.q.n_groups();
        let counters = &mut scratch.counters;
        let t = Timer::start();
        for b in 0..m_batch {
            let xb = &x[b * k..(b + 1) * k];
            for r in 0..n {
                let qrow = &self.q.qweight[r * k..(r + 1) * k];
                let srow = &self.q.scales[r * n_groups..(r + 1) * n_groups];
                let mut acc = 0f32;
                for (gi, scale) in srow.iter().enumerate() {
                    let lo = gi * group;
                    let mut gacc = 0f32;
                    for c in lo..lo + group {
                        gacc += qrow[c] as f32 * xb[c];
                    }
                    acc += scale * gacc;
                }
                y[b * n + r] = acc;
            }
        }
        counters.read_seconds += t.elapsed_s();
        let macs = (n * k * m_batch) as u64;
        counters.mac_flops += macs;
        counters.read_ops += macs;
        // Weight stream: packed ints + fp16 scales.
        counters.weight_bytes += ((n * k * self.q.bits).div_ceil(8) + n * n_groups * 2) as u64;
        counters.activation_bytes += (k * m_batch * 2) as u64;
        counters.calls += 1;
    }

    fn scratch(&self) -> &EngineScratch {
        &self.scratch
    }

    fn scratch_mut(&mut self) -> &mut EngineScratch {
        &mut self.scratch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::DenseEngine;
    use crate::util::prng::Prng;
    use crate::util::stats;

    #[test]
    fn matches_dense_on_dequantized_weights() {
        let (n, k) = (24, 64);
        let w = Prng::seeded(1).normal_vec(n * k, 0.02);
        let q = UniformLinear::quantize(&w, n, k, 4, 32).unwrap();
        let x = Prng::seeded(2).normal_vec(k * 2, 1.0);
        let y_ref = DenseEngine::new(q.dequantize(), n, k).gemm(&x, 2);
        let mut e = UniformGemmEngine::new(q);
        assert!(stats::rel_l2(&e.gemm(&x, 2), &y_ref) < 1e-5);
    }

    #[test]
    fn compute_equals_dense_macs() {
        let (n, k) = (8, 32);
        let q = UniformLinear::quantize(&vec![0.5f32; n * k], n, k, 2, 32).unwrap();
        let mut e = UniformGemmEngine::new(q);
        let _ = e.gemv(&vec![1.0f32; k]);
        assert_eq!(e.counters().mac_flops, (n * k) as u64);
    }

    #[test]
    fn weight_traffic_scales_with_bits() {
        let (n, k) = (8, 128);
        let w = Prng::seeded(3).normal_vec(n * k, 1.0);
        let traffic = |bits| {
            let q = UniformLinear::quantize(&w, n, k, bits, 128).unwrap();
            let mut e = UniformGemmEngine::new(q);
            let _ = e.gemv(&vec![1.0f32; k]);
            e.counters().weight_bytes
        };
        assert!(traffic(2) < traffic(4));
        assert!(traffic(4) < traffic(8));
    }
}
