//! Host-side arena for preempted sequences' private KV pages.
//!
//! When the batcher preempts a decoding slot in spill mode, the backend
//! snapshots the victim's page contents out of the [`super::BlockPool`]
//! into a [`SpilledKv`] (a standalone [`PageStore`] on the heap, outside
//! the pool's fixed budget), releases the pool pages, and parks the
//! spill in the [`SpillArena`] keyed by request id. Resume claims fresh
//! pages, bulk-copies the snapshot back, and continues decoding at the
//! exact position it left — bit-exact in every dtype, because the
//! snapshot holds the sequence's *coded* KV state verbatim
//! ([`super::BlockPool::export_pages`] /
//! [`super::BlockPool::import_page`] never decode→re-encode).
//!
//! Recompute mode skips all of this and replays the prompt plus the
//! already-sampled tokens instead — cheaper in host memory, more compute
//! on resume. Both are toggled by `KvConfig::preempt`.

use std::collections::HashMap;

use super::codec::PageStore;

/// One preempted sequence's KV state: whole pages, in page-table order.
#[derive(Clone, Debug)]
pub struct SpilledKv {
    /// Positions that were filled when the sequence was swapped out.
    pub len: usize,
    /// `pages_for(len)` pages of coded page contents (elements + any
    /// scale sidecar), concatenated in page-table order.
    pub data: PageStore,
}

impl SpilledKv {
    /// Coded host bytes this spill holds.
    pub fn bytes(&self) -> usize {
        self.data.bytes()
    }
}

/// Spilled sequences by request id. Host memory, unbounded by the pool —
/// the batcher bounds it implicitly by the number of slots it can
/// preempt.
#[derive(Clone, Debug, Default)]
pub struct SpillArena {
    spills: HashMap<u64, SpilledKv>,
}

impl SpillArena {
    pub fn new() -> SpillArena {
        SpillArena::default()
    }

    pub fn insert(&mut self, req_id: u64, spill: SpilledKv) {
        let prev = self.spills.insert(req_id, spill);
        debug_assert!(prev.is_none(), "request {req_id} spilled twice without a resume");
    }

    pub fn take(&mut self, req_id: u64) -> Option<SpilledKv> {
        self.spills.remove(&req_id)
    }

    pub fn len(&self) -> usize {
        self.spills.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spills.is_empty()
    }

    /// Total host bytes currently parked here (coded bytes — an int8
    /// victim spills ~3.8× less than an f32 one).
    pub fn bytes(&self) -> usize {
        self.spills.values().map(SpilledKv::bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KvDtype;

    #[test]
    fn insert_take_roundtrip_and_bytes() {
        let mut arena = SpillArena::new();
        assert!(arena.is_empty());
        arena.insert(7, SpilledKv { len: 3, data: PageStore::new(KvDtype::F32, 32, 4) });
        arena.insert(9, SpilledKv { len: 1, data: PageStore::new(KvDtype::Int8, 16, 4) });
        assert_eq!(arena.len(), 2);
        // f32: 32 × 4 bytes; int8: 16 × 1 + 4 row scales × 4 bytes.
        assert_eq!(arena.bytes(), 32 * 4 + 16 + 4 * 4);
        let s = arena.take(7).unwrap();
        assert_eq!(s.len, 3);
        assert_eq!(s.data.elems(), 32);
        assert!(arena.take(7).is_none());
        assert_eq!(arena.bytes(), 16 + 4 * 4);
    }
}
