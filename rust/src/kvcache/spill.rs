//! Host-side arena for preempted sequences' private KV pages.
//!
//! When the batcher preempts a decoding slot in spill mode, the backend
//! copies the victim's page contents out of the [`super::BlockPool`] into
//! a [`SpilledKv`] (plain heap floats, outside the pool's fixed budget),
//! releases the pool pages, and parks the spill in the [`SpillArena`]
//! keyed by request id. Resume claims fresh pages, bulk-copies the floats
//! back, and continues decoding at the exact position it left — bit-exact
//! because the page contents *are* the sequence's KV state.
//!
//! Recompute mode skips all of this and replays the prompt plus the
//! already-sampled tokens instead — cheaper in host memory, more compute
//! on resume. Both are toggled by `KvConfig::preempt`.

use std::collections::HashMap;

/// One preempted sequence's KV state: whole pages, in page-table order.
#[derive(Clone, Debug)]
pub struct SpilledKv {
    /// Positions that were filled when the sequence was swapped out.
    pub len: usize,
    /// `pages_for(len)` pages of raw page contents, concatenated.
    pub data: Vec<f32>,
}

impl SpilledKv {
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// Spilled sequences by request id. Host memory, unbounded by the pool —
/// the batcher bounds it implicitly by the number of slots it can
/// preempt.
#[derive(Clone, Debug, Default)]
pub struct SpillArena {
    spills: HashMap<u64, SpilledKv>,
}

impl SpillArena {
    pub fn new() -> SpillArena {
        SpillArena::default()
    }

    pub fn insert(&mut self, req_id: u64, spill: SpilledKv) {
        let prev = self.spills.insert(req_id, spill);
        debug_assert!(prev.is_none(), "request {req_id} spilled twice without a resume");
    }

    pub fn take(&mut self, req_id: u64) -> Option<SpilledKv> {
        self.spills.remove(&req_id)
    }

    pub fn len(&self) -> usize {
        self.spills.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spills.is_empty()
    }

    /// Total host bytes currently parked here.
    pub fn bytes(&self) -> usize {
        self.spills.values().map(SpilledKv::bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_take_roundtrip_and_bytes() {
        let mut arena = SpillArena::new();
        assert!(arena.is_empty());
        arena.insert(7, SpilledKv { len: 3, data: vec![1.0; 32] });
        arena.insert(9, SpilledKv { len: 1, data: vec![2.0; 16] });
        assert_eq!(arena.len(), 2);
        assert_eq!(arena.bytes(), (32 + 16) * 4);
        let s = arena.take(7).unwrap();
        assert_eq!(s.len, 3);
        assert_eq!(s.data.len(), 32);
        assert!(arena.take(7).is_none());
        assert_eq!(arena.bytes(), 16 * 4);
    }
}
