//! The sequence-facing side of the paged pool: a per-sequence page table
//! ([`SeqKv`]) plus the borrow that binds it to the shared arena for one
//! model call ([`PagedKv`]).
//!
//! `PagedKv` implements [`crate::kvcache::KvStore`] with the same
//! append/read semantics as the contiguous [`crate::model::KvCache`]
//! (write per `(layer, pos)`, length advances when the last layer writes a
//! new position) — bit-compatible by construction, property-pinned by
//! `tests/paged_kv_prop.rs` — but exposes the cache as per-page `&[f32]`
//! tiles instead of one contiguous slice. Pages are claimed lazily on
//! append (free-list pop, no heap traffic) and returned wholesale by
//! [`SeqKv::release`] when the request finishes.

use super::pool::BlockPool;
use super::KvStore;

/// Per-sequence KV state: the page table and the fill length. Owns no
/// storage — pages live in the [`BlockPool`]; `SeqKv` only names them.
#[derive(Clone, Debug, Default)]
pub struct SeqKv {
    /// Physical page id per logical page index (`pos / page_size`).
    pages: Vec<usize>,
    /// Number of positions filled so far.
    len: usize,
}

impl SeqKv {
    /// An empty sequence whose page table can hold `max_pages` entries
    /// without reallocating — pre-reserve with
    /// [`super::pool::KvLayout::max_pages_per_seq`] to keep the decode
    /// hot loop allocation-free.
    pub fn with_capacity(max_pages: usize) -> SeqKv {
        SeqKv { pages: Vec::with_capacity(max_pages), len: 0 }
    }

    /// Number of positions filled so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Pages currently held.
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Page-table capacity (for allocation-free-ness assertions).
    pub fn page_capacity(&self) -> usize {
        self.pages.capacity()
    }

    /// Return every page to `pool` and reset the fill (full reclamation;
    /// the table keeps its capacity for the next sequence in this slot).
    pub fn release(&mut self, pool: &mut BlockPool) {
        for page in self.pages.drain(..) {
            pool.free(page);
        }
        self.len = 0;
    }

    /// Pre-claim pages so this sequence holds at least `n_pages` — the
    /// admission-time reservation: once claimed, appends up to
    /// `n_pages × page_size` positions never touch the free list, and a
    /// subsequent `can_admit` check sees the reduced free count (so
    /// several admissions in one scheduler step cannot jointly
    /// oversubscribe the pool). Returns false (claiming nothing further)
    /// if the pool runs out mid-claim.
    pub fn claim(&mut self, pool: &mut BlockPool, n_pages: usize) -> bool {
        while self.pages.len() < n_pages {
            match pool.try_alloc() {
                Some(page) => self.pages.push(page),
                None => return false,
            }
        }
        true
    }
}

/// A sequence's KV cache bound to the shared pool for the duration of one
/// model call. Created per step by the owner of both halves (e.g. the
/// serving backend, which owns the pool and one `SeqKv` per slot).
pub struct PagedKv<'a> {
    pool: &'a mut BlockPool,
    seq: &'a mut SeqKv,
}

impl<'a> PagedKv<'a> {
    pub fn bind(pool: &'a mut BlockPool, seq: &'a mut SeqKv) -> PagedKv<'a> {
        PagedKv { pool, seq }
    }
}

impl KvStore for PagedKv<'_> {
    fn len(&self) -> usize {
        self.seq.len
    }

    fn max_seq(&self) -> usize {
        self.pool.layout().max_seq
    }

    fn kv_dim(&self) -> usize {
        self.pool.layout().kv_dim
    }

    fn n_layers(&self) -> usize {
        self.pool.layout().n_layers
    }

    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let l = self.pool.layout();
        assert!(pos < l.max_seq, "kv cache overflow: pos {pos} >= {}", l.max_seq);
        let pi = pos / l.page_size;
        // Lazy growth: claim pages up to the one holding `pos` (normally
        // a single pop every `page_size` appends).
        while self.seq.pages.len() <= pi {
            let page = self.pool.try_alloc().unwrap_or_else(|| {
                panic!(
                    "kv pool exhausted: {} pages all in use (size the pool for the worst-case \
                     concurrent footprint, or gate admission on free pages)",
                    self.pool.total_pages()
                )
            });
            self.seq.pages.push(page);
        }
        self.pool.write(self.seq.pages[pi], layer, pos % l.page_size, k, v);
        if layer + 1 == l.n_layers && pos >= self.seq.len {
            self.seq.len = pos + 1;
        }
    }

    fn clear(&mut self) {
        self.seq.release(self.pool);
    }

    fn tile_tokens(&self) -> usize {
        self.pool.layout().page_size
    }

    fn tile(&self, layer: usize, t: usize, upto: usize) -> (&[f32], &[f32]) {
        let ps = self.pool.layout().page_size;
        debug_assert!(t * ps < upto, "tile {t} starts at or past upto {upto}");
        let tokens = upto.min((t + 1) * ps) - t * ps;
        let page = self.seq.pages[t];
        (self.pool.k_tile(page, layer, tokens), self.pool.v_tile(page, layer, tokens))
    }

    fn bytes(&self) -> usize {
        self.seq.pages.len() * self.pool.layout().page_bytes()
    }

    fn bytes_used(&self) -> usize {
        self.pool.layout().bytes_for(self.seq.len)
    }
}

#[cfg(test)]
mod tests {
    use super::super::pool::KvLayout;
    use super::*;

    fn pool() -> BlockPool {
        BlockPool::new(KvLayout { n_layers: 2, kv_dim: 4, page_size: 4, max_seq: 16 }, 8)
    }

    #[test]
    fn append_read_matches_contiguous_semantics() {
        let mut pool = pool();
        let mut seq = SeqKv::with_capacity(4);
        {
            let mut kv = PagedKv::bind(&mut pool, &mut seq);
            let k = [1.0, 2.0, 3.0, 4.0];
            let v = [5.0, 6.0, 7.0, 8.0];
            kv.write(0, 0, &k, &v);
            assert_eq!(kv.len(), 0, "len advances only on the last layer");
            kv.write(1, 0, &k, &v);
            assert_eq!(kv.len(), 1);
            let (keys, vals) = kv.tile(0, 0, 1);
            assert_eq!(keys, &k);
            assert_eq!(vals, &v);
        }
        assert_eq!(seq.n_pages(), 1);
    }

    #[test]
    fn lazy_growth_claims_one_page_per_page_span() {
        let mut pool = pool();
        let mut seq = SeqKv::with_capacity(4);
        let mut kv = PagedKv::bind(&mut pool, &mut seq);
        let row = [0.0f32; 4];
        for pos in 0..9 {
            kv.write(0, pos, &row, &row);
            kv.write(1, pos, &row, &row);
        }
        assert_eq!(kv.len(), 9);
        assert_eq!(kv.bytes_used(), 2 * 2 * 9 * 4 * 4);
        drop(kv);
        // 9 positions at 4 tokens/page ⇒ 3 pages.
        assert_eq!(seq.n_pages(), 3);
        assert_eq!(pool.used_pages(), 3);
    }

    #[test]
    fn tiles_cover_positions_in_order() {
        let mut pool = pool();
        let mut seq = SeqKv::with_capacity(4);
        let mut kv = PagedKv::bind(&mut pool, &mut seq);
        for pos in 0..7 {
            let k = [pos as f32; 4];
            kv.write(0, pos, &k, &k);
            kv.write(1, pos, &k, &k);
        }
        // upto = 6 spans tile 0 (positions 0..4) and tile 1 (4..6).
        let (k0, _) = kv.tile(0, 0, 6);
        assert_eq!(k0.len(), 4 * 4);
        assert_eq!(k0[0], 0.0);
        assert_eq!(k0[3 * 4], 3.0);
        let (k1, v1) = kv.tile(0, 1, 6);
        assert_eq!(k1.len(), 2 * 4);
        assert_eq!(k1[0], 4.0);
        assert_eq!(k1[4], 5.0);
        assert_eq!(v1[4], 5.0);
    }

    #[test]
    fn release_reclaims_everything_and_keeps_capacity() {
        let mut pool = pool();
        let mut seq = SeqKv::with_capacity(4);
        {
            let mut kv = PagedKv::bind(&mut pool, &mut seq);
            let row = [0.0f32; 4];
            for pos in 0..16 {
                kv.write(0, pos, &row, &row);
                kv.write(1, pos, &row, &row);
            }
        }
        assert_eq!(pool.used_pages(), 4);
        let cap = seq.page_capacity();
        seq.release(&mut pool);
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(pool.free_pages(), pool.total_pages());
        assert_eq!(seq.len(), 0);
        assert_eq!(seq.page_capacity(), cap, "release must keep the table allocation");
    }

    #[test]
    #[should_panic(expected = "kv pool exhausted")]
    fn exhaustion_panics_with_context() {
        let mut pool =
            BlockPool::new(KvLayout { n_layers: 1, kv_dim: 2, page_size: 1, max_seq: 16 }, 2);
        let mut seq = SeqKv::with_capacity(16);
        let mut kv = PagedKv::bind(&mut pool, &mut seq);
        for pos in 0..3 {
            kv.write(0, pos, &[0.0; 2], &[0.0; 2]);
        }
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut pool = pool();
        let mut seq = SeqKv::default();
        let mut kv = PagedKv::bind(&mut pool, &mut seq);
        kv.write(0, 16, &[0.0; 4], &[0.0; 4]);
    }
}
