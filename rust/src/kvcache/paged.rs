//! The sequence-facing side of the paged pool: a per-sequence page table
//! ([`SeqKv`]) plus the borrow that binds it to the shared arena for one
//! model call ([`PagedKv`]).
//!
//! `PagedKv` implements [`crate::kvcache::KvStore`] with the same
//! append/read semantics as the contiguous [`crate::model::KvCache`]
//! (write per `(layer, pos)`, length advances when the last layer writes a
//! new position) — bit-compatible by construction, property-pinned by
//! `tests/paged_kv_prop.rs` — but exposes the cache as per-page tile
//! views instead of one contiguous slice (decoded into the caller's
//! scratch under coded dtypes, borrowed zero-copy under f32). Pages are
//! claimed lazily on append (free-list pop, no heap traffic) and
//! dereferenced wholesale by [`SeqKv::release`] when the request
//! finishes.
//!
//! With prefix sharing, a table may start with *pinned* pages another
//! sequence filled ([`SeqKv::set_prefix`]); those are immutable, and the
//! first write into one triggers copy-on-write — the page is copied into
//! a private page (the admission-pre-claimed [`SeqKv::claim_cow_spare`]
//! when available), the shared reference is dropped, and the table entry
//! is swapped. Reads before the divergence point see bit-identical
//! content by construction.

use super::pool::BlockPool;
use super::KvStore;

/// Per-sequence KV state: the page table and the fill length. Owns no
/// storage — pages live in the [`BlockPool`]; `SeqKv` only names them,
/// holding one reference per table entry (plus one for the optional
/// copy-on-write spare).
#[derive(Clone, Debug, Default)]
pub struct SeqKv {
    /// Physical page id per logical page index (`pos / page_size`).
    pages: Vec<usize>,
    /// Number of positions filled so far.
    len: usize,
    /// A page pre-claimed at admission for the guaranteed copy-on-write
    /// when the sequence's first write lands inside a pinned prefix page
    /// — so the CoW can never hit an exhausted free list mid-step.
    cow_spare: Option<usize>,
}

impl SeqKv {
    /// An empty sequence whose page table can hold `max_pages` entries
    /// without reallocating — pre-reserve with
    /// [`super::pool::KvLayout::max_pages_per_seq`] to keep the decode
    /// hot loop allocation-free.
    pub fn with_capacity(max_pages: usize) -> SeqKv {
        SeqKv { pages: Vec::with_capacity(max_pages), len: 0, cow_spare: None }
    }

    /// Number of positions filled so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Pages currently held.
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Page-table capacity (for allocation-free-ness assertions).
    pub fn page_capacity(&self) -> usize {
        self.pages.capacity()
    }

    /// The page table (shared prefix pages first, in prompt order).
    pub fn pages(&self) -> &[usize] {
        &self.pages
    }

    /// Drop one reference to every held page and reset the fill (full
    /// reclamation from this sequence's side; shared pages survive under
    /// their other holders or park in the prefix cache). The table keeps
    /// its capacity for the next sequence in this slot.
    pub fn release(&mut self, pool: &mut BlockPool) {
        for page in self.pages.drain(..) {
            pool.free(page);
        }
        if let Some(spare) = self.cow_spare.take() {
            pool.free(spare);
        }
        self.len = 0;
    }

    /// Install admission's prefix-cache pins: `pages` (already pinned in
    /// the pool, in prompt order) become the head of the table and the
    /// first `matched` positions are treated as filled — prefill resumes
    /// at `matched` instead of 0. Only valid on an empty sequence.
    pub fn set_prefix(&mut self, pages: &[usize], matched: usize) {
        debug_assert!(self.pages.is_empty() && self.len == 0, "set_prefix on a live sequence");
        self.pages.extend_from_slice(pages);
        self.len = matched;
    }

    /// Pre-claim the copy-on-write spare (see [`SeqKv::release`] for its
    /// lifecycle). Returns false when the pool is exhausted.
    pub fn claim_cow_spare(&mut self, pool: &mut BlockPool) -> bool {
        debug_assert!(self.cow_spare.is_none());
        match pool.try_alloc() {
            Some(page) => {
                self.cow_spare = Some(page);
                true
            }
            None => false,
        }
    }

    /// Force the fill length (spill-restore: pages were bulk-copied back
    /// rather than appended position-by-position).
    pub fn set_len(&mut self, len: usize) {
        self.len = len;
    }

    /// Pre-claim pages so this sequence holds at least `n_pages` — the
    /// admission-time reservation: once claimed, appends up to
    /// `n_pages × page_size` positions never touch the free list, and a
    /// subsequent `can_admit` check sees the reduced free count (so
    /// several admissions in one scheduler step cannot jointly
    /// oversubscribe the pool). Returns false (claiming nothing further)
    /// if the pool runs out mid-claim.
    pub fn claim(&mut self, pool: &mut BlockPool, n_pages: usize) -> bool {
        while self.pages.len() < n_pages {
            match pool.try_alloc() {
                Some(page) => self.pages.push(page),
                None => return false,
            }
        }
        true
    }
}

/// A sequence's KV cache bound to the shared pool for the duration of one
/// model call. Created per step by the owner of both halves (e.g. the
/// serving backend, which owns the pool and one `SeqKv` per slot).
pub struct PagedKv<'a> {
    pool: &'a mut BlockPool,
    seq: &'a mut SeqKv,
}

impl<'a> PagedKv<'a> {
    pub fn bind(pool: &'a mut BlockPool, seq: &'a mut SeqKv) -> PagedKv<'a> {
        PagedKv { pool, seq }
    }
}

impl KvStore for PagedKv<'_> {
    fn len(&self) -> usize {
        self.seq.len
    }

    fn max_seq(&self) -> usize {
        self.pool.layout().max_seq
    }

    fn kv_dim(&self) -> usize {
        self.pool.layout().kv_dim
    }

    fn n_layers(&self) -> usize {
        self.pool.layout().n_layers
    }

    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let l = self.pool.layout();
        assert!(pos < l.max_seq, "kv cache overflow: pos {pos} >= {}", l.max_seq);
        let pi = pos / l.page_size;
        // Lazy growth: claim pages up to the one holding `pos` (normally
        // a single pop every `page_size` appends).
        while self.seq.pages.len() <= pi {
            let page = self.pool.try_alloc().unwrap_or_else(|| {
                panic!(
                    "kv pool exhausted: {} pages all in use (size the pool for the worst-case \
                     concurrent footprint, or gate admission on free pages)",
                    self.pool.total_pages()
                )
            });
            self.seq.pages.push(page);
        }
        let mut page = self.seq.pages[pi];
        // Copy-on-write: a page another sequence (or the prefix index)
        // can observe is immutable — divergence copies it into a private
        // page first. Admission pre-claims `cow_spare` whenever it pins a
        // page the sequence will write into, so the guaranteed copy never
        // races the free list; lazy divergence (direct PagedKv users)
        // falls back to an ordinary allocation.
        if self.pool.is_immutable(page) {
            let np = self.seq.cow_spare.take().or_else(|| self.pool.try_alloc()).unwrap_or_else(
                || {
                    panic!(
                        "kv pool exhausted during copy-on-write of page {page} \
                         (admission must pre-claim the CoW spare)"
                    )
                },
            );
            self.pool.copy_page(page, np);
            self.pool.free(page);
            self.seq.pages[pi] = np;
            page = np;
        }
        self.pool.write(page, layer, pos % l.page_size, k, v);
        if layer + 1 == l.n_layers && pos >= self.seq.len {
            self.seq.len = pos + 1;
        }
    }

    fn clear(&mut self) {
        self.seq.release(self.pool);
    }

    fn tile_tokens(&self) -> usize {
        self.pool.layout().page_size
    }

    fn k_tile<'b>(&'b self, layer: usize, t: usize, upto: usize, buf: &'b mut Vec<f32>) -> &'b [f32] {
        let ps = self.pool.layout().page_size;
        debug_assert!(t * ps < upto, "tile {t} starts at or past upto {upto}");
        let tokens = upto.min((t + 1) * ps) - t * ps;
        self.pool.k_tile(self.seq.pages[t], layer, tokens, buf)
    }

    fn v_tile<'b>(&'b self, layer: usize, t: usize, upto: usize, buf: &'b mut Vec<f32>) -> &'b [f32] {
        let ps = self.pool.layout().page_size;
        debug_assert!(t * ps < upto, "tile {t} starts at or past upto {upto}");
        let tokens = upto.min((t + 1) * ps) - t * ps;
        self.pool.v_tile(self.seq.pages[t], layer, tokens, buf)
    }

    fn bytes(&self) -> usize {
        self.seq.pages.len() * self.pool.layout().page_bytes()
    }

    fn bytes_used(&self) -> usize {
        self.pool.layout().bytes_for(self.seq.len)
    }
}

#[cfg(test)]
mod tests {
    use super::super::pool::KvLayout;
    use super::*;

    fn pool() -> BlockPool {
        let l = KvLayout {
            n_layers: 2,
            kv_dim: 4,
            page_size: 4,
            max_seq: 16,
            dtype: crate::config::KvDtype::F32,
        };
        BlockPool::new(l, 8)
    }

    #[test]
    fn append_read_matches_contiguous_semantics() {
        let mut pool = pool();
        let mut seq = SeqKv::with_capacity(4);
        {
            let mut kv = PagedKv::bind(&mut pool, &mut seq);
            let k = [1.0, 2.0, 3.0, 4.0];
            let v = [5.0, 6.0, 7.0, 8.0];
            kv.write(0, 0, &k, &v);
            assert_eq!(kv.len(), 0, "len advances only on the last layer");
            kv.write(1, 0, &k, &v);
            assert_eq!(kv.len(), 1);
            let mut buf = Vec::new();
            assert_eq!(kv.k_tile(0, 0, 1, &mut buf), &k);
            let mut buf = Vec::new();
            assert_eq!(kv.v_tile(0, 0, 1, &mut buf), &v);
        }
        assert_eq!(seq.n_pages(), 1);
    }

    #[test]
    fn lazy_growth_claims_one_page_per_page_span() {
        let mut pool = pool();
        let mut seq = SeqKv::with_capacity(4);
        let mut kv = PagedKv::bind(&mut pool, &mut seq);
        let row = [0.0f32; 4];
        for pos in 0..9 {
            kv.write(0, pos, &row, &row);
            kv.write(1, pos, &row, &row);
        }
        assert_eq!(kv.len(), 9);
        assert_eq!(kv.bytes_used(), 2 * 2 * 9 * 4 * 4);
        drop(kv);
        // 9 positions at 4 tokens/page ⇒ 3 pages.
        assert_eq!(seq.n_pages(), 3);
        assert_eq!(pool.used_pages(), 3);
    }

    #[test]
    fn tiles_cover_positions_in_order() {
        let mut pool = pool();
        let mut seq = SeqKv::with_capacity(4);
        let mut kv = PagedKv::bind(&mut pool, &mut seq);
        for pos in 0..7 {
            let k = [pos as f32; 4];
            kv.write(0, pos, &k, &k);
            kv.write(1, pos, &k, &k);
        }
        // upto = 6 spans tile 0 (positions 0..4) and tile 1 (4..6).
        let mut buf = Vec::new();
        let k0 = kv.k_tile(0, 0, 6, &mut buf);
        assert_eq!(k0.len(), 4 * 4);
        assert_eq!(k0[0], 0.0);
        assert_eq!(k0[3 * 4], 3.0);
        let mut buf = Vec::new();
        let k1 = kv.k_tile(0, 1, 6, &mut buf);
        assert_eq!(k1.len(), 2 * 4);
        assert_eq!(k1[0], 4.0);
        assert_eq!(k1[4], 5.0);
        let mut buf = Vec::new();
        let v1 = kv.v_tile(0, 1, 6, &mut buf);
        assert_eq!(v1[4], 5.0);
    }

    #[test]
    fn release_reclaims_everything_and_keeps_capacity() {
        let mut pool = pool();
        let mut seq = SeqKv::with_capacity(4);
        {
            let mut kv = PagedKv::bind(&mut pool, &mut seq);
            let row = [0.0f32; 4];
            for pos in 0..16 {
                kv.write(0, pos, &row, &row);
                kv.write(1, pos, &row, &row);
            }
        }
        assert_eq!(pool.used_pages(), 4);
        let cap = seq.page_capacity();
        seq.release(&mut pool);
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(pool.free_pages(), pool.total_pages());
        assert_eq!(seq.len(), 0);
        assert_eq!(seq.page_capacity(), cap, "release must keep the table allocation");
    }

    #[test]
    fn cow_diverges_shared_page_without_touching_original() {
        let mut pool = pool();
        let mut a = SeqKv::with_capacity(4);
        {
            let mut kv = PagedKv::bind(&mut pool, &mut a);
            for pos in 0..4 {
                let k = [pos as f32; 4];
                kv.write(0, pos, &k, &k);
                kv.write(1, pos, &k, &k);
            }
        }
        let prompt: Vec<usize> = (10..14).collect();
        pool.publish_prefix(&prompt, a.pages());
        // Hitter pins the full page but recomputes the last position
        // (the admission cap), so its first write lands inside the
        // pinned page and must diverge.
        let mut b = SeqKv::with_capacity(4);
        let pinned = pool.prefix_acquire(&prompt, usize::MAX);
        assert_eq!(pinned.len(), 1);
        b.set_prefix(&pinned, 3);
        assert!(b.claim_cow_spare(&mut pool));
        {
            let mut kv = PagedKv::bind(&mut pool, &mut b);
            let k = [9.0f32; 4];
            kv.write(0, 3, &k, &k);
            kv.write(1, 3, &k, &k);
            assert_eq!(kv.len(), 4);
        }
        assert_ne!(a.pages()[0], b.pages()[0], "divergence must copy, not mutate");
        let mut buf = Vec::new();
        assert_eq!(pool.k_tile(a.pages()[0], 0, 4, &mut buf)[3 * 4], 3.0, "original untouched");
        let mut buf = Vec::new();
        assert_eq!(pool.k_tile(b.pages()[0], 0, 4, &mut buf)[3 * 4], 9.0, "copy holds the new write");
        let mut buf = Vec::new();
        assert_eq!(pool.k_tile(b.pages()[0], 0, 4, &mut buf)[2 * 4], 2.0, "pre-divergence content shared");
        assert_eq!(pool.stats().cow_copies, 1);
        b.release(&mut pool);
        a.release(&mut pool);
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(pool.free_pages(), pool.total_pages(), "cached prefix page still allocatable");
    }

    #[test]
    #[should_panic(expected = "kv pool exhausted")]
    fn exhaustion_panics_with_context() {
        let l = KvLayout {
            n_layers: 1,
            kv_dim: 2,
            page_size: 1,
            max_seq: 16,
            dtype: crate::config::KvDtype::F32,
        };
        let mut pool = BlockPool::new(l, 2);
        let mut seq = SeqKv::with_capacity(16);
        let mut kv = PagedKv::bind(&mut pool, &mut seq);
        for pos in 0..3 {
            kv.write(0, pos, &[0.0; 2], &[0.0; 2]);
        }
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut pool = pool();
        let mut seq = SeqKv::default();
        let mut kv = PagedKv::bind(&mut pool, &mut seq);
        kv.write(0, 16, &[0.0; 4], &[0.0; 4]);
    }
}
