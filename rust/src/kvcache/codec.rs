//! Page **codec**: the pool's coded element storage, pluggable per
//! [`KvDtype`].
//!
//! [`PageStore`] owns the arena bytes for every page in a
//! [`super::BlockPool`] (and for the page snapshots a
//! [`super::SpillArena`] holds). Three layouts:
//!
//! - **f32** — passthrough. Tile reads borrow pool memory directly
//!   (zero copy, zero decode), so the default config pays nothing for
//!   the codec layer existing.
//! - **f16** — IEEE half, round-to-nearest-even
//!   ([`crate::util::f16`]). 2 bytes/element; decode reproduces the
//!   stored value exactly, so paged runs stay deterministic
//!   bit-for-bit (write → read → write round-trips are fixed points).
//! - **int8** — round-to-nearest uniform quantization with one f32
//!   scale per **row** (one kv_dim vector: per page, per layer, per
//!   K/V side, per position), reusing `quant::uniform`'s recipe:
//!   `scale = round_f16(amax / 127)` (degenerate rows store scale 1),
//!   `q = clamp(round(x / scale), -128, 127)`. 1 byte/element + a
//!   4-byte sidecar scale per row. Per-row granularity means every
//!   write is independent and deterministic — the batched prefill walk
//!   and the m=1 walk store identical bytes, and CoW / spill copy the
//!   coded representation verbatim without re-encoding drift.
//!
//! Every offset handed to the store is a multiple of the row width
//! `kv_dim` (pages are `[layer][K rows | V rows]` with row-aligned
//! sections), which is what lets the int8 sidecar index be simply
//! `offset / kv_dim`.

use crate::config::KvDtype;
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits, round_f16};

/// Coded element storage for a run of KV rows. All offsets/lengths are
/// in *elements* (f32 lanes) and must be multiples of the row width
/// `kv_dim` the store was built with.
#[derive(Clone, Debug, PartialEq)]
pub enum PageStore {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Int8 {
        q: Vec<i8>,
        /// One scale per kv_dim row: `scales[off / kv_dim]`.
        scales: Vec<f32>,
        kv_dim: usize,
    },
}

impl PageStore {
    /// A zeroed store of `elems` f32 lanes coded as `dtype`, with rows
    /// of `kv_dim` elements. `elems` must be a multiple of `kv_dim`.
    pub fn new(dtype: KvDtype, elems: usize, kv_dim: usize) -> PageStore {
        assert!(kv_dim > 0 && elems % kv_dim == 0, "elems {elems} not row-aligned to kv_dim {kv_dim}");
        match dtype {
            KvDtype::F32 => PageStore::F32(vec![0.0; elems]),
            KvDtype::F16 => PageStore::F16(vec![0; elems]),
            // Scale 1.0 matches what encoding a zero row stores, so a
            // fresh store equals an explicitly-zeroed one bit-for-bit.
            KvDtype::Int8 => PageStore::Int8 {
                q: vec![0; elems],
                scales: vec![1.0; elems / kv_dim],
                kv_dim,
            },
        }
    }

    pub fn dtype(&self) -> KvDtype {
        match self {
            PageStore::F32(_) => KvDtype::F32,
            PageStore::F16(_) => KvDtype::F16,
            PageStore::Int8 { .. } => KvDtype::Int8,
        }
    }

    /// Total f32-lane capacity.
    pub fn elems(&self) -> usize {
        match self {
            PageStore::F32(d) => d.len(),
            PageStore::F16(d) => d.len(),
            PageStore::Int8 { q, .. } => q.len(),
        }
    }

    /// Coded bytes actually held (element storage + int8 scale sidecar).
    pub fn bytes(&self) -> usize {
        match self {
            PageStore::F32(d) => d.len() * 4,
            PageStore::F16(d) => d.len() * 2,
            PageStore::Int8 { q, scales, .. } => q.len() + scales.len() * 4,
        }
    }

    /// Encode one full row (`src.len() == kv_dim`) at element offset
    /// `off` (a multiple of kv_dim).
    pub fn write_row(&mut self, off: usize, src: &[f32]) {
        match self {
            PageStore::F32(d) => d[off..off + src.len()].copy_from_slice(src),
            PageStore::F16(d) => {
                for (dst, &x) in d[off..off + src.len()].iter_mut().zip(src) {
                    *dst = f32_to_f16_bits(x);
                }
            }
            PageStore::Int8 { q, scales, kv_dim } => {
                debug_assert_eq!(src.len(), *kv_dim, "int8 rows encode whole kv_dim vectors");
                debug_assert_eq!(off % *kv_dim, 0, "int8 write offset {off} not row-aligned");
                let amax = src.iter().fold(0f32, |m, &x| m.max(x.abs()));
                // quant::uniform's RTN recipe: an f16-rounded scale (so
                // the sidecar is reproducible) with degenerate rows
                // pinned to 1.0.
                let mut scale = if amax > 0.0 { round_f16(amax / 127.0) } else { 1.0 };
                if scale == 0.0 {
                    scale = 1.0;
                }
                scales[off / *kv_dim] = scale;
                for (dst, &x) in q[off..off + src.len()].iter_mut().zip(src) {
                    *dst = (x / scale).round().clamp(-128.0, 127.0) as i8;
                }
            }
        }
    }

    /// Decode `len` elements starting at `off`. For f32 this borrows
    /// pool memory directly and ignores `buf`; coded layouts decode
    /// into `buf` (resized as needed) and return a borrow of it.
    pub fn read<'a>(&'a self, off: usize, len: usize, buf: &'a mut Vec<f32>) -> &'a [f32] {
        match self {
            PageStore::F32(d) => &d[off..off + len],
            PageStore::F16(d) => {
                buf.clear();
                buf.extend(d[off..off + len].iter().map(|&h| f16_bits_to_f32(h)));
                &buf[..]
            }
            PageStore::Int8 { q, scales, kv_dim } => {
                debug_assert!(off % *kv_dim == 0 && len % *kv_dim == 0, "int8 reads are row-aligned");
                buf.clear();
                buf.reserve(len);
                for (r, row) in q[off..off + len].chunks_exact(*kv_dim).enumerate() {
                    let scale = scales[off / *kv_dim + r];
                    buf.extend(row.iter().map(|&v| v as f32 * scale));
                }
                &buf[..]
            }
        }
    }

    /// Copy `len` coded elements (plus their sidecar scales) from
    /// `src_off` to `dst_off` within this store — never decodes, so the
    /// destination is bit-identical to the source in every dtype.
    pub fn copy_within(&mut self, src_off: usize, dst_off: usize, len: usize) {
        match self {
            PageStore::F32(d) => d.copy_within(src_off..src_off + len, dst_off),
            PageStore::F16(d) => d.copy_within(src_off..src_off + len, dst_off),
            PageStore::Int8 { q, scales, kv_dim } => {
                q.copy_within(src_off..src_off + len, dst_off);
                let (s0, d0, n) = (src_off / *kv_dim, dst_off / *kv_dim, len / *kv_dim);
                scales.copy_within(s0..s0 + n, d0);
            }
        }
    }

    /// Copy `len` coded elements (plus sidecar scales) from another
    /// store of the same dtype — the spill/restore path, which must
    /// move the quantized representation verbatim.
    pub fn copy_from(&mut self, src: &PageStore, src_off: usize, dst_off: usize, len: usize) {
        match (self, src) {
            (PageStore::F32(d), PageStore::F32(s)) => {
                d[dst_off..dst_off + len].copy_from_slice(&s[src_off..src_off + len])
            }
            (PageStore::F16(d), PageStore::F16(s)) => {
                d[dst_off..dst_off + len].copy_from_slice(&s[src_off..src_off + len])
            }
            (
                PageStore::Int8 { q: dq, scales: ds, kv_dim: dk },
                PageStore::Int8 { q: sq, scales: ss, kv_dim: sk },
            ) => {
                debug_assert_eq!(dk, sk, "int8 stores disagree on row width");
                dq[dst_off..dst_off + len].copy_from_slice(&sq[src_off..src_off + len]);
                let (s0, d0, n) = (src_off / *sk, dst_off / *dk, len / *dk);
                ds[d0..d0 + n].copy_from_slice(&ss[s0..s0 + n]);
            }
            (me, src) => panic!(
                "page codec dtype mismatch: copying {:?} into {:?}",
                src.dtype(),
                me.dtype()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn f32_reads_are_zero_copy_and_exact() {
        let mut s = PageStore::new(KvDtype::F32, 16, 4);
        let row = [1.5f32, -2.25, 0.0, 1e-3];
        s.write_row(4, &row);
        let mut buf = Vec::new();
        assert_eq!(s.read(4, 4, &mut buf), &row);
        assert!(buf.is_empty(), "f32 path must not touch the decode buffer");
        assert_eq!(s.bytes(), 16 * 4);
    }

    #[test]
    fn f16_roundtrip_is_a_fixed_point() {
        let mut s = PageStore::new(KvDtype::F16, 8, 4);
        let row = Prng::seeded(3).normal_vec(4, 1.0);
        s.write_row(0, &row);
        let mut buf = Vec::new();
        let once: Vec<f32> = s.read(0, 4, &mut buf).to_vec();
        // Re-encoding the decoded values must be lossless (RNE half is
        // exact on values that are already halves).
        s.write_row(4, &once);
        let mut buf2 = Vec::new();
        assert_eq!(s.read(4, 4, &mut buf2), &once[..]);
        assert_eq!(s.bytes(), 8 * 2);
    }

    #[test]
    fn int8_rows_decode_within_half_step_and_account_sidecar() {
        let kv_dim = 8;
        let mut s = PageStore::new(KvDtype::Int8, 2 * kv_dim, kv_dim);
        let row = Prng::seeded(7).normal_vec(kv_dim, 0.5);
        s.write_row(kv_dim, &row);
        let mut buf = Vec::new();
        let dec = s.read(kv_dim, kv_dim, &mut buf);
        let amax = row.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let step = round_f16(amax / 127.0);
        for (d, x) in dec.iter().zip(&row) {
            assert!((d - x).abs() <= 0.5 * step + 1e-7, "decoded {d} vs {x} (step {step})");
        }
        // 1 byte/element + one f32 scale per row.
        assert_eq!(s.bytes(), 2 * kv_dim + 2 * 4);
    }

    #[test]
    fn int8_zero_row_is_the_fresh_store() {
        let mut s = PageStore::new(KvDtype::Int8, 8, 4);
        let fresh = s.clone();
        s.write_row(0, &[0.0; 4]);
        s.write_row(4, &[0.0; 4]);
        assert_eq!(s, fresh, "encoding zero rows must be idempotent on a fresh store");
    }

    #[test]
    fn coded_copies_are_verbatim_in_every_dtype() {
        for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
            let kv_dim = 4;
            let mut a = PageStore::new(dtype, 4 * kv_dim, kv_dim);
            let mut rng = Prng::seeded(11);
            for r in 0..2 {
                let row = rng.normal_vec(kv_dim, 2.0);
                a.write_row(r * kv_dim, &row);
            }
            // within-store copy (the CoW path)
            a.copy_within(0, 2 * kv_dim, 2 * kv_dim);
            let (mut b1, mut b2) = (Vec::new(), Vec::new());
            let lo = a.read(0, 2 * kv_dim, &mut b1).to_vec();
            let hi = a.read(2 * kv_dim, 2 * kv_dim, &mut b2).to_vec();
            assert_eq!(lo, hi, "{dtype:?} copy_within drifted");
            // cross-store copy (the spill path)
            let mut b = PageStore::new(dtype, 2 * kv_dim, kv_dim);
            b.copy_from(&a, 0, 0, 2 * kv_dim);
            let mut b3 = Vec::new();
            assert_eq!(b.read(0, 2 * kv_dim, &mut b3), &lo[..], "{dtype:?} copy_from drifted");
        }
    }

    #[test]
    #[should_panic(expected = "dtype mismatch")]
    fn cross_dtype_copy_panics() {
        let mut a = PageStore::new(KvDtype::F32, 4, 4);
        let b = PageStore::new(KvDtype::F16, 4, 4);
        a.copy_from(&b, 0, 0, 4);
    }
}
