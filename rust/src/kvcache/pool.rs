//! Fixed-size-page KV arena shared by every sequence and layer.
//!
//! One [`BlockPool`] backs all serving slots: a single `f32` allocation
//! carved into pages of [`KvLayout::page_size`] tokens, handed out through
//! a LIFO free list and returned in full when a sequence finishes. Pool
//! memory therefore bounds *concurrency × live tokens*, not
//! `slots × max_seq` — the per-request worst-case allocation the
//! contiguous [`crate::model::KvCache`] pays.
//!
//! Page layout (one page, `page_elems` floats):
//!
//! ```text
//! [layer 0: K rows (page_size × kv_dim) | V rows (page_size × kv_dim)]
//! [layer 1: K rows                      | V rows                     ]
//! ...
//! ```
//!
//! Keys of consecutive positions within a page are contiguous per layer,
//! so the chunked attention kernel ([`crate::model::attention`]) walks a
//! sequence page-by-page with the same inner loops it would run over a
//! contiguous cache — the page size is the attention tile size.

use crate::config::{KvConfig, ModelConfig};

/// Geometry of every page in a pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvLayout {
    pub n_layers: usize,
    /// Floats per cached position per layer (for K; same for V).
    pub kv_dim: usize,
    /// Tokens per page — also the attention kernel's tile height.
    pub page_size: usize,
    /// Maximum sequence length (positions; bounds page tables, not pool
    /// memory).
    pub max_seq: usize,
}

impl KvLayout {
    /// Floats in one page (all layers, K and V).
    pub fn page_elems(&self) -> usize {
        self.n_layers * 2 * self.page_size * self.kv_dim
    }

    /// Bytes in one page.
    pub fn page_bytes(&self) -> usize {
        self.page_elems() * 4
    }

    /// Pages needed to hold `tokens` positions.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_size)
    }

    /// Bytes filled by `positions` cached positions (K and V, all
    /// layers) — the single source of the fill-bytes formula shared by
    /// the paged handle and the serving metrics.
    pub fn bytes_for(&self, positions: usize) -> usize {
        2 * self.n_layers * positions * self.kv_dim * 4
    }

    /// Upper bound of pages one sequence can ever hold.
    pub fn max_pages_per_seq(&self) -> usize {
        self.pages_for(self.max_seq)
    }

    /// Offset of layer `layer`'s K block inside a page.
    #[inline]
    fn layer_off(&self, layer: usize) -> usize {
        layer * 2 * self.page_size * self.kv_dim
    }
}

/// Point-in-time pool occupancy and lifetime churn counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub page_size: usize,
    pub page_bytes: usize,
    pub total_pages: usize,
    pub free_pages: usize,
    pub used_pages: usize,
    /// High-water mark of simultaneously used pages.
    pub used_hwm: usize,
    /// Cumulative page allocations (churn).
    pub allocated: u64,
    /// Cumulative page frees (churn).
    pub freed: u64,
}

/// The shared page arena: one allocation, a free list, churn counters.
#[derive(Clone, Debug)]
pub struct BlockPool {
    layout: KvLayout,
    data: Vec<f32>,
    /// LIFO free list of page ids (recently freed pages are reused first,
    /// keeping the hot working set small).
    free: Vec<usize>,
    allocated: u64,
    freed: u64,
    used_hwm: usize,
}

impl BlockPool {
    /// A pool of `pages` pages with the given geometry.
    pub fn new(layout: KvLayout, pages: usize) -> BlockPool {
        assert!(layout.page_size >= 1, "page_size must be >= 1");
        assert!(pages >= 1, "pool needs at least one page");
        BlockPool {
            data: vec![0.0; pages * layout.page_elems()],
            free: (0..pages).rev().collect(),
            layout,
            allocated: 0,
            freed: 0,
            used_hwm: 0,
        }
    }

    /// Pool sized for a model under a serving [`KvConfig`]:
    /// `kv.pool_pages` pages, or (when 0, the "auto" default) enough
    /// pages for `slots` sequences of `max_seq` tokens — the same total
    /// capacity the contiguous per-slot caches would hold, so default
    /// configs change layout, not memory bounds.
    pub fn for_model(cfg: &ModelConfig, kv: &KvConfig, slots: usize) -> BlockPool {
        // Config paths (JSON, serve CLI) validate at parse; this guards
        // direct construction with the same clean message instead of a
        // divide-by-zero in the page math.
        kv.validate().expect("invalid KvConfig");
        let layout = KvLayout {
            n_layers: cfg.n_layers,
            kv_dim: cfg.kv_dim(),
            page_size: kv.page_size,
            max_seq: cfg.max_seq,
        };
        BlockPool::new(layout, kv.pool_pages_for(cfg.max_seq, slots))
    }

    pub fn layout(&self) -> KvLayout {
        self.layout
    }

    pub fn total_pages(&self) -> usize {
        self.data.len() / self.layout.page_elems()
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages() - self.free.len()
    }

    /// Pop a page off the free list (`None` when the pool is exhausted —
    /// callers gate admission on [`Self::free_pages`], see the batcher).
    pub fn try_alloc(&mut self) -> Option<usize> {
        let page = self.free.pop()?;
        self.allocated += 1;
        self.used_hwm = self.used_hwm.max(self.used_pages());
        Some(page)
    }

    /// Return a page to the free list.
    pub fn free(&mut self, page: usize) {
        debug_assert!(page < self.total_pages(), "freeing page {page} out of range");
        debug_assert!(!self.free.contains(&page), "double free of page {page}");
        self.free.push(page);
        self.freed += 1;
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            page_size: self.layout.page_size,
            page_bytes: self.layout.page_bytes(),
            total_pages: self.total_pages(),
            free_pages: self.free_pages(),
            used_pages: self.used_pages(),
            used_hwm: self.used_hwm,
            allocated: self.allocated,
            freed: self.freed,
        }
    }

    /// Keys of the first `tokens` positions of `page` for `layer`
    /// (contiguous rows of `kv_dim`).
    #[inline]
    pub fn k_tile(&self, page: usize, layer: usize, tokens: usize) -> &[f32] {
        let l = self.layout;
        debug_assert!(tokens <= l.page_size);
        let base = page * l.page_elems() + l.layer_off(layer);
        &self.data[base..base + tokens * l.kv_dim]
    }

    /// Values of the first `tokens` positions of `page` for `layer`.
    #[inline]
    pub fn v_tile(&self, page: usize, layer: usize, tokens: usize) -> &[f32] {
        let l = self.layout;
        debug_assert!(tokens <= l.page_size);
        let base = page * l.page_elems() + l.layer_off(layer) + l.page_size * l.kv_dim;
        &self.data[base..base + tokens * l.kv_dim]
    }

    /// Write one position's K/V rows into `page` at in-page index `idx`.
    /// Pages are not zeroed on allocation — every position is written
    /// before the attention kernel can read it (reads are bounded by the
    /// sequence length), so recycled pages may carry stale floats that
    /// are never observed.
    pub fn write(&mut self, page: usize, layer: usize, idx: usize, k: &[f32], v: &[f32]) {
        let l = self.layout;
        debug_assert!(idx < l.page_size);
        debug_assert_eq!(k.len(), l.kv_dim);
        debug_assert_eq!(v.len(), l.kv_dim);
        let base = page * l.page_elems() + l.layer_off(layer);
        let ko = base + idx * l.kv_dim;
        self.data[ko..ko + l.kv_dim].copy_from_slice(k);
        let vo = base + l.page_size * l.kv_dim + idx * l.kv_dim;
        self.data[vo..vo + l.kv_dim].copy_from_slice(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> KvLayout {
        KvLayout { n_layers: 2, kv_dim: 4, page_size: 8, max_seq: 32 }
    }

    #[test]
    fn geometry() {
        let l = layout();
        assert_eq!(l.page_elems(), 2 * 2 * 8 * 4);
        assert_eq!(l.pages_for(0), 0);
        assert_eq!(l.pages_for(8), 1);
        assert_eq!(l.pages_for(9), 2);
        assert_eq!(l.max_pages_per_seq(), 4);
    }

    #[test]
    fn alloc_free_roundtrip_and_churn() {
        let mut p = BlockPool::new(layout(), 3);
        assert_eq!(p.free_pages(), 3);
        let a = p.try_alloc().unwrap();
        let b = p.try_alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.used_pages(), 2);
        p.free(a);
        assert_eq!(p.free_pages(), 2);
        // LIFO: the page just freed is reused next.
        assert_eq!(p.try_alloc().unwrap(), a);
        let s = p.stats();
        assert_eq!(s.allocated, 3);
        assert_eq!(s.freed, 1);
        assert_eq!(s.used_hwm, 2);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut p = BlockPool::new(layout(), 1);
        assert!(p.try_alloc().is_some());
        assert!(p.try_alloc().is_none());
    }

    #[test]
    fn write_then_read_tiles() {
        let mut p = BlockPool::new(layout(), 2);
        let page = p.try_alloc().unwrap();
        let k = [1.0, 2.0, 3.0, 4.0];
        let v = [5.0, 6.0, 7.0, 8.0];
        p.write(page, 1, 3, &k, &v);
        let keys = p.k_tile(page, 1, 4);
        assert_eq!(&keys[3 * 4..4 * 4], &k);
        let vals = p.v_tile(page, 1, 4);
        assert_eq!(&vals[3 * 4..4 * 4], &v);
        // The other layer's tile is unaffected at that index… (stale or
        // zero-init contents, but disjoint storage).
        p.write(page, 0, 3, &v, &k);
        assert_eq!(&p.k_tile(page, 1, 4)[3 * 4..4 * 4], &k);
    }

    #[test]
    fn for_model_auto_sizing_matches_contiguous_capacity() {
        let cfg = ModelConfig::tiny();
        let kv = KvConfig { page_size: 16, pool_pages: 0 };
        let p = BlockPool::for_model(&cfg, &kv, 4);
        // 4 slots × ceil(128/16) pages each.
        assert_eq!(p.total_pages(), 4 * 8);
        let total_bytes = p.total_pages() * p.layout().page_bytes();
        assert_eq!(total_bytes, 4 * 2 * cfg.n_layers * cfg.max_seq * cfg.kv_dim() * 4);
    }
}
