//! Fixed-size-page KV arena shared by every sequence and layer, with
//! refcounted prefix sharing and pluggable page encoding.
//!
//! One [`BlockPool`] backs all serving slots: a single *coded*
//! allocation (a [`super::codec::PageStore`], dtype per
//! [`KvLayout::dtype`]) carved into pages of [`KvLayout::page_size`]
//! tokens. Pool memory therefore bounds *concurrency × live tokens*,
//! not `slots × max_seq` — the per-request worst-case allocation the
//! contiguous [`crate::model::KvCache`] pays — and under f16/int8
//! encodings each of those tokens costs 2×/~3.8× fewer bytes.
//!
//! # Page lifecycle
//!
//! Every page is in exactly one of three states, tracked by its refcount
//! and its membership in the pool's [`PrefixIndex`]:
//!
//! ```text
//!            try_alloc                      free (refs 1→0)
//!   FREE ───────────────▶ USED (refs ≥ 1) ───────────────▶ FREE
//!                          │        ▲                  (unregistered)
//!            publish_prefix│        │pin (refs 0→1,
//!              (register)  │        │ a prefix hit)
//!                          ▼        │
//!                   USED+registered │           free (refs 1→0)
//!                          └────────┴──────────────────▶ CACHED
//!                                                     (registered,
//!                                    evict ◀───────────  refs == 0)
//!                                 (try_alloc under
//!                                  free-list pressure)
//! ```
//!
//! - **Free**: on the LIFO free list, content meaningless.
//! - **Used**: refcount ≥ 1. A refcount of 1 with no registration means
//!   the page is privately owned and writable; a refcount > 1 *or* a
//!   registration means it is shared-immutable and writers must
//!   copy-on-write first ([`BlockPool::is_immutable`], enforced by
//!   [`super::PagedKv`]).
//! - **Cached**: refcount 0 but still registered in the prefix index —
//!   hittable by future prompts, reclaimed FIFO by [`BlockPool::try_alloc`]
//!   only after the free list empties ([`PoolStats::evictions`]).
//!
//! [`BlockPool::free`] is a *reference drop*, not a deallocation: it hard-
//! asserts the refcount is non-zero (the double-free that previously put a
//! page on the free list twice — and thus into two sequences' page tables —
//! now panics at the faulty call site in both debug and release), and only
//! a 1→0 drop changes the page's state.
//!
//! # Page layout
//!
//! One page, `page_elems` floats:
//!
//! ```text
//! [layer 0: K rows (page_size × kv_dim) | V rows (page_size × kv_dim)]
//! [layer 1: K rows                      | V rows                     ]
//! ...
//! ```
//!
//! Keys of consecutive positions within a page are contiguous per layer,
//! so the chunked attention kernel ([`crate::model::attention`]) walks a
//! sequence page-by-page with the same inner loops it would run over a
//! contiguous cache — the page size is the attention tile size. Under
//! coded dtypes a tile read decodes into caller scratch
//! ([`BlockPool::k_tile`]/[`BlockPool::v_tile`] take a decode buffer);
//! f32 stays a zero-copy borrow. Every *page*-granular operation — CoW
//! ([`BlockPool::copy_page`]), spill ([`BlockPool::export_pages`]) and
//! restore ([`BlockPool::import_page`]) — copies the coded bytes
//! verbatim, never decode→re-encode, so shared and resumed pages are
//! bit-identical to their sources in every dtype.

use std::collections::VecDeque;

use super::codec::PageStore;
use super::prefix::{chain_hash, PrefixIndex, ROOT_HASH};
use crate::config::{KvConfig, KvDtype, ModelConfig};

/// Geometry of every page in a pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvLayout {
    pub n_layers: usize,
    /// Floats per cached position per layer (for K; same for V).
    pub kv_dim: usize,
    /// Tokens per page — also the attention kernel's tile height.
    pub page_size: usize,
    /// Maximum sequence length (positions; bounds page tables, not pool
    /// memory).
    pub max_seq: usize,
    /// Page element encoding (f32 passthrough, f16, int8 + scales).
    pub dtype: KvDtype,
}

impl KvLayout {
    /// Logical f32 lanes in one page (all layers, K and V) — the coded
    /// element count, independent of dtype.
    pub fn page_elems(&self) -> usize {
        self.n_layers * 2 * self.page_size * self.kv_dim
    }

    /// Sidecar scales per page: one per kv_dim row under int8, none
    /// otherwise.
    pub fn scales_per_page(&self) -> usize {
        match self.dtype {
            KvDtype::Int8 => self.n_layers * 2 * self.page_size,
            _ => 0,
        }
    }

    /// *Coded* bytes in one page: element storage at the dtype's width
    /// plus the f32 scale sidecar. This is the pool's true allocation
    /// quantum — admission accounting and the serving byte gauges all
    /// derive from it.
    pub fn page_bytes(&self) -> usize {
        self.page_elems() * self.dtype.elem_bytes() + self.scales_per_page() * 4
    }

    /// Pages needed to hold `tokens` positions.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_size)
    }

    /// Coded bytes filled by `positions` cached positions (K and V, all
    /// layers, including their sidecar scales) — the single source of
    /// the fill-bytes formula shared by the paged handle and the
    /// serving metrics.
    pub fn bytes_for(&self, positions: usize) -> usize {
        let rows = 2 * self.n_layers * positions;
        let scale_bytes = match self.dtype {
            KvDtype::Int8 => rows * 4,
            _ => 0,
        };
        rows * self.kv_dim * self.dtype.elem_bytes() + scale_bytes
    }

    /// Upper bound of pages one sequence can ever hold.
    pub fn max_pages_per_seq(&self) -> usize {
        self.pages_for(self.max_seq)
    }

    /// Offset of layer `layer`'s K block inside a page.
    #[inline]
    fn layer_off(&self, layer: usize) -> usize {
        layer * 2 * self.page_size * self.kv_dim
    }
}

/// Point-in-time pool occupancy and lifetime churn counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub page_size: usize,
    /// Coded bytes per page (element width + scale sidecar).
    pub page_bytes: usize,
    /// Page element encoding.
    pub dtype: KvDtype,
    pub total_pages: usize,
    /// Allocatable pages: truly free plus cached-evictable.
    pub free_pages: usize,
    /// Pages with refcount ≥ 1.
    pub used_pages: usize,
    /// High-water mark of simultaneously used pages.
    pub used_hwm: usize,
    /// Cumulative 0→1 refcount transitions (fresh allocations and
    /// cache-hit re-pins alike — churn).
    pub allocated: u64,
    /// Cumulative 1→0 refcount transitions (churn).
    pub freed: u64,
    /// Pages currently cached (registered, refcount 0).
    pub cached_pages: usize,
    /// Sum of all page refcounts (shared pages count once per holder).
    pub live_refs: usize,
    /// Pages currently registered in the prefix index (used or cached).
    pub prefix_pages: usize,
    /// Prompts whose admission pinned at least one prefix page.
    pub prefix_hits: u64,
    /// Prompts that consulted the index and pinned nothing.
    pub prefix_misses: u64,
    /// Prompt tokens covered by pages pinned at admission (page
    /// granularity; prefill skips all but at most the final one).
    pub prefix_hit_tokens: u64,
    /// Cached pages recycled by the allocator (registration dropped).
    pub evictions: u64,
    /// Copy-on-write page copies (divergence from a shared prefix).
    pub cow_copies: u64,
}

/// The shared page arena: one allocation, per-page refcounts, a free
/// list, a cached-page queue, and the prefix index that names immutable
/// prompt pages.
#[derive(Clone, Debug)]
pub struct BlockPool {
    layout: KvLayout,
    /// Coded page arena (element storage + int8 scale sidecar).
    data: PageStore,
    /// LIFO free list of page ids (recently freed pages are reused first,
    /// keeping the hot working set small).
    free: Vec<usize>,
    /// Holders per page; 0 means free or cached.
    refs: Vec<u32>,
    /// FIFO eviction queue of cached pages. Lazily maintained: entries
    /// whose `in_evictable` bit was cleared by a re-pin are skipped at
    /// pop time instead of being searched out on every hit.
    evictable: VecDeque<usize>,
    in_evictable: Vec<bool>,
    index: PrefixIndex,
    used_ct: usize,
    cached_ct: usize,
    live_refs: usize,
    allocated: u64,
    freed: u64,
    used_hwm: usize,
    prefix_hits: u64,
    prefix_misses: u64,
    prefix_hit_tokens: u64,
    evictions: u64,
    cow_copies: u64,
}

impl BlockPool {
    /// A pool of `pages` pages with the given geometry.
    pub fn new(layout: KvLayout, pages: usize) -> BlockPool {
        assert!(layout.page_size >= 1, "page_size must be >= 1");
        assert!(pages >= 1, "pool needs at least one page");
        BlockPool {
            data: PageStore::new(layout.dtype, pages * layout.page_elems(), layout.kv_dim),
            free: (0..pages).rev().collect(),
            refs: vec![0; pages],
            evictable: VecDeque::new(),
            in_evictable: vec![false; pages],
            index: PrefixIndex::new(),
            layout,
            used_ct: 0,
            cached_ct: 0,
            live_refs: 0,
            allocated: 0,
            freed: 0,
            used_hwm: 0,
            prefix_hits: 0,
            prefix_misses: 0,
            prefix_hit_tokens: 0,
            evictions: 0,
            cow_copies: 0,
        }
    }

    /// Pool sized for a model under a serving [`KvConfig`]:
    /// `kv.pool_pages` pages, or (when 0, the "auto" default) enough
    /// pages for `slots` sequences of `max_seq` tokens — the same total
    /// capacity the contiguous per-slot caches would hold, so default
    /// configs change layout, not memory bounds.
    pub fn for_model(cfg: &ModelConfig, kv: &KvConfig, slots: usize) -> BlockPool {
        // Config paths (JSON, serve CLI) validate at parse; this guards
        // direct construction with the same clean message instead of a
        // divide-by-zero in the page math.
        kv.validate().expect("invalid KvConfig");
        let layout = KvLayout {
            n_layers: cfg.n_layers,
            kv_dim: cfg.kv_dim(),
            page_size: kv.page_size,
            max_seq: cfg.max_seq,
            dtype: Self::resolve_dtype(kv.kv_dtype),
        };
        BlockPool::new(layout, kv.pool_pages_for(cfg.max_seq, slots))
    }

    /// Resolve the pool dtype: the `CODEGEMM_KV_DTYPE` env var wins over
    /// the config (mirroring `CODEGEMM_KERNEL` — it lets CI matrix legs
    /// force an encoding without threading flags through every harness).
    /// Unparseable values are ignored, not fatal: an env typo should not
    /// take down a server.
    pub fn resolve_dtype(cfg_dtype: KvDtype) -> KvDtype {
        match std::env::var("CODEGEMM_KV_DTYPE") {
            Ok(s) => KvDtype::parse(s.trim()).unwrap_or(cfg_dtype),
            Err(_) => cfg_dtype,
        }
    }

    pub fn layout(&self) -> KvLayout {
        self.layout
    }

    pub fn total_pages(&self) -> usize {
        self.refs.len()
    }

    /// Allocatable pages: the free list plus cached pages the allocator
    /// may evict. (A pool fully drained of sequences reports
    /// `free_pages == total_pages` even when prefix pages remain cached.)
    pub fn free_pages(&self) -> usize {
        self.free.len() + self.cached_ct
    }

    /// Pages with refcount ≥ 1.
    pub fn used_pages(&self) -> usize {
        self.used_ct
    }

    /// Pages registered but unreferenced — hittable, evictable.
    pub fn cached_pages(&self) -> usize {
        self.cached_ct
    }

    /// Sum of all page refcounts.
    pub fn live_refs(&self) -> usize {
        self.live_refs
    }

    /// Current holders of `page` (0 = free or cached).
    pub fn refs(&self, page: usize) -> u32 {
        self.refs[page]
    }

    /// Whether `page` is registered in the prefix index.
    pub fn is_registered(&self, page: usize) -> bool {
        self.index.contains_page(page)
    }

    /// Whether writing `page` in place would be observable by another
    /// holder or by future prefix hits — if so, writers must copy first.
    pub fn is_immutable(&self, page: usize) -> bool {
        self.refs[page] > 1 || self.index.contains_page(page)
    }

    /// Claim a page with refcount 1: free list first, then FIFO eviction
    /// of cached pages (whose registration is dropped —
    /// [`PoolStats::evictions`]). `None` when the pool is exhausted —
    /// callers gate admission on [`Self::free_pages`], see the batcher.
    pub fn try_alloc(&mut self) -> Option<usize> {
        let page = match self.free.pop() {
            Some(page) => page,
            None => self.evict()?,
        };
        debug_assert_eq!(self.refs[page], 0, "allocating page {page} that still has holders");
        self.retain(page);
        Some(page)
    }

    /// Pop the oldest cached page, dropping its index entry.
    fn evict(&mut self) -> Option<usize> {
        while let Some(page) = self.evictable.pop_front() {
            if !self.in_evictable[page] {
                continue; // stale: re-pinned since it was queued
            }
            self.in_evictable[page] = false;
            let removed = self.index.remove_page(page);
            debug_assert!(removed, "evictable page {page} was not registered");
            self.cached_ct -= 1;
            self.evictions += 1;
            return Some(page);
        }
        None
    }

    /// 0→1 refcount bookkeeping shared by allocation and cache-hit pins.
    fn retain(&mut self, page: usize) {
        self.refs[page] = 1;
        self.used_ct += 1;
        self.live_refs += 1;
        self.allocated += 1;
        self.used_hwm = self.used_hwm.max(self.used_ct);
    }

    /// Add a holder to `page`. Pinning a cached page (refcount 0) revives
    /// it out of the eviction queue; pinning a used page shares it.
    pub fn pin(&mut self, page: usize) {
        assert!(page < self.total_pages(), "pinning page {page} out of range");
        if self.refs[page] == 0 {
            assert!(
                self.in_evictable[page],
                "pinning free page {page}: only used or cached pages can gain holders"
            );
            self.in_evictable[page] = false;
            self.cached_ct -= 1;
            self.retain(page);
        } else {
            self.refs[page] += 1;
            self.live_refs += 1;
        }
    }

    /// Drop one holder of `page`. The terminal 1→0 drop sends the page
    /// back to the free list — or parks it in the cached state when it is
    /// registered as a prefix page.
    ///
    /// Hard-asserts (debug *and* release) that the page has a holder: a
    /// double free would otherwise put the page on the free list twice
    /// and hand it to two sequences — silent KV corruption.
    pub fn free(&mut self, page: usize) {
        assert!(page < self.total_pages(), "freeing page {page} out of range");
        assert!(self.refs[page] > 0, "double free of page {page}: refcount is already zero");
        self.refs[page] -= 1;
        self.live_refs -= 1;
        if self.refs[page] > 0 {
            return;
        }
        self.used_ct -= 1;
        self.freed += 1;
        if self.index.contains_page(page) {
            debug_assert!(!self.in_evictable[page], "cached page {page} queued twice");
            self.in_evictable[page] = true;
            self.evictable.push_back(page);
            self.cached_ct += 1;
        } else {
            self.free.push(page);
        }
    }

    /// Full pages of `tokens` currently resident in the prefix index —
    /// what [`Self::prefix_acquire`] could pin, without side effects.
    pub fn prefix_peek(&self, tokens: &[usize]) -> usize {
        self.prefix_peek_detail(tokens).0
    }

    /// [`Self::prefix_peek`] plus how many of the matched pages are
    /// currently *cached* (refcount 0) — pinning those removes them from
    /// the allocatable set, which admission must price in
    /// ([`Self::free_pages`] counts cached pages as allocatable).
    pub fn prefix_peek_detail(&self, tokens: &[usize]) -> (usize, usize) {
        let ps = self.layout.page_size;
        let mut parent = ROOT_HASH;
        let (mut matched, mut cached) = (0, 0);
        for chunk in tokens.chunks_exact(ps) {
            let hash = chain_hash(parent, chunk);
            match self.index.lookup_hashed(hash, parent, chunk) {
                Some(page) => {
                    if self.refs[page] == 0 {
                        cached += 1;
                    }
                    parent = hash;
                    matched += 1;
                }
                None => break,
            }
        }
        (matched, cached)
    }

    /// Pin the longest chain of cached/used pages matching the full pages
    /// of `tokens` — at most `max_pages` of them — in prompt order.
    /// Counts a prefix hit (and the tokens the pinned pages cover) when
    /// at least one page is pinned, a miss otherwise; admission passes
    /// `max_pages = 0` on a planned non-match so misses are still
    /// counted.
    pub fn prefix_acquire(&mut self, tokens: &[usize], max_pages: usize) -> Vec<usize> {
        let ps = self.layout.page_size;
        let mut parent = ROOT_HASH;
        let mut pages = Vec::new();
        for chunk in tokens.chunks_exact(ps) {
            if pages.len() >= max_pages {
                break;
            }
            let hash = chain_hash(parent, chunk);
            match self.index.lookup_hashed(hash, parent, chunk) {
                Some(page) => {
                    self.pin(page);
                    pages.push(page);
                    parent = hash;
                }
                None => break,
            }
        }
        if pages.is_empty() {
            self.prefix_misses += 1;
        } else {
            self.prefix_hits += 1;
            self.prefix_hit_tokens += (pages.len() * ps) as u64;
        }
        pages
    }

    /// Register the full pages of `tokens` (held by `pages`, the owning
    /// sequence's page table) in the prefix index, making them
    /// shared-immutable. First publisher wins: pages whose chain key is
    /// already registered, or that already serve another key, are
    /// skipped. The caller keeps its references; registration only
    /// changes what happens when they drop (cached, not freed).
    pub fn publish_prefix(&mut self, tokens: &[usize], pages: &[usize]) {
        let ps = self.layout.page_size;
        let mut parent = ROOT_HASH;
        for (i, chunk) in tokens.chunks_exact(ps).enumerate() {
            let hash = chain_hash(parent, chunk);
            let page = pages[i];
            debug_assert!(self.refs[page] > 0, "publishing unheld page {page}");
            if self.index.lookup_hashed(hash, parent, chunk).is_none()
                && !self.index.contains_page(page)
            {
                self.index.insert_hashed(hash, parent, chunk, page);
            }
            parent = hash;
        }
    }

    /// Copy the full contents of page `src` into page `dst` (the
    /// copy-on-write body; `dst` is a freshly claimed private page).
    /// Copies *coded* bytes — the copy is bit-identical to the source
    /// in every dtype, never a decode→re-encode.
    pub fn copy_page(&mut self, src: usize, dst: usize) {
        let pe = self.layout.page_elems();
        debug_assert!(src != dst);
        self.data.copy_within(src * pe, dst * pe, pe);
        self.cow_copies += 1;
    }

    /// Snapshot the coded contents of `pages` into a standalone
    /// [`PageStore`] (spill path: copy out before releasing). The
    /// snapshot preserves the quantized representation verbatim, so a
    /// later [`Self::import_page`] restores bit-identical pages.
    pub fn export_pages(&self, pages: &[usize]) -> PageStore {
        let pe = self.layout.page_elems();
        let mut out = PageStore::new(self.layout.dtype, pages.len() * pe, self.layout.kv_dim);
        for (i, &page) in pages.iter().enumerate() {
            out.copy_from(&self.data, page * pe, i * pe, pe);
        }
        out
    }

    /// Overwrite the full coded contents of `page` with snapshot page
    /// `src_index` of `src` (spill restore into a freshly claimed
    /// private page).
    pub fn import_page(&mut self, page: usize, src: &PageStore, src_index: usize) {
        let pe = self.layout.page_elems();
        debug_assert!(
            self.refs[page] == 1 && !self.index.contains_page(page),
            "bulk write to shared page {page}"
        );
        self.data.copy_from(src, src_index * pe, page * pe, pe);
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            page_size: self.layout.page_size,
            page_bytes: self.layout.page_bytes(),
            dtype: self.layout.dtype,
            total_pages: self.total_pages(),
            free_pages: self.free_pages(),
            used_pages: self.used_pages(),
            used_hwm: self.used_hwm,
            allocated: self.allocated,
            freed: self.freed,
            cached_pages: self.cached_ct,
            live_refs: self.live_refs,
            prefix_pages: self.index.len(),
            prefix_hits: self.prefix_hits,
            prefix_misses: self.prefix_misses,
            prefix_hit_tokens: self.prefix_hit_tokens,
            evictions: self.evictions,
            cow_copies: self.cow_copies,
        }
    }

    /// Keys of the first `tokens` positions of `page` for `layer`
    /// (contiguous rows of `kv_dim`), decoded into `buf` for coded
    /// dtypes; f32 borrows pool memory directly and leaves `buf` alone.
    #[inline]
    pub fn k_tile<'a>(
        &'a self,
        page: usize,
        layer: usize,
        tokens: usize,
        buf: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        let l = self.layout;
        debug_assert!(tokens <= l.page_size);
        let base = page * l.page_elems() + l.layer_off(layer);
        self.data.read(base, tokens * l.kv_dim, buf)
    }

    /// Values of the first `tokens` positions of `page` for `layer`
    /// (decoded like [`Self::k_tile`]).
    #[inline]
    pub fn v_tile<'a>(
        &'a self,
        page: usize,
        layer: usize,
        tokens: usize,
        buf: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        let l = self.layout;
        debug_assert!(tokens <= l.page_size);
        let base = page * l.page_elems() + l.layer_off(layer) + l.page_size * l.kv_dim;
        self.data.read(base, tokens * l.kv_dim, buf)
    }

    /// Write (encode) one position's K/V rows into `page` at in-page
    /// index `idx`. Pages are not zeroed on allocation — every position
    /// is written before the attention kernel can read it (reads are
    /// bounded by the sequence length), so recycled pages may carry
    /// stale coded bytes that are never observed. The page must be
    /// privately held ([`Self::is_immutable`] false) —
    /// [`super::PagedKv`] copies first. Encoding is per-row (int8
    /// scales cover exactly one kv_dim vector), so each write is
    /// independent and deterministic regardless of batch shape.
    pub fn write(&mut self, page: usize, layer: usize, idx: usize, k: &[f32], v: &[f32]) {
        let l = self.layout;
        debug_assert!(idx < l.page_size);
        debug_assert_eq!(k.len(), l.kv_dim);
        debug_assert_eq!(v.len(), l.kv_dim);
        debug_assert!(
            self.refs[page] == 1 && !self.index.contains_page(page),
            "in-place write to shared page {page} (copy-on-write missed)"
        );
        let base = page * l.page_elems() + l.layer_off(layer);
        self.data.write_row(base + idx * l.kv_dim, k);
        self.data.write_row(base + l.page_size * l.kv_dim + idx * l.kv_dim, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> KvLayout {
        KvLayout { n_layers: 2, kv_dim: 4, page_size: 8, max_seq: 32, dtype: KvDtype::F32 }
    }

    #[test]
    fn geometry() {
        let l = layout();
        assert_eq!(l.page_elems(), 2 * 2 * 8 * 4);
        assert_eq!(l.pages_for(0), 0);
        assert_eq!(l.pages_for(8), 1);
        assert_eq!(l.pages_for(9), 2);
        assert_eq!(l.max_pages_per_seq(), 4);
    }

    #[test]
    fn coded_footprint_math_per_dtype() {
        let f32_l = layout();
        let f16_l = KvLayout { dtype: KvDtype::F16, ..f32_l };
        let i8_l = KvLayout { dtype: KvDtype::Int8, ..f32_l };
        assert_eq!(f32_l.page_bytes(), f32_l.page_elems() * 4);
        assert_eq!(f16_l.page_bytes(), f32_l.page_elems() * 2);
        // int8: 1 byte/elem + one f32 scale per kv_dim row.
        let rows = 2 * f32_l.n_layers * f32_l.page_size;
        assert_eq!(i8_l.scales_per_page(), rows);
        assert_eq!(i8_l.page_bytes(), f32_l.page_elems() + rows * 4);
        // Fill bytes follow the same coded accounting.
        assert_eq!(f32_l.bytes_for(3), 2 * 2 * 3 * 4 * 4);
        assert_eq!(f16_l.bytes_for(3), 2 * 2 * 3 * 4 * 2);
        assert_eq!(i8_l.bytes_for(3), 2 * 2 * 3 * 4 + 2 * 2 * 3 * 4);
        // The headline ratio (1/4 + 1/kv_dim of f32): ≥ 3× smaller at
        // model-scale row widths (kv_dim ≥ 16).
        let wide = KvLayout { kv_dim: 64, ..f32_l };
        let wide_i8 = KvLayout { dtype: KvDtype::Int8, ..wide };
        assert!(wide_i8.page_bytes() * 3 <= wide.page_bytes());
    }

    #[test]
    fn alloc_free_roundtrip_and_churn() {
        let mut p = BlockPool::new(layout(), 3);
        assert_eq!(p.free_pages(), 3);
        let a = p.try_alloc().unwrap();
        let b = p.try_alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.used_pages(), 2);
        p.free(a);
        assert_eq!(p.free_pages(), 2);
        // LIFO: the page just freed is reused next.
        assert_eq!(p.try_alloc().unwrap(), a);
        let s = p.stats();
        assert_eq!(s.allocated, 3);
        assert_eq!(s.freed, 1);
        assert_eq!(s.used_hwm, 2);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut p = BlockPool::new(layout(), 1);
        assert!(p.try_alloc().is_some());
        assert!(p.try_alloc().is_none());
    }

    #[test]
    #[should_panic(expected = "double free of page")]
    fn double_free_panics_in_release_too() {
        let mut p = BlockPool::new(layout(), 2);
        let a = p.try_alloc().unwrap();
        p.free(a);
        p.free(a);
    }

    #[test]
    #[should_panic(expected = "double free of page")]
    fn free_of_never_allocated_page_panics() {
        let mut p = BlockPool::new(layout(), 2);
        p.free(0);
    }

    #[test]
    fn pin_shares_and_free_drops_one_holder() {
        let mut p = BlockPool::new(layout(), 2);
        let a = p.try_alloc().unwrap();
        p.pin(a);
        assert_eq!(p.refs(a), 2);
        assert!(p.is_immutable(a), "two holders: in-place writes forbidden");
        assert_eq!(p.used_pages(), 1, "shared page counts once");
        assert_eq!(p.live_refs(), 2);
        p.free(a);
        assert_eq!(p.refs(a), 1);
        assert!(!p.is_immutable(a));
        assert_eq!(p.used_pages(), 1);
        p.free(a);
        assert_eq!(p.used_pages(), 0);
        assert_eq!(p.free_pages(), 2);
    }

    #[test]
    fn registered_page_parks_cached_then_revives_on_hit() {
        let mut p = BlockPool::new(layout(), 2);
        let toks: Vec<usize> = (0..8).collect();
        let a = p.try_alloc().unwrap();
        p.publish_prefix(&toks, &[a]);
        assert!(p.is_registered(a));
        assert!(p.is_immutable(a), "registered pages are immutable even at refs 1");
        p.free(a);
        // Cached: unreferenced but hittable, and still allocatable.
        assert_eq!(p.used_pages(), 0);
        assert_eq!(p.cached_pages(), 1);
        assert_eq!(p.free_pages(), 2);
        assert_eq!(p.prefix_peek(&toks), 1);
        let pages = p.prefix_acquire(&toks, usize::MAX);
        assert_eq!(pages, vec![a]);
        assert_eq!(p.refs(a), 1);
        assert_eq!(p.cached_pages(), 0);
        let s = p.stats();
        assert_eq!(s.prefix_hits, 1);
        assert_eq!(s.prefix_hit_tokens, 8);
        p.free(a);
    }

    #[test]
    fn allocation_pressure_evicts_cached_fifo_and_unregisters() {
        let mut p = BlockPool::new(layout(), 2);
        let t0: Vec<usize> = (0..8).collect();
        let t1: Vec<usize> = (100..108).collect();
        let a = p.try_alloc().unwrap();
        let b = p.try_alloc().unwrap();
        p.publish_prefix(&t0, &[a]);
        p.publish_prefix(&t1, &[b]);
        p.free(a); // cached first → evicted first
        p.free(b);
        assert_eq!(p.free_pages(), 2);
        let first = p.try_alloc().unwrap();
        assert_eq!(first, a, "FIFO: oldest cached page evicted first");
        assert!(!p.is_registered(a));
        assert_eq!(p.prefix_peek(&t0), 0, "evicted page left the index");
        assert_eq!(p.prefix_peek(&t1), 1, "survivor still hittable");
        let s = p.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.prefix_pages, 1);
        p.free(first);
    }

    #[test]
    fn eviction_never_reclaims_referenced_pages() {
        let mut p = BlockPool::new(layout(), 2);
        let toks: Vec<usize> = (0..8).collect();
        let a = p.try_alloc().unwrap();
        let _b = p.try_alloc().unwrap();
        p.publish_prefix(&toks, &[a]);
        // `a` is registered but still referenced: not evictable, pool is
        // genuinely exhausted.
        assert_eq!(p.free_pages(), 0);
        assert!(p.try_alloc().is_none());
        assert!(p.is_registered(a), "failed alloc must not disturb a live registration");
    }

    #[test]
    fn prefix_chain_matches_in_order_only() {
        let mut p = BlockPool::new(layout(), 4);
        let prompt: Vec<usize> = (0..16).collect();
        let a = p.try_alloc().unwrap();
        let b = p.try_alloc().unwrap();
        p.publish_prefix(&prompt, &[a, b]);
        assert_eq!(p.prefix_peek(&prompt), 2);
        // Same second page under a different first page: no match at all
        // (the chain hash roots each page in its ancestry).
        let mut swapped = prompt.clone();
        swapped[0] = 999;
        assert_eq!(p.prefix_peek(&swapped), 0);
        // A longer prompt still matches its first two full pages.
        let mut longer = prompt.clone();
        longer.extend(200..210);
        assert_eq!(p.prefix_peek(&longer), 2);
        let pages = p.prefix_acquire(&longer, usize::MAX);
        assert_eq!(pages, vec![a, b]);
        for page in pages {
            p.free(page);
        }
        p.free(a);
        p.free(b);
        assert_eq!(p.free_pages(), 4);
        assert_eq!(p.stats().prefix_pages, 2, "drained pool keeps its cache");
    }

    #[test]
    fn copy_page_copies_all_layers() {
        let mut p = BlockPool::new(layout(), 2);
        let a = p.try_alloc().unwrap();
        let b = p.try_alloc().unwrap();
        let k = [1.0, 2.0, 3.0, 4.0];
        let v = [5.0, 6.0, 7.0, 8.0];
        p.write(a, 1, 3, &k, &v);
        p.copy_page(a, b);
        let mut buf = Vec::new();
        let ka = p.k_tile(a, 1, 4, &mut buf).to_vec();
        let mut buf = Vec::new();
        let va = p.v_tile(a, 1, 4, &mut buf).to_vec();
        let mut buf = Vec::new();
        assert_eq!(p.k_tile(b, 1, 4, &mut buf), &ka[..]);
        let mut buf = Vec::new();
        assert_eq!(p.v_tile(b, 1, 4, &mut buf), &va[..]);
        assert_eq!(p.stats().cow_copies, 1);
    }

    #[test]
    fn write_then_read_tiles() {
        let mut p = BlockPool::new(layout(), 2);
        let page = p.try_alloc().unwrap();
        let k = [1.0, 2.0, 3.0, 4.0];
        let v = [5.0, 6.0, 7.0, 8.0];
        p.write(page, 1, 3, &k, &v);
        let mut buf = Vec::new();
        assert_eq!(&p.k_tile(page, 1, 4, &mut buf)[3 * 4..4 * 4], &k);
        let mut buf = Vec::new();
        assert_eq!(&p.v_tile(page, 1, 4, &mut buf)[3 * 4..4 * 4], &v);
        // The other layer's tile is unaffected at that index… (stale or
        // zero-init contents, but disjoint storage).
        p.write(page, 0, 3, &v, &k);
        let mut buf = Vec::new();
        assert_eq!(&p.k_tile(page, 1, 4, &mut buf)[3 * 4..4 * 4], &k);
    }

    #[test]
    fn coded_pools_roundtrip_tiles_per_dtype() {
        // f16 decodes exactly what RNE stored; int8 decodes within half
        // a scale step of the written row. Both must survive CoW and
        // export/import with bit-identical decoded reads.
        for dtype in [KvDtype::F16, KvDtype::Int8] {
            let l = KvLayout { dtype, ..layout() };
            let mut p = BlockPool::new(l, 2);
            let a = p.try_alloc().unwrap();
            let b = p.try_alloc().unwrap();
            let k: Vec<f32> = vec![0.5, -1.25, 3.0, 0.01];
            let v: Vec<f32> = vec![-0.75, 2.5, 0.0, 10.0];
            for idx in 0..l.page_size {
                p.write(a, 0, idx, &k, &v);
                p.write(a, 1, idx, &v, &k);
            }
            let mut buf = Vec::new();
            let ka = p.k_tile(a, 0, l.page_size, &mut buf).to_vec();
            let amax = k.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let step = amax / 127.0;
            let tol = if dtype == KvDtype::Int8 { 0.51 * step } else { amax / 1024.0 };
            for row in ka.chunks_exact(l.kv_dim) {
                for (d, x) in row.iter().zip(&k) {
                    assert!((d - x).abs() <= tol, "{dtype:?}: decoded {d} vs {x}");
                }
            }
            // CoW copy and spill round-trip both preserve coded bytes,
            // so decoded reads are identical (== not epsilon).
            p.copy_page(a, b);
            let mut buf = Vec::new();
            assert_eq!(p.k_tile(b, 0, l.page_size, &mut buf), &ka[..]);
            let snap = p.export_pages(&[a]);
            assert_eq!(snap.bytes(), l.page_bytes());
            p.import_page(b, &snap, 0);
            let mut buf = Vec::new();
            assert_eq!(p.k_tile(b, 0, l.page_size, &mut buf), &ka[..]);
            let mut buf = Vec::new();
            let va = p.v_tile(a, 1, l.page_size, &mut buf).to_vec();
            let mut buf = Vec::new();
            assert_eq!(p.v_tile(b, 1, l.page_size, &mut buf), &va[..]);
        }
    }

    #[test]
    fn for_model_auto_sizing_matches_contiguous_capacity() {
        let cfg = ModelConfig::tiny();
        let kv = KvConfig { page_size: 16, pool_pages: 0, ..KvConfig::default() };
        let p = BlockPool::for_model(&cfg, &kv, 4);
        // 4 slots × ceil(128/16) pages each.
        assert_eq!(p.total_pages(), 4 * 8);
        let total_bytes = p.total_pages() * p.layout().page_bytes();
        assert_eq!(total_bytes, 4 * 2 * cfg.n_layers * cfg.max_seq * cfg.kv_dim() * 4);
    }

    #[test]
    fn for_model_coded_pool_shrinks_bytes() {
        let cfg = ModelConfig::tiny();
        let f32_kv = KvConfig { page_size: 16, ..KvConfig::default() };
        let i8_kv = KvConfig { page_size: 16, kv_dtype: KvDtype::Int8, ..KvConfig::default() };
        let pf = BlockPool::for_model(&cfg, &f32_kv, 4);
        let pi = BlockPool::for_model(&cfg, &i8_kv, 4);
        assert_eq!(pf.total_pages(), pi.total_pages(), "capacity (tokens) is unchanged");
        let (bf, bi) = (pf.layout().page_bytes(), pi.layout().page_bytes());
        assert!(bi * 3 <= bf, "int8 pages {bi}B vs f32 {bf}B: expected ≥3× shrink");
    }
}
