//! Content-addressed index of immutable prompt-prefix pages.
//!
//! A full page of prompt tokens is identified by a **chain hash**: the
//! hash of its own token ids combined with the hash of the page before
//! it ([`chain_hash`], rooted at [`ROOT_HASH`]). Two sequences whose
//! prompts agree on their first `k × page_size` tokens therefore derive
//! the same chain of keys, and admission can convert those pages from
//! "pages to allocate" into "pages to pin" (see
//! [`super::BlockPool::prefix_acquire`]).
//!
//! The index never trusts a hash alone: every entry stores the page's
//! token ids plus its parent key, and [`PrefixIndex::lookup`] compares
//! both before returning a page — a hash collision degrades to a cache
//! miss, never to serving another prompt's KV (pinned by
//! `tests/prefix_kv_prop.rs`).
//!
//! The index holds **weak** references only: registering a page does not
//! bump its refcount. Liveness is the pool's job — a registered page
//! whose refcount drops to zero becomes *cached* (evictable, but still
//! hittable); the pool unregisters it here when eviction recycles it.

use std::collections::HashMap;

/// FNV-1a offset basis — the chain hash of the empty prefix.
pub const ROOT_HASH: u64 = 0xcbf2_9ce4_8422_2325;

const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Chain hash of one full page of token ids under its parent's hash.
/// Deterministic across runs/processes (unlike `DefaultHasher`), so
/// hashes are stable cache keys.
pub fn chain_hash(parent: u64, tokens: &[usize]) -> u64 {
    let mut h = ROOT_HASH;
    for byte in parent.to_le_bytes() {
        h = (h ^ byte as u64).wrapping_mul(FNV_PRIME);
    }
    for &t in tokens {
        for byte in (t as u64).to_le_bytes() {
            h = (h ^ byte as u64).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

#[derive(Clone, Debug)]
struct Entry {
    parent: u64,
    tokens: Vec<usize>,
    page: usize,
}

/// Hash-keyed map from `(parent chain hash, page token ids)` to the pool
/// page holding that content. Buckets hold every entry sharing a hash;
/// lookups verify the full identity.
#[derive(Clone, Debug, Default)]
pub struct PrefixIndex {
    buckets: HashMap<u64, Vec<Entry>>,
    /// Reverse map for O(1)-ish invalidation when a page is evicted.
    page_hash: HashMap<usize, u64>,
    entries: usize,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex::default()
    }

    /// Registered entries (= registered pages; a page holds one entry).
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    /// The page holding `tokens` under `parent`, verified against the
    /// stored token ids — a colliding hash with different content is a
    /// miss, not a wrong page.
    pub fn lookup(&self, parent: u64, tokens: &[usize]) -> Option<usize> {
        self.lookup_hashed(chain_hash(parent, tokens), parent, tokens)
    }

    /// [`Self::lookup`] with the hash supplied by the caller — the
    /// collision-injection hook for tests; production callers use
    /// [`Self::lookup`].
    pub fn lookup_hashed(&self, hash: u64, parent: u64, tokens: &[usize]) -> Option<usize> {
        self.buckets.get(&hash)?.iter().find(|e| e.parent == parent && e.tokens == tokens).map(|e| e.page)
    }

    /// Register `page` as the holder of `tokens` under `parent`. Returns
    /// false (and changes nothing) when the identity is already
    /// registered — first publisher wins, so a page is never re-pointed.
    pub fn insert(&mut self, parent: u64, tokens: &[usize], page: usize) -> bool {
        self.insert_hashed(chain_hash(parent, tokens), parent, tokens, page)
    }

    /// [`Self::insert`] with the hash supplied by the caller (test hook
    /// for forcing bucket collisions).
    pub fn insert_hashed(&mut self, hash: u64, parent: u64, tokens: &[usize], page: usize) -> bool {
        let bucket = self.buckets.entry(hash).or_default();
        if bucket.iter().any(|e| e.parent == parent && e.tokens == tokens) {
            return false;
        }
        debug_assert!(
            !self.page_hash.contains_key(&page),
            "page {page} already registered under another key"
        );
        bucket.push(Entry { parent, tokens: tokens.to_vec(), page });
        self.page_hash.insert(page, hash);
        self.entries += 1;
        true
    }

    /// Drop the entry registered for `page` (eviction). Returns false if
    /// the page was not registered.
    pub fn remove_page(&mut self, page: usize) -> bool {
        let Some(hash) = self.page_hash.remove(&page) else {
            return false;
        };
        if let Some(bucket) = self.buckets.get_mut(&hash) {
            bucket.retain(|e| e.page != page);
            if bucket.is_empty() {
                self.buckets.remove(&hash);
            }
        }
        self.entries -= 1;
        true
    }

    /// Whether `page` holds a registered entry.
    pub fn contains_page(&self, page: usize) -> bool {
        self.page_hash.contains_key(&page)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_hash_is_deterministic_and_order_sensitive() {
        let a = chain_hash(ROOT_HASH, &[1, 2, 3]);
        assert_eq!(a, chain_hash(ROOT_HASH, &[1, 2, 3]));
        assert_ne!(a, chain_hash(ROOT_HASH, &[3, 2, 1]));
        // The parent hash separates equal pages at different depths.
        assert_ne!(chain_hash(a, &[7, 8]), chain_hash(ROOT_HASH, &[7, 8]));
    }

    #[test]
    fn insert_lookup_remove_roundtrip() {
        let mut ix = PrefixIndex::new();
        assert!(ix.insert(ROOT_HASH, &[1, 2], 5));
        assert_eq!(ix.lookup(ROOT_HASH, &[1, 2]), Some(5));
        assert_eq!(ix.lookup(ROOT_HASH, &[1, 3]), None);
        // First publisher wins.
        assert!(!ix.insert(ROOT_HASH, &[1, 2], 9));
        assert_eq!(ix.lookup(ROOT_HASH, &[1, 2]), Some(5));
        assert!(ix.contains_page(5));
        assert!(ix.remove_page(5));
        assert!(!ix.remove_page(5));
        assert_eq!(ix.lookup(ROOT_HASH, &[1, 2]), None);
        assert!(ix.is_empty());
    }

    #[test]
    fn colliding_hashes_never_alias_content() {
        let mut ix = PrefixIndex::new();
        // Force two different identities into the same bucket.
        assert!(ix.insert_hashed(42, ROOT_HASH, &[1, 2], 0));
        assert!(ix.insert_hashed(42, ROOT_HASH, &[9, 9], 1));
        assert_eq!(ix.lookup_hashed(42, ROOT_HASH, &[1, 2]), Some(0));
        assert_eq!(ix.lookup_hashed(42, ROOT_HASH, &[9, 9]), Some(1));
        // Same hash, unknown content: a miss, never a page.
        assert_eq!(ix.lookup_hashed(42, ROOT_HASH, &[5, 5]), None);
        assert_eq!(ix.len(), 2);
        assert!(ix.remove_page(0));
        assert_eq!(ix.lookup_hashed(42, ROOT_HASH, &[9, 9]), Some(1), "bucket survives partial removal");
    }
}
