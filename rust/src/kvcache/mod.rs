//! Paged KV-cache pool: one shared fixed-size-page arena for every
//! sequence and layer, plus the trait that lets the model walk any KV
//! cache tile-by-tile.
//!
//! CodeGEMM's argument is about memory-subsystem utilization in
//! memory-bound inference; on the serving side the same wall is the KV
//! cache. The contiguous [`crate::model::KvCache`] allocates
//! `2 × n_layers × max_seq × kv_dim` floats per request up front, so
//! serving capacity degrades with the *worst-case* sequence length even
//! when live sequences are short. This module replaces that with
//! vLLM-style paging:
//!
//! - [`pool::BlockPool`] — the arena: one allocation carved into pages of
//!   `page_size` tokens (all layers, K and V), per-page refcounts, a LIFO
//!   free list, and churn/occupancy counters ([`pool::PoolStats`]). Pool
//!   pages bound total KV memory; the batcher gates admission on free
//!   pages.
//! - [`paged::SeqKv`] / [`paged::PagedKv`] — the per-sequence page table
//!   and the handle that binds it to the pool for one model call, with
//!   the contiguous cache's exact append/read semantics (bit-compatible;
//!   property-pinned) but per-page `&[f32]` views. Pages are claimed
//!   lazily on append and dereferenced wholesale when the request
//!   finishes.
//! - [`KvStore`] — the capability the model actually needs: positional
//!   writes plus tiled reads. The contiguous cache implements it as one
//!   big tile; the paged cache as page-sized tiles. The chunked attention
//!   kernel ([`crate::model::attention`]) is written against this trait,
//!   so decode and prefill run identically over either representation.
//!
//! # Sharing: the page lifecycle
//!
//! Pages are refcounted so identical prompt prefixes are stored once
//! (the shared-system-prompt scenario that dominates chat traffic):
//!
//! - **owned** — refcount 1, unregistered: the ordinary private page;
//!   writable in place.
//! - **shared** — the [`prefix::PrefixIndex`] names a full prompt page by
//!   the chain hash of its token ids; admission
//!   ([`pool::BlockPool::prefix_acquire`]) pins matching pages instead of
//!   allocating and re-prefilling them. Any registered or multiply-held
//!   page is immutable.
//! - **CoW** — a sequence writing into an immutable page (diverging
//!   mid-page, or continuing past a fully-shared prompt) copies it to a
//!   private page first ([`paged::PagedKv`]'s write path; the spare is
//!   pre-claimed at admission so the copy cannot race the free list).
//! - **evicted** — a registered page whose refcount drops to 0 parks as
//!   *cached*: still hittable, reclaimed FIFO by the allocator only when
//!   the free list runs dry, at which point its registration is dropped.
//!
//! # Preemption
//!
//! When the pool saturates and a lower-priority slot is mid-decode, the
//! batcher swaps it out instead of deferring the newcomer (the state
//! machine lives in `coordinator::batcher`; the KV mechanics here):
//! **spill** copies the victim's private pages to the host-side
//! [`spill::SpillArena`] and releases them, and resume bulk-copies them
//! back into freshly claimed pages; **recompute** just releases and later
//! replays prompt + already-sampled tokens through prefill. Both resume
//! bit-exact — spilled floats are the sequence's exact KV state, and
//! replay recomputes the identical values position-by-position.
//!
//! [`KvStats`] packages a pool snapshot with per-slot byte gauges for
//! `coordinator::metrics`.

pub mod paged;
pub mod pool;
pub mod prefix;
pub mod spill;

pub use paged::{PagedKv, SeqKv};
pub use pool::{BlockPool, KvLayout, PoolStats};
pub use prefix::{chain_hash, PrefixIndex, ROOT_HASH};
pub use spill::{SpillArena, SpilledKv};

/// What the model requires of a KV cache: append one position per layer,
/// read back position ranges as contiguous `(keys, values)` tiles.
///
/// Tile `t` covers positions `t * tile_tokens() .. min((t+1) *
/// tile_tokens(), upto)`; within a tile, position rows are contiguous
/// (`kv_dim` floats each). A contiguous cache reports one `max_seq`-sized
/// tile; a paged cache reports page-sized tiles. The attention kernel
/// visits positions in ascending order either way, which is what keeps
/// the tiled walk bit-exact against a flat loop.
pub trait KvStore {
    /// Number of positions filled so far.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum position capacity (the model context window).
    fn max_seq(&self) -> usize;

    /// Floats per position per layer (for each of K and V).
    fn kv_dim(&self) -> usize;

    fn n_layers(&self) -> usize;

    fn is_full(&self) -> bool {
        self.len() >= self.max_seq()
    }

    /// Write K/V for `layer` at position `pos` (`pos <= len`; writing at
    /// `len` on the last layer advances the cache).
    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]);

    /// Drop all cached state (paged implementations also return their
    /// pages to the pool).
    fn clear(&mut self);

    /// Tokens per read tile.
    fn tile_tokens(&self) -> usize;

    /// Number of tiles covering positions `0..upto`.
    fn n_tiles(&self, upto: usize) -> usize {
        upto.div_ceil(self.tile_tokens())
    }

    /// `(keys, values)` of tile `t`, trimmed to `upto`: positions
    /// `t * tile_tokens() .. min((t+1) * tile_tokens(), upto)`.
    fn tile(&self, layer: usize, t: usize, upto: usize) -> (&[f32], &[f32]);

    /// Bytes of storage currently *held* by this sequence (pages claimed,
    /// or the full contiguous allocation).
    fn bytes(&self) -> usize;

    /// Bytes actually *filled* (`2 × n_layers × len × kv_dim × 4`).
    fn bytes_used(&self) -> usize;
}

/// KV occupancy snapshot a pool-backed serving backend reports to
/// `coordinator::metrics`: the pool-level page accounting plus per-slot
/// held/filled byte gauges.
#[derive(Clone, Debug, Default)]
pub struct KvStats {
    pub pool: PoolStats,
    /// Bytes held (pages claimed) per slot.
    pub slot_bytes: Vec<usize>,
    /// Bytes filled per slot.
    pub slot_bytes_used: Vec<usize>,
}

impl KvStats {
    /// Total bytes held across slots.
    pub fn held_bytes(&self) -> usize {
        self.slot_bytes.iter().sum()
    }

    /// Total bytes filled across slots.
    pub fn used_bytes(&self) -> usize {
        self.slot_bytes_used.iter().sum()
    }
}
