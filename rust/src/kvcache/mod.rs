//! Paged KV-cache pool: one shared fixed-size-page arena for every
//! sequence and layer — stored in a pluggable page *codec* — plus the
//! trait that lets the model walk any KV cache tile-by-tile.
//!
//! CodeGEMM's argument is about memory-subsystem utilization in
//! memory-bound inference; on the serving side the same wall is the KV
//! cache. The contiguous [`crate::model::KvCache`] allocates
//! `2 × n_layers × max_seq × kv_dim` floats per request up front, so
//! serving capacity degrades with the *worst-case* sequence length even
//! when live sequences are short. This module replaces that with
//! vLLM-style paging over coded pages:
//!
//! - [`codec::PageStore`] — the element codec behind every page byte:
//!   f32 passthrough (tile reads borrow pool memory, zero cost), f16
//!   (half the bytes, decode exact for the stored value), or int8 with
//!   one f32 scale per kv_dim row (~3.8× fewer bytes at model-scale row
//!   widths, round-to-nearest per `quant::uniform`'s recipe). Selected
//!   by `KvConfig::kv_dtype` / [`KvLayout::dtype`], overridable with
//!   `CODEGEMM_KV_DTYPE`.
//! - [`pool::BlockPool`] — the arena: one coded allocation carved into
//!   pages of `page_size` tokens (all layers, K and V), per-page
//!   refcounts, a LIFO free list, and churn/occupancy counters
//!   ([`pool::PoolStats`], in *coded* bytes). Pool pages bound total KV
//!   memory; the batcher gates admission on free pages — so a smaller
//!   dtype directly buys admission capacity, prefix-cache reach and
//!   smaller spills.
//! - [`paged::SeqKv`] / [`paged::PagedKv`] — the per-sequence page table
//!   and the handle that binds it to the pool for one model call, with
//!   the contiguous cache's exact append/read semantics but per-page
//!   tile views. Pages are claimed lazily on append and dereferenced
//!   wholesale when the request finishes.
//! - [`KvStore`] — the capability the model actually needs: positional
//!   writes plus tiled reads. Reads are **decode-into-caller-scratch**:
//!   [`KvStore::k_tile`]/[`KvStore::v_tile`] take a decode buffer
//!   (owned by the model's per-call `AttnScratch`) and return a borrow
//!   that is pool memory for f32 and the decoded buffer otherwise. The
//!   chunked attention kernel ([`crate::model::attention`]) is written
//!   against this trait, so decode and prefill run identically over
//!   either representation and any dtype.
//!
//! # Exactness contract per dtype
//!
//! - **f32** — bit-exact vs the contiguous cache (property-pinned).
//! - **f16** — deterministic: decode returns exactly the RNE-rounded
//!   stored value, so paged runs agree bit-for-bit with each other and
//!   with a contiguous run *of the same encoding*; vs f32 the error is
//!   half-precision rounding.
//! - **int8** — per-row scale quantization: attention reads are within
//!   half a scale step per element; greedy decode on the smoke model
//!   matches f32 token-for-token (pinned by `tests/paged_kv_prop.rs`).
//!
//! In *all* dtypes, page-granular motion is exact: CoW, prefix sharing,
//! spill and restore copy coded bytes verbatim (never
//! decode→re-encode), so shared and resumed sequences are bit-identical
//! to uninterrupted ones.
//!
//! # Sharing: the page lifecycle
//!
//! Pages are refcounted so identical prompt prefixes are stored once
//! (the shared-system-prompt scenario that dominates chat traffic):
//!
//! - **owned** — refcount 1, unregistered: the ordinary private page;
//!   writable in place.
//! - **shared** — the [`prefix::PrefixIndex`] names a full prompt page by
//!   the chain hash of its token ids; admission
//!   ([`pool::BlockPool::prefix_acquire`]) pins matching pages instead of
//!   allocating and re-prefilling them. Any registered or multiply-held
//!   page is immutable. Under coded dtypes the hitters share the
//!   *quantized* bytes — the O(prompt) shared footprint shrinks by the
//!   same 2–4× as the pool.
//! - **CoW** — a sequence writing into an immutable page (diverging
//!   mid-page, or continuing past a fully-shared prompt) copies it to a
//!   private page first ([`paged::PagedKv`]'s write path; the spare is
//!   pre-claimed at admission so the copy cannot race the free list).
//! - **evicted** — a registered page whose refcount drops to 0 parks as
//!   *cached*: still hittable, reclaimed FIFO by the allocator only when
//!   the free list runs dry, at which point its registration is dropped.
//!
//! # Preemption
//!
//! When the pool saturates and a lower-priority slot is mid-decode, the
//! batcher swaps it out instead of deferring the newcomer (the state
//! machine lives in `coordinator::batcher`; the KV mechanics here):
//! **spill** snapshots the victim's private pages — coded bytes, via
//! [`pool::BlockPool::export_pages`] — into the host-side
//! [`spill::SpillArena`] and releases them, and resume bulk-copies them
//! back into freshly claimed pages ([`pool::BlockPool::import_page`]);
//! **recompute** just releases and later replays prompt +
//! already-sampled tokens through prefill. Both resume bit-exact in
//! every dtype — the spilled snapshot *is* the sequence's coded KV
//! state, and replay re-encodes the identical values
//! position-by-position (per-row encoding is deterministic).
//!
//! [`KvStats`] packages a pool snapshot with per-slot byte gauges (in
//! coded bytes) for `coordinator::metrics`.

pub mod codec;
pub mod paged;
pub mod pool;
pub mod prefix;
pub mod spill;

pub use codec::PageStore;
pub use paged::{PagedKv, SeqKv};
pub use pool::{BlockPool, KvLayout, PoolStats};
pub use prefix::{chain_hash, PrefixIndex, ROOT_HASH};
pub use spill::{SpillArena, SpilledKv};

// Re-exported so KV call sites can name the dtype without reaching into
// the config module tree.
pub use crate::config::KvDtype;

/// What the model requires of a KV cache: append one position per layer,
/// read back position ranges as contiguous `(keys, values)` tiles.
///
/// Tile `t` covers positions `t * tile_tokens() .. min((t+1) *
/// tile_tokens(), upto)`; within a tile, position rows are contiguous
/// (`kv_dim` floats each). A contiguous cache reports one `max_seq`-sized
/// tile; a paged cache reports page-sized tiles. The attention kernel
/// visits positions in ascending order either way, which is what keeps
/// the tiled walk bit-exact against a flat loop.
///
/// Tile reads are split per pass (keys for the score pass, values for
/// the weighting pass) and take a caller decode buffer: coded backings
/// decode the tile into `buf` and return a borrow of it, while f32
/// backings return a zero-copy borrow of their own storage and leave
/// `buf` untouched.
pub trait KvStore {
    /// Number of positions filled so far.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum position capacity (the model context window).
    fn max_seq(&self) -> usize;

    /// Floats per position per layer (for each of K and V).
    fn kv_dim(&self) -> usize;

    fn n_layers(&self) -> usize;

    fn is_full(&self) -> bool {
        self.len() >= self.max_seq()
    }

    /// Write K/V for `layer` at position `pos` (`pos <= len`; writing at
    /// `len` on the last layer advances the cache).
    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]);

    /// Drop all cached state (paged implementations also return their
    /// pages to the pool).
    fn clear(&mut self);

    /// Tokens per read tile.
    fn tile_tokens(&self) -> usize;

    /// Number of tiles covering positions `0..upto`.
    fn n_tiles(&self, upto: usize) -> usize {
        upto.div_ceil(self.tile_tokens())
    }

    /// Keys of tile `t`, trimmed to `upto`: positions
    /// `t * tile_tokens() .. min((t+1) * tile_tokens(), upto)`, decoded
    /// into `buf` when the backing is coded.
    fn k_tile<'a>(&'a self, layer: usize, t: usize, upto: usize, buf: &'a mut Vec<f32>)
        -> &'a [f32];

    /// Values of tile `t`, trimmed to `upto` (see [`Self::k_tile`]).
    fn v_tile<'a>(&'a self, layer: usize, t: usize, upto: usize, buf: &'a mut Vec<f32>)
        -> &'a [f32];

    /// Coded bytes of storage currently *held* by this sequence (pages
    /// claimed, or the full contiguous allocation).
    fn bytes(&self) -> usize;

    /// Coded bytes actually *filled* (`len` positions, K and V, all
    /// layers, plus any scale sidecar).
    fn bytes_used(&self) -> usize;
}

/// KV occupancy snapshot a pool-backed serving backend reports to
/// `coordinator::metrics`: the pool-level page accounting plus per-slot
/// held/filled byte gauges (coded bytes — what the arena actually
/// holds).
#[derive(Clone, Debug, Default)]
pub struct KvStats {
    pub pool: PoolStats,
    /// Coded bytes held (pages claimed) per slot.
    pub slot_bytes: Vec<usize>,
    /// Coded bytes filled per slot.
    pub slot_bytes_used: Vec<usize>,
}

impl KvStats {
    /// Total bytes held across slots.
    pub fn held_bytes(&self) -> usize {
        self.slot_bytes.iter().sum()
    }

    /// Total bytes filled across slots.
    pub fn used_bytes(&self) -> usize {
        self.slot_bytes_used.iter().sum()
    }
}
