//! Tensor-parallel linear layers (Megatron-style, on the CPU pool).
//!
//! Two shard orientations, chosen per layer class by
//! [`crate::model::LlamaModel::load_parallel`]:
//!
//! - **Column-parallel** ([`TpMode::Column`]): the *output* dim `n` is
//!   sharded; every worker sees the full activation and writes a slice
//!   of output rows — on the single-column decode path a true disjoint
//!   sub-slice of the caller's output buffer (bit-exact combining, no
//!   copies). Used for Q/K/V and gate/up projections and the LM head.
//! - **Row-parallel** ([`TpMode::Row`]): the *reduction* dim `k` is
//!   sharded; every worker computes a full-height partial product over
//!   its column range into a block of the reused staging buffer;
//!   combining is the deterministic ordered all-reduce of
//!   [`super::reduce::ordered_sum_into`]. Used for the O and down
//!   projections, whose inputs arrive already sharded in head/ffn space.
//!
//! Execution follows the `gemm_into` model throughout: engines are shared
//! `&self` across workers, every worker gets its own child
//! [`EngineScratch`], and all staging (per-shard inputs in `buf`,
//! partials / batched outputs in `buf2`) comes from the caller's scratch
//! — zero heap allocation per call after warmup.
//!
//! Row-parallel changes the association order of the k-sum, so it is
//! *deterministic* but not bit-identical to the serial engine —
//! outputs differ by float reassociation noise only.
//!
//! Relation to [`super::sharded_engine::ShardedEngine`]: `ShardedEngine`
//! is the statically-dispatched column-parallel wrapper the factory uses
//! for standalone engines (one concrete engine type per shard);
//! `TpLinear` is the boxed, mode-carrying variant for model layers where
//! row-parallel is needed and both orientations must share one type.

use super::fanout::{self, ShardRef};
use super::plan::ShardPlan;
use super::reduce;
use crate::gemm::scratch::grow_slice;
use crate::gemm::{EngineScratch, GemmEngine};
use crate::util::threadpool::{ScopedJob, ThreadPool};
use std::sync::Arc;

/// Shard orientation of a tensor-parallel linear.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TpMode {
    /// Shard the output dim; shards write disjoint output rows.
    Column,
    /// Shard the reduction dim; ordered all-reduce of partials.
    Row,
}

type BoxedEngine = Box<dyn GemmEngine + Send + Sync>;

/// A tensor-parallel linear layer over boxed inner engines.
pub struct TpLinear {
    mode: TpMode,
    /// Partition of `n` (Column) or `k` (Row).
    plan: ShardPlan,
    shards: Vec<BoxedEngine>,
    pool: Arc<ThreadPool>,
    n: usize,
    k: usize,
    scratch: EngineScratch,
}

impl TpLinear {
    /// Column-parallel: `shards[i]` computes output rows `plan.range(i)`
    /// over the full reduction dim.
    pub fn column(plan: ShardPlan, shards: Vec<BoxedEngine>, pool: Arc<ThreadPool>) -> TpLinear {
        assert_eq!(plan.num_shards(), shards.len(), "one engine per shard");
        assert!(!shards.is_empty(), "need at least one shard");
        let k = shards[0].dims().1;
        for (i, e) in shards.iter().enumerate() {
            let (r0, r1) = plan.range(i);
            assert_eq!(e.dims().0, r1 - r0, "column shard {i} row count mismatch");
            assert_eq!(e.dims().1, k, "column shard {i} reduction dim mismatch");
        }
        let n = plan.len;
        TpLinear { mode: TpMode::Column, plan, shards, pool, n, k, scratch: EngineScratch::new() }
    }

    /// Row-parallel: `shards[i]` computes the full `n` output rows over
    /// reduction columns `plan.range(i)`.
    pub fn row(plan: ShardPlan, shards: Vec<BoxedEngine>, pool: Arc<ThreadPool>) -> TpLinear {
        assert_eq!(plan.num_shards(), shards.len(), "one engine per shard");
        assert!(!shards.is_empty(), "need at least one shard");
        let n = shards[0].dims().0;
        for (i, e) in shards.iter().enumerate() {
            let (c0, c1) = plan.range(i);
            assert_eq!(e.dims().0, n, "row shard {i} output dim mismatch");
            assert_eq!(e.dims().1, c1 - c0, "row shard {i} reduction width mismatch");
        }
        let k = plan.len;
        TpLinear { mode: TpMode::Row, plan, shards, pool, n, k, scratch: EngineScratch::new() }
    }

    pub fn mode(&self) -> TpMode {
        self.mode
    }

    pub fn num_shards(&self) -> usize {
        self.plan.num_shards()
    }
}

impl GemmEngine for TpLinear {
    fn name(&self) -> &'static str {
        match self.mode {
            TpMode::Column => "tp-column",
            TpMode::Row => "tp-row",
        }
    }

    fn dims(&self) -> (usize, usize) {
        (self.n, self.k)
    }

    fn gemm_into(&self, x: &[f32], m_batch: usize, y: &mut [f32], scratch: &mut EngineScratch) {
        assert_eq!(x.len(), self.k * m_batch);
        assert_eq!(y.len(), self.n * m_batch);
        let ns = self.plan.num_shards();
        if ns == 1 {
            return self.shards[0].gemm_into(x, m_batch, y, scratch);
        }
        let EngineScratch { counters, buf, buf2, children, .. } = scratch;
        if children.len() < ns {
            children.resize_with(ns, EngineScratch::new);
        }
        match self.mode {
            TpMode::Column => {
                // Output-dim sharding: the shared fan-out (sub-slices of
                // `y` on the decode path, stage+scatter when batched).
                let engines: Vec<ShardRef> = self.shards.iter().map(|b| &**b as ShardRef).collect();
                fanout::column_fan_out(
                    &self.pool,
                    &engines,
                    &self.plan,
                    x,
                    m_batch,
                    y,
                    buf2,
                    &mut children[..ns],
                );
            }
            TpMode::Row => {
                // Stage each shard's column range of every batch column
                // into `buf` (contiguous per shard), give each worker a
                // full-height partial block of `buf2`, then combine with
                // the deterministic ordered all-reduce.
                let (n, k) = (self.n, self.k);
                let xin_all = grow_slice(buf, k * m_batch);
                let mut off = 0usize;
                for &(c0, c1) in &self.plan.shards {
                    let w = c1 - c0;
                    for b in 0..m_batch {
                        xin_all[off + b * w..off + (b + 1) * w]
                            .copy_from_slice(&x[b * k + c0..b * k + c1]);
                    }
                    off += w * m_batch;
                }
                let parts = grow_slice(buf2, ns * n * m_batch);
                let mut jobs: Vec<ScopedJob> = Vec::with_capacity(ns);
                let mut xin_rest: &[f32] = xin_all;
                let mut part_rest: &mut [f32] = &mut *parts;
                for ((e, &(c0, c1)), child) in
                    self.shards.iter().zip(&self.plan.shards).zip(children.iter_mut())
                {
                    let w = c1 - c0;
                    let (xs, xtail) = xin_rest.split_at(w * m_batch);
                    xin_rest = xtail;
                    let (ys, ytail) = std::mem::take(&mut part_rest).split_at_mut(n * m_batch);
                    part_rest = ytail;
                    jobs.push(Box::new(move || e.gemm_into(xs, m_batch, ys, child)));
                }
                self.pool.scope_run(jobs);
                reduce::ordered_sum_into(parts, n * m_batch, y);
            }
        }
        // Merge this call's per-shard counters (one logical GEMM call).
        fanout::merge_children_into(counters, &mut children[..ns]);
    }

    fn scratch(&self) -> &EngineScratch {
        &self.scratch
    }

    fn scratch_mut(&mut self) -> &mut EngineScratch {
        &mut self.scratch
    }

    fn reset_counters(&mut self) {
        for e in &mut self.shards {
            e.reset_counters();
        }
        self.scratch.counters.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::DenseEngine;
    use crate::parallel::shard;
    use crate::util::prng::Prng;
    use crate::util::stats;

    fn pool() -> Arc<ThreadPool> {
        Arc::new(ThreadPool::new(4))
    }

    fn dense_column(w: &[f32], n: usize, k: usize, shards: usize) -> TpLinear {
        let plan = ShardPlan::new(n, shards, 1, 1);
        let engines: Vec<BoxedEngine> = plan
            .shards
            .iter()
            .map(|&(r0, r1)| {
                Box::new(DenseEngine::new(shard::dense_rows(w, k, r0, r1), r1 - r0, k))
                    as BoxedEngine
            })
            .collect();
        TpLinear::column(plan, engines, pool())
    }

    fn dense_row(w: &[f32], n: usize, k: usize, shards: usize) -> TpLinear {
        let plan = ShardPlan::new(k, shards, 1, 1);
        let engines: Vec<BoxedEngine> = plan
            .shards
            .iter()
            .map(|&(c0, c1)| {
                Box::new(DenseEngine::new(shard::dense_cols(w, k, c0, c1), n, c1 - c0))
                    as BoxedEngine
            })
            .collect();
        TpLinear::row(plan, engines, pool())
    }

    #[test]
    fn column_parallel_is_bit_exact() {
        let (n, k) = (30, 40);
        let w = Prng::seeded(1).normal_vec(n * k, 1.0);
        let x = Prng::seeded(2).normal_vec(k * 2, 1.0);
        let mut serial = DenseEngine::new(w.clone(), n, k);
        let mut tp = dense_column(&w, n, k, 3);
        assert_eq!(tp.dims(), (n, k));
        assert_eq!(tp.gemm(&x, 2), serial.gemm(&x, 2));
    }

    #[test]
    fn column_parallel_gemv_into_writes_sub_slices_bit_exact() {
        let (n, k) = (31, 24);
        let w = Prng::seeded(7).normal_vec(n * k, 1.0);
        let x = Prng::seeded(8).normal_vec(k, 1.0);
        let tp = dense_column(&w, n, k, 4);
        let mut scratch = EngineScratch::new();
        let mut y = vec![f32::NAN; n];
        tp.gemv_into(&x, &mut y, &mut scratch);
        assert_eq!(y, DenseEngine::new(w.clone(), n, k).gemv(&x));
        assert_eq!(scratch.counters.calls, 1);
    }

    #[test]
    fn row_parallel_matches_serial_up_to_reassociation() {
        let (n, k) = (24, 64);
        let w = Prng::seeded(3).normal_vec(n * k, 1.0);
        let x = Prng::seeded(4).normal_vec(k * 2, 1.0);
        let mut serial = DenseEngine::new(w.clone(), n, k);
        let mut tp = dense_row(&w, n, k, 4);
        assert_eq!(tp.dims(), (n, k));
        let (y, y_ref) = (tp.gemm(&x, 2), serial.gemm(&x, 2));
        assert!(stats::rel_l2(&y, &y_ref) < 1e-5, "reassociation noise only");
        // MACs are conserved exactly under the k-split.
        assert_eq!(tp.counters().mac_flops, serial.counters().mac_flops);
    }

    #[test]
    fn row_parallel_is_deterministic() {
        let (n, k) = (16, 48);
        let w = Prng::seeded(5).normal_vec(n * k, 1.0);
        let x = Prng::seeded(6).normal_vec(k, 1.0);
        let run = || {
            let mut tp = dense_row(&w, n, k, 3);
            tp.gemv(&x)
        };
        // Ordered reduction ⇒ bitwise identical across runs/schedules.
        assert_eq!(run(), run());
    }
}
