//! Tensor-parallel linear layers (Megatron-style, on the CPU pool).
//!
//! Two shard orientations, chosen per layer class by
//! [`crate::model::LlamaModel::load_parallel`]:
//!
//! - **Column-parallel** ([`TpMode::Column`]): the *output* dim `n` is
//!   sharded; every worker sees the full activation and computes a slice
//!   of output rows; combining is concatenation (bit-exact). Used for
//!   Q/K/V and gate/up projections and the LM head.
//! - **Row-parallel** ([`TpMode::Row`]): the *reduction* dim `k` is
//!   sharded; every worker computes a full-height partial product over
//!   its column range; combining is the deterministic ordered all-reduce
//!   of [`super::reduce::ordered_sum`]. Used for the O and down
//!   projections, whose inputs arrive already sharded in head/ffn space.
//!
//! Row-parallel changes the association order of the k-sum, so it is
//! *deterministic* but not bit-identical to the serial engine —
//! outputs differ by float reassociation noise only.
//!
//! Relation to [`super::sharded_engine::ShardedEngine`]: `ShardedEngine`
//! is the statically-dispatched column-parallel wrapper the factory uses
//! for standalone engines (one concrete engine type per shard);
//! `TpLinear` is the boxed, mode-carrying variant for model layers where
//! row-parallel is needed and both orientations must share one type.

use super::plan::ShardPlan;
use super::reduce;
use crate::gemm::{Counters, GemmEngine};
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Shard orientation of a tensor-parallel linear.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TpMode {
    /// Shard the output dim; concatenate shard outputs.
    Column,
    /// Shard the reduction dim; ordered all-reduce of partials.
    Row,
}

type BoxedEngine = Box<dyn GemmEngine + Send>;

/// A tensor-parallel linear layer over boxed inner engines.
pub struct TpLinear {
    mode: TpMode,
    /// Partition of `n` (Column) or `k` (Row).
    plan: ShardPlan,
    shards: Vec<BoxedEngine>,
    pool: Arc<ThreadPool>,
    n: usize,
    k: usize,
    counters: Counters,
}

impl TpLinear {
    /// Column-parallel: `shards[i]` computes output rows `plan.range(i)`
    /// over the full reduction dim.
    pub fn column(plan: ShardPlan, shards: Vec<BoxedEngine>, pool: Arc<ThreadPool>) -> TpLinear {
        assert_eq!(plan.num_shards(), shards.len(), "one engine per shard");
        assert!(!shards.is_empty(), "need at least one shard");
        let k = shards[0].dims().1;
        for (i, e) in shards.iter().enumerate() {
            let (r0, r1) = plan.range(i);
            assert_eq!(e.dims().0, r1 - r0, "column shard {i} row count mismatch");
            assert_eq!(e.dims().1, k, "column shard {i} reduction dim mismatch");
        }
        let n = plan.len;
        TpLinear { mode: TpMode::Column, plan, shards, pool, n, k, counters: Counters::new() }
    }

    /// Row-parallel: `shards[i]` computes the full `n` output rows over
    /// reduction columns `plan.range(i)`.
    pub fn row(plan: ShardPlan, shards: Vec<BoxedEngine>, pool: Arc<ThreadPool>) -> TpLinear {
        assert_eq!(plan.num_shards(), shards.len(), "one engine per shard");
        assert!(!shards.is_empty(), "need at least one shard");
        let n = shards[0].dims().0;
        for (i, e) in shards.iter().enumerate() {
            let (c0, c1) = plan.range(i);
            assert_eq!(e.dims().0, n, "row shard {i} output dim mismatch");
            assert_eq!(e.dims().1, c1 - c0, "row shard {i} reduction width mismatch");
        }
        let k = plan.len;
        TpLinear { mode: TpMode::Row, plan, shards, pool, n, k, counters: Counters::new() }
    }

    pub fn mode(&self) -> TpMode {
        self.mode
    }

    pub fn num_shards(&self) -> usize {
        self.plan.num_shards()
    }

    fn refresh_counters(&mut self) {
        self.counters = reduce::merge_counters(self.shards.iter().map(|e| e.counters()));
        self.counters.calls /= self.plan.num_shards().max(1) as u64;
    }

    /// Fan the per-shard inputs out over the pool, moving engines into
    /// the jobs and back; returns per-shard outputs in shard order.
    /// Inputs are `Arc`s so Column mode shares one activation buffer
    /// across all shards instead of copying it per shard.
    fn fan_out(&mut self, inputs: Vec<Arc<Vec<f32>>>, m_batch: usize) -> Vec<Vec<f32>> {
        let engines = std::mem::take(&mut self.shards);
        let items: Vec<(BoxedEngine, Arc<Vec<f32>>)> = engines.into_iter().zip(inputs).collect();
        let results = self.pool.parallel_map(items, move |(mut e, xin)| {
            let y = e.gemm(&xin, m_batch);
            (e, y)
        });
        let mut parts = Vec::with_capacity(results.len());
        for (e, y) in results {
            self.shards.push(e);
            parts.push(y);
        }
        parts
    }
}

impl GemmEngine for TpLinear {
    fn name(&self) -> &'static str {
        match self.mode {
            TpMode::Column => "tp-column",
            TpMode::Row => "tp-row",
        }
    }

    fn dims(&self) -> (usize, usize) {
        (self.n, self.k)
    }

    fn gemm(&mut self, x: &[f32], m_batch: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.k * m_batch);
        assert_eq!(
            self.shards.len(),
            self.plan.num_shards(),
            "tp linear poisoned: a previous call panicked mid-fan-out"
        );
        if self.shards.len() == 1 {
            let y = self.shards[0].gemm(x, m_batch);
            self.refresh_counters();
            return y;
        }
        let y = match self.mode {
            TpMode::Column => {
                // Every shard reads the whole activation (one shared
                // buffer; the Arc clone is free).
                let xs = Arc::new(x.to_vec());
                let inputs = vec![xs; self.plan.num_shards()];
                let parts = self.fan_out(inputs, m_batch);
                reduce::concat_row_shards(&parts, &self.plan, m_batch)
            }
            TpMode::Row => {
                // Each shard reads its own column range of every batch col.
                let k = self.k;
                let inputs: Vec<Arc<Vec<f32>>> = self
                    .plan
                    .shards
                    .iter()
                    .map(|&(c0, c1)| {
                        let mut xi = Vec::with_capacity((c1 - c0) * m_batch);
                        for b in 0..m_batch {
                            xi.extend_from_slice(&x[b * k + c0..b * k + c1]);
                        }
                        Arc::new(xi)
                    })
                    .collect();
                let parts = self.fan_out(inputs, m_batch);
                reduce::ordered_sum(&parts)
            }
        };
        self.refresh_counters();
        y
    }

    fn counters(&self) -> &Counters {
        &self.counters
    }

    fn reset_counters(&mut self) {
        for e in &mut self.shards {
            e.reset_counters();
        }
        self.counters.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::DenseEngine;
    use crate::parallel::shard;
    use crate::util::prng::Prng;
    use crate::util::stats;

    fn pool() -> Arc<ThreadPool> {
        Arc::new(ThreadPool::new(4))
    }

    fn dense_column(w: &[f32], n: usize, k: usize, shards: usize) -> TpLinear {
        let plan = ShardPlan::new(n, shards, 1, 1);
        let engines: Vec<BoxedEngine> = plan
            .shards
            .iter()
            .map(|&(r0, r1)| {
                Box::new(DenseEngine::new(shard::dense_rows(w, k, r0, r1), r1 - r0, k))
                    as BoxedEngine
            })
            .collect();
        TpLinear::column(plan, engines, pool())
    }

    fn dense_row(w: &[f32], n: usize, k: usize, shards: usize) -> TpLinear {
        let plan = ShardPlan::new(k, shards, 1, 1);
        let engines: Vec<BoxedEngine> = plan
            .shards
            .iter()
            .map(|&(c0, c1)| {
                Box::new(DenseEngine::new(shard::dense_cols(w, k, c0, c1), n, c1 - c0))
                    as BoxedEngine
            })
            .collect();
        TpLinear::row(plan, engines, pool())
    }

    #[test]
    fn column_parallel_is_bit_exact() {
        let (n, k) = (30, 40);
        let w = Prng::seeded(1).normal_vec(n * k, 1.0);
        let x = Prng::seeded(2).normal_vec(k * 2, 1.0);
        let mut serial = DenseEngine::new(w.clone(), n, k);
        let mut tp = dense_column(&w, n, k, 3);
        assert_eq!(tp.dims(), (n, k));
        assert_eq!(tp.gemm(&x, 2), serial.gemm(&x, 2));
    }

    #[test]
    fn row_parallel_matches_serial_up_to_reassociation() {
        let (n, k) = (24, 64);
        let w = Prng::seeded(3).normal_vec(n * k, 1.0);
        let x = Prng::seeded(4).normal_vec(k * 2, 1.0);
        let mut serial = DenseEngine::new(w.clone(), n, k);
        let mut tp = dense_row(&w, n, k, 4);
        assert_eq!(tp.dims(), (n, k));
        let (y, y_ref) = (tp.gemm(&x, 2), serial.gemm(&x, 2));
        assert!(stats::rel_l2(&y, &y_ref) < 1e-5, "reassociation noise only");
        // MACs are conserved exactly under the k-split.
        assert_eq!(tp.counters().mac_flops, serial.counters().mac_flops);
    }

    #[test]
    fn row_parallel_is_deterministic() {
        let (n, k) = (16, 48);
        let w = Prng::seeded(5).normal_vec(n * k, 1.0);
        let x = Prng::seeded(6).normal_vec(k, 1.0);
        let run = || {
            let mut tp = dense_row(&w, n, k, 3);
            tp.gemv(&x)
        };
        // Ordered reduction ⇒ bitwise identical across runs/schedules.
        assert_eq!(run(), run());
    }
}
