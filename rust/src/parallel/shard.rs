//! Shard extraction: carve a row range (output sharding) or a column
//! range (reduction-dim sharding) out of a quantized or dense layer.
//!
//! Row slicing happens *after* quantization, so a shard's codebooks,
//! codes and scales are byte-identical to the corresponding rows of the
//! serial layer — which is what makes `ShardedEngine` bit-exact against
//! the serial engine (row partitioning never reorders the per-row float
//! accumulation). Column slicing is alignment-checked against `v` and the
//! normalization group `g` so group scales never straddle a shard
//! boundary.

use crate::quant::{PackedCodes, QuantizedLinear};

/// Rows `[r0, r1)` of a quantized layer as a standalone layer.
///
/// The codebooks are shared (cloned), the code stream for a row range is
/// contiguous in the packed `[r][j][c]` order, and the per-row group
/// scales slice directly.
pub fn slice_rows(q: &QuantizedLinear, r0: usize, r1: usize) -> QuantizedLinear {
    slice_rows_unpacked(q, &q.codes.unpack(), r0, r1)
}

/// [`slice_rows`] with the code stream already unpacked — callers
/// carving many shards out of one layer unpack once and reuse it
/// instead of paying the O(n·k/v·m) unpack per shard.
pub fn slice_rows_unpacked(
    q: &QuantizedLinear,
    codes: &[u32],
    r0: usize,
    r1: usize,
) -> QuantizedLinear {
    assert!(r0 < r1 && r1 <= q.n, "row range [{r0}, {r1}) out of [0, {})", q.n);
    let jn = q.vectors_per_row();
    let m = q.cfg.m;
    let gpr = q.groups_per_row();
    assert_eq!(codes.len(), q.n * jn * m, "unpacked code stream length mismatch");
    let sub = &codes[r0 * jn * m..r1 * jn * m];
    let out = QuantizedLinear {
        cfg: q.cfg,
        n: r1 - r0,
        k: q.k,
        codebooks: q.codebooks.clone(),
        codes: PackedCodes::pack(sub, q.codes.bits()).expect("codes stay in range"),
        scales: q.scales[r0 * gpr..r1 * gpr].to_vec(),
    };
    debug_assert!(out.validate().is_ok());
    out
}

/// Columns `[c0, c1)` of a quantized layer as a standalone layer (all
/// rows, reduced reduction dim — the shard shape of row-parallel /
/// tensor-parallel execution).
///
/// `c0` must be a multiple of `v` and of the group size `g` (when
/// grouped); `c1` likewise, except that `c1 == k` is always allowed (the
/// ragged final group stays intact inside the last shard).
pub fn slice_cols(q: &QuantizedLinear, c0: usize, c1: usize) -> QuantizedLinear {
    slice_cols_unpacked(q, &q.codes.unpack(), c0, c1)
}

/// [`slice_cols`] with the code stream already unpacked (see
/// [`slice_rows_unpacked`]).
pub fn slice_cols_unpacked(
    q: &QuantizedLinear,
    codes: &[u32],
    c0: usize,
    c1: usize,
) -> QuantizedLinear {
    let v = q.cfg.v;
    assert!(c0 < c1 && c1 <= q.k, "col range [{c0}, {c1}) out of [0, {})", q.k);
    assert_eq!(c0 % v, 0, "c0 ({c0}) must be a multiple of v ({v})");
    assert!(c1 % v == 0 || c1 == q.k, "c1 ({c1}) must be a multiple of v ({v}) or k");
    let jn = q.vectors_per_row();
    let (j0, j1) = (c0 / v, c1 / v);
    let m = q.cfg.m;
    assert_eq!(codes.len(), q.n * jn * m, "unpacked code stream length mismatch");
    let mut sub = Vec::with_capacity(q.n * (j1 - j0) * m);
    for r in 0..q.n {
        let base = (r * jn + j0) * m;
        sub.extend_from_slice(&codes[base..base + (j1 - j0) * m]);
    }
    let scales = match q.cfg.g {
        Some(g) => {
            assert_eq!(c0 % g, 0, "c0 ({c0}) must be a multiple of g ({g})");
            assert!(c1 % g == 0 || c1 == q.k, "c1 ({c1}) must be a multiple of g ({g}) or k");
            let gpr = q.groups_per_row();
            let (g0, g1) = (c0 / g, (c1 + g - 1) / g);
            let mut s = Vec::with_capacity(q.n * (g1 - g0));
            for r in 0..q.n {
                s.extend_from_slice(&q.scales[r * gpr + g0..r * gpr + g1]);
            }
            s
        }
        // Row-wise normalization: the single per-row scale covers any
        // column subset unchanged.
        None => q.scales.clone(),
    };
    let out = QuantizedLinear {
        cfg: q.cfg,
        n: q.n,
        k: c1 - c0,
        codebooks: q.codebooks.clone(),
        codes: PackedCodes::pack(&sub, q.codes.bits()).expect("codes stay in range"),
        scales,
    };
    debug_assert!(out.validate().is_ok());
    out
}

/// Rows `[r0, r1)` of a dense row-major `n × k` matrix.
pub fn dense_rows(w: &[f32], k: usize, r0: usize, r1: usize) -> Vec<f32> {
    w[r0 * k..r1 * k].to_vec()
}

/// Columns `[c0, c1)` of a dense row-major `n × k` matrix (all rows).
pub fn dense_cols(w: &[f32], k: usize, c0: usize, c1: usize) -> Vec<f32> {
    assert!(c0 < c1 && c1 <= k);
    let n = w.len() / k;
    let mut out = Vec::with_capacity(n * (c1 - c0));
    for r in 0..n {
        out.extend_from_slice(&w[r * k + c0..r * k + c1]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantConfig;
    use crate::quant::Quantizer;
    use crate::util::prng::Prng;

    fn quantize(n: usize, k: usize, label: &str, seed: u64) -> QuantizedLinear {
        let w = Prng::seeded(seed).normal_vec(n * k, 0.02);
        Quantizer::new(QuantConfig::parse_label(label).unwrap()).quantize(&w, n, k)
    }

    #[test]
    fn row_slice_dequantizes_to_row_slice() {
        for label in ["m1v4g32", "m2v8g-1", "m1v8g16"] {
            let q = quantize(24, 64, label, 1);
            let full = q.dequantize();
            for (r0, r1) in [(0usize, 8usize), (8, 24), (5, 6), (0, 24)] {
                let s = slice_rows(&q, r0, r1);
                s.validate().unwrap();
                assert_eq!(s.dequantize(), full[r0 * 64..r1 * 64].to_vec(), "{label} rows {r0}..{r1}");
            }
        }
    }

    #[test]
    fn col_slice_dequantizes_to_col_slice() {
        for label in ["m1v4g32", "m2v8g-1", "m2v8g32"] {
            let q = quantize(12, 128, label, 2);
            let full = q.dequantize();
            for (c0, c1) in [(0usize, 64usize), (64, 128), (32, 96), (0, 128)] {
                let s = slice_cols(&q, c0, c1);
                s.validate().unwrap();
                let want = dense_cols(&full, 128, c0, c1);
                assert_eq!(s.dequantize(), want, "{label} cols {c0}..{c1}");
            }
        }
    }

    #[test]
    fn col_slice_ragged_final_group() {
        // k=96 with g=64: last group is ragged (32 wide); slicing at the
        // group boundary keeps it intact in the last shard.
        let q = quantize(8, 96, "m1v4g64", 3);
        let full = q.dequantize();
        let a = slice_cols(&q, 0, 64);
        let b = slice_cols(&q, 64, 96);
        a.validate().unwrap();
        b.validate().unwrap();
        assert_eq!(a.dequantize(), dense_cols(&full, 96, 0, 64));
        assert_eq!(b.dequantize(), dense_cols(&full, 96, 64, 96));
    }

    #[test]
    #[should_panic(expected = "multiple of g")]
    fn col_slice_rejects_group_straddle() {
        let q = quantize(4, 128, "m1v4g32", 4);
        let _ = slice_cols(&q, 16, 128);
    }

    #[test]
    fn dense_helpers() {
        // 2×4 matrix [[0,1,2,3],[4,5,6,7]]
        let w: Vec<f32> = (0..8).map(|x| x as f32).collect();
        assert_eq!(dense_rows(&w, 4, 1, 2), vec![4.0, 5.0, 6.0, 7.0]);
        assert_eq!(dense_cols(&w, 4, 1, 3), vec![1.0, 2.0, 5.0, 6.0]);
    }
}
