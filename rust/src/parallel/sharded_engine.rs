//! `ShardedEngine<E>`: run any [`GemmEngine`] row-sharded across the
//! worker pool.
//!
//! Each shard is a complete inner engine over its own row range — with
//! its *own* Psumbook / LUT / decode scratch, mirroring the
//! thread-block-local tables of the GPU kernels — so shards share no
//! mutable state and fan out over `ThreadPool::parallel_map` with no
//! synchronization beyond the final join. Outputs are concatenated in
//! shard order; since row partitioning never reorders any row's float
//! accumulation, the result is **bit-exact** against the serial engine
//! the shards were sliced from (the property tests assert `==`, not
//! approximate equality).

use super::plan::ShardPlan;
use super::reduce;
use crate::gemm::{Counters, GemmEngine};
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Generic row-sharded wrapper around per-shard inner engines.
pub struct ShardedEngine<E: GemmEngine + Send + 'static> {
    plan: ShardPlan,
    shards: Vec<E>,
    pool: Arc<ThreadPool>,
    k: usize,
    counters: Counters,
}

impl<E: GemmEngine + Send + 'static> ShardedEngine<E> {
    /// Wrap pre-built shard engines. `shards[i]` must compute the rows of
    /// `plan.range(i)` (i.e. its `dims().0 == plan.shard_len(i)`), and
    /// every shard must share the reduction dim `k`.
    pub fn new(plan: ShardPlan, shards: Vec<E>, pool: Arc<ThreadPool>) -> ShardedEngine<E> {
        assert_eq!(plan.num_shards(), shards.len(), "one engine per shard");
        assert!(!shards.is_empty(), "need at least one shard");
        let k = shards[0].dims().1;
        for (i, e) in shards.iter().enumerate() {
            let (r0, r1) = plan.range(i);
            assert_eq!(e.dims().0, r1 - r0, "shard {i} row count mismatch");
            assert_eq!(e.dims().1, k, "shard {i} reduction dim mismatch");
        }
        ShardedEngine { plan, shards, pool, k, counters: Counters::new() }
    }

    /// Build shard engines from a factory called with each row range.
    pub fn from_factory(
        plan: ShardPlan,
        pool: Arc<ThreadPool>,
        f: impl Fn((usize, usize)) -> E,
    ) -> ShardedEngine<E> {
        let shards = plan.shards.iter().map(|&r| f(r)).collect();
        ShardedEngine::new(plan, shards, pool)
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn num_shards(&self) -> usize {
        self.plan.num_shards()
    }

    /// Borrow the inner shard engines (tests / introspection).
    pub fn shards(&self) -> &[E] {
        &self.shards
    }

    fn refresh_counters(&mut self) {
        self.counters = reduce::merge_counters(self.shards.iter().map(|e| e.counters()));
        // One sharded call is one logical GEMM call, not `num_shards`.
        self.counters.calls /= self.plan.num_shards().max(1) as u64;
    }
}

impl<E: GemmEngine + Send + 'static> GemmEngine for ShardedEngine<E> {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn dims(&self) -> (usize, usize) {
        (self.plan.len, self.k)
    }

    fn gemm(&mut self, x: &[f32], m_batch: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.k * m_batch);
        // A shard job that panicked in an earlier call unwound through
        // `parallel_map` while the engines were checked out — surface
        // that state directly instead of a confusing downstream error.
        assert_eq!(
            self.shards.len(),
            self.plan.num_shards(),
            "sharded engine poisoned: a previous call panicked mid-fan-out"
        );
        if self.shards.len() == 1 {
            let y = self.shards[0].gemm(x, m_batch);
            self.refresh_counters();
            return y;
        }
        // Shard engines are moved into the pool jobs and moved back with
        // their outputs — no shared mutable state, no unsafe. The
        // activation vector is shared read-only via Arc.
        let xs: Arc<Vec<f32>> = Arc::new(x.to_vec());
        let engines = std::mem::take(&mut self.shards);
        let results = self.pool.parallel_map(engines, move |mut e: E| {
            let y = e.gemm(&xs, m_batch);
            (e, y)
        });
        let mut parts = Vec::with_capacity(results.len());
        for (e, y) in results {
            self.shards.push(e);
            parts.push(y);
        }
        let y = reduce::concat_row_shards(&parts, &self.plan, m_batch);
        self.refresh_counters();
        y
    }

    fn counters(&self) -> &Counters {
        &self.counters
    }

    fn reset_counters(&mut self) {
        for e in &mut self.shards {
            e.reset_counters();
        }
        self.counters.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantConfig;
    use crate::gemm::{CodeGemmEngine, DenseEngine};
    use crate::parallel::shard;
    use crate::quant::Quantizer;
    use crate::util::prng::Prng;

    fn pool() -> Arc<ThreadPool> {
        Arc::new(ThreadPool::new(4))
    }

    #[test]
    fn dense_sharded_is_bit_exact() {
        let (n, k) = (37, 48);
        let w = Prng::seeded(1).normal_vec(n * k, 1.0);
        let x = Prng::seeded(2).normal_vec(k * 3, 1.0);
        let mut serial = DenseEngine::new(w.clone(), n, k);
        let plan = ShardPlan::new(n, 4, 1, 1);
        let mut sharded = ShardedEngine::from_factory(plan, pool(), |(r0, r1)| {
            DenseEngine::new(shard::dense_rows(&w, k, r0, r1), r1 - r0, k)
        });
        assert_eq!(sharded.dims(), (n, k));
        assert_eq!(sharded.gemm(&x, 3), serial.gemm(&x, 3));
        assert_eq!(sharded.counters().mac_flops, serial.counters().mac_flops);
        assert_eq!(sharded.counters().calls, 1);
    }

    #[test]
    fn codegemm_sharded_is_bit_exact() {
        let (n, k) = (64, 128);
        let w = Prng::seeded(3).normal_vec(n * k, 0.02);
        let q = Quantizer::new(QuantConfig::parse_label("m2v8g32").unwrap()).quantize(&w, n, k);
        let x = Prng::seeded(4).normal_vec(k, 1.0);
        let mut serial = CodeGemmEngine::from_quantized(&q);
        let plan = ShardPlan::new(n, 3, 8, 1);
        let mut sharded = ShardedEngine::from_factory(plan, pool(), |(r0, r1)| {
            CodeGemmEngine::from_quantized(&shard::slice_rows(&q, r0, r1))
        });
        assert_eq!(sharded.gemv(&x), serial.gemv(&x));
        // Gather work is per-row, so merged lookups match the serial run.
        assert_eq!(sharded.counters().lookups, serial.counters().lookups);
        assert_eq!(sharded.counters().read_ops, serial.counters().read_ops);
    }

    #[test]
    fn single_shard_stays_serial() {
        let (n, k) = (8, 16);
        let w = Prng::seeded(5).normal_vec(n * k, 1.0);
        let x = Prng::seeded(6).normal_vec(k, 1.0);
        let plan = ShardPlan::serial(n);
        let mut sharded = ShardedEngine::from_factory(plan, pool(), |(r0, r1)| {
            DenseEngine::new(shard::dense_rows(&w, k, r0, r1), r1 - r0, k)
        });
        assert_eq!(sharded.num_shards(), 1);
        let y = sharded.gemv(&x);
        assert_eq!(y, DenseEngine::new(w.clone(), n, k).gemv(&x));
    }

    #[test]
    fn counters_reset_recursively() {
        let (n, k) = (16, 16);
        let w = Prng::seeded(7).normal_vec(n * k, 1.0);
        let x = vec![1.0f32; k];
        let plan = ShardPlan::new(n, 2, 1, 1);
        let mut sharded = ShardedEngine::from_factory(plan, pool(), |(r0, r1)| {
            DenseEngine::new(shard::dense_rows(&w, k, r0, r1), r1 - r0, k)
        });
        let _ = sharded.gemv(&x);
        assert!(sharded.counters().mac_flops > 0);
        sharded.reset_counters();
        assert_eq!(sharded.counters().mac_flops, 0);
        assert!(sharded.shards().iter().all(|e| e.counters().mac_flops == 0));
    }
}
