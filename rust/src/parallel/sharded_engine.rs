//! `ShardedEngine<E>`: run any [`GemmEngine`] row-sharded across the
//! worker pool.
//!
//! Each shard is a complete inner engine over its own row range, executed
//! via the `&self` zero-allocation `gemm_into` core: workers share the
//! engines read-only and every worker gets (a) a disjoint sub-slice of
//! the *caller's* output buffer and (b) its own per-worker
//! [`EngineScratch`] from the caller scratch's `children`. There is no
//! per-shard `Vec` allocation and no concatenation step on the single
//! column (decode) path; batched calls stage per-shard blocks in the
//! reused `buf2` and scatter once. Since row partitioning never reorders
//! any row's float accumulation, the result is **bit-exact** against the
//! serial engine the shards were sliced from (the property tests assert
//! `==`, not approximate equality).
//!
//! ## Private tables vs. one shared Psumbook
//!
//! Generic shards run the *private-table* schedule: each worker's engine
//! builds its own Psumbook/LUT in its child scratch (the thread-block-
//! local tables of the GPU kernels) — which makes a K-way sharded
//! CodeGEMM layer pay K× the Psumbook build MACs. When every shard is a
//! [`CodeGemmEngine`] with matching quantization and tile geometry
//! (detected at construction via [`GemmEngine::as_codegemm`]), the engine
//! instead takes the **build-once/gather-many** path of
//! `fanout::shared_book_fan_out`: per k-tile, phase 1 builds one shared
//! book in the *caller's* scratch (parallelized by j-ranges), phase 2
//! fans the gather out over the row shards reading it read-only. Same
//! bit-exact outputs; build MACs attributed once per logical call
//! regardless of shard count; scratch buffers stay grow-only, though
//! the per-k-tile job dispatch itself is not allocation-free (see
//! `fanout`). [`ShardedEngine::with_shared_book`] opts out (the private
//! schedule remains available for measurement).
//!
//! A panicking shard propagates at the caller after all jobs of the call
//! settle (`ThreadPool::scope_run`); the engine itself stays usable.

use super::fanout::{self, ShardRef};
use super::plan::ShardPlan;
use crate::gemm::{CodeGemmEngine, EngineScratch, GemmEngine};
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Generic row-sharded wrapper around per-shard inner engines.
pub struct ShardedEngine<E: GemmEngine + Send + Sync> {
    plan: ShardPlan,
    shards: Vec<E>,
    pool: Arc<ThreadPool>,
    k: usize,
    scratch: EngineScratch,
    /// Take the shared-Psumbook schedule when the shards support it.
    shared_book: bool,
    /// All shards are CodeGEMM engines with matching book geometry
    /// (computed once at construction).
    shared_compatible: bool,
}

impl<E: GemmEngine + Send + Sync> ShardedEngine<E> {
    /// Wrap pre-built shard engines. `shards[i]` must compute the rows of
    /// `plan.range(i)` (i.e. its `dims().0 == plan.shard_len(i)`), and
    /// every shard must share the reduction dim `k`.
    pub fn new(plan: ShardPlan, shards: Vec<E>, pool: Arc<ThreadPool>) -> ShardedEngine<E> {
        assert_eq!(plan.num_shards(), shards.len(), "one engine per shard");
        assert!(!shards.is_empty(), "need at least one shard");
        let k = shards[0].dims().1;
        for (i, e) in shards.iter().enumerate() {
            let (r0, r1) = plan.range(i);
            assert_eq!(e.dims().0, r1 - r0, "shard {i} row count mismatch");
            assert_eq!(e.dims().1, k, "shard {i} reduction dim mismatch");
        }
        let shared_compatible = {
            let cgs: Option<Vec<&CodeGemmEngine>> =
                shards.iter().map(|e| e.as_codegemm()).collect();
            cgs.map_or(false, |cgs| fanout::shared_book_compatible(&cgs))
        };
        ShardedEngine {
            plan,
            shards,
            pool,
            k,
            scratch: EngineScratch::new(),
            shared_book: true,
            shared_compatible,
        }
    }

    /// Enable/disable the shared-Psumbook schedule (on by default; only
    /// effective when the shards are compatible CodeGEMM engines). The
    /// private per-shard-table schedule is kept available so the
    /// build-share amortization is directly measurable.
    pub fn with_shared_book(mut self, on: bool) -> ShardedEngine<E> {
        self.shared_book = on;
        self
    }

    /// True when calls will take the build-once/gather-many path.
    pub fn uses_shared_book(&self) -> bool {
        self.shared_book && self.shared_compatible && self.plan.num_shards() > 1
    }

    /// Build shard engines from a factory called with each row range.
    pub fn from_factory(
        plan: ShardPlan,
        pool: Arc<ThreadPool>,
        f: impl Fn((usize, usize)) -> E,
    ) -> ShardedEngine<E> {
        let shards = plan.shards.iter().map(|&r| f(r)).collect();
        ShardedEngine::new(plan, shards, pool)
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn num_shards(&self) -> usize {
        self.plan.num_shards()
    }

    /// Borrow the inner shard engines (tests / introspection).
    pub fn shards(&self) -> &[E] {
        &self.shards
    }
}

impl<E: GemmEngine + Send + Sync> GemmEngine for ShardedEngine<E> {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn dims(&self) -> (usize, usize) {
        (self.plan.len, self.k)
    }

    fn gemm_into(&self, x: &[f32], m_batch: usize, y: &mut [f32], scratch: &mut EngineScratch) {
        assert_eq!(x.len(), self.k * m_batch);
        assert_eq!(y.len(), self.plan.len * m_batch);
        let ns = self.plan.num_shards();
        if ns == 1 {
            // Serial fast path: run on the caller's thread with the
            // caller's scratch directly.
            return self.shards[0].gemm_into(x, m_batch, y, scratch);
        }
        if self.shared_book && self.shared_compatible {
            // Build-once/gather-many: one shared Psumbook per k-tile in
            // the caller's scratch, gathered by every row shard
            // (compatibility was proven once at construction).
            return fanout::shared_book_fan_out(
                &self.pool,
                &self.shards,
                &self.plan,
                x,
                m_batch,
                y,
                scratch,
            );
        }
        let EngineScratch { counters, buf2, children, .. } = scratch;
        if children.len() < ns {
            children.resize_with(ns, EngineScratch::new);
        }
        let engines: Vec<ShardRef> = self.shards.iter().map(|e| e as ShardRef).collect();
        fanout::column_fan_out(
            &self.pool,
            &engines,
            &self.plan,
            x,
            m_batch,
            y,
            buf2,
            &mut children[..ns],
        );
        fanout::merge_children_into(counters, &mut children[..ns]);
    }

    fn scratch(&self) -> &EngineScratch {
        &self.scratch
    }

    fn scratch_mut(&mut self) -> &mut EngineScratch {
        &mut self.scratch
    }

    fn reset_counters(&mut self) {
        for e in &mut self.shards {
            e.reset_counters();
        }
        self.scratch.counters.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantConfig;
    use crate::gemm::{CodeGemmEngine, DenseEngine};
    use crate::parallel::shard;
    use crate::quant::Quantizer;
    use crate::util::prng::Prng;

    fn pool() -> Arc<ThreadPool> {
        Arc::new(ThreadPool::new(4))
    }

    #[test]
    fn dense_sharded_is_bit_exact() {
        let (n, k) = (37, 48);
        let w = Prng::seeded(1).normal_vec(n * k, 1.0);
        let x = Prng::seeded(2).normal_vec(k * 3, 1.0);
        let mut serial = DenseEngine::new(w.clone(), n, k);
        let plan = ShardPlan::new(n, 4, 1, 1);
        let mut sharded = ShardedEngine::from_factory(plan, pool(), |(r0, r1)| {
            DenseEngine::new(shard::dense_rows(&w, k, r0, r1), r1 - r0, k)
        });
        assert_eq!(sharded.dims(), (n, k));
        assert_eq!(sharded.gemm(&x, 3), serial.gemm(&x, 3));
        assert_eq!(sharded.counters().mac_flops, serial.counters().mac_flops);
        assert_eq!(sharded.counters().calls, 1);
    }

    #[test]
    fn codegemm_sharded_is_bit_exact() {
        let (n, k) = (64, 128);
        let w = Prng::seeded(3).normal_vec(n * k, 0.02);
        let q = Quantizer::new(QuantConfig::parse_label("m2v8g32").unwrap()).quantize(&w, n, k);
        let x = Prng::seeded(4).normal_vec(k, 1.0);
        let mut serial = CodeGemmEngine::from_quantized(&q);
        let plan = ShardPlan::new(n, 3, 8, 1);
        let mut sharded = ShardedEngine::from_factory(plan, pool(), |(r0, r1)| {
            CodeGemmEngine::from_quantized(&shard::slice_rows(&q, r0, r1))
        });
        assert!(sharded.uses_shared_book(), "uniform CodeGEMM shards share the book");
        assert_eq!(sharded.gemv(&x), serial.gemv(&x));
        // Gather work is per-row, so merged lookups match the serial run.
        assert_eq!(sharded.counters().lookups, serial.counters().lookups);
        assert_eq!(sharded.counters().read_ops, serial.counters().read_ops);
        // Build once per k-tile (serial tile_h covers all rows here, so
        // its build count is the shared schedule's).
        assert_eq!(sharded.counters().build_ops, serial.counters().build_ops);
    }

    #[test]
    fn private_book_schedule_still_available_and_bit_exact() {
        let (n, k) = (32, 64);
        let w = Prng::seeded(11).normal_vec(n * k, 0.02);
        let q = Quantizer::new(QuantConfig::parse_label("m1v4g32").unwrap()).quantize(&w, n, k);
        let x = Prng::seeded(12).normal_vec(k * 2, 1.0);
        let mut serial = CodeGemmEngine::from_quantized(&q);
        let plan = ShardPlan::new(n, 4, 1, 1);
        let mk = |shared: bool| {
            ShardedEngine::from_factory(plan.clone(), pool(), |(r0, r1)| {
                CodeGemmEngine::from_quantized(&shard::slice_rows(&q, r0, r1))
            })
            .with_shared_book(shared)
        };
        let mut private = mk(false);
        let mut shared = mk(true);
        assert!(!private.uses_shared_book());
        assert!(shared.uses_shared_book());
        let y_ref = serial.gemm(&x, 2);
        assert_eq!(private.gemm(&x, 2), y_ref);
        assert_eq!(shared.gemm(&x, 2), y_ref);
        // Private tables pay the build once per shard; the shared book
        // pays it once per logical call.
        assert_eq!(private.counters().build_ops, 4 * shared.counters().build_ops);
        assert_eq!(private.counters().read_ops, shared.counters().read_ops);
        assert!(
            shared.counters().build_share_ops() < private.counters().build_share_ops(),
            "amortization must shrink the build share"
        );
    }

    #[test]
    fn gemm_into_writes_caller_buffer_without_allocating_outputs() {
        let (n, k) = (24, 32);
        let w = Prng::seeded(5).normal_vec(n * k, 1.0);
        let x = Prng::seeded(6).normal_vec(k * 2, 1.0);
        let plan = ShardPlan::new(n, 3, 1, 1);
        let sharded = ShardedEngine::from_factory(plan, pool(), |(r0, r1)| {
            DenseEngine::new(shard::dense_rows(&w, k, r0, r1), r1 - r0, k)
        });
        let mut scratch = EngineScratch::new();
        // Dirty output buffers must be fully overwritten, for both the
        // sub-slice (mb=1) and staging-scatter (mb>1) paths.
        let mut y1 = vec![f32::NAN; n];
        sharded.gemm_into(&x[..k], 1, &mut y1, &mut scratch);
        assert_eq!(y1, DenseEngine::new(w.clone(), n, k).gemv(&x[..k]));
        let mut y2 = vec![f32::NAN; n * 2];
        sharded.gemm_into(&x, 2, &mut y2, &mut scratch);
        assert_eq!(y2, DenseEngine::new(w.clone(), n, k).gemm(&x, 2));
        // Caller scratch accumulated both logical calls.
        assert_eq!(scratch.counters.calls, 2);
        // Steady state: repeating the same shapes must not grow scratch.
        let fp: usize = scratch.footprint_bytes()
            + scratch.children.iter().map(|c| c.footprint_bytes()).sum::<usize>();
        sharded.gemm_into(&x, 2, &mut y2, &mut scratch);
        let fp2: usize = scratch.footprint_bytes()
            + scratch.children.iter().map(|c| c.footprint_bytes()).sum::<usize>();
        assert_eq!(fp, fp2, "warm scratch must not grow");
    }

    #[test]
    fn single_shard_stays_serial() {
        let (n, k) = (8, 16);
        let w = Prng::seeded(5).normal_vec(n * k, 1.0);
        let x = Prng::seeded(6).normal_vec(k, 1.0);
        let plan = ShardPlan::serial(n);
        let mut sharded = ShardedEngine::from_factory(plan, pool(), |(r0, r1)| {
            DenseEngine::new(shard::dense_rows(&w, k, r0, r1), r1 - r0, k)
        });
        assert_eq!(sharded.num_shards(), 1);
        let y = sharded.gemv(&x);
        assert_eq!(y, DenseEngine::new(w.clone(), n, k).gemv(&x));
    }

    #[test]
    fn counters_reset_recursively() {
        let (n, k) = (16, 16);
        let w = Prng::seeded(7).normal_vec(n * k, 1.0);
        let x = vec![1.0f32; k];
        let plan = ShardPlan::new(n, 2, 1, 1);
        let mut sharded = ShardedEngine::from_factory(plan, pool(), |(r0, r1)| {
            DenseEngine::new(shard::dense_rows(&w, k, r0, r1), r1 - r0, k)
        });
        let _ = sharded.gemv(&x);
        assert!(sharded.counters().mac_flops > 0);
        sharded.reset_counters();
        assert_eq!(sharded.counters().mac_flops, 0);
        assert!(sharded.shards().iter().all(|e| e.counters().mac_flops == 0));
    }
}
