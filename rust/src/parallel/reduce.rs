//! Deterministic reductions for sharded execution.
//!
//! Two combining steps exist in the subsystem:
//!
//! - **Row concatenation** (output/column-parallel sharding): each shard
//!   computed disjoint output rows, so combining is pure placement —
//!   bit-exact by construction.
//! - **Ordered all-reduce** (reduction-dim/row-parallel sharding): each
//!   shard computed a partial sum over its column range; partials are
//!   summed in *shard-index order*, a fixed association that makes the
//!   result reproducible across runs and thread schedules (unlike atomic
//!   or completion-order accumulation).

use super::plan::ShardPlan;
use crate::gemm::Counters;

/// Stitch per-shard row outputs (each batch-major `shard_rows × m_batch`)
/// into the full batch-major `n × m_batch` output, in shard order.
pub fn concat_row_shards(parts: &[Vec<f32>], plan: &ShardPlan, m_batch: usize) -> Vec<f32> {
    assert_eq!(parts.len(), plan.num_shards(), "one output per shard");
    let n = plan.len;
    let mut y = vec![0f32; n * m_batch];
    for (part, &(r0, r1)) in parts.iter().zip(&plan.shards) {
        let ns = r1 - r0;
        assert_eq!(part.len(), ns * m_batch, "shard output shape mismatch");
        for b in 0..m_batch {
            y[b * n + r0..b * n + r1].copy_from_slice(&part[b * ns..(b + 1) * ns]);
        }
    }
    y
}

/// Scatter per-shard batch-major row blocks — stored back-to-back in
/// shard order in `stage` (shard `i` occupies `shard_len(i) * m_batch`
/// entries) — into the full batch-major `n × m_batch` output. The
/// zero-allocation counterpart of [`concat_row_shards`]: workers write
/// contiguous blocks of a reused staging buffer, one pass places them.
pub fn scatter_row_shards(stage: &[f32], plan: &ShardPlan, m_batch: usize, y: &mut [f32]) {
    let n = plan.len;
    assert_eq!(y.len(), n * m_batch, "output shape mismatch");
    let mut off = 0usize;
    for &(r0, r1) in &plan.shards {
        let rows = r1 - r0;
        let part = &stage[off..off + rows * m_batch];
        for b in 0..m_batch {
            y[b * n + r0..b * n + r1].copy_from_slice(&part[b * rows..(b + 1) * rows]);
        }
        off += rows * m_batch;
    }
}

/// Sum `parts.len() / len` equal `len`-sized partials stored back-to-back
/// in `parts` into `y`, in storage order (fixed association — the
/// zero-allocation counterpart of [`ordered_sum`]).
pub fn ordered_sum_into(parts: &[f32], len: usize, y: &mut [f32]) {
    assert!(len > 0 && parts.len() >= len && parts.len() % len == 0, "partial length mismatch");
    assert_eq!(y.len(), len, "output length mismatch");
    y.copy_from_slice(&parts[..len]);
    for part in parts[len..].chunks_exact(len) {
        for (o, p) in y.iter_mut().zip(part) {
            *o += p;
        }
    }
}

/// Sum equal-length partial outputs in slice order (fixed association).
pub fn ordered_sum(parts: &[Vec<f32>]) -> Vec<f32> {
    assert!(!parts.is_empty(), "ordered_sum needs at least one partial");
    let mut out = parts[0].clone();
    for p in &parts[1..] {
        assert_eq!(p.len(), out.len(), "partial length mismatch");
        for (o, x) in out.iter_mut().zip(p) {
            *o += x;
        }
    }
    out
}

/// Merge per-shard counters into one set (order-independent: counters are
/// sums).
pub fn merge_counters<'a>(parts: impl IntoIterator<Item = &'a Counters>) -> Counters {
    let mut total = Counters::new();
    for c in parts {
        total.merge(c);
    }
    // Wall-clock seconds summed across shards over-count elapsed time
    // under true parallelism; they remain useful as total CPU seconds.
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_places_rows_in_shard_order() {
        let plan = ShardPlan::new(5, 2, 1, 1); // (0,3), (3,5)
        let parts = vec![
            vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0], // 3 rows × 2 batch cols
            vec![4.0, 5.0, 40.0, 50.0],            // 2 rows × 2 batch cols
        ];
        let y = concat_row_shards(&parts, &plan, 2);
        assert_eq!(y, vec![1.0, 2.0, 3.0, 4.0, 5.0, 10.0, 20.0, 30.0, 40.0, 50.0]);
    }

    #[test]
    fn ordered_sum_is_fixed_association() {
        let parts = vec![vec![1.0f32, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
        assert_eq!(ordered_sum(&parts), vec![111.0, 222.0]);
        // Same parts, same order ⇒ bitwise identical.
        assert_eq!(ordered_sum(&parts), ordered_sum(&parts));
    }

    #[test]
    fn merge_counters_sums() {
        let a = Counters { mac_flops: 3, lookups: 1, calls: 1, ..Default::default() };
        let b = Counters { mac_flops: 7, lookups: 2, calls: 1, ..Default::default() };
        let t = merge_counters([&a, &b]);
        assert_eq!(t.mac_flops, 10);
        assert_eq!(t.lookups, 3);
        assert_eq!(t.calls, 2);
    }

    #[test]
    #[should_panic(expected = "one output per shard")]
    fn concat_rejects_wrong_part_count() {
        let plan = ShardPlan::new(4, 2, 1, 1);
        let _ = concat_row_shards(&[vec![0.0; 2]], &plan, 1);
    }

    #[test]
    fn scatter_matches_concat() {
        let plan = ShardPlan::new(5, 2, 1, 1); // (0,3), (3,5)
        let parts = vec![
            vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0], // 3 rows × 2 batch cols
            vec![4.0, 5.0, 40.0, 50.0],            // 2 rows × 2 batch cols
        ];
        let stage: Vec<f32> = parts.iter().flatten().copied().collect();
        let mut y = vec![0f32; 10];
        scatter_row_shards(&stage, &plan, 2, &mut y);
        assert_eq!(y, concat_row_shards(&parts, &plan, 2));
    }

    #[test]
    fn ordered_sum_into_matches_ordered_sum() {
        let parts = vec![vec![1.0f32, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
        let flat: Vec<f32> = parts.iter().flatten().copied().collect();
        let mut y = vec![0f32; 2];
        ordered_sum_into(&flat, 2, &mut y);
        assert_eq!(y, ordered_sum(&parts));
    }
}
