//! Shared fan-out scaffolding for the sharded / tensor-parallel
//! wrappers: hand each worker a disjoint region of the caller's output
//! buffer (or a block of reused staging for batched calls) plus its own
//! child [`EngineScratch`], then fold the per-shard counters back as one
//! logical GEMM call. Keeping this in one place means the sub-slice
//! split, stage+scatter and counter-merge logic cannot drift between
//! `ShardedEngine` and `TpLinear`.

use super::plan::ShardPlan;
use super::reduce;
use crate::gemm::scratch::grow_slice;
use crate::gemm::{Counters, EngineScratch, GemmEngine};
use crate::util::threadpool::{ScopedJob, ThreadPool};

/// A shard engine viewed dynamically, shareable across worker threads.
pub(crate) type ShardRef<'a> = &'a (dyn GemmEngine + Send + Sync);

/// Column-parallel fan-out: `engines[i]` computes output rows
/// `plan.range(i)` over the full activation `x`. On the single-column
/// (decode) path every worker writes a true sub-slice of `y`; batched
/// calls stage per-shard blocks in the reused `buf2` and scatter once.
/// Both paths are bit-exact vs. the serial engine. `children` must hold
/// exactly one scratch per shard.
pub(crate) fn column_fan_out(
    pool: &ThreadPool,
    engines: &[ShardRef<'_>],
    plan: &ShardPlan,
    x: &[f32],
    m_batch: usize,
    y: &mut [f32],
    buf2: &mut Vec<f32>,
    children: &mut [EngineScratch],
) {
    let ns = plan.num_shards();
    debug_assert_eq!(engines.len(), ns);
    debug_assert_eq!(children.len(), ns);
    if m_batch == 1 {
        let mut jobs: Vec<ScopedJob> = Vec::with_capacity(ns);
        let mut rest: &mut [f32] = &mut *y;
        for ((&e, &(r0, r1)), child) in engines.iter().zip(&plan.shards).zip(children.iter_mut()) {
            let (ys, tail) = std::mem::take(&mut rest).split_at_mut(r1 - r0);
            rest = tail;
            jobs.push(Box::new(move || e.gemm_into(x, 1, ys, child)));
        }
        pool.scope_run(jobs);
    } else {
        let n = plan.len;
        let stage = grow_slice(buf2, n * m_batch);
        let mut jobs: Vec<ScopedJob> = Vec::with_capacity(ns);
        let mut rest: &mut [f32] = &mut *stage;
        for ((&e, &(r0, r1)), child) in engines.iter().zip(&plan.shards).zip(children.iter_mut()) {
            let (ys, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * m_batch);
            rest = tail;
            jobs.push(Box::new(move || e.gemm_into(x, m_batch, ys, child)));
        }
        pool.scope_run(jobs);
        reduce::scatter_row_shards(stage, plan, m_batch, y);
    }
}

/// Fold one fan-out's per-shard counters into the caller's set and clear
/// the children for the next call (one fan-out == one logical GEMM call,
/// not `children.len()`).
pub(crate) fn merge_children_into(counters: &mut Counters, children: &mut [EngineScratch]) {
    let mut step = Counters::new();
    for child in children.iter_mut() {
        step.merge(&child.counters);
        child.counters.reset();
    }
    step.calls = 1;
    counters.merge(&step);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::DenseEngine;
    use crate::parallel::shard;
    use crate::util::prng::Prng;

    #[test]
    fn column_fan_out_matches_serial_both_paths() {
        let (n, k) = (21, 16);
        let w = Prng::seeded(1).normal_vec(n * k, 1.0);
        let x = Prng::seeded(2).normal_vec(k * 2, 1.0);
        let plan = ShardPlan::new(n, 3, 1, 1);
        let shards: Vec<DenseEngine> = plan
            .shards
            .iter()
            .map(|&(r0, r1)| DenseEngine::new(shard::dense_rows(&w, k, r0, r1), r1 - r0, k))
            .collect();
        let refs: Vec<ShardRef> = shards.iter().map(|e| e as ShardRef).collect();
        let pool = ThreadPool::new(3);
        let mut buf2 = Vec::new();
        let mut children = vec![EngineScratch::new(); plan.num_shards()];
        let mut serial = DenseEngine::new(w.clone(), n, k);

        let mut y1 = vec![f32::NAN; n];
        column_fan_out(&pool, &refs, &plan, &x[..k], 1, &mut y1, &mut buf2, &mut children);
        assert_eq!(y1, serial.gemv(&x[..k]));

        let mut y2 = vec![f32::NAN; n * 2];
        column_fan_out(&pool, &refs, &plan, &x, 2, &mut y2, &mut buf2, &mut children);
        assert_eq!(y2, serial.gemm(&x, 2));

        let mut total = Counters::new();
        merge_children_into(&mut total, &mut children);
        // Two fan-outs' worth of shard work folded as... one merge call:
        // callers merge after every fan-out; here both accumulate first.
        assert_eq!(total.mac_flops, serial.counters().mac_flops);
        assert!(children.iter().all(|c| c.counters.mac_flops == 0));
    }
}
