//! Shared fan-out scaffolding for the sharded / tensor-parallel
//! wrappers: hand each worker a disjoint region of the caller's output
//! buffer (or a block of reused staging for batched calls) plus its own
//! child [`EngineScratch`], then fold the per-shard counters back as one
//! logical GEMM call. Keeping this in one place means the sub-slice
//! split, stage+scatter and counter-merge logic cannot drift between
//! `ShardedEngine` and `TpLinear`.
//!
//! ## The shared-Psumbook schedule (build once, gather many)
//!
//! [`column_fan_out`] is the *private-table* schedule: every shard runs
//! its complete engine, building its own Psumbook/LUT in its child
//! scratch — K row shards of a CodeGEMM layer pay K× the build MACs.
//! [`shared_book_fan_out_multi`] is the CodeGEMM specialization the
//! paper's Eq. 3 actually prices, generalized to a **projection group**:
//! per k-tile, **phase 1** builds one shared, scratch-resident Psumbook
//! by fanning disjoint j-ranges of its storage out over the pool
//! ([`psumbook::build_range`]), and **phase 2** fans the gather out over
//! the **shard × member matrix** — every row shard of every fused member
//! projection (Q/K/V, gate/up) reading the book read-only into its
//! disjoint output region. Build MACs/bytes/time are attributed once per
//! logical call — independent of the shard count *and* the member count
//! — so `Counters::build_share_ops` reflects the amortization (and
//! `Counters::group_fanout` records how many member GEMMs each build
//! served); gather work is per-row and folds in from the child scratches
//! as usual. [`shared_book_fan_out`] is the single-member case used by
//! `ShardedEngine`; `gemm::GemmGroup` drives the multi-member form.
//!
//! ## Software-pipelined k-tiles
//!
//! With `KernelConfig::pipeline_tiles` on (the default), the per-tile
//! build barrier disappears from the steady state: tile `t+1`'s book is
//! built **inside the same pool scope** as tile `t`'s shard × member
//! gather, writing the *other* buffer of a double-buffered book pair
//! (`EngineScratch::book` / `book2`, swapped every tile). Only tile 0
//! pays a dedicated build barrier (the prologue); every later build
//! rides the gather barrier, keeping build latency off the critical
//! path — one pipeline stage deep, exactly the overlap the GPU kernel
//! gets from issuing the next tile's table build while warps gather the
//! current one. Outputs are bit-exact either way (each tile's book is
//! built by the same [`crate::gemm::simd::build_range`] calls, only
//! earlier), and build MACs are still counted exactly once per tile at
//! staging time. Timing attribution shifts: `build_seconds` covers the
//! prologue build only, while the overlapped scopes land in
//! `read_seconds` — the split measures the *exposed* (non-overlapped)
//! build cost, which is the pipeline's whole point. With `obs::prof`
//! enabled every build j-range, gather job, staging call and barrier
//! wait is recorded as a tile-tagged span, so the exported Chrome trace
//! shows tile `t+1`'s build running under tile `t`'s gather barrier
//! directly — and [`crate::obs::prof::Timeline::overlap`] turns that
//! into the hidden-vs-exposed build-seconds gauge.
//!
//! Cost model caveat: unlike the private schedule's single rendezvous
//! per call, the shared schedule still synchronizes the pool once per
//! k-tile and boxes fresh scoped jobs for each — the float buffers stay
//! allocation-free after warmup, the job dispatch does not. The
//! build-MAC savings must outweigh that dispatch; the scaling bench's
//! shared-vs-private matrix measures exactly this trade.

use super::plan::ShardPlan;
use super::reduce;
use crate::gemm::psumbook::Psumbook;
use crate::gemm::scratch::grow_slice;
use crate::gemm::simd;
use crate::gemm::tiling::Tiles;
use crate::gemm::{CodeGemmEngine, Counters, EngineScratch, GemmEngine};
use crate::obs::prof;
use crate::util::threadpool::{ScopedJob, ThreadPool};
use crate::util::timer::Timer;

/// A shard engine viewed dynamically, shareable across worker threads.
pub(crate) type ShardRef<'a> = &'a (dyn GemmEngine + Send + Sync);

/// Minimum vectors per worker in the phase-1 parallel book build (below
/// this, job dispatch costs more than the dot products it hides).
const MIN_BUILD_VECS: usize = 4;

/// One member of a fused projection group as the scheduler sees it: its
/// row shards plus the plan that places them. A lone sharded engine is
/// the single-member case; `gemm::GemmGroup` passes one entry per fused
/// projection (Q/K/V, gate/up).
pub(crate) struct GroupMemberRef<'a, E: GemmEngine + Send + Sync> {
    pub engines: &'a [E],
    pub plan: &'a ShardPlan,
}

/// Column-parallel fan-out: `engines[i]` computes output rows
/// `plan.range(i)` over the full activation `x`. On the single-column
/// (decode) path every worker writes a true sub-slice of `y`; batched
/// calls stage per-shard blocks in the reused `buf2` and scatter once.
/// Both paths are bit-exact vs. the serial engine. `children` must hold
/// exactly one scratch per shard.
pub(crate) fn column_fan_out(
    pool: &ThreadPool,
    engines: &[ShardRef<'_>],
    plan: &ShardPlan,
    x: &[f32],
    m_batch: usize,
    y: &mut [f32],
    buf2: &mut Vec<f32>,
    children: &mut [EngineScratch],
) {
    let ns = plan.num_shards();
    debug_assert_eq!(engines.len(), ns);
    debug_assert_eq!(children.len(), ns);
    if m_batch == 1 {
        let mut jobs: Vec<ScopedJob> = Vec::with_capacity(ns);
        let mut rest: &mut [f32] = &mut *y;
        for ((&e, &(r0, r1)), child) in engines.iter().zip(&plan.shards).zip(children.iter_mut()) {
            let (ys, tail) = std::mem::take(&mut rest).split_at_mut(r1 - r0);
            rest = tail;
            jobs.push(Box::new(move || e.gemm_into(x, 1, ys, child)));
        }
        pool.scope_run(jobs);
    } else {
        let n = plan.len;
        let stage = grow_slice(buf2, n * m_batch);
        let mut jobs: Vec<ScopedJob> = Vec::with_capacity(ns);
        let mut rest: &mut [f32] = &mut *stage;
        for ((&e, &(r0, r1)), child) in engines.iter().zip(&plan.shards).zip(children.iter_mut()) {
            let (ys, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * m_batch);
            rest = tail;
            jobs.push(Box::new(move || e.gemm_into(x, m_batch, ys, child)));
        }
        pool.scope_run(jobs);
        reduce::scatter_row_shards(stage, plan, m_batch, y);
    }
}

/// True when `engines` can gather from one shared Psumbook per k-tile:
/// every row shard must be the same quantized format (config **and**
/// codebooks — shards sliced from one layer share them by construction)
/// over the same reduction dim, with the same aligned tile width so the
/// shared k-tiles line up with every shard's gather geometry.
pub(crate) fn shared_book_compatible(engines: &[&CodeGemmEngine]) -> bool {
    let Some(first) = engines.first() else {
        return false;
    };
    let cfg = first.quant_config();
    let tile_w = first.kernel_config().tile_w;
    let k = first.dims().1;
    engines.iter().all(|e| {
        e.quant_config() == cfg
            && e.kernel_config().tile_w == tile_w
            && e.dims().1 == k
            && e.codebooks() == first.codebooks()
    })
}

/// Build-once/gather-many fan-out over row-sharded CodeGEMM engines —
/// the single-member case of [`shared_book_fan_out_multi`].
///
/// For each k-tile: phase 1 builds **one** shared book in the caller's
/// scratch (parallelized by j-ranges over the pool), phase 2 fans the
/// gather out over the row shards reading that book read-only. Outputs
/// are bit-exact vs. the serial engine (gather order per row is
/// unchanged; book entries are identical however the build is split).
/// Build work lands in the caller's counters exactly once per logical
/// call; per-shard gather counters fold in via [`merge_children_into`].
///
/// Generic over the shard type so callers hand their shard slice over
/// directly (no per-call ref collection); every shard must downcast via
/// `as_codegemm` and satisfy [`shared_book_compatible`] — the caller is
/// expected to have verified this once at construction.
pub(crate) fn shared_book_fan_out<E: GemmEngine + Send + Sync>(
    pool: &ThreadPool,
    engines: &[E],
    plan: &ShardPlan,
    x: &[f32],
    m_batch: usize,
    y: &mut [f32],
    scratch: &mut EngineScratch,
) {
    shared_book_fan_out_multi(
        pool,
        &[GroupMemberRef { engines, plan }],
        x,
        m_batch,
        &mut [y],
        scratch,
    );
}

/// Build-once/gather-many fan-out over a **projection group**: several
/// members (row-sharded CodeGEMM engine sets over the *same* activations
/// and codebooks — Q/K/V of one layer, gate/up of one MLP) execute as a
/// single logical call. Per k-tile, phase 1 builds ONE shared book in
/// the caller's scratch (fanned out by j-ranges over the pool), phase 2
/// fans the gather out over the full **shard × member matrix**, every
/// job reading the book read-only into its disjoint region of its
/// member's output. The book is thus shared across *both* axes: the row
/// shards within each member (PR 3's amortization) and the member
/// projections themselves (the group amortization — build MACs counted
/// once serve `Σ members` gathers).
///
/// `dests[i]` is member `i`'s batch-major output (`plan.len × m_batch`,
/// fully overwritten). Build MACs/bytes/time land in the caller's
/// counters exactly once per call regardless of shard count *and*
/// member count; per-shard gather counters fold in via
/// [`merge_children_into`] (`calls += 1` for the whole group). Every
/// shard of every member must satisfy [`shared_book_compatible`] —
/// callers verify once at construction.
pub(crate) fn shared_book_fan_out_multi<E: GemmEngine + Send + Sync>(
    pool: &ThreadPool,
    members: &[GroupMemberRef<'_, E>],
    x: &[f32],
    m_batch: usize,
    dests: &mut [&mut [f32]],
    scratch: &mut EngineScratch,
) {
    debug_assert_eq!(members.len(), dests.len());
    debug_assert!(shared_book_compatible(
        &members
            .iter()
            .flat_map(|m| m.engines.iter())
            .map(|e| e.as_codegemm().expect("codegemm shard"))
            .collect::<Vec<_>>()
    ));
    let total_shards: usize = members.iter().map(|m| m.engines.len()).sum();
    let EngineScratch { counters, buf, buf2, book, book2, children } = scratch;
    if children.len() < total_shards {
        children.resize_with(total_shards, EngineScratch::new);
    }
    let children = &mut children[..total_shards];
    if m_batch == 1 {
        // Decode path: every (member, shard) job writes a true sub-slice
        // of its member's caller-owned output.
        let mut blocks: Vec<&mut [f32]> = dests.iter_mut().map(|d| &mut **d).collect();
        shared_book_tiles(pool, members, x, 1, &mut blocks, buf, book, book2, children, counters);
    } else {
        // Batched path: stage per-member blocks back-to-back in reused
        // staging and scatter each member once at the end.
        let total_rows: usize = members.iter().map(|m| m.plan.len).sum();
        let stage = grow_slice(buf2, total_rows * m_batch);
        let mut blocks: Vec<&mut [f32]> = Vec::with_capacity(members.len());
        let mut rest: &mut [f32] = stage;
        for member in members {
            let (block, tail) = std::mem::take(&mut rest).split_at_mut(member.plan.len * m_batch);
            blocks.push(block);
            rest = tail;
        }
        shared_book_tiles(
            pool, members, x, m_batch, &mut blocks, buf, book, book2, children, counters,
        );
        for ((member, block), dest) in members.iter().zip(&blocks).zip(dests.iter_mut()) {
            reduce::scatter_row_shards(&**block, member.plan, m_batch, dest);
        }
    }
    // Per-row group scales stream once per logical call (row partitioning
    // conserves this stream exactly; each member streams its own rows').
    // Read during gather's scale application ⇒ also the read side of the
    // roofline byte split.
    let scales_bytes = members
        .iter()
        .flat_map(|m| m.engines.iter())
        .map(|e| e.as_codegemm().expect("codegemm shard").scales_stream_bytes())
        .sum::<u64>();
    counters.weight_bytes += scales_bytes;
    counters.read_bytes += scales_bytes;
    merge_children_into(counters, children);
}

/// Append one scoped job per j-range of the phase-1 parallel book build
/// (a single job when the tile is too narrow to split — still a win
/// under the pipeline, where it overlaps the previous tile's gather).
/// `book` must already be reshaped for the tile (via `prepare_tile`);
/// each job writes its disjoint slice of the book's storage through the
/// engine's resolved SIMD build kernel. `tile` tags the profiler spans
/// with the k-tile index so the trace shows *which* tile's build ran
/// under which tile's gather.
fn append_build_jobs<'env>(
    jobs: &mut Vec<ScopedJob<'env>>,
    pool_size: usize,
    e0: &'env CodeGemmEngine,
    x_tile: &'env [f32],
    book: &'env mut Psumbook,
    tile: u32,
) {
    let (jn_tile, m, nc, mb) = (book.jn, book.m, book.nc, book.mb);
    let v = e0.quant_config().v;
    let sel = e0.kernel_sel();
    let codebooks = e0.codebooks();
    let build_plan = ShardPlan::new(jn_tile, pool_size, MIN_BUILD_VECS, 1);
    let stride = m * nc * mb;
    let mut rest: &mut [f32] = book.data.as_mut_slice();
    for &(j0, j1) in &build_plan.shards {
        let (chunk, tail) = std::mem::take(&mut rest).split_at_mut((j1 - j0) * stride);
        rest = tail;
        jobs.push(Box::new(move || {
            prof::with_span(prof::Label::Build, tile, || {
                simd::build_range(sel, codebooks, v, x_tile, jn_tile, m, nc, mb, j0, j1, chunk);
            });
        }));
    }
}

/// Append the phase-2 shard × member gather jobs for the k-tile starting
/// at column `c0`, each reading `book` read-only into its disjoint block
/// of its member's dest and counting into its own child scratch. `tile`
/// tags the profiler spans with the k-tile index.
#[allow(clippy::too_many_arguments)]
fn append_gather_jobs<'env, 'b, E: GemmEngine + Send + Sync>(
    jobs: &mut Vec<ScopedJob<'env>>,
    members: &'env [GroupMemberRef<'env, E>],
    book: &'env Psumbook,
    c0: usize,
    m_batch: usize,
    dest_blocks: &'env mut [&'b mut [f32]],
    children: &'env mut [EngineScratch],
    tile: u32,
) {
    let mut child_iter = children.iter_mut();
    for (member, block) in members.iter().zip(dest_blocks.iter_mut()) {
        let mut rest: &mut [f32] = &mut **block;
        for (e, &(r0, r1)) in member.engines.iter().zip(&member.plan.shards) {
            let child = child_iter.next().expect("one child scratch per shard");
            let e = e.as_codegemm().expect("codegemm shard");
            let (ys, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * m_batch);
            rest = tail;
            let gather_counters = &mut child.counters;
            jobs.push(Box::new(move || {
                prof::with_span(prof::Label::Gather, tile, || {
                    e.gather_into(book, c0, m_batch, ys, gather_counters)
                })
            }));
        }
    }
}

/// The per-k-tile two-phase loop of [`shared_book_fan_out_multi`].
/// `dest_blocks[i]` holds member `i`'s per-shard output blocks
/// back-to-back in shard order (`shard_len(s) * m_batch` each) — the
/// caller's own output slices on the single-column path, reused staging
/// otherwise.
///
/// With `pipeline_tiles` on and more than one tile, the loop runs
/// software-pipelined: tile 0's build is the prologue barrier, then each
/// pool scope runs tile `t`'s gathers *and* tile `t+1`'s build jobs
/// together, the build writing the spare book (`book2`) while the
/// gathers read the current one; the two swap roles every tile. Build
/// work is attributed once per tile at staging time either way, so
/// counters are schedule-independent; `build_seconds` holds only the
/// exposed (prologue) build time under the pipeline, the overlapped
/// scopes landing in `read_seconds`.
#[allow(clippy::too_many_arguments)]
fn shared_book_tiles<E: GemmEngine + Send + Sync>(
    pool: &ThreadPool,
    members: &[GroupMemberRef<'_, E>],
    x: &[f32],
    m_batch: usize,
    dest_blocks: &mut [&mut [f32]],
    buf: &mut Vec<f32>,
    book: &mut Psumbook,
    book2: &mut Psumbook,
    children: &mut [EngineScratch],
    counters: &mut Counters,
) {
    let e0 = members[0].engines[0].as_codegemm().expect("codegemm shard");
    let k = e0.dims().1;
    let tile_w = e0.kernel_config().tile_w;
    // Gathers accumulate across k-tiles: zero once up front.
    for (member, block) in members.iter().zip(dest_blocks.iter_mut()) {
        debug_assert_eq!(block.len(), member.plan.len * m_batch);
        block.fill(0.0);
    }
    let total_shards: usize = members.iter().map(|m| m.engines.len()).sum();
    debug_assert_eq!(children.len(), total_shards);
    let tiles: Vec<(usize, usize)> = Tiles::new(k, tile_w).collect();
    let pipelined = e0.kernel_config().pipeline_tiles && tiles.len() > 1;

    if !pipelined {
        for (ti, &(c0, c1)) in tiles.iter().enumerate() {
            let ti = ti as u32;
            // Phase 1: build one shared book for this k-tile, fanned out
            // by j-ranges (disjoint slices of the book's storage).
            let t = Timer::start();
            let ts = prof::begin();
            let x_tile: &[f32] = e0.prepare_tile(x, m_batch, c0, c1, book, buf);
            prof::record_since(prof::Label::Stage, ti, ts);
            // Build work is attributed ONCE per logical call, independent
            // of the shard count and the member count — the amortization
            // `build_share_*` / `group_fanout` price. `count_build` is
            // the same accounting the serial engine uses, so the shared-
            // vs-private and fused-vs-independent comparisons cannot
            // drift.
            e0.count_build(book, counters);
            let mut jobs: Vec<ScopedJob> = Vec::new();
            append_build_jobs(&mut jobs, pool.size(), e0, x_tile, book, ti);
            let tb = prof::begin();
            pool.scope_run(jobs);
            prof::record_since(prof::Label::Barrier, ti, tb);
            counters.build_seconds += t.elapsed_s();

            // Phase 2: the shard × member matrix gathers read-only from
            // the shared book, each job into its disjoint block of its
            // member's dest.
            let t = Timer::start();
            let mut jobs: Vec<ScopedJob> = Vec::with_capacity(total_shards);
            append_gather_jobs(&mut jobs, members, book, c0, m_batch, dest_blocks, children, ti);
            let tb = prof::begin();
            pool.scope_run(jobs);
            prof::record_since(prof::Label::Barrier, ti, tb);
            counters.read_seconds += t.elapsed_s();
        }
        return;
    }

    // Pipelined schedule. Prologue: tile 0's build is the only exposed
    // build barrier.
    {
        let (c0, c1) = tiles[0];
        let t = Timer::start();
        let ts = prof::begin();
        let x_tile: &[f32] = e0.prepare_tile(x, m_batch, c0, c1, book, buf);
        prof::record_since(prof::Label::Stage, 0, ts);
        e0.count_build(book, counters);
        let mut jobs: Vec<ScopedJob> = Vec::new();
        append_build_jobs(&mut jobs, pool.size(), e0, x_tile, book, 0);
        let tb = prof::begin();
        pool.scope_run(jobs);
        prof::record_since(prof::Label::Barrier, 0, tb);
        counters.build_seconds += t.elapsed_s();
    }
    // Steady state: one scope per tile runs tile t's gathers against
    // `cur` together with tile t+1's build into `nxt`. The scope's
    // barrier makes the freshly built book safe to gather from next
    // iteration, when the buffers swap. The single staging `buf` is safe
    // to re-stage each iteration: tile t's activations were only read by
    // its *build*, which completed at the previous barrier — gathers
    // read the book, never the staging.
    let mut cur: &mut Psumbook = book;
    let mut nxt: &mut Psumbook = book2;
    for ti in 0..tiles.len() {
        let (c0, _) = tiles[ti];
        let t = Timer::start();
        let mut jobs: Vec<ScopedJob> = Vec::with_capacity(total_shards + pool.size());
        append_gather_jobs(&mut jobs, members, &*cur, c0, m_batch, dest_blocks, children, ti as u32);
        if let Some(&(n0, n1)) = tiles.get(ti + 1) {
            let ts = prof::begin();
            let x_next: &[f32] = e0.prepare_tile(x, m_batch, n0, n1, nxt, buf);
            prof::record_since(prof::Label::Stage, (ti + 1) as u32, ts);
            e0.count_build(nxt, counters);
            append_build_jobs(&mut jobs, pool.size(), e0, x_next, &mut *nxt, (ti + 1) as u32);
        }
        let tb = prof::begin();
        pool.scope_run(jobs);
        prof::record_since(prof::Label::Barrier, ti as u32, tb);
        counters.read_seconds += t.elapsed_s();
        std::mem::swap(&mut cur, &mut nxt);
    }
}

/// Fold one fan-out's per-shard counters into the caller's set and clear
/// the children for the next call (one fan-out == one logical GEMM call,
/// not `children.len()`).
pub(crate) fn merge_children_into(counters: &mut Counters, children: &mut [EngineScratch]) {
    let mut step = Counters::new();
    for child in children.iter_mut() {
        step.merge(&child.counters);
        child.counters.reset();
    }
    step.calls = 1;
    counters.merge(&step);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantConfig;
    use crate::gemm::DenseEngine;
    use crate::parallel::shard;
    use crate::quant::Quantizer;
    use crate::util::prng::Prng;

    #[test]
    fn column_fan_out_matches_serial_both_paths() {
        let (n, k) = (21, 16);
        let w = Prng::seeded(1).normal_vec(n * k, 1.0);
        let x = Prng::seeded(2).normal_vec(k * 2, 1.0);
        let plan = ShardPlan::new(n, 3, 1, 1);
        let shards: Vec<DenseEngine> = plan
            .shards
            .iter()
            .map(|&(r0, r1)| DenseEngine::new(shard::dense_rows(&w, k, r0, r1), r1 - r0, k))
            .collect();
        let refs: Vec<ShardRef> = shards.iter().map(|e| e as ShardRef).collect();
        let pool = ThreadPool::new(3);
        let mut buf2 = Vec::new();
        let mut children = vec![EngineScratch::new(); plan.num_shards()];
        let mut serial = DenseEngine::new(w.clone(), n, k);

        let mut y1 = vec![f32::NAN; n];
        column_fan_out(&pool, &refs, &plan, &x[..k], 1, &mut y1, &mut buf2, &mut children);
        assert_eq!(y1, serial.gemv(&x[..k]));

        let mut y2 = vec![f32::NAN; n * 2];
        column_fan_out(&pool, &refs, &plan, &x, 2, &mut y2, &mut buf2, &mut children);
        assert_eq!(y2, serial.gemm(&x, 2));

        let mut total = Counters::new();
        merge_children_into(&mut total, &mut children);
        // Two fan-outs' worth of shard work folded as... one merge call:
        // callers merge after every fan-out; here both accumulate first.
        assert_eq!(total.mac_flops, serial.counters().mac_flops);
        assert!(children.iter().all(|c| c.counters.mac_flops == 0));
    }

    #[test]
    fn shared_book_fan_out_is_bit_exact_and_counts_build_once() {
        let (n, k) = (24, 128);
        let w = Prng::seeded(3).normal_vec(n * k, 0.02);
        let q = Quantizer::new(QuantConfig::parse_label("m2v8g32").unwrap()).quantize(&w, n, k);
        let plan = ShardPlan::new(n, 3, 1, 1);
        let shards: Vec<CodeGemmEngine> = plan
            .shards
            .iter()
            .map(|&(r0, r1)| CodeGemmEngine::from_quantized(&shard::slice_rows(&q, r0, r1)))
            .collect();
        let refs: Vec<&CodeGemmEngine> = shards.iter().collect();
        assert!(shared_book_compatible(&refs));
        let pool = ThreadPool::new(3);
        let mut serial = CodeGemmEngine::from_quantized(&q);
        for mb in [1usize, 2] {
            let x = Prng::seeded(4 + mb as u64).normal_vec(k * mb, 1.0);
            let mut scratch = EngineScratch::new();
            let mut y = vec![f32::NAN; n * mb];
            shared_book_fan_out(&pool, &shards, &plan, &x, mb, &mut y, &mut scratch);
            serial.reset_counters();
            assert_eq!(y, serial.gemm(&x, mb), "mb={mb}");
            // One build per k-tile per logical call — the serial engine
            // (tile_h >= n here) costs exactly the same build MACs, while
            // the private-book schedule would cost 3x.
            assert_eq!(scratch.counters.build_ops, serial.counters().build_ops);
            assert_eq!(scratch.counters.read_ops, serial.counters().read_ops);
            assert_eq!(scratch.counters.lookups, serial.counters().lookups);
            assert_eq!(scratch.counters.calls, 1);
        }
    }

    #[test]
    fn shared_book_compatibility_rejects_mismatched_tiles() {
        let (n, k) = (16, 64);
        let w = Prng::seeded(5).normal_vec(n * k, 0.02);
        let q = Quantizer::new(QuantConfig::parse_label("m1v8g32").unwrap()).quantize(&w, n, k);
        let a = CodeGemmEngine::with_kernel(
            &shard::slice_rows(&q, 0, 8),
            crate::config::KernelConfig { tile_w: 32, tile_h: 8, ..Default::default() },
        );
        let b = CodeGemmEngine::with_kernel(
            &shard::slice_rows(&q, 8, 16),
            crate::config::KernelConfig { tile_w: 16, tile_h: 8, ..Default::default() },
        );
        assert!(shared_book_compatible(&[&a, &a]));
        assert!(!shared_book_compatible(&[&a, &b]), "mismatched tile_w must not share");
        assert!(!shared_book_compatible(&[]));
    }
}
