//! Sharded multi-threaded execution for GEMM engines and the Llama
//! forward pass (the L3 parallel subsystem).
//!
//! The paper's kernels win by partitioning table-lookup GEMM across
//! parallel workers with *per-partition scratch* — thread-block-local
//! Psumbooks on the GPU. This module is the CPU analogue, layered on
//! [`crate::util::threadpool::ThreadPool`]:
//!
//! - [`plan::ShardPlan`] — deterministic, alignment-aware partition of a
//!   weight matrix axis into contiguous shards.
//! - [`shard`] — carve row/column shards out of quantized or dense
//!   layers *after* quantization, so shard data is byte-identical to the
//!   serial layer's rows.
//! - [`sharded_engine::ShardedEngine`] — any [`crate::gemm::GemmEngine`]
//!   row-sharded over the pool; each shard owns its Psumbook/LUT/decode
//!   scratch; outputs concatenate in shard order and are **bit-exact**
//!   vs. serial.
//! - [`tensor_parallel::TpLinear`] — Megatron-style column-parallel
//!   (Q/K/V, gate/up, LM head) and row-parallel (O, down) linears; the
//!   row-parallel k-sum uses the deterministic ordered all-reduce of
//!   [`reduce`].
//! - [`reduce`] — shard-order concatenation, ordered all-reduce, and
//!   counter merging.
//!
//! Model- and serving-level entry points:
//! [`crate::model::LlamaModel::load_parallel`] builds a tensor-parallel
//! model from any [`crate::model::EngineKind`];
//! [`crate::coordinator::NativeBackend::new_parallel`] serves it, so
//! every batcher step fans each linear out across the pool. Configured by
//! [`crate::config::ParallelConfig`].

pub mod plan;
pub mod reduce;
pub mod shard;
pub mod sharded_engine;
pub mod tensor_parallel;

pub use plan::ShardPlan;
pub use sharded_engine::ShardedEngine;
pub use tensor_parallel::{TpLinear, TpMode};
