//! Sharded multi-threaded execution for GEMM engines and the Llama
//! forward pass (the L3 parallel subsystem).
//!
//! The paper's kernels win by partitioning table-lookup GEMM across
//! parallel workers with *per-partition scratch* — thread-block-local
//! Psumbooks on the GPU. This module is the CPU analogue, layered on
//! [`crate::util::threadpool::ThreadPool`]:
//!
//! - [`plan::ShardPlan`] — deterministic, alignment-aware partition of a
//!   weight matrix axis into contiguous shards.
//! - [`shard`] — carve row/column shards out of quantized or dense
//!   layers *after* quantization, so shard data is byte-identical to the
//!   serial layer's rows.
//! - [`sharded_engine::ShardedEngine`] — any [`crate::gemm::GemmEngine`]
//!   row-sharded over the pool via the `&self` `gemm_into` core: workers
//!   share the engines read-only, each writing a disjoint sub-slice of
//!   the caller's output buffer with its own per-worker
//!   [`crate::gemm::EngineScratch`] (Psumbook/LUT/decode scratch);
//!   **bit-exact** vs. serial and allocation-free after warmup.
//! - [`tensor_parallel::TpLinear`] — Megatron-style column-parallel
//!   (Q/K/V, gate/up, LM head) and row-parallel (O, down) linears; the
//!   row-parallel k-sum uses the deterministic ordered all-reduce of
//!   [`reduce`].
//! - [`reduce`] — shard-order scatter/concatenation, ordered all-reduce
//!   (in-place and allocating variants), and counter merging.
//!
//! Model- and serving-level entry points:
//! [`crate::model::LlamaModel::load_parallel`] builds a tensor-parallel
//! model from any [`crate::model::EngineKind`];
//! [`crate::coordinator::NativeBackend::new_parallel`] serves it, so
//! every batcher step fans each linear out across the pool. Configured by
//! [`crate::config::ParallelConfig`].

pub(crate) mod fanout;
pub mod plan;
pub mod reduce;
pub mod shard;
pub mod sharded_engine;
pub mod tensor_parallel;

pub use plan::ShardPlan;
pub use sharded_engine::ShardedEngine;
pub use tensor_parallel::{TpLinear, TpMode};
