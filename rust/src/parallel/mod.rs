//! Sharded multi-threaded execution for GEMM engines and the Llama
//! forward pass (the L3 parallel subsystem).
//!
//! The paper's kernels win by partitioning table-lookup GEMM across
//! parallel workers. This module is the CPU analogue, layered on
//! [`crate::util::threadpool::ThreadPool`], with **two table schedules**:
//!
//! - *Private tables* (the generic path): every shard's engine builds
//!   its own Psumbook/LUT in its per-worker scratch — the GPU's
//!   thread-block-local tables. Correct for any engine, but a K-way
//!   sharded CodeGEMM layer pays K× the Psumbook build MACs.
//! - *One shared Psumbook* (the CodeGEMM specialization): the book for a
//!   k-tile depends only on the activations, never on the rows reading
//!   it, so `fanout` builds it **once per (k-tile, batch)** in the
//!   caller's scratch — phase 1 fans disjoint j-ranges of the build out
//!   over the pool ([`crate::gemm::psumbook::build_range`]), phase 2
//!   fans the gather out over the row shards reading the book read-only.
//!   Build MACs are attributed once per logical call regardless of shard
//!   count (the Eq. 3 amortization `Counters::build_share_ops`
//!   measures), outputs stay bit-exact, and the build itself scales with
//!   the pool instead of being duplicated across it.
//!
//! The shared book generalizes across a second axis: a **fused
//! projection group** (`crate::gemm::GemmGroup` — a layer's Q/K/V or
//! gate/up over one activation, quantized jointly so members share
//! codebooks) hands `fanout::shared_book_fan_out_multi` one member per
//! projection, and phase 2 becomes the full **shard × member gather
//! matrix** reading the single build. One build then serves every row
//! of every projection of the layer — build MACs per decode layer drop
//! ~3× (attention) / ~2× (MLP) on top of the shard amortization, with
//! `Counters::group_fanout` recording the members each build served.
//!
//! Pieces:
//!
//! - [`plan::ShardPlan`] — deterministic, alignment-aware partition of a
//!   weight matrix axis into contiguous shards; `ShardPlan::tiled`
//!   aligns row-shard boundaries to an engine's row-block height when
//!   that costs no parallelism, keeping private-schedule build counts
//!   congruent with the serial engine's blocking.
//! - [`shard`] — carve row/column shards out of quantized or dense
//!   layers *after* quantization, so shard data is byte-identical to the
//!   serial layer's rows.
//! - [`sharded_engine::ShardedEngine`] — any [`crate::gemm::GemmEngine`]
//!   row-sharded over the pool via the `&self` `gemm_into` core: workers
//!   share the engines read-only, each writing a disjoint sub-slice of
//!   the caller's output buffer with its own per-worker
//!   [`crate::gemm::EngineScratch`]; **bit-exact** vs. serial, with all
//!   scratch buffers grow-only after warmup (job dispatch still boxes
//!   closures — per call on the private schedule, per k-tile on the
//!   shared one). Uniform CodeGEMM shards (detected via
//!   `GemmEngine::as_codegemm` + matching tile geometry) take the
//!   shared-book schedule by default; `with_shared_book(false)` keeps
//!   the private schedule measurable.
//! - [`tensor_parallel::TpLinear`] — Megatron-style column-parallel
//!   (Q/K/V, gate/up, LM head) and row-parallel (O, down) linears; the
//!   row-parallel k-sum uses the deterministic ordered all-reduce of
//!   [`reduce`]. (Row-parallel shards see different activation slices,
//!   so there is no book to share across them.)
//! - [`reduce`] — shard-order scatter/concatenation, ordered all-reduce
//!   (in-place and allocating variants), and counter merging.
//!
//! Model- and serving-level entry points:
//! [`crate::model::LlamaModel::load_parallel`] builds a tensor-parallel
//! model from any [`crate::model::EngineKind`];
//! [`crate::coordinator::NativeBackend::new_parallel`] serves it, so
//! every batcher step fans each linear out across the pool. Configured by
//! [`crate::config::ParallelConfig`] (`shared_psumbook` selects the
//! schedule).

pub(crate) mod fanout;
pub mod plan;
pub mod reduce;
pub mod shard;
pub mod sharded_engine;
pub mod tensor_parallel;

pub use plan::ShardPlan;
pub use sharded_engine::ShardedEngine;
pub use tensor_parallel::{TpLinear, TpMode};
