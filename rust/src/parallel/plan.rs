//! Deterministic shard ownership: split one axis of a weight matrix into
//! contiguous half-open ranges, one per worker.
//!
//! The plan is pure data — the same `(len, max_shards, min_len, align)`
//! inputs always produce the same ranges, so shard ownership (and
//! therefore reduction order and output placement) is reproducible across
//! runs and thread schedules.

/// A partition of `[0, len)` into contiguous shards.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Total extent being partitioned (rows for output sharding, columns
    /// for reduction-dim sharding).
    pub len: usize,
    /// Half-open `(start, end)` ranges, ascending, disjoint, covering
    /// `[0, len)` exactly.
    pub shards: Vec<(usize, usize)>,
}

impl ShardPlan {
    /// Split `len` into at most `max_shards` shards of at least `min_len`
    /// each. Every shard boundary except the final `len` is a multiple of
    /// `align` (pass the vector length `v` or the normalization group `g`
    /// when sharding the reduction dim of a quantized layer; `1` for row
    /// sharding). When `len` is not a multiple of `align` the ragged tail
    /// is attached to the last shard.
    pub fn new(len: usize, max_shards: usize, min_len: usize, align: usize) -> ShardPlan {
        if len == 0 {
            return ShardPlan { len, shards: Vec::new() };
        }
        let align = align.max(1);
        let units = len / align;
        if units == 0 {
            // Smaller than one aligned unit: a single shard owns it all.
            return ShardPlan { len, shards: vec![(0, len)] };
        }
        let min_units = min_len.max(1).div_ceil(align).max(1);
        let want = max_shards.max(1).min((units / min_units).max(1));
        let base = units / want;
        let extra = units % want;
        let mut shards = Vec::with_capacity(want);
        let mut start = 0usize;
        for s in 0..want {
            let take = (base + usize::from(s < extra)) * align;
            let end = if s + 1 == want { len } else { start + take };
            shards.push((start, end));
            start = end;
        }
        debug_assert_eq!(start, len);
        ShardPlan { len, shards }
    }

    /// The trivial single-shard plan (serial execution).
    pub fn serial(len: usize) -> ShardPlan {
        ShardPlan::new(len, 1, 1, 1)
    }

    /// Row plan aligned to a tiled engine's row-block height whenever
    /// that alignment is *free*: shard boundaries land on `tile`
    /// multiples so every shard's row-blocks coincide with the serial
    /// engine's blocking. For CodeGEMM under the *private*
    /// per-shard-Psumbook schedule this keeps the total build count
    /// equal to the serial engine's (a shard straddling a row-block
    /// boundary splits one block into two and pays an extra build per
    /// k-tile). Alignment never costs parallelism: when the aligned
    /// partition would produce fewer shards than a unit-aligned one
    /// (extent smaller than `max_shards` full blocks), the unit plan
    /// wins — gather parallelism dominates the build overhead it trades
    /// away, and the shared-book schedule makes the build count
    /// independent of shard boundaries regardless.
    pub fn tiled(len: usize, max_shards: usize, min_len: usize, tile: usize) -> ShardPlan {
        let tile = tile.max(1);
        let unit = ShardPlan::new(len, max_shards, min_len, 1);
        if tile > 1 {
            let aligned = ShardPlan::new(len, max_shards, min_len, tile);
            if aligned.num_shards() >= unit.num_shards() {
                return aligned;
            }
        }
        unit
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// `(start, end)` of shard `i`.
    pub fn range(&self, i: usize) -> (usize, usize) {
        self.shards[i]
    }

    /// Length of shard `i`.
    pub fn shard_len(&self, i: usize) -> usize {
        let (a, b) = self.shards[i];
        b - a
    }

    /// True when the plan degenerates to serial execution.
    pub fn is_serial(&self) -> bool {
        self.shards.len() <= 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_cover(p: &ShardPlan) {
        let mut pos = 0usize;
        for &(a, b) in &p.shards {
            assert_eq!(a, pos, "shards must be contiguous");
            assert!(b > a, "shards must be non-empty");
            pos = b;
        }
        assert_eq!(pos, p.len, "shards must cover [0, len)");
    }

    #[test]
    fn even_split() {
        let p = ShardPlan::new(64, 4, 1, 1);
        assert_eq!(p.shards, vec![(0, 16), (16, 32), (32, 48), (48, 64)]);
        assert_cover(&p);
    }

    #[test]
    fn uneven_split_front_loads_remainder() {
        let p = ShardPlan::new(10, 4, 1, 1);
        assert_eq!(p.shards, vec![(0, 3), (3, 6), (6, 8), (8, 10)]);
        assert_cover(&p);
    }

    #[test]
    fn min_len_caps_shard_count() {
        // 64 rows with min 32 per shard ⇒ at most 2 shards.
        let p = ShardPlan::new(64, 8, 32, 1);
        assert_eq!(p.num_shards(), 2);
        assert_cover(&p);
        // min larger than len ⇒ serial.
        assert!(ShardPlan::new(16, 8, 64, 1).is_serial());
    }

    #[test]
    fn aligned_boundaries() {
        let p = ShardPlan::new(256, 3, 1, 32);
        assert_cover(&p);
        for &(a, _) in &p.shards {
            assert_eq!(a % 32, 0, "start must be aligned");
        }
        assert_eq!(p.num_shards(), 3);
    }

    #[test]
    fn ragged_tail_goes_to_last_shard() {
        // 352 = 2*128 + 96: boundaries at multiples of 128, tail absorbed.
        let p = ShardPlan::new(352, 2, 1, 128);
        assert_eq!(p.shards, vec![(0, 128), (128, 352)]);
        assert_cover(&p);
    }

    #[test]
    fn smaller_than_one_unit_is_serial() {
        let p = ShardPlan::new(96, 4, 1, 128);
        assert_eq!(p.shards, vec![(0, 96)]);
    }

    #[test]
    fn tiled_aligns_to_blocks_when_free() {
        // The previously-misaligned case: 80 rows, 32-row blocks, 2
        // shards. The unit-aligned plan splits mid-block — (0,40)(40,80)
        // covers 4 partial blocks where the serial engine walks 3 —
        // while the tiled plan lands on a block boundary at no cost in
        // shard count.
        let naive = ShardPlan::new(80, 2, 1, 1);
        assert_eq!(naive.shards, vec![(0, 40), (40, 80)]);
        let p = ShardPlan::tiled(80, 2, 1, 32);
        assert_eq!(p.shards, vec![(0, 32), (32, 80)]);
        // Alignment must never shrink parallelism: 64 rows only hold 2
        // full blocks, so a 3-shard request stays unit-aligned.
        let p = ShardPlan::tiled(64, 3, 1, 32);
        assert_eq!(p.num_shards(), 3);
        // Fewer than one full block likewise.
        let p = ShardPlan::tiled(48, 4, 1, 64);
        assert_eq!(p.num_shards(), 4);
        // Degenerate tile behaves like unit alignment.
        assert_eq!(ShardPlan::tiled(10, 2, 1, 0).num_shards(), 2);
        // Aligned and unit plans agree when the split is already exact.
        assert_eq!(ShardPlan::tiled(128, 4, 1, 32), ShardPlan::new(128, 4, 1, 32));
    }

    #[test]
    fn zero_len() {
        let p = ShardPlan::new(0, 4, 1, 1);
        assert_eq!(p.num_shards(), 0);
        assert_eq!(p.len, 0);
    }

    #[test]
    fn serial_and_accessors() {
        let p = ShardPlan::serial(40);
        assert!(p.is_serial());
        assert_eq!(p.range(0), (0, 40));
        assert_eq!(p.shard_len(0), 40);
    }

    #[test]
    fn deterministic() {
        let a = ShardPlan::new(1000, 7, 16, 8);
        let b = ShardPlan::new(1000, 7, 16, 8);
        assert_eq!(a, b);
        assert_cover(&a);
    }
}
