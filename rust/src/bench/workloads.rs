//! Paper workload geometry.
//!
//! The paper evaluates kernels on the linear-layer shapes of Llama-3
//! 8B/70B decoder blocks (Table 2/9: "sum of kernel execution times for
//! all linear layers in a single Transformer decoder block without layer
//! fusion") and a sweep of raw (M, N, K) GEMM shapes (Table 10).

/// One GEMM: output = W(N×K) · x(K×M). `m_batch` is the paper's M (token
/// batch), `n` the output features, `k` the reduction dim.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmShape {
    pub m_batch: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmShape {
    pub fn new(m_batch: usize, n: usize, k: usize) -> GemmShape {
        GemmShape { m_batch, n, k }
    }

    /// Multiply-accumulate count (2 flops each).
    pub fn flops(&self) -> f64 {
        2.0 * self.m_batch as f64 * self.n as f64 * self.k as f64
    }

    pub fn weight_elems(&self) -> usize {
        self.n * self.k
    }

    pub fn label(&self) -> String {
        format!("M{} N{} K{}", self.m_batch, self.n, self.k)
    }
}

/// Transformer geometry for the models the paper evaluates.
#[derive(Clone, Copy, Debug)]
pub struct LlamaGeometry {
    pub name: &'static str,
    pub hidden: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub ffn: usize,
    pub n_layers: usize,
    pub vocab: usize,
}

/// Llama-3 8B geometry (d=4096, 32 heads / 8 KV heads, ffn 14336).
pub const LLAMA3_8B: LlamaGeometry = LlamaGeometry {
    name: "llama3-8b",
    hidden: 4096,
    n_heads: 32,
    n_kv_heads: 8,
    ffn: 14336,
    n_layers: 32,
    vocab: 128_256,
};

/// Llama-3 70B geometry (d=8192, 64 heads / 8 KV heads, ffn 28672).
pub const LLAMA3_70B: LlamaGeometry = LlamaGeometry {
    name: "llama3-70b",
    hidden: 8192,
    n_heads: 64,
    n_kv_heads: 8,
    ffn: 28672,
    n_layers: 80,
    vocab: 128_256,
};

impl LlamaGeometry {
    pub fn by_name(name: &str) -> Option<LlamaGeometry> {
        match name {
            "llama3-8b" | "8b" | "8B" => Some(LLAMA3_8B),
            "llama3-70b" | "70b" | "70B" => Some(LLAMA3_70B),
            _ => None,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }
}

/// The 7 linear layers of one decoder block, as (label, GemmShape), for a
/// given token batch `m_batch`: q/k/v/o projections + gate/up/down MLP.
pub fn decoder_block_shapes(geom: &LlamaGeometry, m_batch: usize) -> Vec<(&'static str, GemmShape)> {
    let d = geom.hidden;
    let kv = geom.kv_dim();
    let f = geom.ffn;
    vec![
        ("q_proj", GemmShape::new(m_batch, d, d)),
        ("k_proj", GemmShape::new(m_batch, kv, d)),
        ("v_proj", GemmShape::new(m_batch, kv, d)),
        ("o_proj", GemmShape::new(m_batch, d, d)),
        ("gate_proj", GemmShape::new(m_batch, f, d)),
        ("up_proj", GemmShape::new(m_batch, f, d)),
        ("down_proj", GemmShape::new(m_batch, d, f)),
    ]
}

/// Total decoder-block weight elements (for footprint accounting).
pub fn decoder_block_weight_elems(geom: &LlamaGeometry) -> usize {
    decoder_block_shapes(geom, 1).iter().map(|(_, s)| s.weight_elems()).sum()
}

/// The 27 (M, N, K) shapes of the paper's Table 10 sweep.
pub fn table10_shapes() -> Vec<GemmShape> {
    let mnk = [
        (1, 2048, 2048),
        (4, 2048, 2048),
        (8, 2048, 2048),
        (1, 8192, 2048),
        (4, 8192, 2048),
        (8, 8192, 2048),
        (1, 2048, 8192),
        (4, 2048, 8192),
        (8, 2048, 8192),
        (1, 4096, 4096),
        (4, 4096, 4096),
        (8, 4096, 4096),
        (1, 14336, 4096),
        (4, 14336, 4096),
        (8, 14336, 4096),
        (1, 4096, 14336),
        (4, 4096, 14336),
        (8, 4096, 14336),
        (1, 8192, 8192),
        (4, 8192, 8192),
        (8, 8192, 8192),
        (1, 28672, 8192),
        (4, 28672, 8192),
        (8, 28672, 8192),
        (1, 8192, 28672),
        (4, 8192, 28672),
        (8, 8192, 28672),
    ];
    mnk.iter().map(|&(m, n, k)| GemmShape::new(m, n, k)).collect()
}

/// The Table 3 telemetry GEMV shape.
pub fn table3_shape() -> GemmShape {
    GemmShape::new(1, 28672, 8192)
}

/// Scaled-down analogues of the decoder-block shapes for CPU-measurable
/// benches (same aspect ratios, ~1/16 the area). Used where wall-clock
/// measurement on the CPU engines is wanted rather than the simulator.
pub fn scaled_block_shapes(geom: &LlamaGeometry, m_batch: usize, scale: usize) -> Vec<(&'static str, GemmShape)> {
    decoder_block_shapes(geom, m_batch)
        .into_iter()
        .map(|(l, s)| (l, GemmShape::new(s.m_batch, (s.n / scale).max(64), (s.k / scale).max(64))))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_shapes_8b() {
        let shapes = decoder_block_shapes(&LLAMA3_8B, 1);
        assert_eq!(shapes.len(), 7);
        let q = shapes[0].1;
        assert_eq!((q.n, q.k), (4096, 4096));
        let k = shapes[1].1;
        assert_eq!((k.n, k.k), (1024, 4096)); // 8 KV heads * 128
        let down = shapes[6].1;
        assert_eq!((down.n, down.k), (4096, 14336));
    }

    #[test]
    fn block_shapes_70b() {
        let shapes = decoder_block_shapes(&LLAMA3_70B, 1);
        let gate = shapes[4].1;
        assert_eq!((gate.n, gate.k), (28672, 8192));
        assert_eq!(LLAMA3_70B.head_dim(), 128);
        assert_eq!(LLAMA3_70B.kv_dim(), 1024);
    }

    #[test]
    fn table10_has_27_shapes() {
        let shapes = table10_shapes();
        assert_eq!(shapes.len(), 27);
        assert!(shapes.contains(&GemmShape::new(1, 28672, 8192)));
        assert!(shapes.contains(&GemmShape::new(8, 2048, 8192)));
    }

    #[test]
    fn flops_formula() {
        let s = GemmShape::new(2, 3, 4);
        assert_eq!(s.flops(), 48.0);
        assert_eq!(s.weight_elems(), 12);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(LlamaGeometry::by_name("8b").unwrap().hidden, 4096);
        assert_eq!(LlamaGeometry::by_name("llama3-70b").unwrap().ffn, 28672);
        assert!(LlamaGeometry::by_name("13b").is_none());
    }

    #[test]
    fn scaled_shapes_floor() {
        let s = scaled_block_shapes(&LLAMA3_8B, 1, 1_000_000);
        assert!(s.iter().all(|(_, g)| g.n == 64 && g.k == 64));
    }

    #[test]
    fn block_weight_elems_positive() {
        let w8 = decoder_block_weight_elems(&LLAMA3_8B);
        // 2*4096*4096 + 2*1024*4096 + 3*14336*4096
        assert_eq!(w8, 2 * 4096 * 4096 + 2 * 1024 * 4096 + 3 * 14336 * 4096);
    }
}
