//! Regeneration of every table and figure in the paper's evaluation.
//!
//! Latency/telemetry tables come from the calibrated A100 analytic model
//! (`crate::simulator`); accuracy columns come from the tiny trained model
//! evaluated under each quantized engine (`crate::eval`) — trained weights
//! and corpus are loaded from `artifacts/` when present, otherwise the
//! analytically-constructed bigram model on the synthetic corpus is used
//! (the fallback is clearly labelled in the output).
//!
//! Each function returns the rendered table(s); the CLI (`tables`
//! subcommand) and the bench binaries both call through here.

use crate::bench::workloads::{table3_shape, GemmShape, LLAMA3_70B, LLAMA3_8B};
use crate::config::{KernelConfig, ModelConfig, QuantConfig};
use crate::eval::corpus::{Corpus, CorpusSpec};
use crate::eval::sweep::{measure, AccuracyRow};
use crate::model::{EngineKind, ModelWeights};
use crate::quant::calib::TuneLevel;
use crate::quant::footprint::bits_per_weight;
use crate::simulator::methods::Method;
use crate::simulator::paper_data;
use crate::simulator::power::table3_structure_holds;
use crate::simulator::Simulator;
use crate::util::npy::TensorFile;
use crate::util::table::{fnum, Align, Table};
use std::path::Path;

/// Accuracy evaluation context: trained artifacts if present, otherwise
/// the constructed-bigram fallback.
pub struct EvalContext {
    pub weights: ModelWeights,
    pub held_out: Vec<usize>,
    pub source: &'static str,
    /// Tokens to score per measurement (trade speed vs noise).
    pub max_tokens: usize,
}

impl EvalContext {
    /// Load from `artifacts/` or fall back to the bigram construction.
    pub fn load(artifacts: &Path) -> EvalContext {
        match EvalContext::from_artifacts(artifacts) {
            Some(ctx) => ctx,
            None => EvalContext::bigram_fallback(),
        }
    }

    fn from_artifacts(dir: &Path) -> Option<EvalContext> {
        let wf = dir.join("weights.f32.bin");
        let cf = dir.join("corpus.bin");
        if !wf.exists() || !cf.exists() {
            return None;
        }
        let weights = ModelWeights::load(ModelConfig::tiny(), &wf).ok()?;
        let tf = TensorFile::load(&cf).ok()?;
        let tokens: Vec<usize> = tf.get("tokens").ok()?.data.as_i32().ok()?.iter().map(|&t| t as usize).collect();
        let held_out = tokens[tokens.len() / 2..].to_vec();
        Some(EvalContext { weights, held_out, source: "trained tiny model (artifacts/)", max_tokens: 256 })
    }

    pub fn bigram_fallback() -> EvalContext {
        let corpus = Corpus::synthesize(CorpusSpec { vocab: 64, len: 4096, ..Default::default() });
        let weights = ModelWeights::bigram(ModelConfig::tiny(), &corpus.log_probs, 7);
        let (_, held) = corpus.split();
        EvalContext {
            weights,
            held_out: held.to_vec(),
            source: "constructed bigram model (no artifacts — run `make artifacts`)",
            max_tokens: 160,
        }
    }

    pub fn measure(&self, kind: EngineKind) -> AccuracyRow {
        measure(&self.weights, kind, None, &self.held_out, self.max_tokens)
    }
}

fn sim() -> Simulator {
    Simulator::a100()
}

// ---------------------------------------------------------------- Table 1

/// Table 1: average bits per weight per (v, m, b, g).
pub fn table1() -> String {
    let mut t = Table::new(
        "Table 1 — average bits per weight (Eq. 1, 4096×4096 layers)",
        &["v", "m", "b", "g", "q_code", "q_codebook", "q_norm", "q̄ (model)", "q̄ (paper)"],
    );
    let rows: &[(usize, usize, usize, i64, f64)] = &[
        (4, 1, 8, -1, 2.005),
        (8, 2, 8, -1, 2.008),
        (16, 4, 8, -1, 2.020),
        (8, 1, 8, 16, 2.002),
        (16, 3, 8, 32, 2.012),
    ];
    for &(v, m, b, g, paper) in rows {
        let cfg = QuantConfig::new(v, m, b, g).unwrap();
        let f = bits_per_weight(&cfg, 4096, 4096);
        t.row(vec![
            v.to_string(),
            m.to_string(),
            b.to_string(),
            if g < 0 { "-1".into() } else { g.to_string() },
            fnum(f.q_code, 3),
            fnum(f.q_codebook, 3),
            fnum(f.q_norm, 3),
            fnum(f.total, 3),
            fnum(paper, 3),
        ]);
    }
    t.render()
}

// ---------------------------------------------------------------- Table 2

/// Methods of Table 2/9/10, in paper column order.
pub fn table2_methods() -> Vec<Method> {
    vec![
        Method::CuBlas,
        Method::LutGemm { q: 2, g: 128 },
        Method::QuipSharp,
        Method::Qtip,
        Method::aqlm_1x16(),
        Method::aqlm_2x8(),
        Method::codegemm_m2v8g128(),
        Method::codegemm_m1v4g128(),
    ]
}

/// Table 2: decoder-block kernel latency, 8B and 70B, model vs paper.
pub fn table2() -> String {
    let s = sim();
    let mut t = Table::new(
        "Table 2 — decoder-block linear latency (µs), M=1 (model | paper)",
        &["model", "cuBLAS", "LUTGEMM", "QuIP#", "QTIP", "AQLM-1x16", "AQLM-2x8", "CG-m2v8", "CG-m1v4"],
    );
    for (geom, p) in [(LLAMA3_8B, &paper_data::TABLE2[0]), (LLAMA3_70B, &paper_data::TABLE2[1])] {
        let l = |m: &Method| s.block_latency_us(m, &geom, 1);
        let ms = table2_methods();
        let paper = [p.cublas, p.lutgemm, p.quip, p.qtip, p.aqlm_1x16, p.aqlm_2x8, p.codegemm_m2v8, p.codegemm_m1v4];
        let mut cells = vec![p.model.to_string()];
        for (m, pv) in ms.iter().zip(paper) {
            cells.push(format!("{} | {}", fnum(l(m), 1), fnum(pv, 1)));
        }
        t.row(cells);
    }
    t.render()
}

// ---------------------------------------------------------------- Table 3

/// Table 3: GEMV telemetry on (1, 28672, 8192).
pub fn table3() -> String {
    let s = sim();
    let shape = table3_shape();
    let methods = [
        Method::CuBlas,
        Method::aqlm_1x16(),
        Method::aqlm_2x8(),
        Method::codegemm_m2v8g128(),
        Method::codegemm_m1v4g128(),
    ];
    let rows: Vec<_> = methods.iter().map(|m| s.telemetry(m, shape)).collect();
    let mut t = Table::new(
        "Table 3 — GEMV (1, 28672, 8192) telemetry (model | paper)",
        &["Method", "TFLOPS", "Power (W)", "GFLOPS/W", "GPU Util %", "Mem Util %"],
    );
    for (tele, p) in rows.iter().zip(paper_data::TABLE3) {
        t.row(vec![
            tele.method.clone(),
            format!("{} | {}", fnum(tele.tflops, 2), fnum(p.tflops, 2)),
            format!("{} | {}", fnum(tele.power_w, 1), fnum(p.power_w, 1)),
            format!("{} | {}", fnum(tele.gflops_per_w, 2), fnum(p.gflops_per_w, 2)),
            format!("{} | {}", fnum(tele.gpu_util, 1), fnum(p.gpu_util, 1)),
            format!("{} | {}", fnum(tele.mem_util, 1), fnum(p.mem_util, 1)),
        ]);
    }
    let verdict = match table3_structure_holds(&rows) {
        Ok(()) => "qualitative structure HOLDS (orderings + AQLM-1x16 spin signature)".to_string(),
        Err(e) => format!("STRUCTURE VIOLATION: {e}"),
    };
    format!("{}\n  {}\n", t.render(), verdict)
}

// ------------------------------------------------------------ Tables 4/5

/// The accuracy/throughput method grid of Tables 4 and 5.
pub fn table4(ctx: &EvalContext) -> String {
    let s = sim();
    let cfg_m1v4 = tiny_cfg(4, 1, 8);
    let cfg_m2v8 = tiny_cfg(8, 2, 8);
    let aqlm28 = tiny_cfg(8, 2, 8);
    // Methods: (label, engine for accuracy, sim method for tok/s, paper tok/s, paper avg)
    let rows: Vec<(String, Option<EngineKind>, Method, f64, f64)> = vec![
        ("FP16".into(), Some(EngineKind::Dense), Method::CuBlas, 103.8, 71.26),
        (
            "FlexRound-q2g128".into(),
            Some(EngineKind::Uniform { bits: 2, group: 32 }),
            Method::LutGemm { q: 2, g: 128 },
            205.3,
            41.65,
        ),
        (
            "AQLM-2x8".into(),
            Some(EngineKind::Dequant { cfg: aqlm28, tune: TuneLevel::Calibrated }),
            Method::aqlm_2x8(),
            124.5,
            47.82,
        ),
        (
            "AQLM-1x16".into(),
            Some(EngineKind::Dequant { cfg: tiny_cfg(8, 1, 12), tune: TuneLevel::Calibrated }),
            Method::aqlm_1x16(),
            49.0,
            63.57,
        ),
        (
            "CodeGEMM-m1v4".into(),
            Some(EngineKind::CodeGemm { cfg: cfg_m1v4, kernel: KernelConfig::default(), tune: TuneLevel::Calibrated }),
            Method::codegemm_m1v4g128(),
            228.3,
            53.93,
        ),
        (
            "  +PV-Tuning".into(),
            Some(EngineKind::CodeGemm { cfg: cfg_m1v4, kernel: KernelConfig::default(), tune: TuneLevel::PvTuned }),
            Method::codegemm_m1v4g128(),
            228.3,
            63.96,
        ),
        (
            "CodeGEMM-m2v8".into(),
            Some(EngineKind::CodeGemm { cfg: cfg_m2v8, kernel: KernelConfig::default(), tune: TuneLevel::Calibrated }),
            Method::codegemm_m2v8g128(),
            214.4,
            52.67,
        ),
        (
            "  +PV-Tuning".into(),
            Some(EngineKind::CodeGemm { cfg: cfg_m2v8, kernel: KernelConfig::default(), tune: TuneLevel::PvTuned }),
            Method::codegemm_m2v8g128(),
            214.4,
            63.76,
        ),
    ];
    let mut t = Table::new(
        "Table 4 — Llama-3.1-8B-class accuracy & throughput (model | paper)",
        &["Method", "tok/s (sim|paper)", "ppl", "top1 %", "top5 %", "Avg (paper)"],
    );
    for (label, kind, method, paper_toks, paper_avg) in rows {
        let toks = s.tokens_per_s(&method, &LLAMA3_8B, 1);
        let acc = kind.map(|k| ctx.measure(k));
        let (ppl, top1, top5) = acc.map(|a| (a.ppl, a.top1, a.top5)).unwrap_or((f64::NAN, f64::NAN, f64::NAN));
        t.row(vec![
            label,
            format!("{} | {}", fnum(toks, 1), fnum(paper_toks, 1)),
            fnum(ppl, 2),
            fnum(top1, 1),
            fnum(top5, 1),
            fnum(paper_avg, 2),
        ]);
    }
    format!("{}\n  accuracy substrate: {}\n", t.render(), ctx.source)
}

/// Table 5: the 70B scaling table (throughput simulated at 70B geometry;
/// accuracy columns share the tiny-model substrate with Table 4).
pub fn table5(ctx: &EvalContext) -> String {
    let s = sim();
    let rows: Vec<(String, Option<EngineKind>, Method, f64)> = vec![
        ("FP16".into(), Some(EngineKind::Dense), Method::CuBlas, f64::NAN), // OOM in paper
        ("GPTQ-q2g128".into(), Some(EngineKind::Uniform { bits: 2, group: 32 }), Method::LutGemm { q: 2, g: 128 }, 41.7),
        (
            "AQLM-2x8".into(),
            Some(EngineKind::Dequant { cfg: tiny_cfg(8, 2, 8), tune: TuneLevel::Calibrated }),
            Method::aqlm_2x8(),
            19.0,
        ),
        (
            "AQLM-1x16".into(),
            Some(EngineKind::Dequant { cfg: tiny_cfg(8, 1, 12), tune: TuneLevel::Calibrated }),
            Method::aqlm_1x16(),
            5.5,
        ),
        (
            "CodeGEMM-m1v4g128".into(),
            Some(EngineKind::codegemm(tiny_cfg(4, 1, 8))),
            Method::codegemm_m1v4g128(),
            51.2,
        ),
        (
            "CodeGEMM-m1v4g32".into(),
            Some(EngineKind::CodeGemm {
                cfg: QuantConfig::new(4, 1, 8, 32).unwrap(),
                kernel: KernelConfig::default(),
                tune: TuneLevel::Calibrated,
            }),
            Method::codegemm(QuantConfig::new(4, 1, 8, 32).unwrap()),
            49.1,
        ),
    ];
    let mut t = Table::new(
        "Table 5 — Llama-3.1-70B scaling (model | paper)",
        &["Method", "tok/s (sim|paper)", "ppl", "top1 %", "top5 %"],
    );
    for (label, kind, method, paper_toks) in rows {
        let toks = s.tokens_per_s(&method, &LLAMA3_70B, 1);
        let acc = kind.map(|k| ctx.measure(k));
        let (ppl, top1, top5) = acc.map(|a| (a.ppl, a.top1, a.top5)).unwrap_or((f64::NAN, f64::NAN, f64::NAN));
        t.row(vec![
            label,
            format!("{} | {}", fnum(toks, 1), if paper_toks.is_nan() { "OOM".into() } else { fnum(paper_toks, 1) }),
            fnum(ppl, 2),
            fnum(top1, 1),
            fnum(top5, 1),
        ]);
    }
    let sp = s.tokens_per_s(&Method::codegemm_m1v4g128(), &LLAMA3_70B, 1)
        / s.tokens_per_s(&Method::aqlm_1x16(), &LLAMA3_70B, 1);
    format!(
        "{}\n  accuracy substrate: {}\n  headline: CodeGEMM-m1v4 vs AQLM-1x16 at 70B = {:.2}× (paper 8.93×, tok/s 51.2/5.5 = 9.3×)\n",
        t.render(),
        ctx.source,
        sp
    )
}

/// Quantization configs for the tiny model: g=32 divides every tiny layer
/// (k ∈ {128, 352}); the Llama-scale labels keep g=128.
fn tiny_cfg(v: usize, m: usize, b: usize) -> QuantConfig {
    QuantConfig::new(v, m, b, 32).unwrap()
}

// ---------------------------------------------------------------- Table 6

/// Table 6: Psumbook build vs read cycle split.
///
/// Op counts are exact at the paper's true shapes (build `m·2^b·K·⌈N/t_h⌉·M`
/// MACs, read `N·K·m/v·M` gathers — the same formulas the CPU engine's
/// counters implement and unit tests validate); gathers are weighted 2×
/// a build MAC in cycles (random table access vs streaming dot products —
/// the single weight is derived once from the paper's first row and then
/// applied everywhere, so all other rows are predictions).
pub fn table6() -> String {
    const READ_CYCLE_WEIGHT: f64 = 2.0;
    let mut t = Table::new(
        "Table 6 — Psumbook build vs read cycle share (%), weighted op model (model | paper build-%)",
        &["M", "N", "K", "t_w", "m2v8 build%", "m1v4 build%"],
    );
    for r in paper_data::TABLE6 {
        let mut cells = vec![r.m_batch.to_string(), r.n.to_string(), r.k.to_string(), r.tile_w.to_string()];
        for (cfg, paper) in [
            (QuantConfig::m2v8g128(), r.build_m2v8),
            (QuantConfig::m1v4g128(), r.build_m1v4),
        ] {
            let th = 2048usize;
            let mb = r.m_batch as f64;
            let build = (cfg.m * cfg.n_centroids() * r.k * r.n.div_ceil(th)) as f64 * mb;
            let read = (r.n * r.k * cfg.m / cfg.v) as f64 * mb;
            let share = 100.0 * build / (build + READ_CYCLE_WEIGHT * read);
            cells.push(format!("{} | {}", fnum(share, 1), fnum(paper, 1)));
        }
        t.row(cells);
    }
    format!(
        "{}\n  build/read split is M-invariant (both phases scale with M — the paper's §A.1 point);\n  \
         m2v8 > m1v4 build share holds everywhere; the paper's 8192² rows additionally see\n  \
         per-SM occupancy effects an op-count model does not capture (45% vs modeled ~33%).\n",
        t.render()
    )
}

// ---------------------------------------------------------------- Table 7

pub fn table7() -> String {
    let s = sim();
    let mut t = Table::new(
        "Table 7 — tile-size sensitivity (µs), M=1 (model | paper)",
        &["N", "K", "t_w", "t_h", "m2v8", "m1v4"],
    );
    for r in paper_data::TABLE7 {
        let kernel = KernelConfig::new(r.tile_w, r.tile_h).unwrap();
        let shape = GemmShape::new(1, r.n, r.k);
        let m2 = s.latency_us(&Method::CodeGemm { cfg: QuantConfig::m2v8g128(), kernel }, shape);
        let m1 = s.latency_us(&Method::CodeGemm { cfg: QuantConfig::m1v4g128(), kernel }, shape);
        t.row(vec![
            r.n.to_string(),
            r.k.to_string(),
            r.tile_w.to_string(),
            r.tile_h.to_string(),
            format!("{} | {}", fnum(m2, 2), fnum(r.m2v8, 2)),
            format!("{} | {}", fnum(m1, 2), fnum(r.m1v4, 2)),
        ]);
    }
    t.render()
}

// ---------------------------------------------------------------- Table 8

pub fn table8() -> String {
    let s = sim();
    let mut t = Table::new(
        "Table 8 — higher bit precisions (µs), g=128 b=8 t=(32,2048) (model | paper)",
        &["N", "K", "m", "v", "bits", "latency"],
    );
    for r in paper_data::TABLE8 {
        let shape = GemmShape::new(1, r.n, r.k);
        let (label_m, label_v, lat, bits) = if r.m_books == 0 {
            (String::from("-"), String::from("-"), s.latency_us(&Method::CuBlas, shape), 16.0)
        } else {
            let cfg = QuantConfig::new(r.v, r.m_books, 8, 128).unwrap();
            (
                r.m_books.to_string(),
                r.v.to_string(),
                s.latency_us(&Method::codegemm(cfg), shape),
                bits_per_weight(&cfg, r.n, r.k).total,
            )
        };
        t.row(vec![
            r.n.to_string(),
            r.k.to_string(),
            label_m,
            label_v,
            format!("{} | {}", fnum(bits, 3), fnum(r.bits, 3)),
            format!("{} | {}", fnum(lat, 2), fnum(r.latency, 2)),
        ]);
    }
    t.render()
}

// ---------------------------------------------------------------- Table 9

pub fn table9() -> String {
    let s = sim();
    let mut t = Table::new(
        "Table 9 — 8B decoder-block latency vs batch (µs), fair dequant accounting (model | paper)",
        &["BS", "cuBLAS", "Dequant", "cuBLAS+Deq", "AQLM-1x16", "AQLM-2x8", "QuIP#", "QTIP", "CG-m2v8", "CG-m1v4"],
    );
    for r in paper_data::TABLE9 {
        let l = |m: &Method| s.block_latency_us(m, &LLAMA3_8B, r.batch);
        let pairs: Vec<(f64, f64)> = vec![
            (l(&Method::CuBlas), r.cublas),
            (l(&Method::DequantStage), r.dequant_stage),
            (l(&Method::CuBlasPlusDequant), r.cublas_plus_dequant),
            (l(&Method::aqlm_1x16()), r.aqlm_1x16),
            (l(&Method::aqlm_2x8()), r.aqlm_2x8),
            (l(&Method::QuipSharp), r.quip),
            (l(&Method::Qtip), r.qtip),
            (l(&Method::codegemm_m2v8g128()), r.codegemm_m2v8),
            (l(&Method::codegemm_m1v4g128()), r.codegemm_m1v4),
        ];
        let mut cells = vec![r.batch.to_string()];
        cells.extend(pairs.iter().map(|(m, p)| format!("{} | {}", fnum(*m, 0), fnum(*p, 0))));
        t.row(cells);
    }
    t.render()
}

// --------------------------------------------------------------- Table 10

pub fn table10() -> String {
    let s = sim();
    let mut t = Table::new(
        "Table 10 — kernel latency (µs) across (M, N, K) (model | paper)",
        &["M", "N", "K", "cuBLAS", "AQLM-1x16", "AQLM-2x8", "CG-m2v8", "CG-m1v4", "QuIP#", "QTIP"],
    );
    for r in paper_data::TABLE10 {
        let shape = GemmShape::new(r.m, r.n, r.k);
        let pairs: Vec<(f64, f64)> = vec![
            (s.latency_us(&Method::CuBlas, shape), r.cublas),
            (s.latency_us(&Method::aqlm_1x16(), shape), r.aqlm_1x16),
            (s.latency_us(&Method::aqlm_2x8(), shape), r.aqlm_2x8),
            (s.latency_us(&Method::codegemm_m2v8g128(), shape), r.codegemm_m2v8),
            (s.latency_us(&Method::codegemm_m1v4g128(), shape), r.codegemm_m1v4),
            (s.latency_us(&Method::QuipSharp, shape), r.quip),
            (s.latency_us(&Method::Qtip, shape), r.qtip),
        ];
        let mut cells = vec![r.m.to_string(), r.n.to_string(), r.k.to_string()];
        cells.extend(pairs.iter().map(|(m, p)| format!("{} | {}", fnum(*m, 1), fnum(*p, 1))));
        t.row(cells);
    }
    // Aggregate fit quality.
    let mut errs = Vec::new();
    for r in paper_data::TABLE10 {
        let shape = GemmShape::new(r.m, r.n, r.k);
        for (m, p) in [
            (Method::CuBlas, r.cublas),
            (Method::aqlm_2x8(), r.aqlm_2x8),
            (Method::codegemm_m1v4g128(), r.codegemm_m1v4),
            (Method::QuipSharp, r.quip),
            (Method::Qtip, r.qtip),
        ] {
            errs.push(((s.latency_us(&m, shape) - p) / p).abs());
        }
    }
    let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
    format!("{}\n  mean |rel err| over fitted families: {:.1}%\n", t.render(), 100.0 * mean_err)
}

// ----------------------------------------------------------- Figures 4/5

/// Figure 4(a): footprint vs latency sweep (8B geometry).
pub fn fig4a() -> String {
    let s = sim();
    let mut t = Table::new(
        "Figure 4(a) — memory footprint vs latency, Llama-3.1-8B block, M=1",
        &["config", "q̄ (bits)", "block µs", "vs fp16"],
    )
    .align(1, Align::Right);
    let fp16 = s.block_latency_us(&Method::CuBlas, &LLAMA3_8B, 1);
    let mut rows: Vec<(QuantConfig, f64, f64)> = Vec::new();
    for (v, m, g) in [
        (4usize, 1usize, -1i64),
        (4, 1, 128),
        (4, 1, 32),
        (4, 1, 16),
        (4, 1, 4),
        (8, 2, -1),
        (8, 2, 128),
        (8, 2, 32),
        (8, 2, 8),
        (8, 1, 128),
        (16, 3, 32),
        (4, 2, 128),
        (8, 4, 128),
    ] {
        let Ok(cfg) = QuantConfig::new(v, m, 8, g) else { continue };
        let bits = bits_per_weight(&cfg, 4096, 4096).total;
        let lat = s.block_latency_us(&Method::codegemm(cfg), &LLAMA3_8B, 1);
        rows.push((cfg, bits, lat));
    }
    rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (cfg, bits, lat) in &rows {
        t.row(vec![cfg.label(), fnum(*bits, 3), fnum(*lat, 1), format!("{:.2}×", fp16 / lat)]);
    }
    // Qualitative check from the paper: per-vector normalization (g=v)
    // spikes latency; g>=32 is nearly flat.
    let lat_of = |g: i64| {
        let cfg = QuantConfig::new(4, 1, 8, g).unwrap();
        s.block_latency_us(&Method::codegemm(cfg), &LLAMA3_8B, 1)
    };
    let flat = (lat_of(128) - lat_of(32)).abs() / lat_of(128);
    let spike = lat_of(4) / lat_of(128);
    format!(
        "{}\n  g∈{{32,128}} latency spread {:.1}% (paper: minimal); g=v latency {:.2}× g=128 (paper: sharp rise)\n",
        t.render(),
        100.0 * flat,
        spike
    )
}

/// Figure 4(b): footprint vs perplexity sweep on the tiny model.
pub fn fig4b(ctx: &EvalContext) -> String {
    let mut t = Table::new(
        "Figure 4(b) — memory footprint vs perplexity (tiny-model substrate)",
        &["config", "q̄ @Llama scale", "ppl", "top1 %"],
    );
    let mut rows = Vec::new();
    for (v, m, g) in [
        (4usize, 1usize, -1i64),
        (8, 2, -1),
        (16, 4, -1),
        (4, 1, 32),
        (8, 2, 32),
        (8, 1, 16),
        (4, 2, 32),
        (8, 4, 32),
        (16, 2, 32),
    ] {
        let Ok(cfg) = QuantConfig::new(v, m, 8, g) else { continue };
        // tiny layers need g | k (k ∈ {128, 352}): remap g=-1 to row-wise
        // (valid) and keep g=16/32 (both divide).
        let bits = bits_per_weight(&cfg, 4096, 4096).total;
        let acc = ctx.measure(EngineKind::codegemm(cfg));
        rows.push((cfg.label(), bits, acc));
    }
    rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let mut monotone_pairs = 0;
    let mut total_pairs = 0;
    for w in rows.windows(2) {
        if w[1].1 > w[0].1 + 0.2 {
            total_pairs += 1;
            if w[1].2.ppl <= w[0].2.ppl * 1.05 {
                monotone_pairs += 1;
            }
        }
    }
    for (label, bits, acc) in &rows {
        t.row(vec![label.clone(), fnum(*bits, 3), fnum(acc.ppl, 3), fnum(acc.top1, 1)]);
    }
    format!(
        "{}\n  substrate: {} — more bits ⇒ lower ppl held for {monotone_pairs}/{total_pairs} bit-separated pairs\n",
        t.render(),
        ctx.source
    )
}

/// Figure 5: throughput vs accuracy scatter (8B and 70B).
pub fn fig5(ctx: &EvalContext) -> String {
    let s = sim();
    let mut out = String::new();
    for (geom, tag) in [(LLAMA3_8B, "8B"), (LLAMA3_70B, "70B")] {
        let mut t = Table::new(
            &format!("Figure 5 ({tag}) — throughput vs accuracy"),
            &["method", "tok/s (sim)", "ppl", "top1 %"],
        );
        let entries: Vec<(String, EngineKind, Method)> = vec![
            ("FP16".into(), EngineKind::Dense, Method::CuBlas),
            ("Uniform-2bit".into(), EngineKind::Uniform { bits: 2, group: 32 }, Method::LutGemm { q: 2, g: 128 }),
            (
                "AQLM-2x8".into(),
                EngineKind::Dequant { cfg: tiny_cfg(8, 2, 8), tune: TuneLevel::Calibrated },
                Method::aqlm_2x8(),
            ),
            (
                "AQLM-1x16".into(),
                EngineKind::Dequant { cfg: tiny_cfg(8, 1, 12), tune: TuneLevel::Calibrated },
                Method::aqlm_1x16(),
            ),
            ("CodeGEMM-m1v4".into(), EngineKind::codegemm(tiny_cfg(4, 1, 8)), Method::codegemm_m1v4g128()),
            (
                "CodeGEMM-m1v4+PV".into(),
                EngineKind::CodeGemm { cfg: tiny_cfg(4, 1, 8), kernel: KernelConfig::default(), tune: TuneLevel::PvTuned },
                Method::codegemm_m1v4g128(),
            ),
            ("CodeGEMM-m2v8".into(), EngineKind::codegemm(tiny_cfg(8, 2, 8)), Method::codegemm_m2v8g128()),
        ];
        for (label, kind, method) in entries {
            let toks = s.tokens_per_s(&method, &geom, 1);
            let acc = ctx.measure(kind);
            t.row(vec![label, fnum(toks, 1), fnum(acc.ppl, 2), fnum(acc.top1, 1)]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    let sp8 = s.tokens_per_s(&Method::codegemm_m1v4g128(), &LLAMA3_8B, 1)
        / s.tokens_per_s(&Method::aqlm_2x8(), &LLAMA3_8B, 1);
    let sp70 = s.tokens_per_s(&Method::codegemm_m1v4g128(), &LLAMA3_70B, 1)
        / s.tokens_per_s(&Method::aqlm_1x16(), &LLAMA3_70B, 1);
    out.push_str(&format!(
        "  headline speedups at comparable accuracy: 8B {:.2}× (paper 1.83×), 70B {:.2}× (paper 8.93×)\n",
        sp8, sp70
    ));
    out
}

/// Render one table/figure by id.
pub fn render(id: &str, ctx: &EvalContext) -> Option<String> {
    Some(match id {
        "1" => table1(),
        "2" => table2(),
        "3" => table3(),
        "4" => table4(ctx),
        "5" => table5(ctx),
        "6" => table6(),
        "7" => table7(),
        "8" => table8(),
        "9" => table9(),
        "10" => table10(),
        "fig4a" => fig4a(),
        "fig4b" => fig4b(ctx),
        "fig5" => fig5(ctx),
        _ => return None,
    })
}

/// All ids in paper order.
pub fn all_ids() -> &'static [&'static str] {
    &["1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "fig4a", "fig4b", "fig5"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_tables_render() {
        for id in ["1", "2", "3", "7", "8", "9", "10", "fig4a"] {
            let ctx = EvalContext::bigram_fallback();
            let s = render(id, &ctx).unwrap();
            assert!(s.len() > 100, "{id} too short");
            assert!(!s.contains("NaN"), "{id} contains NaN:\n{s}");
        }
    }

    #[test]
    fn table6_build_share_in_paper_ballpark() {
        let s = table6();
        assert!(s.contains('|'));
    }

    #[test]
    fn table10_fit_is_tight() {
        let s = table10();
        // "mean |rel err| over fitted families: X%" — must stay under 25%.
        let pct: f64 = s
            .split("mean |rel err| over fitted families:")
            .nth(1)
            .unwrap()
            .trim()
            .trim_end_matches('%')
            .parse()
            .unwrap();
        assert!(pct < 25.0, "mean rel err {pct}%");
    }
}
