//! Criterion-style micro/meso benchmark harness: warmup, fixed trial
//! count or time budget, robust summary statistics, and uniform
//! reporting. All `cargo bench` targets and the perf pass use this.

use crate::util::stats::Summary;
use crate::util::timer::Timer;

/// Options controlling a bench run.
#[derive(Clone, Copy, Debug)]
pub struct BenchOptions {
    /// Warmup iterations (not recorded).
    pub warmup: usize,
    /// Recorded iterations.
    pub trials: usize,
    /// Optional wall-clock budget in seconds; stops early once exceeded
    /// (after at least `min_trials`).
    pub max_seconds: f64,
    pub min_trials: usize,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions { warmup: 3, trials: 30, max_seconds: 5.0, min_trials: 5 }
    }
}

impl BenchOptions {
    /// Fast preset for CI / smoke runs.
    pub fn quick() -> Self {
        BenchOptions { warmup: 1, trials: 5, max_seconds: 1.0, min_trials: 2 }
    }

    /// Honour the CODEGEMM_BENCH_QUICK env var (set by `make test`).
    pub fn from_env() -> Self {
        if std::env::var("CODEGEMM_BENCH_QUICK").is_ok() {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.summary.mean * 1e6
    }

    pub fn median_us(&self) -> f64 {
        self.summary.p50 * 1e6
    }

    pub fn line(&self) -> String {
        format!(
            "{:40} mean {:>10.2} us  p50 {:>10.2} us  p95 {:>10.2} us  (n={})",
            self.name,
            self.summary.mean * 1e6,
            self.summary.p50 * 1e6,
            self.summary.p95 * 1e6,
            self.summary.n
        )
    }
}

/// Run a benchmark: `f` is invoked once per trial; its own duration is
/// measured (use closures that do a fixed amount of work).
pub fn run_bench(name: &str, opts: BenchOptions, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..opts.warmup {
        f();
    }
    let budget = Timer::start();
    let mut samples = Vec::with_capacity(opts.trials);
    for i in 0..opts.trials {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_s());
        if i + 1 >= opts.min_trials && budget.elapsed_s() > opts.max_seconds {
            break;
        }
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples) }
}

/// Run a benchmark whose closure processes `items` units per call and
/// report per-unit throughput too.
pub fn run_bench_throughput(
    name: &str,
    opts: BenchOptions,
    items_per_call: f64,
    f: impl FnMut(),
) -> (BenchResult, f64) {
    let r = run_bench(name, opts, f);
    let per_sec = items_per_call / r.summary.p50.max(1e-12);
    (r, per_sec)
}

/// Prevent the optimizer from discarding a value (stable-Rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_requested_trials() {
        let r = run_bench("noop", BenchOptions { warmup: 1, trials: 8, max_seconds: 60.0, min_trials: 2 }, || {
            black_box(1 + 1);
        });
        assert_eq!(r.summary.n, 8);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn budget_stops_early() {
        let opts = BenchOptions { warmup: 0, trials: 1000, max_seconds: 0.05, min_trials: 2 };
        let r = run_bench("sleepy", opts, || std::thread::sleep(std::time::Duration::from_millis(10)));
        assert!(r.summary.n < 1000, "stopped early, got {}", r.summary.n);
        assert!(r.summary.n >= 2);
    }

    #[test]
    fn throughput_positive() {
        let (_r, tput) = run_bench_throughput("t", BenchOptions::quick(), 100.0, || {
            black_box((0..100).sum::<usize>());
        });
        assert!(tput > 0.0);
    }

    #[test]
    fn line_formats() {
        let r = run_bench("fmt", BenchOptions::quick(), || {});
        assert!(r.line().contains("fmt"));
    }
}
