//! Benchmark harness and paper workload definitions.
//!
//! The offline registry carries no `criterion`, so `harness` implements
//! warmup + timed trials + outlier-robust summaries, and `workloads`
//! encodes the exact matrix shapes used by the paper's evaluation
//! (Llama-3 8B/70B decoder-block linears, the Table 10 sweep, ...).

pub mod harness;
pub mod tables;
pub mod workloads;

pub use harness::{run_bench, BenchOptions, BenchResult};
pub use workloads::{decoder_block_shapes, table10_shapes, GemmShape, LlamaGeometry};
