//! GPU device specifications used by the analytic model.

/// Static device parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Streaming multiprocessors.
    pub sms: usize,
    /// Usable shared memory per SM (bytes).
    pub smem_per_sm: usize,
    /// DRAM bandwidth (GB/s).
    pub dram_gbps: f64,
    /// Effective fraction of peak DRAM bandwidth a streaming kernel
    /// achieves in practice.
    pub dram_efficiency: f64,
    /// L2 cache (bytes).
    pub l2_bytes: usize,
    /// CUDA-core FP32 peak (TFLOPS).
    pub cuda_tflops: f64,
    /// Tensor-core FP16 peak (TFLOPS).
    pub tensor_tflops: f64,
    /// SM clock (GHz).
    pub clock_ghz: f64,
    /// Kernel launch + measurement overhead (µs).
    pub launch_us: f64,
    /// Idle board power (W).
    pub idle_watts: f64,
    /// Incremental power at full DRAM bandwidth (W).
    pub dram_watts: f64,
    /// Incremental power at full SM arithmetic activity (W).
    pub sm_watts: f64,
    /// Board power limit (W).
    pub tdp_watts: f64,
}

/// NVIDIA A100-SXM4-80GB — the paper's evaluation platform (§4 Setup).
pub const A100_80GB: DeviceSpec = DeviceSpec {
    name: "A100-SXM4-80GB",
    sms: 108,
    smem_per_sm: 164 * 1024,
    dram_gbps: 2039.0,
    dram_efficiency: 0.80,
    l2_bytes: 40 * 1024 * 1024,
    cuda_tflops: 19.5,
    tensor_tflops: 312.0,
    clock_ghz: 1.41,
    launch_us: 6.0,
    idle_watts: 80.0,
    dram_watts: 300.0,
    sm_watts: 170.0,
    tdp_watts: 400.0,
};

/// NVIDIA H100-SXM5-80GB (used for what-if projections; the paper cites
/// its 224 KB shared memory when discussing codebook capacity).
pub const H100_SXM: DeviceSpec = DeviceSpec {
    name: "H100-SXM5-80GB",
    sms: 132,
    smem_per_sm: 224 * 1024,
    dram_gbps: 3350.0,
    dram_efficiency: 0.80,
    l2_bytes: 50 * 1024 * 1024,
    cuda_tflops: 67.0,
    tensor_tflops: 989.0,
    clock_ghz: 1.83,
    launch_us: 5.0,
    idle_watts: 90.0,
    dram_watts: 330.0,
    sm_watts: 250.0,
    tdp_watts: 700.0,
};

impl DeviceSpec {
    /// Effective DRAM bandwidth in bytes/µs.
    pub fn dram_bytes_per_us(&self) -> f64 {
        self.dram_gbps * self.dram_efficiency * 1e9 / 1e6
    }

    /// Time (µs) to stream `bytes` at effective DRAM bandwidth.
    pub fn stream_us(&self, bytes: f64) -> f64 {
        bytes / self.dram_bytes_per_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_matches_paper_citations() {
        // §2.3: "A100 (164KB)" shared memory; the 1 MB AQLM-1×16 codebook
        // must not fit.
        assert_eq!(A100_80GB.smem_per_sm, 164 * 1024);
        let codebook_1x16 = (1usize << 16) * 8 * 2; // 2^16 centroids × v=8 × fp16
        assert_eq!(codebook_1x16, 1024 * 1024);
        assert!(codebook_1x16 > A100_80GB.smem_per_sm);
        // H100 (224KB) also cannot hold it — §2.3.
        assert!(codebook_1x16 > H100_SXM.smem_per_sm);
    }

    #[test]
    fn stream_time_sane() {
        // 470 MB at ~1631 GB/s effective ≈ 288 µs (the cuBLAS fp16 weight
        // stream for N=28672, K=8192 — paper Table 10 shows ~298 µs).
        let t = A100_80GB.stream_us(2.0 * 28672.0 * 8192.0);
        assert!((t - 288.0).abs() < 5.0, "t={t}");
    }
}
