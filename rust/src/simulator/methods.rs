//! Kernel methods in the paper's evaluation and their format-exact
//! per-shape weight/traffic/work counts.
//!
//! Every method of Tables 2/3/9/10 is an enum variant; the analytic model
//! (`kernels.rs`) expresses each method's latency over *derived features*
//! computed here — weight-stream bytes exact per storage format, compute
//! stream, lookup counts, Psumbook/LUT build work — so that the fitted
//! coefficients stay physically interpretable.

use crate::config::{KernelConfig, QuantConfig};
use crate::quant::footprint;

/// A GEMM kernel as evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    /// FP16 cuBLAS on tensor cores (the unquantized baseline).
    CuBlas,
    /// The dequantize-into-DRAM stage that must precede cuBLAS in a
    /// codebook pipeline (paper Table 9 "Dequant" column).
    DequantStage,
    /// cuBLAS + dequantization stage (fair accounting, §A.4).
    CuBlasPlusDequant,
    /// LUT-GEMM over BCQ weights (`q` bits, group `g`).
    LutGemm { q: usize, g: usize },
    /// QuIP# E8P lattice codebook with fused Hadamard smoothening.
    QuipSharp,
    /// QTIP trellis codes with fused rotation.
    Qtip,
    /// AQLM dequantization-based kernel, `m` codebooks × `b` bits over
    /// vectors of length `v` (paper uses 1×16/v=8 and 2×8/v=8).
    Aqlm { m: usize, b: usize, v: usize },
    /// The paper's kernel.
    CodeGemm { cfg: QuantConfig, kernel: KernelConfig },
}

impl Method {
    pub fn aqlm_1x16() -> Method {
        Method::Aqlm { m: 1, b: 16, v: 8 }
    }

    pub fn aqlm_2x8() -> Method {
        Method::Aqlm { m: 2, b: 8, v: 8 }
    }

    pub fn codegemm(cfg: QuantConfig) -> Method {
        Method::CodeGemm { cfg, kernel: KernelConfig::default() }
    }

    pub fn codegemm_m1v4g128() -> Method {
        Method::codegemm(QuantConfig::m1v4g128())
    }

    pub fn codegemm_m2v8g128() -> Method {
        Method::codegemm(QuantConfig::m2v8g128())
    }

    /// Table label.
    pub fn label(&self) -> String {
        match self {
            Method::CuBlas => "cuBLAS".into(),
            Method::DequantStage => "Dequant".into(),
            Method::CuBlasPlusDequant => "cuBLAS+Dequant".into(),
            Method::LutGemm { q, g } => format!("LUTGEMM-q{q}g{g}"),
            Method::QuipSharp => "QuIP#-e8p".into(),
            Method::Qtip => "QTIP-r2".into(),
            Method::Aqlm { m, b, .. } => format!("AQLM-{m}x{b}"),
            Method::CodeGemm { cfg, .. } => format!("CodeGEMM-{}", cfg.label()),
        }
    }

    /// Key used to group rows of the same method family during fitting
    /// (all CodeGEMM configurations share one coefficient set; the shape
    /// features carry the (v, m, b, g, t_w, t_h) dependence).
    pub fn family(&self) -> &'static str {
        match self {
            Method::CuBlas => "cublas",
            Method::DequantStage => "dequant_stage",
            Method::CuBlasPlusDequant => "cublas_dequant",
            Method::LutGemm { .. } => "lutgemm",
            Method::QuipSharp => "quip",
            Method::Qtip => "qtip",
            Method::Aqlm { b: 16, .. } => "aqlm1x16",
            Method::Aqlm { .. } => "aqlm2x8",
            Method::CodeGemm { .. } => "codegemm",
        }
    }

    /// All families the simulator can be asked about.
    pub fn families() -> &'static [&'static str] {
        &["cublas", "dequant_stage", "cublas_dequant", "lutgemm", "quip", "qtip", "aqlm1x16", "aqlm2x8", "codegemm"]
    }

    /// Exact weight-stream bytes for an `(n × k)` layer in this format
    /// (codes + codebooks/LUT constants + scales; fp16 = 2 bytes/elem).
    pub fn weight_bytes(&self, n: usize, k: usize) -> f64 {
        let (nf, kf) = (n as f64, k as f64);
        match self {
            Method::CuBlas => 2.0 * nf * kf,
            // The dequant stage reads codes and writes fp16 weights; the
            // following cuBLAS then re-reads the fp16 weights.
            Method::DequantStage => Method::aqlm_2x8().weight_bytes(n, k) + 2.0 * nf * kf,
            Method::CuBlasPlusDequant => Method::DequantStage.weight_bytes(n, k) + 2.0 * nf * kf,
            Method::LutGemm { q, g } => {
                // BCQ: q binary planes (1 bit each) + fp16 alpha per plane
                // per group.
                nf * kf * (*q as f64) / 8.0 + nf * (kf / *g as f64) * (*q as f64) * 2.0
            }
            // 2-bit lattice/trellis codes + fp16 row scales.
            Method::QuipSharp | Method::Qtip => nf * kf / 4.0 + nf * 2.0,
            Method::Aqlm { m, b, v } => {
                let codes = nf * (kf / *v as f64) * (*m as f64) * (*b as f64) / 8.0;
                let codebook = (*m as f64) * (1u64 << *b) as f64 * (*v as f64) * 2.0;
                let scales = nf * 2.0; // row-wise
                codes + codebook + scales
            }
            Method::CodeGemm { cfg, .. } => footprint::quantized_bytes(cfg, n, k),
        }
    }

    /// Average bits per weight (for footprint axes in figures).
    pub fn bits_per_weight(&self, n: usize, k: usize) -> f64 {
        self.weight_bytes(n, k) * 8.0 / (n as f64 * k as f64)
    }

    /// On-chip (shared-memory) bytes the kernel wants resident per thread
    /// block: full codebook for dequantization-based kernels, Psumbook for
    /// CodeGEMM, sub-LUT for LUT-GEMM.
    pub fn smem_bytes(&self, m_batch: usize) -> usize {
        match self {
            Method::CuBlas | Method::CuBlasPlusDequant => 96 * 1024, // cuBLAS stage tiles
            Method::DequantStage => 8 * 1024,
            Method::LutGemm { .. } => (1usize << 8) * 32 * 4, // 2^mu sub-table per mu-chunk
            Method::QuipSharp | Method::Qtip => 16 * 1024,    // lattice tables + act tile
            Method::Aqlm { m, b, v } => m * (1usize << b) * v * 2,
            Method::CodeGemm { cfg, kernel } => {
                // Psumbook: m · 2^b · (t_w / v) f32 entries per batch column.
                cfg.m * cfg.n_centroids() * (kernel.tile_w / cfg.v) * 4 * m_batch
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::device::A100_80GB;

    #[test]
    fn labels() {
        assert_eq!(Method::aqlm_1x16().label(), "AQLM-1x16");
        assert_eq!(Method::codegemm_m1v4g128().label(), "CodeGEMM-m1v4g128");
        assert_eq!(Method::LutGemm { q: 2, g: 128 }.label(), "LUTGEMM-q2g128");
    }

    #[test]
    fn weight_bytes_2bit_class_is_8x_smaller_than_fp16() {
        let (n, k) = (8192, 8192);
        let fp16 = Method::CuBlas.weight_bytes(n, k);
        for m in [
            Method::aqlm_2x8(),
            Method::codegemm_m1v4g128(),
            Method::QuipSharp,
            Method::LutGemm { q: 2, g: 128 },
        ] {
            let r = fp16 / m.weight_bytes(n, k);
            assert!((6.0..9.0).contains(&r), "{}: ratio {r}", m.label());
        }
    }

    #[test]
    fn bits_match_footprint_eq1() {
        let m = Method::codegemm_m1v4g128();
        let q = m.bits_per_weight(4096, 4096);
        assert!((q - 2.126).abs() < 0.01, "q̄={q}");
    }

    #[test]
    fn aqlm_1x16_codebook_exceeds_smem_but_psumbook_fits() {
        // §2.3 + §3: the paper's core capacity argument.
        let smem = A100_80GB.smem_per_sm;
        assert!(Method::aqlm_1x16().smem_bytes(1) > smem);
        assert!(Method::codegemm_m2v8g128().smem_bytes(1) < smem);
        assert!(Method::codegemm_m1v4g128().smem_bytes(1) < smem);
    }

    #[test]
    fn psumbook_smaller_than_codebook_by_v_over_tw_ratio() {
        // Space complexity §3: O(m·2^b·t_w/v) vs O(m·2^b·v).
        let cfg = QuantConfig::m2v8g128();
        let kernel = KernelConfig::default();
        let psum = Method::CodeGemm { cfg, kernel }.smem_bytes(1);
        // m·2^b·(32/8)·4 = 2·256·4·4 = 8 KB
        assert_eq!(psum, 2 * 256 * 4 * 4);
    }
}
