//! A100 analytic performance model.
//!
//! No GPU is available in this environment, so the paper's latency and
//! telemetry tables are regenerated through a physically-structured cost
//! model (DESIGN.md §Hardware-Adaptation):
//!
//! - every kernel's cost is expressed over *derived* features — launch
//!   overhead, weight-stream bytes (exact per format), compute stream
//!   (`M·N·K`), table lookups (`M·N·K·m/v`), Psumbook build MACs
//!   (`M·m·2^b·K·⌈N/t_h⌉`), per-batch-column overhead;
//! - the feature coefficients are fitted by non-negative least squares to
//!   the paper's *published* measurements (Tables 8, 9 and 10 — embedded
//!   in `paper_data.rs`), i.e. the model is calibrated once against the
//!   authors' A100 and then queried for every other table;
//! - structural effects that the features cannot express — shared-memory
//!   overflow of the AQLM-1×16 codebook, SM occupancy for large tiles —
//!   are modelled explicitly in `memory.rs`.
//!
//! The model's quality is checked by cross-validation tests: rows held
//! out of the fit must still be predicted within tolerance, and every
//! qualitative claim of the paper (who wins, crossovers, scaling slopes)
//! must hold in the regenerated tables.

pub mod device;
pub mod kernels;
pub mod lsq;
pub mod memory;
pub mod methods;
pub mod paper_data;
pub mod power;

pub use device::{DeviceSpec, A100_80GB, H100_SXM};
pub use kernels::Simulator;
pub use methods::Method;
pub use power::Telemetry;
