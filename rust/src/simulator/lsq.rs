//! Small dense least-squares solver with non-negativity projection.
//!
//! The analytic model fits ≤6 coefficients to a few dozen published
//! measurements, so normal equations + Gaussian elimination with partial
//! pivoting are ample. Non-negativity (a latency model must not have
//! negative cost components) is enforced by iterative clamping: negative
//! coefficients are pinned to zero and the reduced system is refit.

/// Solve `A x = b` for square `A` (row-major, n×n) with partial pivoting.
/// Returns `None` if singular.
pub fn solve_square(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut m = a.to_vec();
    let mut rhs = b.to_vec();
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        for r in col + 1..n {
            if m[r * n + col].abs() > m[piv * n + col].abs() {
                piv = r;
            }
        }
        if m[piv * n + col].abs() < 1e-12 {
            return None;
        }
        if piv != col {
            for c in 0..n {
                m.swap(col * n + c, piv * n + c);
            }
            rhs.swap(col, piv);
        }
        // Eliminate.
        let d = m[col * n + col];
        for r in col + 1..n {
            let f = m[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                m[r * n + c] -= f * m[col * n + c];
            }
            rhs[r] -= f * rhs[col];
        }
    }
    // Back-substitute.
    let mut x = vec![0f64; n];
    for col in (0..n).rev() {
        let mut acc = rhs[col];
        for c in col + 1..n {
            acc -= m[col * n + c] * x[c];
        }
        x[col] = acc / m[col * n + col];
    }
    Some(x)
}

/// Ordinary least squares: rows of `features` (each length `dim`) against
/// `targets`. Ridge-damped (`lambda`) for conditioning.
pub fn least_squares(features: &[Vec<f64>], targets: &[f64], dim: usize, lambda: f64) -> Option<Vec<f64>> {
    assert_eq!(features.len(), targets.len());
    let mut ata = vec![0f64; dim * dim];
    let mut atb = vec![0f64; dim];
    for (f, &y) in features.iter().zip(targets) {
        assert_eq!(f.len(), dim);
        for i in 0..dim {
            atb[i] += f[i] * y;
            for j in 0..dim {
                ata[i * dim + j] += f[i] * f[j];
            }
        }
    }
    for i in 0..dim {
        ata[i * dim + i] += lambda;
    }
    solve_square(&ata, &atb, dim)
}

/// Non-negative least squares by iterative clamping (projected refit).
/// Good enough for well-posed low-dimensional latency fits.
pub fn nnls(features: &[Vec<f64>], targets: &[f64], dim: usize, lambda: f64) -> Vec<f64> {
    // Floor the ridge so collinear feature sets (common when one feature
    // is a multiple of another for a method family) stay solvable.
    let lambda = lambda.max(1e-6);
    let mut active: Vec<bool> = vec![true; dim]; // coefficient is free
    for _ in 0..dim + 1 {
        // Build reduced system over free coefficients.
        let free: Vec<usize> = (0..dim).filter(|&i| active[i]).collect();
        if free.is_empty() {
            return vec![0.0; dim];
        }
        let reduced: Vec<Vec<f64>> = features
            .iter()
            .map(|f| free.iter().map(|&i| f[i]).collect::<Vec<f64>>())
            .collect();
        let sol = match least_squares(&reduced, targets, free.len(), lambda) {
            Some(s) => s,
            None => return vec![0.0; dim],
        };
        let mut any_negative = false;
        for (idx, &i) in free.iter().enumerate() {
            if sol[idx] < 0.0 {
                active[i] = false;
                any_negative = true;
            }
        }
        if !any_negative {
            let mut full = vec![0f64; dim];
            for (idx, &i) in free.iter().enumerate() {
                full[i] = sol[idx];
            }
            return full;
        }
    }
    vec![0.0; dim]
}

/// Root-mean-square relative error of a fit (diagnostics/tests).
pub fn rel_rmse(features: &[Vec<f64>], targets: &[f64], coef: &[f64]) -> f64 {
    let mut acc = 0f64;
    for (f, &y) in features.iter().zip(targets) {
        let pred: f64 = f.iter().zip(coef).map(|(a, b)| a * b).sum();
        let rel = (pred - y) / y.max(1e-9);
        acc += rel * rel;
    }
    (acc / targets.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![3.0, 4.0];
        assert_eq!(solve_square(&a, &b, 2).unwrap(), vec![3.0, 4.0]);
    }

    #[test]
    fn solves_with_pivoting() {
        // Requires a row swap: [[0,1],[1,0]] x = [2,5] -> x=[5,2]
        let a = vec![0.0, 1.0, 1.0, 0.0];
        let x = solve_square(&a, &[2.0, 5.0], 2).unwrap();
        assert!((x[0] - 5.0).abs() < 1e-12 && (x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn singular_returns_none() {
        let a = vec![1.0, 2.0, 2.0, 4.0];
        assert!(solve_square(&a, &[1.0, 2.0], 2).is_none());
    }

    #[test]
    fn ols_recovers_exact_linear_model() {
        // y = 2 + 3 f1
        let feats: Vec<Vec<f64>> = (0..10).map(|i| vec![1.0, i as f64]).collect();
        let ys: Vec<f64> = (0..10).map(|i| 2.0 + 3.0 * i as f64).collect();
        let c = least_squares(&feats, &ys, 2, 0.0).unwrap();
        assert!((c[0] - 2.0).abs() < 1e-9 && (c[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn nnls_clamps_negative_component() {
        // Best unconstrained fit would give a negative coefficient on f1;
        // NNLS must pin it to 0 and still fit the rest.
        let feats: Vec<Vec<f64>> = (0..20)
            .map(|i| vec![1.0, i as f64, (20 - i) as f64])
            .collect();
        let ys: Vec<f64> = (0..20).map(|i| 5.0 + 2.0 * (20 - i) as f64).collect();
        let c = nnls(&feats, &ys, 3, 0.0);
        assert!(c.iter().all(|&x| x >= 0.0), "{c:?}");
        assert!(rel_rmse(&feats, &ys, &c) < 0.05);
    }

    #[test]
    fn rel_rmse_zero_for_perfect() {
        let feats = vec![vec![1.0, 2.0], vec![1.0, 3.0]];
        let ys = vec![5.0, 7.0];
        let c = vec![1.0, 2.0];
        assert!(rel_rmse(&feats, &ys, &c) < 1e-12);
    }
}
