//! The A100 analytic latency model.
//!
//! Each method family's latency over a GEMM shape `(M, N, K)` is a
//! non-negative linear combination of *physically derived features*
//! (launch, memory-stream time, per-element work, table-build work,
//! split-K reduction, overflow gathers), with the coefficients fitted
//! once by NNLS against the paper's published A100 measurements
//! (Tables 7, 8 and 10 — see `paper_data.rs`). Structural effects
//! (shared-memory overflow, occupancy) enter through `memory.rs`.
//!
//! The model is *calibrated on per-kernel shapes* and *validated on
//! aggregates*: decoder-block latencies (Table 2/9) and end-to-end
//! throughput (Tables 4/5) are predicted, not fitted, apart from one
//! scalar decode-overhead factor anchored on the FP16 row of Table 4.

use std::collections::BTreeMap;

use super::device::{DeviceSpec, A100_80GB};
use super::lsq::{nnls, rel_rmse};
use super::memory;
use super::methods::Method;
use super::paper_data;
use crate::bench::workloads::{decoder_block_shapes, GemmShape, LlamaGeometry};
use crate::config::{KernelConfig, QuantConfig};

/// Number of latency features per family (constant across families; unused
/// features are zero for a family).
pub const N_FEATURES: usize = 5;

/// The fitted analytic model.
#[derive(Clone, Debug)]
pub struct Simulator {
    pub dev: DeviceSpec,
    /// Per-family NNLS coefficients over [const, mem_us, work_gops,
    /// build_gops, reduce_gops].
    coefs: BTreeMap<&'static str, Vec<f64>>,
    /// In-sample relative RMSE per fitted family (diagnostics).
    pub fit_rmse: BTreeMap<&'static str, f64>,
    /// Decode-loop overhead factor: tok_us ≈ a · n_layers · block_us.
    tok_a: f64,
}

/// One calibration sample: a method at a shape with the paper's µs.
#[derive(Clone, Debug)]
pub struct Sample {
    pub method: Method,
    pub shape: GemmShape,
    pub micros: f64,
}

impl Simulator {
    /// Build and calibrate the model for the paper's A100.
    pub fn a100() -> Simulator {
        Simulator::fit(A100_80GB, &calibration_samples())
    }

    /// Fit coefficients per family on the given samples.
    pub fn fit(dev: DeviceSpec, samples: &[Sample]) -> Simulator {
        let mut sim = Simulator { dev, coefs: BTreeMap::new(), fit_rmse: BTreeMap::new(), tok_a: 1.0 };
        let mut by_family: BTreeMap<&'static str, Vec<&Sample>> = BTreeMap::new();
        for s in samples {
            by_family.entry(s.method.family()).or_default().push(s);
        }
        for (family, rows) in &by_family {
            let feats: Vec<Vec<f64>> = rows.iter().map(|s| sim.features(&s.method, s.shape)).collect();
            let targets: Vec<f64> = rows.iter().map(|s| s.micros).collect();
            // Minimize *relative* error: scale each row by 1/y so small
            // shapes (launch-bound µs) weigh as much as large ones. This
            // keeps the fitted constant near the true launch overhead
            // instead of soaking up large-shape residuals.
            let scaled: Vec<Vec<f64>> = feats
                .iter()
                .zip(&targets)
                .map(|(f, &y)| f.iter().map(|x| x / y).collect())
                .collect();
            let ones = vec![1.0; targets.len()];
            let coef = nnls(&scaled, &ones, N_FEATURES, 1e-6);
            let rmse = rel_rmse(&feats, &targets, &coef);
            sim.coefs.insert(family, coef);
            sim.fit_rmse.insert(family, rmse);
        }
        // Methods without per-shape published data inherit analytic
        // defaults, then the dequant stage is anchored on Table 9.
        sim.coefs.entry("dequant_stage").or_insert_with(|| {
            // c0=launch, c1=1 (streams at full eff.), c2 fitted from the
            // 1027 µs Table-9 anchor below.
            vec![dev.launch_us, 1.0, 0.0, 0.0, 0.0]
        });
        sim.anchor_dequant_stage();
        sim.anchor_tok_factor();
        sim
    }

    /// Feature vector for a method at a shape:
    /// `[1, mem_us, work_gops, build_gops, reduce_gops]`, pre-multiplied
    /// by the occupancy penalty where applicable.
    pub fn features(&self, method: &Method, s: GemmShape) -> Vec<f64> {
        let (mb, n, k) = (s.m_batch as f64, s.n as f64, s.k as f64);
        let act_bytes = 2.0 * (s.k + s.n) as f64 * mb;
        let overflow = memory::overflow_gather_bytes(method, &self.dev, s.m_batch, s.n, s.k);
        let mem_us = self.dev.stream_us(method.weight_bytes(s.n, s.k) + act_bytes + overflow);
        let mnk = mb * n * k / 1e9;
        let (work, build, reduce) = match method {
            Method::CuBlas => (mnk, 0.0, 0.0),
            Method::DequantStage => (n * k / 1e9, 0.0, 0.0),
            Method::CuBlasPlusDequant => (mnk, n * k / 1e9, 0.0),
            Method::LutGemm { q, .. } => {
                // mu=8 LUT: read = MNK·q/mu lookups, build = 2^mu·K/mu·M.
                let mu = 8.0;
                (mnk * *q as f64 / mu, 256.0 * (k / mu) * mb / 1e9, 0.0)
            }
            Method::QuipSharp | Method::Qtip => {
                // fused dequant-multiply + per-column Hadamard transform
                (mnk, mb * k * k.log2() / 1e9, 0.0)
            }
            Method::Aqlm { m, v, .. } => {
                // dequant MACs (m centroid adds per element) + per-vector
                // codebook gathers.
                (mnk * *m as f64, mb * n * (k / *v as f64) * *m as f64 / 1e9, 0.0)
            }
            Method::CodeGemm { cfg, kernel } => {
                let read = cfg.m as f64 * mnk / cfg.v as f64;
                let build =
                    cfg.m as f64 * cfg.n_centroids() as f64 * k * mb * s.n.div_ceil(kernel.tile_h) as f64 / 1e9;
                let reduce = mb * n * (s.k.div_ceil(kernel.tile_w)) as f64 / 1e9;
                (read, build, reduce)
            }
        };
        let occ = memory::occupancy_penalty(method, &self.dev, s.m_batch, s.n, s.k);
        vec![1.0, occ * mem_us, occ * work, occ * build, occ * reduce]
    }

    /// Predicted kernel latency (µs) for `method` at shape `s`.
    pub fn latency_us(&self, method: &Method, s: GemmShape) -> f64 {
        if let Method::CuBlasPlusDequant = method {
            return self.latency_us(&Method::CuBlas, s) + self.latency_us(&Method::DequantStage, s);
        }
        let coef = self
            .coefs
            .get(method.family())
            .unwrap_or_else(|| panic!("no coefficients for family {}", method.family()));
        let f = self.features(method, s);
        let fitted: f64 = f.iter().zip(coef.iter()).map(|(x, c)| x * c).sum();
        // Structural term outside the fit (no published data varies g):
        // fine-grained group scales add weight-stream traffic the fitted
        // features do not see — all calibration rows use g=128. Charge the
        // *extra* scale bytes beyond the g=128 baseline at an effective
        // 2× stream cost (strided, row-interleaved access). This is the
        // mechanism behind Fig. 4(a): flat for g ≥ 32, sharp rise at g=v.
        fitted + memory::scale_traffic_penalty_us(method, &self.dev, s.n, s.k)
    }

    /// Aggregate latency (µs) of all linear layers in one decoder block
    /// (paper Tables 2 and 9: no layer fusion, M = batch).
    pub fn block_latency_us(&self, method: &Method, geom: &LlamaGeometry, m_batch: usize) -> f64 {
        decoder_block_shapes(geom, m_batch).iter().map(|(_, s)| self.latency_us(method, *s)).sum()
    }

    /// End-to-end decode throughput (tok/s, single stream at batch
    /// `m_batch`, HF-style unfused loop — Tables 4/5).
    pub fn tokens_per_s(&self, method: &Method, geom: &LlamaGeometry, m_batch: usize) -> f64 {
        let block = self.block_latency_us(method, geom, m_batch);
        let tok_us = self.tok_a * geom.n_layers as f64 * block;
        m_batch as f64 * 1e6 / tok_us
    }

    /// Fitted coefficient vector for a family (for inspection/tests).
    pub fn coef(&self, family: &str) -> Option<&[f64]> {
        self.coefs.get(family).map(|v| v.as_slice())
    }

    /// Anchor the dequant-stage work coefficient on Table 9's 1027 µs
    /// (aggregate dequantization of one Llama-3-8B decoder block).
    fn anchor_dequant_stage(&mut self) {
        let geom = crate::bench::workloads::LLAMA3_8B;
        let shapes = decoder_block_shapes(&geom, 1);
        let target = paper_data::TABLE9[0].dequant_stage;
        let mut fixed = 0.0;
        let mut work = 0.0;
        for (_, s) in &shapes {
            let f = self.features(&Method::DequantStage, *s);
            fixed += self.dev.launch_us * f[0] + f[1];
            work += f[2];
        }
        let c2 = ((target - fixed) / work).max(0.0);
        self.coefs.insert("dequant_stage", vec![self.dev.launch_us, 1.0, c2, 0.0, 0.0]);
    }

    /// Anchor the decode-loop factor on Table 4's measured tok/s rows
    /// (least squares through the origin over all six methods).
    fn anchor_tok_factor(&mut self) {
        let geom = crate::bench::workloads::LLAMA3_8B;
        let anchors: &[(Method, f64)] = &[
            (Method::CuBlas, 103.8),
            (Method::LutGemm { q: 2, g: 128 }, 205.3),
            (Method::aqlm_2x8(), 124.5),
            (Method::aqlm_1x16(), 49.0),
            (Method::codegemm_m1v4g128(), 228.3),
            (Method::codegemm_m2v8g128(), 214.4),
        ];
        let (mut sxy, mut sxx) = (0.0, 0.0);
        for (m, toks) in anchors {
            let x = geom.n_layers as f64 * self.block_latency_us(m, &geom, 1);
            let y = 1e6 / toks;
            sxy += x * y;
            sxx += x * x;
        }
        self.tok_a = (sxy / sxx).max(0.1);
    }
}

/// All per-shape calibration samples from the paper's appendix tables.
pub fn calibration_samples() -> Vec<Sample> {
    let mut out = Vec::new();
    let m2v8 = QuantConfig::m2v8g128();
    let m1v4 = QuantConfig::m1v4g128();
    let kdef = KernelConfig::default();
    // Table 10: 27 shapes × 7 methods.
    for r in paper_data::TABLE10 {
        let s = GemmShape::new(r.m, r.n, r.k);
        out.push(Sample { method: Method::CuBlas, shape: s, micros: r.cublas });
        // The published AQLM-1x16 column for (N=8192, K=2048) duplicates
        // the (2048, 2048) column verbatim (28.84/74.67/135.36) — a clear
        // transcription artifact; every other 1x16 row is consistent with
        // latency ≈ a + b·M·N·K. Exclude those three rows from the fit.
        if !(r.n == 8192 && r.k == 2048) {
            out.push(Sample { method: Method::aqlm_1x16(), shape: s, micros: r.aqlm_1x16 });
        }
        out.push(Sample { method: Method::aqlm_2x8(), shape: s, micros: r.aqlm_2x8 });
        out.push(Sample { method: Method::QuipSharp, shape: s, micros: r.quip });
        out.push(Sample { method: Method::Qtip, shape: s, micros: r.qtip });
        out.push(Sample {
            method: Method::CodeGemm { cfg: m2v8, kernel: kdef },
            shape: s,
            micros: r.codegemm_m2v8,
        });
        out.push(Sample {
            method: Method::CodeGemm { cfg: m1v4, kernel: kdef },
            shape: s,
            micros: r.codegemm_m1v4,
        });
    }
    // Table 7: CodeGEMM tile sweep.
    for r in paper_data::TABLE7 {
        let s = GemmShape::new(1, r.n, r.k);
        let kernel = KernelConfig::new(r.tile_w, r.tile_h).unwrap();
        out.push(Sample { method: Method::CodeGemm { cfg: m2v8, kernel }, shape: s, micros: r.m2v8 });
        out.push(Sample { method: Method::CodeGemm { cfg: m1v4, kernel }, shape: s, micros: r.m1v4 });
    }
    // Table 8: CodeGEMM bit sweep (+ cuBLAS reference rows).
    for r in paper_data::TABLE8 {
        let s = GemmShape::new(1, r.n, r.k);
        if r.m_books == 0 {
            out.push(Sample { method: Method::CuBlas, shape: s, micros: r.latency });
        } else {
            let cfg = QuantConfig::new(r.v, r.m_books, 8, 128).unwrap();
            out.push(Sample { method: Method::CodeGemm { cfg, kernel: kdef }, shape: s, micros: r.latency });
        }
    }
    // LUT-GEMM has no per-shape rows in the paper; synthesize per-shape
    // anchors by distributing the Table 2 block measurements over the
    // block's shapes proportionally to a provisional (launch + stream +
    // work/CUDA-peak) estimate. This keeps the family's scaling physical
    // while matching the published block totals.
    for (geom, total) in
        [(crate::bench::workloads::LLAMA3_8B, 160.1), (crate::bench::workloads::LLAMA3_70B, 299.9)]
    {
        let method = Method::LutGemm { q: 2, g: 128 };
        let shapes = decoder_block_shapes(&geom, 1);
        let prov: Vec<f64> = shapes
            .iter()
            .map(|(_, s)| {
                let w = method.weight_bytes(s.n, s.k) + 2.0 * (s.k + s.n) as f64;
                A100_80GB.launch_us
                    + A100_80GB.stream_us(w)
                    + (s.m_batch * s.n * s.k) as f64 / 4.0 / 1e9 / A100_80GB.cuda_tflops * 1e3
            })
            .collect();
        let sum: f64 = prov.iter().sum();
        for ((_, s), p) in shapes.iter().zip(prov.iter()) {
            out.push(Sample { method, shape: *s, micros: total * p / sum });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads::{LLAMA3_70B, LLAMA3_8B};

    fn sim() -> Simulator {
        Simulator::a100()
    }

    #[test]
    fn in_sample_fit_is_tight() {
        let s = sim();
        for (family, rmse) in &s.fit_rmse {
            assert!(*rmse < 0.22, "{family}: rel RMSE {rmse}");
        }
    }

    #[test]
    fn holdout_cross_validation() {
        // Remove three Table-10 shapes entirely from the fit; predictions
        // for them must stay within 35% — the model generalizes, it does
        // not memorize.
        let held: &[(usize, usize, usize)] = &[(1, 8192, 8192), (4, 4096, 4096), (8, 28672, 8192)];
        let all = calibration_samples();
        let train: Vec<Sample> = all
            .iter()
            .filter(|s| !held.contains(&(s.shape.m_batch, s.shape.n, s.shape.k)))
            .cloned()
            .collect();
        let model = Simulator::fit(A100_80GB, &train);
        let mut worst: f64 = 0.0;
        for s in all.iter().filter(|s| held.contains(&(s.shape.m_batch, s.shape.n, s.shape.k))) {
            let pred = model.latency_us(&s.method, s.shape);
            let rel = (pred - s.micros).abs() / s.micros;
            worst = worst.max(rel);
        }
        assert!(worst < 0.35, "worst holdout rel err {worst}");
    }

    #[test]
    fn table2_ordering_holds() {
        // Qualitative claims of Table 2 at block level (predicted, not
        // fitted): CodeGEMM m1v4 beats m2v8 beats AQLM-2x8 beats cuBLAS
        // beats AQLM-1x16; on 70B the AQLM-1x16 gap widens.
        let s = sim();
        for geom in [LLAMA3_8B, LLAMA3_70B] {
            let l = |m: &Method| s.block_latency_us(m, &geom, 1);
            let m1v4 = l(&Method::codegemm_m1v4g128());
            let m2v8 = l(&Method::codegemm_m2v8g128());
            let a28 = l(&Method::aqlm_2x8());
            let a116 = l(&Method::aqlm_1x16());
            let cb = l(&Method::CuBlas);
            assert!(m1v4 < m2v8, "{}: m1v4 {m1v4} < m2v8 {m2v8}", geom.name);
            assert!(m2v8 < a28, "{}: m2v8 {m2v8} < aqlm2x8 {a28}", geom.name);
            assert!(a28 < cb, "{}: aqlm2x8 {a28} < cublas {cb}", geom.name);
            assert!(cb < a116, "{}: cublas {cb} < aqlm1x16 {a116}", geom.name);
        }
        let gap8 = s.block_latency_us(&Method::aqlm_1x16(), &LLAMA3_8B, 1)
            / s.block_latency_us(&Method::codegemm_m1v4g128(), &LLAMA3_8B, 1);
        let gap70 = s.block_latency_us(&Method::aqlm_1x16(), &LLAMA3_70B, 1)
            / s.block_latency_us(&Method::codegemm_m1v4g128(), &LLAMA3_70B, 1);
        assert!(gap8 > 2.5, "8B gap {gap8}");
        assert!(gap70 > gap8 * 0.8, "70B gap {gap70} vs 8B {gap8}");
    }

    #[test]
    fn table2_magnitudes_close() {
        let s = sim();
        for (i, geom) in [LLAMA3_8B, LLAMA3_70B].iter().enumerate() {
            let p = &paper_data::TABLE2[i];
            for (m, paper) in [
                (Method::CuBlas, p.cublas),
                (Method::aqlm_1x16(), p.aqlm_1x16),
                (Method::aqlm_2x8(), p.aqlm_2x8),
                (Method::codegemm_m1v4g128(), p.codegemm_m1v4),
                (Method::codegemm_m2v8g128(), p.codegemm_m2v8),
            ] {
                let pred = s.block_latency_us(&m, geom, 1);
                let rel = (pred - paper).abs() / paper;
                assert!(rel < 0.45, "{} {}: pred {pred:.0} vs paper {paper} ({rel:.2})", geom.name, m.label());
            }
        }
    }

    #[test]
    fn headline_speedups_reproduced() {
        // Abstract: 1.83× (8B) and 8.93× (70B) end-to-end vs AQLM at
        // comparable accuracy (m1v4 vs 2x8 on 8B, m1v4 vs 1x16 on 70B).
        let s = sim();
        let sp8 = s.tokens_per_s(&Method::codegemm_m1v4g128(), &LLAMA3_8B, 1)
            / s.tokens_per_s(&Method::aqlm_2x8(), &LLAMA3_8B, 1);
        assert!((1.3..2.4).contains(&sp8), "8B speedup {sp8} (paper 1.83×)");
        let sp70 = s.tokens_per_s(&Method::codegemm_m1v4g128(), &LLAMA3_70B, 1)
            / s.tokens_per_s(&Method::aqlm_1x16(), &LLAMA3_70B, 1);
        assert!((5.0..13.0).contains(&sp70), "70B speedup {sp70} (paper 8.93×)");
    }

    #[test]
    fn fp16_throughput_anchor() {
        let s = sim();
        let t = s.tokens_per_s(&Method::CuBlas, &LLAMA3_8B, 1);
        assert!((70.0..140.0).contains(&t), "fp16 8B tok/s {t} (paper 103.8)");
    }

    #[test]
    fn batch_scaling_matches_table9_shape() {
        // AQLM-1x16 degrades ~linearly in batch; cuBLAS stays flat.
        let s = sim();
        let a1 = s.block_latency_us(&Method::aqlm_1x16(), &LLAMA3_8B, 1);
        let a16 = s.block_latency_us(&Method::aqlm_1x16(), &LLAMA3_8B, 16);
        assert!(a16 / a1 > 8.0, "aqlm1x16 16/1 ratio {}", a16 / a1);
        let c1 = s.block_latency_us(&Method::CuBlas, &LLAMA3_8B, 1);
        let c16 = s.block_latency_us(&Method::CuBlas, &LLAMA3_8B, 16);
        assert!(c16 / c1 < 1.6, "cublas 16/1 ratio {}", c16 / c1);
        // §A.4: with fair dequant accounting CodeGEMM stays competitive
        // with cuBLAS+Dequant even at batch 16.
        let cg16 = s.block_latency_us(&Method::codegemm_m1v4g128(), &LLAMA3_8B, 16);
        let cd16 = s.block_latency_us(&Method::CuBlasPlusDequant, &LLAMA3_8B, 16);
        assert!(cg16 < cd16 * 1.6, "codegemm {cg16} vs cublas+dequant {cd16}");
    }

    #[test]
    fn higher_bits_cost_more_latency_on_large_mats() {
        // Table 8 trend: increasing m at fixed v raises latency.
        let s = sim();
        let shape = GemmShape::new(1, 8192, 8192);
        let lat = |m: usize, v: usize| {
            let cfg = QuantConfig::new(v, m, 8, 128).unwrap();
            s.latency_us(&Method::codegemm(cfg), shape)
        };
        assert!(lat(1, 8) < lat(2, 8));
        assert!(lat(2, 8) < lat(4, 8));
        assert!(lat(1, 4) < lat(2, 4));
    }

    #[test]
    #[ignore = "diagnostic dump, run with --ignored --nocapture"]
    fn debug_dump() {
        let s = sim();
        for (fam, c) in &s.coefs {
            println!("{fam:14} rmse={:.3} coef={:?}", s.fit_rmse[fam], c);
        }
        for geom in [LLAMA3_8B, LLAMA3_70B] {
            for m in [
                Method::CuBlas,
                Method::LutGemm { q: 2, g: 128 },
                Method::QuipSharp,
                Method::Qtip,
                Method::aqlm_1x16(),
                Method::aqlm_2x8(),
                Method::codegemm_m2v8g128(),
                Method::codegemm_m1v4g128(),
            ] {
                println!("{} {:22} block={:8.1}us tok/s={:7.1}", geom.name, m.label(),
                    s.block_latency_us(&m, &geom, 1), s.tokens_per_s(&m, &geom, 1));
                for (name, shape) in decoder_block_shapes(&geom, 1) {
                    println!("    {name:8} {:18} {:8.2}us", shape.label(), s.latency_us(&m, shape));
                }
            }
        }
    }

    #[test]
    fn dequant_stage_anchor() {
        let s = sim();
        let d = s.block_latency_us(&Method::DequantStage, &LLAMA3_8B, 1);
        assert!((d - 1027.0).abs() / 1027.0 < 0.05, "dequant stage {d} vs 1027");
    }
}
