//! Shared-memory capacity / occupancy model.
//!
//! Two structural effects in the paper's evaluation cannot be expressed as
//! smooth per-element features and are modelled explicitly here:
//!
//! 1. **Codebook overflow (AQLM-1×16).** The 1 MB codebook (2^16 centroids
//!    × v=8 × fp16) exceeds every GPU's shared memory (§2.3), so centroid
//!    gathers hit L2/DRAM instead of smem. We model this as an extra
//!    traffic stream: each of the `M·N·K/v` gathers touches a `2·v`-byte
//!    centroid with an L2-resident hit probability determined by codebook
//!    vs L2 size.
//!
//! 2. **Occupancy (CodeGEMM tile sweep, §A.2).** The Psumbook grows with
//!    `t_w/v` and with the batch `M`; larger footprints reduce the number
//!    of concurrently resident thread blocks per SM, which lowers
//!    latency-hiding. Wider tiles also shrink the grid until it no longer
//!    covers all SMs (wave quantization).

use super::device::DeviceSpec;
use super::methods::Method;

/// Does this method's working set fit in shared memory?
pub fn fits_smem(method: &Method, dev: &DeviceSpec, m_batch: usize) -> bool {
    method.smem_bytes(m_batch) <= dev.smem_per_sm
}

/// Extra DRAM/L2 gather traffic (bytes) caused by a codebook that does not
/// fit in shared memory. Zero for methods whose tables fit.
pub fn overflow_gather_bytes(method: &Method, dev: &DeviceSpec, m_batch: usize, n: usize, k: usize) -> f64 {
    let smem = method.smem_bytes(1); // per-column table size
    if smem <= dev.smem_per_sm {
        return 0.0;
    }
    match method {
        Method::Aqlm { m, v, .. } => {
            // Every code triggers a 2·v-byte centroid gather from L2 (if
            // the codebook is L2-resident) or DRAM. With a 1 MB codebook
            // and 40 MB L2 the table is L2-resident, but L2 gather
            // bandwidth is far below smem; we charge the full gather
            // stream at DRAM-equivalent cost scaled by the L2 speedup.
            let gathers = (m_batch * n * (k / v)) as f64 * *m as f64;
            let l2_speedup = if smem <= dev.l2_bytes { 3.0 } else { 1.0 };
            gathers * (2.0 * *v as f64) / l2_speedup
        }
        _ => 0.0,
    }
}

/// Extra latency (µs) from fine-grained group-normalization scales: every
/// calibration row uses g = 128, so the fitted model is blind to g. The
/// scales stream `N·(K/g)·2` bytes; we charge the bytes *beyond* the
/// g = 128 baseline at 2× stream cost (strided, row-interleaved access).
/// Reproduces Fig. 4(a)'s shape: flat for g ≥ 32, sharp rise toward g = v.
pub fn scale_traffic_penalty_us(method: &Method, dev: &DeviceSpec, n: usize, k: usize) -> f64 {
    let Method::CodeGemm { cfg, .. } = method else {
        return 0.0;
    };
    let scale_bytes = |g: f64| n as f64 * (k as f64 / g) * 2.0;
    let g_eff = cfg.group_size(k) as f64;
    let extra = (scale_bytes(g_eff) - scale_bytes(128.0)).max(0.0);
    2.0 * dev.stream_us(extra)
}

/// Number of thread blocks that fit concurrently per SM given the
/// method's shared-memory appetite (≥ 1 once launched at all).
pub fn blocks_per_sm(method: &Method, dev: &DeviceSpec, m_batch: usize) -> usize {
    let want = method.smem_bytes(m_batch).max(1);
    (dev.smem_per_sm / want).clamp(1, 8)
}

/// Occupancy-driven latency multiplier for CodeGEMM's tile sweep:
/// `1.0` at full residency, growing as the Psumbook squeezes out
/// concurrent blocks or the grid under-fills the device.
pub fn occupancy_penalty(method: &Method, dev: &DeviceSpec, m_batch: usize, n: usize, k: usize) -> f64 {
    let Method::CodeGemm { kernel, .. } = method else {
        return 1.0;
    };
    // Latency hiding: fewer resident blocks ⇒ less overlap of the gather
    // latency. Calibrated so 1 block/SM costs ~26% over 4+ blocks/SM.
    let resident = blocks_per_sm(method, dev, m_batch) as f64;
    let hiding = 1.0 + 0.35 / resident.max(1.0) - 0.35 / 4.0;
    // Wave quantization: the split-K grid is ceil(N/t_h) · ceil(K/t_w)
    // blocks; a grid that cannot fill the final wave of SMs leaves the
    // device partially idle.
    let grid = (n.div_ceil(kernel.tile_h) * k.div_ceil(kernel.tile_w)) as f64;
    let waves = (grid / dev.sms as f64).ceil().max(1.0);
    let fill = grid / (waves * dev.sms as f64);
    hiding * (1.0 + 0.25 * (1.0 - fill))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{KernelConfig, QuantConfig};
    use crate::simulator::device::A100_80GB;

    #[test]
    fn aqlm_1x16_overflows_and_pays_gathers() {
        let m = Method::aqlm_1x16();
        assert!(!fits_smem(&m, &A100_80GB, 1));
        let extra = overflow_gather_bytes(&m, &A100_80GB, 1, 8192, 8192);
        assert!(extra > 0.0);
        // 2x8's 8 KB codebook fits; no overflow traffic.
        let m28 = Method::aqlm_2x8();
        assert!(fits_smem(&m28, &A100_80GB, 1));
        assert_eq!(overflow_gather_bytes(&m28, &A100_80GB, 1, 8192, 8192), 0.0);
    }

    #[test]
    fn overflow_scales_linearly_with_batch() {
        let m = Method::aqlm_1x16();
        let e1 = overflow_gather_bytes(&m, &A100_80GB, 1, 4096, 4096);
        let e4 = overflow_gather_bytes(&m, &A100_80GB, 4, 4096, 4096);
        assert!((e4 / e1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn wider_tiles_raise_occupancy_penalty() {
        let mk = |tw: usize, th: usize| Method::CodeGemm {
            cfg: QuantConfig::m2v8g128(),
            kernel: KernelConfig::new(tw, th).unwrap(),
        };
        let p32 = occupancy_penalty(&mk(32, 2048), &A100_80GB, 8, 4096, 4096);
        let p128 = occupancy_penalty(&mk(128, 2048), &A100_80GB, 8, 4096, 4096);
        assert!(p128 >= p32, "t_w=128 ({p128}) should not beat t_w=32 ({p32}) at M=8");
        // Taller tiles under-fill the grid on small N (§A.2: t_h=4096 is
        // worse at N=4096 — half the blocks).
        let p2048 = occupancy_penalty(&mk(32, 2048), &A100_80GB, 1, 4096, 4096);
        let p4096 = occupancy_penalty(&mk(32, 4096), &A100_80GB, 1, 4096, 4096);
        assert!(p4096 > p2048, "t_h=4096 ({p4096}) should trail t_h=2048 ({p2048}) at N=4096");
    }

    #[test]
    fn blocks_per_sm_bounded() {
        let m = Method::codegemm_m1v4g128();
        let b = blocks_per_sm(&m, &A100_80GB, 1);
        assert!((1..=8).contains(&b));
    }
}
