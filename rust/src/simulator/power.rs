//! Power / utilization telemetry model (paper Table 3).
//!
//! The paper samples `nvidia-smi` during a sustained GEMV loop and reports
//! TFLOPS, board power, GFLOPS/W, GPU utilization and *memory utilization*
//! (fraction of the sampling window in which DRAM was actively read or
//! written). We derive the same quantities from the analytic model's busy
//! fractions:
//!
//! - `t_mem / t_total` — the DRAM-active fraction → memory utilization;
//! - kernel-resident fraction → GPU utilization (a kernel that spins on
//!   L2 gathers, like AQLM-1×16, keeps SMs "utilized" at ~99% while DRAM
//!   sits idle — exactly the paper's 99%/6% row);
//! - power = idle + dram_watts·mem_busy + sm_watts·issue_busy;
//! - effective TFLOPS = dense-equivalent FLOPs (2·M·N·K) / latency.

use super::kernels::Simulator;
use super::methods::Method;
use crate::bench::workloads::GemmShape;

/// Modelled telemetry for one sustained kernel workload.
#[derive(Clone, Debug)]
pub struct Telemetry {
    pub method: String,
    pub latency_us: f64,
    /// Dense-equivalent throughput (2·M·N·K / latency), TFLOPS.
    pub tflops: f64,
    pub power_w: f64,
    pub gflops_per_w: f64,
    /// Fraction of time a kernel was resident (%, nvidia-smi "GPU util").
    pub gpu_util: f64,
    /// Fraction of time DRAM was actively transferring (%).
    pub mem_util: f64,
}

impl Simulator {
    /// Model Table-3-style telemetry for `method` looped on shape `s`.
    pub fn telemetry(&self, method: &Method, s: GemmShape) -> Telemetry {
        let dev = &self.dev;
        let lat = self.latency_us(method, s);
        // DRAM-active time: the weight/activation stream (overflow gathers
        // hit L2, not DRAM, so they do NOT count towards mem-util — that
        // is why AQLM-1×16 shows 6% despite being the slowest kernel).
        let act_bytes = 2.0 * (s.k + s.n) as f64 * s.m_batch as f64;
        let dram_bytes = method.weight_bytes(s.n, s.k) + act_bytes;
        let t_mem = dev.stream_us(dram_bytes);
        // Resident time excludes only the launch gap between iterations.
        let resident = ((lat - dev.launch_us * 0.25) / lat).clamp(0.0, 1.0);
        let mem_busy = (t_mem / lat).clamp(0.0, 1.0);
        // Issue activity: fraction of peak CUDA-core issue slots consumed
        // (tensor-core kernels charge against tensor peak).
        let f = self.features(method, s);
        let work_gops = f[2] + f[3] + f[4];
        let peak = match method {
            Method::CuBlas | Method::CuBlasPlusDequant => dev.tensor_tflops,
            _ => dev.cuda_tflops,
        };
        let issue_busy = ((2.0 * work_gops * 1e3 / lat) / peak).clamp(0.0, 1.0);
        // A gather-stalled kernel still occupies SMs: floor issue power at
        // a fraction of residency.
        let sm_frac = issue_busy.max(0.12 * resident);
        let power = dev.idle_watts + dev.dram_watts * mem_busy + dev.sm_watts * sm_frac;
        let power = power.min(dev.tdp_watts);
        let flops = 2.0 * (s.m_batch * s.n) as f64 * s.k as f64;
        let tflops = flops / (lat * 1e6);
        Telemetry {
            method: method.label(),
            latency_us: lat,
            tflops,
            power_w: power,
            gflops_per_w: tflops * 1e3 / power,
            gpu_util: 100.0 * resident,
            mem_util: 100.0 * mem_busy,
        }
    }

    /// Effective memory-bound roofline efficiency: achieved weight-stream
    /// bandwidth over device peak for this kernel.
    pub fn roofline_efficiency(&self, method: &Method, s: GemmShape) -> f64 {
        let lat = self.latency_us(method, s);
        let bytes = method.weight_bytes(s.n, s.k);
        let achieved = bytes / lat; // bytes/µs
        achieved / (self.dev.dram_gbps * 1e3)
    }
}

/// Sanity helper shared by tests and benches: does the modelled Table 3
/// preserve the paper's qualitative structure?
pub fn table3_structure_holds(rows: &[Telemetry]) -> Result<(), String> {
    let find = |name: &str| {
        rows.iter()
            .find(|t| t.method.contains(name))
            .ok_or_else(|| format!("missing row {name}"))
    };
    let cublas = find("cuBLAS")?;
    let a116 = find("AQLM-1x16")?;
    let a28 = find("AQLM-2x8")?;
    let m1v4 = find("m1v4")?;
    let m2v8 = find("m2v8")?;
    // CodeGEMM beats dequantization kernels on GFLOPS/W …
    if !(m1v4.gflops_per_w > a28.gflops_per_w && m2v8.gflops_per_w > a28.gflops_per_w) {
        return Err("CodeGEMM should lead AQLM-2x8 in GFLOPS/W".into());
    }
    if !(a28.gflops_per_w > cublas.gflops_per_w) {
        return Err("AQLM-2x8 should lead cuBLAS in GFLOPS/W".into());
    }
    // … and shows *higher* memory utilization than AQLM (structured DRAM
    // access), while cuBLAS saturates DRAM.
    if !(m1v4.mem_util > a28.mem_util && a28.mem_util > a116.mem_util) {
        return Err("mem-util ordering CodeGEMM > AQLM-2x8 > AQLM-1x16 violated".into());
    }
    if !(cublas.mem_util > 80.0) {
        return Err("cuBLAS should be DRAM-saturated".into());
    }
    // AQLM-1x16: busy SMs, idle DRAM.
    if !(a116.gpu_util > 90.0 && a116.mem_util < 15.0) {
        return Err(format!("AQLM-1x16 should spin (gpu {} mem {})", a116.gpu_util, a116.mem_util));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads::table3_shape;

    fn rows() -> Vec<Telemetry> {
        let sim = Simulator::a100();
        let s = table3_shape();
        [
            Method::CuBlas,
            Method::aqlm_1x16(),
            Method::aqlm_2x8(),
            Method::codegemm_m2v8g128(),
            Method::codegemm_m1v4g128(),
        ]
        .iter()
        .map(|m| sim.telemetry(m, s))
        .collect()
    }

    #[test]
    fn table3_qualitative_structure() {
        table3_structure_holds(&rows()).unwrap();
    }

    #[test]
    fn codegemm_tflops_exceed_cublas_effective() {
        // Paper Table 3: 6.12 vs 1.58 TFLOPS (dense-equivalent).
        let r = rows();
        let cublas = r[0].tflops;
        let m1v4 = r[4].tflops;
        assert!(m1v4 > 2.0 * cublas, "m1v4 {m1v4} vs cublas {cublas}");
    }

    #[test]
    fn power_within_board_limits() {
        for t in rows() {
            assert!(t.power_w >= 80.0 && t.power_w <= 400.0, "{}: {}W", t.method, t.power_w);
        }
    }

    #[test]
    fn memory_bound_kernels_near_roofline() {
        let sim = Simulator::a100();
        let eff = sim.roofline_efficiency(&Method::CuBlas, table3_shape());
        assert!(eff > 0.5, "cuBLAS GEMV should be near the memory roofline, got {eff}");
    }

    #[test]
    fn utilizations_are_percentages() {
        for t in rows() {
            assert!((0.0..=100.0).contains(&t.gpu_util));
            assert!((0.0..=100.0).contains(&t.mem_util));
        }
    }
}
