//! The paper's published measurements, embedded verbatim.
//!
//! These serve two purposes: (a) **calibration anchors** — the analytic
//! model's coefficients are fitted against Tables 8/9/10 (the authors' own
//! A100 numbers); (b) **validation targets** — the bench harness prints
//! paper-vs-model columns, and cross-validation tests hold rows out of the
//! fit and check they are still predicted within tolerance.

/// One Table-10 row: (M, N, K) then latency µs per method.
#[derive(Clone, Copy, Debug)]
pub struct Table10Row {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub cublas: f64,
    pub aqlm_1x16: f64,
    pub aqlm_2x8: f64,
    pub codegemm_m2v8: f64,
    pub codegemm_m1v4: f64,
    pub quip: f64,
    pub qtip: f64,
}

/// Paper Table 10: kernel latency (µs) across diverse (M, N, K).
pub const TABLE10: &[Table10Row] = &[
    Table10Row { m: 1, n: 2048, k: 2048, cublas: 19.82, aqlm_1x16: 28.84, aqlm_2x8: 20.55, codegemm_m2v8: 20.75, codegemm_m1v4: 20.66, quip: 19.47, qtip: 19.44 },
    Table10Row { m: 4, n: 2048, k: 2048, cublas: 19.99, aqlm_1x16: 74.67, aqlm_2x8: 43.31, codegemm_m2v8: 44.04, codegemm_m1v4: 41.92, quip: 36.71, qtip: 36.00 },
    Table10Row { m: 8, n: 2048, k: 2048, cublas: 19.79, aqlm_1x16: 135.36, aqlm_2x8: 73.03, codegemm_m2v8: 75.18, codegemm_m1v4: 69.72, quip: 59.44, qtip: 57.87 },
    Table10Row { m: 1, n: 8192, k: 2048, cublas: 30.57, aqlm_1x16: 28.84, aqlm_2x8: 28.83, codegemm_m2v8: 25.94, codegemm_m1v4: 26.70, quip: 25.52, qtip: 27.08 },
    Table10Row { m: 4, n: 8192, k: 2048, cublas: 31.31, aqlm_1x16: 74.67, aqlm_2x8: 76.15, codegemm_m2v8: 63.97, codegemm_m1v4: 65.36, quip: 60.70, qtip: 66.18 },
    Table10Row { m: 8, n: 8192, k: 2048, cublas: 31.70, aqlm_1x16: 135.36, aqlm_2x8: 138.09, codegemm_m2v8: 115.39, codegemm_m1v4: 116.11, quip: 107.85, qtip: 118.99 },
    Table10Row { m: 1, n: 2048, k: 8192, cublas: 27.52, aqlm_1x16: 60.47, aqlm_2x8: 30.93, codegemm_m2v8: 24.28, codegemm_m1v4: 23.81, quip: 23.44, qtip: 24.90 },
    Table10Row { m: 4, n: 2048, k: 8192, cublas: 29.82, aqlm_1x16: 203.86, aqlm_2x8: 82.18, codegemm_m2v8: 56.21, codegemm_m1v4: 52.57, quip: 51.91, qtip: 59.03 },
    Table10Row { m: 8, n: 2048, k: 8192, cublas: 28.69, aqlm_1x16: 396.44, aqlm_2x8: 149.98, codegemm_m2v8: 98.92, codegemm_m1v4: 90.73, quip: 89.91, qtip: 103.24 },
    Table10Row { m: 1, n: 4096, k: 4096, cublas: 28.00, aqlm_1x16: 63.13, aqlm_2x8: 32.28, codegemm_m2v8: 24.76, codegemm_m1v4: 24.97, quip: 23.96, qtip: 26.74 },
    Table10Row { m: 4, n: 4096, k: 4096, cublas: 28.54, aqlm_1x16: 210.03, aqlm_2x8: 89.76, codegemm_m2v8: 60.58, codegemm_m1v4: 57.79, quip: 53.92, qtip: 62.74 },
    Table10Row { m: 8, n: 4096, k: 4096, cublas: 28.11, aqlm_1x16: 396.37, aqlm_2x8: 165.49, codegemm_m2v8: 108.16, codegemm_m1v4: 103.92, quip: 93.43, qtip: 110.84 },
    Table10Row { m: 1, n: 14336, k: 4096, cublas: 88.67, aqlm_1x16: 168.12, aqlm_2x8: 64.76, codegemm_m2v8: 38.85, codegemm_m1v4: 37.51, quip: 38.91, qtip: 51.30 },
    Table10Row { m: 4, n: 14336, k: 4096, cublas: 89.08, aqlm_1x16: 632.69, aqlm_2x8: 217.68, codegemm_m2v8: 111.20, codegemm_m1v4: 106.90, quip: 113.28, qtip: 161.23 },
    Table10Row { m: 8, n: 14336, k: 4096, cublas: 89.29, aqlm_1x16: 1252.55, aqlm_2x8: 422.89, codegemm_m2v8: 211.37, codegemm_m1v4: 196.68, quip: 212.55, qtip: 308.37 },
    Table10Row { m: 1, n: 4096, k: 14336, cublas: 86.31, aqlm_1x16: 169.31, aqlm_2x8: 58.70, codegemm_m2v8: 36.15, codegemm_m1v4: 33.92, quip: 37.27, qtip: 43.85 },
    Table10Row { m: 4, n: 4096, k: 14336, cublas: 86.51, aqlm_1x16: 635.74, aqlm_2x8: 193.41, codegemm_m2v8: 103.15, codegemm_m1v4: 92.61, quip: 106.63, qtip: 133.36 },
    Table10Row { m: 8, n: 4096, k: 14336, cublas: 86.49, aqlm_1x16: 1253.11, aqlm_2x8: 372.97, codegemm_m2v8: 192.63, codegemm_m1v4: 170.16, quip: 199.31, qtip: 252.12 },
    Table10Row { m: 1, n: 8192, k: 8192, cublas: 96.40, aqlm_1x16: 188.91, aqlm_2x8: 62.50, codegemm_m2v8: 37.99, codegemm_m1v4: 35.45, quip: 38.31, qtip: 49.86 },
    Table10Row { m: 4, n: 8192, k: 8192, cublas: 100.41, aqlm_1x16: 713.24, aqlm_2x8: 208.11, codegemm_m2v8: 111.00, codegemm_m1v4: 98.66, quip: 111.08, qtip: 157.26 },
    Table10Row { m: 8, n: 8192, k: 8192, cublas: 95.45, aqlm_1x16: 1408.68, aqlm_2x8: 402.29, codegemm_m2v8: 207.73, codegemm_m1v4: 184.25, quip: 208.29, qtip: 299.24 },
    Table10Row { m: 1, n: 28672, k: 8192, cublas: 297.74, aqlm_1x16: 625.53, aqlm_2x8: 181.54, codegemm_m2v8: 86.48, codegemm_m1v4: 76.71, quip: 101.98, qtip: 134.03 },
    Table10Row { m: 4, n: 28672, k: 8192, cublas: 303.10, aqlm_1x16: 2462.88, aqlm_2x8: 684.92, codegemm_m2v8: 305.47, codegemm_m1v4: 264.31, quip: 366.74, qtip: 492.14 },
    Table10Row { m: 8, n: 28672, k: 8192, cublas: 295.11, aqlm_1x16: 4913.52, aqlm_2x8: 1355.70, codegemm_m2v8: 597.22, codegemm_m1v4: 514.85, quip: 718.13, qtip: 970.35 },
    Table10Row { m: 1, n: 8192, k: 28672, cublas: 302.42, aqlm_1x16: 618.61, aqlm_2x8: 180.38, codegemm_m2v8: 86.20, codegemm_m1v4: 76.50, quip: 101.13, qtip: 124.90 },
    Table10Row { m: 4, n: 8192, k: 28672, cublas: 292.59, aqlm_1x16: 2437.82, aqlm_2x8: 679.24, codegemm_m2v8: 305.14, codegemm_m1v4: 263.70, quip: 361.95, qtip: 455.84 },
    Table10Row { m: 8, n: 8192, k: 28672, cublas: 293.69, aqlm_1x16: 4860.85, aqlm_2x8: 1344.49, codegemm_m2v8: 596.63, codegemm_m1v4: 515.12, quip: 710.94, qtip: 897.41 },
];

/// One Table-8 row: CodeGEMM higher-bit sweep at (g=128, b=8, t_w=32,
/// t_h=2048). `m = 0` encodes the FP16 cuBLAS reference rows.
#[derive(Clone, Copy, Debug)]
pub struct Table8Row {
    pub n: usize,
    pub k: usize,
    pub m_books: usize,
    pub v: usize,
    pub bits: f64,
    pub latency: f64,
}

/// Paper Table 8 (appendix A.3), M = 1 throughout.
pub const TABLE8: &[Table8Row] = &[
    Table8Row { n: 4096, k: 4096, m_books: 0, v: 0, bits: 16.000, latency: 28.118 },
    Table8Row { n: 4096, k: 4096, m_books: 1, v: 4, bits: 2.126, latency: 25.074 },
    Table8Row { n: 4096, k: 4096, m_books: 2, v: 4, bits: 4.127, latency: 27.009 },
    Table8Row { n: 4096, k: 4096, m_books: 1, v: 8, bits: 1.127, latency: 24.015 },
    Table8Row { n: 4096, k: 4096, m_books: 2, v: 8, bits: 2.129, latency: 26.574 },
    Table8Row { n: 4096, k: 4096, m_books: 3, v: 8, bits: 3.126, latency: 27.385 },
    Table8Row { n: 4096, k: 4096, m_books: 4, v: 8, bits: 4.127, latency: 29.797 },
    Table8Row { n: 8192, k: 8192, m_books: 0, v: 0, bits: 16.000, latency: 95.785 },
    Table8Row { n: 8192, k: 8192, m_books: 1, v: 4, bits: 2.125, latency: 36.020 },
    Table8Row { n: 8192, k: 8192, m_books: 2, v: 4, bits: 4.125, latency: 49.636 },
    Table8Row { n: 8192, k: 8192, m_books: 1, v: 8, bits: 1.125, latency: 31.883 },
    Table8Row { n: 8192, k: 8192, m_books: 2, v: 8, bits: 2.126, latency: 39.040 },
    Table8Row { n: 8192, k: 8192, m_books: 3, v: 8, bits: 3.126, latency: 47.210 },
    Table8Row { n: 8192, k: 8192, m_books: 4, v: 8, bits: 4.127, latency: 58.364 },
];

/// Paper Table 9 (appendix A.4): aggregate decoder-block linear latency
/// (µs) on Llama-3-8B vs batch size.
#[derive(Clone, Copy, Debug)]
pub struct Table9Row {
    pub batch: usize,
    pub cublas: f64,
    pub dequant_stage: f64,
    pub cublas_plus_dequant: f64,
    pub aqlm_1x16: f64,
    pub aqlm_2x8: f64,
    pub quip: f64,
    pub qtip: f64,
    pub codegemm_m2v8: f64,
    pub codegemm_m1v4: f64,
}

pub const TABLE9: &[Table9Row] = &[
    Table9Row { batch: 1, cublas: 332.0, dequant_stage: 1027.0, cublas_plus_dequant: 1360.0, aqlm_1x16: 646.0, aqlm_2x8: 250.0, quip: 163.0, qtip: 190.0, codegemm_m2v8: 172.0, codegemm_m1v4: 153.0 },
    Table9Row { batch: 4, cublas: 333.0, dequant_stage: 1027.0, cublas_plus_dequant: 1361.0, aqlm_1x16: 2373.0, aqlm_2x8: 794.0, quip: 445.0, qtip: 550.0, codegemm_m2v8: 491.0, codegemm_m1v4: 405.0 },
    Table9Row { batch: 8, cublas: 336.0, dequant_stage: 1027.0, cublas_plus_dequant: 1364.0, aqlm_1x16: 4695.0, aqlm_2x8: 1515.0, quip: 818.0, qtip: 1034.0, codegemm_m2v8: 909.0, codegemm_m1v4: 744.0 },
    Table9Row { batch: 16, cublas: 340.0, dequant_stage: 1027.0, cublas_plus_dequant: 1367.0, aqlm_1x16: 9267.0, aqlm_2x8: 2959.0, quip: 1554.0, qtip: 1991.0, codegemm_m2v8: 1748.0, codegemm_m1v4: 1416.0 },
];

/// Paper Table 2: decoder-block kernel latency (µs), M = 1.
#[derive(Clone, Copy, Debug)]
pub struct Table2Row {
    pub model: &'static str,
    pub cublas: f64,
    pub lutgemm: f64,
    pub quip: f64,
    pub qtip: f64,
    pub aqlm_1x16: f64,
    pub aqlm_2x8: f64,
    pub codegemm_m2v8: f64,
    pub codegemm_m1v4: f64,
}

pub const TABLE2: &[Table2Row] = &[
    Table2Row { model: "8B", cublas: 332.45, lutgemm: 160.1, quip: 162.63, qtip: 189.94, aqlm_1x16: 645.51, aqlm_2x8: 250.12, codegemm_m2v8: 172.18, codegemm_m1v4: 152.69 },
    Table2Row { model: "70B", cublas: 1111.36, lutgemm: 299.9, quip: 403.59, qtip: 477.04, aqlm_1x16: 2285.5, aqlm_2x8: 674.67, codegemm_m2v8: 373.49, codegemm_m1v4: 293.82 },
];

/// Paper Table 3: telemetry on GEMV (1, 28672, 8192).
#[derive(Clone, Copy, Debug)]
pub struct Table3Row {
    pub method: &'static str,
    pub tflops: f64,
    pub power_w: f64,
    pub gflops_per_w: f64,
    pub gpu_util: f64,
    pub mem_util: f64,
}

pub const TABLE3: &[Table3Row] = &[
    Table3Row { method: "cuBLAS", tflops: 1.58, power_w: 318.55, gflops_per_w: 4.95, gpu_util: 96.87, mem_util: 96.94 },
    Table3Row { method: "AQLM-1x16", tflops: 0.75, power_w: 126.54, gflops_per_w: 5.93, gpu_util: 99.00, mem_util: 6.00 },
    Table3Row { method: "AQLM-2x8", tflops: 2.59, power_w: 254.20, gflops_per_w: 10.18, gpu_util: 92.84, mem_util: 19.96 },
    Table3Row { method: "CodeGEMM-m2v8g128", tflops: 5.43, power_w: 304.69, gflops_per_w: 17.83, gpu_util: 85.32, mem_util: 43.75 },
    Table3Row { method: "CodeGEMM-m1v4g128", tflops: 6.12, power_w: 316.38, gflops_per_w: 19.36, gpu_util: 84.47, mem_util: 49.80 },
];

/// Paper Table 7 (appendix A.2): tile-size sensitivity, M = 1.
#[derive(Clone, Copy, Debug)]
pub struct Table7Row {
    pub n: usize,
    pub k: usize,
    pub tile_w: usize,
    pub tile_h: usize,
    pub m2v8: f64,
    pub m1v4: f64,
}

pub const TABLE7: &[Table7Row] = &[
    Table7Row { n: 4096, k: 4096, tile_w: 32, tile_h: 2048, m2v8: 26.57, m1v4: 25.07 },
    Table7Row { n: 4096, k: 4096, tile_w: 64, tile_h: 2048, m2v8: 26.76, m1v4: 25.40 },
    Table7Row { n: 4096, k: 4096, tile_w: 128, tile_h: 2048, m2v8: 29.61, m1v4: 26.81 },
    Table7Row { n: 4096, k: 4096, tile_w: 32, tile_h: 4096, m2v8: 28.95, m1v4: 27.60 },
    Table7Row { n: 4096, k: 4096, tile_w: 64, tile_h: 4096, m2v8: 28.49, m1v4: 27.68 },
    Table7Row { n: 4096, k: 4096, tile_w: 128, tile_h: 4096, m2v8: 37.58, m1v4: 32.87 },
    Table7Row { n: 8192, k: 8192, tile_w: 32, tile_h: 2048, m2v8: 39.04, m1v4: 36.02 },
    Table7Row { n: 8192, k: 8192, tile_w: 64, tile_h: 2048, m2v8: 37.23, m1v4: 35.33 },
    Table7Row { n: 8192, k: 8192, tile_w: 128, tile_h: 2048, m2v8: 40.09, m1v4: 38.54 },
    Table7Row { n: 8192, k: 8192, tile_w: 32, tile_h: 4096, m2v8: 37.78, m1v4: 36.17 },
    Table7Row { n: 8192, k: 8192, tile_w: 64, tile_h: 4096, m2v8: 38.29, m1v4: 37.70 },
    Table7Row { n: 8192, k: 8192, tile_w: 128, tile_h: 4096, m2v8: 45.40, m1v4: 42.75 },
];

/// Paper Table 6 (appendix A.1): Psumbook build/read cycle share (%).
#[derive(Clone, Copy, Debug)]
pub struct Table6Row {
    pub m_batch: usize,
    pub n: usize,
    pub k: usize,
    pub tile_w: usize,
    pub build_m2v8: f64,
    pub build_m1v4: f64,
}

pub const TABLE6: &[Table6Row] = &[
    Table6Row { m_batch: 1, n: 4096, k: 4096, tile_w: 32, build_m2v8: 30.5, build_m1v4: 20.3 },
    Table6Row { m_batch: 1, n: 4096, k: 4096, tile_w: 64, build_m2v8: 33.0, build_m1v4: 28.5 },
    Table6Row { m_batch: 1, n: 4096, k: 4096, tile_w: 128, build_m2v8: 31.2, build_m1v4: 30.7 },
    Table6Row { m_batch: 1, n: 8192, k: 8192, tile_w: 32, build_m2v8: 45.4, build_m1v4: 41.2 },
    Table6Row { m_batch: 1, n: 8192, k: 8192, tile_w: 64, build_m2v8: 45.6, build_m1v4: 39.7 },
    Table6Row { m_batch: 1, n: 8192, k: 8192, tile_w: 128, build_m2v8: 28.3, build_m1v4: 29.5 },
    Table6Row { m_batch: 4, n: 4096, k: 4096, tile_w: 32, build_m2v8: 30.4, build_m1v4: 20.7 },
    Table6Row { m_batch: 8, n: 4096, k: 4096, tile_w: 32, build_m2v8: 30.7, build_m1v4: 20.4 },
    Table6Row { m_batch: 4, n: 8192, k: 8192, tile_w: 32, build_m2v8: 45.7, build_m1v4: 41.3 },
    Table6Row { m_batch: 8, n: 8192, k: 8192, tile_w: 32, build_m2v8: 46.1, build_m1v4: 41.6 },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table10_complete() {
        assert_eq!(TABLE10.len(), 27);
        // Paper headline: CodeGEMM beats AQLM-2x8 on all large shapes.
        for r in TABLE10.iter().filter(|r| r.n * r.k >= 8192 * 4096) {
            assert!(r.codegemm_m1v4 < r.aqlm_2x8);
        }
    }

    #[test]
    fn table8_bits_match_eq1() {
        use crate::config::QuantConfig;
        use crate::quant::footprint::bits_per_weight;
        for r in TABLE8.iter().filter(|r| r.m_books > 0) {
            let cfg = QuantConfig::new(r.v, r.m_books, 8, 128).unwrap();
            let q = bits_per_weight(&cfg, r.n, r.k).total;
            assert!((q - r.bits).abs() < 0.01, "(m{},v{}) q̄={q} vs paper {}", r.m_books, r.v, r.bits);
        }
    }

    #[test]
    fn table9_block_consistency_with_table2() {
        // Table 9 BS=1 row should match Table 2's 8B row (same workload).
        let t9 = &TABLE9[0];
        let t2 = &TABLE2[0];
        assert!((t9.codegemm_m1v4 - t2.codegemm_m1v4).abs() < 1.0);
        assert!((t9.aqlm_2x8 - t2.aqlm_2x8).abs() < 1.0);
    }

    #[test]
    fn headline_speedups_derivable() {
        // 1.83× (8B) and 8.93× (70B) vs AQLM-1x16 at comparable accuracy.
        // Table 4/5 tok/s: 228.3/124.5 = 1.83; 51.2/5.5 ≈ 9.3 (throughput).
        assert!((228.3f64 / 124.5 - 1.83).abs() < 0.01);
        assert!((TABLE2[1].aqlm_1x16 / TABLE2[1].codegemm_m1v4 - 7.78).abs() < 0.1);
    }
}
