//! Streaming log-bucketed histograms with **fixed memory**, mergeable
//! state and percentile queries.
//!
//! ## Error model
//!
//! Buckets are geometric: bucket `i` covers
//! `[MIN_VALUE·G^i, MIN_VALUE·G^(i+1))` with growth factor
//! `G = 2^(1/BUCKETS_PER_OCTAVE)`. A recorded value is represented by the
//! geometric midpoint of its bucket, so any quantile query is within a
//! **relative error of `G^(1/2) − 1`** of the true order statistic
//! (≈ 2.2% at the default 16 buckets/octave), independent of how many
//! samples were recorded. Quantile results are additionally clamped to
//! the exactly-tracked `[min, max]`, so 0th/100th percentiles are exact.
//!
//! The bucket array is allocated once at construction
//! ([`Histogram::footprint_bytes`] is constant forever after): recording
//! the 10^9th sample costs the same memory as the first. `count`, `sum`,
//! `sum_sq`, `min` and `max` are tracked exactly, so `mean` and `std`
//! carry no bucket error at all.
//!
//! ## Mergeability
//!
//! All histograms share one bucket geometry, so [`Histogram::merge`] is
//! element-wise addition — commutative and associative (pinned by
//! property tests in `tests/obs_prop.rs`), which is what lets per-shard
//! or per-replica recorders be combined without resampling.

use crate::util::stats::Summary;

/// Smallest resolvable value (seconds-flavored: 1 ns). Everything at or
/// below it lands in bucket 0.
pub const MIN_VALUE: f64 = 1e-9;

/// Buckets per doubling of the value. 16 ⇒ ≤ 2.2% relative quantile error.
pub const BUCKETS_PER_OCTAVE: usize = 16;

/// Octaves covered above [`MIN_VALUE`]: 60 doublings spans 1 ns ..
/// ~1.15e9 s. Values beyond the top land in the last bucket (and `max`
/// stays exact).
pub const OCTAVES: usize = 60;

/// Total bucket count.
pub const N_BUCKETS: usize = BUCKETS_PER_OCTAVE * OCTAVES;

/// A streaming histogram over positive values (latencies, durations).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Fixed-size bucket counts — the only O(buckets) storage; never
    /// grows after construction.
    counts: Box<[u64]>,
    count: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0u64; N_BUCKETS].into_boxed_slice(),
            count: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Bucket index for a value (total: non-finite and non-positive
    /// values clamp into the extreme buckets).
    fn bucket(v: f64) -> usize {
        if !(v > MIN_VALUE) {
            return 0;
        }
        let i = ((v / MIN_VALUE).log2() * BUCKETS_PER_OCTAVE as f64) as usize;
        i.min(N_BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` — the representative value
    /// returned by quantile queries.
    fn representative(i: usize) -> f64 {
        MIN_VALUE * ((i as f64 + 0.5) / BUCKETS_PER_OCTAVE as f64).exp2()
    }

    /// Record one sample.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.counts[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Element-wise merge — associative and commutative because every
    /// histogram shares the same bucket geometry.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact population standard deviation (0 for < 2 samples).
    pub fn std_dev(&self) -> f64 {
        if self.count < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.sum_sq / self.count as f64 - m * m).max(0.0).sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Quantile query, `q` in `[0, 1]`: walk the cumulative bucket counts
    /// to the target rank, return the bucket's geometric midpoint clamped
    /// to the exact `[min, max]`. 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > target {
                return Self::representative(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Percentile query, `p` in `[0, 100]` (matches `util::stats`).
    pub fn percentile(&self, p: f64) -> f64 {
        self.quantile(p / 100.0)
    }

    /// Summary view matching `util::stats::Summary` (mean/std/min/max
    /// exact, p50/p95/p99 within bucket error).
    pub fn summary(&self) -> Summary {
        Summary {
            n: self.count as usize,
            mean: self.mean(),
            std: self.std_dev(),
            min: self.min(),
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            max: self.max(),
        }
    }

    /// Heap + inline footprint — constant for the histogram's lifetime
    /// (the memory-boundedness contract; pinned by tests).
    pub fn footprint_bytes(&self) -> usize {
        std::mem::size_of::<Histogram>() + self.counts.len() * std::mem::size_of::<u64>()
    }

    /// Worst-case relative quantile error of this bucket geometry (the
    /// half-bucket width): values are reported at their bucket's
    /// geometric midpoint.
    pub fn relative_error_bound() -> f64 {
        (0.5 / BUCKETS_PER_OCTAVE as f64).exp2() - 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_total() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        let s = h.summary();
        assert_eq!(s.n, 0);
        assert_eq!(s.p99, 0.0);
    }

    #[test]
    fn single_sample_is_exact_at_extremes() {
        let mut h = Histogram::new();
        h.record(0.125);
        // min/max clamp makes every quantile of a single sample exact.
        assert_eq!(h.quantile(0.0), 0.125);
        assert_eq!(h.quantile(0.5), 0.125);
        assert_eq!(h.quantile(1.0), 0.125);
        assert_eq!(h.mean(), 0.125);
    }

    #[test]
    fn quantiles_within_relative_error() {
        let mut h = Histogram::new();
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64 * 1e-3).collect();
        for &x in &xs {
            h.record(x);
        }
        let tol = Histogram::relative_error_bound() + 1e-3; // + rank granularity
        for (q, exact) in [(0.5, 0.5), (0.95, 0.95), (0.99, 0.99)] {
            let got = h.quantile(q);
            let rel = (got - exact).abs() / exact;
            assert!(rel <= tol + 0.01, "q{q}: got {got}, exact {exact}, rel {rel}");
        }
        assert!((h.mean() - 0.5005).abs() < 1e-9, "mean is exact");
    }

    #[test]
    fn degenerate_values_clamp_not_panic() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(-5.0);
        h.record(1e300); // far beyond the top octave
        h.record(f64::NAN); // dropped
        h.record(f64::INFINITY); // dropped
        assert_eq!(h.count(), 3);
        assert_eq!(h.max(), 1e300);
        assert_eq!(h.min(), -5.0);
        // Top-bucket representative is clamped to the exact max.
        assert_eq!(h.quantile(1.0), 1e300);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let (mut a, mut b, mut both) = (Histogram::new(), Histogram::new(), Histogram::new());
        for i in 0..100 {
            let x = 1e-4 * (i + 1) as f64;
            if i % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            both.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.quantile(0.5), both.quantile(0.5));
        assert_eq!(a.sum(), both.sum());
        assert_eq!(a.min(), both.min());
        assert_eq!(a.max(), both.max());
    }

    #[test]
    fn footprint_is_constant() {
        let mut h = Histogram::new();
        let fp0 = h.footprint_bytes();
        for i in 0..50_000 {
            h.record((i % 997) as f64 * 1e-5 + 1e-6);
        }
        assert_eq!(h.footprint_bytes(), fp0, "recording must never allocate");
    }
}
