//! Request lifecycle tracing: one [`SpanRecord`] per finished request,
//! kept in a bounded ring ([`TraceLog`]).
//!
//! ## Span schema
//!
//! A request's life is `submit → (queue) → admit → prefill chunks →
//! first token → decode → finish`; the span attributes wall time to each
//! segment:
//!
//! | field            | meaning                                         |
//! |------------------|-------------------------------------------------|
//! | `queue_wait_s`   | submit → admission into a slot                  |
//! | `prefill_s`      | admission → last prompt chunk consumed          |
//! | `ttft_s`         | submit → first generated token (client-visible) |
//! | `decode_s`       | first token → finish                            |
//! | `latency_s`      | submit → finish (= queue + prefill + decode up  |
//! |                  | to scheduler quantization)                      |
//! | `tpot_s`         | decode seconds per generated token after the    |
//! |                  | first (0 when < 2 tokens were generated)        |
//! | `prefill_chunks` | scheduler steps that fed prompt tokens (> 1 ⇒   |
//! |                  | the shared prefill budget split this prompt)    |
//! | `preemptions`    | times the request was swapped out of its slot   |
//! |                  | mid-decode (spilled or dropped for recompute)   |
//! | `prefix_hit_tokens` | prompt tokens served from pinned prefix-cache |
//! |                  | pages instead of prefill at (re-)admission      |
//!
//! Token counts and the finish reason make the *structural* part of a
//! span: two runs of the same seeded workload produce identical
//! structural spans (timing fields aside), which is what the scenario
//! harness's determinism check compares ([`SpanRecord::structural_key`]).
//!
//! The ring keeps the most recent [`TraceLog::capacity`] spans — memory
//! is bounded no matter how many requests are served; `total()` still
//! counts every span ever pushed.

use crate::util::json::Json;

/// Why a traced request finished (stringly-typed so the trace schema is
/// decoupled from `coordinator::FinishReason`).
pub const FINISH_LENGTH: &str = "length";
pub const FINISH_STOP: &str = "stop";
pub const FINISH_CONTEXT: &str = "context";
pub const FINISH_REJECTED: &str = "rejected";

/// Lifecycle record of one finished request. Times in seconds; `*_s`
/// segments as documented in the module header.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanRecord {
    pub id: u64,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub finish: &'static str,
    pub queue_wait_s: f64,
    pub prefill_s: f64,
    pub ttft_s: f64,
    pub decode_s: f64,
    pub latency_s: f64,
    pub tpot_s: f64,
    pub prefill_chunks: u32,
    /// Times this request was swapped out of a slot mid-decode.
    pub preemptions: u32,
    /// Prompt tokens served from pinned prefix-cache pages instead of
    /// prefill at (re-)admission.
    pub prefix_hit_tokens: usize,
}

impl SpanRecord {
    /// The timing-free projection of the span: everything two runs of
    /// the same seeded workload must agree on exactly.
    pub fn structural_key(&self) -> (u64, usize, usize, &'static str) {
        (self.id, self.prompt_tokens, self.generated_tokens, self.finish)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::from(self.id as usize)),
            ("prompt_tokens", Json::from(self.prompt_tokens)),
            ("generated_tokens", Json::from(self.generated_tokens)),
            ("finish", Json::from(self.finish)),
            ("queue_wait_s", Json::Num(self.queue_wait_s)),
            ("prefill_s", Json::Num(self.prefill_s)),
            ("ttft_s", Json::Num(self.ttft_s)),
            ("decode_s", Json::Num(self.decode_s)),
            ("latency_s", Json::Num(self.latency_s)),
            ("tpot_s", Json::Num(self.tpot_s)),
            ("prefill_chunks", Json::from(self.prefill_chunks as usize)),
            ("preemptions", Json::from(self.preemptions as usize)),
            ("prefix_hit_tokens", Json::from(self.prefix_hit_tokens)),
        ])
    }

    /// One-line rendering for `MetricsReport::render`.
    pub fn render(&self) -> String {
        let mut out = format!(
            "id {} [{}]: {}+{} tok, wait {:.1} ms, prefill {:.1} ms ({} chunks), \
             ttft {:.1} ms, decode {:.1} ms",
            self.id,
            self.finish,
            self.prompt_tokens,
            self.generated_tokens,
            self.queue_wait_s * 1e3,
            self.prefill_s * 1e3,
            self.prefill_chunks,
            self.ttft_s * 1e3,
            self.decode_s * 1e3,
        );
        if self.prefix_hit_tokens > 0 {
            out.push_str(&format!(", {} cached tok", self.prefix_hit_tokens));
        }
        if self.preemptions > 0 {
            out.push_str(&format!(", {} preemptions", self.preemptions));
        }
        out
    }
}

/// Bounded ring of recent spans. Push is O(1); memory is
/// `capacity × size_of::<SpanRecord>` forever.
#[derive(Clone, Debug)]
pub struct TraceLog {
    spans: Vec<SpanRecord>,
    /// Next write position in the ring.
    head: usize,
    /// Spans ever pushed (not just retained).
    total: u64,
    capacity: usize,
}

impl Default for TraceLog {
    fn default() -> Self {
        TraceLog::with_capacity(TraceLog::DEFAULT_CAPACITY)
    }
}

impl TraceLog {
    /// Default retained-span budget: enough to inspect a serving burst,
    /// small enough to be irrelevant next to the model.
    pub const DEFAULT_CAPACITY: usize = 256;

    pub fn with_capacity(capacity: usize) -> TraceLog {
        let capacity = capacity.max(1);
        TraceLog { spans: Vec::with_capacity(capacity), head: 0, total: 0, capacity }
    }

    pub fn push(&mut self, span: SpanRecord) {
        if self.spans.len() < self.capacity {
            self.spans.push(span);
        } else {
            self.spans[self.head] = span;
        }
        self.head = (self.head + 1) % self.capacity;
        self.total += 1;
    }

    /// Spans ever recorded (including evicted ones).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Retained spans, oldest → newest.
    pub fn recent(&self) -> Vec<SpanRecord> {
        if self.spans.len() < self.capacity {
            return self.spans.clone();
        }
        let mut out = Vec::with_capacity(self.capacity);
        for i in 0..self.capacity {
            out.push(self.spans[(self.head + i) % self.capacity].clone());
        }
        out
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Spans evicted by the bounded ring (`total - retained`) — nonzero
    /// means the retained window is a *truncated* view of the run, which
    /// the metrics report and bench artifact surface so a clipped trace
    /// is never mistaken for a complete one.
    pub fn dropped(&self) -> u64 {
        self.total - self.spans.len() as u64
    }

    /// Ring storage footprint — constant once the ring has filled.
    pub fn footprint_bytes(&self) -> usize {
        std::mem::size_of::<TraceLog>()
            + self.spans.capacity() * std::mem::size_of::<SpanRecord>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64) -> SpanRecord {
        SpanRecord {
            id,
            prompt_tokens: 3,
            generated_tokens: 4,
            finish: FINISH_LENGTH,
            queue_wait_s: 0.001,
            prefill_s: 0.002,
            ttft_s: 0.003,
            decode_s: 0.004,
            latency_s: 0.007,
            tpot_s: 0.001,
            prefill_chunks: 1,
            preemptions: 2,
            prefix_hit_tokens: 16,
        }
    }

    #[test]
    fn ring_keeps_most_recent() {
        let mut log = TraceLog::with_capacity(4);
        for id in 0..10 {
            log.push(span(id));
        }
        assert_eq!(log.total(), 10);
        let ids: Vec<u64> = log.recent().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9], "oldest → newest of the last 4");
    }

    #[test]
    fn overflow_is_counted_never_silent() {
        let mut log = TraceLog::with_capacity(4);
        for id in 0..3 {
            log.push(span(id));
        }
        assert_eq!(log.dropped(), 0, "under capacity nothing drops");
        for id in 3..11 {
            log.push(span(id));
        }
        assert_eq!(log.total(), 11);
        assert_eq!(log.recent().len(), 4);
        assert_eq!(log.dropped(), 7, "evicted spans must be counted");
    }

    #[test]
    fn footprint_bounded_under_many_pushes() {
        let mut log = TraceLog::with_capacity(8);
        for id in 0..8 {
            log.push(span(id));
        }
        let fp = log.footprint_bytes();
        for id in 8..10_000 {
            log.push(span(id));
        }
        assert_eq!(log.footprint_bytes(), fp);
        assert_eq!(log.total(), 10_000);
    }

    #[test]
    fn span_json_has_schema_fields() {
        let j = span(7).to_json();
        assert_eq!(j.req_usize("id").unwrap(), 7);
        assert_eq!(j.req_str("finish").unwrap(), FINISH_LENGTH);
        assert_eq!(j.req_usize("prefill_chunks").unwrap(), 1);
        assert_eq!(j.req_usize("preemptions").unwrap(), 2);
        assert_eq!(j.req_usize("prefix_hit_tokens").unwrap(), 16);
        assert!((j.req_f64("ttft_s").unwrap() - 0.003).abs() < 1e-12);
    }

    #[test]
    fn structural_key_ignores_timing() {
        let mut a = span(1);
        let mut b = span(1);
        a.ttft_s = 0.5;
        b.ttft_s = 0.9;
        assert_eq!(a.structural_key(), b.structural_key());
    }
}
