//! Trace-driven scenario harness: seeded workload mixes, arrival
//! processes and SLO declarations for the serving coordinator.
//!
//! A [`WorkloadMix`] names a set of request classes (prompt/generation
//! length ranges + mix weights), an [`Arrival`] process, and the
//! [`Slo`] the mix is served against. [`generate`] expands a mix into a
//! concrete request trace **deterministically from a seed** (same seed ⇒
//! same prompts, lengths and arrival offsets, bit-for-bit — the property
//! the BENCH artifact's repeatability contract rests on), and [`drive`]
//! plays the trace through a [`crate::coordinator::Server`].
//!
//! The built-in mixes mirror the traffic classes the ROADMAP calls out:
//! chat (short prompt / short gen), RAG (long prompt / short gen),
//! long-form generation, a bursty Poisson-arrival chat mix, and a
//! weighted blend of all three request classes.

use crate::coordinator::{Request, Server};
use crate::util::prng::Prng;

/// One request class in a mix.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadClass {
    pub name: &'static str,
    /// Relative mix weight (unnormalized).
    pub weight: f64,
    /// Prompt length range `[lo, hi]`, tokens.
    pub prompt: (usize, usize),
    /// Generation budget range `[lo, hi]`, tokens.
    pub gen: (usize, usize),
}

/// Arrival process for a mix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// All requests submitted up front (offline/batch serving).
    Batch,
    /// Poisson arrivals at `rate_per_s` (bursty online serving); offsets
    /// are drawn from the seeded PRNG, so the trace stays deterministic.
    Poisson { rate_per_s: f64 },
}

/// Declared service-level objectives for a mix (advisory: the harness
/// reports pass/fail next to the measured percentiles).
#[derive(Clone, Copy, Debug)]
pub struct Slo {
    /// p99 time-to-first-token bound (seconds).
    pub ttft_p99_s: f64,
    /// p95 time-per-output-token bound (seconds).
    pub tpot_p95_s: f64,
    /// Minimum aggregate decode throughput (tokens/second).
    pub min_decode_tok_s: f64,
}

/// A named workload mix.
#[derive(Clone, Debug)]
pub struct WorkloadMix {
    pub name: &'static str,
    pub classes: Vec<WorkloadClass>,
    pub arrival: Arrival,
    pub slo: Slo,
}

const CHAT: WorkloadClass =
    WorkloadClass { name: "chat", weight: 1.0, prompt: (4, 16), gen: (8, 16) };
const RAG: WorkloadClass =
    WorkloadClass { name: "rag", weight: 1.0, prompt: (48, 96), gen: (4, 12) };
const LONGFORM: WorkloadClass =
    WorkloadClass { name: "longform", weight: 1.0, prompt: (4, 8), gen: (32, 64) };

/// Default (deliberately loose, CPU-reference-model-friendly) SLOs.
const DEFAULT_SLO: Slo =
    Slo { ttft_p99_s: 5.0, tpot_p95_s: 0.5, min_decode_tok_s: 1.0 };

impl WorkloadMix {
    /// Look up a built-in mix by name.
    pub fn by_name(name: &str) -> Option<WorkloadMix> {
        let mix = |name, classes: Vec<WorkloadClass>, arrival| WorkloadMix {
            name,
            classes,
            arrival,
            slo: DEFAULT_SLO,
        };
        match name {
            "chat" => Some(mix("chat", vec![CHAT], Arrival::Batch)),
            "rag" => Some(mix("rag", vec![RAG], Arrival::Batch)),
            "longform" => Some(mix("longform", vec![LONGFORM], Arrival::Batch)),
            "bursty" => Some(mix("bursty", vec![CHAT], Arrival::Poisson { rate_per_s: 50.0 })),
            "mixed" => Some(WorkloadMix {
                name: "mixed",
                classes: vec![
                    WorkloadClass { weight: 3.0, ..CHAT },
                    WorkloadClass { weight: 1.0, ..RAG },
                    WorkloadClass { weight: 1.0, ..LONGFORM },
                ],
                arrival: Arrival::Poisson { rate_per_s: 50.0 },
                slo: DEFAULT_SLO,
            }),
            _ => None,
        }
    }

    /// Names accepted by [`WorkloadMix::by_name`].
    pub fn names() -> &'static [&'static str] {
        &["chat", "rag", "longform", "bursty", "mixed"]
    }
}

/// One concrete request of a generated trace.
#[derive(Clone, Debug, PartialEq)]
pub struct GenRequest {
    /// Trace-local id (0-based submission order).
    pub id: u64,
    /// Arrival offset from the trace start (seconds; 0 under batch
    /// arrivals).
    pub at_s: f64,
    /// Which class of the mix produced it.
    pub class: &'static str,
    pub prompt: Vec<usize>,
    pub max_new_tokens: usize,
}

/// Expand `mix` into `n` concrete requests, deterministically from
/// `seed`. Tokens are drawn in `[1, vocab)` (0 is reserved).
pub fn generate(mix: &WorkloadMix, seed: u64, n: usize, vocab: usize) -> Vec<GenRequest> {
    assert!(vocab >= 2, "vocab too small for token draws");
    let mut rng = Prng::seeded(seed);
    let weights: Vec<f64> = mix.classes.iter().map(|c| c.weight).collect();
    let mut at = 0.0f64;
    (0..n as u64)
        .map(|id| {
            let class = &mix.classes[rng.weighted_index(&weights)];
            let span = |(lo, hi): (usize, usize), rng: &mut Prng| {
                lo + rng.index(hi - lo + 1)
            };
            let prompt_len = span(class.prompt, &mut rng);
            let max_new = span(class.gen, &mut rng);
            let prompt: Vec<usize> =
                (0..prompt_len).map(|_| rng.index(vocab - 1) + 1).collect();
            if let Arrival::Poisson { rate_per_s } = mix.arrival {
                // Exponential inter-arrival; guard ln(0).
                at += -(1.0 - rng.uniform()).ln() / rate_per_s.max(1e-9);
            }
            GenRequest { id, at_s: at, class: class.name, prompt, max_new_tokens: max_new }
        })
        .collect()
}

/// Play a generated trace through the server: submit each request at its
/// arrival offset (sleeping between arrivals when the trace has them),
/// then wait for every response. Returns responses in submission order.
pub fn drive(server: &Server, trace: &[GenRequest]) -> Vec<crate::coordinator::Response> {
    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(trace.len());
    for r in trace {
        let wait = r.at_s - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(wait));
        }
        handles.push(server.submit(Request::new(r.id, r.prompt.clone(), r.max_new_tokens)));
    }
    handles.into_iter().map(|h| h.wait()).collect()
}

/// Evaluate the declared SLOs against a metrics report. Returns
/// human-readable violations (empty ⇒ all SLOs met).
pub fn check_slo(slo: &Slo, report: &crate::coordinator::MetricsReport) -> Vec<String> {
    let mut v = Vec::new();
    if report.ttft.p99 > slo.ttft_p99_s {
        v.push(format!(
            "ttft p99 {:.1} ms exceeds SLO {:.1} ms",
            report.ttft.p99 * 1e3,
            slo.ttft_p99_s * 1e3
        ));
    }
    if report.tpot.p95 > slo.tpot_p95_s {
        v.push(format!(
            "tpot p95 {:.1} ms exceeds SLO {:.1} ms",
            report.tpot.p95 * 1e3,
            slo.tpot_p95_s * 1e3
        ));
    }
    if report.tokens_per_s < slo.min_decode_tok_s && report.decode_tokens > 0 {
        v.push(format!(
            "decode throughput {:.1} tok/s below SLO {:.1} tok/s",
            report.tokens_per_s, slo.min_decode_tok_s
        ));
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let mix = WorkloadMix::by_name("mixed").unwrap();
        let a = generate(&mix, 7, 32, 256);
        let b = generate(&mix, 7, 32, 256);
        assert_eq!(a, b, "same seed must reproduce the trace bit-for-bit");
        let c = generate(&mix, 8, 32, 256);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn class_ranges_respected() {
        for name in WorkloadMix::names() {
            let mix = WorkloadMix::by_name(name).unwrap();
            let trace = generate(&mix, 3, 64, 256);
            assert_eq!(trace.len(), 64);
            for r in &trace {
                let class = mix.classes.iter().find(|c| c.name == r.class).unwrap();
                assert!(r.prompt.len() >= class.prompt.0 && r.prompt.len() <= class.prompt.1);
                assert!(r.max_new_tokens >= class.gen.0 && r.max_new_tokens <= class.gen.1);
                assert!(r.prompt.iter().all(|&t| t >= 1 && t < 256));
                // The tiny reference model's window fits every class.
                assert!(r.prompt.len() + r.max_new_tokens <= 128);
            }
        }
    }

    #[test]
    fn poisson_arrivals_increase_batch_stay_zero() {
        let bursty = WorkloadMix::by_name("bursty").unwrap();
        let trace = generate(&bursty, 5, 16, 256);
        for w in trace.windows(2) {
            assert!(w[1].at_s >= w[0].at_s, "arrival offsets must be monotone");
        }
        assert!(trace.last().unwrap().at_s > 0.0);
        let chat = WorkloadMix::by_name("chat").unwrap();
        assert!(generate(&chat, 5, 16, 256).iter().all(|r| r.at_s == 0.0));
    }

    #[test]
    fn unknown_mix_is_none() {
        assert!(WorkloadMix::by_name("nope").is_none());
    }
}
