//! Versioned perf artifact (`BENCH_<n>.json`) and the regression
//! comparator that diffs two artifacts.
//!
//! ## BENCH JSON schema (version 1)
//!
//! Top-level object fields:
//!
//! - `schema_version` (int) — see the versioning policy below
//! - `bench_id`, `workload`, `backend` (str); `seed`, `requests` (int)
//! - headline gauges (floats): `decode_tok_s`, `ttft_p50_s`/`ttft_p99_s`,
//!   `tpot_p50_s`/`tpot_p99_s`, `latency_p50_s`/`latency_p99_s`,
//!   `queue_wait_p99_s`, `mean_batch`, `build_share_ops`
//! - kernel dispatch gauges: `kernel_impl` (str) and `simd_lanes` (int)
//!   — the resolved CodeGEMM kernel the run dispatched to (added within
//!   schema v1; older artifacts lack them and parse as `""` / `0`)
//! - contention gauges: `prefix_hit_rate` (float) and `preemptions`
//!   (int) — prefix-cache effectiveness and scheduler preemptions
//!   (added within schema v1; older artifacts lack them and parse as
//!   `0.0` / `0`)
//! - counters (ints): `completed`, `rejected`, `infeasible`, `deferred`,
//!   `kv_used_hwm_pages`, `kv_total_pages`
//! - KV codec gauges (added within schema v1; older artifacts lack them
//!   and parse as `"f32"` / `0` / `0`): `kv_dtype` (the pool's page
//!   codec — `f32`/`f16`/`int8`), `kv_page_bytes` (coded bytes per pool
//!   page, scale sidecar included) and `kv_held_bytes` (coded bytes held
//!   across slots at the final snapshot) — the gauges that make dtype
//!   shrink visible and comparable across artifacts
//! - profiler gauges (added within schema v1; older artifacts lack them
//!   and parse as `0` / `0.0` / `""`): `spans_dropped` (spans evicted
//!   from the bounded metrics ring — nonzero ⇒ the artifact's `spans`
//!   are a truncated view), `overlap_efficiency` and `prof_occupancy`
//!   (pipeline hidden-build share and mean per-barrier worker occupancy
//!   from `obs::prof`), `gather_gbs_achieved` / `gather_gbs_peak`
//!   (gather-phase bandwidth vs the STREAM-calibrated peak), and
//!   `footprint_bytes` / `footprint_level` (engine-scratch working set
//!   and the cache level it fits)
//! - repeat gauges (added within schema v1): `repeats` (measurement
//!   repetitions aggregated into this artifact; older artifacts parse
//!   as 1) and `spread` — array of `{gauge, min, max, stddev}` rows
//!   characterizing the run-to-run spread of the headline gauges across
//!   the repeats (empty for single runs)
//! - `phase_shares` — array of `{name, share}` step-phase attribution
//!   rows (shares of the total attributed seconds)
//! - `slo_violations` — array of strings (empty ⇒ all SLOs met)
//! - `spans` — array of span objects (see `obs::trace` for the fields);
//!   the timing-free part of each span is the run's *structural trace*,
//!   identical across same-seed runs
//!
//! ## Versioning policy
//!
//! `SCHEMA_VERSION` bumps only on breaking changes (field removal,
//! rename, or semantic change); adding fields is allowed within a
//! version. [`BenchArtifact::load`] refuses artifacts from a *newer*
//! schema (forward compatibility is not promised) and accepts older
//! ones as far as the required fields allow.
//!
//! ## Comparator
//!
//! [`compare`] flags regressions beyond a relative `threshold` on the
//! throughput/latency headline gauges: decode tok/s dropping, or p99
//! TTFT / p99 TPOT rising. It returns human-readable findings; the
//! `bench-serve` CLI exits nonzero on any finding unless run in
//! advisory mode.

use crate::coordinator::MetricsReport;
use crate::util::json::Json;

/// Current BENCH artifact schema version.
pub const SCHEMA_VERSION: usize = 1;

/// One serving-bench result, shaped for `BENCH_<n>.json`.
#[derive(Clone, Debug)]
pub struct BenchArtifact {
    pub schema_version: usize,
    pub bench_id: String,
    pub workload: String,
    pub seed: u64,
    pub requests: usize,
    pub backend: String,
    pub decode_tok_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tpot_p50_s: f64,
    pub tpot_p99_s: f64,
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
    pub queue_wait_p99_s: f64,
    pub mean_batch: f64,
    pub completed: u64,
    pub rejected: u64,
    pub infeasible: u64,
    pub deferred: u64,
    /// Step-phase attribution: `(phase name, share of attributed time)`.
    pub phase_shares: Vec<(String, f64)>,
    /// Engine Psumbook build share by MACs (0 when the backend has no
    /// engine counters).
    pub build_share_ops: f64,
    /// Resolved CodeGEMM kernel implementation label (`scalar` /
    /// `unrolled` / `avx2`; `""` when the backend has no kernel layer or
    /// the artifact predates the gauge).
    pub kernel_impl: String,
    /// Lane width of the resolved kernel (0 when absent, matching
    /// `kernel_impl`).
    pub simd_lanes: usize,
    /// Fraction of prefix-cache probes that pinned shared pages (0.0
    /// when the cache is off, never consulted, or the artifact predates
    /// the gauge).
    pub prefix_hit_rate: f64,
    /// Decoding slots swapped out for higher-priority admissions (0 for
    /// uncontended runs and artifacts predating the gauge).
    pub preemptions: u64,
    pub kv_used_hwm_pages: usize,
    pub kv_total_pages: usize,
    /// KV pool page codec (`"f32"`/`"f16"`/`"int8"`); artifacts
    /// predating the gauge parse as `"f32"` — the only dtype that
    /// existed then.
    pub kv_dtype: String,
    /// Coded bytes per pool page, scale sidecar included (0 when no pool
    /// or predating the gauge).
    pub kv_page_bytes: usize,
    /// Coded bytes held across slots at the final KV snapshot (0 when
    /// absent, matching `kv_page_bytes`).
    pub kv_held_bytes: usize,
    /// Spans evicted from the bounded metrics ring during the run — 0
    /// means `spans` is the complete trace (or the artifact predates the
    /// gauge), nonzero that it is a truncated view.
    pub spans_dropped: u64,
    /// Kernel-profiler pipeline overlap efficiency (hidden build seconds
    /// over total build seconds; 0.0 untraced or predating the gauge).
    pub overlap_efficiency: f64,
    /// Mean per-barrier worker occupancy from the profiler (0.0 when
    /// absent, matching `overlap_efficiency`).
    pub prof_occupancy: f64,
    /// Gather-phase achieved bandwidth, GB/s (0.0 when the engine gauge
    /// carried no read-side byte/seconds split).
    pub gather_gbs_achieved: f64,
    /// STREAM-calibrated peak bandwidth, GB/s (0.0 when no calibration
    /// ran alongside the bench).
    pub gather_gbs_peak: f64,
    /// Engine-scratch working set, bytes (0 when the backend reported no
    /// scratch).
    pub footprint_bytes: usize,
    /// Cache level the working set fits (`"L1"`/`"L2"`/`"LLC"`/`"DRAM"`;
    /// `""` when absent, matching `footprint_bytes`).
    pub footprint_level: String,
    /// Measurement repetitions aggregated into this artifact (gauges are
    /// from the first repeat; `spread` characterizes the rest). Older
    /// artifacts parse as 1.
    pub repeats: usize,
    /// Per-gauge run-to-run spread across the repeats:
    /// `(gauge, min, max, stddev)`. Empty for single runs.
    pub spread: Vec<(String, f64, f64, f64)>,
    pub slo_violations: Vec<String>,
    /// Retained request spans (see `obs::trace` for the object schema).
    pub spans: Vec<Json>,
}

impl BenchArtifact {
    /// Build an artifact from a finished run's metrics report.
    pub fn from_report(
        bench_id: &str,
        workload: &str,
        seed: u64,
        requests: usize,
        backend: &str,
        report: &MetricsReport,
        slo_violations: Vec<String>,
    ) -> BenchArtifact {
        let total: f64 = report.phases.iter().map(|(_, s)| s).sum();
        let phase_shares = report
            .phases
            .iter()
            .map(|(n, s)| (n.clone(), if total > 0.0 { s / total } else { 0.0 }))
            .collect();
        let (hwm, pages, kv_dtype, kv_page_bytes, kv_held_bytes) = report
            .kv
            .as_ref()
            .map(|kv| {
                (
                    kv.pool.used_hwm,
                    kv.pool.total_pages,
                    kv.pool.dtype.as_str().to_string(),
                    kv.pool.page_bytes,
                    kv.held_bytes(),
                )
            })
            .unwrap_or((0, 0, "f32".to_string(), 0, 0));
        BenchArtifact {
            schema_version: SCHEMA_VERSION,
            bench_id: bench_id.to_string(),
            workload: workload.to_string(),
            seed,
            requests,
            backend: backend.to_string(),
            decode_tok_s: report.tokens_per_s,
            ttft_p50_s: report.ttft.p50,
            ttft_p99_s: report.ttft.p99,
            tpot_p50_s: report.tpot.p50,
            tpot_p99_s: report.tpot.p99,
            latency_p50_s: report.latency.p50,
            latency_p99_s: report.latency.p99,
            queue_wait_p99_s: report.queue_wait.p99,
            mean_batch: report.mean_batch,
            completed: report.completed,
            rejected: report.rejected,
            infeasible: report.infeasible,
            deferred: report.deferred,
            phase_shares,
            build_share_ops: report.build_share_ops().unwrap_or(0.0),
            kernel_impl: report.kernel.map(|k| k.label().to_string()).unwrap_or_default(),
            simd_lanes: report.kernel.map(|k| k.lanes).unwrap_or(0),
            prefix_hit_rate: report.prefix_hit_rate(),
            preemptions: report.preemptions,
            kv_used_hwm_pages: hwm,
            kv_total_pages: pages,
            kv_dtype,
            kv_page_bytes,
            kv_held_bytes,
            spans_dropped: report.spans_dropped,
            overlap_efficiency: report.prof.as_ref().map(|p| p.overlap_efficiency).unwrap_or(0.0),
            prof_occupancy: report.prof.as_ref().map(|p| p.occupancy).unwrap_or(0.0),
            gather_gbs_achieved: report.gather_gbs_achieved().unwrap_or(0.0),
            gather_gbs_peak: report.prof.as_ref().map(|p| p.gather_gbs_peak).unwrap_or(0.0),
            footprint_bytes: report.footprint.as_ref().map(|f| f.total_bytes).unwrap_or(0),
            footprint_level: report
                .footprint
                .as_ref()
                .map(|f| f.level.clone())
                .unwrap_or_default(),
            repeats: 1,
            spread: Vec::new(),
            slo_violations,
            spans: report.spans.iter().map(|s| s.to_json()).collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::from(self.schema_version)),
            ("bench_id", Json::from(self.bench_id.as_str())),
            ("workload", Json::from(self.workload.as_str())),
            ("seed", Json::from(self.seed as usize)),
            ("requests", Json::from(self.requests)),
            ("backend", Json::from(self.backend.as_str())),
            ("decode_tok_s", Json::Num(self.decode_tok_s)),
            ("ttft_p50_s", Json::Num(self.ttft_p50_s)),
            ("ttft_p99_s", Json::Num(self.ttft_p99_s)),
            ("tpot_p50_s", Json::Num(self.tpot_p50_s)),
            ("tpot_p99_s", Json::Num(self.tpot_p99_s)),
            ("latency_p50_s", Json::Num(self.latency_p50_s)),
            ("latency_p99_s", Json::Num(self.latency_p99_s)),
            ("queue_wait_p99_s", Json::Num(self.queue_wait_p99_s)),
            ("mean_batch", Json::Num(self.mean_batch)),
            ("completed", Json::from(self.completed as usize)),
            ("rejected", Json::from(self.rejected as usize)),
            ("infeasible", Json::from(self.infeasible as usize)),
            ("deferred", Json::from(self.deferred as usize)),
            (
                "phase_shares",
                Json::Arr(
                    self.phase_shares
                        .iter()
                        .map(|(n, s)| {
                            Json::obj(vec![
                                ("name", Json::from(n.as_str())),
                                ("share", Json::Num(*s)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("build_share_ops", Json::Num(self.build_share_ops)),
            ("kernel_impl", Json::from(self.kernel_impl.as_str())),
            ("simd_lanes", Json::from(self.simd_lanes)),
            ("prefix_hit_rate", Json::Num(self.prefix_hit_rate)),
            ("preemptions", Json::from(self.preemptions as usize)),
            ("kv_used_hwm_pages", Json::from(self.kv_used_hwm_pages)),
            ("kv_total_pages", Json::from(self.kv_total_pages)),
            ("kv_dtype", Json::from(self.kv_dtype.as_str())),
            ("kv_page_bytes", Json::from(self.kv_page_bytes)),
            ("kv_held_bytes", Json::from(self.kv_held_bytes)),
            ("spans_dropped", Json::from(self.spans_dropped as usize)),
            ("overlap_efficiency", Json::Num(self.overlap_efficiency)),
            ("prof_occupancy", Json::Num(self.prof_occupancy)),
            ("gather_gbs_achieved", Json::Num(self.gather_gbs_achieved)),
            ("gather_gbs_peak", Json::Num(self.gather_gbs_peak)),
            ("footprint_bytes", Json::from(self.footprint_bytes)),
            ("footprint_level", Json::from(self.footprint_level.as_str())),
            ("repeats", Json::from(self.repeats)),
            (
                "spread",
                Json::Arr(
                    self.spread
                        .iter()
                        .map(|(g, lo, hi, sd)| {
                            Json::obj(vec![
                                ("gauge", Json::from(g.as_str())),
                                ("min", Json::Num(*lo)),
                                ("max", Json::Num(*hi)),
                                ("stddev", Json::Num(*sd)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "slo_violations",
                Json::Arr(self.slo_violations.iter().map(|v| Json::from(v.as_str())).collect()),
            ),
            ("spans", Json::Arr(self.spans.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<BenchArtifact> {
        let version = j.req_usize("schema_version")?;
        if version > SCHEMA_VERSION {
            anyhow::bail!(
                "artifact schema_version {version} is newer than supported {SCHEMA_VERSION}"
            );
        }
        let phase_shares = j
            .req_arr("phase_shares")?
            .iter()
            .map(|p| Ok((p.req_str("name")?.to_string(), p.req_f64("share")?)))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let slo_violations = j
            .req_arr("slo_violations")?
            .iter()
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("slo_violations entries must be strings"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(BenchArtifact {
            schema_version: version,
            bench_id: j.req_str("bench_id")?.to_string(),
            workload: j.req_str("workload")?.to_string(),
            seed: j.req_usize("seed")? as u64,
            requests: j.req_usize("requests")?,
            backend: j.req_str("backend")?.to_string(),
            decode_tok_s: j.req_f64("decode_tok_s")?,
            ttft_p50_s: j.req_f64("ttft_p50_s")?,
            ttft_p99_s: j.req_f64("ttft_p99_s")?,
            tpot_p50_s: j.req_f64("tpot_p50_s")?,
            tpot_p99_s: j.req_f64("tpot_p99_s")?,
            latency_p50_s: j.req_f64("latency_p50_s")?,
            latency_p99_s: j.req_f64("latency_p99_s")?,
            queue_wait_p99_s: j.req_f64("queue_wait_p99_s")?,
            mean_batch: j.req_f64("mean_batch")?,
            completed: j.req_usize("completed")? as u64,
            rejected: j.req_usize("rejected")? as u64,
            infeasible: j.req_usize("infeasible")? as u64,
            deferred: j.req_usize("deferred")? as u64,
            phase_shares,
            build_share_ops: j.req_f64("build_share_ops")?,
            // Kernel gauges arrived within schema v1 — older artifacts
            // (e.g. the committed BENCH baselines) simply lack them.
            kernel_impl: j
                .get("kernel_impl")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            simd_lanes: j.opt_usize("simd_lanes", 0)?,
            // Contention gauges also arrived within schema v1 — absent
            // in baselines from uninstrumented builds.
            prefix_hit_rate: j.get("prefix_hit_rate").and_then(|v| v.as_f64()).unwrap_or(0.0),
            preemptions: j.opt_usize("preemptions", 0)? as u64,
            kv_used_hwm_pages: j.req_usize("kv_used_hwm_pages")?,
            kv_total_pages: j.req_usize("kv_total_pages")?,
            // KV codec gauges arrived within schema v1 — artifacts that
            // predate them were all produced by f32-only pools.
            kv_dtype: j.get("kv_dtype").and_then(|v| v.as_str()).unwrap_or("f32").to_string(),
            kv_page_bytes: j.opt_usize("kv_page_bytes", 0)?,
            kv_held_bytes: j.opt_usize("kv_held_bytes", 0)?,
            // Profiler + repeat gauges arrived within schema v1 — absent
            // in baselines from uninstrumented builds.
            spans_dropped: j.opt_usize("spans_dropped", 0)? as u64,
            overlap_efficiency: j
                .get("overlap_efficiency")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            prof_occupancy: j.get("prof_occupancy").and_then(|v| v.as_f64()).unwrap_or(0.0),
            gather_gbs_achieved: j
                .get("gather_gbs_achieved")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            gather_gbs_peak: j.get("gather_gbs_peak").and_then(|v| v.as_f64()).unwrap_or(0.0),
            footprint_bytes: j.opt_usize("footprint_bytes", 0)?,
            footprint_level: j
                .get("footprint_level")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            repeats: j.opt_usize("repeats", 1)?,
            spread: match j.get("spread") {
                Some(Json::Arr(rows)) => rows
                    .iter()
                    .map(|r| {
                        Ok((
                            r.req_str("gauge")?.to_string(),
                            r.req_f64("min")?,
                            r.req_f64("max")?,
                            r.req_f64("stddev")?,
                        ))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
                _ => Vec::new(),
            },
            slo_violations,
            spans: j.req_arr("spans")?.to_vec(),
        })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<BenchArtifact> {
        let text = std::fs::read_to_string(path)?;
        BenchArtifact::from_json(&Json::parse(&text)?)
    }

    /// The timing-free projection of the span list (sorted by request
    /// id): two same-seed runs must produce identical structural traces.
    pub fn structural_trace(&self) -> Vec<String> {
        let mut rows: Vec<(usize, String)> = self
            .spans
            .iter()
            .filter_map(|s| {
                let id = s.req_usize("id").ok()?;
                Some((
                    id,
                    format!(
                        "{}:{}:{}:{}",
                        id,
                        s.req_usize("prompt_tokens").ok()?,
                        s.req_usize("generated_tokens").ok()?,
                        s.req_str("finish").ok()?
                    ),
                ))
            })
            .collect();
        rows.sort_by_key(|(id, _)| *id);
        rows.into_iter().map(|(_, r)| r).collect()
    }
}

/// Diff `current` against `baseline`; a finding is any headline gauge
/// moving the wrong way by more than `threshold` (relative, e.g. 0.2 =
/// 20%). Latency gauges with a sub-microsecond baseline are skipped —
/// they are below timer resolution and would only produce noise.
pub fn compare(baseline: &BenchArtifact, current: &BenchArtifact, threshold: f64) -> Vec<String> {
    let mut findings = Vec::new();
    if baseline.decode_tok_s > 0.0
        && current.decode_tok_s < baseline.decode_tok_s * (1.0 - threshold)
    {
        findings.push(format!(
            "decode throughput regressed {:.1}% ({:.1} → {:.1} tok/s)",
            100.0 * (1.0 - current.decode_tok_s / baseline.decode_tok_s),
            baseline.decode_tok_s,
            current.decode_tok_s,
        ));
    }
    let lat = [
        ("ttft p99", baseline.ttft_p99_s, current.ttft_p99_s),
        ("tpot p99", baseline.tpot_p99_s, current.tpot_p99_s),
    ];
    for (name, base, cur) in lat {
        if base > 1e-6 && cur > base * (1.0 + threshold) {
            findings.push(format!(
                "{name} regressed {:.1}% ({:.2} → {:.2} ms)",
                100.0 * (cur / base - 1.0),
                base * 1e3,
                cur * 1e3,
            ));
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact(decode_tok_s: f64) -> BenchArtifact {
        BenchArtifact {
            schema_version: SCHEMA_VERSION,
            bench_id: "BENCH_T".into(),
            workload: "chat".into(),
            seed: 7,
            requests: 8,
            backend: "native/test".into(),
            decode_tok_s,
            ttft_p50_s: 0.01,
            ttft_p99_s: 0.02,
            tpot_p50_s: 0.001,
            tpot_p99_s: 0.002,
            latency_p50_s: 0.05,
            latency_p99_s: 0.09,
            queue_wait_p99_s: 0.001,
            mean_batch: 2.0,
            completed: 8,
            rejected: 0,
            infeasible: 0,
            deferred: 1,
            phase_shares: vec![("model/gemm".into(), 0.6), ("model/attention".into(), 0.4)],
            build_share_ops: 0.25,
            kernel_impl: "unrolled".into(),
            simd_lanes: 8,
            prefix_hit_rate: 0.5,
            preemptions: 2,
            kv_used_hwm_pages: 5,
            kv_total_pages: 8,
            kv_dtype: "int8".into(),
            kv_page_bytes: 4352,
            kv_held_bytes: 21760,
            spans_dropped: 3,
            overlap_efficiency: 0.8,
            prof_occupancy: 0.9,
            gather_gbs_achieved: 2.5,
            gather_gbs_peak: 10.0,
            footprint_bytes: 65536,
            footprint_level: "L2".into(),
            repeats: 1,
            spread: vec![("decode_tok_s".into(), 95.0, 105.0, 4.0)],
            slo_violations: vec![],
            spans: vec![Json::obj(vec![
                ("id", Json::from(1usize)),
                ("prompt_tokens", Json::from(4usize)),
                ("generated_tokens", Json::from(8usize)),
                ("finish", Json::from("length")),
            ])],
        }
    }

    #[test]
    fn json_roundtrip_preserves_fields() {
        let a = artifact(100.0);
        let b = BenchArtifact::from_json(&a.to_json()).unwrap();
        assert_eq!(b.schema_version, SCHEMA_VERSION);
        assert_eq!(b.bench_id, "BENCH_T");
        assert_eq!(b.seed, 7);
        assert_eq!(b.decode_tok_s, 100.0);
        assert_eq!(b.phase_shares, a.phase_shares);
        assert_eq!(b.kernel_impl, "unrolled");
        assert_eq!(b.simd_lanes, 8);
        assert_eq!(b.prefix_hit_rate, 0.5);
        assert_eq!(b.preemptions, 2);
        assert_eq!(b.kv_dtype, "int8");
        assert_eq!(b.kv_page_bytes, 4352);
        assert_eq!(b.kv_held_bytes, 21760);
        assert_eq!(b.spans_dropped, 3);
        assert_eq!(b.overlap_efficiency, 0.8);
        assert_eq!(b.prof_occupancy, 0.9);
        assert_eq!(b.gather_gbs_achieved, 2.5);
        assert_eq!(b.gather_gbs_peak, 10.0);
        assert_eq!(b.footprint_bytes, 65536);
        assert_eq!(b.footprint_level, "L2");
        assert_eq!(b.repeats, 1);
        assert_eq!(b.spread, a.spread);
        assert_eq!(b.structural_trace(), vec!["1:4:8:length".to_string()]);
    }

    #[test]
    fn artifacts_without_profiler_gauges_still_parse() {
        // Baselines from builds predating the kernel profiler must load
        // with the documented 0 / 0.0 / "" / 1 defaults — this pins the
        // backward-compatible parse the acceptance criteria require.
        let mut j = artifact(50.0).to_json();
        if let Json::Obj(o) = &mut j {
            for key in [
                "spans_dropped",
                "overlap_efficiency",
                "prof_occupancy",
                "gather_gbs_achieved",
                "gather_gbs_peak",
                "footprint_bytes",
                "footprint_level",
                "repeats",
                "spread",
            ] {
                o.remove(key);
            }
        }
        let b = BenchArtifact::from_json(&j).unwrap();
        assert_eq!(b.spans_dropped, 0);
        assert_eq!(b.overlap_efficiency, 0.0);
        assert_eq!(b.prof_occupancy, 0.0);
        assert_eq!(b.gather_gbs_achieved, 0.0);
        assert_eq!(b.gather_gbs_peak, 0.0);
        assert_eq!(b.footprint_bytes, 0);
        assert_eq!(b.footprint_level, "");
        assert_eq!(b.repeats, 1, "single run is the legacy meaning");
        assert!(b.spread.is_empty());
        assert_eq!(b.decode_tok_s, 50.0);
    }

    #[test]
    fn artifacts_without_kernel_gauges_still_parse() {
        // Committed baselines predate the kernel dispatch gauges; they
        // must load with the documented "" / 0 defaults.
        let mut j = artifact(50.0).to_json();
        if let Json::Obj(o) = &mut j {
            o.remove("kernel_impl");
            o.remove("simd_lanes");
        }
        let b = BenchArtifact::from_json(&j).unwrap();
        assert_eq!(b.kernel_impl, "");
        assert_eq!(b.simd_lanes, 0);
        assert_eq!(b.decode_tok_s, 50.0);
    }

    #[test]
    fn artifacts_without_contention_gauges_still_parse() {
        // Baselines from builds predating prefix caching / preemption
        // must load with the documented 0.0 / 0 defaults.
        let mut j = artifact(50.0).to_json();
        if let Json::Obj(o) = &mut j {
            o.remove("prefix_hit_rate");
            o.remove("preemptions");
        }
        let b = BenchArtifact::from_json(&j).unwrap();
        assert_eq!(b.prefix_hit_rate, 0.0);
        assert_eq!(b.preemptions, 0);
        assert_eq!(b.decode_tok_s, 50.0);
    }

    #[test]
    fn artifacts_without_kv_codec_gauges_still_parse() {
        // Baselines from builds predating coded KV pages were all
        // produced by f32-only pools — they must load with the
        // documented "f32" / 0 / 0 defaults.
        let mut j = artifact(50.0).to_json();
        if let Json::Obj(o) = &mut j {
            o.remove("kv_dtype");
            o.remove("kv_page_bytes");
            o.remove("kv_held_bytes");
        }
        let b = BenchArtifact::from_json(&j).unwrap();
        assert_eq!(b.kv_dtype, "f32", "pre-codec artifacts default to f32");
        assert_eq!(b.kv_page_bytes, 0);
        assert_eq!(b.kv_held_bytes, 0);
        assert_eq!(b.decode_tok_s, 50.0);
    }

    #[test]
    fn newer_schema_is_refused() {
        let mut j = artifact(1.0).to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("schema_version".into(), Json::from(SCHEMA_VERSION + 1));
        }
        assert!(BenchArtifact::from_json(&j).is_err());
    }

    #[test]
    fn comparator_flags_decode_regression_beyond_threshold() {
        let base = artifact(100.0);
        // 25% drop > 20% threshold → finding; 10% drop → none.
        assert_eq!(compare(&base, &artifact(75.0), 0.2).len(), 1);
        assert!(compare(&base, &artifact(90.0), 0.2).is_empty());
        // Improvements never flag.
        assert!(compare(&base, &artifact(140.0), 0.2).is_empty());
    }

    #[test]
    fn comparator_flags_latency_regressions() {
        let base = artifact(100.0);
        let mut cur = artifact(100.0);
        cur.ttft_p99_s = base.ttft_p99_s * 1.5;
        cur.tpot_p99_s = base.tpot_p99_s * 1.3;
        let findings = compare(&base, &cur, 0.2);
        assert_eq!(findings.len(), 2, "{findings:?}");
        assert!(findings[0].contains("ttft p99"));
    }

    #[test]
    fn save_load_roundtrip() {
        let path = std::env::temp_dir().join(format!("codegemm_bench_{}.json", std::process::id()));
        let a = artifact(42.0);
        a.save(&path).unwrap();
        let b = BenchArtifact::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(b.decode_tok_s, 42.0);
        assert_eq!(b.spans.len(), 1);
    }
}
