//! Observability: the measurement substrate the ROADMAP's perf items
//! report through.
//!
//! - [`hist`] — fixed-memory log-bucketed streaming histograms
//!   (mergeable; p50/p95/p99 within a ~2.2% relative error bound; see
//!   the module docs for the bucket geometry and error model). These
//!   back `coordinator::Metrics`, replacing per-sample `Vec<f64>`
//!   buffers that grew without bound under sustained traffic.
//! - [`trace`] — per-request lifecycle spans (submit → admit → prefill
//!   chunks → first token → decode → finish) with queue-wait / prefill /
//!   decode attribution, retained in a bounded ring. The span schema is
//!   documented in the module header.
//! - [`loadgen`] — seeded workload mixes (chat, RAG, long-form, bursty
//!   Poisson, mixed) with declared SLOs, expanded deterministically into
//!   request traces and driven through `coordinator::Server`.
//! - [`export`] — the schema-versioned `BENCH_<n>.json` artifact
//!   (headline gauges + phase shares + spans) and the regression
//!   comparator used by the `bench-serve` CLI and CI. Schema and
//!   versioning policy live in the module header.
//! - [`prof`] — the kernel-level profiler: lock-free per-worker event
//!   rings recorded by the thread pool and the `parallel::fanout`
//!   schedules, drained into Chrome trace-event JSON (Perfetto /
//!   `chrome://tracing` loadable), with derived pipeline-overlap
//!   efficiency and per-barrier occupancy gauges. The event schema and
//!   viewing instructions live in the module header. ~Zero cost when
//!   off (one relaxed load per potential span).
//! - [`roofline`] — STREAM-triad bandwidth + peak-MAC calibration, the
//!   roofline placement of the build/gather phases over the engines'
//!   exact counters, and the on-chip footprint audit vs. detected
//!   L1/L2/LLC sizes. Calibration methodology and its error model are
//!   in the module header.
//!
//! Step-phase attribution follows a namespace convention:
//! `sched/*` phases come from the batcher (prefill / decode / sample
//! wall time per step), `model/*` from `LlamaModel`'s forward timer
//! (gemm / attention / lm_head), and `engine/*` from the engines'
//! cumulative `gemm::Counters` (Psumbook build vs gather seconds — the
//! paper's Table 6 split).
//!
//! ## Profiling a serving run
//!
//! `bench-serve --profile on --trace-out trace.json` traces the whole
//! seeded workload and writes the Chrome trace next to the bench
//! artifact; open it at <https://ui.perfetto.dev>. The `profile`
//! subcommand runs the calibration + per-kernel roofline standalone.
//! Overlap efficiency, occupancy, ring drops, the gather-phase
//! achieved-vs-peak GB/s and the footprint audit all surface in
//! `MetricsReport::render` and ride the bench artifact
//! (backward-compatibly — old artifacts parse with the gauges absent).

pub mod export;
pub mod hist;
pub mod loadgen;
pub mod prof;
pub mod roofline;
pub mod trace;

pub use export::{compare, BenchArtifact, SCHEMA_VERSION};
pub use hist::Histogram;
pub use loadgen::{check_slo, drive, generate, Arrival, GenRequest, Slo, WorkloadClass, WorkloadMix};
pub use prof::{ProfSummary, Timeline};
pub use roofline::{CacheSizes, FootprintAudit, Peaks, RooflinePoint};
pub use trace::{SpanRecord, TraceLog};
