//! Observability: the measurement substrate the ROADMAP's perf items
//! report through.
//!
//! - [`hist`] — fixed-memory log-bucketed streaming histograms
//!   (mergeable; p50/p95/p99 within a ~2.2% relative error bound; see
//!   the module docs for the bucket geometry and error model). These
//!   back `coordinator::Metrics`, replacing per-sample `Vec<f64>`
//!   buffers that grew without bound under sustained traffic.
//! - [`trace`] — per-request lifecycle spans (submit → admit → prefill
//!   chunks → first token → decode → finish) with queue-wait / prefill /
//!   decode attribution, retained in a bounded ring. The span schema is
//!   documented in the module header.
//! - [`loadgen`] — seeded workload mixes (chat, RAG, long-form, bursty
//!   Poisson, mixed) with declared SLOs, expanded deterministically into
//!   request traces and driven through `coordinator::Server`.
//! - [`export`] — the schema-versioned `BENCH_<n>.json` artifact
//!   (headline gauges + phase shares + spans) and the regression
//!   comparator used by the `bench-serve` CLI and CI. Schema and
//!   versioning policy live in the module header.
//!
//! Step-phase attribution follows a namespace convention:
//! `sched/*` phases come from the batcher (prefill / decode / sample
//! wall time per step), `model/*` from `LlamaModel`'s forward timer
//! (gemm / attention / lm_head), and `engine/*` from the engines'
//! cumulative `gemm::Counters` (Psumbook build vs gather seconds — the
//! paper's Table 6 split).

pub mod export;
pub mod hist;
pub mod loadgen;
pub mod trace;

pub use export::{compare, BenchArtifact, SCHEMA_VERSION};
pub use hist::Histogram;
pub use loadgen::{check_slo, drive, generate, Arrival, GenRequest, Slo, WorkloadClass, WorkloadMix};
pub use trace::{SpanRecord, TraceLog};
