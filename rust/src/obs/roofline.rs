//! Calibrated roofline + on-chip footprint audit.
//!
//! The engines' [`crate::gemm::Counters`] are exact — MACs, lookups and
//! bytes derived from the algorithm, not sampled — but "achieved 9 GB/s
//! on the gather stream" means nothing without knowing what *this*
//! machine can do. This module measures the two roofs and places the
//! measured phases under them:
//!
//! - [`measure_bandwidth_gbs`]: STREAM-triad (`a[i] = b[i] + s·c[i]`)
//!   over three arrays sized well past the LLC, best-of-N reps — the
//!   sustainable memory bandwidth roof.
//! - [`measure_peak_gmacs`]: independent-accumulator multiply-add chains
//!   over an L1-resident buffer, best-of-N — the compute roof for the
//!   portable (auto-vectorized) mul+add the kernels actually compile to.
//!
//! ## Error model
//!
//! Calibration is best-of-N wall-clock on a possibly noisy machine:
//! treat single-digit percent as noise (CI runners: tens of percent —
//! which is why the bench comparator stays advisory there). The triad
//! understates achievable bandwidth when the compiler fails to
//! vectorize the copy loop and overstates the *gather* roof slightly
//! because gathers are not pure streams; the MAC roof measures mul+add
//! pairs (fused only under `-C target-cpu=native`-style flags). Both
//! errors are stable on one machine, so *ratios across configs/kernels*
//! are trustworthy even where absolute percentages carry the noise.
//!
//! [`analyze`] combines a phase's exact counters (MACs, bytes, seconds)
//! with the measured [`Peaks`]: arithmetic intensity (MACs/byte), the
//! binding roof (`min(peak_mac, AI × bw)`), and % of attainable.
//!
//! ## Footprint audit
//!
//! [`FootprintAudit`] prices the on-chip working set the way the paper's
//! §3 space argument does, but against *this* machine's detected cache
//! sizes ([`CacheSizes::detect`], sysfs with fallbacks): the Psumbook
//! (+ the PR-7 `book2` double buffer under the pipeline) plus staging
//! buffers, and the smallest cache level that holds them. A config whose
//! audit says `DRAM` has lost the paper's bet — the gather loop will
//! stream its tables from memory and the roofline will show it.

use crate::util::timer::Timer;
use std::hint::black_box;

/// Detected (or fallback) cache capacities in bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheSizes {
    pub l1d: usize,
    pub l2: usize,
    pub llc: usize,
}

impl CacheSizes {
    /// Conservative defaults when sysfs is unavailable (containers,
    /// non-Linux): 32 KiB / 1 MiB / 32 MiB.
    pub const FALLBACK: CacheSizes =
        CacheSizes { l1d: 32 << 10, l2: 1 << 20, llc: 32 << 20 };

    /// Read `/sys/devices/system/cpu/cpu0/cache/index*` (Linux),
    /// falling back per level when absent or unparsable.
    pub fn detect() -> CacheSizes {
        let mut out = CacheSizes::FALLBACK;
        let mut best_llc = 0usize;
        for idx in 0..8usize {
            let base = format!("/sys/devices/system/cpu/cpu0/cache/index{idx}");
            let read = |f: &str| std::fs::read_to_string(format!("{base}/{f}"));
            let (Ok(level), Ok(ty), Ok(size)) = (read("level"), read("type"), read("size"))
            else {
                continue;
            };
            let Ok(level) = level.trim().parse::<usize>() else { continue };
            let Some(bytes) = parse_size(size.trim()) else { continue };
            if ty.trim() == "Instruction" {
                continue;
            }
            match level {
                1 => out.l1d = bytes,
                2 => out.l2 = bytes,
                _ => {
                    if bytes > best_llc {
                        best_llc = bytes;
                        out.llc = bytes;
                    }
                }
            }
        }
        out
    }

    /// Smallest level that holds `bytes`: "L1" | "L2" | "LLC" | "DRAM".
    pub fn level_of(&self, bytes: usize) -> &'static str {
        if bytes <= self.l1d {
            "L1"
        } else if bytes <= self.l2 {
            "L2"
        } else if bytes <= self.llc {
            "LLC"
        } else {
            "DRAM"
        }
    }
}

/// Parse a sysfs cache size string ("32K", "1024K", "8M", raw bytes).
fn parse_size(s: &str) -> Option<usize> {
    let s = s.trim();
    if let Some(v) = s.strip_suffix('K') {
        v.parse::<usize>().ok().map(|v| v << 10)
    } else if let Some(v) = s.strip_suffix('M') {
        v.parse::<usize>().ok().map(|v| v << 20)
    } else if let Some(v) = s.strip_suffix('G') {
        v.parse::<usize>().ok().map(|v| v << 30)
    } else {
        s.parse::<usize>().ok()
    }
}

/// Measured machine peaks for the two roofs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Peaks {
    /// Sustainable memory bandwidth (GB/s), STREAM triad best-of-N.
    pub bw_gbs: f64,
    /// Peak multiply-add throughput (GMAC/s), best-of-N.
    pub gmacs: f64,
}

/// STREAM-triad bandwidth: `a[i] = b[i] + s·c[i]` over three f32 arrays
/// totalling ~2× `llc_bytes` so the streams miss every cache level.
/// Returns the best of `reps` passes in GB/s (3 streams × 4 bytes).
pub fn measure_bandwidth_gbs(llc_bytes: usize, reps: usize) -> f64 {
    let len = ((llc_bytes * 2) / (3 * 4)).max(1 << 16);
    let mut a = vec![0f32; len];
    let b = vec![1.5f32; len];
    let c = vec![0.25f32; len];
    let s = 3.0f32;
    let mut best = 0.0f64;
    for _ in 0..reps.max(1) {
        let t = Timer::start();
        for i in 0..len {
            a[i] = b[i] + s * c[i];
        }
        black_box(&mut a);
        let dt = t.elapsed_s();
        if dt > 0.0 {
            let gbs = (len as f64 * 3.0 * 4.0) / dt / 1e9;
            if gbs > best {
                best = gbs;
            }
        }
    }
    best
}

/// Peak MAC throughput: 16 independent accumulator chains over a 4 KiB
/// (L1-resident) buffer — the same independent-lane structure the
/// `gemm::simd` kernels use, so the auto-vectorizer has the same room.
/// Counts one MAC per mul+add pair; best of `reps` passes in GMAC/s.
pub fn measure_peak_gmacs(reps: usize) -> f64 {
    const LANES: usize = 16;
    const LEN: usize = 1024;
    const INNER: usize = 2048;
    let x: Vec<f32> = (0..LEN).map(|i| 1.0 + (i % 7) as f32 * 1e-3).collect();
    let mut acc = [0f32; LANES];
    let mut best = 0.0f64;
    for _ in 0..reps.max(1) {
        for a in acc.iter_mut() {
            *a = 0.0;
        }
        let t = Timer::start();
        for pass in 0..INNER {
            let scale = 1.0 + (pass % 3) as f32 * 1e-4;
            let mut i = 0;
            while i + LANES <= LEN {
                for l in 0..LANES {
                    acc[l] += x[i + l] * scale;
                }
                i += LANES;
            }
        }
        black_box(&mut acc);
        let dt = t.elapsed_s();
        if dt > 0.0 {
            let macs = (INNER * LEN) as f64;
            let g = macs / dt / 1e9;
            if g > best {
                best = g;
            }
        }
    }
    best
}

/// Run both calibration loops. `quick` caps the triad working set and
/// rep count for CI smoke legs.
pub fn calibrate(caches: &CacheSizes, quick: bool) -> Peaks {
    let (reps, llc) = if quick { (3, caches.llc.min(8 << 20)) } else { (7, caches.llc) };
    Peaks { bw_gbs: measure_bandwidth_gbs(llc, reps), gmacs: measure_peak_gmacs(reps) }
}

/// One phase placed under the calibrated roofs.
#[derive(Clone, Debug, PartialEq)]
pub struct RooflinePoint {
    pub phase: String,
    pub macs: u64,
    pub bytes: u64,
    pub seconds: f64,
    pub achieved_gbs: f64,
    pub achieved_gmacs: f64,
    /// Arithmetic intensity: MACs per byte moved.
    pub intensity: f64,
    /// `min(peak_mac, intensity × peak_bw)` — what this phase could do.
    pub attainable_gmacs: f64,
    pub pct_attainable: f64,
    /// Which roof binds: "memory" or "compute".
    pub bound: &'static str,
}

/// Place a phase's exact counters under the measured peaks.
pub fn analyze(phase: &str, macs: u64, bytes: u64, seconds: f64, peaks: &Peaks) -> RooflinePoint {
    let achieved_gbs = if seconds > 0.0 { bytes as f64 / seconds / 1e9 } else { 0.0 };
    let achieved_gmacs = if seconds > 0.0 { macs as f64 / seconds / 1e9 } else { 0.0 };
    let intensity = if bytes > 0 { macs as f64 / bytes as f64 } else { 0.0 };
    let mem_roof = intensity * peaks.bw_gbs;
    let attainable = if peaks.gmacs > 0.0 { mem_roof.min(peaks.gmacs) } else { mem_roof };
    RooflinePoint {
        phase: phase.to_string(),
        macs,
        bytes,
        seconds,
        achieved_gbs,
        achieved_gmacs,
        intensity,
        attainable_gmacs: attainable,
        pct_attainable: if attainable > 0.0 { 100.0 * achieved_gmacs / attainable } else { 0.0 },
        bound: if peaks.gmacs > 0.0 && mem_roof < peaks.gmacs { "memory" } else { "compute" },
    }
}

/// On-chip working set of one engine scratch vs. the cache hierarchy.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FootprintAudit {
    /// Psumbook high-water bytes (`EngineScratch::book`).
    pub book_bytes: usize,
    /// The pipeline's spare book (`book2`) — zero when not pipelining.
    pub book2_bytes: usize,
    /// Activation staging (`buf` + `buf2`) high-water bytes.
    pub staging_bytes: usize,
    pub total_bytes: usize,
    pub l1d: usize,
    pub l2: usize,
    pub llc: usize,
    /// Smallest cache level holding the total ("L1"/"L2"/"LLC"/"DRAM").
    pub level: String,
}

impl FootprintAudit {
    /// Audit component byte counts against `caches`.
    pub fn new(
        book_bytes: usize,
        book2_bytes: usize,
        staging_bytes: usize,
        caches: &CacheSizes,
    ) -> FootprintAudit {
        let total_bytes = book_bytes + book2_bytes + staging_bytes;
        FootprintAudit {
            book_bytes,
            book2_bytes,
            staging_bytes,
            total_bytes,
            l1d: caches.l1d,
            l2: caches.l2,
            llc: caches.llc,
            level: caches.level_of(total_bytes).to_string(),
        }
    }

    /// Audit from an [`crate::gemm::EngineScratch`]'s component parts
    /// (`(buf, buf2, book, book2)` bytes, as `footprint_parts` returns).
    pub fn from_parts(parts: (usize, usize, usize, usize), caches: &CacheSizes) -> FootprintAudit {
        FootprintAudit::new(parts.2, parts.3, parts.0 + parts.1, caches)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CACHES: CacheSizes = CacheSizes { l1d: 32 << 10, l2: 1 << 20, llc: 32 << 20 };

    #[test]
    fn parse_size_suffixes() {
        assert_eq!(parse_size("32K"), Some(32 << 10));
        assert_eq!(parse_size("1024K"), Some(1 << 20));
        assert_eq!(parse_size("8M"), Some(8 << 20));
        assert_eq!(parse_size("512"), Some(512));
        assert_eq!(parse_size("x"), None);
    }

    #[test]
    fn level_of_walks_the_hierarchy() {
        assert_eq!(CACHES.level_of(1 << 10), "L1");
        assert_eq!(CACHES.level_of(64 << 10), "L2");
        assert_eq!(CACHES.level_of(2 << 20), "LLC");
        assert_eq!(CACHES.level_of(64 << 20), "DRAM");
    }

    #[test]
    fn detect_returns_positive_sizes() {
        let c = CacheSizes::detect();
        assert!(c.l1d > 0 && c.l2 >= c.l1d.min(c.l2) && c.llc > 0);
    }

    #[test]
    fn analyze_places_phases_under_the_roofs() {
        let peaks = Peaks { bw_gbs: 10.0, gmacs: 50.0 };
        // 1e9 MACs over 4e9 bytes in 1s: AI = 0.25, mem roof = 2.5 GMACs
        // < 50 ⇒ memory bound, achieved 1 GMAC/s = 40% of attainable.
        let p = analyze("gather", 1_000_000_000, 4_000_000_000, 1.0, &peaks);
        assert_eq!(p.bound, "memory");
        assert!((p.intensity - 0.25).abs() < 1e-12);
        assert!((p.achieved_gbs - 4.0).abs() < 1e-9);
        assert!((p.attainable_gmacs - 2.5).abs() < 1e-9);
        assert!((p.pct_attainable - 40.0).abs() < 1e-6);
        // High intensity flips to the compute roof.
        let p2 = analyze("build", 1_000_000_000, 1_000_000, 1.0, &peaks);
        assert_eq!(p2.bound, "compute");
        assert!((p2.attainable_gmacs - 50.0).abs() < 1e-9);
        // Zero time ⇒ zero achieved, no division blowups.
        let p3 = analyze("empty", 0, 0, 0.0, &peaks);
        assert_eq!(p3.achieved_gbs, 0.0);
        assert_eq!(p3.pct_attainable, 0.0);
    }

    #[test]
    fn calibration_loops_produce_positive_peaks() {
        // Tiny working set: correctness of the plumbing, not the numbers.
        let bw = measure_bandwidth_gbs(1 << 16, 1);
        let mac = measure_peak_gmacs(1);
        assert!(bw > 0.0, "triad bandwidth {bw}");
        assert!(mac > 0.0, "mac peak {mac}");
    }

    #[test]
    fn footprint_audit_sums_and_levels() {
        let a = FootprintAudit::new(16 << 10, 16 << 10, 8 << 10, &CACHES);
        assert_eq!(a.total_bytes, 40 << 10);
        assert_eq!(a.level, "L2");
        let b = FootprintAudit::from_parts((4 << 10, 4 << 10, 8 << 10, 0), &CACHES);
        assert_eq!(a.book_bytes, 16 << 10);
        assert_eq!(b.book_bytes, 8 << 10);
        assert_eq!(b.book2_bytes, 0);
        assert_eq!(b.staging_bytes, 8 << 10);
        assert_eq!(b.level, "L1");
    }
}
