//! Kernel-level profiler: per-worker timeline tracing with Chrome-trace
//! export and pipeline-overlap gauges.
//!
//! The exact [`crate::gemm::Counters`] say *how much* work and traffic a
//! call did; this module says *when* it happened and on *which worker* —
//! the instrument that makes the PR-7 software pipeline's
//! tile-`t+1`-build-under-tile-`t`-gather overlap directly visible
//! instead of inferred from `build_seconds`.
//!
//! ## Event schema
//!
//! Every event is a closed span `(label, tag, tid, start_ns, end_ns)`:
//!
//! | label     | recorded by                         | tag            |
//! |-----------|-------------------------------------|----------------|
//! | `job`     | every `ThreadPool` worker, per job  | 0              |
//! | `build`   | shared-book build j-range jobs      | k-tile index   |
//! | `gather`  | shard × member gather jobs          | k-tile index   |
//! | `stage`   | control thread: tile staging        | k-tile index   |
//! | `barrier` | control thread: scope submit→join   | k-tile index   |
//!
//! `build`/`gather`/`stage` spans are nested *inside* the worker's
//! generic `job` span (or the control thread's `barrier` span), so
//! occupancy computations use the `job` layer and phase analysis uses
//! the labelled layer — they are different views of the same wall time,
//! not double counting.
//!
//! ## Recording: lock-free per-thread rings
//!
//! Each recording thread owns one preallocated ring of atomic slots
//! (registered on first use, found again through a thread-local). A
//! record is three relaxed stores plus one release store of the ring
//! length — no locks, no allocation, no contention with other workers.
//! When a ring fills, further events are **dropped and counted** (never
//! overwritten — a wrapping write would race the drain), so a truncated
//! timeline is always visible as `Timeline::dropped > 0`.
//!
//! When profiling is off (the default), [`begin`] is a single relaxed
//! atomic load returning a sentinel and [`record_since`] returns
//! immediately — the hot loops pay ~one predictable branch, and kernel
//! outputs/counters are bit-identical either way (pinned by
//! `tests/prof_trace.rs`).
//!
//! ## Draining and viewing
//!
//! [`drain`] snapshots and clears every registered ring into a
//! [`Timeline`]. Call it only while no traced work is in flight (after
//! the pool scopes have joined — every call site in this repo drains
//! after a barrier); a racing recorder cannot corrupt memory (all slots
//! are atomics) but could lose its event.
//!
//! [`Timeline::to_chrome_trace`] renders the Chrome trace-event JSON
//! format: open <https://ui.perfetto.dev> (or `chrome://tracing`) and
//! load the file — one row per worker, `build` spans for tile `t+1`
//! visibly overlapping `gather` spans for tile `t` when the pipeline is
//! doing its job. Derived gauges: [`Timeline::overlap`] (hidden vs
//! exposed build seconds against the union of concurrent gather
//! intervals) and [`Timeline::barrier_occupancy`] (mean fraction of
//! worker-seconds actually busy inside each pool barrier).

use crate::util::json::Json;
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Sentinel returned by [`begin`] when profiling is disabled.
pub const OFF: u64 = u64::MAX;

/// Default per-thread ring capacity (events). At the pipeline's event
/// rate (a handful of spans per k-tile per worker) this holds minutes of
/// serving; overflow drops-and-counts rather than wrapping.
pub const DEFAULT_RING_CAPACITY: usize = 1 << 16;

static ENABLED: AtomicBool = AtomicBool::new(false);
static RING_CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_RING_CAPACITY);
static NEXT_TID: AtomicUsize = AtomicUsize::new(0);
static EPOCH: Mutex<Option<Instant>> = Mutex::new(None);
static REGISTRY: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());

/// Span labels. Small closed set so events pack into one atomic word —
/// never store string pointers in the ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Label {
    /// Generic pool job (recorded by every `ThreadPool` worker).
    Job = 0,
    /// Psumbook build j-range job (tag = k-tile index).
    Build = 1,
    /// Shard × member gather job (tag = k-tile index).
    Gather = 2,
    /// Control-thread activation staging + book reshape (tag = k-tile).
    Stage = 3,
    /// Control-thread pool scope, submit → barrier (tag = k-tile).
    Barrier = 4,
}

impl Label {
    pub fn as_str(self) -> &'static str {
        match self {
            Label::Job => "job",
            Label::Build => "build",
            Label::Gather => "gather",
            Label::Stage => "stage",
            Label::Barrier => "barrier",
        }
    }

    fn from_id(id: u32) -> Label {
        match id {
            1 => Label::Build,
            2 => Label::Gather,
            3 => Label::Stage,
            4 => Label::Barrier,
            _ => Label::Job,
        }
    }
}

/// One preallocated event slot: `meta` packs the label (low 32 bits) and
/// tag (high 32); `start`/`end` are nanoseconds since the profiler epoch.
struct Slot {
    meta: AtomicU64,
    start: AtomicU64,
    end: AtomicU64,
}

/// One thread's event ring. Only the owning thread pushes; any thread
/// may drain. `len` is published with Release so a drain's Acquire load
/// sees fully written slots.
struct Ring {
    slots: Box<[Slot]>,
    len: AtomicUsize,
    dropped: AtomicU64,
    tid: usize,
    thread: String,
}

impl Ring {
    fn new(capacity: usize, tid: usize, thread: String) -> Ring {
        let slots: Vec<Slot> = (0..capacity.max(1))
            .map(|_| Slot {
                meta: AtomicU64::new(0),
                start: AtomicU64::new(0),
                end: AtomicU64::new(0),
            })
            .collect();
        Ring { slots: slots.into_boxed_slice(), len: AtomicUsize::new(0), dropped: AtomicU64::new(0), tid, thread }
    }

    fn push(&self, label: Label, tag: u32, start_ns: u64, end_ns: u64) {
        let i = self.len.load(Ordering::Relaxed);
        if i >= self.slots.len() {
            // Full: drop-and-count. Overwriting the oldest slot would
            // race a concurrent drain; losing the newest is safe and the
            // loss is never silent.
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let s = &self.slots[i];
        s.meta.store(label as u64 | ((tag as u64) << 32), Ordering::Relaxed);
        s.start.store(start_ns, Ordering::Relaxed);
        s.end.store(end_ns, Ordering::Relaxed);
        self.len.store(i + 1, Ordering::Release);
    }
}

/// Per-thread recording state: the registered ring plus a copy of the
/// shared epoch so the hot path never takes the epoch lock.
struct Local {
    epoch: Instant,
    ring: Arc<Ring>,
}

thread_local! {
    static LOCAL: RefCell<Option<Local>> = RefCell::new(None);
}

/// The process-wide profiling epoch: set once (first `enable`/record)
/// and kept forever, so timestamps stay monotone across enable/disable
/// cycles and traces from successive drains can be concatenated.
fn epoch() -> Instant {
    let mut g = EPOCH.lock().expect("prof epoch lock");
    *g.get_or_insert_with(Instant::now)
}

fn with_local<R>(f: impl FnOnce(&Local) -> R) -> R {
    LOCAL.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let thread = std::thread::current()
                .name()
                .map(|s| s.to_string())
                .unwrap_or_else(|| format!("thread-{tid}"));
            let ring = Arc::new(Ring::new(RING_CAPACITY.load(Ordering::Relaxed), tid, thread));
            REGISTRY.lock().expect("prof registry lock").push(Arc::clone(&ring));
            *slot = Some(Local { epoch: epoch(), ring });
        }
        f(slot.as_ref().expect("local ring just initialized"))
    })
}

/// Is profiling on? One relaxed load — the entire cost the hot loops pay
/// when tracing is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on (sets the epoch on first use).
pub fn enable() {
    let _ = epoch();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn tracing off. Already-recorded events stay in the rings until
/// [`drain`].
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Capacity (events) for rings registered *after* this call; existing
/// rings keep their size. Mainly for tests that exercise overflow.
pub fn set_ring_capacity(capacity: usize) {
    RING_CAPACITY.store(capacity.max(1), Ordering::SeqCst);
}

/// Start a span: returns the start timestamp, or [`OFF`] when disabled.
#[inline]
pub fn begin() -> u64 {
    if !enabled() {
        return OFF;
    }
    with_local(|l| l.epoch.elapsed().as_nanos() as u64)
}

/// Close a span opened with [`begin`]. No-op when `start_ns` is [`OFF`]
/// or profiling has been disabled meanwhile.
#[inline]
pub fn record_since(label: Label, tag: u32, start_ns: u64) {
    if start_ns == OFF || !enabled() {
        return;
    }
    with_local(|l| {
        let end_ns = l.epoch.elapsed().as_nanos() as u64;
        l.ring.push(label, tag, start_ns, end_ns);
    });
}

/// Run `f` inside a span. With profiling off this is `f()` plus one
/// relaxed load — `f`'s outputs are identical either way.
#[inline]
pub fn with_span<R>(label: Label, tag: u32, f: impl FnOnce() -> R) -> R {
    let t0 = begin();
    let r = f();
    record_since(label, tag, t0);
    r
}

/// One closed span as drained from a ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub label: Label,
    pub tag: u32,
    /// Stable per-thread id (registration order), the Chrome-trace tid.
    pub tid: usize,
    pub start_ns: u64,
    pub end_ns: u64,
}

impl Event {
    pub fn duration_s(&self) -> f64 {
        self.end_ns.saturating_sub(self.start_ns) as f64 * 1e-9
    }
}

/// A drained snapshot of every thread's events, sorted by (tid, start).
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    pub events: Vec<Event>,
    /// `(tid, thread name)` for every ring ever registered.
    pub threads: Vec<(usize, String)>,
    /// Events lost to full rings since the previous drain.
    pub dropped: u64,
}

/// Hidden-vs-exposed build time against concurrent gathers — the
/// pipeline's report card. `efficiency = hidden_s / build_s` (1.0 means
/// every build nanosecond ran under some gather; the tile-0 prologue is
/// exposed by construction, so steady-state pipelined runs land below
/// but near the `(tiles-1)/tiles` ceiling).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Overlap {
    pub build_s: f64,
    pub hidden_s: f64,
    pub exposed_s: f64,
    pub efficiency: f64,
}

/// Snapshot and clear all rings. Call after the traced work has passed
/// its barriers (see module docs).
pub fn drain() -> Timeline {
    let rings: Vec<Arc<Ring>> = REGISTRY.lock().expect("prof registry lock").clone();
    let mut events = Vec::new();
    let mut threads = Vec::with_capacity(rings.len());
    let mut dropped = 0u64;
    for ring in &rings {
        let n = ring.len.load(Ordering::Acquire).min(ring.slots.len());
        for slot in &ring.slots[..n] {
            let meta = slot.meta.load(Ordering::Relaxed);
            events.push(Event {
                label: Label::from_id((meta & 0xffff_ffff) as u32),
                tag: (meta >> 32) as u32,
                tid: ring.tid,
                start_ns: slot.start.load(Ordering::Relaxed),
                end_ns: slot.end.load(Ordering::Relaxed),
            });
        }
        dropped += ring.dropped.swap(0, Ordering::Relaxed);
        ring.len.store(0, Ordering::Release);
        threads.push((ring.tid, ring.thread.clone()));
    }
    events.sort_by_key(|e| (e.tid, e.start_ns, e.end_ns));
    threads.sort();
    threads.dedup();
    Timeline { events, threads, dropped }
}

impl Timeline {
    /// Chrome trace-event JSON (the `{"traceEvents": [...]}` object
    /// form): complete `ph:"X"` spans in microseconds plus `ph:"M"`
    /// thread-name metadata. Loadable in Perfetto / `chrome://tracing`.
    pub fn to_chrome_trace(&self) -> Json {
        let mut rows: Vec<Json> = Vec::with_capacity(self.threads.len() + self.events.len());
        for (tid, name) in &self.threads {
            rows.push(Json::obj(vec![
                ("name", Json::from("thread_name")),
                ("ph", Json::from("M")),
                ("pid", Json::from(1usize)),
                ("tid", Json::from(*tid)),
                ("args", Json::obj(vec![("name", Json::from(name.as_str()))])),
            ]));
        }
        for e in &self.events {
            rows.push(Json::obj(vec![
                ("name", Json::from(e.label.as_str())),
                ("cat", Json::from("codegemm")),
                ("ph", Json::from("X")),
                ("ts", Json::Num(e.start_ns as f64 / 1000.0)),
                ("dur", Json::Num(e.end_ns.saturating_sub(e.start_ns) as f64 / 1000.0)),
                ("pid", Json::from(1usize)),
                ("tid", Json::from(e.tid)),
                ("args", Json::obj(vec![("tile", Json::from(e.tag as usize))])),
            ]));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(rows)),
            ("displayTimeUnit", Json::from("ms")),
        ])
    }

    /// Build time hidden under concurrent gathers: intersect every
    /// `build` span with the merged union of all `gather` intervals
    /// (across threads).
    pub fn overlap(&self) -> Overlap {
        let mut gathers: Vec<(u64, u64)> = self
            .events
            .iter()
            .filter(|e| e.label == Label::Gather)
            .map(|e| (e.start_ns, e.end_ns))
            .collect();
        gathers.sort_unstable();
        let mut union: Vec<(u64, u64)> = Vec::new();
        for (s, e) in gathers {
            match union.last_mut() {
                Some(last) if s <= last.1 => last.1 = last.1.max(e),
                _ => union.push((s, e)),
            }
        }
        let mut build_ns = 0u64;
        let mut hidden_ns = 0u64;
        for ev in self.events.iter().filter(|e| e.label == Label::Build) {
            let (s, e) = (ev.start_ns, ev.end_ns);
            build_ns += e.saturating_sub(s);
            let mut i = union.partition_point(|&(_, ue)| ue <= s);
            while i < union.len() && union[i].0 < e {
                let lo = union[i].0.max(s);
                let hi = union[i].1.min(e);
                hidden_ns += hi.saturating_sub(lo);
                i += 1;
            }
        }
        let build_s = build_ns as f64 * 1e-9;
        let hidden_s = hidden_ns as f64 * 1e-9;
        Overlap {
            build_s,
            hidden_s,
            exposed_s: build_ns.saturating_sub(hidden_ns) as f64 * 1e-9,
            efficiency: if build_ns == 0 { 0.0 } else { hidden_s / build_s },
        }
    }

    /// Mean worker occupancy across `barrier` spans: for each barrier,
    /// the busy worker-seconds inside its window over `window ×
    /// workers`. Uses the generic `job` layer when present (the
    /// labelled build/gather spans nest inside it — counting both would
    /// double-bill); `None` when no barriers were traced.
    pub fn barrier_occupancy(&self) -> Option<f64> {
        let barriers: Vec<&Event> =
            self.events.iter().filter(|e| e.label == Label::Barrier).collect();
        if barriers.is_empty() {
            return None;
        }
        let mut work: Vec<&Event> = self.events.iter().filter(|e| e.label == Label::Job).collect();
        if work.is_empty() {
            work = self
                .events
                .iter()
                .filter(|e| matches!(e.label, Label::Build | Label::Gather))
                .collect();
        }
        let mut tids: Vec<usize> = work.iter().map(|e| e.tid).collect();
        tids.sort_unstable();
        tids.dedup();
        if tids.is_empty() {
            return Some(0.0);
        }
        let mut acc = 0.0f64;
        let mut n = 0usize;
        for b in &barriers {
            let window = b.end_ns.saturating_sub(b.start_ns);
            if window == 0 {
                continue;
            }
            let mut busy = 0u64;
            for w in &work {
                let lo = w.start_ns.max(b.start_ns);
                let hi = w.end_ns.min(b.end_ns);
                busy += hi.saturating_sub(lo);
            }
            acc += busy as f64 / (window as f64 * tids.len() as f64);
            n += 1;
        }
        if n == 0 {
            None
        } else {
            Some(acc / n as f64)
        }
    }

    /// Schedule-invariant structural view: the sorted multiset of
    /// `(label, tag)` pairs. Same-seed runs produce the same structure
    /// regardless of which worker ran which job or how the clock fell.
    pub fn structural(&self) -> Vec<(Label, u32)> {
        let mut v: Vec<(Label, u32)> = self.events.iter().map(|e| (e.label, e.tag)).collect();
        v.sort_unstable();
        v
    }
}

/// Derived profiler gauges in report/artifact form — what `MetricsReport`
/// and `BENCH_<n>.json` carry when a traced run finishes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ProfSummary {
    /// Spans drained from the worker rings.
    pub events: u64,
    /// Spans lost to full rings (truncated trace ⇒ nonzero).
    pub dropped: u64,
    /// Hidden build share, [`Overlap::efficiency`].
    pub overlap_efficiency: f64,
    pub hidden_build_s: f64,
    pub exposed_build_s: f64,
    /// Mean per-barrier worker occupancy (0 when untraceable).
    pub occupancy: f64,
    /// Calibrated peak memory bandwidth (STREAM triad), GB/s; 0 when no
    /// calibration ran alongside the trace.
    pub gather_gbs_peak: f64,
}

impl ProfSummary {
    pub fn from_timeline(tl: &Timeline) -> ProfSummary {
        let o = tl.overlap();
        ProfSummary {
            events: tl.events.len() as u64,
            dropped: tl.dropped,
            overlap_efficiency: o.efficiency,
            hidden_build_s: o.hidden_s,
            exposed_build_s: o.exposed_s,
            occupancy: tl.barrier_occupancy().unwrap_or(0.0),
            gather_gbs_peak: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that flip the process-wide profiler state
    /// (cargo runs `#[test]`s on parallel threads).
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn ev(label: Label, tag: u32, tid: usize, start_ns: u64, end_ns: u64) -> Event {
        Event { label, tag, tid, start_ns, end_ns }
    }

    #[test]
    fn overlap_math_on_synthetic_timeline() {
        // Gathers cover [0,100) and [150,200); builds [50,160) and
        // [300,310). Hidden = 50 + 10 = 60 of 120 build ns.
        let tl = Timeline {
            events: vec![
                ev(Label::Gather, 0, 1, 0, 100),
                ev(Label::Gather, 0, 2, 150, 200),
                ev(Label::Build, 1, 3, 50, 160),
                ev(Label::Build, 2, 3, 300, 310),
            ],
            threads: vec![],
            dropped: 0,
        };
        let o = tl.overlap();
        assert_eq!((o.build_s * 1e9).round() as u64, 120);
        assert_eq!((o.hidden_s * 1e9).round() as u64, 60);
        assert_eq!((o.exposed_s * 1e9).round() as u64, 60);
        assert!((o.efficiency - 0.5).abs() < 1e-12, "efficiency {}", o.efficiency);
    }

    #[test]
    fn overlap_merges_touching_gather_intervals() {
        // Two abutting gathers must not double-count a build overlapping
        // the seam.
        let tl = Timeline {
            events: vec![
                ev(Label::Gather, 0, 1, 0, 50),
                ev(Label::Gather, 0, 2, 50, 100),
                ev(Label::Build, 1, 3, 40, 60),
            ],
            threads: vec![],
            dropped: 0,
        };
        let o = tl.overlap();
        assert_eq!((o.hidden_s * 1e9).round() as u64, 20);
        assert!((o.efficiency - 1.0).abs() < 1e-12);
    }

    #[test]
    fn barrier_occupancy_uses_job_layer() {
        // One 100ns barrier; two workers each busy 50ns inside it (job
        // spans), with nested build spans that must NOT double-count.
        let tl = Timeline {
            events: vec![
                ev(Label::Barrier, 0, 0, 0, 100),
                ev(Label::Job, 0, 1, 0, 50),
                ev(Label::Build, 0, 1, 0, 50),
                ev(Label::Job, 0, 2, 50, 100),
            ],
            threads: vec![],
            dropped: 0,
        };
        let occ = tl.barrier_occupancy().expect("has barriers");
        assert!((occ - 0.5).abs() < 1e-12, "occupancy {occ}");
        assert_eq!(Timeline::default().barrier_occupancy(), None);
    }

    #[test]
    fn chrome_trace_shape_roundtrips() {
        let tl = Timeline {
            events: vec![ev(Label::Build, 3, 1, 1000, 2500), ev(Label::Gather, 2, 2, 0, 4000)],
            threads: vec![(1, "w1".to_string()), (2, "w2".to_string())],
            dropped: 0,
        };
        let j = Json::parse(&tl.to_chrome_trace().to_string_compact()).expect("valid JSON");
        let rows = j.req_arr("traceEvents").expect("traceEvents");
        assert_eq!(rows.len(), 4);
        let metas = rows.iter().filter(|r| r.req_str("ph").unwrap() == "M").count();
        assert_eq!(metas, 2);
        for r in rows.iter().filter(|r| r.req_str("ph").unwrap() == "X") {
            assert!(r.req_f64("dur").unwrap() >= 0.0);
            assert!(r.req_f64("ts").unwrap() >= 0.0);
            assert!(r.get("args").and_then(|a| a.get("tile")).is_some());
        }
    }

    #[test]
    fn disabled_recording_is_a_no_op() {
        let _g = lock();
        disable();
        let _ = drain();
        assert_eq!(begin(), OFF);
        record_since(Label::Build, 7, OFF);
        let out = with_span(Label::Gather, 9, || 41 + 1);
        assert_eq!(out, 42);
        let tl = drain();
        assert!(
            tl.events.iter().all(|e| e.label == Label::Job),
            "no labelled spans may appear while disabled"
        );
    }

    #[test]
    fn ring_overflow_drops_and_counts() {
        let _g = lock();
        disable();
        let _ = drain();
        set_ring_capacity(16);
        enable();
        // A fresh thread gets a fresh (16-slot) ring.
        std::thread::spawn(|| {
            for i in 0..21u32 {
                with_span(Label::Stage, 0xD1, || std::hint::black_box(i));
            }
        })
        .join()
        .expect("recorder thread");
        disable();
        set_ring_capacity(DEFAULT_RING_CAPACITY);
        let tl = drain();
        let mine = tl.events.iter().filter(|e| e.tag == 0xD1).count();
        assert_eq!(mine, 16, "ring must keep exactly its capacity");
        assert!(tl.dropped >= 5, "dropped {} events, expected >= 5", tl.dropped);
    }

    #[test]
    fn with_span_records_label_tag_and_order() {
        let _g = lock();
        disable();
        let _ = drain();
        enable();
        std::thread::spawn(|| {
            with_span(Label::Build, 0xA2, || {
                with_span(Label::Gather, 0xA3, || std::hint::black_box(1));
            });
        })
        .join()
        .expect("recorder thread");
        disable();
        let tl = drain();
        let build = tl.events.iter().find(|e| e.tag == 0xA2).expect("build span");
        let gather = tl.events.iter().find(|e| e.tag == 0xA3).expect("gather span");
        assert_eq!(build.label, Label::Build);
        assert_eq!(gather.label, Label::Gather);
        // The nested span closes first but lies inside the outer one.
        assert!(build.start_ns <= gather.start_ns && gather.end_ns <= build.end_ns);
        assert!(build.end_ns >= build.start_ns);
        let s = tl.structural();
        assert!(s.windows(2).all(|w| w[0] <= w[1]), "structural view is sorted");
    }
}
