//! # CodeGEMM
//!
//! A codebook-centric GEMM stack for quantized LLM inference, reproducing
//! *"CodeGEMM: A Codebook-Centric Approach to Efficient GEMM in Quantized
//! LLMs"* (Park et al., 2025).
//!
//! The crate is the **Layer-3 (Rust) half** of a three-layer system:
//!
//! - **L1** — a Pallas kernel (`python/compile/kernels/codegemm.py`) that
//!   builds a *Psumbook* (all centroid·activation inner products) in on-chip
//!   scratch and gathers partial sums through the code matrix.
//! - **L2** — a JAX Llama-style decoder whose linear layers call the L1
//!   kernel; AOT-lowered once to HLO text (`make artifacts`).
//! - **L3** — this crate: the quantization toolkit, CPU reference engines
//!   for every kernel in the paper's evaluation, an A100 analytic
//!   performance model regenerating the paper's tables, a PJRT runtime
//!   that loads and executes the AOT artifacts, and a serving coordinator
//!   (router / dynamic batcher / scheduler) with Python *never* on the
//!   request path.
//!
//! ## Parallel execution (`parallel::`)
//!
//! The L3 engines and the Llama forward pass scale across cores the same
//! way the GPU kernels scale across thread blocks: a [`parallel::ShardPlan`]
//! assigns contiguous row ranges of each weight matrix to workers,
//! [`parallel::ShardedEngine`] gives every shard its **own Psumbook/LUT
//! scratch** (the CPU analogue of thread-block-local tables) and
//! concatenates outputs in shard order — bit-exact against the serial
//! engine — while [`parallel::TpLinear`] adds Megatron-style tensor
//! parallelism for the model: Q/K/V/gate/up column-parallel, O/down
//! row-parallel with a deterministic **ordered all-reduce**
//! (`parallel::reduce`), so sharded decode is reproducible across runs
//! and thread schedules. `config::ParallelConfig` selects thread count,
//! minimum shard size and which layer classes shard;
//! `coordinator::NativeBackend::new_parallel` serves the sharded model.
//!
//! ## Paged KV cache (`kvcache::`)
//!
//! Serving-side memory is pooled the way vLLM pools it: one
//! [`kvcache::BlockPool`] page arena backs every slot, each sequence
//! holds a page table ([`kvcache::SeqKv`]) that grows lazily on append
//! and is reclaimed wholesale on completion, and the model reads the
//! cache through the tiled [`kvcache::KvStore`] trait — the chunked GQA
//! attention kernel (`model::attention`, bit-exact against the flat
//! loop) walks page-sized tiles, so the page size is an attention tiling
//! knob exactly like the GEMM tile dims. The batcher gates admission on
//! free pages and spreads a **shared per-step prefill token budget**
//! across prefilling slots (`config::ServeConfig::prefill_budget`), so
//! long prompts cannot stall decoding slots.
//!
//! ## Quick start
//!
//! (`no_run`: rustdoc test binaries do not inherit the cargo-config rpath
//! to `$XLA_EXTENSION_DIR/lib`, so they cannot load libstdc++ in this
//! offline image; the same code *is* executed by `examples/quickstart.rs`
//! and the `gemm` unit tests.)
//!
//! ```no_run
//! use codegemm::config::QuantConfig;
//! use codegemm::quant::Quantizer;
//! use codegemm::gemm::{CodeGemmEngine, DenseEngine, GemmEngine};
//! use codegemm::util::prng::Prng;
//!
//! let mut rng = Prng::seeded(7);
//! let (n, k) = (64, 128);
//! let w: Vec<f32> = (0..n * k).map(|_| rng.normal_f32()).collect();
//! let cfg = QuantConfig::new(4, 1, 8, 128).unwrap(); // v=4, m=1, b=8, g=128
//! let qw = Quantizer::new(cfg).quantize(&w, n, k);
//! let x: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
//!
//! let mut engine = CodeGemmEngine::from_quantized(&qw);
//! let y = engine.gemv(&x);
//! let y_ref = DenseEngine::new(w.clone(), n, k).gemv(&x);
//! let rel = codegemm::util::stats::rel_l2(&y, &y_ref);
//! assert!(rel < 0.5, "2-bit-class quantization keeps gross structure");
//! ```

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod gemm;
pub mod kvcache;
pub mod model;
pub mod obs;
pub mod parallel;
pub mod quant;
pub mod runtime;
pub mod simulator;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI and the serving endpoint.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
