//! Serving metrics: counters and latency summaries, shared between the
//! batcher thread and callers.

use crate::util::stats::Summary;
use std::sync::Mutex;

/// Raw metric samples (seconds).
#[derive(Debug, Default)]
struct Inner {
    submitted: u64,
    completed: u64,
    rejected: u64,
    prefill_tokens: u64,
    decode_tokens: u64,
    steps: u64,
    batched_slots: u64,
    ttft: Vec<f64>,
    latency: Vec<f64>,
    step_seconds: Vec<f64>,
    started: Option<std::time::Instant>,
    finished: Option<std::time::Instant>,
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time metrics report.
#[derive(Clone, Debug)]
pub struct MetricsReport {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Prompt tokens consumed by batched prefill passes.
    pub prefill_tokens: u64,
    /// Generated tokens consumed by decode steps.
    pub decode_tokens: u64,
    pub steps: u64,
    /// Mean occupied slots per step (batch efficiency).
    pub mean_batch: f64,
    pub ttft: Summary,
    pub latency: Summary,
    pub step_time: Summary,
    /// Aggregate decode throughput over the serving window (tok/s).
    pub tokens_per_s: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn on_submit(&self) {
        self.inner.lock().unwrap().submitted += 1;
    }

    pub fn on_reject(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Record one batcher step: `occupied` slots advanced, consuming
    /// `prefill` prompt tokens (batched prefill) and `decode` generated
    /// tokens (one per decoding slot).
    pub fn on_step(&self, occupied: usize, prefill: usize, decode: usize, seconds: f64) {
        let mut g = self.inner.lock().unwrap();
        g.steps += 1;
        g.batched_slots += occupied as u64;
        g.prefill_tokens += prefill as u64;
        g.decode_tokens += decode as u64;
        g.step_seconds.push(seconds);
        let now = std::time::Instant::now();
        g.started.get_or_insert(now);
        g.finished = Some(now);
    }

    pub fn on_complete(&self, ttft_s: f64, latency_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        g.ttft.push(ttft_s);
        g.latency.push(latency_s);
    }

    pub fn report(&self) -> MetricsReport {
        let g = self.inner.lock().unwrap();
        let window = match (g.started, g.finished) {
            (Some(a), Some(b)) => (b - a).as_secs_f64().max(1e-9),
            _ => f64::INFINITY,
        };
        let summary = |xs: &[f64]| {
            if xs.is_empty() {
                Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, p50: 0.0, p95: 0.0, p99: 0.0, max: 0.0 }
            } else {
                Summary::of(xs)
            }
        };
        MetricsReport {
            submitted: g.submitted,
            completed: g.completed,
            rejected: g.rejected,
            prefill_tokens: g.prefill_tokens,
            decode_tokens: g.decode_tokens,
            steps: g.steps,
            mean_batch: if g.steps > 0 { g.batched_slots as f64 / g.steps as f64 } else { 0.0 },
            ttft: summary(&g.ttft),
            latency: summary(&g.latency),
            step_time: summary(&g.step_seconds),
            tokens_per_s: if window.is_finite() { g.decode_tokens as f64 / window } else { 0.0 },
        }
    }
}

impl MetricsReport {
    pub fn render(&self) -> String {
        format!(
            "requests: {} submitted / {} completed / {} rejected\n\
             tokens:   {} prefill / {} decode ({:.1} tok/s decode)\n\
             batching: {} steps, mean occupancy {:.2}\n\
             ttft:     p50 {:.1} ms, p95 {:.1} ms\n\
             latency:  p50 {:.1} ms, p95 {:.1} ms",
            self.submitted,
            self.completed,
            self.rejected,
            self.prefill_tokens,
            self.decode_tokens,
            self.tokens_per_s,
            self.steps,
            self.mean_batch,
            self.ttft.p50 * 1e3,
            self.ttft.p95 * 1e3,
            self.latency.p50 * 1e3,
            self.latency.p95 * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_step(2, 2, 0, 0.001);
        m.on_step(2, 0, 2, 0.001);
        m.on_complete(0.01, 0.05);
        let r = m.report();
        assert_eq!(r.submitted, 2);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.completed, 1);
        assert_eq!(r.prefill_tokens, 2);
        assert_eq!(r.decode_tokens, 2);
        assert!((r.mean_batch - 2.0).abs() < 1e-9);
        assert!(r.render().contains("mean occupancy 2.00"));
    }
}
