//! Serving metrics: counters, latency summaries, KV-pool occupancy and
//! engine-work gauges, shared between the batcher thread and callers.

use crate::gemm::Counters;
use crate::kvcache::KvStats;
use crate::util::stats::Summary;
use std::sync::Mutex;

/// Raw metric samples (seconds).
#[derive(Debug, Default)]
struct Inner {
    submitted: u64,
    completed: u64,
    rejected: u64,
    /// Accepted requests later found unservable (footprint > whole pool),
    /// finished with `FinishReason::Rejected`.
    infeasible: u64,
    /// Steps on which the queue head waited for KV pool pages.
    deferred: u64,
    prefill_tokens: u64,
    decode_tokens: u64,
    steps: u64,
    batched_slots: u64,
    ttft: Vec<f64>,
    latency: Vec<f64>,
    step_seconds: Vec<f64>,
    /// Latest pool snapshot from a pool-backed backend (gauge; the
    /// churn and high-water counters inside it are lifetime totals, so
    /// the latest snapshot carries the whole history).
    kv: Option<KvStats>,
    /// Latest cumulative engine work counters (gauge, same rationale) —
    /// the source of the build-share and fused-projection-fanout lines.
    engine: Option<Counters>,
    started: Option<std::time::Instant>,
    finished: Option<std::time::Instant>,
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time metrics report.
#[derive(Clone, Debug)]
pub struct MetricsReport {
    pub submitted: u64,
    pub completed: u64,
    /// Requests dropped at submit time (queue full) — never counted as
    /// submitted and never produce a `Response`.
    pub rejected: u64,
    /// Submitted requests finished with `FinishReason::Rejected` because
    /// their worst-case KV footprint exceeds the whole pool (so
    /// `submitted == completed + infeasible` once the queue drains).
    pub infeasible: u64,
    /// Steps on which admission was deferred waiting for KV pool pages.
    pub deferred: u64,
    /// Prompt tokens consumed by batched prefill passes.
    pub prefill_tokens: u64,
    /// Generated tokens consumed by decode steps.
    pub decode_tokens: u64,
    pub steps: u64,
    /// Mean occupied slots per step (batch efficiency).
    pub mean_batch: f64,
    pub ttft: Summary,
    pub latency: Summary,
    pub step_time: Summary,
    /// Aggregate decode throughput over the serving window (tok/s).
    pub tokens_per_s: f64,
    /// Latest KV-pool snapshot (pool/page occupancy, high-water mark,
    /// churn, per-slot held/filled bytes); `None` for backends without a
    /// pool.
    pub kv: Option<KvStats>,
    /// Latest cumulative engine work counters (`None` for backends
    /// without engine-level accounting): GEMM calls, Psumbook
    /// build-vs-gather split, and the fused-projection fanout per call.
    pub engine: Option<Counters>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn on_submit(&self) {
        self.inner.lock().unwrap().submitted += 1;
    }

    pub fn on_reject(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Record a submitted request finished as unservable (its KV
    /// footprint exceeds the whole pool).
    pub fn on_infeasible(&self) {
        self.inner.lock().unwrap().infeasible += 1;
    }

    /// Record one step on which the queue head could not be admitted for
    /// lack of free KV pool pages.
    pub fn on_admit_defer(&self) {
        self.inner.lock().unwrap().deferred += 1;
    }

    /// Record the latest KV-pool occupancy snapshot (gauge semantics:
    /// the last snapshot wins — its high-water/churn counters are
    /// pool-lifetime totals and therefore monotone).
    pub fn on_kv(&self, kv: KvStats) {
        self.inner.lock().unwrap().kv = Some(kv);
    }

    /// Record the latest cumulative engine counters (gauge semantics:
    /// engine counters only grow, so the last snapshot carries the whole
    /// serving history).
    pub fn on_engine(&self, counters: Counters) {
        self.inner.lock().unwrap().engine = Some(counters);
    }

    /// Record one batcher step: `occupied` slots advanced, consuming
    /// `prefill` prompt tokens (batched prefill) and `decode` generated
    /// tokens (one per decoding slot).
    pub fn on_step(&self, occupied: usize, prefill: usize, decode: usize, seconds: f64) {
        let mut g = self.inner.lock().unwrap();
        g.steps += 1;
        g.batched_slots += occupied as u64;
        g.prefill_tokens += prefill as u64;
        g.decode_tokens += decode as u64;
        g.step_seconds.push(seconds);
        let now = std::time::Instant::now();
        g.started.get_or_insert(now);
        g.finished = Some(now);
    }

    pub fn on_complete(&self, ttft_s: f64, latency_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        g.ttft.push(ttft_s);
        g.latency.push(latency_s);
    }

    pub fn report(&self) -> MetricsReport {
        let g = self.inner.lock().unwrap();
        let window = match (g.started, g.finished) {
            (Some(a), Some(b)) => (b - a).as_secs_f64().max(1e-9),
            _ => f64::INFINITY,
        };
        let summary = |xs: &[f64]| {
            if xs.is_empty() {
                Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, p50: 0.0, p95: 0.0, p99: 0.0, max: 0.0 }
            } else {
                Summary::of(xs)
            }
        };
        MetricsReport {
            submitted: g.submitted,
            completed: g.completed,
            rejected: g.rejected,
            infeasible: g.infeasible,
            deferred: g.deferred,
            prefill_tokens: g.prefill_tokens,
            decode_tokens: g.decode_tokens,
            steps: g.steps,
            mean_batch: if g.steps > 0 { g.batched_slots as f64 / g.steps as f64 } else { 0.0 },
            ttft: summary(&g.ttft),
            latency: summary(&g.latency),
            step_time: summary(&g.step_seconds),
            tokens_per_s: if window.is_finite() { g.decode_tokens as f64 / window } else { 0.0 },
            kv: g.kv.clone(),
            engine: g.engine.clone(),
        }
    }
}

impl MetricsReport {
    pub fn render(&self) -> String {
        let mut out = format!(
            "requests: {} submitted / {} completed / {} rejected / {} infeasible / {} deferred\n\
             tokens:   {} prefill / {} decode ({:.1} tok/s decode)\n\
             batching: {} steps, mean occupancy {:.2}\n\
             ttft:     p50 {:.1} ms, p95 {:.1} ms\n\
             latency:  p50 {:.1} ms, p95 {:.1} ms",
            self.submitted,
            self.completed,
            self.rejected,
            self.infeasible,
            self.deferred,
            self.prefill_tokens,
            self.decode_tokens,
            self.tokens_per_s,
            self.steps,
            self.mean_batch,
            self.ttft.p50 * 1e3,
            self.ttft.p95 * 1e3,
            self.latency.p50 * 1e3,
            self.latency.p95 * 1e3,
        );
        if let Some(kv) = &self.kv {
            out.push_str(&format!(
                "\nkv pool:  {}/{} pages used (hwm {}), {} tok/page, \
                 churn {} alloc / {} free, {} KiB held / {} KiB filled",
                kv.pool.used_pages,
                kv.pool.total_pages,
                kv.pool.used_hwm,
                kv.pool.page_size,
                kv.pool.allocated,
                kv.pool.freed,
                kv.held_bytes() / 1024,
                kv.used_bytes() / 1024,
            ));
        }
        if let Some(e) = &self.engine {
            out.push_str(&format!(
                "\nengine:   {} gemm calls, build share {:.1}% (ops), \
                 fused-projection fanout {:.2}/call",
                e.calls,
                100.0 * e.build_share_ops(),
                e.fanout_per_call(),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_step(2, 2, 0, 0.001);
        m.on_step(2, 0, 2, 0.001);
        m.on_complete(0.01, 0.05);
        let r = m.report();
        assert_eq!(r.submitted, 2);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.completed, 1);
        assert_eq!(r.prefill_tokens, 2);
        assert_eq!(r.decode_tokens, 2);
        assert!((r.mean_batch - 2.0).abs() < 1e-9);
        assert!(r.render().contains("mean occupancy 2.00"));
        assert!(r.kv.is_none(), "no pool snapshot recorded");
    }

    #[test]
    fn engine_gauge_reports_build_share_and_fanout() {
        let m = Metrics::new();
        // Stale snapshot, then the cumulative one: latest wins.
        m.on_engine(Counters { calls: 1, ..Default::default() });
        m.on_engine(Counters {
            calls: 4,
            build_ops: 10,
            read_ops: 30,
            group_fanout: 10,
            ..Default::default()
        });
        let r = m.report();
        let e = r.engine.as_ref().expect("engine snapshot recorded");
        assert_eq!(e.calls, 4);
        let rendered = r.render();
        assert!(rendered.contains("build share 25.0%"), "{rendered}");
        assert!(rendered.contains("fanout 2.50/call"), "{rendered}");
    }

    #[test]
    fn kv_gauge_keeps_latest_snapshot_and_hwm() {
        use crate::kvcache::PoolStats;
        let m = Metrics::new();
        m.on_admit_defer();
        m.on_kv(KvStats {
            pool: PoolStats { total_pages: 8, used_pages: 6, used_hwm: 6, ..Default::default() },
            slot_bytes: vec![1024, 0],
            slot_bytes_used: vec![512, 0],
        });
        m.on_kv(KvStats {
            pool: PoolStats { total_pages: 8, used_pages: 1, used_hwm: 6, ..Default::default() },
            slot_bytes: vec![256, 0],
            slot_bytes_used: vec![128, 0],
        });
        let r = m.report();
        assert_eq!(r.deferred, 1);
        let kv = r.kv.expect("snapshot recorded");
        assert_eq!(kv.pool.used_pages, 1, "gauge keeps the latest snapshot");
        assert_eq!(kv.pool.used_hwm, 6, "lifetime high-water mark rides the snapshot");
        assert_eq!(kv.held_bytes(), 256);
        assert!(r.render().contains("kv pool:"));
        assert!(r.render().contains("1 deferred"));
    }
}
