//! Serving metrics over **fixed-memory** recorders: counters, streaming
//! latency histograms (`obs::hist` — no per-sample buffer grows with
//! request count), a bounded ring of request lifecycle spans
//! (`obs::trace`), per-step phase attribution, and the KV-pool /
//! engine-work gauges — shared between the batcher thread and callers.
//!
//! Semantics per recorder:
//!
//! - **Counters** (`submitted`, `completed`, tokens, steps, …) and
//!   **histograms** (`ttft`, `latency`, `tpot`, `queue_wait`,
//!   `step_time`) accumulate incrementally.
//! - **Scheduler phases** (`sched/prefill`, `sched/decode`,
//!   `sched/sample`) accumulate incrementally per step via
//!   [`Metrics::on_step_phases`].
//! - **Gauges** keep the latest snapshot, which carries the whole
//!   history because the underlying values are monotone: the KV-pool
//!   snapshot ([`Metrics::on_kv`] — its high-water/churn counters are
//!   pool-lifetime totals), the engine counters ([`Metrics::on_engine`]
//!   — cumulative MAC/seconds tallies), and the model-forward phase
//!   timer ([`Metrics::on_model_phases`] — `model/*` seconds accumulate
//!   inside the model's scratch).
//!
//! [`MetricsReport::phases`] merges all three phase sources into one
//! attribution list (`sched/*`, `model/*`, plus `engine/build` /
//! `engine/gather` derived from the counters' seconds split), so a
//! single report answers "where did the serving time go" from the
//! scheduler down to the paper's Table 6 build-vs-gather split.

use crate::gemm::{Counters, KernelSel};
use crate::kvcache::KvStats;
use crate::obs::hist::Histogram;
use crate::obs::prof::ProfSummary;
use crate::obs::roofline::{CacheSizes, FootprintAudit};
use crate::obs::trace::{SpanRecord, TraceLog};
use crate::util::stats::Summary;
use crate::util::timer::PhaseTimer;
use std::sync::Mutex;

/// Fixed-memory metric state (seconds for all times).
#[derive(Debug, Default)]
struct Inner {
    submitted: u64,
    completed: u64,
    rejected: u64,
    /// Accepted requests later found unservable (footprint > whole pool),
    /// finished with `FinishReason::Rejected`.
    infeasible: u64,
    /// Steps on which the queue head waited for KV pool pages.
    deferred: u64,
    /// Decoding slots swapped out to admit higher-priority work.
    preemptions: u64,
    /// Preemptions that spilled KV to the host arena.
    preempt_spills: u64,
    /// Preemptions that dropped KV for replay (including spill-path
    /// fallbacks after a failed/panicked spill).
    preempt_recomputes: u64,
    /// Preempted requests re-admitted to a slot.
    resumes: u64,
    prefill_tokens: u64,
    decode_tokens: u64,
    steps: u64,
    batched_slots: u64,
    ttft: Histogram,
    latency: Histogram,
    tpot: Histogram,
    queue_wait: Histogram,
    step_time: Histogram,
    /// Exact total of recorded step seconds — the throughput fallback
    /// window when `started == finished` (a single recorded step).
    step_seconds_sum: f64,
    /// Scheduler-phase seconds (`sched/*`), accumulated per step.
    sched_phases: PhaseTimer,
    /// Latest model-forward phase snapshot (`model/*`; gauge — the
    /// timer accumulates inside the model scratch, so the latest
    /// snapshot carries the whole history).
    model_phases: Option<PhaseTimer>,
    /// Bounded ring of recent request spans.
    spans: TraceLog,
    /// Latest pool snapshot from a pool-backed backend (gauge; the
    /// churn and high-water counters inside it are lifetime totals, so
    /// the latest snapshot carries the whole history).
    kv: Option<KvStats>,
    /// Latest cumulative engine work counters (gauge, same rationale) —
    /// the source of the build-share and fused-projection-fanout lines.
    engine: Option<Counters>,
    /// Resolved CodeGEMM kernel dispatch (gauge; fixed per backend
    /// construction, so any snapshot is the whole story).
    kernel: Option<KernelSel>,
    /// Latest kernel-profiler gauge bundle from a traced run (gauge;
    /// recorded once when the trace is drained, before shutdown).
    prof: Option<ProfSummary>,
    /// Latest engine-scratch footprint split (`buf`, `buf2`, `book`,
    /// `book2` bytes; gauge — capacities only grow, so the latest
    /// snapshot is the serving high-water mark).
    footprint: Option<(usize, usize, usize, usize)>,
    started: Option<std::time::Instant>,
    finished: Option<std::time::Instant>,
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time metrics report.
#[derive(Clone, Debug)]
pub struct MetricsReport {
    pub submitted: u64,
    pub completed: u64,
    /// Requests dropped at submit time (queue full) — never counted as
    /// submitted and never produce a `Response`.
    pub rejected: u64,
    /// Submitted requests finished with `FinishReason::Rejected` because
    /// their worst-case KV footprint exceeds the whole pool (so
    /// `submitted == completed + infeasible` once the queue drains).
    pub infeasible: u64,
    /// Steps on which admission was deferred waiting for KV pool pages.
    pub deferred: u64,
    /// Decoding slots swapped out to admit higher-priority work.
    pub preemptions: u64,
    /// Preemptions that spilled KV to the host arena (the rest dropped
    /// their KV for recompute-on-resume).
    pub preempt_spills: u64,
    /// Preemptions resolved by recompute — explicit recompute mode plus
    /// spill-path fallbacks.
    pub preempt_recomputes: u64,
    /// Preempted requests re-admitted to a slot.
    pub resumes: u64,
    /// Prompt tokens consumed by batched prefill passes.
    pub prefill_tokens: u64,
    /// Generated tokens consumed by decode steps.
    pub decode_tokens: u64,
    pub steps: u64,
    /// Mean occupied slots per step (batch efficiency).
    pub mean_batch: f64,
    /// Summaries from the streaming histograms: mean/std/min/max exact,
    /// percentiles within the histogram bucket error (~2.2%).
    pub ttft: Summary,
    pub latency: Summary,
    /// Time per output token after the first, per request (only requests
    /// generating ≥ 2 tokens contribute).
    pub tpot: Summary,
    /// Submit → admission wait, per request.
    pub queue_wait: Summary,
    pub step_time: Summary,
    /// Aggregate decode throughput over the serving window (tok/s). When
    /// the wall window is degenerate (a single recorded step), the
    /// summed step seconds serve as the window; 0 when nothing ran.
    pub tokens_per_s: f64,
    /// Merged per-phase seconds: `sched/*` (batcher step phases),
    /// `model/*` (forward timer), `engine/build` / `engine/gather`
    /// (derived from the engine counters' seconds split).
    pub phases: Vec<(String, f64)>,
    /// Recent request lifecycle spans, oldest → newest (bounded ring —
    /// at most `TraceLog::DEFAULT_CAPACITY`).
    pub spans: Vec<SpanRecord>,
    /// Spans ever recorded (including ones evicted from the ring).
    pub spans_total: u64,
    /// Spans evicted by the bounded ring — nonzero means `spans` is a
    /// *truncated* view of the run (surfaced so a clipped trace is never
    /// mistaken for a complete one).
    pub spans_dropped: u64,
    /// Latest KV-pool snapshot (pool/page occupancy, high-water mark,
    /// churn, per-slot held/filled bytes); `None` for backends without a
    /// pool.
    pub kv: Option<KvStats>,
    /// Latest cumulative engine work counters (`None` for backends
    /// without engine-level accounting): GEMM calls, Psumbook
    /// build-vs-gather split, and the fused-projection fanout per call.
    pub engine: Option<Counters>,
    /// Resolved CodeGEMM kernel dispatch — implementation + lane width
    /// (`None` for backends without a CodeGEMM kernel layer).
    pub kernel: Option<KernelSel>,
    /// Kernel-profiler gauges from the latest traced run: span/drop
    /// counts, pipeline overlap efficiency (hidden vs exposed build
    /// seconds), per-barrier worker occupancy, and the calibrated peak
    /// gather bandwidth when a calibration ran. `None` untraced.
    pub prof: Option<ProfSummary>,
    /// Engine-scratch working set placed against the detected cache
    /// hierarchy (`None` when the backend reported no scratch).
    pub footprint: Option<FootprintAudit>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn on_submit(&self) {
        self.inner.lock().unwrap().submitted += 1;
    }

    pub fn on_reject(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Record a submitted request finished as unservable (its KV
    /// footprint exceeds the whole pool). The span documents the
    /// rejection (zero tokens, `finish = "rejected"`).
    pub fn on_infeasible(&self, span: &SpanRecord) {
        let mut g = self.inner.lock().unwrap();
        g.infeasible += 1;
        g.queue_wait.record(span.queue_wait_s);
        g.spans.push(span.clone());
    }

    /// Record one step on which the queue head could not be admitted for
    /// lack of free KV pool pages.
    pub fn on_admit_defer(&self) {
        self.inner.lock().unwrap().deferred += 1;
    }

    /// Record one preemption: a decoding slot swapped out for
    /// higher-priority work. `spilled` says whether its KV reached the
    /// host arena (false ⇒ dropped for recompute, including fallbacks).
    pub fn on_preempt(&self, spilled: bool) {
        let mut g = self.inner.lock().unwrap();
        g.preemptions += 1;
        if spilled {
            g.preempt_spills += 1;
        } else {
            g.preempt_recomputes += 1;
        }
    }

    /// Record a preempted request winning a slot again.
    pub fn on_resume(&self) {
        self.inner.lock().unwrap().resumes += 1;
    }

    /// Record the latest KV-pool occupancy snapshot (gauge semantics:
    /// the last snapshot wins — its high-water/churn counters are
    /// pool-lifetime totals and therefore monotone).
    pub fn on_kv(&self, kv: KvStats) {
        self.inner.lock().unwrap().kv = Some(kv);
    }

    /// Record the latest cumulative engine counters (gauge semantics:
    /// engine counters only grow, so the last snapshot carries the whole
    /// serving history).
    pub fn on_engine(&self, counters: Counters) {
        self.inner.lock().unwrap().engine = Some(counters);
    }

    /// Record the resolved CodeGEMM kernel selection (gauge; the
    /// dispatch is fixed at backend construction, so re-recording the
    /// same value is the expected idempotent case).
    pub fn on_kernel(&self, sel: KernelSel) {
        self.inner.lock().unwrap().kernel = Some(sel);
    }

    /// Record the kernel-profiler gauge bundle of a traced run (gauge:
    /// the summary aggregates the whole trace, so the latest recording
    /// carries the run).
    pub fn on_prof(&self, summary: ProfSummary) {
        self.inner.lock().unwrap().prof = Some(summary);
    }

    /// Record the latest engine-scratch footprint split (`buf`, `buf2`,
    /// `book`, `book2` bytes; gauge — capacities only grow).
    pub fn on_footprint(&self, parts: (usize, usize, usize, usize)) {
        self.inner.lock().unwrap().footprint = Some(parts);
    }

    /// Record the latest model-forward phase timer (`model/*` phases;
    /// gauge semantics — the timer accumulates across the model's whole
    /// life, so the latest snapshot carries the history).
    pub fn on_model_phases(&self, phases: PhaseTimer) {
        self.inner.lock().unwrap().model_phases = Some(phases);
    }

    /// Accumulate scheduler-phase seconds for one step (incremental:
    /// each call adds onto the running totals).
    pub fn on_step_phases(&self, phases: &[(&str, f64)]) {
        let mut g = self.inner.lock().unwrap();
        for (name, s) in phases {
            g.sched_phases.add(name, *s);
        }
    }

    /// Record one batcher step: `occupied` slots advanced, consuming
    /// `prefill` prompt tokens (batched prefill) and `decode` generated
    /// tokens (one per decoding slot).
    pub fn on_step(&self, occupied: usize, prefill: usize, decode: usize, seconds: f64) {
        let mut g = self.inner.lock().unwrap();
        g.steps += 1;
        g.batched_slots += occupied as u64;
        g.prefill_tokens += prefill as u64;
        g.decode_tokens += decode as u64;
        g.step_time.record(seconds);
        g.step_seconds_sum += seconds;
        let now = std::time::Instant::now();
        g.started.get_or_insert(now);
        g.finished = Some(now);
    }

    /// Record a finished request from its lifecycle span: latency
    /// histograms (TTFT, latency, queue wait, TPOT for requests that
    /// generated ≥ 2 tokens) plus the span ring.
    pub fn on_complete(&self, span: &SpanRecord) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        g.ttft.record(span.ttft_s);
        g.latency.record(span.latency_s);
        g.queue_wait.record(span.queue_wait_s);
        if span.generated_tokens > 1 {
            g.tpot.record(span.tpot_s);
        }
        g.spans.push(span.clone());
    }

    /// Bytes held by the metric recorders themselves — constant for the
    /// sink's lifetime regardless of request count (pinned by tests).
    pub fn footprint_bytes(&self) -> usize {
        let g = self.inner.lock().unwrap();
        [&g.ttft, &g.latency, &g.tpot, &g.queue_wait, &g.step_time]
            .iter()
            .map(|h| h.footprint_bytes())
            .sum::<usize>()
            + g.spans.footprint_bytes()
    }

    pub fn report(&self) -> MetricsReport {
        let g = self.inner.lock().unwrap();
        // Wall window between the first and last recorded step. With a
        // single step the endpoints coincide and the window is
        // degenerate — fall back to the summed step seconds (exact for
        // one step), or report 0 throughput when nothing ran.
        let window = match (g.started, g.finished) {
            (Some(a), Some(b)) => {
                let w = (b - a).as_secs_f64();
                if w > 0.0 {
                    Some(w)
                } else if g.step_seconds_sum > 0.0 {
                    Some(g.step_seconds_sum)
                } else {
                    None
                }
            }
            _ => None,
        };
        let mut phases: Vec<(String, f64)> =
            g.sched_phases.phases().iter().cloned().collect();
        if let Some(mp) = &g.model_phases {
            phases.extend(mp.phases().iter().cloned());
        }
        if let Some(e) = &g.engine {
            if e.build_seconds + e.read_seconds > 0.0 {
                phases.push(("engine/build".to_string(), e.build_seconds));
                phases.push(("engine/gather".to_string(), e.read_seconds));
            }
        }
        MetricsReport {
            submitted: g.submitted,
            completed: g.completed,
            rejected: g.rejected,
            infeasible: g.infeasible,
            deferred: g.deferred,
            preemptions: g.preemptions,
            preempt_spills: g.preempt_spills,
            preempt_recomputes: g.preempt_recomputes,
            resumes: g.resumes,
            prefill_tokens: g.prefill_tokens,
            decode_tokens: g.decode_tokens,
            steps: g.steps,
            mean_batch: if g.steps > 0 { g.batched_slots as f64 / g.steps as f64 } else { 0.0 },
            ttft: g.ttft.summary(),
            latency: g.latency.summary(),
            tpot: g.tpot.summary(),
            queue_wait: g.queue_wait.summary(),
            step_time: g.step_time.summary(),
            tokens_per_s: window.map(|w| g.decode_tokens as f64 / w).unwrap_or(0.0),
            phases,
            spans: g.spans.recent(),
            spans_total: g.spans.total(),
            spans_dropped: g.spans.dropped(),
            kv: g.kv.clone(),
            engine: g.engine.clone(),
            kernel: g.kernel,
            prof: g.prof.clone(),
            footprint: g
                .footprint
                .map(|p| FootprintAudit::from_parts(p, &CacheSizes::detect())),
        }
    }
}

impl MetricsReport {
    /// Seconds attributed to `phase` (0 when absent).
    pub fn phase_seconds(&self, phase: &str) -> f64 {
        self.phases.iter().find(|(n, _)| n == phase).map(|(_, s)| *s).unwrap_or(0.0)
    }

    /// Share of `phase` within its namespace (`sched/`, `model/`,
    /// `engine/` — the prefix up to `/`), so scheduler, model and engine
    /// attributions each sum to 1 independently.
    pub fn phase_share(&self, phase: &str) -> f64 {
        let ns = phase.split('/').next().unwrap_or("");
        let total: f64 = self
            .phases
            .iter()
            .filter(|(n, _)| n.split('/').next().unwrap_or("") == ns)
            .map(|(_, s)| s)
            .sum();
        if total > 0.0 {
            self.phase_seconds(phase) / total
        } else {
            0.0
        }
    }

    /// Engine Psumbook build share by MACs, straight from the counters
    /// gauge (`None` without engine accounting).
    pub fn build_share_ops(&self) -> Option<f64> {
        self.engine.as_ref().map(|e| e.build_share_ops())
    }

    /// Gather-phase achieved bandwidth (GB/s) from the engine gauge's
    /// read-side byte/seconds split — the numerator `Counters::read_bytes`
    /// (code stream + Psumbook reads + scales) over `read_seconds`.
    /// `None` without engine accounting or before any gather time
    /// accrued. Compare against `prof.gather_gbs_peak` (STREAM triad)
    /// for the % of attainable.
    pub fn gather_gbs_achieved(&self) -> Option<f64> {
        let e = self.engine.as_ref()?;
        if e.read_seconds > 0.0 && e.read_bytes > 0 {
            Some(e.read_bytes as f64 / e.read_seconds / 1e9)
        } else {
            None
        }
    }

    /// Fraction of prefix-cache probes that pinned at least one shared
    /// page, straight from the KV-pool gauge. 0.0 when the backend has
    /// no pool or no probe ever ran (prefix cache off / no admissions).
    pub fn prefix_hit_rate(&self) -> f64 {
        let Some(kv) = &self.kv else { return 0.0 };
        let probes = kv.pool.prefix_hits + kv.pool.prefix_misses;
        if probes == 0 {
            0.0
        } else {
            kv.pool.prefix_hits as f64 / probes as f64
        }
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "requests: {} submitted / {} completed / {} rejected / {} infeasible / {} deferred\n\
             tokens:   {} prefill / {} decode ({:.1} tok/s decode)\n\
             batching: {} steps, mean occupancy {:.2}\n\
             ttft:     p50 {:.1} ms, p95 {:.1} ms\n\
             latency:  p50 {:.1} ms, p95 {:.1} ms\n\
             tpot:     p50 {:.2} ms, p95 {:.2} ms (queue wait p95 {:.1} ms)",
            self.submitted,
            self.completed,
            self.rejected,
            self.infeasible,
            self.deferred,
            self.prefill_tokens,
            self.decode_tokens,
            self.tokens_per_s,
            self.steps,
            self.mean_batch,
            self.ttft.p50 * 1e3,
            self.ttft.p95 * 1e3,
            self.latency.p50 * 1e3,
            self.latency.p95 * 1e3,
            self.tpot.p50 * 1e3,
            self.tpot.p95 * 1e3,
            self.queue_wait.p95 * 1e3,
        );
        if !self.phases.is_empty() {
            let mut parts: Vec<String> = self
                .phases
                .iter()
                .map(|(n, _)| format!("{n} {:.1}%", 100.0 * self.phase_share(n)))
                .collect();
            parts.sort();
            out.push_str(&format!("\nphases:   {}", parts.join(" · ")));
        }
        if self.preemptions > 0 {
            out.push_str(&format!(
                "\npreempt:  {} preemptions ({} spilled / {} recomputed), {} resumes",
                self.preemptions, self.preempt_spills, self.preempt_recomputes, self.resumes,
            ));
        }
        if let Some(kv) = &self.kv {
            out.push_str(&format!(
                "\nkv pool:  {}/{} pages used (hwm {}), {} tok/page, {} dtype, \
                 churn {} alloc / {} free, {} KiB held / {} KiB filled",
                kv.pool.used_pages,
                kv.pool.total_pages,
                kv.pool.used_hwm,
                kv.pool.page_size,
                kv.pool.dtype.as_str(),
                kv.pool.allocated,
                kv.pool.freed,
                kv.held_bytes() / 1024,
                kv.used_bytes() / 1024,
            ));
            if kv.pool.prefix_hits + kv.pool.prefix_misses > 0 {
                out.push_str(&format!(
                    "\nprefix:   hit rate {:.1}% ({} hits / {} misses), \
                     {} tokens served from cache, {} shared pages, \
                     {} evictions, {} CoW copies",
                    100.0 * self.prefix_hit_rate(),
                    kv.pool.prefix_hits,
                    kv.pool.prefix_misses,
                    kv.pool.prefix_hit_tokens,
                    kv.pool.prefix_pages,
                    kv.pool.evictions,
                    kv.pool.cow_copies,
                ));
            }
        }
        if let Some(e) = &self.engine {
            out.push_str(&format!(
                "\nengine:   {} gemm calls, build share {:.1}% (ops), \
                 fused-projection fanout {:.2}/call",
                e.calls,
                100.0 * e.build_share_ops(),
                e.fanout_per_call(),
            ));
            if let Some(k) = &self.kernel {
                out.push_str(&format!(", kernel {} ×{} lanes", k.label(), k.lanes));
            }
            if let Some(gbs) = self.gather_gbs_achieved() {
                out.push_str(&format!(", gather {gbs:.2} GB/s achieved"));
                if let Some(p) = &self.prof {
                    if p.gather_gbs_peak > 0.0 {
                        out.push_str(&format!(
                            " of {:.1} peak ({:.0}%)",
                            p.gather_gbs_peak,
                            100.0 * gbs / p.gather_gbs_peak,
                        ));
                    }
                }
            }
        }
        if let Some(p) = &self.prof {
            out.push_str(&format!(
                "\nprofiler: {} spans ({} dropped), overlap efficiency {:.1}% \
                 ({:.2} ms build hidden / {:.2} ms exposed), barrier occupancy {:.1}%",
                p.events,
                p.dropped,
                100.0 * p.overlap_efficiency,
                p.hidden_build_s * 1e3,
                p.exposed_build_s * 1e3,
                100.0 * p.occupancy,
            ));
        }
        if let Some(f) = &self.footprint {
            out.push_str(&format!(
                "\nfootprint: {} KiB scratch working set (books {} KiB, staging {} KiB) \
                 — fits {}",
                f.total_bytes / 1024,
                (f.book_bytes + f.book2_bytes) / 1024,
                f.staging_bytes / 1024,
                f.level,
            ));
        }
        if self.spans_total > 0 {
            out.push_str(&format!("\nspans:    {} recorded", self.spans_total));
            if self.spans_dropped > 0 {
                out.push_str(&format!(" ({} evicted from the ring)", self.spans_dropped));
            }
            out.push_str("; most recent:");
            for s in self.spans.iter().rev().take(4).rev() {
                out.push_str(&format!("\n  {}", s.render()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::FINISH_LENGTH;

    fn span(id: u64, ttft_s: f64, latency_s: f64) -> SpanRecord {
        SpanRecord {
            id,
            prompt_tokens: 2,
            generated_tokens: 4,
            finish: FINISH_LENGTH,
            queue_wait_s: 0.001,
            prefill_s: 0.002,
            ttft_s,
            decode_s: latency_s - ttft_s,
            latency_s,
            tpot_s: (latency_s - ttft_s) / 3.0,
            prefill_chunks: 1,
            preemptions: 0,
            prefix_hit_tokens: 0,
        }
    }

    #[test]
    fn accumulates() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_step(2, 2, 0, 0.001);
        m.on_step(2, 0, 2, 0.001);
        m.on_complete(&span(1, 0.01, 0.05));
        let r = m.report();
        assert_eq!(r.submitted, 2);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.completed, 1);
        assert_eq!(r.prefill_tokens, 2);
        assert_eq!(r.decode_tokens, 2);
        assert!((r.mean_batch - 2.0).abs() < 1e-9);
        assert!(r.render().contains("mean occupancy 2.00"));
        assert!(r.kv.is_none(), "no pool snapshot recorded");
        assert_eq!(r.spans_total, 1);
        assert_eq!(r.spans[0].id, 1);
    }

    #[test]
    fn histogram_percentiles_track_samples_within_bucket_error() {
        let m = Metrics::new();
        for i in 1..=100 {
            let lat = i as f64 * 1e-3;
            m.on_complete(&span(i, lat / 2.0, lat));
        }
        let r = m.report();
        assert_eq!(r.completed, 100);
        let tol = Histogram::relative_error_bound() + 0.02; // + rank granularity
        assert!((r.latency.p50 - 0.050).abs() / 0.050 <= tol, "p50 {}", r.latency.p50);
        assert!((r.latency.p99 - 0.099).abs() / 0.099 <= tol, "p99 {}", r.latency.p99);
        assert!((r.latency.mean - 0.0505).abs() < 1e-12, "mean stays exact");
    }

    #[test]
    fn tpot_recorded_and_rendered() {
        let m = Metrics::new();
        m.on_complete(&span(1, 0.01, 0.04)); // tpot = 0.01
        let r = m.report();
        assert_eq!(r.tpot.n, 1);
        assert!((r.tpot.p50 - 0.01).abs() < 1e-9);
        assert!(r.render().contains("tpot:"), "{}", r.render());
    }

    #[test]
    fn throughput_window_degenerate_single_step_uses_step_seconds() {
        let m = Metrics::new();
        // One step: started == finished, but 10 decode tokens over a
        // recorded 0.5 s of step time must report 20 tok/s, not 1e10.
        m.on_step(1, 0, 10, 0.5);
        let r = m.report();
        assert!((r.tokens_per_s - 20.0).abs() < 1.0, "tok/s {}", r.tokens_per_s);
    }

    #[test]
    fn throughput_zero_when_nothing_ran() {
        let m = Metrics::new();
        assert_eq!(m.report().tokens_per_s, 0.0);
    }

    #[test]
    fn phases_merge_sched_model_and_engine() {
        let m = Metrics::new();
        m.on_step_phases(&[("sched/prefill", 0.3), ("sched/decode", 0.1)]);
        m.on_step_phases(&[("sched/decode", 0.1)]);
        let mut mp = PhaseTimer::new();
        mp.add("model/gemm", 0.6);
        mp.add("model/attention", 0.2);
        m.on_model_phases(mp);
        m.on_engine(Counters {
            build_seconds: 0.25,
            read_seconds: 0.75,
            build_ops: 1,
            read_ops: 3,
            ..Default::default()
        });
        let r = m.report();
        assert_eq!(r.phase_seconds("sched/prefill"), 0.3);
        assert_eq!(r.phase_seconds("sched/decode"), 0.2, "incremental accumulation");
        assert_eq!(r.phase_seconds("model/gemm"), 0.6);
        assert!((r.phase_share("sched/prefill") - 0.6).abs() < 1e-12);
        assert!((r.phase_share("model/gemm") - 0.75).abs() < 1e-12);
        assert!((r.phase_share("engine/build") - 0.25).abs() < 1e-12);
        assert_eq!(r.build_share_ops(), Some(0.25));
        assert!(r.render().contains("phases:"), "{}", r.render());
    }

    #[test]
    fn metrics_memory_constant_under_many_requests() {
        let m = Metrics::new();
        m.on_complete(&span(0, 0.01, 0.02));
        m.on_step(1, 1, 1, 0.001);
        let fp = m.footprint_bytes();
        for i in 1..5_000 {
            m.on_complete(&span(i, 0.01 + (i % 7) as f64 * 1e-3, 0.05));
            m.on_step(1, 0, 1, 0.001 * ((i % 5) as f64 + 1.0));
        }
        assert_eq!(m.footprint_bytes(), fp, "per-request memory must not grow");
        let r = m.report();
        assert_eq!(r.completed, 5_000);
        assert_eq!(r.spans_total, 5_000);
        assert!(r.spans.len() <= TraceLog::DEFAULT_CAPACITY);
    }

    #[test]
    fn engine_gauge_reports_build_share_and_fanout() {
        let m = Metrics::new();
        // Stale snapshot, then the cumulative one: latest wins.
        m.on_engine(Counters { calls: 1, ..Default::default() });
        m.on_engine(Counters {
            calls: 4,
            build_ops: 10,
            read_ops: 30,
            group_fanout: 10,
            ..Default::default()
        });
        m.on_kernel(KernelSel { imp: crate::config::KernelImpl::Unrolled, lanes: 8 });
        let r = m.report();
        let e = r.engine.as_ref().expect("engine snapshot recorded");
        assert_eq!(e.calls, 4);
        assert_eq!(r.kernel.map(|k| k.lanes), Some(8));
        let rendered = r.render();
        assert!(rendered.contains("build share 25.0%"), "{rendered}");
        assert!(rendered.contains("fanout 2.50/call"), "{rendered}");
        assert!(rendered.contains("kernel unrolled ×8 lanes"), "{rendered}");
    }

    #[test]
    fn prof_and_footprint_gauges_surface_in_report() {
        let m = Metrics::new();
        m.on_engine(Counters {
            read_bytes: 2_000_000_000,
            read_seconds: 1.0,
            read_ops: 1,
            calls: 1,
            ..Default::default()
        });
        m.on_prof(ProfSummary {
            events: 40,
            dropped: 2,
            overlap_efficiency: 0.8,
            hidden_build_s: 0.008,
            exposed_build_s: 0.002,
            occupancy: 0.9,
            gather_gbs_peak: 10.0,
        });
        m.on_footprint((1024, 0, 4096, 4096));
        let r = m.report();
        assert!((r.gather_gbs_achieved().unwrap() - 2.0).abs() < 1e-9);
        let p = r.prof.as_ref().expect("prof gauge recorded");
        assert_eq!(p.events, 40);
        let f = r.footprint.as_ref().expect("footprint gauge recorded");
        assert_eq!(f.total_bytes, 1024 + 4096 + 4096);
        assert_eq!(f.book_bytes + f.book2_bytes, 8192);
        let rendered = r.render();
        assert!(rendered.contains("overlap efficiency 80.0%"), "{rendered}");
        assert!(rendered.contains("gather 2.00 GB/s achieved of 10.0 peak (20%)"), "{rendered}");
        assert!(rendered.contains("footprint:"), "{rendered}");
    }

    #[test]
    fn gather_gbs_none_without_engine_or_time() {
        let m = Metrics::new();
        assert!(m.report().gather_gbs_achieved().is_none());
        m.on_engine(Counters { read_bytes: 100, ..Default::default() });
        assert!(m.report().gather_gbs_achieved().is_none(), "no seconds yet");
        assert!(m.report().prof.is_none());
        assert!(m.report().footprint.is_none());
    }

    #[test]
    fn span_ring_eviction_is_reported_never_silent() {
        let m = Metrics::new();
        for i in 0..(TraceLog::DEFAULT_CAPACITY as u64 + 10) {
            m.on_complete(&span(i, 0.01, 0.02));
        }
        let r = m.report();
        assert_eq!(r.spans_total, TraceLog::DEFAULT_CAPACITY as u64 + 10);
        assert_eq!(r.spans_dropped, 10);
        assert!(r.render().contains("(10 evicted from the ring)"), "{}", r.render());
    }

    #[test]
    fn preempt_counters_and_prefix_hit_rate() {
        use crate::kvcache::PoolStats;
        let m = Metrics::new();
        m.on_preempt(true);
        m.on_preempt(false);
        m.on_preempt(false);
        m.on_resume();
        m.on_resume();
        m.on_kv(KvStats {
            pool: PoolStats {
                total_pages: 8,
                prefix_hits: 3,
                prefix_misses: 1,
                prefix_hit_tokens: 96,
                ..Default::default()
            },
            slot_bytes: vec![0],
            slot_bytes_used: vec![0],
        });
        let r = m.report();
        assert_eq!(r.preemptions, 3);
        assert_eq!(r.preempt_spills, 1);
        assert_eq!(r.preempt_recomputes, 2);
        assert_eq!(r.resumes, 2);
        assert!((r.prefix_hit_rate() - 0.75).abs() < 1e-12);
        let rendered = r.render();
        assert!(rendered.contains("3 preemptions (1 spilled / 2 recomputed), 2 resumes"), "{rendered}");
        assert!(rendered.contains("hit rate 75.0%"), "{rendered}");
        assert!(rendered.contains("96 tokens served from cache"), "{rendered}");
    }

    #[test]
    fn prefix_hit_rate_zero_without_pool_or_probes() {
        let m = Metrics::new();
        assert_eq!(m.report().prefix_hit_rate(), 0.0);
    }

    #[test]
    fn kv_gauge_keeps_latest_snapshot_and_hwm() {
        use crate::kvcache::PoolStats;
        let m = Metrics::new();
        m.on_admit_defer();
        m.on_kv(KvStats {
            pool: PoolStats { total_pages: 8, used_pages: 6, used_hwm: 6, ..Default::default() },
            slot_bytes: vec![1024, 0],
            slot_bytes_used: vec![512, 0],
        });
        m.on_kv(KvStats {
            pool: PoolStats { total_pages: 8, used_pages: 1, used_hwm: 6, ..Default::default() },
            slot_bytes: vec![256, 0],
            slot_bytes_used: vec![128, 0],
        });
        let r = m.report();
        assert_eq!(r.deferred, 1);
        let kv = r.kv.expect("snapshot recorded");
        assert_eq!(kv.pool.used_pages, 1, "gauge keeps the latest snapshot");
        assert_eq!(kv.pool.used_hwm, 6, "lifetime high-water mark rides the snapshot");
        assert_eq!(kv.held_bytes(), 256);
        assert!(r.render().contains("kv pool:"));
        assert!(r.render().contains("1 deferred"));
    }
}
