//! Continuous (iteration-level) dynamic batcher.
//!
//! Orca/vLLM-style scheduling over a two-phase step: prefilling
//! sequences consume their prompts in batched chunks under a **shared
//! per-step prefill token budget** (`ServeConfig::prefill_budget`,
//! spread round-robin across prefilling slots — so decode stall per step
//! is bounded regardless of how many prompts are in flight, the
//! per-slot-cap gap the roadmap called out), then all decoding sequences
//! advance one token per step — so new requests join the batch *between*
//! steps without draining it ("continuous batching"). Chunks run through
//! `DecodeBackend::prefill` → `forward_batch_logits` as true `m_batch =
//! chunk_len` GEMMs (Psumbook build amortized), and non-final chunks pass
//! `want_logits = false` so the lm_head GEMM whose logits would be
//! discarded is skipped.
//!
//! Admission is gated twice: a bounded queue (reject) and, for
//! pool-backed backends, KV pages (`DecodeBackend::can_admit` — the head
//! request waits until the prompt's pages plus one growth page are free,
//! counted as a *deferral* in metrics, FIFO preserved). Completion
//! reclaims the sequence's pages, unblocking the queue.
//! `coordinator::metrics` reports prefill/decode token counts and the
//! pool occupancy snapshot per step.

use super::backend::{DecodeBackend, SlotStep};
use super::metrics::Metrics;
use super::request::{FinishReason, InFlight, Request, Response};
use crate::config::ServeConfig;
use crate::model::Sampler;
use crate::obs::trace::{self, SpanRecord};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// Span-schema name for a finish reason (`obs::trace` is stringly typed
/// so the trace schema stays decoupled from the enum).
fn finish_str(reason: FinishReason) -> &'static str {
    match reason {
        FinishReason::Length => trace::FINISH_LENGTH,
        FinishReason::Stop => trace::FINISH_STOP,
        FinishReason::Context => trace::FINISH_CONTEXT,
        FinishReason::Rejected => trace::FINISH_REJECTED,
    }
}

/// Slot state.
enum Slot {
    Free,
    Busy(InFlight),
}

/// The batcher owns the backend, the admission queue and the slot table.
pub struct Batcher {
    backend: Box<dyn DecodeBackend>,
    cfg: ServeConfig,
    slots: Vec<Slot>,
    queue: VecDeque<Request>,
    sampler: Sampler,
    pub metrics: Arc<Metrics>,
    finished: Vec<Response>,
    /// Rotating start slot for the prefill budget scan, so a tight budget
    /// round-robins across prefilling slots instead of starving the
    /// highest-numbered ones.
    prefill_rr: usize,
    /// Sampling seconds accumulated by `advance_after_logits` since the
    /// last drain — lets `step` subtract sampling out of the prefill and
    /// decode phases so `sched/*` attribution is exclusive.
    sample_s: f64,
}

impl Batcher {
    pub fn new(backend: Box<dyn DecodeBackend>, cfg: ServeConfig, metrics: Arc<Metrics>) -> Batcher {
        let n = backend.max_batch().min(cfg.max_batch.max(1));
        Batcher {
            backend,
            sampler: Sampler::new(cfg.temperature, 0x5EED),
            cfg,
            slots: (0..n).map(|_| Slot::Free).collect(),
            queue: VecDeque::new(),
            metrics,
            finished: Vec::new(),
            prefill_rr: 0,
            sample_s: 0.0,
        }
    }

    /// Enqueue a request (admission control: bounded queue).
    pub fn submit(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.cfg.queue_capacity {
            self.metrics.on_reject();
            return false;
        }
        self.metrics.on_submit();
        self.queue.push_back(req);
        true
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s, Slot::Busy(_))).count()
    }

    pub fn is_idle(&self) -> bool {
        self.occupied() == 0 && self.queue.is_empty()
    }

    /// A request's worst-case KV footprint in positions: the whole
    /// prompt plus its generation budget (backends clamp to the context
    /// window). Admission gates and reservations both use this bound, so
    /// an admitted sequence can never exhaust the pool mid-decode.
    fn lifetime_tokens(req: &Request) -> usize {
        req.prompt.len().saturating_add(req.max_new_tokens)
    }

    /// Move queued requests into free slots (the router step). FIFO: the
    /// head request must fit the backend's KV pool
    /// ([`DecodeBackend::can_admit`] over its whole-lifetime footprint)
    /// or admission stops for this step — a deferral, counted in
    /// metrics; later completions reclaim pages and unblock it. A head
    /// request that could never fit even an *empty* pool is rejected
    /// with [`FinishReason::Rejected`] instead of deferring forever.
    fn admit(&mut self) {
        let mut deferred = false;
        for i in 0..self.slots.len() {
            // Drop queue heads that no amount of reclamation could ever
            // admit (footprint > whole pool) — deferring them would
            // livelock the queue behind an unsatisfiable request.
            while let Some(req) = self.queue.front() {
                if self.backend.can_ever_admit(Self::lifetime_tokens(req)) {
                    break;
                }
                let req = self.queue.pop_front().unwrap();
                let queue_wait_s = req.created.elapsed().as_secs_f64();
                self.metrics.on_infeasible(&SpanRecord {
                    id: req.id,
                    prompt_tokens: req.prompt.len(),
                    generated_tokens: 0,
                    finish: trace::FINISH_REJECTED,
                    queue_wait_s,
                    prefill_s: 0.0,
                    ttft_s: 0.0,
                    decode_s: 0.0,
                    latency_s: queue_wait_s,
                    tpot_s: 0.0,
                    prefill_chunks: 0,
                });
                self.finished.push(Response {
                    id: req.id,
                    tokens: Vec::new(),
                    finish: FinishReason::Rejected,
                    ttft_s: 0.0,
                    latency_s: 0.0,
                    tok_per_s: 0.0,
                });
            }
            let need_tokens = match self.queue.front() {
                Some(req) => Self::lifetime_tokens(req),
                None => break,
            };
            if !matches!(self.slots[i], Slot::Free) {
                continue;
            }
            if !self.backend.can_admit(need_tokens) {
                deferred = true;
                break;
            }
            let req = self.queue.pop_front().unwrap();
            self.backend.reset_slot(i);
            // Pre-claim the sequence's whole-lifetime pages so the next
            // iteration's `can_admit` sees the reduced free count and
            // decode growth never races the free list.
            self.backend.reserve(i, need_tokens);
            self.slots[i] = Slot::Busy(InFlight::new(req));
        }
        if deferred {
            self.metrics.on_admit_defer();
        }
    }

    /// Run one engine step: batched prefill across prefilling slots under
    /// the shared `prefill_budget` token cap (decode stall per step is
    /// bounded by the budget, not by the number of prefilling slots),
    /// then one decode token for every decoding slot. Returns the number
    /// of slots advanced (0 ⇒ idle).
    pub fn step(&mut self) -> usize {
        self.admit();
        let max_seq = self.backend.max_seq();
        let t0 = Instant::now();
        let mut advanced = 0usize;
        let mut prefill_tokens = 0usize;
        let n = self.slots.len();
        let mut just_prefilled = vec![false; n];

        // Phase 1: batched prefill under the shared per-step token
        // budget, scanned round-robin from a rotating start slot. A
        // partially prefilled slot (or one skipped when the budget ran
        // out) simply resumes on a later step; the final position's
        // logits seed the first sampled token.
        let mut budget = self.cfg.prefill_budget.max(1);
        let start = if n > 0 { self.prefill_rr % n } else { 0 };
        for off in 0..n {
            if budget == 0 {
                break;
            }
            let i = (start + off) % n;
            let (feed, pos, finishes_prompt) = match &self.slots[i] {
                Slot::Busy(f) if f.is_prefilling() => {
                    let remaining = &f.req.prompt[f.prefill_idx..];
                    // Clamp to the context window (an over-long prompt
                    // finishes with `FinishReason::Context` below) and to
                    // what's left of the shared step budget.
                    let room = max_seq.saturating_sub(f.pos).min(budget);
                    if room == 0 {
                        continue;
                    }
                    let take = remaining.len().min(room);
                    (remaining[..take].to_vec(), f.pos, take == remaining.len())
                }
                _ => continue,
            };
            // Logits are only needed when this chunk completes the prompt
            // (they seed the first sampled token); otherwise the backend
            // skips the lm_head GEMM.
            let logits = self
                .backend
                .prefill(i, &feed, pos, finishes_prompt)
                .expect("backend prefill failed");
            budget -= feed.len();
            prefill_tokens += feed.len();
            advanced += 1;
            just_prefilled[i] = true;
            let Slot::Busy(f) = &mut self.slots[i] else { unreachable!() };
            f.prefill_idx += feed.len();
            f.pos += feed.len();
            f.prefill_chunks += 1;
            if finishes_prompt {
                f.prefill_done = Some(Instant::now());
            }
            self.advance_after_logits(i, logits.as_deref().unwrap_or(&[]), max_seq);
        }
        if n > 0 {
            self.prefill_rr = (self.prefill_rr + 1) % n;
        }
        // Sampling time inside phase 1 (final-chunk logits seed the first
        // token) — drained so the sched/* phases stay exclusive.
        let sample_p1 = std::mem::take(&mut self.sample_s);
        let prefill_s = t0.elapsed().as_secs_f64() - sample_p1;
        let t1 = Instant::now();

        // Phase 2: one decode token for every slot already decoding.
        let mut steps: Vec<SlotStep> = Vec::new();
        for (i, s) in self.slots.iter().enumerate() {
            if let Slot::Busy(f) = s {
                if !f.is_prefilling() && !just_prefilled[i] {
                    steps.push(SlotStep { slot: i, token: f.next_input(), pos: f.pos });
                }
            }
        }
        let decode_n = steps.len();
        if decode_n > 0 {
            let logits = self.backend.step(&steps).expect("backend step failed");
            advanced += decode_n;
            for (ss, lg) in steps.iter().zip(&logits) {
                let Slot::Busy(f) = &mut self.slots[ss.slot] else { unreachable!() };
                f.pos += 1;
                self.advance_after_logits(ss.slot, lg, max_seq);
            }
        }
        let sample_p2 = std::mem::take(&mut self.sample_s);
        let decode_s = t1.elapsed().as_secs_f64() - sample_p2;
        if advanced > 0 {
            self.metrics.on_step(advanced, prefill_tokens, decode_n, t0.elapsed().as_secs_f64());
            // Scheduler phase attribution: prefill and decode wall time
            // with sampling carved out into its own phase.
            self.metrics.on_step_phases(&[
                ("sched/prefill", prefill_s.max(0.0)),
                ("sched/decode", decode_s.max(0.0)),
                ("sched/sample", sample_p1 + sample_p2),
            ]);
            // Pool occupancy gauge (post-step, so reclamation shows up).
            if let Some(kv) = self.backend.kv_stats() {
                self.metrics.on_kv(kv);
            }
            // Engine work gauge (cumulative counters: latest wins).
            if let Some(eng) = self.backend.engine_counters() {
                self.metrics.on_engine(eng);
            }
            // Kernel dispatch gauge (fixed at backend construction, so
            // re-recording the same value each step is idempotent).
            if let Some(sel) = self.backend.kernel_sel() {
                self.metrics.on_kernel(sel);
            }
            // Model forward phase gauge (cumulative timer: latest wins).
            if let Some(p) = self.backend.phases() {
                self.metrics.on_model_phases(p);
            }
        }
        advanced
    }

    /// Shared post-GEMM bookkeeping for a slot whose position just
    /// advanced past `logits`' token: sample when decoding, then retire
    /// the sequence if any finish condition hit.
    fn advance_after_logits(&mut self, slot_idx: usize, logits: &[f32], max_seq: usize) {
        let slot = &mut self.slots[slot_idx];
        let Slot::Busy(f) = slot else { unreachable!() };
        let mut finish: Option<FinishReason> = None;
        if !f.is_prefilling() {
            // Sample the next token (valid both for the final prefill
            // position's logits and for decode steps).
            let ts = Instant::now();
            let tok = self.sampler.sample(logits);
            self.sample_s += ts.elapsed().as_secs_f64();
            if f.first_token.is_none() {
                f.first_token = Some(Instant::now());
            }
            f.generated.push(tok);
            if f.req.stop_token == Some(tok) {
                finish = Some(FinishReason::Stop);
            } else if f.generated.len() >= f.req.max_new_tokens {
                finish = Some(FinishReason::Length);
            }
        }
        if finish.is_none() && f.pos >= max_seq {
            finish = Some(FinishReason::Context);
        }
        if let Some(reason) = finish {
            // Lifecycle attribution, all anchored at submit time
            // (`req.created`) so TTFT/latency are client-visible:
            // queue wait → prefill → first token → decode → finish.
            let now = Instant::now();
            let created = f.req.created;
            let ttft = f.first_token.map(|t| (t - created).as_secs_f64()).unwrap_or_default();
            let latency = (now - created).as_secs_f64();
            let decode_time = f.first_token.map(|t| (now - t).as_secs_f64()).unwrap_or(0.0);
            let n_gen = f.generated.len();
            let span = SpanRecord {
                id: f.req.id,
                prompt_tokens: f.req.prompt.len(),
                generated_tokens: n_gen,
                finish: finish_str(reason),
                queue_wait_s: (f.admitted - created).as_secs_f64(),
                prefill_s: f.prefill_done.map(|t| (t - f.admitted).as_secs_f64()).unwrap_or(0.0),
                ttft_s: ttft,
                decode_s: decode_time,
                latency_s: latency,
                tpot_s: if n_gen > 1 { decode_time / (n_gen - 1) as f64 } else { 0.0 },
                prefill_chunks: f.prefill_chunks,
            };
            let resp = Response {
                id: f.req.id,
                tokens: std::mem::take(&mut f.generated),
                finish: reason,
                ttft_s: ttft,
                latency_s: latency,
                tok_per_s: if n_gen > 1 {
                    (n_gen - 1) as f64 / decode_time.max(1e-9)
                } else {
                    0.0
                },
            };
            self.metrics.on_complete(&span);
            self.finished.push(resp);
            *slot = Slot::Free;
            // Reclaim the sequence's KV pages immediately (not at the
            // slot's next assignment) so deferred requests can admit as
            // soon as capacity exists.
            self.backend.reset_slot(slot_idx);
        }
    }

    /// Drain finished responses.
    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// Run until every queued/in-flight request completes; returns all
    /// responses. (The offline/batch entrypoint; the server wraps `step`
    /// for online serving.)
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        while !self.is_idle() {
            self.step();
            out.extend(self.take_finished());
        }
        out
    }

    pub fn backend_label(&self) -> String {
        self.backend.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::coordinator::backend::NativeBackend;
    use crate::model::{EngineKind, ModelWeights};

    fn mk_batcher(max_batch: usize, queue_cap: usize) -> Batcher {
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let backend = Box::new(NativeBackend::new(&w, EngineKind::Dense, max_batch));
        let cfg = ServeConfig {
            max_batch,
            queue_capacity: queue_cap,
            max_new_tokens: 4,
            temperature: 0.0,
            ..Default::default()
        };
        Batcher::new(backend, cfg, Arc::new(Metrics::new()))
    }

    #[test]
    fn single_request_completes_with_exact_token_budget() {
        let mut b = mk_batcher(2, 8);
        b.submit(Request::new(7, vec![1, 2, 3], 4));
        let out = b.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 7);
        assert_eq!(out[0].tokens.len(), 4);
        assert_eq!(out[0].finish, FinishReason::Length);
    }

    #[test]
    fn batched_equals_sequential_greedy() {
        // Continuous batching must not change greedy outputs.
        let prompts: Vec<Vec<usize>> = vec![vec![5, 6], vec![100, 101, 102], vec![9]];
        let mut seq_out = Vec::new();
        for p in &prompts {
            let mut b = mk_batcher(1, 8);
            b.submit(Request::new(0, p.clone(), 4));
            seq_out.push(b.run_to_completion().remove(0).tokens);
        }
        let mut b = mk_batcher(3, 8);
        for (i, p) in prompts.iter().enumerate() {
            b.submit(Request::new(i as u64, p.clone(), 4));
        }
        let mut batched = b.run_to_completion();
        batched.sort_by_key(|r| r.id);
        for (i, r) in batched.iter().enumerate() {
            assert_eq!(r.tokens, seq_out[i], "request {i} diverged under batching");
        }
    }

    #[test]
    fn queue_overflow_rejects() {
        let mut b = mk_batcher(1, 2);
        assert!(b.submit(Request::new(1, vec![1], 2)));
        assert!(b.submit(Request::new(2, vec![1], 2)));
        assert!(!b.submit(Request::new(3, vec![1], 2)));
        assert_eq!(b.metrics.report().rejected, 1);
    }

    #[test]
    fn more_requests_than_slots_all_complete() {
        let mut b = mk_batcher(2, 16);
        for i in 0..6 {
            b.submit(Request::new(i, vec![(i as usize) % 200 + 1, 2], 3));
        }
        let out = b.run_to_completion();
        assert_eq!(out.len(), 6);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        // Slots were actually shared.
        assert!(b.metrics.report().mean_batch > 1.0);
    }

    #[test]
    fn stop_token_halts_generation() {
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let backend = Box::new(NativeBackend::new(&w, EngineKind::Dense, 1));
        let cfg = ServeConfig { max_batch: 1, max_new_tokens: 64, temperature: 0.0, ..Default::default() };
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        // Find what greedy generates first, then use it as the stop token.
        let mut probe = mk_batcher(1, 4);
        probe.submit(Request::new(0, vec![1, 2], 1));
        let first = probe.run_to_completion()[0].tokens[0];
        let mut req = Request::new(1, vec![1, 2], 64);
        req.stop_token = Some(first);
        b.submit(req);
        let out = b.run_to_completion();
        assert_eq!(out[0].finish, FinishReason::Stop);
        assert_eq!(out[0].tokens.len(), 1);
    }

    #[test]
    fn context_limit_terminates() {
        let mut b = mk_batcher(1, 4);
        let long_prompt: Vec<usize> = (0..120).map(|i| (i % 250) + 1).collect();
        b.submit(Request::new(1, long_prompt, 1000));
        let out = b.run_to_completion();
        assert_eq!(out[0].finish, FinishReason::Context);
        // Positions 0..119 hold the prompt; forwards at 119..=127 each
        // produce one sampled token ⇒ 9 generated, all 128 positions used.
        assert_eq!(out[0].tokens.len(), 9);
    }

    #[test]
    fn shared_prefill_budget_bounds_tokens_per_step() {
        // Two slots, both prefilling 40-token prompts, budget 16: each
        // step consumes at most 16 prompt tokens *total* (not per slot),
        // and the round-robin start lets both slots make progress.
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let backend = Box::new(NativeBackend::new(&w, EngineKind::Dense, 2));
        let cfg = ServeConfig {
            max_batch: 2,
            max_new_tokens: 2,
            temperature: 0.0,
            prefill_budget: 16,
            ..Default::default()
        };
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        let prompt: Vec<usize> = (0..40).map(|i| (i % 200) + 1).collect();
        b.submit(Request::new(0, prompt.clone(), 2));
        b.submit(Request::new(1, prompt.clone(), 2));
        let mut before = 0u64;
        while !b.is_idle() {
            b.step();
            let after = b.metrics.report().prefill_tokens;
            assert!(after - before <= 16, "step consumed {} prefill tokens", after - before);
            before = after;
        }
        let out = b.take_finished();
        assert_eq!(out.len(), 2);
        assert_eq!(b.metrics.report().prefill_tokens, 80);
    }

    #[test]
    fn budget_constrained_batched_equals_sequential_greedy() {
        // A tight shared budget changes scheduling, never outputs.
        let prompts: Vec<Vec<usize>> = vec![
            (0..20).map(|i| (i * 3) % 200 + 1).collect(),
            (0..11).map(|i| (i * 7) % 200 + 1).collect(),
            vec![9, 10, 11],
        ];
        let mk = |batch: usize| {
            let w = ModelWeights::random(ModelConfig::tiny(), 3);
            let backend = Box::new(NativeBackend::new(&w, EngineKind::Dense, batch));
            let cfg = ServeConfig {
                max_batch: batch,
                max_new_tokens: 4,
                temperature: 0.0,
                prefill_budget: 8,
                ..Default::default()
            };
            Batcher::new(backend, cfg, Arc::new(Metrics::new()))
        };
        let mut seq_out = Vec::new();
        for p in &prompts {
            let mut b = mk(1);
            b.submit(Request::new(0, p.clone(), 4));
            seq_out.push(b.run_to_completion().remove(0).tokens);
        }
        let mut b = mk(3);
        for (i, p) in prompts.iter().enumerate() {
            b.submit(Request::new(i as u64, p.clone(), 4));
        }
        let mut batched = b.run_to_completion();
        batched.sort_by_key(|r| r.id);
        for (i, r) in batched.iter().enumerate() {
            assert_eq!(r.tokens, seq_out[i], "request {i} diverged under a tight budget");
        }
    }

    #[test]
    fn pool_exhaustion_defers_admission_then_reclaims() {
        use crate::config::KvConfig;
        // Pool of 2 pages × 4 tokens: one request's lifetime footprint
        // (3 prompt + 3 generated → 2 pages) takes the whole pool, so a
        // second request must wait for the first to finish and release
        // its pages — admission is gated by pool pages, not by the 4
        // free slots.
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let kv = KvConfig { page_size: 4, pool_pages: 2 };
        let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 4, &kv));
        let cfg = ServeConfig {
            max_batch: 4,
            max_new_tokens: 3,
            temperature: 0.0,
            queue_capacity: 8,
            ..Default::default()
        };
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        for i in 0..3 {
            b.submit(Request::new(i, vec![1, 2, 3], 3));
        }
        // First step: only one request fits the pool; the rest defer.
        b.step();
        assert_eq!(b.occupied(), 1, "pool must gate admission below slot count");
        assert!(b.queue_depth() >= 1);
        let out = b.run_to_completion();
        assert_eq!(out.len(), 3, "deferred requests complete after reclamation");
        assert!(out.iter().all(|r| r.tokens.len() == 3), "deferral must not truncate");
        let report = b.metrics.report();
        assert!(report.deferred > 0, "deferrals must be observable");
        // Full reclamation: every page is back on the free list.
        let kv_stats = report.kv.expect("pool-backed backend reports kv stats");
        assert_eq!(kv_stats.pool.free_pages, kv_stats.pool.total_pages);
        assert!(kv_stats.pool.freed >= 3, "each completed request frees its pages");
    }

    #[test]
    fn impossible_request_rejected_not_livelocked() {
        use crate::config::KvConfig;
        // Pool capacity is 2 pages × 16 tokens = 32 positions; a request
        // whose lifetime footprint (10 prompt + 30 generated = 40) can
        // never fit must be rejected — deferring it would head-of-line
        // block the queue forever. A feasible request behind it must
        // still be served.
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let kv = KvConfig { page_size: 16, pool_pages: 2 };
        let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
        let cfg = ServeConfig {
            max_batch: 2,
            max_new_tokens: 30,
            temperature: 0.0,
            queue_capacity: 8,
            ..Default::default()
        };
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        b.submit(Request::new(1, (1..=10).collect(), 30));
        b.submit(Request::new(2, vec![1, 2, 3], 4));
        let mut out = b.run_to_completion();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].finish, FinishReason::Rejected);
        assert!(out[0].tokens.is_empty());
        assert_eq!(out[1].finish, FinishReason::Length);
        assert_eq!(out[1].tokens.len(), 4);
        let report = b.metrics.report();
        assert_eq!(report.infeasible, 1);
        assert_eq!(report.rejected, 0, "queue-full rejects are a separate counter");
    }
}
